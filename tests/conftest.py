"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the real 1-CPU world;
only launch/dryrun.py requests 512 placeholder devices (and only in its own
process)."""
import threading

import jax
import numpy as np
import pytest


@pytest.fixture(autouse=True)
def no_leaked_reader_threads():
    """Every chunk-reader thread must be joined by its stream's close() —
    a reader surviving a test is a leak (the CI persistence job asserts
    the same across processes)."""
    yield
    from repro.data.pipeline import AsyncChunkReader

    leaked = [t.name for t in threading.enumerate()
              if t.name == AsyncChunkReader.THREAD_NAME and t.is_alive()]
    assert not leaked, f"leaked chunk-reader threads: {leaked}"


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
