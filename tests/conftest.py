"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the real 1-CPU world;
only launch/dryrun.py requests 512 placeholder devices (and only in its own
process)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
