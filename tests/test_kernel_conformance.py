"""Differential conformance harness: Pallas kernels vs the ref.py oracles.

Every kernel the engine can route to (ed_matrix / ed_min / lb_sax / wkv6) is
exercised through the production entry points (``kernels/ops.py`` wrappers,
so the ragged padding/tiling layer is under test too) and compared against
the straight-line jnp oracle, across dtypes, ragged tails, degenerate shapes
and adversarial values. Property-based cases run when hypothesis is
installed (requirements-dev.txt; the CI kernel leg); the example-based cases
below them run everywhere.

Execution mode comes from ``REPRO_KERNEL_MODE`` (default ``interpret`` — the
same kernel bodies on the Pallas interpreter; set ``pallas`` on a TPU host
to run the compiled Mosaic kernels against the same oracle).

Tolerance policy
----------------
The oracles accumulate in float32. Kernels compute the same math after a
rearrangement (blocked accumulation; the matmul identity
``||q-s||^2 = ||q||^2 + ||s||^2 - 2 q.s`` for ED), so agreement is limited
by fp32 cancellation, which scales with the *squared* input magnitude:

* float32 inputs: ``rtol = atol = 1e-4`` at unit scale; ``atol`` scales by
  ``scale**2`` for magnitude-``scale`` inputs (distances are quadratic).
* bfloat16 inputs (8-bit mantissa): inputs are quantized before either path
  runs, so both see identical arrays; the comparison tolerance reflects
  fp32-vs-fp32 accumulation of quantized values plus bf16 output rounding
  where the kernel stores bf16: ``rtol = 0.05``, ``atol = 0.25`` at unit
  scale.

Integer results (argmin indices) must be exactly equal, including on ties
(both paths resolve ties to the lowest index).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import summaries as S
from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")

MODE = os.environ.get("REPRO_KERNEL_MODE", "interpret")

_TOL = {
    jnp.dtype(jnp.float32): dict(rtol=1e-4, atol=1e-4),
    jnp.dtype(jnp.bfloat16): dict(rtol=5e-2, atol=2.5e-1),
}


def assert_close(got, want, dtype=jnp.float32, scale: float = 1.0):
    tol = _TOL[jnp.dtype(dtype)]
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol["rtol"], atol=tol["atol"] * max(scale, 1.0) ** 2)


def _qs(seed, q, n, length, dtype=jnp.float32, scale=1.0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return ((jax.random.normal(k1, (q, length)) * scale).astype(dtype),
            (jax.random.normal(k2, (n, length)) * scale).astype(dtype))


# ---------------------------------------------------------------------------
# ed_matrix
# ---------------------------------------------------------------------------

class TestEDMatrixConformance:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 9), st.integers(1, 130),
           st.integers(1, 96))
    def test_property_ragged_shapes(self, seed, q, n, length):
        qa, sa = _qs(seed, q, n, length)
        out = ops.ed_matrix(qa, sa, mode=MODE)
        assert_close(out, ref.ed_matrix_ref(qa, sa))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("q,n,length", [
        (1, 1, 1),          # fully degenerate
        (1, 100, 128),      # single query, ragged rows
        (5, 77, 48),        # ragged everything
        (8, 129, 33),       # one past a block boundary
    ])
    def test_shapes_dtypes(self, q, n, length, dtype):
        qa, sa = _qs(0, q, n, length, dtype)
        out = ops.ed_matrix(qa, sa, mode=MODE)
        assert_close(out, ref.ed_matrix_ref(qa, sa), dtype)

    def test_constant_series(self):
        # constant inputs: every distance is an exact multiple, incl. 0
        qa = jnp.ones((3, 32))
        sa = jnp.concatenate([jnp.ones((2, 32)), jnp.zeros((2, 32)),
                              jnp.full((1, 32), 2.0)])
        out = ops.ed_matrix(qa, sa, mode=MODE)
        assert_close(out, ref.ed_matrix_ref(qa, sa))

    def test_inf_adjacent_magnitudes(self):
        # |x| ~ 1e18: squares ~ 1e36, sums stay below f32 max (3.4e38)
        qa, sa = _qs(1, 3, 17, 24, scale=1.0e18)
        out = ops.ed_matrix(qa, sa, mode=MODE)
        want = ref.ed_matrix_ref(qa, sa)
        assert np.all(np.isfinite(np.asarray(want)))
        assert_close(out, want, scale=1.0e18)


# ---------------------------------------------------------------------------
# ed_min (fused 1-NN)
# ---------------------------------------------------------------------------

class TestEDMinConformance:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 9), st.integers(1, 130),
           st.integers(1, 96))
    def test_property_ragged_shapes(self, seed, q, n, length):
        qa, sa = _qs(seed, q, n, length)
        dmin, amin = ops.ed_min(qa, sa, mode=MODE)
        want_d, want_a = ref.ed_min_ref(qa, sa)
        assert_close(dmin, want_d)
        # exact argmin equality is only guaranteed when the runner-up lies
        # outside the matmul-identity fp32 rounding band (selection on
        # hypothesis-random draws can legitimately flip inside it); exact
        # ties and deterministic cases are pinned by the example tests below
        d_all = np.sort(np.asarray(ref.ed_matrix_ref(qa, sa)), axis=1)
        gap = (d_all[:, 1] - d_all[:, 0] if d_all.shape[1] > 1
               else np.full(d_all.shape[0], np.inf))
        decisive = gap > 1e-3 * np.maximum(d_all[:, 0], 1.0)
        np.testing.assert_array_equal(np.asarray(amin)[decisive],
                                      np.asarray(want_a)[decisive])

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("q,n,length", [(1, 1, 1), (3, 13, 64),
                                            (5, 77, 48)])
    def test_shapes_dtypes(self, q, n, length, dtype):
        qa, sa = _qs(2, q, n, length, dtype)
        dmin, amin = ops.ed_min(qa, sa, mode=MODE)
        want_d, want_a = ref.ed_min_ref(qa, sa)
        assert_close(dmin, want_d, dtype)
        np.testing.assert_array_equal(np.asarray(amin), np.asarray(want_a))

    def test_tie_break_on_duplicate_rows(self):
        # constant collection: every row ties; argmin must be the lowest
        # index in both paths
        qa = jnp.zeros((4, 16))
        sa = jnp.ones((11, 16))
        dmin, amin = ops.ed_min(qa, sa, mode=MODE)
        want_d, want_a = ref.ed_min_ref(qa, sa)
        assert_close(dmin, want_d)
        np.testing.assert_array_equal(np.asarray(amin), np.asarray(want_a))
        assert np.all(np.asarray(amin) == 0)

    def test_all_inf_distances_match_oracle(self):
        # magnitudes past sqrt(f32 max): every squared distance overflows to
        # inf. The fold must still match the oracle (dmin=inf, argmin=0) —
        # a finite init sentinel would silently saturate instead.
        qa = jnp.full((2, 16), 2.0e19, jnp.float32)
        sa = jnp.full((5, 16), -2.0e19, jnp.float32)
        dmin, amin = ops.ed_min(qa, sa, mode=MODE)
        want_d, want_a = ref.ed_min_ref(qa, sa)
        assert np.all(np.isinf(np.asarray(want_d)))
        np.testing.assert_array_equal(np.asarray(dmin), np.asarray(want_d))
        np.testing.assert_array_equal(np.asarray(amin), np.asarray(want_a))

    def test_adversarial_constant_huge_ragged(self):
        # regression for the old sentinel-row padding: a constant
        # huge-magnitude query matching the last (ragged-tail) row must
        # select that row, not a padding artifact
        qc = jnp.full((3, 32), 1.0e18, jnp.float32)
        sc = jnp.concatenate(
            [_qs(3, 1, 9, 32, scale=1e18)[1], qc[:1]], axis=0)   # 10 rows
        dmin, amin = ops.ed_min(qc, sc, mode=MODE)
        want_d, want_a = ref.ed_min_ref(qc, sc)
        np.testing.assert_array_equal(np.asarray(amin), np.asarray(want_a))
        assert np.all(np.asarray(amin) == 9)
        assert_close(dmin, want_d, scale=1e18)


# ---------------------------------------------------------------------------
# decode_bf16 + ed_matrix (fused codec decode, format v3)
# ---------------------------------------------------------------------------

def _bf16_payload(seed, q, n, length, scale=1.0):
    """Queries + the byte image of bf16-quantized rows (what Bf16Codec's
    payload prefix stores), via the same astype both codec and XLA use."""
    qa, sa = _qs(seed, q, n, length, scale=scale)
    payload = np.asarray(sa.astype(jnp.bfloat16)).view(np.uint8)
    return qa, jnp.asarray(payload.reshape(n, 2 * length))


class TestDecodeBf16EDConformance:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 9), st.integers(1, 130),
           st.integers(1, 96))
    def test_property_ragged_shapes(self, seed, q, n, length):
        qa, payload = _bf16_payload(seed, q, n, length)
        out = ops.decode_bf16_ed_matrix(qa, payload, mode=MODE)
        assert_close(out, ref.decode_bf16_ed_matrix_ref(qa, payload))

    @pytest.mark.parametrize("q,n,length", [
        (1, 1, 1),          # fully degenerate
        (1, 100, 128),      # single query, ragged rows
        (5, 77, 48),        # ragged everything
        (8, 129, 33),       # one past a block boundary
    ])
    def test_shapes(self, q, n, length):
        qa, payload = _bf16_payload(0, q, n, length)
        out = ops.decode_bf16_ed_matrix(qa, payload, mode=MODE)
        assert_close(out, ref.decode_bf16_ed_matrix_ref(qa, payload))

    def test_decode_matches_numpy_bitcast(self):
        # the byte image decodes to exactly the bf16 values (upcast exact)
        _, payload = _bf16_payload(3, 1, 13, 40)
        rows = ref.decode_bf16_ref(payload)
        want = np.asarray(payload, np.uint8).reshape(13, 40, 2) \
            .view("<u2").squeeze(-1).astype(np.uint32) << 16
        want = want.view(np.float32).reshape(13, 40)
        np.testing.assert_array_equal(np.asarray(rows), want)

    def test_fused_matches_codec_decode_then_ed(self):
        # the fused entry point == Bf16Codec.decode followed by ed_matrix:
        # the engine's kernel-mode branch and generic branch agree
        from repro.storage.codecs import get_codec

        codec = get_codec("bf16")
        rng = np.random.default_rng(7)
        block = rng.normal(size=(33, 48)).astype(np.float32) * 3.0
        enc = jnp.asarray(codec.encode(block))
        payload, _ = codec.split(enc)
        qa = jnp.asarray(rng.normal(size=(4, 48)).astype(np.float32))
        fused = ops.decode_bf16_ed_matrix(qa, payload, mode=MODE)
        rows, _ = codec.decode(enc, 48)
        assert_close(fused, ref.ed_matrix_ref(qa, rows))

    def test_large_magnitudes(self):
        # bf16 keeps f32's exponent range: 1e18-scale rows stay finite
        qa, payload = _bf16_payload(2, 3, 17, 24, scale=1.0e18)
        out = ops.decode_bf16_ed_matrix(qa, payload, mode=MODE)
        want = ref.decode_bf16_ed_matrix_ref(qa, payload)
        assert np.all(np.isfinite(np.asarray(want)))
        assert_close(out, want, scale=1.0e18)


# ---------------------------------------------------------------------------
# lb_sax
# ---------------------------------------------------------------------------

class TestLBSaxConformance:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 9), st.integers(1, 300),
           st.sampled_from([8, 16]), st.sampled_from([16, 64, 256]))
    def test_property_ragged_shapes(self, seed, q, n, m, alphabet):
        length = 4 * m
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        q_paa = S.paa(jax.random.normal(k1, (q, length)), m)
        codes = S.isax(jax.random.normal(k2, (n, length)), m, alphabet)
        out = ops.lb_sax(q_paa, codes, length, alphabet=alphabet, mode=MODE)
        assert_close(out, ref.lb_sax_matrix_ref(q_paa, codes, length,
                                                alphabet=alphabet))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("q,n,m", [(1, 1, 16), (5, 77, 16), (3, 130, 8)])
    def test_shapes_dtypes(self, q, n, m, dtype):
        length = 4 * m
        k1, k2 = jax.random.split(jax.random.PRNGKey(4))
        q_paa = S.paa(jax.random.normal(k1, (q, length)), m).astype(dtype)
        codes = S.isax(jax.random.normal(k2, (n, length)), m)
        out = ops.lb_sax(q_paa, codes, length, mode=MODE)
        assert_close(out, ref.lb_sax_matrix_ref(q_paa, codes, length), dtype)

    def test_constant_series_zero_bound(self):
        # a constant-zero query sits inside the central SAX cell of a
        # constant-zero collection: the lower bound must be exactly 0
        length, m = 64, 16
        q_paa = jnp.zeros((2, m))
        codes = S.isax(jnp.zeros((5, length)), m)
        out = ops.lb_sax(q_paa, codes, length, mode=MODE)
        want = ref.lb_sax_matrix_ref(q_paa, codes, length)
        assert_close(out, want)
        np.testing.assert_array_equal(np.asarray(out), 0.0)

    def test_extreme_paa_magnitudes(self):
        # query PAA far outside every breakpoint: distance to the outermost
        # cells dominates; both paths must agree at 1e15 scale
        length, m = 64, 16
        q_paa = jnp.full((2, m), 1.0e15)
        codes = S.isax(jax.random.normal(jax.random.PRNGKey(5), (7, length)), m)
        out = ops.lb_sax(q_paa, codes, length, mode=MODE)
        assert_close(out, ref.lb_sax_matrix_ref(q_paa, codes, length),
                     scale=1.0e15)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------

def _wkv_inputs(seed, b, t, h, dk, dv, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = jax.random.normal(ks[0], (b, t, h, dk)).astype(dtype)
    k = jax.random.normal(ks[1], (b, t, h, dk)).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, h, dv)).astype(dtype)
    # decay stays f32: the model layer computes it in f32 regardless of the
    # activation dtype (bf16 w would quantize 1 - 1e-6 to exactly 1.0)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, dk)))
    u = jax.random.normal(ks[4], (h, dk))
    s0 = jax.random.normal(ks[5], (b, h, dk, dv))
    return r, k, v, w, u, s0


class TestWKV6Conformance:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 40),
           st.sampled_from([4, 8, 16]))
    def test_property_ragged_t(self, seed, t, chunk):
        r, k, v, w, u, s0 = _wkv_inputs(seed, 2, t, 2, 4, 4)
        out, sf = ops.wkv6(r, k, v, w, u, s0, chunk=chunk, mode=MODE)
        want_o, want_s = ref.wkv6_ref(r, k, v, w, u, s0)
        assert_close(out, want_o)
        assert_close(sf, want_s)

    @settings(max_examples=6, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_property_extreme_decay_mix(self, seed):
        # random per-(token, channel) scatter of exact 0s and 1s into the
        # decay — the extreme-decay regression as a property
        r, k, v, w, u, s0 = _wkv_inputs(seed, 1, 24, 1, 4, 4)
        key = jax.random.PRNGKey(seed ^ 0x5EED)
        sel = jax.random.randint(key, w.shape, 0, 3)
        w = jnp.where(sel == 0, 0.0, jnp.where(sel == 1, 1.0, w))
        out, sf = ops.wkv6(r, k, v, w, u, s0, chunk=8, mode=MODE)
        want_o, want_s = ref.wkv6_ref(r, k, v, w, u, s0)
        assert_close(out, want_o)
        assert_close(sf, want_s)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("b,t,h,dk,dv,chunk", [
        (1, 1, 1, 1, 1, 4),          # fully degenerate, ragged tail
        (2, 37, 2, 4, 4, 16),        # ragged T, multi-head
        (1, 64, 2, 8, 8, 16),        # aligned multi-chunk
    ])
    def test_shapes_dtypes(self, b, t, h, dk, dv, chunk, dtype):
        r, k, v, w, u, s0 = _wkv_inputs(6, b, t, h, dk, dv, dtype)
        out, sf = ops.wkv6(r, k, v, w, u, s0, chunk=chunk, mode=MODE)
        want_o, want_s = ref.wkv6_ref(r, k, v, w, u, s0)
        assert_close(out, want_o, dtype)
        assert_close(sf, want_s, dtype)

    def test_constant_inputs(self):
        b, t, h, dk, dv = 1, 16, 1, 4, 4
        one = jnp.ones((b, t, h, dk))
        out, sf = ops.wkv6(one, one, jnp.ones((b, t, h, dv)),
                           0.5 * one, jnp.ones((h, dk)),
                           jnp.zeros((b, h, dk, dv)), chunk=8, mode=MODE)
        want_o, want_s = ref.wkv6_ref(one, one, jnp.ones((b, t, h, dv)),
                                      0.5 * one, jnp.ones((h, dk)),
                                      jnp.zeros((b, h, dk, dv)))
        assert_close(out, want_o)
        assert_close(sf, want_s)
