"""Compressed leaves (format v3): codec layer + encoded out-of-core serving.

Covers the PR's acceptance contract:
* per-codec round-trips — raw is bit-exact; lossy codecs reconstruct
  within the *embedded* per-row error bound (the soundness invariant the
  engine's pruning math relies on), example-based and property-based;
* format v3 — ``enc.npy`` sidecar + manifest codec section on create,
  v2 directories still open and serve, ``compact(codec=...)`` migrates in
  both directions (raw -> lossy -> raw removes the sidecar);
* serving — ooc-scan and ooc-local answer **bit-identically** to
  ``LocalBackend`` under every codec (sync + threaded prefetch, waves,
  and under ``REPRO_SANITIZE=1``), with the certify-guard fallback still
  exact when forced;
* API — registry validation, ``SearchConfig.codec`` validation, codec /
  index mismatch errors, telemetry counters.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.engine as engine
from repro.analysis import sanitize
from repro.core.engine import LocalBackend, QueryEngine
from repro.core.index import HerculesIndex, IndexConfig
from repro.core.search import SearchConfig
from repro.core.tree import BuildConfig
from repro.data.synthetic import make_query_workload, random_walks
from repro.storage import Hercules, open_index
from repro.storage.codecs import (CODEC_CHOICES, Codec, get_codec,
                                  list_codecs, register_codec,
                                  sax_segments_for)
from repro.storage.format import ENC_FILE, MANIFEST_FILE, array_path

from _hypothesis_compat import given, settings, st

NUM, LEN = 4096, 64
CFG = IndexConfig(
    build=BuildConfig(leaf_capacity=64),
    search=SearchConfig(k=3, l_max=4, chunk=256, scan_block=512))
LOSSY = ("bf16", "sax-residual")
BUDGET_MB = 2.0


@pytest.fixture(scope="module")
def data():
    return random_walks(jax.random.PRNGKey(0), NUM, LEN)


@pytest.fixture(scope="module")
def queries(data):
    return make_query_workload(jax.random.PRNGKey(1), data, 5, "5%")


@pytest.fixture(scope="module")
def stores(data, tmp_path_factory):
    root = tmp_path_factory.mktemp("codecs")
    out = {}
    for name in list_codecs():
        path = str(root / name.replace("-", "_"))
        Hercules.create(path, CFG, data=np.asarray(data), codec=name).close()
        out[name] = path
    return out


@pytest.fixture(scope="module")
def local_ref(data, queries):
    res = LocalBackend(HerculesIndex.build(data, CFG)).knn(queries, k=3)
    return np.asarray(res.dists), np.asarray(res.ids)


def _blocks(seed=0, num=64, n=LEN, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(num, n)) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# codec round-trips + embedded error-bound soundness
# ---------------------------------------------------------------------------

class TestCodecRoundTrip:
    def test_registry_lists_builtins(self):
        assert list_codecs() == ("raw", "bf16", "sax-residual")
        assert CODEC_CHOICES == ("auto", "raw", "bf16", "sax-residual")
        for name in list_codecs():
            codec = get_codec(name)
            assert isinstance(codec, Codec) and codec.name == name
        with pytest.raises(ValueError, match="unknown codec"):
            get_codec("zstd")

    def test_raw_is_bit_exact(self):
        block = _blocks(1)
        codec = get_codec("raw")
        enc = codec.encode(block)
        assert enc.dtype == np.uint8
        assert enc.shape == (block.shape[0], codec.row_bytes(LEN))
        rows, err = codec.decode(jnp.asarray(enc), LEN)
        np.testing.assert_array_equal(np.asarray(rows), block)
        assert not np.any(np.asarray(err))

    @pytest.mark.parametrize("name", LOSSY)
    @pytest.mark.parametrize("scale", [1.0, 1e-3, 1e4])
    def test_lossy_error_within_embedded_bound(self, name, scale):
        block = _blocks(2, scale=scale)
        codec = get_codec(name)
        assert not codec.exact
        enc = codec.encode(block)
        assert enc.shape == (block.shape[0], codec.row_bytes(LEN))
        rows, err = codec.decode(jnp.asarray(enc), LEN)
        true = np.linalg.norm(
            block.astype(np.float64)
            - np.asarray(rows).astype(np.float64), axis=1)
        assert np.all(true <= np.asarray(err).astype(np.float64)), name

    @pytest.mark.parametrize("name", LOSSY)
    def test_bound_holds_under_jit(self, name):
        # XLA may fuse the decode arithmetic differently inside a larger
        # jit than in the eager evaluation encode measured against; the
        # analytic re-association margin must absorb that
        block = _blocks(3, num=128, scale=3.0)
        codec = get_codec(name)
        enc = jnp.asarray(codec.encode(block))
        rows, err = jax.jit(
            lambda e: codec.decode(e, LEN))(enc)
        true = np.linalg.norm(
            block.astype(np.float64)
            - np.asarray(rows).astype(np.float64), axis=1)
        assert np.all(true <= np.asarray(err).astype(np.float64)), name

    @pytest.mark.parametrize("name", list_codecs())
    @pytest.mark.parametrize("n", [7, 16, 96, 128])
    def test_ragged_lengths(self, name, n):
        block = _blocks(4, num=9, n=n)
        codec = get_codec(name)
        enc = codec.encode(block)
        assert enc.shape == (9, codec.row_bytes(n))
        rows, err = codec.decode(jnp.asarray(enc), n)
        true = np.linalg.norm(
            block.astype(np.float64)
            - np.asarray(rows).astype(np.float64), axis=1)
        assert np.all(true <= np.asarray(err).astype(np.float64))

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 40),
           st.integers(1, 100), st.sampled_from(["bf16", "sax-residual"]),
           st.floats(1e-4, 1e6))
    def test_property_bound_soundness(self, seed, num, n, name, scale):
        block = _blocks(seed % (2**16), num=num, n=n, scale=scale)
        codec = get_codec(name)
        rows, err = codec.decode(jnp.asarray(codec.encode(block)), n)
        true = np.linalg.norm(
            block.astype(np.float64)
            - np.asarray(rows).astype(np.float64), axis=1)
        assert np.all(true <= np.asarray(err).astype(np.float64))

    def test_sax_segments_for_divides(self):
        for n in (1, 7, 16, 64, 96, 100, 128):
            m = sax_segments_for(n)
            assert 1 <= m <= 16 and n % m == 0

    def test_register_codec_name_mismatch_rejected(self):
        @dataclasses.dataclass(frozen=True)
        class Bogus:
            name: str = "actually-this"
            exact: bool = True

            def row_bytes(self, n):
                return 4 * n

            def encode(self, block):
                return get_codec("raw").encode(block)

            def decode(self, enc, n):
                return get_codec("raw").decode(enc, n)

        with pytest.raises(ValueError, match="name mismatch"):
            register_codec("registered-as-that")(Bogus)
        assert "registered-as-that" not in list_codecs()


# ---------------------------------------------------------------------------
# format v3: sidecar files, manifest section, migration
# ---------------------------------------------------------------------------

class TestFormatV3:
    def test_create_writes_sidecar_and_manifest_section(self, stores):
        for name in LOSSY:
            with Hercules.open(stores[name]) as hx:
                assert hx.codec == name
                sec = hx.manifest["codec"]
                assert sec["name"] == name and sec["exact"] is False
                assert sec["row_bytes"] == get_codec(name).row_bytes(LEN)
                enc = hx.saved.enc
                assert enc is not None and enc.dtype == np.uint8
                assert enc.shape == (hx.saved.lrd.shape[0], sec["row_bytes"])

    def test_raw_store_has_no_sidecar(self, stores):
        with Hercules.open(stores["raw"]) as hx:
            assert hx.codec == "raw" and hx.saved.enc is None
            manifest = json.load(
                open(os.path.join(stores["raw"], MANIFEST_FILE)))
            assert ENC_FILE not in manifest["files"]
            with pytest.raises(Exception, match="no encoded sidecar"):
                hx.saved._mapped("enc")

    def test_sidecar_decodes_consistently_with_lrd(self, stores):
        for name in LOSSY:
            with Hercules.open(stores[name]) as hx:
                codec = get_codec(name)
                rows, err = codec.decode(jnp.asarray(hx.saved.enc[:256]), LEN)
                true = np.linalg.norm(
                    hx.saved.lrd[:256].astype(np.float64)
                    - np.asarray(rows).astype(np.float64), axis=1)
                assert np.all(true <= np.asarray(err).astype(np.float64))

    def test_invalid_codec_rejected_at_create(self, data, tmp_path):
        with pytest.raises(ValueError, match="unknown codec"):
            Hercules.create(str(tmp_path / "bad"), CFG,
                            data=np.asarray(data)[:256], codec="zstd")

    def test_v2_manifest_still_opens_and_serves(self, data, queries,
                                                tmp_path):
        path = str(tmp_path / "v2idx")
        Hercules.create(path, CFG, data=np.asarray(data), codec="raw").close()
        mf = os.path.join(path, MANIFEST_FILE)
        manifest = json.load(open(mf))
        manifest["version"] = 2
        manifest.pop("codec", None)
        json.dump(manifest, open(mf, "w"))
        with Hercules.open(path) as hx:
            assert hx.codec == "raw"
            res = hx.query(queries, k=3, backend="ooc-scan",
                           memory_budget_mb=BUDGET_MB)
            mem = LocalBackend(HerculesIndex.build(data, CFG)).knn(queries,
                                                                   k=3)
            np.testing.assert_array_equal(np.asarray(res.dists),
                                          np.asarray(mem.dists))

    def test_compact_migrates_v2_to_v3_with_codec(self, data, queries,
                                                  tmp_path, local_ref):
        path = str(tmp_path / "migrate")
        Hercules.create(path, CFG, data=np.asarray(data), codec="raw").close()
        mf = os.path.join(path, MANIFEST_FILE)
        manifest = json.load(open(mf))
        manifest["version"] = 2
        manifest.pop("codec", None)
        json.dump(manifest, open(mf, "w"))
        with Hercules.open(path, "a") as hx:
            hx.compact(codec="bf16")
            assert hx.codec == "bf16"
            assert json.load(open(mf))["version"] >= 3
            res = hx.query(queries, k=3, backend="ooc-local",
                           memory_budget_mb=BUDGET_MB)
            np.testing.assert_array_equal(np.asarray(res.dists), local_ref[0])

    def test_compact_back_to_raw_drops_sidecar(self, data, tmp_path):
        path = str(tmp_path / "back")
        Hercules.create(path, CFG, data=np.asarray(data)[:512],
                        codec="bf16").close()
        with Hercules.open(path, "a") as hx:
            enc_file = os.path.join(path, array_path(hx.manifest, ENC_FILE))
            assert os.path.exists(enc_file)
            hx.compact(codec="raw")
            assert hx.codec == "raw" and hx.saved.enc is None
            assert ENC_FILE not in hx.manifest["files"]
            # the orphan sweep on the next writable open removes the old
            # generation's sidecar file from disk
        with Hercules.open(path, "a") as hx:
            assert not any(f.startswith("enc")
                           for f in os.listdir(path) if f.endswith(".npy"))

    def test_append_then_compact_keeps_codec(self, data, queries, tmp_path,
                                             local_ref):
        path = str(tmp_path / "appended")
        half = NUM // 2
        arr = np.asarray(data)
        Hercules.create(path, CFG, data=arr[:half], codec="bf16").close()
        with Hercules.open(path, "a") as hx:
            hx.append(arr[half:])
            hx.compact()
            assert hx.codec == "bf16" and hx.generation == 1
            res = hx.query(queries, k=3, backend="ooc-scan",
                           memory_budget_mb=BUDGET_MB)
            np.testing.assert_array_equal(np.asarray(res.dists), local_ref[0])
            np.testing.assert_array_equal(np.asarray(res.ids), local_ref[1])


# ---------------------------------------------------------------------------
# serving: bit-identical answers through the encoded stream
# ---------------------------------------------------------------------------

class TestCodecServing:
    @pytest.mark.parametrize("backend", ["ooc-scan", "ooc-local"])
    @pytest.mark.parametrize("name", list_codecs())
    def test_bit_identical_to_local_backend(self, stores, queries, local_ref,
                                            backend, name):
        with Hercules.open(stores[name]) as hx:
            eng = hx.engine(backend, memory_budget_mb=BUDGET_MB)
            res = eng.knn(queries, k=3)
            np.testing.assert_array_equal(np.asarray(res.dists), local_ref[0])
            np.testing.assert_array_equal(np.asarray(res.ids), local_ref[1])
            t = eng.stats()
            assert t["codec_fallbacks"] == 0
            if name in LOSSY:
                assert t["codec_refine_rows"] > 0

    @pytest.mark.parametrize("backend", ["ooc-scan", "ooc-local"])
    @pytest.mark.parametrize("name", LOSSY)
    def test_threaded_prefetch_under_sanitizer(self, stores, queries,
                                               local_ref, backend, name,
                                               monkeypatch):
        monkeypatch.setenv(sanitize.ENV_VAR, "1")
        assert sanitize.sanitize_enabled()
        with Hercules.open(stores[name]) as hx:
            eng = hx.engine(backend, memory_budget_mb=BUDGET_MB,
                            prefetch="thread")
            res = eng.knn(queries, k=3)
            np.testing.assert_array_equal(np.asarray(res.dists), local_ref[0])
            np.testing.assert_array_equal(np.asarray(res.ids), local_ref[1])

    @pytest.mark.parametrize("backend", ["ooc-scan", "ooc-local"])
    @pytest.mark.parametrize("name", LOSSY)
    def test_wave_plan_bit_identical(self, stores, queries, local_ref,
                                     backend, name):
        with Hercules.open(stores[name]) as hx:
            eng = hx.engine(backend, memory_budget_mb=BUDGET_MB)
            res = eng.knn(queries, k=3, wave=True)
            np.testing.assert_array_equal(np.asarray(res.dists), local_ref[0])
            np.testing.assert_array_equal(np.asarray(res.ids), local_ref[1])
            assert eng.telemetry().ooc.wave_calls >= 1

    @pytest.mark.parametrize("name", LOSSY)
    def test_forced_guard_fallback_stays_exact(self, stores, queries,
                                               local_ref, name, monkeypatch):
        # a zero candidate margin makes the LB pool exactly k wide, which
        # the certify guard (k-th LB >= k-th UB) rejects for lossy codecs
        monkeypatch.setattr(engine, "_CAND_MARGIN", 0)
        with Hercules.open(stores[name]) as hx:
            eng = hx.engine("ooc-scan", memory_budget_mb=BUDGET_MB)
            res = eng.knn(queries, k=3)
            np.testing.assert_array_equal(np.asarray(res.dists), local_ref[0])
            assert eng.stats()["codec_fallbacks"] > 0

    @pytest.mark.parametrize("name", LOSSY)
    def test_bf16_streams_fewer_bytes_than_raw(self, stores, queries, name):
        with Hercules.open(stores["raw"]) as hx:
            eng = hx.engine("ooc-scan", memory_budget_mb=BUDGET_MB)
            eng.knn(queries, k=3)
            raw_bytes = eng.stats()["bytes_streamed"]
        with Hercules.open(stores[name]) as hx:
            eng = hx.engine("ooc-scan", memory_budget_mb=BUDGET_MB)
            eng.knn(queries, k=3)
            enc_bytes = eng.stats()["bytes_streamed"]
        # encoded stream + float32 re-check must stay well under raw
        assert enc_bytes < 0.62 * raw_bytes

    def test_codec_raw_override_streams_float32(self, stores, queries,
                                                local_ref):
        with Hercules.open(stores["bf16"]) as hx:
            eng = hx.engine("ooc-scan", memory_budget_mb=BUDGET_MB,
                            search=dataclasses.replace(CFG.search,
                                                       codec="raw"))
            res = eng.knn(queries, k=3)
            np.testing.assert_array_equal(np.asarray(res.dists), local_ref[0])
            assert eng.stats()["codec_refine_rows"] == 0

    def test_codec_mismatch_raises(self, stores, queries):
        with Hercules.open(stores["bf16"]) as hx:
            eng = hx.engine("ooc-scan", memory_budget_mb=BUDGET_MB,
                            search=dataclasses.replace(
                                CFG.search, codec="sax-residual"))
            with pytest.raises(ValueError, match="encoded with"):
                eng.knn(queries, k=3)

    def test_lossy_codec_on_raw_index_raises(self, stores, queries):
        with Hercules.open(stores["raw"]) as hx:
            eng = hx.engine("ooc-scan", memory_budget_mb=BUDGET_MB,
                            search=dataclasses.replace(CFG.search,
                                                       codec="bf16"))
            with pytest.raises(ValueError, match="encoded with"):
                eng.knn(queries, k=3)

    def test_search_config_validates_codec(self):
        with pytest.raises(ValueError, match="codec"):
            SearchConfig(codec="zstd")
        assert SearchConfig(codec="bf16").codec == "bf16"

    def test_telemetry_exposes_codec_counters(self, stores, queries):
        with Hercules.open(stores["bf16"]) as hx:
            eng = hx.engine("ooc-scan", memory_budget_mb=BUDGET_MB)
            eng.knn(queries, k=3)
            tele = eng.telemetry()
            assert tele.ooc.codec_fallbacks == 0
            assert tele.ooc.codec_refine_rows > 0
            assert tele["ooc"]["bytes_streamed"] == tele.ooc.bytes_streamed
            assert hx.describe()["codec"] == "bf16"
            assert eng.stats()["codec"] == "bf16"


# ---------------------------------------------------------------------------
# direct open_index path (no store facade)
# ---------------------------------------------------------------------------

class TestSavedIndexCodec:
    def test_open_index_maps_sidecar(self, stores):
        saved = open_index(stores["sax-residual"])
        try:
            assert saved.codec == "sax-residual"
            enc = saved._mapped("enc")
            assert enc.dtype == np.uint8
        finally:
            saved.close()

    def test_backend_through_query_engine(self, stores, queries, local_ref):
        saved = open_index(stores["bf16"])
        try:
            eng = QueryEngine(engine.OutOfCoreScanBackend(
                saved, CFG.search, memory_budget_mb=BUDGET_MB))
            res = eng.knn(queries, k=3)
            np.testing.assert_array_equal(np.asarray(res.dists), local_ref[0])
        finally:
            saved.close()
