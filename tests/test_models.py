"""Per-arch smoke tests (deliverable f): reduced configs, one forward/train
step on CPU, output shapes + no NaNs; decode-path consistency per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_smoke
from repro.models import get_model
from repro.train import TrainConfig, make_train_step
from repro.train.train_step import init_train_state

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 16


def _batch(cfg, key, seq=S):
    batch = {"tokens": jax.random.randint(key, (B, seq), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_patch))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.num_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
class TestSmoke:
    def test_forward_shapes_no_nan(self, arch, key):
        cfg = get_smoke(arch)
        model = get_model(cfg)
        params = model.init(key, cfg)
        batch = _batch(cfg, key)
        logits, aux = model.forward(params, batch, cfg)
        extra = cfg.num_patches if cfg.family == "vlm" else 0
        assert logits.shape == (B, S + extra, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any())

    def test_one_train_step(self, arch, key):
        cfg = get_smoke(arch)
        model = get_model(cfg)
        tcfg = TrainConfig()
        params, opt = init_train_state(model, cfg, tcfg, key)
        step = jax.jit(make_train_step(model, cfg, tcfg))
        batch = _batch(cfg, key)
        params2, opt2, metrics = step(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert float(metrics["grad_norm"]) > 0
        # params actually changed
        delta = max(float(jnp.abs(a - b).max())
                    for a, b in zip(jax.tree.leaves(params),
                                    jax.tree.leaves(params2)))
        assert delta > 0


@pytest.mark.parametrize("arch", ["codeqwen1.5-7b", "granite-moe-1b-a400m",
                                  "rwkv6-7b", "recurrentgemma-2b",
                                  "whisper-large-v3", "phi-3-vision-4.2b"])
class TestDecodeConsistency:
    """prefill(prompt) + decode steps must reproduce the full forward."""

    def test_prefill_decode_matches_forward(self, arch, key):
        cfg = get_smoke(arch)
        model = get_model(cfg)
        seq = 12
        tokens = jax.random.randint(key, (B, seq), 0, cfg.vocab_size)
        batch = _batch(cfg, key, seq)
        batch["tokens"] = tokens
        params = model.init(key, cfg)
        full, _ = model.forward(params, batch, cfg)
        off = cfg.num_patches if cfg.family == "vlm" else 0

        prompt = dict(batch)
        prompt["tokens"] = tokens[:, :seq - 2]
        cache = model.init_cache(cfg, B, 32)
        lg, cache = model.prefill(params, prompt, cfg, cache)
        np.testing.assert_allclose(np.asarray(lg[:, -1]),
                                   np.asarray(full[:, seq - 3 + off]),
                                   rtol=1e-3, atol=1e-3)
        lg, cache = model.decode_step(params, tokens[:, seq - 2:seq - 1], cfg, cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, seq - 2 + off]),
                                   rtol=1e-3, atol=1e-3)
        lg, cache = model.decode_step(params, tokens[:, seq - 1:seq], cfg, cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, seq - 1 + off]),
                                   rtol=1e-3, atol=1e-3)


class TestConfigs:
    @pytest.mark.parametrize("arch", ARCH_NAMES)
    def test_full_config_loads(self, arch):
        cfg = get_config(arch)
        assert cfg.name == arch
        assert cfg.num_layers > 0 and cfg.d_model > 0

    @pytest.mark.parametrize("arch,published_b,tol", [
        ("llama3-405b", 405e9, 0.10),
        ("codeqwen1.5-7b", 7.2e9, 0.15),
        ("granite-34b", 34e9, 0.05),     # GPTBigCode gelu MLP: exact to 5%
        ("minicpm-2b", 2.7e9, 0.25),
        ("whisper-large-v3", 1.55e9, 0.25),
        ("rwkv6-7b", 7.6e9, 0.25),
        ("recurrentgemma-2b", 2.7e9, 0.30),
        # assignment fixes 48L x 64 full-MoE layers; the published 16B has 27L
        # with a dense first layer + shared experts — we verify the arithmetic
        # of the ASSIGNED config, not the hf checkpoint layout
        ("moonshot-v1-16b-a3b", 28.1e9, 0.10),
        ("granite-moe-1b-a400m", 1.3e9, 0.30),
        ("phi-3-vision-4.2b", 4.2e9, 0.30),
    ])
    def test_param_count_near_published(self, arch, published_b, tol):
        cfg = get_config(arch)
        n = cfg.param_count()
        assert abs(n - published_b) / published_b < tol, \
            f"{arch}: analytic {n / 1e9:.2f}B vs published {published_b / 1e9:.2f}B"

    def test_moe_active_less_than_total(self):
        cfg = get_config("moonshot-v1-16b-a3b")
        assert cfg.active_param_count() < cfg.param_count() / 3

    def test_chunked_attention_matches_full(self, key):
        from repro.models import common as C
        spec_f = C.AttnSpec(4, 2, 16, causal=True, impl="full")
        spec_c = C.AttnSpec(4, 2, 16, causal=True, impl="chunked", chunk=8)
        k1, k2, k3 = jax.random.split(key, 3)
        q = jax.random.normal(k1, (2, 32, 4, 16))
        kk = jax.random.normal(k2, (2, 32, 2, 16))
        v = jax.random.normal(k3, (2, 32, 2, 16))
        pos = jnp.arange(32)
        a = C.attention_full(q, kk, v, pos, pos, spec_f)
        b = C.attention_chunked(q, kk, v, pos, pos, spec_c)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)

    def test_local_window_attention(self, key):
        from repro.models import common as C
        spec = C.AttnSpec(2, 1, 8, causal=True, window=4, impl="full")
        q = jax.random.normal(key, (1, 16, 2, 8))
        kk = jax.random.normal(jax.random.fold_in(key, 1), (1, 16, 1, 8))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 16, 1, 8))
        pos = jnp.arange(16)
        out = C.attention_full(q, kk, v, pos, pos, spec)
        # position 10 must not attend to position <= 6: perturbing k[0] there
        # must not change the output at position 10
        kk2 = kk.at[:, 3].add(100.0)
        out2 = C.attention_full(q, kk2, v, pos, pos, spec)
        np.testing.assert_allclose(np.asarray(out[:, 10:]),
                                   np.asarray(out2[:, 10:]), atol=1e-5)
