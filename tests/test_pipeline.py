"""Pipeline parallelism (GPipe over a 'stage' mesh axis)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compat import auto_axis_types, make_mesh
from repro.distributed.pipeline import pipeline_forward, split_stages

jax.config.update("jax_platform_name", "cpu")


class TestPipeline:
    def test_single_stage_degenerate(self):
        """P=1 pipeline == plain forward."""
        mesh = make_mesh((1,), ("stage",), axis_types=auto_axis_types(1))
        w = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8)) * 0.3

        def stage_fn(params, x):
            def layer(x, wi):
                return jnp.tanh(x @ wi), None
            return jax.lax.scan(layer, x, params)[0]

        xs = jax.random.normal(jax.random.PRNGKey(1), (3, 2, 8))
        out = pipeline_forward(stage_fn, split_stages(w, 1), xs, mesh)
        ref = jnp.stack([stage_fn(w, xs[i]) for i in range(3)])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)

    def test_split_stages_shape(self):
        w = jnp.zeros((8, 4, 4))
        s = split_stages(w, 4)
        assert s.shape == (4, 2, 4, 4)
        with pytest.raises(ValueError):
            split_stages(jnp.zeros((7, 4)), 4)

    @pytest.mark.slow
    def test_four_stage_subprocess_fwd_and_grad(self):
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import sys; sys.path.insert(0, "src")
            import jax, jax.numpy as jnp, numpy as np
            from repro.distributed.compat import auto_axis_types, make_mesh
            from repro.distributed.pipeline import pipeline_forward, split_stages
            mesh = make_mesh((4,), ("stage",), axis_types=auto_axis_types(1))
            L, d, mb, M = 8, 16, 4, 6
            ks = jax.random.split(jax.random.PRNGKey(0), L)
            w = jnp.stack([jax.random.normal(k, (d, d)) * 0.3 for k in ks])
            def stage_fn(params, x):
                def layer(x, wi):
                    return jnp.tanh(x @ wi), None
                return jax.lax.scan(layer, x, params)[0]
            xs = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
            out = pipeline_forward(stage_fn, split_stages(w, 4), xs, mesh)
            def ref_f(w, x):
                for i in range(L):
                    x = jnp.tanh(x @ w[i])
                return x
            ref = jnp.stack([ref_f(w, xs[i]) for i in range(M)])
            assert float(jnp.abs(out - ref).max()) < 1e-5
            g1 = jax.grad(lambda w: jnp.sum(pipeline_forward(
                stage_fn, split_stages(w, 4), xs, mesh) ** 2))(w)
            g2 = jax.grad(lambda w: jnp.sum(jnp.stack(
                [ref_f(w, xs[i]) for i in range(M)]) ** 2))(w)
            assert float(jnp.abs(g1 - g2).max()) < 1e-4
            print("PIPELINE_OK")
        """)
        res = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                             capture_output=True, text=True, timeout=600)
        assert "PIPELINE_OK" in res.stdout, res.stderr[-2000:]
