"""Corner cases and invariants beyond the happy path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import (BuildConfig, HerculesIndex, IndexConfig, SearchConfig,
                        brute_force_knn)
from repro.data import make_query_workload, random_walks
from repro.models import get_model
from repro.models.common import grad_cast
from repro.models.moe import moe_capacity, moe_forward, init_moe

jax.config.update("jax_platform_name", "cpu")


class TestSearchCorners:
    def _idx(self, data, tau=64):
        return HerculesIndex.build(data, IndexConfig(
            build=BuildConfig(leaf_capacity=tau),
            search=SearchConfig(k=3, l_max=4, chunk=128, scan_block=256)))

    def test_k_larger_than_leaf(self):
        data = random_walks(jax.random.PRNGKey(0), 600, 64)
        idx = self._idx(data, tau=16)
        q = make_query_workload(jax.random.PRNGKey(1), data, 4, "5%")
        res = idx.knn(q, k=50)
        bf, _ = brute_force_knn(data, q, 50)
        np.testing.assert_allclose(np.asarray(res.dists), np.asarray(bf),
                                   rtol=1e-3, atol=1e-3)

    def test_duplicate_series_in_collection(self):
        base = random_walks(jax.random.PRNGKey(2), 300, 64)
        data = jnp.concatenate([base, base[:100]])     # 100 exact duplicates
        idx = self._idx(data)
        q = base[:4]
        res = idx.knn(q, k=2)
        # both copies at distance 0
        np.testing.assert_allclose(np.asarray(res.dists), 0.0, atol=1e-4)
        ids = np.asarray(res.ids)
        for i in range(4):
            assert set(ids[i]) == {i, 300 + i}

    def test_single_leaf_tree(self):
        data = random_walks(jax.random.PRNGKey(3), 50, 64)
        idx = self._idx(data, tau=128)                 # never splits
        assert idx.stats()["num_leaves"] == 1
        q = make_query_workload(jax.random.PRNGKey(4), data, 4, "5%")
        bf, _ = brute_force_knn(data, q, 3)
        np.testing.assert_allclose(np.asarray(idx.knn(q).dists),
                                   np.asarray(bf), rtol=1e-3, atol=1e-3)

    def test_constant_query(self):
        data = random_walks(jax.random.PRNGKey(5), 500, 64)
        idx = self._idx(data)
        q = jnp.zeros((2, 64))
        bf, _ = brute_force_knn(data, q, 3)
        np.testing.assert_allclose(np.asarray(idx.knn(q).dists),
                                   np.asarray(bf), rtol=1e-3, atol=1e-3)

    def test_lmax_exceeding_leaves(self):
        data = random_walks(jax.random.PRNGKey(6), 400, 64)
        idx = HerculesIndex.build(data, IndexConfig(
            build=BuildConfig(leaf_capacity=64),
            search=SearchConfig(k=3, l_max=1000, chunk=128, scan_block=256)))
        q = make_query_workload(jax.random.PRNGKey(7), data, 4, "5%")
        bf, _ = brute_force_knn(data, q, 3)
        np.testing.assert_allclose(np.asarray(idx.knn(q).dists),
                                   np.asarray(bf), rtol=1e-3, atol=1e-3)


class TestGradCast:
    def test_identity_forward_and_cast_backward(self):
        x = jnp.ones((4,), jnp.bfloat16) * 1.5
        y = grad_cast(x.astype(jnp.float32), jnp.bfloat16)
        np.testing.assert_allclose(np.asarray(y), 1.5)

        def f(x):
            return jnp.sum(grad_cast(x, jnp.bfloat16).astype(jnp.float32) ** 2)

        g = jax.grad(f)(jnp.full((4,), 1.5))
        # grad flowed (values 2*x) and was cast to bf16 en route
        np.testing.assert_allclose(np.asarray(g), 3.0, rtol=1e-2)


class TestMoEInvariants:
    def _setup(self, cf=8.0):
        cfg = dataclasses.replace(get_smoke("granite-moe-1b-a400m"),
                                  capacity_factor=cf)
        params = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
        return cfg, params, x

    def test_no_drop_at_high_capacity(self):
        """At huge capacity the output must equal the dense mixture (each
        token's top-k experts weighted by renormalized router probs)."""
        cfg, params, x = self._setup(cf=16.0)
        out, _ = moe_forward(params, x, cfg)
        # dense reference
        import jax.numpy as jnp
        logits = jnp.einsum("bsd,de->bse", x, params["router"])
        probs = jax.nn.softmax(logits, -1)
        top_w, top_e = jax.lax.top_k(probs, cfg.experts_per_token)
        top_w = top_w / top_w.sum(-1, keepdims=True)
        ref = jnp.zeros_like(x)
        for j in range(cfg.experts_per_token):
            e = top_e[..., j]
            g = jnp.einsum("bsd,bsdf->bsf", x,
                           params["w_gate"][e])
            u = jnp.einsum("bsd,bsdf->bsf", x, params["w_up"][e])
            h = jax.nn.silu(g) * u
            y = jnp.einsum("bsf,bsfd->bsd", h, params["w_down"][e])
            ref = ref + y * top_w[..., j:j + 1]
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-2, atol=2e-3)

    def test_capacity_truncation_drops_not_corrupts(self):
        """Low capacity may zero some tokens' expert contributions but must
        never produce NaN or mix tokens."""
        cfg, params, x = self._setup(cf=0.25)
        out, aux = moe_forward(params, x, cfg)
        assert not bool(jnp.isnan(out).any())
        assert np.isfinite(float(aux))

    def test_capacity_is_static(self):
        cfg, _, _ = self._setup()
        assert moe_capacity(cfg, 1024) == moe_capacity(cfg, 1024)
        assert moe_capacity(cfg, 2048) >= moe_capacity(cfg, 1024)


class TestHerculesEdgeData:
    def test_near_constant_series(self):
        """Catastrophic-cancellation regime for segment stds.

        The fp32 matmul-identity brute force is LESS accurate than the
        index's direct-sum distances at this noise floor, so the oracle here
        is float64 numpy.
        """
        base = jnp.ones((200, 64))
        noise = jax.random.normal(jax.random.PRNGKey(8), (200, 64)) * 1e-3
        data = base + noise
        idx = HerculesIndex.build(data, IndexConfig(
            build=BuildConfig(leaf_capacity=32),
            search=SearchConfig(k=2, l_max=4, chunk=64, scan_block=64)))
        q = data[:3] + 1e-4
        res = idx.knn(q)
        d64 = ((np.asarray(data, np.float64)[None] -
                np.asarray(q, np.float64)[:, None]) ** 2).sum(-1)
        want = np.sort(d64, axis=1)[:, :2]
        np.testing.assert_allclose(np.asarray(res.dists), want,
                                   rtol=1e-3, atol=1e-7)
