"""Disk-aware scheduling (async chunk reader + two-slot host buffer).

Covers the PR's acceptance contract:
* the chunk readers — deterministic submission-order serving, zero-padded
  reusable slots, exception propagation, idempotent close that joins the
  daemon thread (no leaks — also asserted globally by the conftest
  fixture);
* ``prefetch="thread"`` answers bit-identical to ``prefetch="sync"`` on
  ooc-scan and ooc-local, including under randomly jittered read timings;
* adversarial budgets — the minimum viable budget, budgets whose
  ``stream_rows`` divides neither ``scan_block`` nor ``max_leaf`` — stay
  bit-identical to the in-memory backends;
* the ``sax_pr`` fix — seeded-leaf rows count as alive, pinned against
  rows actually streamed;
* one budget→``stream_rows`` code path shared by backends and the CLI;
* ``scan_block`` auto-shrink behaves identically from every entry point.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import jax
import numpy as np
import pytest

from repro.core.engine import (LocalBackend, OutOfCoreLocalBackend,
                               OutOfCoreScanBackend, ScanBackend,
                               _OutOfCoreBase, make_disk_backend)
from repro.core.index import HerculesIndex, IndexConfig
from repro.core.search import SearchConfig
from repro.core.tree import BuildConfig
from repro.data.pipeline import (ArrayChunkSource, AsyncChunkReader,
                                 PREFETCH_MODES, SyncChunkReader,
                                 iter_device_chunks, iter_host_chunks,
                                 make_chunk_reader)
from repro.data.synthetic import make_query_workload, random_walks
from repro.storage import open_index, save_index

NUM, LEN = 2048, 64
CFG = IndexConfig(
    build=BuildConfig(leaf_capacity=64),
    search=SearchConfig(k=3, l_max=4, chunk=256, scan_block=256))
ROW_BYTES = 4 * LEN


def budget_mb_for_stream_rows(stream_rows: int) -> float:
    """The budget that makes ``budget_stream_rows`` == ``stream_rows``."""
    return 2 * stream_rows * ROW_BYTES / (1 << 20)


@pytest.fixture(scope="module")
def data():
    return random_walks(jax.random.PRNGKey(7), NUM, LEN)


@pytest.fixture(scope="module")
def queries(data):
    return make_query_workload(jax.random.PRNGKey(8), data, 4, "5%")


@pytest.fixture(scope="module")
def index(data):
    return HerculesIndex.build(data, CFG)


@pytest.fixture(scope="module")
def saved_dir(index, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("prefetch") / "idx")
    save_index(index, path)
    return path


def _same(a, b):
    assert np.array_equal(np.asarray(a.dists), np.asarray(b.dists))
    assert np.array_equal(np.asarray(a.ids), np.asarray(b.ids))


def _no_reader_threads():
    return not [t for t in threading.enumerate()
                if t.name == AsyncChunkReader.THREAD_NAME and t.is_alive()]


# ---------------------------------------------------------------------------
# Reader unit behaviour
# ---------------------------------------------------------------------------

class TestChunkReader:
    ROWS = np.arange(100 * 8, dtype=np.float32).reshape(100, 8)

    @pytest.mark.parametrize("mode", PREFETCH_MODES)
    def test_submission_order_and_padding(self, mode):
        with make_chunk_reader(self.ROWS, 32, 8, prefetch=mode) as r:
            r.submit(10, 5, 16)
            r.submit(90, 10)
            r.submit(0, 32)
            a = r.get()
            assert a.shape == (16, 8)
            assert np.array_equal(a[:5], self.ROWS[10:15])
            assert not a[5:].any()          # zero-filled pad, every request
            b = r.get()
            # a slot view is valid until the *next* get(): copy to compare
            assert np.array_equal(np.array(b), self.ROWS[90:100])
            c = r.get()
            assert np.array_equal(np.array(c), self.ROWS[0:32])
            assert r.stats["blocks"] == 3
        assert _no_reader_threads()

    def test_thread_reuses_bounded_slots(self):
        r = make_chunk_reader(self.ROWS, 16, 8, prefetch="thread")
        bases = set()
        for i in range(8):
            r.submit(i * 10, 10)
        for _ in range(8):
            view = r.get()
            bases.add(view.base.ctypes.data)
        r.close()
        assert len(bases) == 2              # two reusable slot arrays

    def test_exception_propagates_to_get(self):
        class Exploding:
            def __getitem__(self, sl):
                raise OSError("bad sector")

        r = make_chunk_reader(Exploding(), 8, 4, prefetch="thread")
        r.submit(0, 4)
        r.submit(4, 4)
        with pytest.raises(OSError, match="bad sector"):
            r.get()
        # the failure is latched: a later get()/submit() must fail loudly
        # instead of blocking forever on the dead reader thread
        with pytest.raises(RuntimeError, match="already failed"):
            r.get()
        with pytest.raises(RuntimeError, match="already failed"):
            r.submit(8, 4)
        r.close()
        assert _no_reader_threads()

    def test_close_is_idempotent_and_joins(self):
        r = make_chunk_reader(self.ROWS, 16, 8, prefetch="thread")
        for i in range(16):                 # more requests than slots
            r.submit(i, 1)
        r.get()
        r.close()
        r.close()
        assert _no_reader_threads()
        with pytest.raises(RuntimeError, match="closed"):
            r.get()
        with pytest.raises(RuntimeError, match="closed"):
            r.submit(0, 1)

    @pytest.mark.parametrize("mode", PREFETCH_MODES)
    def test_get_without_submit_raises(self, mode):
        with make_chunk_reader(self.ROWS, 8, 8, prefetch=mode) as r:
            with pytest.raises(RuntimeError, match="without a pending"):
                r.get()

    @pytest.mark.parametrize("mode", PREFETCH_MODES)
    def test_submit_validation(self, mode):
        # both modes enforce the same bounds, so a consumer cannot work
        # under sync and break only when prefetch flips to thread
        with make_chunk_reader(self.ROWS, 8, 8, prefetch=mode) as r:
            with pytest.raises(ValueError, match="positive"):
                r.submit(0, 0)
            with pytest.raises(ValueError, match="pad_to"):
                r.submit(0, 4, 100)         # beyond slot capacity
            with pytest.raises(ValueError, match="pad_to"):
                r.submit(0, 4, 2)           # pad below count

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError, match="prefetch"):
            make_chunk_reader(self.ROWS, 8, 8, prefetch="bogus")

    def test_stage_is_independent_of_slot(self):
        """The staged device copy must not alias the reusable slot (a plain
        device_put may zero-copy an aligned numpy buffer)."""
        r = make_chunk_reader(self.ROWS, 16, 8, prefetch="thread")
        r.submit(0, 16)
        view = r.get()
        dev = r.stage(view)
        view[:] = -1.0                      # simulate the reader refilling
        assert np.array_equal(np.asarray(dev), self.ROWS[:16])
        r.close()


class TestScheduledChunks:
    """The wave path's demand-scheduled fetch loop (iter_scheduled_chunks)."""
    ROWS = np.arange(100 * 8, dtype=np.float32).reshape(100, 8)

    def _reqs(self):
        return [("a", 0, 10, 16), ("b", 20, 10, 16), ("c", 40, 10, 16),
                ("d", 60, 10, 16)]

    @pytest.mark.parametrize("mode", PREFETCH_MODES)
    def test_fetches_in_request_order(self, mode):
        from repro.data.pipeline import iter_scheduled_chunks
        with make_chunk_reader(self.ROWS, 32, 8, prefetch=mode) as r:
            got = list(iter_scheduled_chunks(r, self._reqs()))
        assert [t for t, _ in got] == ["a", "b", "c", "d"]
        for (tag, rows), (_, start, cnt, pad) in zip(got, self._reqs()):
            assert rows.shape == (pad, 8)
            assert np.array_equal(np.asarray(rows)[:cnt],
                                  self.ROWS[start:start + cnt])

    def test_still_needed_checked_at_submit_time(self):
        """A request whose consumers were satisfied while earlier blocks
        were in flight is dropped without a disk read; the drop decision
        runs per-request, as late as the lookahead window allows."""
        from repro.data.pipeline import iter_scheduled_chunks
        dead = set()
        checked = []

        def still_needed(tag):
            checked.append(tag)
            return tag not in dead

        with make_chunk_reader(self.ROWS, 32, 8, prefetch="sync") as r:
            out = []
            for tag, rows in iter_scheduled_chunks(
                    r, self._reqs(), still_needed=still_needed, lookahead=1):
                out.append(tag)
                if tag == "a":
                    dead.add("c")   # bound tightened: run c no longer needed
        assert out == ["a", "b", "d"]
        assert checked == ["a", "b", "c", "d"]

    def test_lookahead_validation(self):
        from repro.data.pipeline import iter_scheduled_chunks
        with make_chunk_reader(self.ROWS, 32, 8, prefetch="sync") as r:
            with pytest.raises(ValueError, match="lookahead"):
                list(iter_scheduled_chunks(r, self._reqs(), lookahead=0))


class TestChunkIterators:
    @pytest.mark.parametrize("chunk_size", [7, 64, 100, 1000])
    def test_device_chunks_thread_matches_sync(self, chunk_size):
        rows = np.random.default_rng(0).standard_normal(
            (100, 8)).astype(np.float32)
        src = ArrayChunkSource(rows, chunk_size)
        sync = [(s, np.asarray(c)) for s, c in iter_device_chunks(src)]
        tel: dict = {}
        thr = [(s, np.asarray(c)) for s, c in
               iter_device_chunks(src, prefetch="thread", telemetry=tel)]
        assert len(sync) == len(thr) == src.num_chunks
        for (s0, c0), (s1, c1) in zip(sync, thr):
            assert s0 == s1
            assert np.array_equal(c0, c1)
        assert tel["read_wait_seconds"] >= 0
        assert _no_reader_threads()

    def test_host_chunks_thread_matches_sync(self):
        rows = np.random.default_rng(1).standard_normal(
            (50, 4)).astype(np.float32)
        src = ArrayChunkSource(rows, 12)
        sync = [(s, c.copy()) for s, c in iter_host_chunks(src)]
        thr = [(s, np.array(c)) for s, c in
               iter_host_chunks(src, prefetch="thread")]
        for (s0, c0), (s1, c1) in zip(sync, thr):
            assert s0 == s1
            assert np.array_equal(c0, c1)

    def test_consumer_break_joins_reader(self):
        src = ArrayChunkSource(np.zeros((100, 4), np.float32), 10)
        for _ in iter_device_chunks(src, prefetch="thread"):
            break                           # generator close -> finally
        assert _no_reader_threads()


# ---------------------------------------------------------------------------
# Backend parity: thread == sync == in-memory, adversarial budgets
# ---------------------------------------------------------------------------

class TestPrefetchParity:
    BUDGET_MB = 0.125                       # collection (0.5 MiB) = 4x this

    def _ooc_scan(self, saved, mode, budget=None, **kw):
        cfg = dataclasses.replace(CFG.search, prefetch=mode, **kw)
        return OutOfCoreScanBackend(saved, cfg,
                                    memory_budget_mb=budget or self.BUDGET_MB)

    def _ooc_local(self, saved, mode, budget=None, **kw):
        cfg = dataclasses.replace(CFG.search, prefetch=mode, **kw)
        return OutOfCoreLocalBackend(saved, cfg,
                                     memory_budget_mb=budget or self.BUDGET_MB)

    def test_scan_thread_matches_sync_and_memory(self, data, saved_dir,
                                                 queries):
        mem = ScanBackend(data, CFG.search).knn(queries)
        with open_index(saved_dir) as saved:
            r_sync = self._ooc_scan(saved, "sync").knn(queries)
            thr = self._ooc_scan(saved, "thread")
            r_thr = thr.knn(queries)
            _same(mem, r_sync)
            _same(r_sync, r_thr)
            st = thr.stats()
            assert st["read_wait_seconds"] >= 0
            assert 0 <= st["overlap_blocks"] <= st["blocks"]

    def test_local_thread_matches_sync_and_memory(self, index, saved_dir,
                                                  queries):
        mem = LocalBackend(index).knn(queries, k=1)
        with open_index(saved_dir) as saved:
            r_sync = self._ooc_local(saved, "sync").knn(queries, k=1)
            r_thr = self._ooc_local(saved, "thread").knn(queries, k=1)
            _same(mem, r_sync)
            _same(r_sync, r_thr)

    def test_parity_under_random_read_timings(self, data, saved_dir, queries,
                                              monkeypatch):
        """Jitter every threaded read by a random delay: answers must not
        depend on when the reader thread lands its fills."""
        rng = np.random.default_rng(1234)
        orig = AsyncChunkReader._fill

        def jittered(self, buf, start, count, pad_to):
            time.sleep(float(rng.uniform(0.0, 0.002)))
            orig(self, buf, start, count, pad_to)

        monkeypatch.setattr(AsyncChunkReader, "_fill", jittered)
        mem_scan = ScanBackend(data, CFG.search).knn(queries)
        with open_index(saved_dir) as saved:
            r_scan = self._ooc_scan(saved, "thread").knn(queries)
            _same(mem_scan, r_scan)
            r_sync = self._ooc_local(saved, "sync").knn(queries, k=2)
            r_thr = self._ooc_local(saved, "thread").knn(queries, k=2)
            _same(r_sync, r_thr)
        assert _no_reader_threads()

    @pytest.mark.parametrize("mode", PREFETCH_MODES)
    def test_minimum_viable_budget(self, data, index, saved_dir, queries,
                                   mode):
        """The smallest budget each backend accepts still answers
        bit-identically to the in-memory backends."""
        with open_index(saved_dir) as saved:
            # ooc-local floor: one max_leaf extent per streamed piece
            budget = budget_mb_for_stream_rows(saved.max_leaf)
            ooc = self._ooc_local(saved, mode, budget=budget)
            assert ooc.stream_rows() == saved.max_leaf
            _same(LocalBackend(index).knn(queries, k=1),
                  ooc.knn(queries, k=1))
            # ooc-scan floor: one scan_block per streamed block
            block = CFG.search.scan_block
            ooc = self._ooc_scan(saved, mode,
                                 budget=budget_mb_for_stream_rows(block))
            assert ooc.stream_rows() == block == ooc.base_config.scan_block
            _same(ScanBackend(data, CFG.search).knn(queries),
                  ooc.knn(queries))

    @pytest.mark.parametrize("mode", PREFETCH_MODES)
    def test_non_divisible_budgets(self, data, index, saved_dir, queries,
                                   mode):
        """stream_rows that divide neither scan_block nor max_leaf: ragged
        final pieces everywhere, still bit-identical."""
        with open_index(saved_dir) as saved:
            stream = 3 * saved.max_leaf // 2 + 1    # not a max_leaf multiple
            ooc = self._ooc_local(saved, mode,
                                  budget=budget_mb_for_stream_rows(stream))
            assert ooc.stream_rows() % saved.max_leaf != 0
            _same(LocalBackend(index).knn(queries, k=3),
                  ooc.knn(queries, k=3))

            stream = CFG.search.scan_block + 77     # not a scan_block multiple
            ooc = self._ooc_scan(saved, mode,
                                 budget=budget_mb_for_stream_rows(stream))
            assert ooc.stream_rows() % ooc.base_config.scan_block != 0
            _same(ScanBackend(data, CFG.search).knn(queries), ooc.knn(queries))


# ---------------------------------------------------------------------------
# Bugfix sweep: sax_pr accounting, shared budget arithmetic, auto-shrink
# ---------------------------------------------------------------------------

class TestSaxPrAccounting:
    def test_seeded_rows_counted_as_alive(self, saved_dir, queries):
        """With every leaf seeded in phase 1 there are no phase-3 pieces;
        the old accounting reported sax_pr == 1 (everything 'pruned') even
        though every row was read and refined. Seeded rows count as alive,
        so full coverage now reads as zero pruning."""
        with open_index(saved_dir) as saved:
            cfg = dataclasses.replace(CFG.search, l_max=saved.num_leaves)
            ooc = OutOfCoreLocalBackend(saved, cfg, memory_budget_mb=4.0)
            res = ooc.knn(queries, k=1)
            assert np.allclose(np.asarray(res.sax_pr), 0.0)

    def test_alive_rows_bounded_by_rows_streamed(self, saved_dir, data):
        """Per query, (1 - sax_pr) * N is the number of rows read-and-
        refined on its behalf; the rows actually streamed in the call are
        a superset (runs are unions over the batch plus contiguity fill)."""
        q = make_query_workload(jax.random.PRNGKey(5), data, 1, "5%")
        with open_index(saved_dir) as saved:
            ooc = OutOfCoreLocalBackend(saved, CFG.search,
                                        memory_budget_mb=0.125)
            res = ooc.knn(q, k=1)
            sax_pr = float(np.asarray(res.sax_pr)[0])
            alive = (1.0 - sax_pr) * saved.num_series
            accessed = int(np.asarray(res.accessed)[0])
            assert 0.0 < sax_pr < 1.0
            assert 0 < alive <= accessed + 1e-6
            assert accessed == ooc.stats()["rows_streamed"]


class TestBudgetArithmetic:
    def test_one_code_path_for_stream_rows(self, saved_dir):
        """The classmethod the CLI uses and the instance method the
        backends validate with must be the same arithmetic."""
        with open_index(saved_dir) as saved:
            for budget in (0.125, 0.5, 1.0, 64.0):
                expect = _OutOfCoreBase.budget_stream_rows(budget, LEN)
                scan = OutOfCoreScanBackend(saved, CFG.search,
                                            memory_budget_mb=budget)
                loc = OutOfCoreLocalBackend(saved, CFG.search,
                                            memory_budget_mb=budget)
                assert scan.stream_rows() == loc.stream_rows() == expect

    def test_stats_expose_read_telemetry(self, saved_dir, queries):
        with open_index(saved_dir) as saved:
            ooc = OutOfCoreScanBackend(saved, CFG.search,
                                       memory_budget_mb=0.125)
            ooc.knn(queries)
            st = ooc.stats()
            for key in ("read_seconds", "read_wait_seconds",
                        "overlap_blocks"):
                assert key in st


class TestScanBlockAutoShrink:
    def test_construction_shrinks_and_logs(self, data, saved_dir, queries,
                                           caplog):
        import logging

        mem = ScanBackend(data, CFG.search).knn(queries)
        with open_index(saved_dir) as saved:
            with caplog.at_level(logging.WARNING, "repro.core.engine"):
                ooc = OutOfCoreScanBackend(saved, CFG.search,
                                           memory_budget_mb=0.06)
            assert ooc.base_config.scan_block == ooc.stream_rows()
            assert any("auto-shrinking" in r.message for r in caplog.records)
            _same(mem, ooc.knn(queries))

    def test_entry_points_agree(self, saved_dir, queries):
        """Direct construction and make_disk_backend (the store/CLI path)
        shrink identically and answer identically."""
        with open_index(saved_dir) as saved:
            direct = OutOfCoreScanBackend(saved, CFG.search,
                                          memory_budget_mb=0.06)
            via_factory = make_disk_backend("ooc-scan", saved_dir,
                                            memory_budget_mb=0.06)
            assert (direct.base_config.scan_block
                    == via_factory.base_config.scan_block)
            _same(direct.knn(queries), via_factory.knn(queries))

    def test_explicit_override_still_rejected(self, saved_dir, queries):
        with open_index(saved_dir) as saved:
            ooc = OutOfCoreScanBackend(saved, CFG.search,
                                       memory_budget_mb=0.06)
            with pytest.raises(ValueError, match="memory_budget_mb"):
                ooc.knn(queries, scan_block=4096)


# ---------------------------------------------------------------------------
# Build-path prefetch: chunked builds stay bit-identical across modes
# ---------------------------------------------------------------------------

class TestBuildPrefetch:
    def test_streaming_build_thread_matches_sync(self, data):
        from repro.storage import build_index_streaming

        src = ArrayChunkSource(np.asarray(data), 300)   # ragged chunks
        a = build_index_streaming(src, CFG, prefetch="sync")
        b = build_index_streaming(src, CFG, prefetch="thread")
        for name in a.tree._fields:
            assert np.array_equal(np.asarray(getattr(a.tree, name)),
                                  np.asarray(getattr(b.tree, name))), name
        for name in ("lrd", "lsd", "perm", "leaf_start", "leaf_count"):
            assert np.array_equal(np.asarray(getattr(a.layout, name)),
                                  np.asarray(getattr(b.layout, name))), name
        assert _no_reader_threads()
