"""The Hercules store facade (repro/storage/store.py): the whole index
lifecycle through one handle.

Covers the PR's acceptance contract:
* append+compact ≡ from-scratch — ``Hercules.open(path, "a").append(B)``
  then ``compact()`` on an index built from A answers bit-identically to a
  from-scratch build over A∥B on ``local``, ``scan``, ``ooc-scan``, and
  ``ooc-local`` (and the tree/layout arrays themselves are bit-identical);
* exact journal-merge queries — with rows pending compaction, ``query``
  still answers bit-identically to the difference-form scan over the whole
  collection;
* crash safety — a kill between journal-segment write and manifest commit
  (or between compaction commit and cleanup) leaves orphans a writable
  reopen sweeps, never a corrupted store; version-1 directories still open;
* random chunkings — appending the collection in arbitrary pieces and
  compacting equals the one-shot build (hypothesis property);
* deterministic resource release — ``close()``/context managers actually
  drop the LRD/LSD memmaps;
* plan-cache invalidation — append/compact invalidate every engine the
  store handed out.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro.core.engine import LocalBackend, ScanBackend, make_disk_backend
from repro.core.index import HerculesIndex, IndexConfig
from repro.core.search import SearchConfig
from repro.core.tree import BuildConfig
from repro.data.pipeline import ArrayChunkSource
from repro.data.synthetic import make_query_workload, random_walks
from repro.storage import (Hercules, IndexFormatError, load_index,
                           open_index, save_index)
from repro.storage.format import (FORMAT_VERSION, JOURNAL_DIR,
                                  MANIFEST_FILE)

from tests._hypothesis_compat import given, settings, st

NUM_A, NUM_B, LEN = 2048, 1024, 64
CFG = IndexConfig(
    build=BuildConfig(leaf_capacity=64),
    search=SearchConfig(k=3, l_max=4, chunk=256, scan_block=512))
BUDGET_MB = 0.25   # collection is several x the ooc streaming budget


@pytest.fixture(scope="module")
def data_a():
    return np.asarray(random_walks(jax.random.PRNGKey(0), NUM_A, LEN))


@pytest.fixture(scope="module")
def data_b():
    return np.asarray(random_walks(jax.random.PRNGKey(5), NUM_B, LEN))


@pytest.fixture(scope="module")
def data_ab(data_a, data_b):
    return np.concatenate([data_a, data_b])


@pytest.fixture(scope="module")
def queries(data_ab):
    return np.asarray(make_query_workload(
        jax.random.PRNGKey(1), data_ab, 5, "5%"))


@pytest.fixture(scope="module")
def scratch_index(data_ab):
    """From-scratch one-shot build over A∥B — the acceptance oracle."""
    return HerculesIndex.build(data_ab, CFG)


@pytest.fixture(scope="module")
def compacted_dir(data_a, data_b, tmp_path_factory):
    """create(A) → reopen → append(B) → compact, in distinct handles (the
    reopen makes this the cross-handle path the acceptance criterion names)."""
    path = str(tmp_path_factory.mktemp("store") / "idx")
    with Hercules.create(path, CFG, data=data_a, chunk_size=700):
        pass
    with Hercules.open(path, "a") as hx:
        hx.append(data_b, chunk_size=500)
        hx.compact(chunk_size=900)
    return path


def _same(a, b, positions=True):
    assert np.array_equal(np.asarray(a.dists), np.asarray(b.dists))
    assert np.array_equal(np.asarray(a.ids), np.asarray(b.ids))
    if positions:
        assert np.array_equal(np.asarray(a.positions), np.asarray(b.positions))


class TestAppendCompactParity:
    """Acceptance oracle: append+compact ≡ from-scratch build over A∥B."""

    def test_tree_and_layout_bit_identical(self, compacted_dir, scratch_index):
        with Hercules.open(compacted_dir) as hx:
            loaded = hx.index()
        for name in scratch_index.tree._fields:
            assert np.array_equal(
                np.asarray(getattr(scratch_index.tree, name)),
                np.asarray(getattr(loaded.tree, name))), name
        for f in dataclasses.fields(scratch_index.layout):
            a = getattr(scratch_index.layout, f.name)
            b = getattr(loaded.layout, f.name)
            if isinstance(a, int):
                assert a == b, f.name
            else:
                assert np.array_equal(np.asarray(a), np.asarray(b)), f.name

    @pytest.mark.parametrize("backend", ["local", "scan", "ooc-scan",
                                         "ooc-local"])
    def test_backend_parity(self, compacted_dir, scratch_index, data_ab,
                            queries, backend):
        if backend == "local":
            mem = LocalBackend(scratch_index)
        else:
            mem = ScanBackend(data_ab, CFG.search)
        with Hercules.open(compacted_dir) as hx:
            res = hx.engine(backend, memory_budget_mb=BUDGET_MB).knn(
                queries, k=3)
            ref = mem.knn(queries, k=3)
            _same(res, ref, positions=backend in ("local",))

    def test_query_routes_through_engine(self, compacted_dir, scratch_index,
                                         queries):
        with Hercules.open(compacted_dir) as hx:
            _same(hx.query(queries, k=3),
                  LocalBackend(scratch_index).knn(queries, k=3))

    def test_multi_append_equals_single(self, data_a, data_b, data_ab,
                                        tmp_path):
        """Two appends in different chunkings compact to the same bytes."""
        path = str(tmp_path / "idx")
        with Hercules.create(path, CFG, data=data_a) as hx:
            hx.append(data_b[:300], chunk_size=128)
            hx.append(data_b[300:], chunk_size=999)
            assert len(hx.journal["segments"]) == 2
            hx.compact()
            oneshot = HerculesIndex.build(data_ab, CFG)
            assert np.array_equal(np.asarray(oneshot.layout.lrd),
                                  np.asarray(hx.saved._mapped("lrd")))


class TestJournalQueries:
    """Exactness with rows pending compaction (no rebuild needed)."""

    def test_journal_merge_matches_scan(self, data_a, data_b, data_ab,
                                        queries, tmp_path):
        path = str(tmp_path / "idx")
        with Hercules.create(path, CFG, data=data_a) as hx:
            hx.append(data_b)
            res = hx.query(queries, k=3)
            ref = ScanBackend(data_ab, CFG.search).knn(queries, k=3)
            assert np.array_equal(np.asarray(res.dists), np.asarray(ref.dists))
            assert np.array_equal(np.asarray(res.ids), np.asarray(ref.ids))
            # journal rows have no layout position yet
            journal_hits = np.asarray(res.ids) >= NUM_A
            assert journal_hits.any()
            assert (np.asarray(res.positions)[journal_hits] == -1).all()

    def test_empty_store_journal_only(self, data_ab, queries, tmp_path):
        path = str(tmp_path / "idx")
        with Hercules.create(path, CFG) as hx:
            assert hx.saved is None and hx.num_series == 0
            with pytest.raises(IndexFormatError, match="empty"):
                hx.query(queries, k=3)
            hx.append(data_ab[:NUM_A])
            hx.append(data_ab[NUM_A:])
            res = hx.query(queries, k=3)
            ref = ScanBackend(data_ab, CFG.search).knn(queries, k=3)
            assert np.array_equal(np.asarray(res.dists), np.asarray(ref.dists))
            assert np.array_equal(np.asarray(res.ids), np.asarray(ref.ids))
            # engine() needs a base; query() does not
            with pytest.raises(IndexFormatError, match="base"):
                hx.engine("local")
            hx.compact()
            res2 = hx.engine("local").knn(queries, k=3)
            assert np.array_equal(np.asarray(res2.dists),
                                  np.asarray(ref.dists))

    def test_index_refuses_pending_rows(self, data_a, data_b, tmp_path):
        path = str(tmp_path / "idx")
        with Hercules.create(path, CFG, data=data_a) as hx:
            hx.append(data_b)
            with pytest.raises(IndexFormatError, match="pending"):
                hx.index()


class TestAppendValidation:
    def test_mode_r_rejects_mutation(self, compacted_dir, data_b):
        with Hercules.open(compacted_dir) as hx:
            with pytest.raises(IndexFormatError, match="read-only"):
                hx.append(data_b)
            with pytest.raises(IndexFormatError, match="read-only"):
                hx.compact()

    def test_series_len_mismatch(self, data_a, tmp_path):
        path = str(tmp_path / "idx")
        with Hercules.create(path, CFG, data=data_a) as hx:
            with pytest.raises(ValueError, match="series length"):
                hx.append(np.zeros((4, LEN * 2), np.float32))

    def test_empty_append(self, data_a, tmp_path):
        path = str(tmp_path / "idx")
        with Hercules.create(path, CFG, data=data_a) as hx:
            with pytest.raises(ValueError, match="at least one row"):
                hx.append(np.zeros((0, LEN), np.float32))

    def test_create_refuses_existing(self, compacted_dir, data_a):
        with pytest.raises(IndexFormatError, match="already"):
            Hercules.create(compacted_dir, CFG, data=data_a)

    def test_compact_without_journal_is_noop(self, data_a, tmp_path):
        path = str(tmp_path / "idx")
        with Hercules.create(path, CFG, data=data_a) as hx:
            gen = hx.generation
            hx.compact()
            assert hx.generation == gen


class TestCrashSafety:
    def _store(self, data_a, tmp_path) -> str:
        path = str(tmp_path / "idx")
        Hercules.create(path, CFG, data=data_a).close()
        return path

    def test_segment_without_commit_is_swept(self, data_a, data_b, tmp_path,
                                             queries):
        """Kill between journal-segment write and manifest commit: the
        segment files exist but the manifest never named them — reopen
        recovers cleanly and serves the committed state."""
        path = self._store(data_a, tmp_path)
        os.makedirs(os.path.join(path, JOURNAL_DIR), exist_ok=True)
        np.save(os.path.join(path, JOURNAL_DIR, "seg-00000.lrd.npy"), data_b)
        np.save(os.path.join(path, JOURNAL_DIR, "seg-00000.lsd.npy"),
                np.zeros((NUM_B, 16), np.uint8))
        with Hercules.open(path, "a") as hx:
            assert sorted(hx.recovered) == [
                f"{JOURNAL_DIR}/seg-00000.lrd.npy",
                f"{JOURNAL_DIR}/seg-00000.lsd.npy"]
            assert hx.pending_rows == 0
            assert hx.num_series == NUM_A
            hx.query(queries, k=1)      # serves the committed state
            # the swept name is reusable: append lands a fresh segment 0
            seg = hx.append(data_b)
            assert seg["name"] == "seg-00000"
            assert hx.pending_rows == NUM_B

    def test_readonly_open_does_not_sweep(self, data_a, tmp_path):
        path = self._store(data_a, tmp_path)
        orphan = os.path.join(path, JOURNAL_DIR, "seg-00000.lrd.npy")
        os.makedirs(os.path.dirname(orphan), exist_ok=True)
        np.save(orphan, np.zeros((2, LEN), np.float32))
        with Hercules.open(path) as hx:
            assert hx.recovered == []
        assert os.path.exists(orphan)

    def test_interrupted_compaction_cleanup(self, data_a, data_b, tmp_path):
        """Kill after the compaction's manifest commit but before the old
        generation + journal were deleted: reopen sweeps the leftovers."""
        path = self._store(data_a, tmp_path)
        with Hercules.open(path, "a") as hx:
            hx.append(data_b)
            hx.compact()
            assert hx.generation == 1
        # resurrect plausible pre-compact leftovers
        np.save(os.path.join(path, "lrd.npy"), np.zeros((4, LEN), np.float32))
        os.makedirs(os.path.join(path, JOURNAL_DIR), exist_ok=True)
        np.save(os.path.join(path, JOURNAL_DIR, "seg-00000.lrd.npy"), data_b)
        with Hercules.open(path, "a") as hx:
            assert "lrd.npy" in hx.recovered
            assert f"{JOURNAL_DIR}/seg-00000.lrd.npy" in hx.recovered
            assert hx.num_series == NUM_A + NUM_B

    def test_journal_segment_corruption_detected(self, data_a, data_b,
                                                 tmp_path):
        path = self._store(data_a, tmp_path)
        with Hercules.open(path, "a") as hx:
            hx.append(data_b)
        seg = os.path.join(path, JOURNAL_DIR, "seg-00000.lrd.npy")
        size = os.path.getsize(seg)
        with open(seg, "r+b") as f:
            f.seek(size // 2)
            byte = f.read(1)
            f.seek(size // 2)
            f.write(bytes([byte[0] ^ 0xFF]))
        with pytest.raises(IndexFormatError, match="checksum|corrupted"):
            Hercules.open(path, "a")

    def test_v1_directory_still_opens(self, data_a, tmp_path, queries):
        """A pre-journal (version 1) manifest opens, serves, and migrates
        to the current format version on its first append."""
        path = str(tmp_path / "idx")
        save_index(HerculesIndex.build(data_a, CFG), path)
        mf = os.path.join(path, MANIFEST_FILE)
        manifest = json.load(open(mf))
        for key in ("journal", "generation"):
            manifest.pop(key, None)
        manifest["version"] = 1
        json.dump(manifest, open(mf, "w"))
        assert load_index(path).layout.num_series == NUM_A
        with Hercules.open(path, "a") as hx:
            assert hx.generation == 0 and hx.pending_rows == 0
            hx.query(queries, k=1)
            hx.append(data_a[:16])
        assert json.load(open(mf))["version"] == FORMAT_VERSION


class TestResourceRelease:
    def test_saved_index_close_releases_memmaps(self, compacted_dir):
        saved = open_index(compacted_dir)
        mm = saved.lrd._mmap
        saved.close()
        assert saved.closed and saved.lrd is None and saved.lsd is None
        assert mm.closed
        saved.close()                    # idempotent
        with pytest.raises(IndexFormatError, match="closed"):
            saved.original_data()

    def test_saved_index_context_manager(self, compacted_dir):
        with open_index(compacted_dir) as saved:
            assert saved.num_series == NUM_A + NUM_B
        assert saved.closed

    def test_store_close_is_loud_for_stale_backends(self, compacted_dir,
                                                    queries):
        hx = Hercules.open(compacted_dir)
        backend = make_disk_backend("ooc-scan", hx,
                                    memory_budget_mb=BUDGET_MB)
        hx.close()
        with pytest.raises(IndexFormatError, match="closed"):
            backend.knn(queries, k=1)
        with pytest.raises(IndexFormatError, match="closed"):
            hx.query(queries, k=1)

    def test_compact_closes_previous_generation(self, data_a, data_b,
                                                tmp_path):
        path = str(tmp_path / "idx")
        with Hercules.create(path, CFG, data=data_a) as hx:
            old = hx.saved
            hx.append(data_b)
            hx.compact()
            assert old.closed and not hx.saved.closed


class TestPlanInvalidation:
    def test_append_and_compact_invalidate_engines(self, data_a, data_b,
                                                   queries, tmp_path):
        path = str(tmp_path / "idx")
        with Hercules.create(path, CFG, data=data_a) as hx:
            eng = hx.engine("local")
            eng.knn(queries, k=1)
            assert eng.telemetry()["plan_cache"]["size"] == 1
            v0 = hx.data_version

            hx.append(data_b)
            assert hx.data_version == v0 + 1
            tele = eng.telemetry()["plan_cache"]
            assert tele["invalidations"] == 1 and tele["size"] == 0
            # the store hands out a *fresh* engine after the mutation
            assert hx.engine("local") is not eng

            eng2 = hx.engine("local")
            hx.compact()
            assert eng2.telemetry()["plan_cache"]["invalidations"] == 1
            # post-compact engine serves the appended rows
            res = hx.engine("local").knn(queries, k=3)
            ref = LocalBackend(HerculesIndex.build(
                np.concatenate([data_a, data_b]), CFG)).knn(queries, k=3)
            assert np.array_equal(np.asarray(res.dists), np.asarray(ref.dists))

    def test_engine_cache_reuse(self, compacted_dir):
        with Hercules.open(compacted_dir) as hx:
            assert hx.engine("local") is hx.engine("local")
            assert hx.engine("local") is not hx.engine("scan")

    def test_make_disk_backend_accepts_handle_and_saved(self, compacted_dir,
                                                        queries):
        with Hercules.open(compacted_dir) as hx:
            via_handle = make_disk_backend("local", hx)
            via_saved = make_disk_backend("local", hx.saved)
            via_path = make_disk_backend("local", compacted_dir)
            r1 = via_handle.knn(queries, k=1)
            _same(via_saved.knn(queries, k=1), r1)
            _same(via_path.knn(queries, k=1), r1)


class TestOocSaxStreaming:
    """Satellite: streamed LSD phase-3 pruning for ooc-local."""

    def test_sax_filter_cuts_reads_and_stays_exact(self, compacted_dir,
                                                   scratch_index, queries):
        with Hercules.open(compacted_dir) as hx:
            with_sax = hx.engine("ooc-local", memory_budget_mb=BUDGET_MB)
            res = with_sax.knn(queries, k=3)
            ref = LocalBackend(scratch_index).knn(queries, k=3)
            assert np.array_equal(np.asarray(res.dists), np.asarray(ref.dists))
            assert np.array_equal(np.asarray(res.ids), np.asarray(ref.ids))
            st_sax = with_sax.backend.stats()
            assert st_sax["sax_rows_read"] > 0
            assert np.all(np.asarray(res.sax_pr) >= 0)

            no_sax = hx.engine(
                "ooc-local",
                search=dataclasses.replace(CFG.search, use_sax=False),
                memory_budget_mb=BUDGET_MB)
            res2 = no_sax.knn(queries, k=3)
            assert np.array_equal(np.asarray(res2.dists),
                                  np.asarray(ref.dists))
            st_no = no_sax.backend.stats()
            assert st_no["sax_rows_read"] == 0
            # the per-series filter must fetch no more rows than
            # leaf-granularity pruning alone
            assert st_sax["rows_streamed"] <= st_no["rows_streamed"]


class TestRandomChunkings:
    @settings(max_examples=5, deadline=None)
    @given(st.data())
    def test_append_any_chunking_equals_oneshot(self, tmp_path_factory, data):
        """Property: appending the collection in arbitrary pieces (random
        split points, random per-append chunk sizes) and compacting equals
        the one-shot build bit-for-bit."""
        num, n = 384, 32
        cfg = IndexConfig(
            build=BuildConfig(leaf_capacity=48),
            search=SearchConfig(k=1, l_max=2, chunk=64, scan_block=64))
        rows = np.asarray(random_walks(jax.random.PRNGKey(7), num, n))
        n_cuts = data.draw(st.integers(0, 3), label="n_cuts")
        cuts = sorted(data.draw(
            st.lists(st.integers(1, num - 1), min_size=n_cuts,
                     max_size=n_cuts, unique=True), label="cuts"))
        pieces = np.split(rows, cuts)
        first_chunk = data.draw(st.integers(32, 512), label="first_chunk")

        path = str(tmp_path_factory.mktemp("prop") / "idx")
        with Hercules.create(path, cfg,
                             data=ArrayChunkSource(pieces[0], first_chunk)) \
                as hx:
            for piece in pieces[1:]:
                hx.append(piece, chunk_size=data.draw(
                    st.integers(16, 512), label="chunk"))
            hx.compact(chunk_size=data.draw(st.integers(32, 512),
                                            label="compact_chunk"))
            oneshot = HerculesIndex.build(rows, cfg)
            for name in oneshot.tree._fields:
                assert np.array_equal(
                    np.asarray(getattr(oneshot.tree, name)),
                    np.asarray(getattr(hx.saved.tree, name))), name
            assert np.array_equal(np.asarray(oneshot.layout.lrd),
                                  np.asarray(hx.saved._mapped("lrd")))
            assert np.array_equal(np.asarray(oneshot.layout.lsd),
                                  np.asarray(hx.saved._mapped("lsd")))
