"""Wave-fused multi-query search: parity, sharing, telemetry.

PR 7 acceptance criteria live here:
  * a wave answer is bit-identical to serving each member through a
    per-query ``QueryEngine.knn`` call, on every backend (in-memory
    local/scan/sharded and streamed ooc-scan/ooc-local) — the shared
    descent, shared BSF matrix and merged leaf-run schedule are pure
    work-sharing, never an approximation;
  * on a clustered workload the ooc-local wave path actually shares work:
    ``runs_deduped > 0`` and the wave streams strictly fewer rows than the
    same queries served independently;
  * wave plans and per-query plans are distinct plan-cache entries.
"""
import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BuildConfig, HerculesIndex, IndexConfig, LocalBackend,
                        QueryEngine, ScanBackend, SearchConfig, exact_knn,
                        make_backend, make_disk_backend, wave_knn)
from repro.data import make_query_workload, random_walks
from repro.storage import save_index

jax.config.update("jax_platform_name", "cpu")

NUM, LEN, K = 2048, 64, 3
CFG = IndexConfig(build=BuildConfig(leaf_capacity=64),
                  search=SearchConfig(k=K, l_max=4, chunk=256,
                                      scan_block=256))


@pytest.fixture(scope="module")
def data():
    return random_walks(jax.random.PRNGKey(0), NUM, LEN)


@pytest.fixture(scope="module")
def queries(data):
    easy = make_query_workload(jax.random.PRNGKey(1), data, 4, "1%")
    hard = make_query_workload(jax.random.PRNGKey(2), data, 4, "ood")
    return jnp.concatenate([easy, hard])


@pytest.fixture(scope="module")
def clustered(data):
    """Queries perturbed from nearby dataset rows: wave members share home
    leaves, so the merged leaf-run schedule has real overlap to dedup."""
    rows = np.asarray(data)[100:108]
    noise = 0.01 * np.asarray(
        jax.random.normal(jax.random.PRNGKey(3), rows.shape))
    return jnp.asarray(rows + noise)


@pytest.fixture(scope="module")
def index(data):
    return HerculesIndex.build(data, CFG)


@pytest.fixture(scope="module")
def saved_dir(index, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("wave") / "idx")
    save_index(index, path)
    return path


def _per_query(engine, queries, **kw):
    outs = [engine.knn(q[None], **kw) for q in np.asarray(queries)]
    return types.SimpleNamespace(
        dists=np.concatenate([np.asarray(r.dists) for r in outs]),
        ids=np.concatenate([np.asarray(r.ids) for r in outs]),
        path=np.concatenate([np.asarray(r.path) for r in outs]))


def _assert_wave_parity(engine, queries):
    solo = _per_query(engine, queries)
    wave = engine.knn(queries, wave=True)
    assert np.array_equal(np.asarray(wave.dists), solo.dists)
    assert np.array_equal(np.sort(np.asarray(wave.ids), axis=1),
                          np.sort(solo.ids, axis=1))


class TestCoreWaveKnn:
    def test_wave_knn_matches_exact_knn_bitwise(self, index, queries):
        base = CFG.search
        cfgs = [base,
                dataclasses.replace(base, use_sax=False),
                dataclasses.replace(base, force_scan=True),
                dataclasses.replace(base, adaptive=False),
                dataclasses.replace(base, refine_select="topk")]
        for cfg in cfgs:
            wave = wave_knn(index.tree, index.layout, queries, cfg,
                            index.max_depth)
            for i, q in enumerate(queries):
                solo = exact_knn(index.tree, index.layout, q[None], cfg,
                                 index.max_depth)
                assert np.array_equal(np.asarray(wave.dists[i]),
                                      np.asarray(solo.dists[0])), cfg
                assert np.array_equal(np.sort(np.asarray(wave.ids[i])),
                                      np.sort(np.asarray(solo.ids[0]))), cfg
                assert int(wave.path[i]) == int(solo.path[0]), cfg


class TestEngineWaveParity:
    def test_local(self, index, queries):
        _assert_wave_parity(QueryEngine(LocalBackend(index)), queries)

    def test_scan(self, data, queries):
        _assert_wave_parity(
            QueryEngine(ScanBackend(data, CFG.search)), queries)

    def test_sharded(self, data, queries):
        _assert_wave_parity(
            QueryEngine(make_backend("sharded", data, index_config=CFG,
                                     num_shards=1)), queries)

    def test_ooc_scan(self, saved_dir, queries):
        eng = QueryEngine(make_disk_backend(
            "ooc-scan", saved_dir, search=CFG.search, memory_budget_mb=1.0))
        _assert_wave_parity(eng, queries)
        st = eng.stats()
        assert st["wave_calls"] == 1 and st["wave_rows_shared"] > 0

    def test_ooc_local(self, saved_dir, queries):
        for search in (CFG.search,
                       dataclasses.replace(CFG.search, use_sax=False)):
            eng = QueryEngine(make_disk_backend(
                "ooc-local", saved_dir, search=search, memory_budget_mb=1.0))
            _assert_wave_parity(eng, queries)
            assert eng.stats()["wave_calls"] == 1


class TestWaveSharing:
    def test_clustered_wave_dedups_runs_and_streams_less(self, saved_dir,
                                                         clustered):
        eng = QueryEngine(make_disk_backend(
            "ooc-local", saved_dir, search=CFG.search, memory_budget_mb=1.0))
        solo = _per_query(eng, clustered)
        rows_solo = eng.stats()["rows_streamed"]
        assert eng.stats()["runs_deduped"] == 0   # per-query: nothing shared

        wave = eng.knn(clustered, wave=True)
        st = eng.stats()
        rows_wave = st["rows_streamed"] - rows_solo
        # exactness first, then the sharing pins
        assert np.array_equal(np.asarray(wave.dists), solo.dists)
        assert st["runs_deduped"] > 0
        assert st["wave_rows_shared"] > 0
        assert rows_wave < rows_solo

    def test_engine_telemetry_surfaces_ooc_wave_counters(self, saved_dir,
                                                         clustered):
        eng = QueryEngine(make_disk_backend(
            "ooc-local", saved_dir, search=CFG.search, memory_budget_mb=1.0))
        eng.knn(clustered, wave=True)
        tele = eng.telemetry()
        assert tele["wave_calls"] == 1
        ooc = tele["ooc"]
        for key in ("rows_streamed", "wave_calls", "wave_rows_shared",
                    "runs_deduped", "runs_skipped_bsf"):
            assert key in ooc
        assert ooc["wave_calls"] == 1

    def test_in_memory_telemetry_has_no_ooc_section(self, index, queries):
        eng = QueryEngine(LocalBackend(index))
        eng.knn(queries, wave=True)
        assert "ooc" not in eng.telemetry()


class TestWavePlanCache:
    def test_wave_and_solo_plans_are_distinct(self, index, queries):
        eng = QueryEngine(LocalBackend(index))
        eng.knn(queries)
        eng.knn(queries, wave=True)
        pc = eng.telemetry()["plan_cache"]
        assert pc["misses"] == 2
        # repeats of either flavour hit their own plan
        eng.knn(queries)
        eng.knn(queries, wave=True)
        pc = eng.telemetry()["plan_cache"]
        assert (pc["misses"], pc["hits"]) == (2, 2)
