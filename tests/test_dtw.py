"""DTW support (paper §2): banded DTW, LB_Keogh bound, exact DTW kNN."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import BuildConfig, HerculesIndex, IndexConfig, SearchConfig
from repro.core.dtw import dtw_distance, dtw_knn, keogh_envelope, lb_keogh
from repro.data import random_walks

jax.config.update("jax_platform_name", "cpu")


def _ref_dtw(a, b, band):
    n = len(a)
    big = 1e30
    dd = np.full((n, n), big)
    for i in range(n):
        for j in range(max(0, i - band), min(n, i + band + 1)):
            c = (a[i] - b[j]) ** 2
            prev = 0.0 if (i == 0 and j == 0) else min(
                dd[i - 1, j] if i else big,
                dd[i, j - 1] if j else big,
                dd[i - 1, j - 1] if (i and j) else big)
            dd[i, j] = c + prev
    return dd[-1, -1]


class TestDTW:
    @pytest.mark.parametrize("band", [1, 3, 7])
    def test_matches_reference(self, band, rng):
        a = rng.normal(size=12).astype(np.float32)
        b = rng.normal(size=(4, 12)).astype(np.float32)
        got = np.asarray(dtw_distance(jnp.asarray(a), jnp.asarray(b), band))
        want = np.array([_ref_dtw(a, x, band) for x in b])
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_band_zero_is_euclidean(self, rng):
        a = rng.normal(size=8).astype(np.float32)
        b = rng.normal(size=(3, 8)).astype(np.float32)
        got = np.asarray(dtw_distance(jnp.asarray(a), jnp.asarray(b), 0))
        np.testing.assert_allclose(got, ((b - a) ** 2).sum(-1), rtol=1e-4)

    def test_identical_series_zero(self, rng):
        a = rng.normal(size=10).astype(np.float32)
        assert float(dtw_distance(jnp.asarray(a), jnp.asarray(a)[None], 3)[0]) \
            == pytest.approx(0.0, abs=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 5))
    def test_lb_keogh_lower_bounds_dtw(self, seed, band):
        rng = np.random.default_rng(seed)
        a = rng.normal(size=12).astype(np.float32)
        b = rng.normal(size=(4, 12)).astype(np.float32)
        lb = np.asarray(lb_keogh(jnp.asarray(a), jnp.asarray(b), band))
        dtw = np.array([_ref_dtw(a, x, band) for x in b])
        assert (lb <= dtw + 1e-3).all()

    def test_envelope_contains_query(self, rng):
        q = jnp.asarray(rng.normal(size=16).astype(np.float32))
        lo, hi = keogh_envelope(q, 2)
        assert bool(jnp.all((lo <= q) & (q <= hi)))

    def test_dtw_knn_exact(self):
        data = random_walks(jax.random.PRNGKey(0), 300, 32)
        idx = HerculesIndex.build(data, IndexConfig(
            build=BuildConfig(leaf_capacity=64),
            search=SearchConfig(k=3, chunk=64, scan_block=64, l_max=4)))
        q = data[:2] + 0.05
        d, p = dtw_knn(idx.layout, q, k=2, band=3,
                       cfg=SearchConfig(k=2, chunk=64, scan_block=64))
        bf = np.stack([
            np.sort([_ref_dtw(np.asarray(qq), np.asarray(s), 3)
                     for s in np.asarray(data)])[:2]
            for qq in np.asarray(q)])
        np.testing.assert_allclose(np.asarray(d), bf, rtol=1e-3, atol=1e-3)
