"""Tree-build invariants: conservation, path consistency, synopsis soundness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import summaries as S
from repro.core.layout import build_layout
from repro.core.tree import (BuildConfig, build_tree, inorder_leaves,
                             route_to_leaf, tree_stats)
from repro.data import random_walks

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def built():
    data = random_walks(jax.random.PRNGKey(7), 3000, 128)
    cfg = BuildConfig(leaf_capacity=100, max_segments=16, init_segments=4)
    tree, node_of = build_tree(data, cfg)
    return data, cfg, tree, node_of


class TestBuildInvariants:
    def test_conservation(self, built):
        data, cfg, tree, node_of = built
        st = tree_stats(tree)
        assert st["total_in_leaves"] == data.shape[0]

    def test_all_assignments_are_leaves(self, built):
        _, _, tree, node_of = built
        assert bool(jnp.all(tree.is_leaf[node_of]))

    def test_leaf_capacity_respected(self, built):
        data, cfg, tree, node_of = built
        st = tree_stats(tree)
        # random walks have no duplicates -> no degenerate leaves
        assert st["max_leaf"] <= cfg.leaf_capacity

    def test_parent_child_wiring(self, built):
        _, _, tree, _ = built
        nn = int(tree.num_nodes)
        left = np.asarray(tree.left[:nn]); right = np.asarray(tree.right[:nn])
        parent = np.asarray(tree.parent[:nn])
        is_leaf = np.asarray(tree.is_leaf[:nn])
        for node in range(nn):
            if is_leaf[node]:
                assert left[node] == -1 and right[node] == -1
            else:
                assert parent[left[node]] == node
                assert parent[right[node]] == node

    def test_routing_matches_assignment(self, built):
        data, _, tree, node_of = built
        depth = tree_stats(tree)["max_depth"]
        routed = route_to_leaf(tree, data, depth)
        np.testing.assert_array_equal(np.asarray(routed), np.asarray(node_of))

    def test_split_semantics_along_path(self, built):
        """Every series satisfies the split predicate of each ancestor."""
        data, _, tree, node_of = built
        nn = int(tree.num_nodes)
        parent = np.asarray(tree.parent[:nn])
        left = np.asarray(tree.left[:nn])
        lo = np.asarray(tree.split_lo[:nn]); hi = np.asarray(tree.split_hi[:nn])
        use_std = np.asarray(tree.split_use_std[:nn])
        val = np.asarray(tree.split_value[:nn])
        x = np.asarray(data)
        nof = np.asarray(node_of)
        for i in range(0, x.shape[0], 97):            # sample series
            node = nof[i]
            while parent[node] != -1:
                par = parent[node]
                seg = x[i, lo[par]:hi[par]]
                stat = seg.std() if use_std[par] else seg.mean()
                if node == left[par]:
                    assert stat < val[par] + 1e-5
                else:
                    assert stat >= val[par] - 1e-5
                node = par

    def test_vsplit_refines_segmentation(self, built):
        _, _, tree, _ = built
        nn = int(tree.num_nodes)
        nsegs = np.asarray(tree.num_segs[:nn])
        parent = np.asarray(tree.parent[:nn])
        for node in range(1, nn):
            assert nsegs[node] in (nsegs[parent[node]], nsegs[parent[node]] + 1)

    def test_synopsis_bounds_members(self, built):
        """Node synopsis must contain the stats of every member series."""
        data, _, tree, node_of = built
        x = np.asarray(data)
        syn = np.asarray(tree.synopsis)
        ep_all = np.asarray(tree.endpoints)
        parent = np.asarray(tree.parent)
        nof = np.asarray(node_of)
        for i in range(0, x.shape[0], 211):
            node = nof[i]
            while node != -1:
                ep = ep_all[node]
                prev = 0
                for j, e in enumerate(ep):
                    if e > prev:
                        seg = x[i, prev:e]
                        mu, sd = seg.mean(), seg.std()
                        assert syn[node, j, 0] <= mu + 1e-4
                        assert syn[node, j, 1] >= mu - 1e-4
                        assert syn[node, j, 2] <= sd + 1e-4
                        assert syn[node, j, 3] >= sd - 1e-4
                    prev = max(prev, e)
                node = parent[node]

    def test_determinism(self):
        data = random_walks(jax.random.PRNGKey(3), 500, 64)
        cfg = BuildConfig(leaf_capacity=50)
        t1, n1 = build_tree(data, cfg)
        t2, n2 = build_tree(data, cfg)
        np.testing.assert_array_equal(np.asarray(n1), np.asarray(n2))
        np.testing.assert_array_equal(np.asarray(t1.num_nodes), np.asarray(t2.num_nodes))

    def test_duplicate_data_no_infinite_loop(self):
        """All-identical series can never split: no_split must engage."""
        data = jnp.ones((300, 64))
        cfg = BuildConfig(leaf_capacity=50, max_rounds=16)
        tree, node_of = build_tree(data, cfg)
        st = tree_stats(tree)
        assert st["num_leaves"] == 1
        assert st["max_leaf"] == 300


class TestLayout:
    def test_inorder_extents_partition(self, built):
        data, _, tree, node_of = built
        lay = build_layout(tree, node_of, data, pad_series_to_multiple=256)
        ls = np.asarray(lay.leaf_start)[:lay.num_leaves]
        lc = np.asarray(lay.leaf_count)[:lay.num_leaves]
        assert lc.sum() == data.shape[0]
        np.testing.assert_array_equal(ls[1:], ls[:-1] + lc[:-1])

    def test_lrd_is_permuted_data(self, built):
        data, _, tree, node_of = built
        lay = build_layout(tree, node_of, data)
        np.testing.assert_allclose(
            np.asarray(lay.lrd)[:data.shape[0]],
            np.asarray(data)[np.asarray(lay.perm)])

    def test_inv_perm_roundtrip(self, built):
        data, _, tree, node_of = built
        lay = build_layout(tree, node_of, data)
        p = np.asarray(lay.perm); ip = np.asarray(lay.inv_perm)
        np.testing.assert_array_equal(p[ip], np.arange(data.shape[0]))

    def test_series_leaf_rank_consistent(self, built):
        data, _, tree, node_of = built
        lay = build_layout(tree, node_of, data, pad_series_to_multiple=128)
        sr = np.asarray(lay.series_leaf_rank)
        ls = np.asarray(lay.leaf_start); lc = np.asarray(lay.leaf_count)
        for r in range(lay.num_leaves):
            np.testing.assert_array_equal(sr[ls[r]:ls[r] + lc[r]], r)
        # pad rows carry the sentinel rank
        assert (sr[data.shape[0]:] == lay.leaf_start.shape[0]).all()

    def test_lsd_matches_isax_of_lrd(self, built):
        data, _, tree, node_of = built
        lay = build_layout(tree, node_of, data)
        want = np.asarray(S.isax(lay.lrd[:data.shape[0]], 16))
        np.testing.assert_array_equal(np.asarray(lay.lsd)[:data.shape[0]], want)

    def test_inorder_covers_all_leaves(self, built):
        _, _, tree, _ = built
        order = inorder_leaves(tree)
        st = tree_stats(tree)
        assert len(order) == st["num_leaves"]
        assert len(set(order.tolist())) == len(order)
