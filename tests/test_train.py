"""Training substrate: optimizer, schedules, loss masking, checkpointing,
gradient compression, microbatching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import get_model
from repro.train import (AdamWConfig, TrainConfig, adamw_init, adamw_update,
                         cross_entropy, load_checkpoint, make_labels,
                         make_train_step, save_checkpoint)
from repro.train.checkpoint import latest_step
from repro.train.compression import (compress_int8, decompress_int8,
                                     init_error_buffer, make_compressed_psum)
from repro.train.optimizer import lr_at
from repro.train.train_step import init_train_state

jax.config.update("jax_platform_name", "cpu")


class TestOptimizer:
    def _quad(self, moment_dtype):
        """AdamW must descend a simple quadratic."""
        cfg = AdamWConfig(learning_rate=0.1, warmup_steps=0, total_steps=100,
                          weight_decay=0.0, moment_dtype=moment_dtype,
                          schedule="constant")
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = adamw_init(params, cfg)
        for _ in range(60):
            grads = {"w": 2.0 * params["w"]}
            params, state, _ = adamw_update(params, grads, state, cfg)
        return float(jnp.abs(params["w"]).max())

    def test_adamw_converges_fp32(self):
        assert self._quad("float32") < 0.5

    def test_adamw_converges_int8_moments(self):
        assert self._quad("int8") < 0.6

    def test_grad_clip(self):
        cfg = AdamWConfig(grad_clip=1.0, warmup_steps=0, schedule="constant")
        params = {"w": jnp.zeros((4,))}
        state = adamw_init(params, cfg)
        _, _, m = adamw_update(params, {"w": jnp.full((4,), 100.0)}, state, cfg)
        assert float(m["grad_norm"]) == pytest.approx(200.0)

    def test_schedules(self):
        cfg = AdamWConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
        assert float(lr_at(cfg, jnp.asarray(5))) == pytest.approx(0.5)
        assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0)
        assert float(lr_at(cfg, jnp.asarray(100))) == pytest.approx(
            cfg.final_lr_frac, rel=1e-3)
        wsd = AdamWConfig(learning_rate=1.0, warmup_steps=10, total_steps=100,
                          schedule="wsd")
        assert float(lr_at(wsd, jnp.asarray(50))) == pytest.approx(1.0)
        assert float(lr_at(wsd, jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)


class TestLoss:
    def test_perfect_prediction_zero_loss(self):
        labels = jnp.asarray([[1, 2, 0]])
        logits = jax.nn.one_hot(labels, 4) * 100.0
        mask = jnp.asarray([[1.0, 1.0, 0.0]])
        loss, m = cross_entropy(logits, labels, mask)
        assert float(loss) < 1e-3
        assert float(m["accuracy"]) == 1.0

    def test_mask_excludes_positions(self):
        labels = jnp.asarray([[1, 1]])
        logits = jnp.zeros((1, 2, 4)).at[0, 1, 1].set(-100.0)
        m_all = cross_entropy(logits, labels, jnp.asarray([[1.0, 1.0]]))[0]
        m_first = cross_entropy(logits, labels, jnp.asarray([[1.0, 0.0]]))[0]
        assert float(m_first) < float(m_all)

    def test_vlm_labels_skip_patches(self):
        from repro.configs import get_smoke
        cfg = get_smoke("phi-3-vision-4.2b")
        tokens = jnp.arange(10)[None].astype(jnp.int32) + 1
        labels, mask = make_labels({"tokens": tokens}, cfg)
        p = cfg.num_patches
        assert labels.shape == (1, p + 10)
        # position p-1 predicts the first text token
        assert int(labels[0, p - 1]) == 1
        assert float(mask[0, 0]) == 0.0
        assert float(mask[0, p - 1]) == 1.0
        assert float(mask[0, -1]) == 0.0


class TestMicrobatching:
    def test_grad_accumulation_matches_full_batch(self, key):
        cfg = get_smoke("codeqwen1.5-7b")
        model = get_model(cfg)
        batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size)}
        t1 = TrainConfig(optimizer=AdamWConfig(warmup_steps=0, schedule="constant"))
        t2 = TrainConfig(optimizer=AdamWConfig(warmup_steps=0, schedule="constant"),
                         microbatches=2)
        params, opt = init_train_state(model, cfg, t1, key)
        p1, _, m1 = jax.jit(make_train_step(model, cfg, t1))(params, opt, batch)
        p2, _, m2 = jax.jit(make_train_step(model, cfg, t2))(params, opt, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-4)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-5)


class TestLossDecreases:
    def test_loss_decreases_over_steps(self, key):
        """The end-to-end sanity check: a tiny model memorizes a batch."""
        cfg = get_smoke("minicpm-2b")
        model = get_model(cfg)
        tcfg = TrainConfig(optimizer=AdamWConfig(
            learning_rate=3e-3, warmup_steps=5, total_steps=40,
            weight_decay=0.0, schedule="constant"))
        params, opt = init_train_state(model, cfg, tcfg, key)
        step = jax.jit(make_train_step(model, cfg, tcfg))
        batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}
        losses = []
        for _ in range(25):
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] * 0.7, losses[::6]


class TestCheckpoint:
    def test_roundtrip_and_atomicity(self, tmp_path, key):
        state = {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                            "blocks": [{"a": jnp.ones((2,))}, {"a": jnp.zeros((2,))}]},
                 "step": jnp.asarray(7)}
        d = str(tmp_path)
        save_checkpoint(d, 7, state, {"rng_seed": 42})
        save_checkpoint(d, 9, state)
        assert latest_step(d) == 9
        loaded, meta = load_checkpoint(d, step=7)
        assert meta["step"] == 7 and meta["rng_seed"] == 42
        np.testing.assert_allclose(np.asarray(loaded["params"]["w"]),
                                   np.asarray(state["params"]["w"]))
        assert isinstance(loaded["params"]["blocks"], list)
        np.testing.assert_allclose(
            np.asarray(loaded["params"]["blocks"][0]["a"]), 1.0)

    def test_no_tmp_left_behind(self, tmp_path):
        import os
        save_checkpoint(str(tmp_path), 1, {"x": jnp.ones((2,))})
        assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]

    def test_restart_exactness(self, tmp_path, key):
        """Training N steps == training k, checkpoint, restore, N-k steps."""
        cfg = get_smoke("codeqwen1.5-7b")
        model = get_model(cfg)
        tcfg = TrainConfig()
        step = jax.jit(make_train_step(model, cfg, tcfg))
        batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}

        params, opt = init_train_state(model, cfg, tcfg, key)
        for _ in range(4):
            params, opt, _ = step(params, opt, batch)
        ref = params

        params, opt = init_train_state(model, cfg, tcfg, key)
        for _ in range(2):
            params, opt, _ = step(params, opt, batch)
        save_checkpoint(str(tmp_path), 2, {"params": params, "opt": opt})
        loaded, _ = load_checkpoint(str(tmp_path))
        params, opt = loaded["params"], loaded["opt"]
        # restore the int step counter dtype
        opt["step"] = opt["step"].astype(jnp.int32)
        for _ in range(2):
            params, opt, _ = step(params, opt, batch)
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


class TestCompression:
    def test_int8_roundtrip_error_bounded(self, key):
        x = jax.random.normal(key, (64, 64)) * 3.0
        q, s = compress_int8(x)
        err = jnp.abs(decompress_int8(q, s) - x)
        assert float(err.max()) <= float(s) * 0.51 + 1e-6

    def test_error_feedback_contracts(self, key):
        """Sum of (compressed + carried error) over steps converges to the
        true sum — the contraction property of error feedback."""
        g = jax.random.normal(key, (128,))
        e = jnp.zeros((128,))
        acc = jnp.zeros((128,))
        for _ in range(50):
            q, s = compress_int8(g + e)
            approx = decompress_int8(q, s)
            e = (g + e) - approx
            acc = acc + approx
        np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g),
                                   atol=0.02)

    def test_compressed_psum_single_device(self, key):
        """Under pmap over 1 device the compressed psum must equal the mean."""
        grads = {"w": jax.random.normal(key, (1, 32))}
        ebuf = {"w": jnp.zeros((1, 32))}
        cpsum = make_compressed_psum("dp")

        def f(g, e):
            return cpsum(g, e)

        mean, new_e = jax.pmap(f, axis_name="dp")(grads, ebuf)
        np.testing.assert_allclose(np.asarray(mean["w"][0]),
                                   np.asarray(grads["w"][0]), atol=0.05)


class TestDataPipeline:
    def test_double_buffer_deterministic_and_resumable(self):
        from repro.data.pipeline import DoubleBufferedLoader
        import numpy as np

        def make(step):
            rng = np.random.default_rng(step)
            return {"x": rng.normal(size=(4,)).astype(np.float32)}

        a = DoubleBufferedLoader(make)
        got = [np.asarray(next(a)["x"]) for _ in range(5)]
        # resume from step 3: identical stream
        b = DoubleBufferedLoader(make, start_step=3)
        np.testing.assert_allclose(np.asarray(next(b)["x"]), got[3])
        np.testing.assert_allclose(np.asarray(next(b)["x"]), got[4])
        assert a.state == 5
