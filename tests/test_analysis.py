"""herculint rules + runtime sanitizers (repro.analysis).

Each lint rule gets at least one true-positive and one clean fixture;
the seeded-bug checks re-introduce the PR 5 (device_put aliases a reader
slot) and PR 4 (manifest committed before data) patterns in scratch
sources and assert the lint catches them. The sanitizer tests alias a
slot for real and assert the REPRO_SANITIZE=1 canary trips at runtime.
"""
import json
import textwrap
import threading

import jax
import numpy as np
import pytest

from repro.analysis import callgraph, deadcode, herculint, sanitize
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.herculint import lint_source


def findings_for(src, rule=None, path="scratch.py"):
    got, problems = lint_source(textwrap.dedent(src), path)
    got = got + problems
    if rule is not None:
        got = [f for f in got if f.rule == rule]
    return got


@pytest.fixture(scope="module")
def repo_root():
    import pathlib
    return pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# alias-transfer
# ---------------------------------------------------------------------------

class TestAliasTransfer:
    def test_flags_device_put_on_mmap(self):
        src = """
            import jax, numpy as np
            def load(path):
                arr = np.load(path, mmap_mode="r")
                return jax.device_put(arr)
        """
        assert findings_for(src, "alias-transfer")

    def test_flags_jnp_asarray_on_slot_view(self):
        # the PR 5 seeded-bug pattern: a reader slot view sent to device
        # without an owning copy
        src = """
            import jax.numpy as jnp
            def consume(reader):
                view = reader.get()
                return jnp.asarray(view)
        """
        assert findings_for(src, "alias-transfer")

    def test_flags_copyless_jnp_array(self):
        src = """
            import jax.numpy as jnp
            def promote(saved):
                return jnp.array(saved.lrd)
        """
        assert findings_for(src, "alias-transfer")

    def test_clean_explicit_copy(self):
        src = """
            import jax.numpy as jnp
            import numpy as np
            def consume(reader):
                view = reader.get()
                a = jnp.array(view, copy=True)
                b = jnp.asarray(np.array(view))
                return a, b
        """
        assert not findings_for(src, "alias-transfer")

    def test_clean_fancy_indexing(self):
        # fancy indexing copies: original_data()-style access is fine
        src = """
            import jax.numpy as jnp
            import numpy as np
            def data(self):
                return jnp.asarray(np.asarray(self._mapped("lrd"))[self.perm])
        """
        assert not findings_for(src, "alias-transfer")

    def test_slice_of_mmap_stays_tainted(self):
        src = """
            import jax.numpy as jnp
            def blocks(self):
                rows = self._journal_rows()[0]
                return jnp.asarray(rows[0:4096])
        """
        assert findings_for(src, "alias-transfer")

    def test_suppression_with_reason_is_honoured(self):
        src = """
            import jax
            def stage(view):
                # herculint: ok[alias-transfer] -- fresh buffer, test fixture
                return jax.device_put(view)
        """
        assert not findings_for(src, "alias-transfer")
        assert not findings_for(src, "bare-suppression")

    def test_bare_suppression_is_flagged(self):
        src = """
            import jax
            def stage(view):
                return jax.device_put(view)  # herculint: ok[alias-transfer]
        """
        assert not findings_for(src, "alias-transfer")
        assert findings_for(src, "bare-suppression")

    def test_flags_enc_sidecar_like_lrd(self):
        # the format-v3 encoded sidecar is a mapped segment too: _enc()
        # results, .enc attributes, and enc-named values are all taint
        # sources for the device-transfer sinks
        src = """
            import jax.numpy as jnp
            def a(self):
                return jnp.asarray(self._enc()[0:64])
            def b(saved):
                return jnp.asarray(saved.enc)
            def c(enc_block):
                return jnp.asarray(enc_block)
        """
        assert len(findings_for(src, "alias-transfer")) == 3

    def test_decode_cleanses_encoded_views(self):
        # the codec hot path: decode()/encode() reconstruct fresh buffers,
        # so their results are safe to transfer even when fed mapped bytes
        src = """
            import jax.numpy as jnp
            def stream(self, codec, n):
                enc = self._enc()[0:4096]
                rows, err = codec.decode(enc, n)
                return jnp.asarray(rows), jnp.asarray(err)
            def build(codec, chunk):
                import numpy as np
                return jnp.asarray(codec.encode(np.asarray(chunk)))
        """
        assert not findings_for(src, "alias-transfer")

    def test_flags_shard_view_slice_transfer(self):
        # dist-ooc per-shard row-range views: slicing a shard view hands
        # out mmap-backed memory exactly like slicing the base file, so a
        # copyless device transfer inside the shard_map fan-out is the
        # same aliasing bug — shard-named values and _mapped() results of
        # a view object are both taint sources
        src = """
            import jax.numpy as jnp
            def refine(self, lo, hi):
                shard_rows = self._view._mapped("lrd")
                return jnp.asarray(shard_rows[lo:hi])
            def gather(shard_view, lo, hi):
                return jnp.asarray(shard_view[lo:hi])
        """
        assert len(findings_for(src, "alias-transfer")) == 2

    def test_shard_take_is_cleansing(self):
        # _ShardRows.take (like np.take) is the copy-guaranteed gather the
        # codec re-check path uses — its result owns its bytes
        src = """
            import jax.numpy as jnp
            import numpy as np
            def recheck(self, idx):
                shard_rows = self._view._mapped("lrd")
                return jnp.asarray(shard_rows.take(idx, axis=0))
        """
        assert not findings_for(src, "alias-transfer")

    def test_np_take_is_a_copy_gather(self):
        # the codec finalize pattern: np.take gathers candidate rows into
        # a fresh array (unlike x[idx], whose copy-vs-view outcome the
        # model guesses from the index expression)
        src = """
            import jax.numpy as jnp
            import numpy as np
            def finalize(self, safe):
                return jnp.asarray(np.take(self._lrd(), safe, axis=0))
        """
        assert not findings_for(src, "alias-transfer")


# ---------------------------------------------------------------------------
# mmap-lifetime
# ---------------------------------------------------------------------------

class TestMmapLifetime:
    def test_flags_use_after_close(self):
        src = """
            import numpy as np
            def peek(path):
                saved = open_index(path)
                view = saved._mapped("lrd")
                saved.close()
                return np.sum(view)
        """
        assert findings_for(src, "mmap-lifetime")

    def test_flags_view_escaping_with_block(self):
        src = """
            def peek(path):
                with open_index(path) as saved:
                    return saved.lrd
        """
        assert findings_for(src, "mmap-lifetime")

    def test_clean_copy_before_close(self):
        src = """
            import numpy as np
            def peek(path):
                saved = open_index(path)
                data = np.array(saved._mapped("lrd"))
                saved.close()
                return np.sum(data)
        """
        assert not findings_for(src, "mmap-lifetime")

    def test_clean_use_inside_with(self):
        src = """
            import numpy as np
            def peek(path):
                with open_index(path) as saved:
                    return float(np.sum(saved._mapped("lrd")))
        """
        assert not findings_for(src, "mmap-lifetime")

    def test_reopen_clears_closed_state(self):
        src = """
            def cycle(path):
                saved = open_index(path)
                saved.close()
                saved = open_index(path)
                return saved._mapped("lrd").shape
        """
        assert not findings_for(src, "mmap-lifetime")


# ---------------------------------------------------------------------------
# atomic-commit
# ---------------------------------------------------------------------------

class TestAtomicCommit:
    def test_flags_manifest_before_data(self):
        # the PR 4 seeded-bug pattern: manifest committed, then data written
        src = """
            import json, os
            import numpy as np
            def save(path, manifest, rows):
                with open(path + "/manifest.json", "w") as f:
                    json.dump(manifest, f)
                np.save(path + "/rows.npy", rows)
        """
        got = findings_for(src, "atomic-commit")
        assert got, "manifest-before-data must be flagged"

    def test_flags_non_atomic_manifest_write(self):
        src = """
            import json
            def save(path, manifest):
                with open(path + "/manifest.json", "w") as f:
                    json.dump(manifest, f)
        """
        assert any("os.replace" in f.message
                   for f in findings_for(src, "atomic-commit"))

    def test_clean_data_then_replace(self):
        src = """
            import json, os
            import numpy as np
            def save(path, manifest, rows):
                np.save(path + "/rows.npy", rows)
                tmp = path + "/manifest.json.tmp"
                with open(tmp, "w") as f:
                    json.dump(manifest, f)
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(tmp, path + "/manifest.json")
        """
        # the temp-file open/flush *are* the commit sequence, not data
        # writes after a commit; write_manifest in the real tree is the
        # canonical instance and must stay clean
        got = [f for f in findings_for(src, "atomic-commit")
               if "os.replace" in f.message]
        assert not got

    def test_real_write_manifest_is_clean(self, repo_root):
        got, _ = herculint.lint_file(
            repo_root / "src/repro/storage/format.py", repo_root)
        assert not [f for f in got if f.rule == "atomic-commit"]


# ---------------------------------------------------------------------------
# lock-discipline
# ---------------------------------------------------------------------------

class TestLockDiscipline:
    def test_flags_cross_thread_attr_store(self):
        src = """
            import threading
            class Reader:
                def __init__(self):
                    self.stats = {}
                    self._t = threading.Thread(target=self._run)
                def _run(self):
                    self.stats["read_seconds"] = 1.0
                def get(self):
                    self.stats["blocks"] = 2
        """
        assert findings_for(src, "lock-discipline")

    def test_clean_when_both_sides_hold_lock(self):
        src = """
            import threading
            class Reader:
                def __init__(self):
                    self.stats = {}
                    self._lock = threading.Lock()
                    self._t = threading.Thread(target=self._run)
                def _run(self):
                    with self._lock:
                        self.stats["read_seconds"] = 1.0
                def get(self):
                    with self._lock:
                        self.stats["blocks"] = 2
        """
        assert not findings_for(src, "lock-discipline")

    def test_clean_queue_protocol(self):
        src = """
            import queue, threading
            class Reader:
                def __init__(self):
                    self._ready = queue.SimpleQueue()
                    self.stats = {}
                    self._t = threading.Thread(target=self._run)
                def _run(self):
                    self._ready.put((0, 1.0))
                def get(self):
                    sid, dt = self._ready.get()
                    self.stats["read_seconds"] = dt
        """
        assert not findings_for(src, "lock-discipline")

    def test_threadless_class_is_ignored(self):
        src = """
            class SlotQueue:
                def push(self):
                    self.depth = 1
                def pop(self):
                    self.depth = 0
        """
        assert not findings_for(src, "lock-discipline")


# ---------------------------------------------------------------------------
# config-plumbing
# ---------------------------------------------------------------------------

class TestConfigPlumbing:
    def test_flags_unvalidated_field(self):
        src = """
            import dataclasses
            @dataclasses.dataclass(frozen=True)
            class SearchConfig:
                k: int = 1
                l_max: int = 80
                def __post_init__(self):
                    if self.k < 1:
                        raise ValueError
        """
        got = findings_for(src, "config-plumbing")
        assert any("l_max" in f.message for f in got)

    def test_flags_missing_post_init(self):
        src = """
            import dataclasses
            @dataclasses.dataclass(frozen=True)
            class SearchConfig:
                k: int = 1
        """
        assert findings_for(src, "config-plumbing")

    def test_flags_plan_key_without_cfg(self):
        src = """
            class QueryEngine:
                def knn(self, q, cfg):
                    key = (cfg.k, cfg.chunk, q.shape[1])
                    return self._plans[key]
        """
        assert findings_for(src, "config-plumbing")

    def test_clean_plan_key_with_whole_cfg(self):
        src = """
            class QueryEngine:
                def knn(self, q, cfg):
                    key = (cfg, bucket, q.shape[1])
                    return self._plans[key]
        """
        assert not findings_for(src, "config-plumbing")

    def test_real_search_config_is_clean(self, repo_root):
        got, _ = herculint.lint_file(
            repo_root / "src/repro/core/search.py", repo_root)
        assert not [f for f in got if f.rule == "config-plumbing"]

    def test_search_config_rejects_bad_values(self):
        from repro.core.search import SearchConfig
        for bad in (dict(k=0), dict(l_max=0), dict(chunk=0),
                    dict(scan_block=-1), dict(topk_budget_chunks=0),
                    dict(eapca_th=-0.1), dict(sax_th=float("nan")),
                    dict(lb_slack=1.0), dict(use_sax="yes"),
                    dict(refine_select="bogus"),
                    dict(kernel_mode="bogus"), dict(prefetch="bogus")):
            with pytest.raises(ValueError):
                SearchConfig(**bad)
        SearchConfig()  # defaults stay valid


# ---------------------------------------------------------------------------
# engine plumbing: ratchet, fingerprints, repo cleanliness
# ---------------------------------------------------------------------------

class TestEngine:
    def test_repo_is_lint_clean(self, repo_root):
        findings = herculint.run_lint(
            [repo_root / "src", repo_root / "benchmarks",
             repo_root / "examples"], repo_root)
        baseline = herculint.load_baseline()
        result = herculint.ratchet(findings, baseline)
        assert result.ok, "\n".join(f.format() for f in result.new)

    def test_cli_exits_zero_on_repo(self, capsys):
        assert analysis_main([]) == 0
        assert "clean" in capsys.readouterr().out

    def test_cli_exits_nonzero_on_seeded_bug(self, tmp_path, capsys):
        bad = tmp_path / "bad.py"
        bad.write_text(textwrap.dedent("""
            import jax
            def pump(reader):
                return jax.device_put(reader.get())
        """))
        assert analysis_main([str(bad), "--repo-root", str(tmp_path)]) == 1
        assert "alias-transfer" in capsys.readouterr().out

    def test_cli_graph_emits_project_json(self, tmp_path, capsys):
        out = tmp_path / "graph.json"
        assert analysis_main(["--graph", str(out)]) == 0
        assert "call graph written" in capsys.readouterr().out
        blob = json.loads(out.read_text())
        assert set(blob) >= {"modules", "imports", "functions", "calls",
                             "telemetry"}
        assert any(f["returns_tainted"] for f in blob["functions"].values())

    def test_fingerprint_stable_across_line_drift(self):
        src_a = """
            import jax
            def pump(reader):
                return jax.device_put(reader.get())
        """
        src_b = """
            import jax
            # a new comment shifts every line number
            # by two
            def pump(reader):
                return jax.device_put(reader.get())
        """
        fa = findings_for(src_a, "alias-transfer")[0].fingerprint
        fb = findings_for(src_b, "alias-transfer")[0].fingerprint
        assert fa == fb

    def test_ratchet_baseline_roundtrip(self, tmp_path):
        findings = findings_for("""
            import jax
            def pump(reader):
                return jax.device_put(reader.get())
        """, "alias-transfer")
        bl_path = tmp_path / "baseline.json"
        herculint.write_baseline(findings, bl_path)
        baseline = herculint.load_baseline(bl_path)
        result = herculint.ratchet(findings, baseline)
        assert result.ok and len(result.grandfathered) == 1
        # fixing the finding leaves a stale entry to shrink
        result = herculint.ratchet([], baseline)
        assert result.ok and result.stale

    def test_baseline_file_is_empty_or_justified(self, repo_root):
        data = json.loads(
            (repo_root / "src/repro/analysis/baseline.json").read_text())
        for entry in data["findings"]:
            just = entry.get("justification", "")
            assert just and not just.startswith("TODO"), entry


class TestDeadCode:
    def test_no_unexplained_dead_modules(self, repo_root):
        report = deadcode.build_report(repo_root)
        assert report["dead"] == [], report["dead"]

    def test_configs_and_models_marked_intentional(self, repo_root):
        report = deadcode.build_report(repo_root)
        mods = report["modules"]
        for name in ("repro.configs", "repro.models.transformer"):
            assert mods[name]["status"] in ("intentional", "reachable"), \
                mods[name]
        # the report never leaves them ambiguous: every intentional entry
        # carries a justification note
        for name, entry in mods.items():
            if entry["status"] == "intentional":
                assert entry.get("note"), name

    def test_core_modules_reachable_from_api(self, repo_root):
        report = deadcode.build_report(repo_root)
        mods = report["modules"]
        for name in ("repro.core.engine", "repro.storage.store",
                     "repro.data.pipeline", "repro.analysis.sanitize"):
            assert "api" in mods[name]["reached_by"] or \
                   "cli" in mods[name]["reached_by"], mods[name]


# ---------------------------------------------------------------------------
# runtime sanitizers
# ---------------------------------------------------------------------------

@pytest.fixture
def sanitized(monkeypatch):
    monkeypatch.setenv(sanitize.ENV_VAR, "1")
    assert sanitize.sanitize_enabled()


def _drain(reader, n_chunks, chunk):
    for i in range(n_chunks):
        reader.submit(i * chunk, chunk)


class TestSlotCanary:
    def test_aliased_stage_trips_canary(self, sanitized, monkeypatch):
        """Deliberately alias a slot (the PR 5 bug) and assert the canary
        trips at the recycle point."""
        from repro.data import pipeline

        # an 'aliasing device_put': returns the slot view itself, the
        # worst possible zero-copy outcome
        monkeypatch.setattr(pipeline, "_staged_copy",
                            lambda view, device=None: view)
        rows = np.arange(64, dtype=np.float32).reshape(8, 8)
        reader = pipeline.AsyncChunkReader(rows, 4, 8)
        try:
            _drain(reader, 2, 4)
            dev = reader.stage(reader.get())
            with pytest.raises(sanitize.SanitizerError,
                               match="aliases reader slot"):
                reader.get()            # recycles the aliased slot
        finally:
            reader.close()

    def test_real_device_put_alias_trips_canary(self, sanitized,
                                                monkeypatch):
        """Same, but through an actual jax.device_put: only meaningful on
        builds where device_put zero-copy aliases aligned host buffers."""
        probe = np.zeros((64, 8), np.float32)
        if not np.shares_memory(np.asarray(jax.device_put(probe)), probe):
            pytest.skip("this jax build copies on device_put; the "
                        "monkeypatched variant covers the alias path")
        from repro.data import pipeline
        monkeypatch.setattr(
            pipeline, "_staged_copy",
            lambda view, device=None: jax.device_put(view))
        rows = np.arange(64, dtype=np.float32).reshape(8, 8)
        reader = pipeline.AsyncChunkReader(rows, 4, 8)
        try:
            _drain(reader, 2, 4)
            reader.stage(reader.get())
            with pytest.raises(sanitize.SanitizerError):
                reader.get()
        finally:
            reader.close()

    def test_clean_stage_does_not_trip(self, sanitized):
        from repro.data import pipeline

        rows = np.arange(256, dtype=np.float32).reshape(32, 8)
        reader = pipeline.AsyncChunkReader(rows, 8, 8)
        try:
            _drain(reader, 4, 8)
            outs = []
            for _ in range(4):
                outs.append(np.asarray(reader.stage(reader.get())))
        finally:
            reader.close()
        np.testing.assert_array_equal(np.concatenate(outs), rows)

    def test_streams_bitwise_identical_under_sanitizer(self, sanitized):
        from repro.data.pipeline import ArrayChunkSource, iter_device_chunks

        rows = np.random.default_rng(7).normal(
            size=(64, 16)).astype(np.float32)
        src = ArrayChunkSource(rows, 16)
        sync = [np.asarray(c) for _, c in iter_device_chunks(src)]
        thread = [np.asarray(c)
                  for _, c in iter_device_chunks(src, prefetch="thread")]
        for a, b in zip(sync, thread):
            np.testing.assert_array_equal(a, b)

    def test_sanitizer_off_by_default(self, monkeypatch):
        monkeypatch.delenv(sanitize.ENV_VAR, raising=False)
        assert not sanitize.sanitize_enabled()
        from repro.data import pipeline
        rows = np.zeros((8, 4), np.float32)
        reader = pipeline.AsyncChunkReader(rows, 4, 4)
        try:
            assert reader._sanitize is False
        finally:
            reader.close()


class TestUseAfterCloseGuard:
    def test_guard_trips_after_close(self, sanitized, tmp_path):
        from repro.api import Hercules

        rows = np.random.default_rng(3).normal(
            size=(64, 16)).astype(np.float32)
        path = str(tmp_path / "idx")
        store = Hercules.create(path, data=rows, chunk_size=16)
        store.close()
        from repro.storage.format import open_index
        saved = open_index(path)
        assert isinstance(saved.lrd, sanitize.MmapGuard)
        escaped = saved.lrd
        assert escaped.shape[0] >= 64          # live reads delegate
        np.testing.assert_array_equal(
            np.asarray(escaped)[:2], np.asarray(saved._mapped("lrd"))[:2])
        saved.close()
        with pytest.raises(sanitize.UseAfterCloseError):
            escaped[0]
        with pytest.raises(sanitize.UseAfterCloseError):
            _ = escaped.shape

    def test_queries_work_through_guard(self, sanitized, tmp_path):
        """The whole read path must behave identically under the guard."""
        from repro.api import Hercules, SearchConfig

        rows = np.random.default_rng(5).normal(
            size=(128, 16)).astype(np.float32)
        path = str(tmp_path / "idx")
        with Hercules.create(path, data=rows, chunk_size=32) as store:
            q = rows[:3] + 1e-3
            res = store.query(q, search=SearchConfig(k=3, chunk=32,
                                                     scan_block=32))
            brute = np.argsort(((rows[None] - q[:, None]) ** 2).sum(-1),
                               axis=1)[:, :3]
            np.testing.assert_array_equal(np.asarray(res.ids), brute)

    def test_no_guard_when_disabled(self, monkeypatch, tmp_path):
        monkeypatch.delenv(sanitize.ENV_VAR, raising=False)
        from repro.api import Hercules
        from repro.storage.format import open_index

        rows = np.random.default_rng(9).normal(
            size=(64, 16)).astype(np.float32)
        path = str(tmp_path / "idx")
        Hercules.create(path, data=rows, chunk_size=16).close()
        saved = open_index(path)
        try:
            assert not isinstance(saved.lrd, sanitize.MmapGuard)
        finally:
            saved.close()


# ---------------------------------------------------------------------------
# pinning regressions for the fixes this pass forced
# ---------------------------------------------------------------------------

class TestPinnedFixes:
    def test_assemble_layout_copies_memmaps(self, tmp_path):
        """assemble_layout promoted memmaps with jnp.asarray (latent PR 4):
        the layout must own its bytes once the mmap is gone."""
        from repro.core.layout import _owned

        p = tmp_path / "a.npy"
        np.save(p, np.arange(32, dtype=np.float32).reshape(4, 8))
        mm = np.load(p, mmap_mode="r")
        owned = _owned(mm)
        assert not np.shares_memory(owned, mm)
        plain = np.arange(8, dtype=np.float32)
        assert _owned(plain) is plain          # in-memory stays zero-copy

    def test_staged_chunks_never_share_slot_memory(self):
        """Every device chunk yielded by the threaded stream must own its
        memory — np.shares_memory against all reader slots."""
        from repro.data import pipeline

        rows = np.random.default_rng(11).normal(
            size=(64, 8)).astype(np.float32)
        reader = pipeline.AsyncChunkReader(rows, 16, 8)
        try:
            _drain(reader, 4, 16)
            for _ in range(4):
                dev = reader.stage(reader.get())
                host = np.asarray(dev)
                for slot in reader._slots:
                    assert not np.shares_memory(host, slot)
        finally:
            reader.close()

    def test_sharded_plan_cache_keys_on_signature(self):
        """ShardedBackend._run_for keyed compiled programs by cfg alone
        while the producer baked in the mesh + stacked layout (the
        plan-key-completeness catch): the cache key must carry the
        backend's plan_signature."""
        from repro.core import (BuildConfig, IndexConfig, SearchConfig,
                                make_backend)
        from repro.data import random_walks

        data = random_walks(jax.random.PRNGKey(3), 256, 32)
        cfg = IndexConfig(build=BuildConfig(leaf_capacity=32),
                          search=SearchConfig(k=2, l_max=4, chunk=64,
                                              scan_block=64))
        backend = make_backend("sharded", data, index_config=cfg,
                               num_shards=1)
        sig = backend.plan_signature
        assert sig[0] == backend.name
        assert backend.stacked.num_shards in sig
        program = backend._run_for(cfg.search)
        assert (cfg.search, sig) in backend._programs
        assert backend._run_for(cfg.search) is program   # same key → hit

    def test_journal_query_survives_reopen(self, tmp_path):
        """_merge_journal blocks now own their bytes: answers must remain
        exact after the segment mmaps are released."""
        from repro.api import Hercules, SearchConfig

        rng = np.random.default_rng(13)
        base = rng.normal(size=(64, 16)).astype(np.float32)
        extra = rng.normal(size=(16, 16)).astype(np.float32)
        path = str(tmp_path / "idx")
        with Hercules.create(path, data=base, chunk_size=16) as store:
            store.append(extra)
            q = extra[:2] + 1e-3
            res = store.query(q, search=SearchConfig(k=1, chunk=16,
                                                     scan_block=16))
            all_rows = np.concatenate([base, extra])
            brute = np.argsort(((all_rows[None] - q[:, None]) ** 2
                                ).sum(-1), axis=1)[:, :1]
            np.testing.assert_array_equal(np.asarray(res.ids), brute)


# ---------------------------------------------------------------------------
# v2: call-graph summaries (repro.analysis.callgraph)
# ---------------------------------------------------------------------------

class TestCallGraph:
    SRC = """
        import numpy as np

        def fetch_rows(reader):
            chunk = reader.get()
            return chunk[:16]

        def snapshot_rows(reader):
            view = reader.get()
            return np.array(view[:16])

        class Saved:
            def window(self):
                return self.lrd[0:10]

            def stats(self):
                return {"n": 1}

        def guarded(state):
            with state.lock:
                state.n += 1
    """

    def index(self):
        return callgraph.index_for_source(
            textwrap.dedent(self.SRC), "scratch.py")

    def test_taint_and_cleanse_summaries(self):
        fns = self.index().functions
        assert fns["scratch.py::fetch_rows"].returns_tainted
        assert not fns["scratch.py::fetch_rows"].cleanses_return
        assert fns["scratch.py::snapshot_rows"].cleanses_return
        assert not fns["scratch.py::snapshot_rows"].returns_tainted

    def test_self_view_and_lock_summaries(self):
        fns = self.index().functions
        assert fns["scratch.py::Saved.window"].returns_self_view
        assert not fns["scratch.py::Saved.stats"].returns_self_view
        assert "state.lock" in fns["scratch.py::guarded"].acquires_locks
        assert "state.lock" in fns["scratch.py::guarded"].releases_locks

    def test_call_verdict_votes_same_file_candidates(self):
        import ast
        index = self.index()
        call = ast.parse("fetch_rows(r)", mode="eval").body
        assert index.call_verdict(call, "scratch.py") == "tainted"
        call = ast.parse("snapshot_rows(r)", mode="eval").body
        assert index.call_verdict(call, "scratch.py") == "cleanses"

    def test_unresolvable_bare_names_never_cross_files(self):
        # `get` is in the unresolvable set: a project-wide match on such
        # a generic name would poison every caller in the repo
        index = callgraph.build_index({
            "a.py": "def get():\n    return reader.get()\n",
            "b.py": "def use(r):\n    return get()\n",
        })
        import ast
        call = ast.parse("get()", mode="eval").body
        # same-file resolution still works in a.py ...
        assert index.candidates("get", "a.py")
        # ... but b.py (no local def) must not reach a.py's `get`
        assert not index.candidates("get", "b.py")

    def test_project_graph_covers_repo(self, repo_root):
        project = callgraph.build_project_graph(repo_root)
        assert "repro.api" in project.modules
        fns = project.index.functions
        key = "src/repro/data/pipeline.py::AsyncChunkReader.get"
        assert key in fns and fns[key].returns_tainted
        assert project.index.telemetry.declared   # Telemetry fields seen
        blob = project.to_json()
        assert set(blob) >= {"modules", "imports", "functions", "calls",
                             "telemetry"}


# ---------------------------------------------------------------------------
# v2: interprocedural meta-tests — v1 (empty index) provably misses what
# the call-graph-aware engine flags
# ---------------------------------------------------------------------------

def v1_findings(src, rule, path="scratch.py"):
    """Lint with summaries disabled — byte-for-byte the v1 engine."""
    got, problems = lint_source(textwrap.dedent(src), path,
                                summaries=callgraph.SummaryIndex.empty())
    return [f for f in got + problems if f.rule == rule]


class TestInterprocedural:
    ALIAS_SRC = """
        import jax

        class Runner:
            def _fetch(self):
                chunk = self.reader.get()
                return chunk[:16]

            def _grab(self):
                return self._fetch()

            def run(self):
                rows = self._grab()
                return jax.device_put(rows)
    """

    def test_v2_flags_view_escaping_through_helpers(self):
        got = findings_for(self.ALIAS_SRC, rule="alias-transfer")
        assert got and any("device_put" in f.message for f in got)

    def test_v1_misses_the_same_fixture(self):
        assert v1_findings(self.ALIAS_SRC, "alias-transfer") == []

    MMAP_SRC = """
        class Saved:
            def window(self):
                return self.lrd[0:10]

        def use(path):
            with open_saved(path) as idx:
                return idx.window()
    """

    def test_v2_flags_self_view_escaping_with_block(self):
        got = findings_for(self.MMAP_SRC, rule="mmap-lifetime")
        assert got and any("idx" in f.message for f in got)

    def test_v1_misses_the_self_view_helper(self):
        assert v1_findings(self.MMAP_SRC, "mmap-lifetime") == []

    def test_cleansing_helper_overrides_view_name(self):
        # helper is *named* like a view producer but provably copies:
        # summaries must silence the name heuristic, not add to it
        src = """
            import jax
            import numpy as np

            def view_of(reader):
                return np.array(reader.get())

            def run(reader):
                rows = view_of(reader)
                return jax.device_put(rows)
        """
        assert findings_for(src, rule="alias-transfer") == []


# ---------------------------------------------------------------------------
# plan-key-completeness
# ---------------------------------------------------------------------------

class TestPlanKeyCompleteness:
    def test_flags_cfg_field_outside_key(self):
        src = """
            class Engine:
                def knn(self, q, cfg):
                    key = (cfg.k, q.shape[1])
                    if key not in self._plans:
                        self._plans[key] = make_plan(cfg.k)
                    block = cfg.scan_block
                    return self._plans[key](q, block)
        """
        got = findings_for(src, rule="plan-key-completeness")
        assert any("cfg.scan_block" in f.message for f in got)
        assert not any("'cfg.k'" in f.message for f in got)

    def test_flags_backend_state_without_signature(self):
        src = """
            class Backend:
                def _run_for(self, cfg):
                    if cfg not in self._programs:
                        self._programs[cfg] = make_search(
                            self.mesh, self.stacked, cfg)
                    return self._programs[cfg]
        """
        got = findings_for(src, rule="plan-key-completeness")
        assert any("self.mesh" in f.message for f in got)
        assert any("plan_signature" in f.message for f in got)

    def test_clean_with_whole_cfg_and_signature(self):
        src = """
            class Engine:
                def knn(self, q, cfg):
                    key = (cfg, q.shape[1], self.backend.plan_signature)
                    if key not in self._plans:
                        self._plans[key] = self.backend.make_plan(cfg)
                    return self._plans[key](q)
        """
        assert findings_for(src, rule="plan-key-completeness") == []

    def test_producer_callee_method_is_not_state(self):
        # `self._build` is the factory being *called*, not state baked
        # into the plan — flagging it would make every engine noisy
        src = """
            class Engine:
                def knn(self, q, cfg):
                    self._plans[(cfg,)] = self._build(cfg)
                    return self._plans[(cfg,)](q)
        """
        assert findings_for(src, rule="plan-key-completeness") == []


# ---------------------------------------------------------------------------
# exactness-invariant
# ---------------------------------------------------------------------------

class TestExactnessInvariant:
    def test_flags_decoded_value_against_bsf(self):
        src = """
            def refine(enc, q, codec, bsf):
                dec = codec.decode(enc)
                d = ((dec - q) ** 2).sum(-1)
                if d[0] <= bsf:
                    return True
                return False
        """
        got = findings_for(src, rule="exactness-invariant")
        assert got and any("float32" in f.message for f in got)

    def test_certified_bound_comparison_is_clean(self):
        src = """
            def refine(enc, codec, theta):
                lb_dec = codec.decode(enc)
                ok = lb_dec[:, -1] >= theta
                return ok
        """
        assert findings_for(src, rule="exactness-invariant") == []

    def test_float32_recompute_is_clean(self):
        src = """
            import numpy as np

            def refine(enc, q, codec, bsf, cand):
                dec = codec.decode(enc)
                pool = np.take(dec, cand, axis=0).astype(np.float32)
                d = ((pool - q) ** 2).sum(-1)
                if d[0] <= bsf:
                    return True
                return False
        """
        assert findings_for(src, rule="exactness-invariant") == []


# ---------------------------------------------------------------------------
# telemetry-contract
# ---------------------------------------------------------------------------

class TestTelemetryContract:
    def test_flags_bump_of_undeclared_key(self):
        src = """
            import dataclasses

            @dataclasses.dataclass
            class ScanTelemetry:
                calls: int = 0

            class Backend:
                def __init__(self):
                    self._t = {"calls": 0}

                def run(self):
                    self._t["callz"] += 1

                def telemetry(self):
                    return ScanTelemetry(calls=self._t["calls"])
        """
        got = findings_for(src, rule="telemetry-contract")
        assert any("callz" in f.message for f in got)

    def test_flags_declared_field_never_fed(self):
        src = """
            import dataclasses

            @dataclasses.dataclass
            class ScanTelemetry:
                pruned: int = 0
                ghost: int = 0

            class Backend:
                def __init__(self):
                    self._t = {"pruned": 0}

                def run(self):
                    self._t["pruned"] += 1

                def telemetry(self):
                    return ScanTelemetry(pruned=self._t["pruned"])
        """
        got = findings_for(src, rule="telemetry-contract")
        assert any("ghost" in f.message for f in got)
        assert not any("'pruned'" in f.message for f in got)

    def test_matched_counters_are_clean(self):
        src = """
            import dataclasses

            @dataclasses.dataclass
            class ScanTelemetry:
                calls: int = 0

            class Backend:
                def __init__(self):
                    self._t = {"calls": 0}

                def run(self):
                    self._t["calls"] += 1

                def telemetry(self):
                    return ScanTelemetry(calls=self._t["calls"])
        """
        assert findings_for(src, rule="telemetry-contract") == []

    def test_inert_without_declared_fields(self):
        assert findings_for(
            "x = {'anything': 1}\nx['other'] = 2\n",
            rule="telemetry-contract") == []


# ---------------------------------------------------------------------------
# lockdep runtime sanitizer
# ---------------------------------------------------------------------------

@pytest.fixture
def lockdep(sanitized):
    sanitize.LOCKDEP.reset()
    yield sanitize.LOCKDEP
    sanitize.LOCKDEP.reset()


class TestLockdep:
    def test_abba_cycle_raises_with_both_stacks(self, lockdep):
        a = sanitize.wrap_lock(threading.Lock(), "A")
        b = sanitize.wrap_lock(threading.Lock(), "B")
        with a:
            with b:
                pass
        with b:
            with pytest.raises(sanitize.LockOrderError) as exc:
                with a:
                    pass
        msg = str(exc.value)
        assert "lock-order cycle" in msg
        assert "Acquisition stack establishing the opposite order" in msg
        assert "Current acquisition stack" in msg
        assert isinstance(exc.value, sanitize.SanitizerError)

    def test_transitive_cycle_is_caught(self, lockdep):
        a = sanitize.wrap_lock(threading.Lock(), "A")
        b = sanitize.wrap_lock(threading.Lock(), "B")
        c = sanitize.wrap_lock(threading.Lock(), "C")
        with a:
            with b:
                pass
        with b:
            with c:
                pass
        with c:
            with pytest.raises(sanitize.LockOrderError):
                with a:
                    pass

    def test_consistent_order_is_clean(self, lockdep):
        a = sanitize.wrap_lock(threading.Lock(), "A")
        b = sanitize.wrap_lock(threading.Lock(), "B")
        for _ in range(3):
            with a:
                with b:
                    pass

    def test_wrap_lock_is_passthrough_when_disabled(self, monkeypatch):
        monkeypatch.delenv(sanitize.ENV_VAR, raising=False)
        raw = threading.Lock()
        assert sanitize.wrap_lock(raw, "A") is raw

    def test_task_wrapper_rejects_held_lock_on_entry(self, lockdep):
        a = sanitize.wrap_lock(threading.Lock(), "A")
        task = sanitize.lockdep_task(lambda: None, name="t")
        with a:
            with pytest.raises(sanitize.HeldLockError,
                               match="entered while holding"):
                task()
        task()      # clean outside the critical section

    def test_task_wrapper_rejects_leaked_lock_on_exit(self, lockdep):
        a = sanitize.wrap_lock(threading.Lock(), "A")
        task = sanitize.lockdep_task(a.acquire, name="t")
        with pytest.raises(sanitize.HeldLockError,
                           match="still holding"):
            task()

    def test_thread_affinity_flags_foreign_touch(self, lockdep):
        aff = sanitize.ThreadAffinity("SlotQueue")
        aff.check("poll")           # binds the current thread
        caught = []

        def foreign():
            try:
                aff.check("submit")
            except sanitize.ThreadOwnershipError as e:
                caught.append(e)

        t = threading.Thread(target=foreign)
        t.start()
        t.join()
        assert caught, "foreign touch must raise ThreadOwnershipError"
        msg = str(caught[0])
        assert "Binding stack" in msg and "Foreign touch stack" in msg
        assert "lock-free by contract" in msg

    def test_thread_affinity_rebind_allows_handoff(self, lockdep):
        aff = sanitize.ThreadAffinity("SlotQueue")
        aff.check("poll")
        aff.rebind()
        out = []

        def new_owner():
            aff.check("poll")
            out.append("ok")

        t = threading.Thread(target=new_owner)
        t.start()
        t.join()
        assert out == ["ok"]

    def test_slot_queue_enforces_single_driver(self, lockdep):
        from repro.serve.engine import SlotQueue

        q = SlotQueue()
        q._enqueue({"payload": 0})
        caught = []

        def foreign():
            try:
                q._enqueue({"payload": 1})
            except sanitize.ThreadOwnershipError as e:
                caught.append(e)

        t = threading.Thread(target=foreign)
        t.start()
        t.join()
        assert caught
        q.rebind_owner()            # explicit handoff clears the binding
        done = []
        t2 = threading.Thread(
            target=lambda: done.append(q._enqueue({"payload": 2})))
        t2.start()
        t2.join()
        assert done

    def test_async_reader_enforces_consumer_affinity(self, lockdep):
        from repro.data import pipeline

        rows = np.arange(64, dtype=np.float32).reshape(8, 8)
        reader = pipeline.AsyncChunkReader(rows, 4, 8)
        try:
            reader.submit(0, 4)
            reader.get()            # binds main as the consumer
            caught = []

            def foreign():
                try:
                    reader.submit(4, 4)
                except sanitize.ThreadOwnershipError as e:
                    caught.append(e)

            t = threading.Thread(target=foreign)
            t.start()
            t.join()
            assert caught
        finally:
            reader.close()          # close is exempt: any thread may close
