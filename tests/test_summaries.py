"""Unit + property tests for PAA / iSAX / EAPCA summarizations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import summaries as S

jax.config.update("jax_platform_name", "cpu")


def _series(rng, num=8, n=64):
    return jnp.asarray(rng.normal(size=(num, n)).astype(np.float32))


class TestPAA:
    def test_matches_block_mean(self, rng):
        x = _series(rng, 4, 64)
        p = S.paa(x, 16)
        ref = np.asarray(x).reshape(4, 16, 4).mean(-1)
        np.testing.assert_allclose(np.asarray(p), ref, rtol=1e-6)

    def test_indivisible_raises(self, rng):
        with pytest.raises(ValueError):
            S.paa(_series(rng, 2, 60), 16)

    def test_mean_preserved(self, rng):
        x = _series(rng, 4, 64)
        np.testing.assert_allclose(np.asarray(S.paa(x, 16)).mean(-1),
                                   np.asarray(x).mean(-1), rtol=1e-5, atol=1e-6)


class TestISAX:
    def test_breakpoints_monotonic(self):
        bps = np.asarray(S.sax_breakpoints(256))
        assert bps.shape == (255,)
        assert (np.diff(bps) > 0).all()
        # standard normal quantiles: symmetric around 0
        np.testing.assert_allclose(bps, -bps[::-1], atol=1e-5)

    def test_codes_in_range(self, rng):
        codes = S.isax(_series(rng, 16, 64))
        c = np.asarray(codes)
        assert c.dtype == np.uint8

    def test_code_monotone_in_value(self):
        # larger PAA value => larger (or equal) symbol
        vals = jnp.linspace(-5, 5, 100)[None, :]
        codes = np.asarray(S.isax_from_paa(vals))[0]
        assert (np.diff(codes.astype(int)) >= 0).all()

    def test_cell_bounds_contain_value(self, rng):
        x = _series(rng, 8, 64)
        p = S.paa(x, 16)
        codes = S.isax_from_paa(p)
        lo, hi = S.isax_cell_bounds(codes)
        assert bool(jnp.all((lo <= p) & (p <= hi)))


class TestEAPCA:
    def test_segment_stats_match_numpy(self, rng):
        x = _series(rng, 4, 32)
        ep = jnp.asarray([[8, 16, 24, 32]] * 4, jnp.int32)
        means, stds = S.eapca(x, ep[0])
        xn = np.asarray(x).reshape(4, 4, 8)
        np.testing.assert_allclose(np.asarray(means), xn.mean(-1), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(stds), xn.std(-1), rtol=1e-4, atol=1e-5)

    def test_empty_segments_zero(self, rng):
        x = _series(rng, 2, 32)
        ep = jnp.asarray([16, 32, 32, 32], jnp.int32)  # 2 real + 2 empty
        means, stds = S.eapca(x, ep)
        np.testing.assert_array_equal(np.asarray(means)[:, 2:], 0.0)
        np.testing.assert_array_equal(np.asarray(stds)[:, 2:], 0.0)
        assert bool(jnp.all(S.segment_lengths(ep)[2:] == 0))

    @settings(max_examples=25, deadline=None, derandomize=True)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 4))
    def test_prefix_sum_stats_property(self, seed, nseg):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(3, 24)).astype(np.float32))
        # random valid segmentation with nseg segments
        cuts = np.sort(rng.choice(np.arange(1, 24), size=nseg - 1, replace=False))
        ep = np.concatenate([cuts, [24]]).astype(np.int32)
        means, stds = S.eapca(x, jnp.asarray(ep))
        prev = 0
        for i, e in enumerate(ep):
            seg = np.asarray(x)[:, prev:e]
            # fp32 prefix-sum differences cancel: abs error bound is
            # ~n*eps*max|cumsum| ~ 3e-5 for n=24 N(0,1) values; stds also
            # lose bits in E[x^2]-mean^2 (the LB slack absorbs this; see
            # SearchConfig.lb_slack)
            np.testing.assert_allclose(np.asarray(means)[:, i], seg.mean(-1),
                                       rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(np.asarray(stds)[:, i], seg.std(-1),
                                       rtol=1e-3, atol=1e-3)
            prev = e


class TestSynopsis:
    def test_synopsis_bounds_members(self, rng):
        x = _series(rng, 32, 32)
        ep = jnp.asarray([8, 16, 24, 32], jnp.int32)
        means, stds = S.eapca(x, ep)
        syn = S.synopsis_from_stats(means, stds)
        assert bool(jnp.all(syn[:, 0] <= means.min(0) + 1e-6))
        assert bool(jnp.all(syn[:, 1] >= means.max(0) - 1e-6))

    def test_merge_is_union(self, rng):
        x = _series(rng, 32, 32)
        ep = jnp.asarray([8, 16, 24, 32], jnp.int32)
        m, s = S.eapca(x, ep)
        a = S.synopsis_from_stats(m[:16], s[:16])
        b = S.synopsis_from_stats(m[16:], s[16:])
        both = S.synopsis_from_stats(m, s)
        np.testing.assert_allclose(np.asarray(S.merge_synopses(a, b)),
                                   np.asarray(both), rtol=1e-6)
