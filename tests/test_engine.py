"""Unified QueryEngine surface: backend parity, plan cache, serving.

The api_redesign acceptance criteria live here:
  * the same workload through LocalBackend / ScanBackend / (single-device
    degenerate) ShardedBackend answers with bit-identical exact top-k
    distances;
  * a repeated same-bucket knn call is a plan-cache hit with zero new
    compiles (plans are AOT executables — a hit cannot retrace);
  * per-call overrides (k, l_max, thresholds, and any chunk/scan_block
    dividing the padded layout) no longer raise pad-multiple errors.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BuildConfig, EngineConfig, HerculesIndex, IndexConfig,
                        LocalBackend, QueryEngine, ScanBackend, SearchBackend,
                        SearchConfig, ShardedBackend, brute_force_knn,
                        make_backend)
from repro.data import make_query_workload, random_walks
from repro.serve import (KnnAnswer, KnnFailure, KnnServeConfig,
                         KnnServeEngine, QueueFull)

jax.config.update("jax_platform_name", "cpu")

NUM, LEN, K = 2000, 64, 3
CFG = IndexConfig(build=BuildConfig(leaf_capacity=64),
                  search=SearchConfig(k=K, l_max=4, chunk=128, scan_block=256))


@pytest.fixture(scope="module")
def data():
    return random_walks(jax.random.PRNGKey(0), NUM, LEN)


@pytest.fixture(scope="module")
def queries(data):
    # mixed difficulty so both access paths (scan + pruned refinement) occur
    easy = make_query_workload(jax.random.PRNGKey(1), data, 4, "1%")
    hard = make_query_workload(jax.random.PRNGKey(2), data, 4, "ood")
    return jnp.concatenate([easy, hard])


@pytest.fixture(scope="module")
def local(data):
    return QueryEngine(LocalBackend(HerculesIndex.build(data, CFG)))


class TestBackendParity:
    def test_local_is_exact(self, data, queries, local):
        res = local.knn(queries)
        bf_d, _ = brute_force_knn(data, queries, K)
        np.testing.assert_allclose(np.asarray(res.dists), np.asarray(bf_d),
                                   rtol=1e-3, atol=1e-3)

    def test_scan_matches_local_bitwise(self, data, queries, local):
        scan = QueryEngine(ScanBackend(data, CFG.search))
        r_local = local.knn(queries)
        r_scan = scan.knn(queries)
        assert np.array_equal(np.asarray(r_local.dists),
                              np.asarray(r_scan.dists))
        assert np.array_equal(np.sort(np.asarray(r_local.ids), axis=1),
                              np.sort(np.asarray(r_scan.ids), axis=1))

    def test_sharded_single_device_matches_local_bitwise(
            self, data, queries, local):
        sharded = QueryEngine(
            make_backend("sharded", data, index_config=CFG, num_shards=1))
        r_local = local.knn(queries)
        r_shard = sharded.knn(queries)
        assert np.array_equal(np.asarray(r_local.dists),
                              np.asarray(r_shard.dists))
        assert np.array_equal(np.sort(np.asarray(r_local.ids), axis=1),
                              np.sort(np.asarray(r_shard.ids), axis=1))

    def test_scan_mxu_is_exact(self, data, queries):
        scan = QueryEngine(ScanBackend(data, CFG.search, mxu=True))
        bf_d, _ = brute_force_knn(data, queries, K)
        np.testing.assert_allclose(np.asarray(scan.knn(queries).dists),
                                   np.asarray(bf_d), rtol=1e-3, atol=1e-3)

    def test_backends_conform_to_protocol(self, data):
        for b in (LocalBackend(HerculesIndex.build(data, CFG)),
                  ScanBackend(data, CFG.search)):
            assert isinstance(b, SearchBackend)
            assert b.describe()["backend"] == b.name


class TestKernelModeParity:
    """Kernelization acceptance: for every ``kernel_mode``, every backend
    answers with bit-identical top-k distances and the same id sets.

    ``ref`` runs the jnp oracles; ``interpret`` routes the hot path through
    the Pallas kernel bodies (ScanBackend ED via ops.ed_matrix/ed_min,
    phase-3 LB_SAX pruning via ops.lb_sax) on the interpreter — the same
    code Mosaic compiles on TPU. ``kernel_mode`` is a per-call override, so
    these also prove a serving engine can flip modes without a rebuild.
    """

    MODES = ("ref", "interpret")

    @staticmethod
    def _assert_same(a, b):
        assert np.array_equal(np.asarray(a.dists), np.asarray(b.dists))
        assert np.array_equal(np.sort(np.asarray(a.ids), axis=1),
                              np.sort(np.asarray(b.ids), axis=1))

    def test_local_bitwise_across_modes(self, queries, local):
        base = local.knn(queries, kernel_mode="ref")
        for mode in self.MODES:
            self._assert_same(local.knn(queries, kernel_mode=mode), base)

    def test_scan_bitwise_across_modes_and_vs_local(self, data, queries,
                                                    local):
        scan = QueryEngine(ScanBackend(data, CFG.search))
        base = local.knn(queries, kernel_mode="ref")
        for mode in self.MODES:
            self._assert_same(scan.knn(queries, kernel_mode=mode), base)

    def test_scan_k1_fused_ed_min_bitwise(self, data, queries):
        # k=1 takes the fused ops.ed_min kernel path, not blocked ed_matrix
        scan = QueryEngine(ScanBackend(data, CFG.search))
        base = scan.knn(queries, k=1, kernel_mode="ref")
        got = scan.knn(queries, k=1, kernel_mode="interpret")
        assert np.array_equal(np.asarray(base.dists), np.asarray(got.dists))
        assert np.array_equal(np.asarray(base.ids), np.asarray(got.ids))

    def test_sharded_bitwise_across_modes(self, data, queries, local):
        sharded = QueryEngine(
            make_backend("sharded", data, index_config=CFG, num_shards=1))
        base = local.knn(queries, kernel_mode="ref")
        for mode in self.MODES:
            self._assert_same(sharded.knn(queries, kernel_mode=mode), base)

    def test_mode_is_a_plan_cache_key(self, data, queries):
        eng = QueryEngine(LocalBackend(HerculesIndex.build(data, CFG)))
        eng.knn(queries, kernel_mode="ref")
        eng.knn(queries, kernel_mode="interpret")
        eng.knn(queries, kernel_mode="ref")        # must hit, not recompile
        pc = eng.telemetry()["plan_cache"]
        assert (pc["misses"], pc["hits"]) == (2, 1)

    def test_invalid_mode_rejected(self, local):
        with pytest.raises(ValueError, match="kernel_mode"):
            local.knn(jnp.zeros((1, LEN)), kernel_mode="bogus")


class TestPlanCache:
    def test_repeat_call_hits_zero_compiles(self, data, queries):
        eng = QueryEngine(LocalBackend(HerculesIndex.build(data, CFG)))
        eng.knn(queries)
        t1 = eng.telemetry()["plan_cache"]
        assert (t1["misses"], t1["hits"], t1["compiles"]) == (1, 0, 1)
        r2 = eng.knn(queries)
        t2 = eng.telemetry()["plan_cache"]
        assert (t2["misses"], t2["hits"], t2["compiles"]) == (1, 1, 1)
        bf_d, _ = brute_force_knn(data, queries, K)
        np.testing.assert_allclose(np.asarray(r2.dists), np.asarray(bf_d),
                                   rtol=1e-3, atol=1e-3)

    def test_same_bucket_different_batch_size_hits(self, data, local):
        before = local.telemetry()["plan_cache"]
        q5 = make_query_workload(jax.random.PRNGKey(3), data, 5, "5%")
        q7 = make_query_workload(jax.random.PRNGKey(4), data, 7, "5%")
        r5 = local.knn(q5)          # bucket 8
        r7 = local.knn(q7)          # same bucket -> must not compile again
        after = local.telemetry()["plan_cache"]
        assert after["compiles"] <= before["compiles"] + 1
        assert r5.dists.shape == (5, K) and r7.dists.shape == (7, K)
        bf_d, _ = brute_force_knn(data, q7, K)
        np.testing.assert_allclose(np.asarray(r7.dists), np.asarray(bf_d),
                                   rtol=1e-3, atol=1e-3)

    def test_distinct_config_compiles_new_plan(self, data, queries):
        eng = QueryEngine(LocalBackend(HerculesIndex.build(data, CFG)))
        eng.knn(queries, k=1)
        eng.knn(queries, k=2)
        pc = eng.telemetry()["plan_cache"]
        assert pc["misses"] == 2 and pc["size"] == 2

    def test_lru_eviction(self, data, queries):
        eng = QueryEngine(LocalBackend(HerculesIndex.build(data, CFG)),
                          EngineConfig(plan_cache_size=1))
        eng.knn(queries, k=1)
        eng.knn(queries, k=2)
        pc = eng.telemetry()["plan_cache"]
        assert pc["size"] == 1 and pc["evictions"] == 1

    def test_explicit_buckets(self, data):
        eng = QueryEngine(LocalBackend(HerculesIndex.build(data, CFG)),
                          EngineConfig(bucket_sizes=(16,)))
        q3 = make_query_workload(jax.random.PRNGKey(5), data, 3, "5%")
        q9 = make_query_workload(jax.random.PRNGKey(6), data, 9, "5%")
        eng.knn(q3)
        eng.knn(q9)                 # both land in the single 16-wide bucket
        pc = eng.telemetry()["plan_cache"]
        assert (pc["misses"], pc["hits"]) == (1, 1)


class TestOverrides:
    def test_per_call_knobs_no_longer_raise(self, data, queries, local):
        res = local.knn(queries, k=5, l_max=2, use_sax=False, adaptive=False)
        bf_d, _ = brute_force_knn(data, queries, 5)
        np.testing.assert_allclose(np.asarray(res.dists), np.asarray(bf_d),
                                   rtol=1e-3, atol=1e-3)

    def test_divisor_chunk_override_accepted(self, data, queries, local):
        n_pad = local.backend.index.layout.lrd.shape[0]
        assert n_pad % 64 == 0
        res = local.knn(queries, chunk=64, scan_block=64)
        bf_d, _ = brute_force_knn(data, queries, K)
        np.testing.assert_allclose(np.asarray(res.dists), np.asarray(bf_d),
                                   rtol=1e-3, atol=1e-3)

    def test_non_divisor_override_rejected(self, data, local):
        n_pad = local.backend.index.layout.lrd.shape[0]
        bad = n_pad - 1             # never divides a padded size > 1
        with pytest.raises(ValueError, match="divide"):
            local.knn(jnp.zeros((1, LEN)), scan_block=bad)

    def test_index_knn_divisor_override(self, data, queries):
        # the old pad-multiple equality check rejected this valid override
        idx = HerculesIndex.build(data, CFG)
        res = idx.knn(queries, k=K, scan_block=128)
        bf_d, _ = brute_force_knn(data, queries, K)
        np.testing.assert_allclose(np.asarray(res.dists), np.asarray(bf_d),
                                   rtol=1e-3, atol=1e-3)


class TestTelemetry:
    def test_paths_and_pruning_accumulate(self, data, queries):
        eng = QueryEngine(LocalBackend(HerculesIndex.build(data, CFG)))
        eng.knn(queries)
        t = eng.telemetry()
        assert t["backend"] == "local"
        assert sum(t["paths"].values()) == queries.shape[0]
        assert 0.0 <= t["pruning"]["eapca_mean"] <= 1.0
        assert t["latency_s"]["total"] > 0
        assert t["queries"] == queries.shape[0]

    def test_describe_lists_cached_plans(self, data, queries):
        eng = QueryEngine(LocalBackend(HerculesIndex.build(data, CFG)))
        eng.knn(queries)
        d = eng.describe()
        assert d["backend"]["backend"] == "local"
        assert len(d["engine"]["cached_plans"]) == 1


class TestKnnServeEngine:
    def test_submit_poll_drain(self, data):
        eng = QueryEngine(LocalBackend(HerculesIndex.build(data, CFG)))
        serve = KnnServeEngine(eng, KnnServeConfig(batch_slots=4))
        workload = np.asarray(
            make_query_workload(jax.random.PRNGKey(7), data, 10, "5%"))
        rids = [serve.submit(q) for q in workload]
        assert serve.poll(rids[0]) is None and serve.pending() == 10
        answers = serve.drain()
        assert set(answers) == set(rids) and serve.pending() == 0
        got = np.stack([answers[r].dists for r in rids])
        bf_d, _ = brute_force_knn(data, jnp.asarray(workload), K)
        np.testing.assert_allclose(got, np.asarray(bf_d),
                                   rtol=1e-3, atol=1e-3)
        assert isinstance(answers[rids[0]], KnnAnswer)
        # drain claimed every answer: results are handed out exactly once
        assert serve.poll(rids[0]) is None
        assert serve.telemetry()["serving"]["unclaimed"] == 0
        # 3 waves, every wave padded to the slot pool -> exactly one plan
        tele = serve.telemetry()
        pc = tele["plan_cache"]
        assert (pc["misses"], pc["hits"]) == (1, 2)
        # slot padding must not pollute telemetry: 10 real queries only
        assert tele["queries"] == 10
        assert sum(tele["paths"].values()) == 10

    def test_step_serves_one_wave(self, data):
        eng = QueryEngine(LocalBackend(HerculesIndex.build(data, CFG)))
        serve = KnnServeEngine(eng, KnnServeConfig(batch_slots=4))
        for q in np.asarray(
                make_query_workload(jax.random.PRNGKey(8), data, 6, "5%")):
            serve.submit(q)
        assert serve.step() == 4 and serve.pending() == 2
        assert serve.step() == 2 and serve.pending() == 0
        assert serve.step() == 0

    def test_mixed_k_groups_into_sub_waves(self, data):
        # regression: interleaved k=1/k=2 traffic used to raise ValueError
        # and requeue the wave at the head — drain() then re-selected the
        # same incompatible wave forever (livelock). Mixed signatures must
        # instead serve as compatible sub-waves, in submission order.
        eng = QueryEngine(LocalBackend(HerculesIndex.build(data, CFG)))
        serve = KnnServeEngine(eng, KnnServeConfig(batch_slots=4))
        q = np.asarray(make_query_workload(
            jax.random.PRNGKey(9), data, 10, "5%"))
        ks = [1 if i % 2 == 0 else 2 for i in range(10)]
        rids = [serve.submit(qi, k=k) for qi, k in zip(q, ks)]
        # head is k=1: its sub-wave takes the 4 oldest k=1 requests only
        assert serve.step() == 4 and serve.pending() == 6
        answers = serve.drain()
        assert set(answers) == set(rids) and serve.pending() == 0
        for k in (1, 2):
            rows = [i for i, kk in enumerate(ks) if kk == k]
            got = np.stack([answers[rids[i]].dists for i in rows])
            assert got.shape == (len(rows), k)
            bf_d, _ = brute_force_knn(data, jnp.asarray(q[rows]), k)
            np.testing.assert_allclose(got, np.asarray(bf_d),
                                       rtol=1e-3, atol=1e-3)
        # 4 sub-waves: 4×k=1, then 4×k=2, then the k=1 and k=2 stragglers
        sv = serve.telemetry()["serving"]
        assert sv["failed"] == 0 and sv["waves"] == 4

    def test_poisoned_request_fails_alone(self, data):
        # regression: one invalid request used to poison its whole wave
        # (np.stack raised before any member was served). It must now
        # complete as a claimable KnnFailure while its wave-mates answer.
        eng = QueryEngine(LocalBackend(HerculesIndex.build(data, CFG)))
        serve = KnnServeEngine(eng, KnnServeConfig(batch_slots=4))
        good = np.asarray(make_query_workload(
            jax.random.PRNGKey(10), data, 3, "5%"))
        g0 = serve.submit(good[0])
        bad = serve.submit(np.zeros(LEN // 2, np.float32))  # wrong length
        g1 = serve.submit(good[1])
        g2 = serve.submit(good[2])
        answers = serve.drain()
        assert serve.pending() == 0
        assert isinstance(answers[bad], KnnFailure)
        assert "ValueError" in answers[bad].error
        got = np.stack([answers[r].dists for r in (g0, g1, g2)])
        bf_d, _ = brute_force_knn(data, jnp.asarray(good), K)
        np.testing.assert_allclose(got, np.asarray(bf_d),
                                   rtol=1e-3, atol=1e-3)
        assert serve.telemetry()["serving"]["failed"] == 1

    def test_admission_control_queue_full(self, data):
        eng = QueryEngine(LocalBackend(HerculesIndex.build(data, CFG)))
        serve = KnnServeEngine(
            eng, KnnServeConfig(batch_slots=2, max_queue=3))
        q = np.asarray(make_query_workload(
            jax.random.PRNGKey(11), data, 5, "5%"))
        for i in range(3):
            serve.submit(q[i])
        with pytest.raises(QueueFull):
            serve.submit(q[3])
        assert serve.telemetry()["serving"]["rejected"] == 1
        serve.step()                      # frees two slots
        serve.submit(q[3])                # backpressure retry succeeds
        serve.drain()
        assert serve.pending() == 0

    def test_difficulty_packing_serves_everything(self, data):
        eng = QueryEngine(LocalBackend(HerculesIndex.build(data, CFG)))
        serve = KnnServeEngine(
            eng, KnnServeConfig(batch_slots=4, pack="difficulty"))
        easy = np.asarray(make_query_workload(
            jax.random.PRNGKey(12), data, 5, "1%"))
        hard = np.asarray(make_query_workload(
            jax.random.PRNGKey(13), data, 5, "ood"))
        q = np.concatenate([easy, hard])
        order = [0, 5, 1, 6, 2, 7, 3, 8, 4, 9]   # interleave easy/hard
        rids = [serve.submit(q[i]) for i in order]
        answers = serve.drain()
        assert set(answers) == set(rids) and serve.pending() == 0
        got = np.stack([answers[r].dists for r in rids])
        bf_d, _ = brute_force_knn(data, jnp.asarray(q[order]), K)
        np.testing.assert_allclose(got, np.asarray(bf_d),
                                   rtol=1e-3, atol=1e-3)
        sv = serve.telemetry()["serving"]
        assert sv["pack"] == "difficulty" and sv["difficulty_scored"] == 10
