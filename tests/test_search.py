"""Exactness + behaviour tests for the full query-answering pipeline.

The paper's invariant: every method returns the same exact kNN answers.
Hercules (all access paths and ablations) must match brute force.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (BuildConfig, HerculesIndex, IndexConfig, SearchConfig,
                        brute_force_knn, pscan_knn)
from repro.data import make_query_workload, random_walks

jax.config.update("jax_platform_name", "cpu")


def _index(num=4000, n=128, tau=100, **search_kw):
    data = random_walks(jax.random.PRNGKey(11), num, n)
    search = SearchConfig(**{"k": 5, "l_max": 8, "chunk": 256,
                             "scan_block": 512, **search_kw})
    idx = HerculesIndex.build(
        data, IndexConfig(build=BuildConfig(leaf_capacity=tau), search=search))
    return data, idx


@pytest.fixture(scope="module")
def default_index():
    return _index()


def _assert_exact(res, data, queries, k):
    bf_d, _ = brute_force_knn(data, queries, k)
    np.testing.assert_allclose(np.asarray(res.dists), np.asarray(bf_d),
                               rtol=1e-3, atol=1e-3)


class TestExactness:
    @pytest.mark.parametrize("difficulty", ["1%", "2%", "5%", "10%", "ood"])
    def test_all_difficulties(self, default_index, difficulty):
        data, idx = default_index
        q = make_query_workload(jax.random.PRNGKey(5), data, 16, difficulty)
        _assert_exact(idx.knn(q), data, q, 5)

    @pytest.mark.parametrize("k", [1, 3, 10, 25])
    def test_k_sweep(self, default_index, k):
        data, idx = default_index
        q = make_query_workload(jax.random.PRNGKey(6), data, 8, "5%")
        _assert_exact(idx.knn(q, k=k), data, q, k)

    def test_result_ids_match_distances(self, default_index):
        data, idx = default_index
        q = make_query_workload(jax.random.PRNGKey(8), data, 8, "5%")
        res = idx.knn(q, k=3)
        got = np.asarray(data)[np.asarray(res.ids)]       # (Q, k, n)
        d = ((got - np.asarray(q)[:, None, :]) ** 2).sum(-1)
        np.testing.assert_allclose(d, np.asarray(res.dists), rtol=1e-3, atol=1e-3)

    def test_no_duplicate_results(self, default_index):
        data, idx = default_index
        q = make_query_workload(jax.random.PRNGKey(9), data, 8, "1%")
        res = idx.knn(q, k=10)
        ids = np.asarray(res.ids)
        for row in ids:
            assert len(set(row.tolist())) == len(row)

    def test_query_from_dataset_finds_itself(self, default_index):
        data, idx = default_index
        q = data[:8]
        res = idx.knn(q, k=1)
        np.testing.assert_allclose(np.asarray(res.dists), 0.0, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(res.ids)[:, 0], np.arange(8))

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_exactness_property(self, seed):
        data, idx = _index(num=1500, n=64, tau=64)
        q = random_walks(jax.random.PRNGKey(seed % 2**31), 4, 64)
        _assert_exact(idx.knn(q, k=3), data, q, 3)


class TestAccessPaths:
    def test_forced_scan_exact(self, default_index):
        data, idx = default_index
        q = make_query_workload(jax.random.PRNGKey(10), data, 8, "10%")
        res = idx.knn(q, force_scan=True)
        assert (np.asarray(res.path) == 3).all()
        _assert_exact(res, data, q, 5)

    def test_nosax_exact(self, default_index):
        data, idx = default_index
        q = make_query_workload(jax.random.PRNGKey(12), data, 8, "5%")
        _assert_exact(idx.knn(q, use_sax=False), data, q, 5)

    def test_nothresh_exact(self, default_index):
        data, idx = default_index
        q = make_query_workload(jax.random.PRNGKey(13), data, 8, "5%")
        res = idx.knn(q, adaptive=False)
        assert (np.asarray(res.path) == 2).all()
        _assert_exact(res, data, q, 5)

    def test_thresholds_trigger_scan(self, default_index):
        """With EAPCA_TH=1.0 every query must take the scan path (ratio<1)."""
        data, idx = default_index
        q = make_query_workload(jax.random.PRNGKey(14), data, 4, "5%")
        res = idx.knn(q, eapca_th=1.01)
        assert (np.asarray(res.path) == 0).all()
        _assert_exact(res, data, q, 5)

    def test_pruning_reduces_access(self, default_index):
        """Easy queries must touch far less data than the scan (paper Fig 10)."""
        data, idx = default_index
        q = make_query_workload(jax.random.PRNGKey(15), data, 8, "1%")
        res = idx.knn(q, k=1)
        frac = np.asarray(res.accessed).mean() / data.shape[0]
        assert frac < 0.5, f"accessed fraction {frac:.2f}"

    def test_sax_prunes_more_than_eapca_alone(self, default_index):
        data, idx = default_index
        q = make_query_workload(jax.random.PRNGKey(16), data, 8, "2%")
        with_sax = idx.knn(q, k=1, adaptive=False)
        without = idx.knn(q, k=1, adaptive=False, use_sax=False)
        assert np.asarray(with_sax.accessed).mean() <= \
            np.asarray(without.accessed).mean() + 1e-6


class TestBaselines:
    def test_pscan_matches_brute_force(self, default_index):
        data, idx = default_index
        q = make_query_workload(jax.random.PRNGKey(17), data, 8, "5%")
        d, p = pscan_knn(data, q, k=5, block=512)
        bf_d, _ = brute_force_knn(data, q, 5)
        np.testing.assert_allclose(np.asarray(d), np.asarray(bf_d),
                                   rtol=1e-3, atol=1e-3)

    def test_pscan_ragged_tail(self):
        data = random_walks(jax.random.PRNGKey(18), 777, 64)
        q = data[:4]
        d, p = pscan_knn(data, q, k=1, block=256)
        np.testing.assert_allclose(np.asarray(d)[:, 0], 0.0, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(p)[:, 0], np.arange(4))


class TestPersistence:
    def test_save_load_roundtrip(self, default_index, tmp_path):
        data, idx = default_index
        path = str(tmp_path / "hercules.npz")
        idx.save(path)
        idx2 = HerculesIndex.load(path)
        q = make_query_workload(jax.random.PRNGKey(19), data, 4, "5%")
        r1 = idx.knn(q, k=3)
        r2 = idx2.knn(q, k=3)
        np.testing.assert_allclose(np.asarray(r1.dists), np.asarray(r2.dists))
        np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))


class TestApproximate:
    def test_approx_never_better_than_exact(self, default_index):
        data, idx = default_index
        q = make_query_workload(jax.random.PRNGKey(20), data, 8, "5%")
        d_approx, ids = idx.knn_approx(q, k=5)
        bf_d, _ = brute_force_knn(data, q, 5)
        # tolerance matches the suite's exactness convention: the brute-force
        # oracle computes distances in matmul-identity form, whose fp32 noise
        # is relative to the distance magnitude
        bf = np.asarray(bf_d)
        assert (np.asarray(d_approx) >= bf - 1e-3 - 1e-3 * np.abs(bf)).all()

    def test_approx_recall_improves_with_lmax(self, default_index):
        data, idx = default_index
        q = make_query_workload(jax.random.PRNGKey(21), data, 8, "5%")
        _, bf_i = brute_force_knn(data, q, 5)

        def recall(l_max):
            _, ids = idx.knn_approx(q, k=5, l_max=l_max)
            return np.mean([len(set(np.asarray(ids)[i])
                                & set(np.asarray(bf_i)[i])) / 5
                            for i in range(8)])

        assert recall(16) >= recall(1) - 1e-9
        assert recall(16) > 0.5


class TestTopkRefine:
    """§Perf iteration 5: top-k candidate selection instead of full argsort."""

    def test_topk_mode_exact(self, default_index):
        data, idx = default_index
        q = make_query_workload(jax.random.PRNGKey(22), data, 8, "5%")
        res = idx.knn(q, refine_select="topk")
        _assert_exact(res, data, q, 5)

    def test_topk_budget_exhaustion_falls_back(self, default_index):
        """A 1-chunk budget forces the scan fallback; answers stay exact."""
        data, idx = default_index
        q = make_query_workload(jax.random.PRNGKey(23), data, 8, "ood")
        res = idx.knn(q, refine_select="topk", topk_budget_chunks=1,
                      adaptive=False)
        _assert_exact(res, data, q, 5)
