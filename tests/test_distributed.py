"""Distribution layer: sharding rules, distributed search (1-dev + 8-dev
subprocess), elastic resharding, serving engine."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke
from repro.core import (BuildConfig, IndexConfig, SearchConfig,
                        brute_force_knn)
from repro.data import make_query_workload, random_walks
from repro.distributed.compat import auto_axis_types, make_mesh
from repro.distributed.search import build_distributed_index, distributed_knn
from repro.distributed.sharding import param_spec, shard_params_tree
from repro.models import get_model
from repro.serve import ServeConfig, ServeEngine

jax.config.update("jax_platform_name", "cpu")


class _FakeMesh:
    """Mesh stand-in for rule unit tests (shape lookup only)."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


class TestShardingRules:
    def setup_method(self):
        self.mesh = _FakeMesh({"data": 16, "model": 16})

    def _spec(self, path, shape):
        return param_spec(path, shape, self.mesh)

    def test_attention_tp(self):
        assert self._spec("blocks/attn/wq", (32, 4096, 4096)) == \
            P(None, "data", "model")
        assert self._spec("blocks/attn/wo", (32, 4096, 4096)) == \
            P(None, "model", "data")

    def test_mlp_tp(self):
        assert self._spec("blocks/mlp/w_gate", (4096, 16384)) == P("data", "model")
        assert self._spec("blocks/mlp/w_down", (16384, 4096)) == P("model", "data")

    def test_moe_ep(self):
        assert self._spec("blocks/moe/w_gate", (24, 32, 1024, 512)) == \
            P(None, "model", "data", None)

    def test_vocab_not_divisible_falls_back(self):
        # 49155 % 16 != 0 -> vocab axis must be dropped, d axis kept
        assert self._spec("embed", (49155, 1024)) == P(None, "data")

    def test_small_dims_replicate(self):
        assert self._spec("blocks/ln_attn", (32, 1024)) == P()

    def test_multipod_fsdp_axes(self):
        mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
        spec = param_spec("blocks/mlp/w_down", (8192, 1024), mesh)
        assert spec == P("model", ("pod", "data"))


class TestDistributedSearch:
    def test_single_device_matches_brute_force(self):
        data = random_walks(jax.random.PRNGKey(0), 1000, 64)
        cfg = IndexConfig(build=BuildConfig(leaf_capacity=64),
                          search=SearchConfig(k=3, l_max=4, chunk=128,
                                              scan_block=256))
        mesh = make_mesh((1,), ("data",), axis_types=auto_axis_types(1))
        idx = build_distributed_index(data, 1, cfg)
        q = make_query_workload(jax.random.PRNGKey(1), data, 4, "5%")
        d, g = distributed_knn(idx, q, mesh)
        bf_d, _ = brute_force_knn(data, q, 3)
        np.testing.assert_allclose(np.asarray(d), np.asarray(bf_d),
                                   rtol=1e-3, atol=1e-3)

    @pytest.mark.slow
    def test_eight_device_subprocess(self):
        """Real multi-device shard_map run (8 placeholder host devices)."""
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys; sys.path.insert(0, "src")
            import jax, numpy as np
            from repro.core import IndexConfig, BuildConfig, SearchConfig, brute_force_knn
            from repro.distributed.compat import auto_axis_types, make_mesh
            from repro.distributed.search import build_distributed_index, distributed_knn
            from repro.data import random_walks, make_query_workload
            data = random_walks(jax.random.PRNGKey(0), 1600, 64)
            cfg = IndexConfig(build=BuildConfig(leaf_capacity=64),
                              search=SearchConfig(k=3, l_max=4, chunk=128, scan_block=256))
            mesh = make_mesh((4, 2), ("data", "model"),
                             axis_types=auto_axis_types(2))
            idx = build_distributed_index(data, 8, cfg)
            q = make_query_workload(jax.random.PRNGKey(1), data, 4, "5%")
            d, g = distributed_knn(idx, q, mesh)
            bf_d, bf_i = brute_force_knn(data, q, 3)
            assert np.allclose(np.asarray(d), np.asarray(bf_d), rtol=1e-3, atol=1e-3)
            assert (np.sort(np.asarray(g),axis=1) == np.sort(np.asarray(bf_i),axis=1)).all()
            print("DISTRIBUTED_OK")
        """)
        res = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                             capture_output=True, text=True, timeout=600)
        assert "DISTRIBUTED_OK" in res.stdout, res.stderr[-2000:]


class TestElasticReshard:
    def test_checkpoint_reshard_roundtrip(self, tmp_path, key):
        """Save under 'mesh A', reload for a different device count: values
        must be identical (checkpoints are mesh-independent)."""
        from repro.train import save_checkpoint, load_checkpoint
        state = {"w": jax.random.normal(key, (16, 8))}
        save_checkpoint(str(tmp_path), 0, state)
        loaded, _ = load_checkpoint(str(tmp_path))
        np.testing.assert_allclose(np.asarray(loaded["w"]),
                                   np.asarray(state["w"]))


class TestServeEngine:
    def test_batched_requests_greedy(self, key):
        cfg = get_smoke("codeqwen1.5-7b")
        model = get_model(cfg)
        params = model.init(key, cfg)
        eng = ServeEngine(model, cfg, params,
                          ServeConfig(max_seq=64, batch_slots=4,
                                      max_new_tokens=8))
        prompts = [np.arange(5) + i for i in range(6)]   # 2 waves
        ids = [eng.submit(p) for p in prompts]
        out = eng.run()
        assert set(out) == set(ids)
        assert all(len(v) == 8 for v in out.values())

    def test_ragged_wave_first_token_matches_solo(self, key):
        # regression: _prefill_batch right-pads ragged prompts and run()
        # sampled logits[:, -1] — for any prompt shorter than the batch max
        # that column is a *pad* position, so the first generated token was
        # wrong. prefill now projects each row's last real token
        # (batch["lens"]), which must reproduce the solo unpadded answer.
        cfg = get_smoke("codeqwen1.5-7b")
        model = get_model(cfg)
        params = model.init(key, cfg)
        prompts = [np.array([1, 2, 3, 4, 5, 6]), np.array([7, 8, 9]),
                   np.array([4, 5])]
        eng = ServeEngine(model, cfg, params,
                          ServeConfig(max_seq=32, batch_slots=4,
                                      max_new_tokens=1))
        rids = [eng.submit(p) for p in prompts]
        out = eng.run()
        for p, rid in zip(prompts, rids):
            cache = model.init_cache(cfg, 1, 32)
            lg, _ = model.prefill(params, {"tokens": jnp.asarray(p)[None]},
                                  cfg, cache)
            assert out[rid][0] == int(jnp.argmax(lg[0, -1]))

    def test_greedy_matches_manual_decode(self, key):
        cfg = get_smoke("codeqwen1.5-7b")
        model = get_model(cfg)
        params = model.init(key, cfg)
        prompt = np.asarray([1, 2, 3, 4])
        eng = ServeEngine(model, cfg, params,
                          ServeConfig(max_seq=32, batch_slots=1,
                                      max_new_tokens=4))
        rid = eng.submit(prompt)
        out = eng.run()[rid]

        # manual reference
        cache = model.init_cache(cfg, 1, 32)
        lg, cache = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                                  cfg, cache)
        toks = [int(jnp.argmax(lg[0, -1]))]
        for _ in range(3):
            lg, cache = model.decode_step(
                params, jnp.asarray([[toks[-1]]], jnp.int32), cfg, cache)
            toks.append(int(jnp.argmax(lg[0, 0])))
        assert out == toks
