"""Persistence + out-of-core subsystem (repro/storage + disk backends).

Covers the PR's acceptance contract:
* save/load round-trip parity — bit-identical KnnResults (exact + approx)
  through every backend fed from disk vs from memory;
* format hardening — version mismatch, truncation, corruption, missing
  files all surface as IndexFormatError;
* chunked streaming build == one-shot build, bit-for-bit (tree, layout,
  ragged and even chunk sizes);
* out-of-core scan/local answer exact kNN on a collection >= 4x the
  memory budget without materializing it, matching the in-memory backends
  bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro.core.engine import (LocalBackend, OutOfCoreLocalBackend,
                               OutOfCoreScanBackend, QueryEngine, ScanBackend,
                               make_disk_backend)
from repro.core.index import HerculesIndex, IndexConfig
from repro.core.search import SearchConfig
from repro.core.tree import BuildConfig, build_tree, build_tree_chunked
from repro.data.pipeline import ArrayChunkSource, NpyChunkSource
from repro.data.synthetic import make_query_workload, random_walks
from repro.storage import (FORMAT_VERSION, IndexFormatError,
                           build_index_streaming, build_index_to_disk,
                           load_index, open_index, save_index)
from repro.storage.format import LRD_FILE, MANIFEST_FILE, TREE_FILE

NUM, LEN = 4096, 64
CFG = IndexConfig(
    build=BuildConfig(leaf_capacity=64),
    search=SearchConfig(k=3, l_max=4, chunk=256, scan_block=512))


@pytest.fixture(scope="module")
def data():
    return random_walks(jax.random.PRNGKey(0), NUM, LEN)


@pytest.fixture(scope="module")
def queries(data):
    return make_query_workload(jax.random.PRNGKey(1), data, 5, "5%")


@pytest.fixture(scope="module")
def index(data):
    return HerculesIndex.build(data, CFG)


@pytest.fixture(scope="module")
def saved_dir(index, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("storage") / "idx")
    save_index(index, path)
    return path


def _same_result(a, b, positions=True):
    assert np.array_equal(np.asarray(a.dists), np.asarray(b.dists))
    assert np.array_equal(np.asarray(a.ids), np.asarray(b.ids))
    if positions:
        assert np.array_equal(np.asarray(a.positions), np.asarray(b.positions))


class TestRoundTrip:
    def test_arrays_bit_identical(self, index, saved_dir):
        loaded = load_index(saved_dir)
        for name in index.tree._fields:
            assert np.array_equal(np.asarray(getattr(index.tree, name)),
                                  np.asarray(getattr(loaded.tree, name))), name
        for f in dataclasses.fields(index.layout):
            a, b = getattr(index.layout, f.name), getattr(loaded.layout, f.name)
            if isinstance(a, int):
                assert a == b, f.name
            else:
                assert np.array_equal(np.asarray(a), np.asarray(b)), f.name
        assert loaded.config == index.config
        assert loaded.max_depth == index.max_depth

    def test_local_backend_parity(self, index, saved_dir, queries):
        mem = LocalBackend(index)
        disk = make_disk_backend("local", saved_dir)
        _same_result(mem.knn(queries), disk.knn(queries))

    def test_scan_backend_parity(self, data, saved_dir, queries):
        mem = ScanBackend(data, CFG.search)
        disk = make_disk_backend("scan", saved_dir)
        _same_result(mem.knn(queries), disk.knn(queries))

    def test_sharded_backend_parity(self, data, saved_dir, queries):
        from repro.core.engine import ShardedBackend
        from repro.distributed.search import build_distributed_index
        shards = len(jax.devices())
        mem = ShardedBackend(build_distributed_index(data, shards, CFG))
        with open_index(saved_dir) as saved:
            reread = jax.numpy.asarray(saved.original_data())
        disk = ShardedBackend(build_distributed_index(reread, shards, CFG))
        _same_result(mem.knn(queries), disk.knn(queries), positions=False)

    def test_approx_parity(self, index, saved_dir, queries):
        loaded = load_index(saved_dir)
        d0, i0 = index.knn_approx(queries, k=3, l_max=4)
        d1, i1 = loaded.knn_approx(queries, k=3, l_max=4)
        assert np.array_equal(np.asarray(d0), np.asarray(d1))
        assert np.array_equal(np.asarray(i0), np.asarray(i1))

    def test_original_data_reconstruction(self, data, saved_dir):
        # context-managed: the memmaps are released deterministically, not
        # whenever GC gets to the handle (tempdir teardown must not rely
        # on collection order)
        with open_index(saved_dir) as saved:
            assert np.array_equal(saved.original_data(), np.asarray(data))
        assert saved.closed


class TestFormatHardening:
    def _copy(self, saved_dir, tmp_path):
        import shutil
        dst = str(tmp_path / "idx")
        shutil.copytree(saved_dir, dst)
        return dst

    def test_version_mismatch(self, saved_dir, tmp_path):
        path = self._copy(saved_dir, tmp_path)
        mf = os.path.join(path, MANIFEST_FILE)
        manifest = json.load(open(mf))
        manifest["version"] = FORMAT_VERSION + 1
        json.dump(manifest, open(mf, "w"))
        with pytest.raises(IndexFormatError, match="version"):
            load_index(path)

    def test_wrong_format_name(self, saved_dir, tmp_path):
        path = self._copy(saved_dir, tmp_path)
        mf = os.path.join(path, MANIFEST_FILE)
        manifest = json.load(open(mf))
        manifest["format"] = "not-an-index"
        json.dump(manifest, open(mf, "w"))
        with pytest.raises(IndexFormatError, match="format"):
            load_index(path)

    def test_truncated_file(self, saved_dir, tmp_path):
        path = self._copy(saved_dir, tmp_path)
        fp = os.path.join(path, LRD_FILE)
        with open(fp, "r+b") as f:
            f.truncate(os.path.getsize(fp) // 2)
        with pytest.raises(IndexFormatError, match="truncated|bytes"):
            load_index(path)

    def test_corrupted_file(self, saved_dir, tmp_path):
        path = self._copy(saved_dir, tmp_path)
        fp = os.path.join(path, TREE_FILE)
        size = os.path.getsize(fp)
        with open(fp, "r+b") as f:
            f.seek(size // 2)
            byte = f.read(1)
            f.seek(size // 2)
            f.write(bytes([byte[0] ^ 0xFF]))
        with pytest.raises(IndexFormatError, match="checksum|corrupted"):
            load_index(path)

    def test_missing_file(self, saved_dir, tmp_path):
        path = self._copy(saved_dir, tmp_path)
        os.remove(os.path.join(path, LRD_FILE))
        with pytest.raises(IndexFormatError, match="missing"):
            load_index(path)

    def test_not_an_index_dir(self, tmp_path):
        with pytest.raises(IndexFormatError, match="manifest"):
            load_index(str(tmp_path / "nope"))

    def test_verify_false_skips_checksums(self, saved_dir, tmp_path):
        # size-preserving corruption goes unnoticed with verify=False;
        # this pins that verify=True is what provides the guarantee
        path = self._copy(saved_dir, tmp_path)
        fp = os.path.join(path, LRD_FILE)
        size = os.path.getsize(fp)
        with open(fp, "r+b") as f:
            f.seek(size - 4)
            f.write(b"\xde\xad\xbe\xef")
        open_index(path, verify=False).close()
        with pytest.raises(IndexFormatError):
            open_index(path, verify=True)


class TestChunkedBuild:
    @pytest.mark.parametrize("chunk_size", [500, 1024])
    def test_tree_equals_oneshot(self, data, chunk_size):
        t1, n1 = build_tree(data, CFG.build)
        t2, n2 = build_tree_chunked(
            ArrayChunkSource(np.asarray(data), chunk_size), CFG.build)
        for name in t1._fields:
            assert np.array_equal(np.asarray(getattr(t1, name)),
                                  np.asarray(getattr(t2, name))), name
        assert np.array_equal(np.asarray(n1), np.asarray(n2))

    def test_streaming_index_equals_oneshot(self, data, index):
        idx2 = HerculesIndex.build_streaming(
            ArrayChunkSource(np.asarray(data), 700), CFG)
        for f in dataclasses.fields(index.layout):
            a, b = getattr(index.layout, f.name), getattr(idx2.layout, f.name)
            if not isinstance(a, int):
                assert np.array_equal(np.asarray(a), np.asarray(b)), f.name

    def test_build_to_disk_equals_oneshot(self, data, index, tmp_path):
        path = str(tmp_path / "idx")
        manifest = build_index_to_disk(
            ArrayChunkSource(np.asarray(data), 1024), path, CFG)
        assert manifest["extra"]["build"]["streaming"]
        loaded = load_index(path)
        assert np.array_equal(np.asarray(index.layout.lrd),
                              np.asarray(loaded.layout.lrd))
        assert np.array_equal(np.asarray(index.layout.lsd),
                              np.asarray(loaded.layout.lsd))

    def test_npy_chunk_source(self, data, tmp_path):
        fp = str(tmp_path / "data.npy")
        np.save(fp, np.asarray(data))
        src = NpyChunkSource(fp, 900)
        assert (src.num_series, src.series_len) == (NUM, LEN)
        idx2 = build_index_streaming(src, CFG)
        t1, _ = build_tree(data, CFG.build)
        assert np.array_equal(np.asarray(t1.num_nodes),
                              np.asarray(idx2.tree.num_nodes))


class TestOutOfCore:
    # 4096 x 64 f32 = 1 MiB; 0.25 MiB budget => collection is 4x the budget
    BUDGET_MB = 0.25

    def _budget_cfg(self):
        return dataclasses.replace(CFG.search, scan_block=256)

    def test_collection_at_least_4x_budget(self):
        assert NUM * LEN * 4 >= 4 * self.BUDGET_MB * (1 << 20)

    def test_ooc_scan_matches_memory_scan(self, data, saved_dir, queries):
        cfg = self._budget_cfg()
        mem = ScanBackend(data, cfg)
        with open_index(saved_dir) as saved:
            ooc = OutOfCoreScanBackend(saved, cfg,
                                       memory_budget_mb=self.BUDGET_MB)
            r_mem, r_ooc = mem.knn(queries), ooc.knn(queries)
            assert np.array_equal(np.asarray(r_mem.dists),
                                  np.asarray(r_ooc.dists))
            assert np.array_equal(np.asarray(r_mem.ids),
                                  np.asarray(r_ooc.ids))
            st = ooc.stats()
            # streamed in budget-bounded blocks, covering everything
            budget_rows = int(self.BUDGET_MB * (1 << 20) // (4 * LEN))
            assert st["blocks"] >= NUM // budget_rows
            assert st["rows_streamed"] == NUM

    def test_ooc_local_matches_local(self, index, saved_dir, queries):
        mem = LocalBackend(index)
        with open_index(saved_dir) as saved:
            ooc = OutOfCoreLocalBackend(saved,
                                        memory_budget_mb=self.BUDGET_MB)
            r_mem, r_ooc = mem.knn(queries, k=1), ooc.knn(queries, k=1)
            assert np.array_equal(np.asarray(r_mem.dists),
                                  np.asarray(r_ooc.dists))
            assert np.array_equal(np.asarray(r_mem.ids),
                                  np.asarray(r_ooc.ids))
            # index pruning means the streamed rows are a strict subset
            assert 0 < ooc.stats()["rows_streamed"] < NUM
            # telemetry mirrors the in-memory pruning ratio semantics
            assert np.all(np.asarray(r_ooc.eapca_pr) >= 0)
            # the streamed LSD phase-3 filter was exercised
            assert ooc.stats()["sax_rows_read"] > 0
            # 'accessed' is per-call, not the backend-lifetime counter
            r2 = ooc.knn(queries, k=1)
            assert np.array_equal(np.asarray(r_ooc.accessed),
                                  np.asarray(r2.accessed))

    def test_ooc_scan_small_budget_autofits(self, data, saved_dir, queries):
        # a base scan_block that cannot fit the budget's streamed blocks is
        # auto-shrunk at construction (every entry point, not just the CLI);
        # only an explicit per-call override still fails validation
        with open_index(saved_dir) as saved:
            ooc = OutOfCoreScanBackend(saved, CFG.search,
                                       memory_budget_mb=0.1)
            assert ooc.base_config.scan_block == ooc.stream_rows()
            r = ooc.knn(queries)
            mem = ScanBackend(data, CFG.search).knn(queries)
            assert np.array_equal(np.asarray(mem.dists), np.asarray(r.dists))
            with pytest.raises(ValueError, match="memory_budget_mb"):
                ooc.knn(queries, scan_block=CFG.search.scan_block)

    def test_ooc_through_engine(self, data, saved_dir, queries):
        cfg = self._budget_cfg()
        with open_index(saved_dir) as saved:
            eng = QueryEngine(OutOfCoreScanBackend(
                saved, cfg, memory_budget_mb=self.BUDGET_MB))
            res = eng.knn(queries, k=3)
            mem = ScanBackend(data, cfg).knn(queries, k=3)
            assert np.array_equal(np.asarray(res.dists), np.asarray(mem.dists))
            tele = eng.telemetry()
            assert tele["queries"] == queries.shape[0]

    def test_make_disk_backend_names(self, saved_dir):
        with pytest.raises(ValueError, match="unknown disk backend"):
            make_disk_backend("nope", saved_dir)
