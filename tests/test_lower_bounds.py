"""Property tests: the lower bounds never exceed the true squared ED.

This is the no-false-dismissal invariant the paper's exactness rests on.
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import lower_bounds as LB
from repro.core import summaries as S

jax.config.update("jax_platform_name", "cpu")

_TOL = 1e-3  # fp32 headroom: bounds and distances accumulate over n terms


def _pair(seed, num=16, n=64):
    rng = np.random.default_rng(seed)
    data = jnp.asarray(rng.normal(size=(num, n)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    return q, data


class TestLBSAX:
    @settings(max_examples=50, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_lb_sax_lower_bounds_ed(self, seed):
        q, data = _pair(seed)
        n = data.shape[1]
        q_paa = S.paa(q[None], 16)[0]
        codes = S.isax(data, 16)
        lb = LB.lb_sax(q_paa, codes, n)
        ed = LB.squared_ed(q[None], data)
        assert bool(jnp.all(lb <= ed + _TOL)), float(jnp.max(lb - ed))

    def test_lb_sax_zero_for_self(self, rng):
        # a series' PAA is inside its own iSAX cell -> LB(q, isax(q)) uses the
        # cell containing q's PAA, so distance contribution is 0
        x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
        q_paa = S.paa(x, 16)
        codes = S.isax(x, 16)
        lb = jax.vmap(lambda p, c: LB.lb_sax(p, c, 64))(q_paa, codes)
        np.testing.assert_allclose(np.asarray(lb), 0.0, atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16]),
           st.sampled_from([16, 64, 256]))
    def test_lb_sax_sweep_segments_alphabet(self, seed, m, alphabet):
        q, data = _pair(seed, n=64)
        q_paa = S.paa(q[None], m)[0]
        codes = S.isax(data, m, alphabet)
        lb = LB.lb_sax(q_paa, codes, 64, alphabet)
        ed = LB.squared_ed(q[None], data)
        assert bool(jnp.all(lb <= ed + _TOL))


class TestLBEAPCA:
    @settings(max_examples=50, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 6))
    def test_series_lb_lower_bounds_ed(self, seed, nseg):
        q, data = _pair(seed, n=48)
        rng = np.random.default_rng(seed + 1)
        cuts = np.sort(rng.choice(np.arange(1, 48), size=nseg - 1, replace=False))
        ep = jnp.asarray(np.concatenate([cuts, [48]]).astype(np.int32))
        sm, ss = S.eapca(data, ep)
        qm, qs = S.eapca(q[None], ep)
        lens = S.segment_lengths(ep)
        lb = LB.lb_eapca_series(qm[0], qs[0], sm, ss, lens)
        ed = LB.squared_ed(q[None], data)
        assert bool(jnp.all(lb <= ed + _TOL)), float(jnp.max(lb - ed))

    @settings(max_examples=50, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_node_lb_lower_bounds_all_members(self, seed):
        q, data = _pair(seed, num=32, n=48)
        ep = jnp.asarray([12, 24, 36, 48], jnp.int32)
        sm, ss = S.eapca(data, ep)
        syn = S.synopsis_from_stats(sm, ss)
        qm, qs = S.eapca(q[None], ep)
        lens = S.segment_lengths(ep)
        lb = LB.lb_eapca_node(qm[0], qs[0], syn, lens)
        ed = LB.squared_ed(q[None], data)
        assert float(lb) <= float(jnp.min(ed)) + _TOL

    def test_node_lb_tighter_than_nothing(self, rng):
        # sanity: LB is strictly positive when query is far away
        data = jnp.asarray(rng.normal(size=(8, 48)).astype(np.float32))
        q = jnp.full((48,), 100.0)
        ep = jnp.asarray([24, 48, 48, 48], jnp.int32)
        sm, ss = S.eapca(data, ep)
        syn = S.synopsis_from_stats(sm, ss)
        qm, qs = S.eapca(q[None], ep)
        lb = LB.lb_eapca_node(qm[0], qs[0], syn, S.segment_lengths(ep))
        assert float(lb) > 1000.0


class TestEDMatrix:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_matmul_identity_matches_direct(self, seed):
        q, data = _pair(seed, num=8)
        d1 = LB.squared_ed_matrix(q[None], data)[0]
        d2 = LB.squared_ed(q[None], data)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                                   rtol=1e-3, atol=1e-3)
