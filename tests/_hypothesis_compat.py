"""Optional-hypothesis shim.

Property-based tests use the real hypothesis API when it is installed
(``pip install -r requirements-dev.txt``). On a clean machine the suite must
still *collect and run*: the fallback below keeps the ``@settings``/``@given``
decorator syntax importable and turns each property test into a single
``pytest.skip`` — example-based tests in the same modules run unchanged.

The skip stub deliberately has a ``(*args, **kwargs)`` signature (and no
``functools.wraps``): pytest must not mistake the strategy parameters of the
wrapped property for fixture requests.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: every attribute is a
        callable returning an inert placeholder (never drawn from)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        if args and callable(args[0]):   # bare @settings
            return args[0]
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            def skipped(*a, **k):
                pytest.skip("hypothesis not installed "
                            "(see requirements-dev.txt)")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco
