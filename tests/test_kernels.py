"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import summaries as S
from repro.kernels import ed as ked
from repro.kernels import lb_sax as klb
from repro.kernels import ops, ref
from repro.kernels.wkv6 import wkv6

jax.config.update("jax_platform_name", "cpu")


def _qs(seed, q, n, length, dtype=jnp.float32):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(k1, (q, length), dtype),
            jax.random.normal(k2, (n, length), dtype))


class TestEDMatrixKernel:
    @pytest.mark.parametrize("q,n,length,bq,bn,bk", [
        (8, 64, 32, 4, 16, 8),
        (4, 32, 64, 4, 32, 64),       # single k-tile
        (16, 128, 16, 8, 64, 16),
        (8, 64, 48, 8, 64, 16),       # multi k-tile, uneven ratios
    ])
    def test_shapes(self, q, n, length, bq, bn, bk):
        qa, sa = _qs(0, q, n, length)
        out = ked.ed_matrix(qa, sa, bq=bq, bn=bn, bk=bk, interpret=True)
        want = ref.ed_matrix_ref(qa, sa)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        qa, sa = _qs(1, 8, 64, 32, dtype)
        out = ked.ed_matrix(qa, sa, bq=4, bn=16, bk=8, interpret=True)
        want = ref.ed_matrix_ref(qa, sa)
        tol = 1e-4 if dtype == jnp.float32 else 0.25
        np.testing.assert_allclose(np.asarray(out), np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_property_random(self, seed):
        qa, sa = _qs(seed, 8, 32, 32)
        out = ked.ed_matrix(qa, sa, bq=4, bn=16, bk=16, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.ed_matrix_ref(qa, sa)),
                                   rtol=1e-4, atol=1e-4)


class TestEDMinKernel:
    @pytest.mark.parametrize("q,n,length,bq,bn,bk", [
        (8, 64, 32, 4, 16, 8),
        (8, 256, 32, 8, 64, 32),
        (4, 32, 96, 4, 16, 32),
    ])
    def test_fused_min(self, q, n, length, bq, bn, bk):
        qa, sa = _qs(2, q, n, length)
        dmin, amin = ked.ed_min(qa, sa, bq=bq, bn=bn, bk=bk, interpret=True)
        want_d, want_a = ref.ed_min_ref(qa, sa)
        np.testing.assert_allclose(np.asarray(dmin), np.asarray(want_d),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(amin), np.asarray(want_a))


class TestLBSaxKernel:
    @pytest.mark.parametrize("q,n,m,alphabet", [
        (8, 128, 16, 256),
        (8, 64, 8, 64),
        (4, 256, 16, 16),
    ])
    def test_vs_oracle(self, q, n, m, alphabet):
        length = 64
        key = jax.random.PRNGKey(3)
        qa = jax.random.normal(key, (q, length))
        sa = jax.random.normal(jax.random.PRNGKey(4), (n, length))
        q_paa = S.paa(qa, m)
        codes = S.isax(sa, m, alphabet)
        out = klb.lb_sax_matrix(q_paa, codes, length, alphabet,
                                bq=4, bn=n // 2, interpret=True)
        want = ref.lb_sax_matrix_ref(q_paa, codes, length, alphabet)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


class TestOpsWrappers:
    """Padding wrappers must be exact for ragged logical shapes."""

    @pytest.mark.parametrize("q,n,length", [(5, 77, 48), (1, 100, 128), (3, 9, 32)])
    def test_ed_matrix_ragged(self, q, n, length):
        qa, sa = _qs(5, q, n, length)
        out = ops.ed_matrix(qa, sa, bq=4, bn=32, bk=16)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.ed_matrix_ref(qa, sa)),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("q,n,length", [(5, 77, 48), (3, 13, 64)])
    def test_ed_min_ragged(self, q, n, length):
        qa, sa = _qs(6, q, n, length)
        dmin, amin = ops.ed_min(qa, sa, bq=4, bn=32, bk=16)
        want_d, want_a = ref.ed_min_ref(qa, sa)
        np.testing.assert_allclose(np.asarray(dmin), np.asarray(want_d),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(amin), np.asarray(want_a))

    def test_lb_sax_ragged(self):
        qa, sa = _qs(7, 5, 77, 64)
        q_paa = S.paa(qa, 16)
        codes = S.isax(sa, 16)
        out = ops.lb_sax_matrix(q_paa, codes, 64, bq=4, bn=32)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.lb_sax_matrix_ref(q_paa, codes, 64)),
                                   rtol=1e-4, atol=1e-4)

    def test_fallback_path(self):
        qa, sa = _qs(8, 4, 16, 32)
        out = ops.ed_matrix(qa, sa, use_pallas=False)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.ed_matrix_ref(qa, sa)))


class TestWKV6Kernel:
    @pytest.mark.parametrize("b,t,h,dk,dv,chunk", [
        (2, 32, 3, 8, 8, 8),
        (1, 64, 2, 16, 16, 16),
        (2, 16, 1, 4, 8, 16),         # single chunk
    ])
    def test_vs_oracle(self, b, t, h, dk, dv, chunk):
        ks = jax.random.split(jax.random.PRNGKey(9), 6)
        r = jax.random.normal(ks[0], (b, t, h, dk))
        k = jax.random.normal(ks[1], (b, t, h, dk))
        v = jax.random.normal(ks[2], (b, t, h, dv))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, dk)))
        u = jax.random.normal(ks[4], (h, dk))
        s0 = jax.random.normal(ks[5], (b, h, dk, dv))
        out, sf = wkv6(r, k, v, w, u, s0, chunk=chunk, interpret=True)
        want_o, want_s = ref.wkv6_ref(r, k, v, w, u, s0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want_o),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(sf), np.asarray(want_s),
                                   rtol=1e-4, atol=1e-4)

    def test_extreme_decay_stable(self):
        """Extreme decay must be exact and finite: w at the exact boundaries
        (0 = instant forget, 1 = no decay), denormal-adjacent, and
        per-channel mixed extremes, across multiple chunks with a nonzero
        initial state."""
        b, t, h, dk, dv = 1, 64, 1, 4, 4
        ks = jax.random.split(jax.random.PRNGKey(10), 6)
        r = jax.random.normal(ks[0], (b, t, h, dk))
        k = jax.random.normal(ks[1], (b, t, h, dk))
        v = jax.random.normal(ks[2], (b, t, h, dv))
        u = jax.random.normal(ks[3], (h, dk))
        s0 = jax.random.normal(ks[4], (b, h, dk, dv))
        mixed = jnp.stack(
            [jnp.zeros((b, t, h)), jnp.ones((b, t, h)),
             jnp.full((b, t, h), 1e-38), jnp.full((b, t, h), 1.0 - 1e-6)],
            axis=-1)                                  # one extreme per channel
        sweeps = [jnp.full((b, t, h, dk), wv)
                  for wv in (0.0, 1e-38, 1e-6, 1.0 - 1e-6, 1.0)] + [mixed]
        for w in sweeps:
            out, sf = wkv6(r, k, v, w, u, s0, chunk=8, interpret=True)
            want_o, want_s = ref.wkv6_ref(r, k, v, w, u, s0)
            assert np.all(np.isfinite(np.asarray(out)))
            np.testing.assert_allclose(np.asarray(out), np.asarray(want_o),
                                       rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(np.asarray(sf), np.asarray(want_s),
                                       rtol=1e-4, atol=1e-4)

    def test_instant_forget_resets_overflowed_state(self):
        """Regression: w == 0 performs an exact state reset. Before the fix,
        the decay was applied as 0 * state, so a state that had overflowed
        to inf (long no-decay stretch, huge k.v outer products) became NaN
        at the first instant-forget token and poisoned every output after
        it. Both the kernel and the oracle must recover."""
        b, t, h, dk, dv = 1, 24, 1, 4, 4
        ks = jax.random.split(jax.random.PRNGKey(11), 4)
        r = jax.random.normal(ks[0], (b, t, h, dk))
        k = jax.random.normal(ks[1], (b, t, h, dk)).at[:, :8].set(2e19)
        v = jax.random.normal(ks[2], (b, t, h, dv)).at[:, :8].set(2e19)
        u = jax.random.normal(ks[3], (h, dk))
        w = jnp.ones((b, t, h, dk)).at[:, 8].set(0.0)  # forget after overflow
        s0 = jnp.zeros((b, h, dk, dv))
        out, sf = wkv6(r, k, v, w, u, s0, chunk=8, interpret=True)
        want_o, want_s = ref.wkv6_ref(r, k, v, w, u, s0)
        # tokens past the reset are finite and exact in kernel and oracle
        assert np.all(np.isfinite(np.asarray(out[:, 9:])))
        assert np.all(np.isfinite(np.asarray(want_o[:, 9:])))
        np.testing.assert_allclose(np.asarray(out[:, 9:]),
                                   np.asarray(want_o[:, 9:]),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(sf), np.asarray(want_s),
                                   rtol=1e-4, atol=1e-4)
