"""Sharded out-of-core serving (dist-ooc): shard plans, per-shard range
views, and bit-identical parity with the single-host backends.

Layout mirrors the environment the backend runs in:

* plan/view/unit tests and single-shard parity run everywhere (1 CPU
  device — conftest keeps the real device world);
* the full mesh matrix (shards {1,2,4,8} x codecs x prefetch x wave x
  journal, tie determinism, residency confinement) runs **in-process**
  when 8+ devices are visible — the CI `distributed` job forces them via
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``;
* a lean subprocess leg covers multi-shard on a plain 1-device machine
  (marked slow, skipped when the in-process matrix already ran).
"""
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro import api
from repro.distributed.ooc import DistOutOfCoreBackend, _ShardRows
from repro.storage.partition import (BALANCE_WARN_RATIO, ShardPlan,
                                     partition_plan, partition_section,
                                     shard_plan)

jax.config.update("jax_platform_name", "cpu")

NUM_DEVICES = len(jax.devices())
MESH_IN_PROCESS = NUM_DEVICES >= 8


def _assert_same(ref, res, *, positions: bool = True):
    assert np.array_equal(np.asarray(ref.dists), np.asarray(res.dists))
    assert np.array_equal(np.asarray(ref.ids), np.asarray(res.ids))
    if positions:
        assert np.array_equal(np.asarray(ref.positions),
                              np.asarray(res.positions))


@pytest.fixture(scope="module")
def rng():
    """Module-local generator shadowing the session ``rng``: this file's
    module-scoped stores must not consume draws from the shared stream
    (later test modules' data would shift with this file's edits)."""
    return np.random.default_rng(9219)


# ---------------------------------------------------------------------------
# shard plans (storage/partition.py)
# ---------------------------------------------------------------------------

class TestPartitionPlan:
    def _uniform(self, leaves: int, rows_per_leaf: int):
        counts = np.full(leaves, rows_per_leaf, np.int64)
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        return starts, counts

    @pytest.mark.parametrize("shards", [1, 2, 4, 8])
    def test_balanced_cut(self, shards):
        starts, counts = self._uniform(16, 100)
        plan = partition_plan(starts, counts, shards)
        assert plan.num_shards == shards
        assert plan.leaf_bounds[0] == 0 and plan.leaf_bounds[-1] == 16
        assert plan.row_bounds[0] == 0 and plan.row_bounds[-1] == 1600
        assert sum(plan.shard_rows) == 1600
        assert plan.balanced and plan.imbalance == 1.0
        # contiguity: shard i's rows are exactly [row_bounds[i], [i+1])
        for s in range(shards):
            lo, hi = plan.row_range(s)
            llo, lhi = plan.leaf_range(s)
            assert lo == starts[llo]
            assert hi == (starts[lhi] if lhi < 16 else 1600)

    def test_every_shard_gets_a_leaf_under_skew(self):
        # one huge head leaf: quantile cuts would all land after it; the
        # clamp still hands every trailing shard at least one leaf
        counts = np.array([10_000, 5, 5, 5], np.int64)
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            plan = partition_plan(starts, counts, 4)
        assert all(plan.leaf_bounds[i] < plan.leaf_bounds[i + 1]
                   for i in range(4))

    def test_skewed_tree_warns_and_flags(self):
        counts = np.array([10_000, 5, 5, 5], np.int64)
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        with pytest.warns(RuntimeWarning, match="imbalanced"):
            plan = partition_plan(starts, counts, 2)
        assert not plan.balanced
        assert plan.imbalance > BALANCE_WARN_RATIO
        # warn=False (what partition_section uses at commit time) is silent
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            partition_plan(starts, counts, 2, warn=False)

    def test_more_shards_than_leaves(self):
        starts, counts = self._uniform(3, 50)
        with pytest.warns(RuntimeWarning):
            plan = partition_plan(starts, counts, 8)
        assert plan.imbalance == float("inf")
        assert sum(plan.shard_rows) == 150
        # trailing shards are empty, never negative
        assert all(r >= 0 for r in plan.shard_rows)

    def test_section_roundtrip_matches_direct_plan(self):
        starts, counts = self._uniform(10, 37)
        section = partition_section(starts, counts)
        assert section["balanced_by"] == "rows"
        for n_str, entry in section["plans"].items():
            n = int(n_str)
            assert ShardPlan.from_manifest(n, entry) == partition_plan(
                starts, counts, n, warn=False)

    def test_validation(self):
        with pytest.raises(ValueError):
            ShardPlan(num_shards=2, leaf_bounds=(0, 1), row_bounds=(0, 5, 9))
        with pytest.raises(ValueError):
            ShardPlan(num_shards=2, leaf_bounds=(0, 2, 1),
                      row_bounds=(0, 5, 9))
        with pytest.raises(ValueError):
            partition_plan([0], [5], 0)


# ---------------------------------------------------------------------------
# per-shard range views
# ---------------------------------------------------------------------------

class TestShardRows:
    def _rows(self, lo=10, hi=20):
        base = np.arange(100, dtype=np.float32).reshape(50, 2)
        audit = [hi, lo]
        return _ShardRows(base, lo, hi, audit), base, audit

    def test_slice_translates_and_audits(self):
        view, base, audit = self._rows()
        np.testing.assert_array_equal(view[2:5], base[12:15])
        assert view.shape == (10, 2) and len(view) == 10
        assert audit == [12, 15]
        np.testing.assert_array_equal(view[0:10], base[10:20])
        assert audit == [10, 20]

    def test_escape_raises(self):
        view, _, _ = self._rows()
        with pytest.raises(IndexError, match="escape"):
            view.take(np.array([11]))
        with pytest.raises(IndexError, match="contiguous"):
            view[0:10:2]
        with pytest.raises(TypeError):
            view[3]

    def test_take_copies_and_stays_local(self):
        view, base, audit = self._rows()
        out = view.take(np.array([0, 9, 3]))
        np.testing.assert_array_equal(out, base[[10, 19, 13]])
        out[0, 0] = -1.0           # a copy: the base must not see this
        assert base[10, 0] != -1.0
        assert audit == [10, 20]


# ---------------------------------------------------------------------------
# serving fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dist_store(tmp_path_factory, rng):
    data = rng.standard_normal((500, 48)).astype(np.float32)
    path = str(tmp_path_factory.mktemp("dist") / "idx")
    with api.Hercules.create(path, api.IndexConfig(), data=data) as hx:
        yield hx, data


@pytest.fixture(scope="module")
def dup_store(tmp_path_factory, rng):
    """Rows duplicated 5x: every distance appears five times, so any top-k
    is wall-to-wall ties. Duplicates share iSAX/EAPCA summaries, so they
    land in one leaf at adjacent file positions — the tie order every
    exact path must reproduce."""
    base = rng.standard_normal((80, 32)).astype(np.float32)
    data = np.repeat(base, 5, axis=0)
    path = str(tmp_path_factory.mktemp("dist_dup") / "idx")
    with api.Hercules.create(path, api.IndexConfig(), data=data) as hx:
        yield hx, base, data


class TestDistOocSingleShard:
    def test_registry_and_api_exports(self):
        assert "dist-ooc" in api.BACKENDS
        assert "dist-ooc" in api.backend_names("disk")
        assert "dist-ooc" not in api.backend_names("memory")
        assert api.DistTelemetry is not None
        assert api.ShardPlan is ShardPlan

    def test_unknown_backend_error_lists_registry(self, dist_store):
        hx, _ = dist_store
        with pytest.raises(ValueError, match="dist-ooc"):
            hx.engine("no-such-backend")
        with pytest.raises(ValueError, match="ooc-local"):
            api.make_disk_backend("no-such-backend", hx)

    def test_budget_keys_streaming_backends_only(self, dist_store):
        hx, _ = dist_store
        assert hx.engine("local", memory_budget_mb=32.0) is \
            hx.engine("local", memory_budget_mb=64.0)
        assert hx.engine("dist-ooc", shards=1, memory_budget_mb=4.0) is not \
            hx.engine("dist-ooc", shards=1, memory_budget_mb=8.0)

    @pytest.mark.parametrize("prefetch", ["sync", "thread"])
    @pytest.mark.parametrize("wave", [False, True])
    def test_parity_one_shard(self, dist_store, rng, prefetch, wave):
        hx, data = dist_store
        q = rng.standard_normal((6, 48)).astype(np.float32)
        ref = hx.engine("local").knn(q, k=5, wave=wave)
        eng = hx.engine("dist-ooc", shards=1, memory_budget_mb=8,
                        prefetch=prefetch)
        _assert_same(ref, eng.knn(q, k=5, wave=wave))

    def test_telemetry_dist_section(self, dist_store, rng):
        hx, data = dist_store
        q = rng.standard_normal((4, 48)).astype(np.float32)
        eng = hx.engine("dist-ooc", shards=1, memory_budget_mb=8)
        eng.knn(q, k=3)
        t = eng.telemetry()
        assert "dist" in t and "ooc" in t
        d = t.dist
        assert d.shards == 1
        assert len(d.rows_streamed) == 1 and d.rows_streamed[0] > 0
        assert len(d.read_wait_seconds) == 1
        assert d.imbalance == 1.0 and d.plan_imbalance == 1.0
        assert not d.balance_warning
        (lo, hi), (tlo, thi) = d.row_range[0], d.rows_touched[0]
        assert lo <= tlo and thi <= hi
        # streamed counters also aggregate into the regular ooc section
        assert t.ooc.rows_streamed == d.rows_streamed[0]

    def test_journal_rows_merge(self, dist_store, rng, tmp_path):
        hx, data = dist_store
        # a fresh store: the module fixture must stay journal-free
        q = rng.standard_normal((4, 48)).astype(np.float32)
        extra = rng.standard_normal((30, 48)).astype(np.float32)
        extra[:4] = q  # each query's 1-NN is a journal row (distance 0)
        path = str(tmp_path / "idx")
        with api.Hercules.create(path, api.IndexConfig(),
                                 data=data) as hx2:
            hx2.append(extra)
            ref = hx2.query(q, k=5, backend="local")
            res = hx2.query(q, k=5, backend="dist-ooc", shards=1,
                            memory_budget_mb=8)
            _assert_same(ref, res)
            # journal ids continue past the base collection
            assert np.asarray(ref.ids).max() >= data.shape[0]

    def test_plan_signature_in_cache_key(self, dist_store):
        hx, _ = dist_store
        be = hx.engine("dist-ooc", shards=1).backend
        assert be.plan_signature[0] == "dist-ooc"
        assert be.plan_signature[1] == 1
        # single-host streaming backends carry no signature: their plans
        # cache under the plain (cfg, bucket, ...) key as before
        assert getattr(hx.engine("ooc-local").backend,
                       "plan_signature", None) is None

    def test_shards_beyond_devices_error_names_recipe(self, dist_store):
        hx, _ = dist_store
        with pytest.raises(ValueError,
                           match="xla_force_host_platform_device_count"):
            DistOutOfCoreBackend(hx.saved, shards=NUM_DEVICES + 1)

    def test_codec_mesh_parity_one_shard(self, rng, tmp_path):
        data = rng.standard_normal((400, 32)).astype(np.float32)
        q = rng.standard_normal((3, 32)).astype(np.float32)
        path = str(tmp_path / "idx")
        with api.Hercules.create(path, api.IndexConfig(), data=data,
                                 codec="bf16") as hx:
            ref = hx.engine("local").knn(q, k=4)
            for wave in (False, True):
                res = hx.engine("dist-ooc", shards=1,
                                memory_budget_mb=8).knn(q, k=4, wave=wave)
                _assert_same(ref, res)


class TestShardPlanOnSavedIndex:
    def test_manifest_records_and_derivation_agrees(self, dist_store):
        hx, _ = dist_store
        saved = hx.saved
        section = saved.manifest.get("partition")
        assert section is not None
        assert set(section["plans"]) == {"2", "4", "8"}
        for n in (2, 3, 4, 8):   # 3 is not recorded: derived on demand
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", RuntimeWarning)
                recorded = shard_plan(saved, n)
                derived = partition_plan(saved.small["leaf_start"],
                                         saved.small["leaf_count"], n,
                                         warn=False)
            assert recorded == derived

    def test_old_manifest_without_section_derives(self, dist_store, rng):
        hx, _ = dist_store
        saved = hx.saved
        stripped = {k: v for k, v in saved.manifest.items()
                    if k != "partition"}
        import dataclasses as dc
        old = dc.replace(saved, manifest=stripped)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            assert shard_plan(old, 4) == shard_plan(saved, 4)


# ---------------------------------------------------------------------------
# top-k tie determinism (satellite: duplicated rows across shards)
# ---------------------------------------------------------------------------

class TestTieDeterminism:
    def test_duplicated_rows_same_ids_as_local(self, dup_store, rng):
        hx, base, data = dup_store
        # query at a tiny offset from real rows: the 5 duplicates of the
        # nearest row are exact distance ties filling the whole top-5
        q = (base[:4] + 1e-3 * rng.standard_normal((4, 32))
             ).astype(np.float32)
        ref = hx.engine("local").knn(q, k=10)
        res = hx.engine("dist-ooc", shards=1, memory_budget_mb=8).knn(
            q, k=10)
        # the ties are real: duplicate groups produce repeated distances
        dref = np.asarray(ref.dists)
        assert any((dref[i, :-1] == dref[i, 1:]).any()
                   for i in range(dref.shape[0]))
        _assert_same(ref, res)

    @settings(max_examples=10, deadline=None)
    @given(row=st.integers(min_value=0, max_value=79),
           scale=st.floats(min_value=1e-4, max_value=1e-2))
    def test_property_tie_merge_matches_local(self, dup_store, row, scale):
        hx, base, data = dup_store
        q = (base[row:row + 1] + np.float32(scale)).astype(np.float32)
        ref = hx.engine("local").knn(q, k=10)
        res = hx.engine("dist-ooc", shards=1, memory_budget_mb=8).knn(
            q, k=10)
        _assert_same(ref, res)


# ---------------------------------------------------------------------------
# the full mesh matrix — in-process when the CI distributed job forces
# 8 host devices, else via one lean subprocess leg
# ---------------------------------------------------------------------------

_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import warnings; warnings.simplefilter("ignore", RuntimeWarning)
    import tempfile
    import numpy as np
    from repro import api

    rng = np.random.default_rng(7)
    data = rng.standard_normal((600, 32)).astype(np.float32)
    extra = rng.standard_normal((40, 32)).astype(np.float32)
    q = rng.standard_normal((4, 32)).astype(np.float32)
    base = rng.standard_normal((60, 32)).astype(np.float32)
    dup = np.repeat(base, 5, axis=0)
    qt = (base[:3] + 1e-3).astype(np.float32)

    def same(a, b):
        assert np.array_equal(np.asarray(a.dists), np.asarray(b.dists))
        assert np.array_equal(np.asarray(a.positions),
                              np.asarray(b.positions))
        assert np.array_equal(np.asarray(a.ids), np.asarray(b.ids))

    with tempfile.TemporaryDirectory() as d:
        for codec in ("raw", "bf16"):
            with api.Hercules.create(d + "/i-" + codec, api.IndexConfig(),
                                     data=data, codec=codec) as hx:
                hx.append(extra)        # journal rows merge on every path
                ref = hx.query(q, k=5, backend="local")
                for shards in (2, 4, 8):
                    for prefetch in ("sync", "thread"):
                        for wave in (False, True):
                            res = hx.query(q, k=5, backend="dist-ooc",
                                           shards=shards, memory_budget_mb=8,
                                           prefetch=prefetch, wave=wave)
                            same(ref, res)
                    # residency confinement, telemetry-asserted (same
                    # cached engine the query loop above served through)
                    t = hx.engine("dist-ooc", shards=shards,
                                  memory_budget_mb=8).telemetry()
                    ds = t.dist
                    assert ds.shards == shards
                    for (lo, hi), touched in zip(ds.row_range,
                                                 ds.rows_touched):
                        if touched is not None:
                            assert lo <= touched[0] and touched[1] <= hi
                    assert sum(ds.rows_streamed) > 0
        # tie determinism across shard counts (duplicated rows)
        with api.Hercules.create(d + "/dup", api.IndexConfig(),
                                 data=dup) as hx:
            ref = hx.engine("local").knn(qt, k=10)
            dd = np.asarray(ref.dists)
            assert any((dd[i, :-1] == dd[i, 1:]).any()
                       for i in range(dd.shape[0]))
            for shards in (1, 2, 4, 8):
                same(ref, hx.engine("dist-ooc", shards=shards,
                                    memory_budget_mb=8).knn(qt, k=10))
    print("DIST_OOC_MESH_OK")
""")


@pytest.mark.skipif(not MESH_IN_PROCESS,
                    reason="needs 8 devices (CI distributed job forces "
                           "them); 1-device machines run the subprocess leg")
class TestDistOocMeshInProcess:
    @pytest.fixture(scope="class")
    def mesh_store(self, tmp_path_factory, rng):
        data = rng.standard_normal((600, 32)).astype(np.float32)
        extra = rng.standard_normal((40, 32)).astype(np.float32)
        path = str(tmp_path_factory.mktemp("mesh") / "idx")
        with api.Hercules.create(path, api.IndexConfig(), data=data) as hx:
            hx.append(extra)
            yield hx

    @pytest.mark.parametrize("shards", [1, 2, 4, 8])
    @pytest.mark.parametrize("prefetch", ["sync", "thread"])
    @pytest.mark.parametrize("wave", [False, True])
    def test_parity_with_journal(self, mesh_store, rng, shards, prefetch,
                                 wave):
        q = rng.standard_normal((4, 32)).astype(np.float32)
        ref = mesh_store.query(q, k=5, backend="local")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            res = mesh_store.query(q, k=5, backend="dist-ooc", shards=shards,
                                   memory_budget_mb=8, prefetch=prefetch,
                                   wave=wave)
        _assert_same(ref, res)

    @pytest.mark.parametrize("codec", ["raw", "bf16"])
    @pytest.mark.parametrize("shards", [2, 8])
    def test_codec_parity(self, rng, tmp_path, codec, shards):
        data = rng.standard_normal((500, 32)).astype(np.float32)
        q = rng.standard_normal((3, 32)).astype(np.float32)
        with api.Hercules.create(str(tmp_path / "i"), api.IndexConfig(),
                                 data=data, codec=codec) as hx:
            ref = hx.engine("local").knn(q, k=4)
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", RuntimeWarning)
                eng = hx.engine("dist-ooc", shards=shards,
                                memory_budget_mb=8)
                _assert_same(ref, eng.knn(q, k=4))
                ds = eng.telemetry().dist
            for (lo, hi), touched in zip(ds.row_range, ds.rows_touched):
                if touched is not None:
                    assert lo <= touched[0] and touched[1] <= hi

    @pytest.mark.parametrize("shards", [2, 4, 8])
    def test_tie_determinism_across_shards(self, rng, tmp_path, shards):
        base = rng.standard_normal((60, 32)).astype(np.float32)
        data = np.repeat(base, 5, axis=0)
        qt = (base[:3] + 1e-3).astype(np.float32)
        with api.Hercules.create(str(tmp_path / "dup"), api.IndexConfig(),
                                 data=data) as hx:
            ref = hx.engine("local").knn(qt, k=10)
            dd = np.asarray(ref.dists)
            assert any((dd[i, :-1] == dd[i, 1:]).any()
                       for i in range(dd.shape[0]))
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", RuntimeWarning)
                res = hx.engine("dist-ooc", shards=shards,
                                memory_budget_mb=8).knn(qt, k=10)
            _assert_same(ref, res)


@pytest.mark.slow
@pytest.mark.skipif(MESH_IN_PROCESS,
                    reason="8 devices visible: the in-process matrix "
                           "already covers the mesh")
def test_dist_ooc_mesh_subprocess():
    res = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                         cwd="/root/repo", capture_output=True, text=True,
                         timeout=600)
    assert "DIST_OOC_MESH_OK" in res.stdout, res.stderr[-3000:]


# the lean always-on leg: 2 forced host devices, sanitizers armed — every
# machine exercises a real multi-device mesh even where the 8-device
# matrix above is skipped
_MESH2_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    os.environ["REPRO_SANITIZE"] = "1"
    import sys; sys.path.insert(0, "src")
    import warnings; warnings.simplefilter("ignore", RuntimeWarning)
    import tempfile
    import numpy as np
    from repro import api

    rng = np.random.default_rng(11)
    data = rng.standard_normal((400, 32)).astype(np.float32)
    extra = rng.standard_normal((24, 32)).astype(np.float32)
    q = rng.standard_normal((3, 32)).astype(np.float32)
    base = rng.standard_normal((50, 32)).astype(np.float32)
    dup = np.repeat(base, 4, axis=0)
    qt = (base[:2] + 1e-3).astype(np.float32)

    def same(a, b):
        assert np.array_equal(np.asarray(a.dists), np.asarray(b.dists))
        assert np.array_equal(np.asarray(a.positions),
                              np.asarray(b.positions))
        assert np.array_equal(np.asarray(a.ids), np.asarray(b.ids))

    with tempfile.TemporaryDirectory() as d:
        with api.Hercules.create(d + "/i", api.IndexConfig(),
                                 data=data) as hx:
            hx.append(extra)        # journal rows merge on every path
            ref = hx.query(q, k=5, backend="local")
            for prefetch in ("sync", "thread"):
                for wave in (False, True):
                    res = hx.query(q, k=5, backend="dist-ooc", shards=2,
                                   memory_budget_mb=8, prefetch=prefetch,
                                   wave=wave)
                    same(ref, res)
        # tie determinism on the 2-device mesh (duplicated rows)
        with api.Hercules.create(d + "/dup", api.IndexConfig(),
                                 data=dup) as hx:
            ref = hx.engine("local").knn(qt, k=8)
            dd = np.asarray(ref.dists)
            assert any((dd[i, :-1] == dd[i, 1:]).any()
                       for i in range(dd.shape[0]))
            same(ref, hx.engine("dist-ooc", shards=2,
                                memory_budget_mb=8).knn(qt, k=8))
    print("DIST_OOC_MESH2_OK")
""")


def test_dist_ooc_two_device_subprocess():
    res = subprocess.run([sys.executable, "-c", _MESH2_SCRIPT],
                         cwd="/root/repo", capture_output=True, text=True,
                         timeout=600)
    assert "DIST_OOC_MESH2_OK" in res.stdout, res.stderr[-3000:]
