"""Units for the dry-run analysis pipeline (no 512-device mesh needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H
from repro.launch.specs import input_specs, param_specs, tree_bytes
from repro.configs import get_config, get_smoke
from repro.models import SHAPES

jax.config.update("jax_platform_name", "cpu")


class TestCollectiveParser:
    HLO = """
  %ag = bf16[128,1024]{1,0} all-gather(%x), replica_groups={{0,1}}
  %ar = f32[256]{0} all-reduce(%y), to_apply=%add
  %t = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-reduce(%a, %b), to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%z), dimensions={0}
  %a2a = bf16[8,8]{1,0} all-to-all(%w), dimensions={0}
  %cp = u8[100]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %ags = bf16[32]{0} all-gather-start(%q)
  %notacoll = f32[4]{0} add(%p, %q)
"""

    def test_bytes_per_type(self):
        out = H.collective_bytes(self.HLO)
        assert out["all-gather"]["bytes"] == 128 * 1024 * 2 + 32 * 2
        assert out["all-gather"]["count"] == 2
        assert out["all-reduce"]["bytes"] == 256 * 4 + 2 * 16 * 16 * 4
        assert out["reduce-scatter"]["bytes"] == 64 * 4
        assert out["all-to-all"]["bytes"] == 8 * 8 * 2
        assert out["collective-permute"]["bytes"] == 100
        assert out["total_bytes"] == sum(
            out[k]["bytes"] for k in ("all-gather", "all-reduce",
                                      "reduce-scatter", "all-to-all",
                                      "collective-permute"))

    def test_real_compiled_module_has_collectives(self):
        """An all-reduce jitted across a 1-device mesh: parser must not crash
        on real HLO text (count may be 0 after optimization)."""
        f = jax.jit(lambda x: x * 2)
        txt = f.lower(jnp.ones((4,))).compile().as_text()
        out = H.collective_bytes(txt)
        assert out["total_bytes"] >= 0


class TestRoofline:
    def test_dominant_term(self):
        r = H.roofline_terms(flops=1e15, bytes_accessed=1e12, coll_bytes=1e9,
                             chips=256)
        assert r.compute_s == pytest.approx(1e15 / (256 * 197e12))
        assert r.memory_s == pytest.approx(1e12 / (256 * 819e9))
        assert r.dominant == "compute"
        r2 = H.roofline_terms(flops=1e12, bytes_accessed=1e15, coll_bytes=0,
                              chips=256)
        assert r2.dominant == "memory"


class TestSpecs:
    def test_train_specs_all_archs(self):
        from repro.configs import ARCH_NAMES
        for arch in ARCH_NAMES:
            cfg = get_config(arch)
            spec = input_specs(cfg, SHAPES["train_4k"])
            assert spec["tokens"].shape[0] == 256
            if cfg.family == "vlm":
                assert spec["tokens"].shape[1] + spec["patch_embeds"].shape[1] \
                    == 4096

    def test_decode_specs(self):
        cfg = get_config("llama3-405b")
        spec = input_specs(cfg, SHAPES["decode_32k"])
        assert spec["tokens"].shape == (128, 1)

    def test_param_specs_match_smoke_init(self, key):
        from repro.models import get_model
        cfg = get_smoke("codeqwen1.5-7b")
        model = get_model(cfg)
        spec = param_specs(cfg)
        real = model.init(key, cfg)
        spec_shapes = jax.tree.map(lambda s: s.shape, spec)
        real_shapes = jax.tree.map(lambda a: a.shape, real)
        assert spec_shapes == real_shapes

    def test_405b_param_spec_bytes(self):
        cfg = get_config("llama3-405b")
        b = tree_bytes(param_specs(cfg))
        n = cfg.param_count()
        # llama3-405b stores params bf16 (EXPERIMENTS.md §Perf iteration 3a):
        # spec bytes within 10% of 2*N
        assert cfg.param_dtype == "bfloat16"
        assert abs(b - 2 * n) / (2 * n) < 0.1
