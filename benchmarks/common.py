"""Benchmark harness utilities. Output contract: ``name,us_per_call,derived``.

CPU numbers are *directional* (the paper's wall-clock claims are validated as
ordering/pruning behaviour here; TPU-targeted absolutes live in the §Roofline
terms from the dry-run artifacts).
"""
from __future__ import annotations

import time

import jax
import numpy as np


def time_call(fn, *args, warmup: int = 1, iters: int = 3, **kw) -> float:
    """Median wall time of fn(*args) in microseconds (blocks on jax arrays)."""
    def run():
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        return out

    for _ in range(warmup):
        run()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        run()
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
