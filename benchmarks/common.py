"""Benchmark harness utilities. Output contract: ``name,us_per_call,derived``.

CPU numbers are *directional* (the paper's wall-clock claims are validated as
ordering/pruning behaviour here; TPU-targeted absolutes live in the §Roofline
terms from the dry-run artifacts).

Every :func:`emit` call is also recorded as a structured row (with any extra
keyword fields, e.g. ``speedup_vs_ref`` from the kernel benches); a run can
dump them with :func:`write_json` (``benchmarks.run --json``) so the perf
trajectory is machine-readable across PRs.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

_ROWS: list[dict] = []


def time_call(fn, *args, warmup: int = 1, iters: int = 3, **kw) -> float:
    """Median wall time of fn(*args) in microseconds (blocks on jax arrays)."""
    def run():
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        return out

    for _ in range(warmup):
        run()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        run()
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def emit(name: str, us_per_call: float, derived: str = "", **extra) -> None:
    """Print one CSV row and record it (plus ``extra`` fields) for --json."""
    _ROWS.append({"name": name, "us_per_call": round(us_per_call, 1),
                  "derived": derived, **extra})
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def rows() -> list[dict]:
    return list(_ROWS)


def write_json(path: str) -> None:
    with open(path, "w") as f:
        json.dump({"schema": "bench_rows/v1", "rows": _ROWS}, f, indent=2)
    print(f"# wrote {len(_ROWS)} rows to {path}", flush=True)
