"""One benchmark per paper table/figure (DESIGN.md §7).

Every function prints ``name,us_per_call,derived`` CSV rows. Sizes are scaled
to CPU (1 core) but preserve the paper's comparisons: method orderings and
pruning ratios are the reproduced claims; absolute wall-clock is directional.

Every method is driven through the unified :class:`repro.core.QueryEngine`
surface — Hercules (LocalBackend), PSCAN (ScanBackend, MXU form), the
ParIS+-like flat filter (FlatSaxBackend) and ablations (per-call overrides)
all answer via the identical ``engine.knn(queries, k=...)`` call, so the
compared numbers include the same dispatch/batching layer a serving system
pays.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.baselines import FlatSaxBackend
from benchmarks.common import emit, time_call
from repro.core import (BuildConfig, HerculesIndex, IndexConfig, LocalBackend,
                        QueryEngine, ScanBackend, SearchConfig,
                        brute_force_knn)
from repro.data import DIFFICULTY_LEVELS, make_query_workload, random_walks

_SEARCH = dict(l_max=8, chunk=512, scan_block=2048)


def _build(data, tau=128, **kw):
    cfg = IndexConfig(build=BuildConfig(leaf_capacity=tau),
                      search=SearchConfig(**{**_SEARCH, **kw}))
    return HerculesIndex.build(data, cfg)


def _engine(data, tau=128, **kw) -> QueryEngine:
    return QueryEngine(LocalBackend(_build(data, tau, **kw)))


def _scan_engine(data, **kw) -> QueryEngine:
    return QueryEngine(ScanBackend(data, SearchConfig(**{**_SEARCH, **kw}),
                                   mxu=True))


def _flat_engine(data, **kw) -> QueryEngine:
    return QueryEngine(FlatSaxBackend(data, SearchConfig(**{**_SEARCH, **kw})))


def _check_exact(res_d, data, q, k):
    bf, _ = brute_force_knn(data, q, k)
    if not np.allclose(np.asarray(res_d), np.asarray(bf), rtol=1e-3, atol=1e-3):
        raise AssertionError("benchmark answer mismatch vs brute force")


# --------------------------------------------------------------------------
# Fig 6/7: scalability with dataset size (index build + query answering)
# --------------------------------------------------------------------------

def bench_scalability_size(sizes=(2048, 8192, 32768), n=128, nq=16):
    key = jax.random.PRNGKey(0)
    for num in sizes:
        data = random_walks(key, num, n)
        q = make_query_workload(jax.random.PRNGKey(1), data, nq, "5%")

        t_build = time_call(lambda d=data: _build(d), warmup=0, iters=1)
        herc = _engine(data)
        scan = _scan_engine(data)
        flat = _flat_engine(data)
        res = herc.knn(q, k=1)
        _check_exact(res.dists, data, q, 1)
        t_herc = time_call(lambda: herc.knn(q, k=1))
        t_scan = time_call(lambda: scan.knn(q, k=1))
        t_flat = time_call(lambda: flat.knn(q, k=1))
        t_nosax = time_call(lambda: herc.knn(q, k=1, use_sax=False))
        emit(f"fig6_size{num}_build_hercules", t_build,
             f"leaves={herc.stats()['num_leaves']}")
        emit(f"fig6_size{num}_query_hercules", t_herc / nq,
             f"accessed={float(res.accessed.mean()) / num:.3f}")
        emit(f"fig6_size{num}_query_pscan", t_scan / nq, "accessed=1.0")
        emit(f"fig6_size{num}_query_parisplus_like", t_flat / nq, "")
        emit(f"fig6_size{num}_query_dstree_like", t_nosax / nq, "")


# --------------------------------------------------------------------------
# Fig 8: scalability with series length
# --------------------------------------------------------------------------

def bench_series_length(lengths=(64, 128, 256, 512), num=8192, nq=8):
    for n in lengths:
        data = random_walks(jax.random.PRNGKey(2), num, n)
        q = make_query_workload(jax.random.PRNGKey(3), data, nq, "5%")
        herc = _engine(data)
        scan = _scan_engine(data)
        res = herc.knn(q, k=1)
        _check_exact(res.dists, data, q, 1)
        t_herc = time_call(lambda: herc.knn(q, k=1))
        t_scan = time_call(lambda: scan.knn(q, k=1))
        emit(f"fig8_len{n}_query_hercules", t_herc / nq,
             f"speedup_vs_scan={t_scan / max(t_herc, 1e-9):.2f}x")
        emit(f"fig8_len{n}_query_pscan", t_scan / nq, "")


# --------------------------------------------------------------------------
# Fig 9/10: query difficulty (time + % data accessed)
# --------------------------------------------------------------------------

def bench_difficulty(num=16384, n=128, nq=16):
    data = random_walks(jax.random.PRNGKey(4), num, n)
    herc = _engine(data)
    scan = _scan_engine(data)
    flat = _flat_engine(data)
    for diff in DIFFICULTY_LEVELS:
        q = make_query_workload(jax.random.PRNGKey(5), data, nq, diff)
        res = herc.knn(q, k=1)
        _check_exact(res.dists, data, q, 1)
        t_herc = time_call(lambda: herc.knn(q, k=1))
        t_scan = time_call(lambda: scan.knn(q, k=1))
        t_flat = time_call(lambda: flat.knn(q, k=1))
        acc = float(res.accessed.mean()) / num
        paths = np.bincount(np.asarray(res.path), minlength=4)
        emit(f"fig10_{diff}_hercules", t_herc / nq,
             f"accessed={acc:.3f};paths={'/'.join(map(str, paths))}")
        emit(f"fig10_{diff}_pscan", t_scan / nq, "accessed=1.0")
        emit(f"fig10_{diff}_parisplus_like", t_flat / nq, "")


# --------------------------------------------------------------------------
# Fig 11: scalability with k
# --------------------------------------------------------------------------

def bench_k(num=16384, n=128, nq=8, ks=(1, 5, 25, 100)):
    data = random_walks(jax.random.PRNGKey(6), num, n)
    q = make_query_workload(jax.random.PRNGKey(7), data, nq, "5%")
    herc = _engine(data)
    for k in ks:
        res = herc.knn(q, k=k)
        _check_exact(res.dists, data, q, k)
        t = time_call(lambda: herc.knn(q, k=k))
        emit(f"fig11_k{k}_hercules", t / nq,
             f"accessed={float(res.accessed.mean()) / num:.3f}")


# --------------------------------------------------------------------------
# Fig 12: ablation (NoSAX / NoThresh / NoPara analogue)
# --------------------------------------------------------------------------

def bench_ablation(num=16384, n=128, nq=16):
    data = random_walks(jax.random.PRNGKey(8), num, n)
    herc = _engine(data)
    # NoPara analogue: narrow vectorization (chunk/scan_block 64) — the
    # vector lanes play the role of the paper's threads+SIMD
    herc_narrow = _engine(data, chunk=64, scan_block=64)
    for diff in ("1%", "5%", "ood"):
        q = make_query_workload(jax.random.PRNGKey(9), data, nq, diff)
        variants = {
            "hercules": lambda: herc.knn(q, k=1),
            "nosax": lambda: herc.knn(q, k=1, use_sax=False),
            "nothresh": lambda: herc.knn(q, k=1, adaptive=False),
            "nopara": lambda: herc_narrow.knn(q, k=1),
        }
        for name, fn in variants.items():
            res = fn()
            _check_exact(res.dists, data, q, 1)
            t = time_call(fn)
            emit(f"fig12_{diff}_{name}", t / nq,
                 f"accessed={float(res.accessed.mean()) / num:.3f}")


# --------------------------------------------------------------------------
# Backend comparison through the one serving surface (QueryEngine)
# --------------------------------------------------------------------------

def bench_backends(backends=("local", "scan", "scan-mxu", "flat-sax"),
                   num=16384, n=128, nq=16, k=1, kernel_mode="auto"):
    """The same workload through every named backend via QueryEngine —
    the api_redesign's acceptance bench (identical call, exact answers).

    ``kernel_mode`` flows into SearchConfig: ``auto`` serves Pallas on TPU
    and the ref path elsewhere; ``interpret`` forces the kernel bodies
    through the interpreter (the CI kernel-drift smoke).
    """
    from repro.core import make_backend

    data = random_walks(jax.random.PRNGKey(11), num, n)
    q = make_query_workload(jax.random.PRNGKey(12), data, nq, "5%")
    cfg = IndexConfig(build=BuildConfig(leaf_capacity=128),
                      search=SearchConfig(k=k, kernel_mode=kernel_mode,
                                          **_SEARCH))
    for name in backends:
        if name == "flat-sax":
            backend = FlatSaxBackend(data, cfg.search)
        else:
            backend = make_backend(name, data, index_config=cfg)
        eng = QueryEngine(backend)
        res = eng.knn(q, k=k)
        _check_exact(res.dists, data, q, k)
        t = time_call(lambda: eng.knn(q, k=k))
        pc = eng.telemetry()["plan_cache"]
        emit(f"backend_{name}", t / nq,
             f"plan_hits={pc['hits']};compiles={pc['compiles']}"
             f";kernel_mode={kernel_mode}",
             kernel_mode=kernel_mode)


# --------------------------------------------------------------------------
# Persistence / out-of-core: build throughput, save/load latency, ooc scan
# --------------------------------------------------------------------------

def bench_persistence(num=16384, n=128, nq=8, k=1, chunk=4096,
                      memory_budget_mb=2.0, save_path=None, load_path=None):
    """The ingest-path trajectory rows: one-shot vs chunked build throughput
    (series/sec), index save/load wall time and on-disk size, and the
    out-of-core streamed scan vs the in-memory scan on the same saved index.

    ``save_path``/``load_path`` (benchmarks.run --save-index/--load-index)
    pin the index directory; by default a temp dir is used and cleaned up.
    ``load_path`` skips building and benches serving a pre-built index.
    """
    import os
    import shutil
    import tempfile
    import time as _time

    from repro.core import make_disk_backend
    from repro.data.pipeline import ArrayChunkSource
    from repro.storage import load_index, open_index, save_index

    cfg = IndexConfig(build=BuildConfig(leaf_capacity=128),
                      search=SearchConfig(k=k, **_SEARCH))
    data = random_walks(jax.random.PRNGKey(21), num, n)
    q = make_query_workload(jax.random.PRNGKey(22), data, nq, "5%")

    tmp = None
    path = load_path or save_path
    if path is None:
        tmp = tempfile.mkdtemp(prefix="bench_idx_")
        path = os.path.join(tmp, "idx")
    try:
        if load_path is None:
            t0 = _time.perf_counter()
            idx = HerculesIndex.build(data, cfg)
            dt = _time.perf_counter() - t0
            emit("build_oneshot", dt * 1e6, f"series_per_s={num / dt:.0f}",
                 series_per_second=round(num / dt, 1), num_series=num)

            src = ArrayChunkSource(np.asarray(data), chunk)
            t0 = _time.perf_counter()
            HerculesIndex.build_streaming(src, cfg)
            dt = _time.perf_counter() - t0
            emit("build_chunked", dt * 1e6,
                 f"series_per_s={num / dt:.0f};chunk={chunk}",
                 series_per_second=round(num / dt, 1), chunk_size=chunk,
                 num_series=num)

            t0 = _time.perf_counter()
            save_index(idx, path)
            dt = _time.perf_counter() - t0
            size = sum(os.path.getsize(os.path.join(path, f))
                       for f in os.listdir(path))
            emit("save_index", dt * 1e6, f"mib={size / 2**20:.1f}",
                 bytes=size)

        t0 = _time.perf_counter()
        loaded = load_index(path)
        dt = _time.perf_counter() - t0
        emit("load_index", dt * 1e6,
             f"series={loaded.layout.num_series}", load_seconds=round(dt, 4))

        eng = QueryEngine(LocalBackend(loaded))
        res = eng.knn(q, k=k)
        _check_exact(res.dists, data, q, k)
        t = time_call(lambda: eng.knn(q, k=k))
        emit("backend_local_loaded", t / nq, "from_disk=1")

        # the streamed backends under both read schedulers: sync (reads
        # block the consumer) vs thread (async reader + two-slot buffer).
        # read_wait_seconds/overlap_blocks quantify the recovered overlap;
        # answers are asserted identical across modes.
        import dataclasses as _dc

        scfg = SearchConfig(k=k, **{**_SEARCH, "scan_block": 512})
        prev = {}
        for mode in ("sync", "thread"):
            ooc = make_disk_backend(
                "ooc-scan", path, search=_dc.replace(scfg, prefetch=mode),
                memory_budget_mb=memory_budget_mb)
            r_ooc = ooc.knn(q, k=k)
            _check_exact(r_ooc.dists, data, q, k)
            if prev:
                assert np.array_equal(np.asarray(prev["dists"]),
                                      np.asarray(r_ooc.dists)), \
                    "prefetch modes disagree"
            prev = {"dists": r_ooc.dists}
            t = time_call(lambda: ooc.knn(q, k=k))
            st = ooc.stats()
            emit(f"backend_ooc_scan_prefetch_{mode}", t / nq,
                 f"budget_mb={memory_budget_mb};blocks={st['blocks']}"
                 f";read_wait_s={st['read_wait_seconds']:.4f}"
                 f";overlap_blocks={st['overlap_blocks']}",
                 memory_budget_mb=memory_budget_mb, prefetch=mode,
                 read_wait_seconds=round(st["read_wait_seconds"], 4),
                 overlap_blocks=int(st["overlap_blocks"]))

        prev = {}
        for mode in ("sync", "thread"):
            oloc = make_disk_backend(
                "ooc-local", path,
                search=_dc.replace(cfg.search, k=k, prefetch=mode),
                memory_budget_mb=memory_budget_mb)
            r_loc = oloc.knn(q, k=k)
            _check_exact(r_loc.dists, data, q, k)
            if prev:
                assert np.array_equal(np.asarray(prev["dists"]),
                                      np.asarray(r_loc.dists)), \
                    "prefetch modes disagree"
            prev = {"dists": r_loc.dists}
            t = time_call(lambda: oloc.knn(q, k=k))
            st = oloc.stats()
            emit(f"backend_ooc_local_prefetch_{mode}", t / nq,
                 f"budget_mb={memory_budget_mb}"
                 f";read_wait_s={st['read_wait_seconds']:.4f}"
                 f";overlap_blocks={st['overlap_blocks']}"
                 f";sax_pr={float(np.mean(np.asarray(r_loc.sax_pr))):.3f}",
                 memory_budget_mb=memory_budget_mb, prefetch=mode,
                 read_wait_seconds=round(st["read_wait_seconds"], 4),
                 overlap_blocks=int(st["overlap_blocks"]))

        # sharded out-of-core serving: the same saved index through a
        # dist-ooc mesh, one reader per shard. Rows only for shard counts
        # the visible device world can host — force more with
        # XLA_FLAGS=--xla_force_host_platform_device_count=N. Answers are
        # asserted exact and identical across shard counts; rows_streamed
        # is per shard, so the imbalance column is the plan quality.
        import warnings as _warnings

        n_dev = len(jax.devices())
        dist_ref = None
        for shards in (1, 2, 4, 8):
            if shards > n_dev:
                print(f"# dist_ooc_shards_{shards}: skipped "
                      f"({n_dev} visible device(s); force 8 with XLA_FLAGS="
                      f"--xla_force_host_platform_device_count=8)")
                continue
            with _warnings.catch_warnings():
                _warnings.simplefilter("ignore", RuntimeWarning)
                dooc = make_disk_backend(
                    "dist-ooc", path,
                    search=_dc.replace(cfg.search, k=k, prefetch="thread"),
                    memory_budget_mb=memory_budget_mb, shards=shards)
            r_d = dooc.knn(q, k=k)
            _check_exact(r_d.dists, data, q, k)
            if dist_ref is not None:
                assert np.array_equal(np.asarray(dist_ref),
                                      np.asarray(r_d.dists)), \
                    "shard counts disagree"
            dist_ref = r_d.dists
            ds = dict(dooc.stats()["dist"])  # one call's streaming traffic
            t = time_call(lambda: dooc.knn(q, k=k))
            emit(f"dist_ooc_shards_{shards}", t / nq,
                 f"rows_streamed={sum(ds['rows_streamed'])}"
                 f";imbalance={ds['imbalance']:.2f}"
                 f";read_wait_s={sum(ds['read_wait_seconds']):.4f}",
                 shards=shards,
                 rows_streamed=[int(r) for r in ds["rows_streamed"]],
                 imbalance=round(float(ds["imbalance"]), 4),
                 plan_imbalance=round(float(ds["plan_imbalance"]), 4),
                 read_wait_seconds=round(sum(ds["read_wait_seconds"]), 4))

        # format v3 leaf codecs: one store per codec over the same
        # collection, streamed through ooc-scan. ``bytes_streamed`` is the
        # bandwidth the codec buys (encoded stream + float32 re-check of
        # the candidate pool); answers are asserted exact under every
        # codec, so the column is a pure cost, not a quality trade.
        from repro.storage import Hercules
        from repro.storage.codecs import list_codecs

        codec_root = path + "_codecs"
        raw_bytes = None
        for cname in list_codecs():
            cpath = os.path.join(codec_root, cname.replace("-", "_"))
            if not os.path.exists(os.path.join(cpath, "manifest.json")):
                Hercules.create(cpath, cfg, data=np.asarray(data),
                                chunk_size=chunk, codec=cname,
                                overwrite=True).close()
            ooc = make_disk_backend("ooc-scan", cpath, search=scfg,
                                    memory_budget_mb=memory_budget_mb)
            r = ooc.knn(q, k=k)
            _check_exact(r.dists, data, q, k)
            per_call = dict(ooc.stats())  # one call's streaming traffic
            t = time_call(lambda: ooc.knn(q, k=k))
            if cname == "raw":
                raw_bytes = per_call["bytes_streamed"]
            ratio = per_call["bytes_streamed"] / max(raw_bytes, 1)
            rows_per_s = per_call["rows_streamed"] / (t / 1e6)
            emit(f"codec_{cname.replace('-', '_')}_ooc_scan", t / nq,
                 f"bytes={per_call['bytes_streamed']}"
                 f";bytes_vs_raw={ratio:.3f}"
                 f";series_per_s={rows_per_s:.0f}"
                 f";fallbacks={per_call['codec_fallbacks']}",
                 codec=cname,
                 bytes_streamed=int(per_call["bytes_streamed"]),
                 bytes_vs_raw=round(ratio, 4),
                 series_per_second=round(rows_per_s, 1),
                 codec_refine_rows=int(per_call["codec_refine_rows"]),
                 codec_fallbacks=int(per_call["codec_fallbacks"]))
        if tmp is not None:
            shutil.rmtree(codec_root, ignore_errors=True)

        if load_path is None:
            # incremental ingest: append a journal segment (no base rewrite)
            # then compact it into the next base generation — the insert-
            # workload trajectory rows (series/sec for each half)
            from repro.storage import Hercules

            n_extra = max(num // 4, 1)
            extra = random_walks(jax.random.PRNGKey(23), n_extra, n)
            with Hercules.open(path, "a") as hx:
                t0 = _time.perf_counter()
                hx.append(np.asarray(extra), chunk_size=chunk)
                dt = _time.perf_counter() - t0
                emit("append_journal", dt * 1e6,
                     f"series_per_s={n_extra / dt:.0f};rows={n_extra}",
                     series_per_second=round(n_extra / dt, 1),
                     rows_appended=n_extra)

                t0 = _time.perf_counter()
                hx.compact(chunk_size=chunk)
                dt = _time.perf_counter() - t0
                total = hx.num_series
                emit("compact_journal", dt * 1e6,
                     f"series_per_s={total / dt:.0f};generation="
                     f"{hx.generation}",
                     series_per_second=round(total / dt, 1),
                     rows_total=total)

                data_all = jnp.concatenate([jnp.asarray(data), extra])
                res = hx.engine("local").knn(q, k=k)
                _check_exact(res.dists, data_all, q, k)
    finally:
        if tmp is not None:
            shutil.rmtree(tmp, ignore_errors=True)


# --------------------------------------------------------------------------
# kernel microbenches: ref (jnp oracle) vs Pallas kernel, per op
# --------------------------------------------------------------------------

def bench_kernels(num=32768, n=128, nq=64, kernel_mode="auto"):
    """Per-op ref-vs-kernel comparison for every kernel the engine routes to.

    Each op emits a ``_ref`` row (jit'd jnp oracle), a ``_kernel`` row run in
    the resolved ``kernel_mode``, and ``speedup_vs_ref`` in the derived field
    and the JSON row — the perf-trajectory record of the kernelization win.
    Under ``auto`` off-TPU the kernel row *is* the ref dispatch (speedup
    ~1.0 by construction); on TPU it is the compiled Mosaic kernel. Answers
    are asserted close before timing.
    """
    from repro.core import pscan_knn
    from repro.core import summaries as S
    from repro.kernels import ops, ref
    from repro.kernels.compat import resolve_kernel_mode

    mode = resolve_kernel_mode(kernel_mode)
    data = random_walks(jax.random.PRNGKey(10), num, n)
    q = data[:nq] + 0.01
    codes = S.isax(data, 16)
    q_paa = S.paa(q, 16)

    b, t_len, h, dk = 4, 256, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(13), 6)
    wr = jax.random.normal(ks[0], (b, t_len, h, dk))
    wk = jax.random.normal(ks[1], (b, t_len, h, dk))
    wv = jax.random.normal(ks[2], (b, t_len, h, dk))
    ww = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t_len, h, dk)))
    wu = jax.random.normal(ks[4], (h, dk))
    ws = jnp.zeros((b, h, dk, dk))

    # both sides jit'd: the comparison is XLA-oracle vs kernel dispatch,
    # not eager-python overhead
    ed_matrix_k = jax.jit(functools.partial(ops.ed_matrix, mode=mode))
    ed_min_k = jax.jit(functools.partial(ops.ed_min, mode=mode))
    lb_sax_k = jax.jit(functools.partial(ops.lb_sax, mode=mode),
                       static_argnums=(2,))
    wkv6_k = jax.jit(functools.partial(ops.wkv6, mode=mode))
    ops_table = {
        "ed_matrix": (jax.jit(ref.ed_matrix_ref),
                      lambda: ed_matrix_k(q, data), (q, data)),
        "ed_min": (jax.jit(ref.ed_min_ref),
                   lambda: ed_min_k(q, data), (q, data)),
        "lb_sax": (jax.jit(functools.partial(ref.lb_sax_matrix_ref,
                                             series_len=n)),
                   lambda: lb_sax_k(q_paa, codes, n), (q_paa, codes)),
        "wkv6": (jax.jit(ref.wkv6_ref),
                 lambda: wkv6_k(wr, wk, wv, ww, wu, ws),
                 (wr, wk, wv, ww, wu, ws)),
    }
    for op, (ref_fn, kern_fn, args) in ops_table.items():
        want = ref_fn(*args)
        got = kern_fn()
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(got)[0], np.float32),
            np.asarray(jax.tree.leaves(want)[0], np.float32),
            rtol=1e-3, atol=1e-3)
        t_ref = time_call(lambda: ref_fn(*args))
        t_kern = time_call(kern_fn)
        speedup = t_ref / max(t_kern, 1e-9)
        emit(f"kern_{op}_ref", t_ref, "")
        emit(f"kern_{op}_kernel", t_kern,
             f"mode={mode};speedup_vs_ref={speedup:.2f}x",
             op=op, kernel_mode=mode, speedup_vs_ref=round(speedup, 3))

    t = time_call(lambda: pscan_knn(data, q, k=1))
    flops = 3.0 * nq * num * n
    emit("kern_pscan_ed_scan", t, f"GFLOPs={flops / t / 1e3:.2f}")

    t = time_call(lambda: _build(data), warmup=0, iters=1)
    emit("kern_index_build", t, f"Mseries/s={num / t:.3f}")


# --------------------------------------------------------------------------
# approximate answering (paper §5 future work): recall/time vs l_max
# --------------------------------------------------------------------------

def bench_approx(num=16384, n=128, nq=16):
    data = random_walks(jax.random.PRNGKey(12), num, n)
    idx = _build(data)
    q = make_query_workload(jax.random.PRNGKey(13), data, nq, "5%")
    bf_d, bf_i = brute_force_knn(data, q, 10)
    for l_max in (1, 4, 16):
        d, ids = idx.knn_approx(q, k=10, l_max=l_max)
        t = time_call(lambda: idx.knn_approx(q, k=10, l_max=l_max))
        recall = float(np.mean([
            len(set(np.asarray(ids)[i]) & set(np.asarray(bf_i)[i])) / 10
            for i in range(nq)]))
        emit(f"approx_lmax{l_max}", t / nq, f"recall@10={recall:.3f}")


# --------------------------------------------------------------------------
# PR 7: wave-fused multi-query serving vs independent per-query serving
# --------------------------------------------------------------------------

def bench_wave(num=8192, n=128, nq=16, k=3, memory_budget_mb=2.0):
    """Clustered wave workload through the streamed ooc-local backend:
    the wave path must dedup the merged leaf-run schedule (fetch each run
    once for every interested member) and therefore stream strictly fewer
    rows than serving the same queries independently — with bit-identical
    answers. Also rows the in-memory fused wave plan vs a per-query loop.
    """
    import os
    import shutil
    import tempfile
    import time as _time

    from repro.core import make_disk_backend
    from repro.storage import save_index

    cfg = IndexConfig(build=BuildConfig(leaf_capacity=128),
                      search=SearchConfig(k=k, **_SEARCH))
    data = random_walks(jax.random.PRNGKey(31), num, n)
    # clustered wave: queries perturbed from nearby dataset rows, so the
    # members' alive-run lists overlap and there is real work to share
    rows = np.asarray(data)[200:200 + nq]
    noise = 0.01 * np.asarray(
        jax.random.normal(jax.random.PRNGKey(32), rows.shape))
    q = jnp.asarray(rows + noise)

    idx = HerculesIndex.build(data, cfg)
    eng = QueryEngine(LocalBackend(idx))
    solo_d = np.concatenate(
        [np.asarray(eng.knn(qi[None]).dists) for qi in np.asarray(q)])
    t_solo = time_call(
        lambda: [eng.knn(qi[None]) for qi in np.asarray(q)])
    wave_d = np.asarray(eng.knn(q, wave=True).dists)
    if not np.array_equal(wave_d, solo_d):
        raise AssertionError("wave answers diverged from per-query answers")
    t_wave = time_call(lambda: eng.knn(q, wave=True))
    emit("wave_local_independent", t_solo / nq, "us/query")
    emit("wave_local_fused", t_wave / nq,
         f"speedup_vs_independent={t_solo / max(t_wave, 1e-9):.2f}x",
         speedup_vs_independent=round(t_solo / max(t_wave, 1e-9), 3))

    tmp = tempfile.mkdtemp(prefix="bench_wave_")
    try:
        path = os.path.join(tmp, "idx")
        save_index(idx, path)
        ooc = make_disk_backend("ooc-local", path, search=cfg.search,
                                memory_budget_mb=memory_budget_mb)
        oeng = QueryEngine(ooc)

        t0 = _time.perf_counter()
        solo_d = np.concatenate(
            [np.asarray(oeng.knn(qi[None]).dists) for qi in np.asarray(q)])
        t_solo = (_time.perf_counter() - t0) * 1e6
        rows_solo = oeng.stats()["rows_streamed"]

        t0 = _time.perf_counter()
        wave_d = np.asarray(oeng.knn(q, wave=True).dists)
        t_wave = (_time.perf_counter() - t0) * 1e6
        st = oeng.stats()
        rows_wave = st["rows_streamed"] - rows_solo
        if not np.array_equal(wave_d, solo_d):
            raise AssertionError("ooc wave answers diverged from per-query")
        if st["runs_deduped"] <= 0:
            raise AssertionError("clustered wave deduped no leaf runs")
        if rows_wave >= rows_solo:
            raise AssertionError(
                f"wave streamed {rows_wave} rows >= independent {rows_solo}")
        emit("wave_ooc_independent", t_solo / nq, f"rows={rows_solo}",
             rows_streamed=int(rows_solo))
        emit("wave_ooc_fused", t_wave / nq,
             f"rows={rows_wave};deduped={st['runs_deduped']};"
             f"shared={st['wave_rows_shared']}",
             rows_streamed=int(rows_wave),
             rows_streamed_independent=int(rows_solo),
             runs_deduped=int(st["runs_deduped"]),
             wave_rows_shared=int(st["wave_rows_shared"]),
             runs_skipped_bsf=int(st["runs_skipped_bsf"]))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
