"""Hillclimb profiler: list the largest collective ops in a compiled cell.

    PYTHONPATH=src python -m benchmarks.inspect_collectives \
        --arch llama3-405b --shape train_4k [--multi]

(Runs in its own process: sets the 512-device XLA flag before importing jax.)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import re


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch.dryrun import _compile_cell
    from repro.launch.hlo_analysis import _SHAPE_RE, _DTYPE_BYTES
    from repro.launch.mesh import make_production_mesh
    from repro.distributed.sharding import install_activation_hook
    from repro.models import SHAPES

    cfg = get_config(args.arch)
    mesh = make_production_mesh(multi_pod=args.multi)
    install_activation_hook(mesh)
    compiled, _ = _compile_cell(cfg, args.arch, SHAPES[args.shape], mesh)

    ops = []
    for line in compiled.as_text().splitlines():
        m = re.match(r"\s*%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        name, shape_str, op = m.groups()
        if not any(op.startswith(k) for k in
                   ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")):
            continue
        nbytes = 0
        for dtype, dims in _SHAPE_RE.findall(shape_str):
            if dtype in _DTYPE_BYTES:
                n = 1
                for d in (dims.split(",") if dims else []):
                    n *= int(d)
                nbytes += n * _DTYPE_BYTES[dtype]
        meta = re.search(r'op_name="([^"]+)"', line)
        ops.append((nbytes, op, shape_str[:60],
                    (meta.group(1)[-80:] if meta else "")))
    ops.sort(reverse=True)
    print(f"top {args.top} collectives (per-device result bytes, one HLO "
          f"occurrence each — scan bodies execute x trip_count):")
    for nbytes, op, shape_str, src in ops[: args.top]:
        print(f"{nbytes / 2**20:10.1f} MiB  {op:20s} {shape_str:60s} {src}")


if __name__ == "__main__":
    main()
