"""Roofline report generator: reads artifacts/dryrun/*.json -> markdown table.

Used to produce EXPERIMENTS.md §Roofline; also callable standalone:
    PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

_DEF_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_records(art_dir: str = _DEF_DIR) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_table(recs: list[dict], mesh: str = "16x16") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | dominant | "
            "MODEL_FLOPS | useful ratio | state GiB/chip |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"skipped | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"ERROR | — | — | — |")
            continue
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4g} | "
            f"{rf['memory_s']:.4g} | {rf['collective_s']:.4g} | "
            f"{rf['dominant']} | {r['model_flops']:.3g} | "
            f"{r['useful_flops_ratio']:.3f} | "
            f"{r['state_bytes_per_chip'] / 2**30:.2f} |")
    return "\n".join(rows)


def summarize(recs: list[dict]) -> dict:
    ok = [r for r in recs if r.get("status") == "ok"]
    sk = [r for r in recs if r.get("status") == "skipped"]
    err = [r for r in recs if r.get("status") == "error"]
    return {"ok": len(ok), "skipped": len(sk), "errors": len(err)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=_DEF_DIR)
    args = ap.parse_args(argv)
    recs = load_records(args.dir)
    print(summarize(recs))
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### mesh {mesh}\n")
        print(fmt_table(recs, mesh))


if __name__ == "__main__":
    main()
