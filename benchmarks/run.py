"""Benchmark entry point: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--backend NAME]
[--kernel-mode MODE] [--json PATH]``
Prints ``name,us_per_call,derived`` CSV (benchmarks verify exactness of every
answer against brute force before timing).

``--backend`` selects a single backend by name (local | scan | scan-mxu |
flat-sax | sharded | all) and runs only the unified-surface backend
comparison for it; without the flag the full figure suite runs.

``--kernel-mode`` (auto | pallas | interpret | ref) selects the Pallas
dispatch for the benched SearchConfigs — ``--backend scan --kernel-mode
interpret`` is the CI smoke that streams the scan through the kernel bodies.

``--save-index DIR`` / ``--load-index DIR`` bench the persistence path
(build-throughput series/sec rows, save/load latency, out-of-core scan)
against a pinned index directory — ``--load-index`` serves a pre-built
index without rebuilding. Without either flag the persistence rows still
run (in a temp dir) as part of the suite.

``--json`` additionally writes every emitted row (including the per-op
``speedup_vs_ref`` fields from ``bench_kernels`` and the ``series_per_second``
ingest fields from ``bench_persistence``) as structured JSON.
"""
from __future__ import annotations

import argparse

from benchmarks import bench_suite as B
from benchmarks.common import write_json

_BACKEND_CHOICES = ("local", "scan", "scan-mxu", "flat-sax", "sharded", "all")
_MODE_CHOICES = ("auto", "pallas", "interpret", "ref")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes (CI-friendly)")
    ap.add_argument("--backend", choices=_BACKEND_CHOICES, default=None,
                    help="run only the backend comparison, for this backend "
                         "('all' = every backend) through the QueryEngine")
    ap.add_argument("--kernel-mode", choices=_MODE_CHOICES, default="auto",
                    help="Pallas kernel dispatch for the benched configs")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write all emitted rows as JSON")
    ap.add_argument("--wave-bench", action="store_true",
                    help="run only the wave-fused multi-query comparison")
    ap.add_argument("--save-index", default=None, metavar="DIR",
                    help="persistence bench: build + save the index here")
    ap.add_argument("--load-index", default=None, metavar="DIR",
                    help="persistence bench: serve this pre-built index "
                         "(skips building)")
    args = ap.parse_args(argv)

    persist_kw = dict(save_path=args.save_index, load_path=args.load_index)
    print("name,us_per_call,derived")
    if args.wave_bench:
        size = dict(num=4096, n=64, nq=8) if args.quick else {}
        B.bench_wave(**size)
    elif args.save_index or args.load_index:
        size = dict(num=4096, n=64, nq=4, chunk=1024) if args.quick else {}
        B.bench_persistence(**size, **persist_kw)
    elif args.backend:
        names = (("local", "scan", "scan-mxu", "flat-sax")
                 if args.backend == "all" else (args.backend,))
        size = dict(num=4096, nq=8) if args.quick else {}
        B.bench_backends(backends=names, kernel_mode=args.kernel_mode, **size)
    elif args.quick:
        B.bench_scalability_size(sizes=(2048, 8192), nq=8)
        B.bench_series_length(lengths=(64, 128), num=4096, nq=4)
        B.bench_difficulty(num=8192, nq=8)
        B.bench_k(num=8192, nq=4, ks=(1, 10))
        B.bench_ablation(num=8192, nq=8)
        B.bench_approx(num=8192, nq=8)
        B.bench_backends(num=4096, nq=8, kernel_mode=args.kernel_mode)
        B.bench_kernels(num=16384, nq=32, kernel_mode=args.kernel_mode)
        B.bench_persistence(num=4096, n=64, nq=4, chunk=1024)
        B.bench_wave(num=4096, n=64, nq=8)
    else:
        B.bench_scalability_size()
        B.bench_series_length()
        B.bench_difficulty()
        B.bench_k()
        B.bench_ablation()
        B.bench_approx()
        B.bench_backends(kernel_mode=args.kernel_mode)
        B.bench_kernels(kernel_mode=args.kernel_mode)
        B.bench_persistence()
        B.bench_wave()
    if args.json:
        write_json(args.json)


if __name__ == "__main__":
    main()
