"""Competitor baselines from the paper's evaluation (§4.1 Algorithms).

* PSCAN       — core/search.py::pscan_knn (optimized parallel scan).
* DSTree*     — Hercules with SAX filtering disabled (EAPCA tree + LB_EAPCA
                pruning + refinement), the paper's "NoSAX"-equivalent of a
                DSTree-style index. Same exact results.
* ParIS+/VA+file-like — a flat quantization-filter index: LB_SAX (iSAX 16x256
                summaries, the ParIS+ filter; swap in DFT for VA+file) over
                the whole collection, then chunked skip-sequential
                refinement ordered by lower bound. No clustering tree, which
                is exactly the structural difference the paper credits for
                Hercules's win on hard workloads.

All baselines return exact kNN (the paper's ground rule). ``FlatSaxBackend``
adapts the ParIS+-like scheme to the :class:`repro.core.engine.SearchBackend`
protocol so benchmarks drive every competitor through the same QueryEngine
surface.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import lower_bounds as LB
from repro.core import summaries as S
from repro.core.engine import BackendBase
from repro.core.search import INF, KnnResult, SearchConfig, _merge_topk


@functools.partial(jax.jit, static_argnames=("k", "chunk"))
def flat_sax_knn(data: jax.Array, codes: jax.Array, queries: jax.Array,
                 k: int = 1, chunk: int = 1024):
    """ParIS+-style skip-sequential: LB_SAX filter + BSF-pruned refinement."""
    n, dim = data.shape
    n_pad = -(-n // chunk) * chunk
    if n_pad != n:
        data = jnp.concatenate(
            [data, jnp.zeros((n_pad - n, dim), data.dtype)], axis=0)
        codes = jnp.concatenate(
            [codes, jnp.zeros((n_pad - n, codes.shape[1]), codes.dtype)], axis=0)

    def one(q):
        q_paa = S.paa(q[None], codes.shape[1])[0]
        lb = LB.lb_sax(q_paa, codes, dim)
        lb = jnp.where(jnp.arange(n_pad) < n, lb, INF)
        order = jnp.argsort(lb).astype(jnp.int32)
        sorted_lb = lb[order]
        n_chunks = n_pad // chunk

        def cond(st):
            c, d_top, p_top, acc = st
            return (c < n_chunks) & (sorted_lb[c * chunk] < d_top[k - 1])

        def body(st):
            c, d_top, p_top, acc = st
            idx = jax.lax.dynamic_slice(order, (c * chunk,), (chunk,))
            lbs = jax.lax.dynamic_slice(sorted_lb, (c * chunk,), (chunk,))
            d = jnp.sum(jnp.square(data[idx] - q[None]), axis=1)
            live = lbs < d_top[k - 1]
            d = jnp.where(live, d, INF)
            d_top, p_top = _merge_topk(d_top, p_top, d, idx, k)
            return (c + 1, d_top, p_top, acc + jnp.sum(live.astype(jnp.int32)))

        d0 = jnp.full((k,), INF)
        p0 = jnp.full((k,), -1, jnp.int32)
        _, d_top, p_top, acc = jax.lax.while_loop(
            cond, body, (jnp.int32(0), d0, p0, jnp.int32(0)))
        return d_top, p_top, acc

    return jax.lax.map(one, queries)


class FlatSaxBackend(BackendBase):
    """ParIS+/VA+file-like flat filter index as a SearchBackend: the iSAX
    summary table is the only index structure (no clustering tree)."""

    name = "flat-sax"

    def __init__(self, data: jax.Array, config: SearchConfig | None = None,
                 sax_segments: int = S.NUM_SAX_SEGMENTS):
        self.data = jnp.asarray(data)
        self.codes = S.isax(self.data, sax_segments)
        self._config = config or SearchConfig()

    @property
    def series_len(self) -> int:
        return int(self.data.shape[1])

    @property
    def base_config(self) -> SearchConfig:
        return self._config

    def _result(self, d, p, acc) -> KnnResult:
        return self._fill_result(d, p, p, accessed=acc)  # identity layout

    def _bind(self, cfg):
        return lambda q: self._result(
            *flat_sax_knn(self.data, self.codes, q, cfg.k, cfg.chunk))

    def make_plan(self, cfg, q_struct):
        compiled = flat_sax_knn.lower(
            self.data, self.codes, q_struct, cfg.k, cfg.chunk).compile()
        return lambda q: self._result(*compiled(self.data, self.codes, q))

    def stats(self) -> dict:
        return {"num_series": int(self.data.shape[0]),
                "series_len": int(self.data.shape[1]),
                "sax_segments": int(self.codes.shape[1])}
