"""End-to-end driver (the paper's kind: a query-serving system).

Builds a disk-persisted Hercules index over a large synthetic collection and
serves batched kNN query workloads of every difficulty level through the
unified ``repro.api`` surface — a :class:`KnnServeEngine` (slot-based
continuous batching) over a :class:`QueryEngine` (compiled-plan cache) over a
:class:`LocalBackend` — reporting latency, access-path selection, pruning and
plan-cache behaviour, then validates exactness against the dense-scan
backend through the very same surface.

    PYTHONPATH=src python examples/serve_index.py [--num-series 100000]
"""
import argparse
import os
import tempfile
import time

import jax
import numpy as np

from repro import api
from repro.data import DIFFICULTY_LEVELS, make_query_workload, random_walks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--num-series", type=int, default=100_000)
    ap.add_argument("--length", type=int, default=128)
    ap.add_argument("--queries", type=int, default=20)
    args = ap.parse_args()

    print(f"=== index construction: {args.num_series} x {args.length} ===")
    data = random_walks(jax.random.PRNGKey(0), args.num_series, args.length)
    t0 = time.time()
    # geometry per EXPERIMENTS.md §Perf iteration 2: small leaves + few
    # phase-1 visits suit memory-resident collections
    idx = api.HerculesIndex.build(data, api.IndexConfig(
        build=api.BuildConfig(leaf_capacity=256),
        search=api.SearchConfig(k=1, l_max=8)))
    print(f"built in {time.time() - t0:.1f}s  {idx.stats()}")

    # persist + reload (the HTree/LRDFile/LSDFile artifact, checkpoint story)
    path = os.path.join(tempfile.gettempdir(), "hercules_demo.npz")
    idx.save(path)
    idx = api.HerculesIndex.load(path)
    print(f"persisted + reloaded {os.path.getsize(path) / 2**20:.1f} MiB")

    engine = api.QueryEngine(api.LocalBackend(idx))

    print("\n=== query answering stage (slot-based serving) ===")
    serve = api.KnnServeEngine(engine,
                               api.KnnServeConfig(batch_slots=args.queries))
    for diff in DIFFICULTY_LEVELS:
        q = np.asarray(make_query_workload(
            jax.random.PRNGKey(1), data, args.queries, diff))
        for qi in q:                           # warm (compile once per bucket)
            serve.submit(qi)
        serve.drain()
        rids = [serve.submit(qi) for qi in q]
        t0 = time.time()
        answers = serve.drain()
        dt = (time.time() - t0) / args.queries
        paths = np.bincount(
            [max(answers[r].path, 0) for r in rids], minlength=4)
        tele = serve.telemetry()
        print(f"[{diff:>4}] {dt * 1e3:7.1f} ms/query  "
              f"paths scan/pruned = {paths[0] + paths[1]}/{paths[2]}  "
              f"plan cache {tele['plan_cache']['hits']}h/"
              f"{tele['plan_cache']['misses']}m")
    print(f"mean pruning: eapca={tele['pruning']['eapca_mean']:.3f} "
          f"sax={tele['pruning']['sax_mean']:.3f}")

    print("\n=== exactness + speedup vs dense scan — same surface ===")
    q = make_query_workload(jax.random.PRNGKey(2), data, args.queries, "ood")
    scan = api.QueryEngine(api.ScanBackend(data, api.SearchConfig(k=1),
                                           mxu=True))
    d_idx = engine.knn(q).dists                # warm
    t0 = time.time(); d_idx = engine.knn(q).dists; t_idx = time.time() - t0
    d_scan = scan.knn(q).dists                 # warm
    t0 = time.time(); d_scan = scan.knn(q).dists; t_scan = time.time() - t0
    assert np.allclose(np.asarray(d_idx), np.asarray(d_scan),
                       rtol=1e-3, atol=1e-3)
    print(f"exact ✓   hercules {t_idx:.2f}s vs pscan {t_scan:.2f}s "
          f"({t_scan / max(t_idx, 1e-9):.1f}x)")


if __name__ == "__main__":
    main()
