"""End-to-end driver (the paper's kind: a query-serving system).

Builds a disk-persisted Hercules index over a large synthetic collection and
serves batched kNN query workloads of every difficulty level, reporting
latency, access-path selection and pruning — then validates exactness
against the optimized parallel scan (PSCAN).

    PYTHONPATH=src python examples/serve_index.py [--num-series 100000]
"""
import argparse
import os
import tempfile
import time

import jax
import numpy as np

from repro.core import (BuildConfig, HerculesIndex, IndexConfig, SearchConfig,
                        pscan_knn)
from repro.data import DIFFICULTY_LEVELS, make_query_workload, random_walks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--num-series", type=int, default=100_000)
    ap.add_argument("--length", type=int, default=128)
    ap.add_argument("--queries", type=int, default=20)
    args = ap.parse_args()

    print(f"=== index construction: {args.num_series} x {args.length} ===")
    data = random_walks(jax.random.PRNGKey(0), args.num_series, args.length)
    t0 = time.time()
    # geometry per EXPERIMENTS.md §Perf iteration 2: small leaves + few
    # phase-1 visits suit memory-resident collections
    idx = HerculesIndex.build(data, IndexConfig(
        build=BuildConfig(leaf_capacity=256),
        search=SearchConfig(k=1, l_max=8)))
    print(f"built in {time.time() - t0:.1f}s  {idx.stats()}")

    # persist + reload (the HTree/LRDFile/LSDFile artifact, checkpoint story)
    path = os.path.join(tempfile.gettempdir(), "hercules_demo.npz")
    idx.save(path)
    idx = HerculesIndex.load(path)
    print(f"persisted + reloaded {os.path.getsize(path) / 2**20:.1f} MiB")

    print("\n=== query answering stage ===")
    for diff in DIFFICULTY_LEVELS:
        q = make_query_workload(jax.random.PRNGKey(1), data, args.queries, diff)
        res = idx.knn(q)                       # warm (compile once)
        jax.block_until_ready(res.dists)
        t0 = time.time()
        res = idx.knn(q)
        jax.block_until_ready(res.dists)
        dt = (time.time() - t0) / args.queries
        paths = np.bincount(np.asarray(res.path), minlength=4)
        print(f"[{diff:>4}] {dt * 1e3:7.1f} ms/query  "
              f"accessed {float(res.accessed.mean()) / args.num_series:6.2%}  "
              f"paths scan/pruned = {paths[0] + paths[1]}/{paths[2]}")

    print("\n=== exactness + speedup vs optimized scan (hard workload) ===")
    q = make_query_workload(jax.random.PRNGKey(2), data, args.queries, "ood")
    d_idx = idx.knn(q).dists
    t0 = time.time(); d_idx = idx.knn(q).dists; jax.block_until_ready(d_idx)
    t_idx = time.time() - t0
    d_scan, _ = pscan_knn(data, q, k=1)
    t0 = time.time(); d_scan, _ = pscan_knn(data, q, k=1); jax.block_until_ready(d_scan)
    t_scan = time.time() - t0
    assert np.allclose(np.asarray(d_idx), np.asarray(d_scan), rtol=1e-3, atol=1e-3)
    print(f"exact ✓   hercules {t_idx:.2f}s vs pscan {t_scan:.2f}s "
          f"({t_scan / max(t_idx, 1e-9):.1f}x)")


if __name__ == "__main__":
    main()
