"""Quickstart: build a Hercules index and answer exact kNN queries.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import (BuildConfig, HerculesIndex, IndexConfig, SearchConfig,
                        brute_force_knn)
from repro.data import make_query_workload, random_walks

# 1. a collection of 20k z-normalized random-walk series (the paper's Synth)
data = random_walks(jax.random.PRNGKey(0), 20_000, 128)

# 2. build the index: EAPCA tree + leaf-ordered LRD layout + iSAX sidecar
idx = HerculesIndex.build(data, IndexConfig(
    build=BuildConfig(leaf_capacity=256),
    search=SearchConfig(k=5, l_max=16)))
print("tree:", idx.stats())

# 3. a workload of medium-hard queries (dataset series + 5% gaussian noise)
queries = make_query_workload(jax.random.PRNGKey(1), data, 10, "5%")

# 4. exact 5-NN
res = idx.knn(queries)
print("\nper-query pruning (1.0 = everything pruned):")
print("  EAPCA:", np.round(np.asarray(res.eapca_pr), 3))
print("  SAX:  ", np.round(np.asarray(res.sax_pr), 3))
print("data accessed:", f"{float(res.accessed.mean()) / 20_000:.2%}")

# 5. the paper's ground rule: answers are exact
bf_d, _ = brute_force_knn(data, queries, 5)
assert np.allclose(np.asarray(res.dists), np.asarray(bf_d), rtol=1e-3, atol=1e-3)
print("\nexact answers verified against brute force — OK")
print("nearest ids for query 0:", np.asarray(res.ids)[0])
