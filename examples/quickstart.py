"""Quickstart: build a Hercules index and answer exact kNN queries through
the unified ``repro.api`` surface (QueryEngine over a backend).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro import api
from repro.data import make_query_workload, random_walks

# 1. a collection of 20k z-normalized random-walk series (the paper's Synth)
data = random_walks(jax.random.PRNGKey(0), 20_000, 128)

# 2. build the index backend: EAPCA tree + leaf-ordered LRD layout + iSAX
#    sidecar, wrapped in a QueryEngine (compiled-plan cache + telemetry)
backend = api.LocalBackend(api.HerculesIndex.build(data, api.IndexConfig(
    build=api.BuildConfig(leaf_capacity=256),
    search=api.SearchConfig(k=5, l_max=16))))
engine = api.QueryEngine(backend)
print("tree:", engine.stats())

# 3. a workload of medium-hard queries (dataset series + 5% gaussian noise)
queries = make_query_workload(jax.random.PRNGKey(1), data, 10, "5%")

# 4. exact 5-NN — per-call overrides (k, l_max, thresholds...) are free;
#    the engine compiles one plan per (config, batch bucket) and reuses it
res = engine.knn(queries)
print("\nper-query pruning (1.0 = everything pruned):")
print("  EAPCA:", np.round(np.asarray(res.eapca_pr), 3))
print("  SAX:  ", np.round(np.asarray(res.sax_pr), 3))
print("data accessed:", f"{float(res.accessed.mean()) / 20_000:.2%}")

# 5. the paper's ground rule: answers are exact — and every backend agrees.
#    The dense-scan backend answers the same workload bit-identically.
scan = api.QueryEngine(api.ScanBackend(data, api.SearchConfig(k=5)))
res_scan = scan.knn(queries)
assert np.array_equal(np.asarray(res.dists), np.asarray(res_scan.dists))
bf_d, _ = api.brute_force_knn(data, queries, 5)
assert np.allclose(np.asarray(res.dists), np.asarray(bf_d), rtol=1e-3, atol=1e-3)
print("\nexact answers verified against dense scan + brute force — OK")

# 6. repeated calls hit the compiled-plan cache (zero retraces)
engine.knn(queries)
print("plan cache:", engine.telemetry()["plan_cache"])
print("nearest ids for query 0:", np.asarray(res.ids)[0])
