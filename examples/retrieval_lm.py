"""Hercules as the retrieval layer for an LM (the paper's Deep-embeddings
scenario: §4.1 uses CNN embeddings; here they come from our own LM zoo).

1. train a tiny causal LM for a few steps (substrate demo),
2. embed a corpus of token sequences with its final hidden states,
3. build a Hercules index over the (z-normalized) embeddings,
4. answer exact nearest-neighbor queries for unseen prompts — and verify
   against brute force.

    PYTHONPATH=src python examples/retrieval_lm.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core import (BuildConfig, HerculesIndex, IndexConfig, SearchConfig,
                        brute_force_knn)
from repro.core.summaries import znormalize
from repro.models import get_model
from repro.models.transformer import embed_inputs, forward
from repro.train import AdamWConfig, TrainConfig, make_train_step
from repro.train.train_step import init_train_state

cfg = get_smoke("minicpm-2b")
model = get_model(cfg)
key = jax.random.PRNGKey(0)

# --- 1. a few training steps ------------------------------------------------
tcfg = TrainConfig(optimizer=AdamWConfig(learning_rate=1e-3, warmup_steps=5,
                                         total_steps=50, schedule="constant"))
params, opt = init_train_state(model, cfg, tcfg, key)
step = jax.jit(make_train_step(model, cfg, tcfg))
batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}
for i in range(20):
    params, opt, metrics = step(params, opt, batch)
print(f"trained 20 steps, loss {float(metrics['loss']):.3f}")


# --- 2. embed a corpus with mean-pooled final hidden states ------------------
@jax.jit
def embed(tokens):
    logits, _ = forward(params, {"tokens": tokens}, cfg)
    # cheap text embedding: logit-space mean pool (keeps the example tiny);
    # production would pool pre-head hidden states
    return jnp.mean(logits, axis=1)


corpus = jax.random.randint(jax.random.PRNGKey(1), (2048, 32), 0,
                            cfg.vocab_size)
vecs = znormalize(embed(corpus))
# Hercules needs length % 16 == 0 for the iSAX sidecar: vocab_size=256 ✓
print(f"corpus embedded: {vecs.shape}")

# --- 3. index the embedding space -------------------------------------------
idx = HerculesIndex.build(vecs, IndexConfig(
    build=BuildConfig(leaf_capacity=64),
    search=SearchConfig(k=3, l_max=8, chunk=256, scan_block=256)))
print("index:", idx.stats())

# --- 4. retrieve for unseen prompts ------------------------------------------
prompts = jax.random.randint(jax.random.PRNGKey(2), (5, 32), 0, cfg.vocab_size)
qvecs = znormalize(embed(prompts))
res = idx.knn(qvecs)
bf_d, bf_i = brute_force_knn(vecs, qvecs, 3)
assert np.allclose(np.asarray(res.dists), np.asarray(bf_d), rtol=1e-3, atol=1e-3)
print("retrieval exact ✓")
for i in range(3):
    print(f"prompt {i}: nearest corpus docs {np.asarray(res.ids)[i]} "
          f"(d² = {np.round(np.asarray(res.dists)[i], 2)})")
