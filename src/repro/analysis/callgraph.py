"""Project-wide call graph + per-function summaries (herculint v2).

v1's rules each re-analysed one function body at a time, so a view that
escaped through a helper return (``reader.get()`` sliced inside a private
method, ``device_put`` three frames later) linted clean. This module
gives the rules the missing interprocedural layer:

* a **call graph** over every function/method in the linted roots, with
  call edges resolved by bare name (same file first, project-wide when
  unambiguous — a *linter's* resolution, not a type checker's);
* a **summary** per function — ``returns_tainted`` (the return value may
  be an mmap-segment/slot view), ``returns_self_view`` (the return
  borrows memory owned by the receiver — the handle-derivation fact
  ``mmap-lifetime`` needs), ``cleanses_return`` (the return always owns
  its bytes, overriding name-based taint heuristics), and the
  ``acquires_locks`` / ``releases_locks`` sets the lockdep tooling and
  ``--graph`` JSON expose;
* a **telemetry index** — declared ``*Telemetry`` dataclass fields vs
  the string counter keys observed at bump/consume sites — backing the
  ``telemetry-contract`` rule;
* the **module import graph** the dead-code report walks (one graph for
  ``--graph``, ``--deadcode`` and the rules; they cannot drift).

Summaries are computed to a fixed point: a helper that returns another
helper's tainted return is itself returns-tainted, however deep the
chain. Resolution is deliberately conservative — a verdict is only
issued when every candidate definition agrees — so the summaries refine
the name heuristics in both directions without inventing findings.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.rules.common import (
    CLEANSING_CALLS, COPYING_CALLS, TaintTracker, VIEW_ATTRS, VIEW_METHODS,
    _subscript_is_view, call_name, dotted, last_attr, name_components,
    statements_in_order,
)

#: Bare names too common / too dynamic to resolve project-wide. Same-file
#: definitions still resolve (a file-local ``get`` is unambiguous enough).
_UNRESOLVABLE = {
    "get", "put", "close", "open", "load", "save", "run", "main", "check",
    "stats", "describe", "keys", "values", "items", "append", "update",
    "__init__", "__enter__", "__exit__", "__post_init__",
}

#: Project-wide resolution gives up beyond this many candidate defs.
_MAX_GLOBAL_CANDIDATES = 3

#: Name components that mark an attribute as a lock-like object.
_LOCK_COMPONENTS = {"lock", "mutex", "cond", "condition", "sem", "semaphore"}


@dataclasses.dataclass
class FunctionSummary:
    """What the rest of the project may assume about one function."""
    qualname: str                  # dotted scope path within the file
    path: str                      # repo-relative posix path
    name: str                      # bare name (resolution key)
    lineno: int
    end_lineno: int
    calls: Tuple[str, ...] = ()    # raw dotted names called in the body
    returns_tainted: bool = False
    returns_self_view: bool = False
    cleanses_return: bool = False
    acquires_locks: Tuple[str, ...] = ()
    releases_locks: Tuple[str, ...] = ()

    def to_json(self) -> dict:
        return {
            "qualname": self.qualname, "path": self.path,
            "line": self.lineno,
            "returns_tainted": self.returns_tainted,
            "returns_self_view": self.returns_self_view,
            "cleanses_return": self.cleanses_return,
            "acquires_locks": list(self.acquires_locks),
            "releases_locks": list(self.releases_locks),
        }


@dataclasses.dataclass
class TelemetryIndex:
    """Declared telemetry counter fields vs the keys actually plumbed.

    ``fed`` and ``consumed`` are deliberately separate sets: a bump site
    must justify itself against declarations/consumers, never against
    other bumps (else a typo'd counter bumped twice would validate
    itself).
    """
    #: field name -> (path, line) of its declaring ``*Telemetry`` dataclass
    declared: Dict[str, Tuple[str, int]] = dataclasses.field(
        default_factory=dict)
    #: keys *written*: counter-store bumps, ``_t``/``stats`` dict-literal
    #: inits, ``*Telemetry(...)`` ctor kwargs, telemetry()/stats()
    #: assembly dict literals
    fed: Set[str] = dataclasses.field(default_factory=set)
    #: keys *read*: counter-store loads, any string subscript read inside
    #: a ``telemetry()`` / ``stats()`` assembly method
    consumed: Set[str] = dataclasses.field(default_factory=set)
    #: deprecated-key aliases (``_ALIASES`` dict literals)
    aliases: Dict[str, str] = dataclasses.field(default_factory=dict)

    @property
    def observed(self) -> Set[str]:
        return self.fed | self.consumed


class SummaryIndex:
    """Queryable per-function summaries for the taint/derivation rules.

    The empty index (``SummaryIndex.empty()``) answers ``None`` to every
    verdict — running a rule against it reproduces v1's single-scope
    behaviour exactly, which is what the meta-tests pin.
    """

    def __init__(self, functions: Iterable[FunctionSummary],
                 telemetry: TelemetryIndex | None = None):
        self.functions: Dict[str, FunctionSummary] = {}
        self._by_name: Dict[str, List[FunctionSummary]] = {}
        for fn in functions:
            self.functions[f"{fn.path}::{fn.qualname}"] = fn
            self._by_name.setdefault(fn.name, []).append(fn)
        self.telemetry = telemetry or TelemetryIndex()

    @classmethod
    def empty(cls) -> "SummaryIndex":
        return cls(())

    # ---- resolution ----------------------------------------------------
    def candidates(self, bare: str, path: Optional[str]) -> List[FunctionSummary]:
        """Definitions a call of ``bare`` may reach: same file first;
        project-wide only when the name is specific and near-unique."""
        defs = self._by_name.get(bare, [])
        if not defs:
            return []
        local = [d for d in defs if d.path == path]
        if local:
            return local
        if bare in _UNRESOLVABLE or len(defs) > _MAX_GLOBAL_CANDIDATES:
            return []
        return defs

    def call_verdict(self, call: ast.Call, path: Optional[str]) -> Optional[str]:
        """``"tainted"`` / ``"cleanses"`` / ``None`` for a call expression,
        by unanimous vote of the resolved candidate definitions."""
        bare = last_attr(call_name(call))
        if bare is None:
            return None
        cands = self.candidates(bare, path)
        if not cands:
            return None
        if all(c.returns_tainted for c in cands):
            return "tainted"
        if all(c.cleanses_return for c in cands):
            return "cleanses"
        return None

    def returns_self_view(self, call: ast.Call, path: Optional[str]) -> bool:
        """True when every candidate for this call returns a view borrowing
        the receiver's memory (``mmap-lifetime`` derivation through
        helpers)."""
        bare = last_attr(call_name(call))
        if bare is None:
            return False
        cands = self.candidates(bare, path)
        return bool(cands) and all(c.returns_self_view for c in cands)


#: Sentinel: "build a single-file index from the source being linted".
AUTO = object()


# ---------------------------------------------------------------------------
# summary extraction
# ---------------------------------------------------------------------------

def _function_nodes(tree: ast.Module) -> Iterable[Tuple[str, ast.AST]]:
    def walk(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                yield qual, child
                yield from walk(child, qual)
            elif isinstance(child, ast.ClassDef):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                yield from walk(child, qual)
            else:
                yield from walk(child, prefix)

    yield from walk(tree, "")


def _lock_name(expr: ast.expr) -> Optional[str]:
    name = dotted(expr)
    if name and name_components(name.replace(".", "_")) & _LOCK_COMPONENTS:
        return name
    return None


def _collect_locks(fn: ast.AST) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
    acquires: List[str] = []
    releases: List[str] = []
    for stmt in statements_in_order(fn):
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                name = _lock_name(item.context_expr)
                if name:
                    acquires.append(name)
                    releases.append(name)    # with-block releases on exit
        for node in ast.walk(stmt):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute):
                recv = _lock_name(node.func.value)
                if recv is None:
                    continue
                if node.func.attr == "acquire":
                    acquires.append(recv)
                elif node.func.attr == "release":
                    releases.append(recv)
    dedup = lambda xs: tuple(dict.fromkeys(xs))  # noqa: E731
    return dedup(acquires), dedup(releases)


class _SelfBorrow:
    """Does an expression borrow memory owned by ``self``?

    The derivation facts ``mmap-lifetime`` keys on, restricted to the
    receiver: ``self.lrd``-style mapped attributes, ``self._mapped()``-style
    mapped methods, calls to other self-methods already summarised as
    self-view returners, and view-preserving wrappers of any of those.
    """

    def __init__(self, index: SummaryIndex, path: str):
        self._index = index
        self._path = path

    def borrows(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Attribute):
            if not self._rooted_at_self(node.value):
                return False
            return node.attr in VIEW_ATTRS or node.attr == "T" or \
                bool(name_components(node.attr) & {"lrd", "lsd", "enc",
                                                   "mmap", "view"})
        if isinstance(node, ast.Subscript):
            return self.borrows(node.value) and _subscript_is_view(node.slice)
        if isinstance(node, ast.Call):
            tail = last_attr(call_name(node))
            if tail in CLEANSING_CALLS or tail in COPYING_CALLS:
                return False
            if isinstance(node.func, ast.Attribute) and \
                    self._rooted_at_self(node.func.value):
                if tail in VIEW_METHODS:
                    return True
                if self._index.returns_self_view(node, self._path):
                    return True
            if tail in ("asarray", "ascontiguousarray") and node.args:
                mod = call_name(node) or ""
                if not mod.startswith(("jnp.", "jax.")):
                    return self.borrows(node.args[0])
            return False
        if isinstance(node, ast.IfExp):
            return self.borrows(node.body) or self.borrows(node.orelse)
        return False

    @staticmethod
    def _rooted_at_self(node: ast.expr) -> bool:
        while isinstance(node, ast.Attribute):
            node = node.value
        return isinstance(node, ast.Name) and node.id == "self"


_FRESH_CALLS = CLEANSING_CALLS | COPYING_CALLS | {"zeros", "ones", "empty",
                                                  "full", "arange", "stack",
                                                  "concatenate"}


def _always_fresh(node: ast.expr, index: SummaryIndex, path: str) -> bool:
    """True when the expression's value certainly owns its bytes."""
    if isinstance(node, (ast.Constant, ast.BinOp, ast.Compare, ast.BoolOp,
                         ast.UnaryOp)):
        return True
    if isinstance(node, ast.Call):
        tail = last_attr(call_name(node))
        if tail in _FRESH_CALLS:
            return True
        return index.call_verdict(node, path) == "cleanses"
    if isinstance(node, (ast.Tuple, ast.List)):
        return bool(node.elts) and all(
            _always_fresh(e, index, path) for e in node.elts)
    return False


def _summarise(qual: str, fn: ast.AST, path: str,
               index: SummaryIndex) -> FunctionSummary:
    taint = TaintTracker(fn, summaries=index, path=path)
    borrow = _SelfBorrow(index, path)
    returns_tainted = False
    returns_self_view = False
    return_values: List[ast.expr] = []
    calls: List[str] = []
    for stmt in statements_in_order(fn):
        for node in ast.walk(stmt):
            if isinstance(node, ast.Call):
                name = call_name(node)
                if name:
                    calls.append(name)
        if isinstance(stmt, ast.Return) and stmt.value is not None:
            return_values.append(stmt.value)
            if taint.is_tainted(stmt.value):
                returns_tainted = True
            if borrow.borrows(stmt.value):
                returns_self_view = True
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            taint.handle_for(stmt)
        else:
            taint.handle_assign(stmt)
    cleanses = bool(return_values) and not returns_tainted and all(
        _always_fresh(v, index, path) for v in return_values)
    acquires, releases = _collect_locks(fn)
    return FunctionSummary(
        qualname=qual, path=path, name=fn.name,
        lineno=fn.lineno, end_lineno=fn.end_lineno or fn.lineno,
        calls=tuple(dict.fromkeys(calls)),
        returns_tainted=returns_tainted,
        returns_self_view=returns_self_view,
        cleanses_return=cleanses,
        acquires_locks=acquires, releases_locks=releases)


# ---------------------------------------------------------------------------
# telemetry declaration / observation collection
# ---------------------------------------------------------------------------

#: Receivers whose string-keyed subscripts count as telemetry sites.
_COUNTER_RECEIVERS = {"_t", "stats"}


def _is_counter_receiver(expr: ast.expr) -> bool:
    tail = last_attr(dotted(expr))
    return tail in _COUNTER_RECEIVERS


def _collect_telemetry(tree: ast.Module, path: str,
                       tix: TelemetryIndex) -> None:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and \
                node.name.endswith("Telemetry") and node.name != "Telemetry":
            for stmt in node.body:
                if isinstance(stmt, ast.AnnAssign) and \
                        isinstance(stmt.target, ast.Name) and \
                        not stmt.target.id.startswith("_"):
                    tix.declared.setdefault(stmt.target.id,
                                            (path, stmt.lineno))
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                tname = tgt.id if isinstance(tgt, ast.Name) else (
                    tgt.attr if isinstance(tgt, ast.Attribute) else None)
                if tname == "_ALIASES" and isinstance(node.value, ast.Dict):
                    for k, v in zip(node.value.keys, node.value.values):
                        if isinstance(k, ast.Constant) and \
                                isinstance(v, ast.Constant):
                            tix.aliases[str(k.value)] = str(v.value)
                # dict literals initialising a counter store feed keys
                if tname in _COUNTER_RECEIVERS and \
                        isinstance(node.value, ast.Dict):
                    for k in node.value.keys:
                        if isinstance(k, ast.Constant) and \
                                isinstance(k.value, str):
                            tix.fed.add(k.value)
        # string-keyed subscripts on a counter receiver: Store = bump
        # (fed), Load = read (consumed)
        if isinstance(node, ast.Subscript) and \
                isinstance(node.slice, ast.Constant) and \
                isinstance(node.slice.value, str) and \
                _is_counter_receiver(node.value):
            if isinstance(node.ctx, ast.Store):
                tix.fed.add(node.slice.value)
            else:
                tix.consumed.add(node.slice.value)
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr == "get" and node.args and \
                isinstance(node.args[0], ast.Constant) and \
                isinstance(node.args[0].value, str) and \
                _is_counter_receiver(node.func.value):
            tix.consumed.add(node.args[0].value)
        # *Telemetry(...) ctor kwargs feed declared fields wherever they
        # appear (the telemetry() assembly path)
        if isinstance(node, ast.Call):
            tail = last_attr(call_name(node)) or ""
            if tail.endswith("Telemetry") and tail != "Telemetry":
                for kw in node.keywords:
                    if kw.arg:
                        tix.fed.add(kw.arg)
        # telemetry()/stats() assembly: dict-literal keys feed the
        # reported structure; string subscript reads consume counters
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                node.name in ("telemetry", "stats"):
            for sub in ast.walk(node):
                if isinstance(sub, ast.Dict):
                    for k in sub.keys:
                        if isinstance(k, ast.Constant) and \
                                isinstance(k.value, str):
                            tix.fed.add(k.value)
                if isinstance(sub, ast.Subscript) and \
                        isinstance(sub.slice, ast.Constant) and \
                        isinstance(sub.slice.value, str) and \
                        isinstance(sub.ctx, ast.Load):
                    tix.consumed.add(sub.slice.value)


# ---------------------------------------------------------------------------
# index / graph construction
# ---------------------------------------------------------------------------

_FIXED_POINT_ROUNDS = 4


def build_index(sources: Dict[str, str]) -> SummaryIndex:
    """Summaries + telemetry index over ``{rel_path: source}``, iterated to
    a fixed point so taint flows through helper-call chains."""
    trees: Dict[str, ast.Module] = {}
    for path, src in sources.items():
        try:
            trees[path] = ast.parse(src)
        except SyntaxError:
            continue
    tix = TelemetryIndex()
    for path, tree in trees.items():
        _collect_telemetry(tree, path, tix)

    index = SummaryIndex.empty()
    index.telemetry = tix
    for _ in range(_FIXED_POINT_ROUNDS):
        fresh: List[FunctionSummary] = []
        for path, tree in trees.items():
            for qual, fn in _function_nodes(tree):
                fresh.append(_summarise(qual, fn, path, index))
        new_index = SummaryIndex(fresh, tix)
        if _verdicts(new_index) == _verdicts(index):
            return new_index
        index = new_index
    return index


def _verdicts(index: SummaryIndex):
    return {k: (f.returns_tainted, f.returns_self_view, f.cleanses_return)
            for k, f in index.functions.items()}


def index_for_source(source: str, rel_path: str = "<source>") -> SummaryIndex:
    """Single-file index — what ``lint_source`` builds when no project
    index is supplied (fixtures with helper + caller in one string)."""
    return build_index({rel_path: source})


# ---------------------------------------------------------------------------
# module import graph (shared with the dead-code report)
# ---------------------------------------------------------------------------

PKG = "repro"

_DYNAMIC_RE = re.compile(r"import_module\(\s*f?['\"]([\w\.]+)\{")


def _module_name(py: Path, src_root: Path) -> str:
    rel = py.resolve().relative_to(src_root.resolve())
    parts = list(rel.with_suffix("").parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def discover_modules(src_root: Path) -> Dict[str, Path]:
    out = {}
    for py in sorted((src_root / PKG).rglob("*.py")):
        if "__pycache__" in py.parts:
            continue
        out[_module_name(py, src_root)] = py
    return out


def module_imports(py: Path, modules: Dict[str, Path],
                   self_name: str) -> Set[str]:
    """repro.* modules statically imported by *py* (incl. the dynamic
    ``import_module(f"...")`` registry edges)."""
    try:
        tree = ast.parse(py.read_text())
    except SyntaxError:
        return set()
    edges: Set[str] = set()

    def add(name: str):
        # an import of a package reaches its __init__; an import of an
        # attribute from a package may actually be a submodule
        while name:
            if name in modules:
                edges.add(name)
                return
            name = name.rpartition(".")[0]

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name.split(".")[0] == PKG:
                    add(a.name)
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if node.level:  # relative import — resolve against self
                base = self_name.split(".")
                if modules.get(self_name, Path()).name != "__init__.py":
                    base = base[:-1]
                base = base[:len(base) - (node.level - 1)]
                mod = ".".join(base + ([mod] if mod else []))
            if mod.split(".")[0] != PKG:
                continue
            add(mod)
            for a in node.names:
                add(f"{mod}.{a.name}")

    for m in _DYNAMIC_RE.finditer(py.read_text()):
        prefix = m.group(1).rstrip(".")
        if prefix.split(".")[0] == PKG:
            for name in modules:
                if name.startswith(prefix + "."):
                    edges.add(name)
    edges.discard(self_name)
    return edges


@dataclasses.dataclass
class ProjectGraph:
    """The one project graph: module imports + function call graph +
    summaries. ``--graph`` serialises it; ``--deadcode`` walks
    ``imports``; the v2 rules consume ``index``."""
    repo_root: Path
    modules: Dict[str, Path]
    imports: Dict[str, Set[str]]
    index: SummaryIndex
    calls: Dict[str, Set[str]]     # function key -> resolved callee keys

    def to_json(self) -> dict:
        return {
            "modules": {name: str(p.relative_to(self.repo_root))
                        for name, p in sorted(self.modules.items())},
            "imports": {name: sorted(edges)
                        for name, edges in sorted(self.imports.items())},
            "functions": {key: fn.to_json()
                          for key, fn in sorted(self.index.functions.items())},
            "calls": {key: sorted(callees)
                      for key, callees in sorted(self.calls.items())
                      if callees},
            "telemetry": {
                "declared": {k: list(v) for k, v in
                             sorted(self.index.telemetry.declared.items())},
                "observed": sorted(self.index.telemetry.observed),
                "aliases": dict(sorted(self.index.telemetry.aliases.items())),
            },
        }


def build_project_graph(repo_root: Path,
                        roots: Optional[Iterable[Path]] = None) -> ProjectGraph:
    src_root = repo_root / "src"
    modules = discover_modules(src_root)
    imports = {name: module_imports(py, modules, name)
               for name, py in modules.items()}

    files: List[Path] = []
    for root in (roots or [src_root]):
        root = Path(root)
        if root.is_file() and root.suffix == ".py":
            files.append(root)
        elif root.is_dir():
            files.extend(sorted(p for p in root.rglob("*.py")
                                if "__pycache__" not in p.parts))
    sources: Dict[str, str] = {}
    for py in files:
        try:
            rel = py.resolve().relative_to(repo_root.resolve()).as_posix()
        except ValueError:
            rel = str(py)
        sources[rel] = py.read_text()
    index = build_index(sources)

    calls: Dict[str, Set[str]] = {}
    for key, fn in index.functions.items():
        resolved: Set[str] = set()
        for raw in fn.calls:
            bare = last_attr(raw)
            for cand in index.candidates(bare, fn.path):
                resolved.add(f"{cand.path}::{cand.qualname}")
        calls[key] = resolved
    return ProjectGraph(repo_root=repo_root, modules=modules,
                        imports=imports, index=index, calls=calls)
