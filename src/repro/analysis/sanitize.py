"""Runtime sanitizers for the zero-copy hot paths (``REPRO_SANITIZE=1``).

Two latent bug classes survived into merged PRs before this existed:

* **PR 5**: a bare ``jax.device_put`` on an aligned reader-slot buffer
  zero-copy aliased it on CPU jax; the reader thread then refilled the slot
  mid-computation and queries went quietly wrong.
* **PR 4**: a ``jnp`` array zero-copy aliased a closed memory map and the
  process segfaulted.

Both failure modes are *silent until they aren't*. With ``REPRO_SANITIZE=1``:

* ``AsyncChunkReader`` poisons every slot with a canary the moment the
  consumer hands it back (before the slot is recycled to the reader thread)
  and re-checks all device copies produced by ``stage()`` against host
  snapshots. An aliased "copy" sees the canary, mismatches its snapshot,
  and raises :class:`SanitizerError` at the recycle point — the earliest
  instant the alias becomes dangerous. Untracked aliases are poisoned too,
  so float pipelines turn into loud NaN storms instead of wrong answers.
* ``open_saved`` wraps the LRD/LSD memory maps in :class:`MmapGuard`
  proxies; any dereference after ``SavedIndex.close()`` raises
  :class:`UseAfterCloseError` instead of segfaulting.

The module is intentionally a leaf (stdlib + numpy) so the hot paths can
import it unconditionally; all checks collapse to no-ops when the
environment variable is unset.
"""
from __future__ import annotations

import os

import numpy as np

ENV_VAR = "REPRO_SANITIZE"

#: Canary for non-float slots. Detection never relies on the value being
#: impossible in real data (staged copies are compared against snapshots);
#: it only has to differ from whatever the slot held when it was staged.
CANARY_INT = 0xAB


class SanitizerError(RuntimeError):
    """A runtime sanitizer check failed (aliasing / lifetime violation)."""


class UseAfterCloseError(SanitizerError):
    """A memory-mapped view was dereferenced after its index was closed."""


def sanitize_enabled() -> bool:
    """True when ``REPRO_SANITIZE`` is set to anything but '' / '0'."""
    return os.environ.get(ENV_VAR, "") not in ("", "0")


def poison(buf: np.ndarray) -> None:
    """Overwrite *buf* in place with a canary (NaN for floats).

    Called on a slot the instant the consumer releases it: any device
    array still aliasing the slot now reads the canary, and any float
    compute that consumes the alias propagates NaNs loudly.
    """
    if buf.dtype.kind == "f":
        buf[...] = np.nan
    elif buf.dtype.kind in ("i", "u"):
        buf[...] = np.asarray(CANARY_INT, dtype=buf.dtype)
    else:  # bool / bytes / anything exotic: a deterministic flip suffices
        buf[...] = buf.dtype.type(0)


def snapshot(view: np.ndarray) -> np.ndarray:
    """Host copy of *view* taken at stage() time, for later verification."""
    return np.array(view, copy=True)


def verify_staged(dev, snap: np.ndarray, *, slot_id: int) -> None:
    """Raise if a staged device array no longer matches its host snapshot.

    Run *after* :func:`poison` on the slot the copy came from: a genuine
    copy is unaffected by the poison; a zero-copy alias now shows the
    canary and mismatches.
    """
    host = np.asarray(dev)
    if not np.array_equal(host, snap, equal_nan=True):
        raise SanitizerError(
            f"staged device copy aliases reader slot {slot_id}: after the "
            "slot was poisoned the 'copy' changed under us. A bare "
            "jax.device_put/jnp.asarray escaped stage()'s explicit copy "
            "(the PR 5 bug class); use jnp.array(view, copy=True) or "
            "reader.stage()."
        )


class MmapGuard:
    """Array-like proxy over a memory map that fails loudly after release.

    Wraps the ``SavedIndex.lrd`` / ``.lsd`` memmaps under
    ``REPRO_SANITIZE=1``. Reads delegate to the underlying array until
    :meth:`release` (called from ``SavedIndex.close()``); afterwards every
    dereference raises :class:`UseAfterCloseError` — the sanitized stand-in
    for the PR 4 segfault.
    """

    def __init__(self, arr: np.ndarray, label: str):
        self._arr = arr
        self._label = label
        self._released = False

    def _live(self) -> np.ndarray:
        if self._released:
            raise UseAfterCloseError(
                f"{self._label}: memory-mapped view dereferenced after "
                "close(). Copy what you need (np.array / to_layout()) "
                "before closing the index — a zero-copy view of a closed "
                "mmap is the PR 4 segfault class."
            )
        return self._arr

    def release(self) -> None:
        """Invalidate the guard and close the underlying memory map."""
        arr, self._arr, self._released = self._arr, None, True
        mm = getattr(arr, "_mmap", None)
        if mm is not None:
            try:
                mm.close()
            except BufferError:
                # Exported buffers keep the map alive; the OS reclaims it
                # at process exit. Matches SavedIndex.close()'s tolerance.
                pass

    # ---- array-like surface -------------------------------------------
    @property
    def shape(self):
        return self._live().shape

    @property
    def dtype(self):
        return self._live().dtype

    @property
    def ndim(self):
        return self._live().ndim

    @property
    def size(self):
        return self._live().size

    def __len__(self):
        return len(self._live())

    def __getitem__(self, idx):
        return self._live()[idx]

    def __array__(self, dtype=None, copy=None):
        arr = self._live()
        if dtype is not None:
            return np.asarray(arr, dtype=dtype)
        return np.asarray(arr)

    def __repr__(self):
        state = "released" if self._released else "live"
        return f"MmapGuard({self._label}, {state})"


def guard_mmap(arr, label: str):
    """Wrap *arr* in a :class:`MmapGuard` when sanitizing, else pass through."""
    if arr is not None and sanitize_enabled():
        return MmapGuard(arr, label)
    return arr
