"""Runtime sanitizers for the zero-copy hot paths (``REPRO_SANITIZE=1``).

Two latent bug classes survived into merged PRs before this existed:

* **PR 5**: a bare ``jax.device_put`` on an aligned reader-slot buffer
  zero-copy aliased it on CPU jax; the reader thread then refilled the slot
  mid-computation and queries went quietly wrong.
* **PR 4**: a ``jnp`` array zero-copy aliased a closed memory map and the
  process segfaulted.

Both failure modes are *silent until they aren't*. With ``REPRO_SANITIZE=1``:

* ``AsyncChunkReader`` poisons every slot with a canary the moment the
  consumer hands it back (before the slot is recycled to the reader thread)
  and re-checks all device copies produced by ``stage()`` against host
  snapshots. An aliased "copy" sees the canary, mismatches its snapshot,
  and raises :class:`SanitizerError` at the recycle point — the earliest
  instant the alias becomes dangerous. Untracked aliases are poisoned too,
  so float pipelines turn into loud NaN storms instead of wrong answers.
* ``open_saved`` wraps the LRD/LSD memory maps in :class:`MmapGuard`
  proxies; any dereference after ``SavedIndex.close()`` raises
  :class:`UseAfterCloseError` instead of segfaulting.

The module is intentionally a leaf (stdlib + numpy) so the hot paths can
import it unconditionally; all checks collapse to no-ops when the
environment variable is unset.
"""
from __future__ import annotations

import os
import threading
import traceback

import numpy as np

ENV_VAR = "REPRO_SANITIZE"

#: Canary for non-float slots. Detection never relies on the value being
#: impossible in real data (staged copies are compared against snapshots);
#: it only has to differ from whatever the slot held when it was staged.
CANARY_INT = 0xAB


class SanitizerError(RuntimeError):
    """A runtime sanitizer check failed (aliasing / lifetime violation)."""


class UseAfterCloseError(SanitizerError):
    """A memory-mapped view was dereferenced after its index was closed."""


def sanitize_enabled() -> bool:
    """True when ``REPRO_SANITIZE`` is set to anything but '' / '0'."""
    return os.environ.get(ENV_VAR, "") not in ("", "0")


def poison(buf: np.ndarray) -> None:
    """Overwrite *buf* in place with a canary (NaN for floats).

    Called on a slot the instant the consumer releases it: any device
    array still aliasing the slot now reads the canary, and any float
    compute that consumes the alias propagates NaNs loudly.
    """
    if buf.dtype.kind == "f":
        buf[...] = np.nan
    elif buf.dtype.kind in ("i", "u"):
        buf[...] = np.asarray(CANARY_INT, dtype=buf.dtype)
    else:  # bool / bytes / anything exotic: a deterministic flip suffices
        buf[...] = buf.dtype.type(0)


def snapshot(view: np.ndarray) -> np.ndarray:
    """Host copy of *view* taken at stage() time, for later verification."""
    return np.array(view, copy=True)


def verify_staged(dev, snap: np.ndarray, *, slot_id: int) -> None:
    """Raise if a staged device array no longer matches its host snapshot.

    Run *after* :func:`poison` on the slot the copy came from: a genuine
    copy is unaffected by the poison; a zero-copy alias now shows the
    canary and mismatches.
    """
    host = np.asarray(dev)
    if not np.array_equal(host, snap, equal_nan=True):
        raise SanitizerError(
            f"staged device copy aliases reader slot {slot_id}: after the "
            "slot was poisoned the 'copy' changed under us. A bare "
            "jax.device_put/jnp.asarray escaped stage()'s explicit copy "
            "(the PR 5 bug class); use jnp.array(view, copy=True) or "
            "reader.stage()."
        )


class MmapGuard:
    """Array-like proxy over a memory map that fails loudly after release.

    Wraps the ``SavedIndex.lrd`` / ``.lsd`` memmaps under
    ``REPRO_SANITIZE=1``. Reads delegate to the underlying array until
    :meth:`release` (called from ``SavedIndex.close()``); afterwards every
    dereference raises :class:`UseAfterCloseError` — the sanitized stand-in
    for the PR 4 segfault.
    """

    def __init__(self, arr: np.ndarray, label: str):
        self._arr = arr
        self._label = label
        self._released = False

    def _live(self) -> np.ndarray:
        if self._released:
            raise UseAfterCloseError(
                f"{self._label}: memory-mapped view dereferenced after "
                "close(). Copy what you need (np.array / to_layout()) "
                "before closing the index — a zero-copy view of a closed "
                "mmap is the PR 4 segfault class."
            )
        return self._arr

    def release(self) -> None:
        """Invalidate the guard and close the underlying memory map."""
        arr, self._arr, self._released = self._arr, None, True
        mm = getattr(arr, "_mmap", None)
        if mm is not None:
            try:
                mm.close()
            except BufferError:
                # Exported buffers keep the map alive; the OS reclaims it
                # at process exit. Matches SavedIndex.close()'s tolerance.
                pass

    # ---- array-like surface -------------------------------------------
    @property
    def shape(self):
        return self._live().shape

    @property
    def dtype(self):
        return self._live().dtype

    @property
    def ndim(self):
        return self._live().ndim

    @property
    def size(self):
        return self._live().size

    def __len__(self):
        return len(self._live())

    def __getitem__(self, idx):
        return self._live()[idx]

    def __array__(self, dtype=None, copy=None):
        arr = self._live()
        if dtype is not None:
            return np.asarray(arr, dtype=dtype)
        return np.asarray(arr)

    def __repr__(self):
        state = "released" if self._released else "live"
        return f"MmapGuard({self._label}, {state})"


def guard_mmap(arr, label: str):
    """Wrap *arr* in a :class:`MmapGuard` when sanitizing, else pass through."""
    if arr is not None and sanitize_enabled():
        return MmapGuard(arr, label)
    return arr


# ---------------------------------------------------------------------------
# lockdep: lock-order-cycle detection + thread ownership (PR 10)
# ---------------------------------------------------------------------------

class LockOrderError(SanitizerError):
    """Two locks were acquired in opposite orders on different paths —
    a latent ABBA deadlock. Raised *before* blocking, at the acquisition
    that would close the cycle, with both acquisition stacks."""


class ThreadOwnershipError(SanitizerError):
    """A single-owner structure (``SlotQueue`` / reader slots) was touched
    from a thread other than the one it is bound to."""


class HeldLockError(SanitizerError):
    """A thread-pool work item started or finished while holding a lock —
    pool threads must never carry locks across work-item boundaries."""


def _stack(skip: int = 2) -> str:
    """Formatted stack of the caller, trimmed of sanitizer frames."""
    return "".join(traceback.format_stack()[:-skip])


class _LockDep:
    """Process-global lock-acquisition-order graph.

    Kept deliberately simple: an edge A→B is recorded (with the stack
    that created it) the first time B is acquired while A is held; when
    acquiring B with A held, an existing *path* B→…→A means some other
    code path takes the same locks in the opposite order — the classic
    ABBA shape — and :class:`LockOrderError` is raised before the
    acquisition can block. Keys are the wrapper-supplied names, so two
    instances sharing a name class (e.g. per-shard locks) are one node;
    that is the conservative direction for deadlock detection.
    """

    def __init__(self):
        self._mutex = threading.Lock()       # guards the edge graph
        self._edges: dict = {}               # (a, b) -> recording stack
        self._held = threading.local()

    def held(self):
        if not hasattr(self._held, "names"):
            self._held.names = []
        return self._held.names

    def reset(self) -> None:
        """Clear the edge graph and the calling thread's held list
        (test isolation)."""
        with self._mutex:
            self._edges.clear()
        if hasattr(self._held, "names"):
            self._held.names = []

    def _find_path(self, src: str, dst: str):
        """Stack of the first edge on a src→…→dst path, or None."""
        seen, frontier = {src}, [(src, None)]
        while frontier:
            node, first_stack = frontier.pop()
            for (a, b), stack in self._edges.items():
                if a != node or b in seen:
                    continue
                edge_stack = first_stack or stack
                if b == dst:
                    return edge_stack
                seen.add(b)
                frontier.append((b, edge_stack))
        return None

    def note_acquire(self, name: str) -> None:
        held = self.held()
        if held:
            with self._mutex:
                for prior in held:
                    if prior == name:
                        continue    # reentrant / same name class
                    reverse = self._find_path(name, prior)
                    if reverse is not None:
                        raise LockOrderError(
                            f"lock-order cycle: acquiring '{name}' while "
                            f"holding '{prior}', but '{name}' -> "
                            f"'{prior}' was already established — the "
                            "ABBA deadlock shape. Acquisition stack "
                            f"establishing the opposite order:\n{reverse}\n"
                            f"Current acquisition stack:\n{_stack()}")
                    self._edges.setdefault((prior, name), _stack())
        held.append(name)

    def note_release(self, name: str) -> None:
        held = self.held()
        if name in held:
            # remove the most recent acquisition of this name
            for i in range(len(held) - 1, -1, -1):
                if held[i] == name:
                    del held[i]
                    break


#: Process-global lockdep state (shared so cycles across subsystems are
#: visible). Tests call ``LOCKDEP.reset()`` between fixtures.
LOCKDEP = _LockDep()


class LockdepLock:
    """Transparent proxy over a ``threading.Lock`` / ``RLock`` /
    ``Condition`` that feeds the acquisition-order graph. All other
    attributes (``wait`` / ``notify`` / ...) delegate to the wrapped
    object."""

    def __init__(self, lock, name: str):
        self._lock = lock
        self._name = name

    def acquire(self, *args, **kwargs):
        LOCKDEP.note_acquire(self._name)   # raises before blocking
        ok = self._lock.acquire(*args, **kwargs)
        if not ok:                          # non-blocking attempt failed
            LOCKDEP.note_release(self._name)
        return ok

    def release(self):
        self._lock.release()
        LOCKDEP.note_release(self._name)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def __getattr__(self, attr):
        return getattr(self._lock, attr)

    def __repr__(self):
        return f"LockdepLock({self._name}, {self._lock!r})"


def wrap_lock(lock, name: str):
    """Wrap a lock/condition for lockdep when sanitizing, else pass
    through unchanged (zero overhead in production)."""
    if sanitize_enabled():
        return LockdepLock(lock, name)
    return lock


def wrap_condition(cond, name: str):
    """Alias of :func:`wrap_lock` — conditions feed the same order graph
    through their ``acquire``/``release``; ``wait``/``notify`` delegate."""
    return wrap_lock(cond, name)


def lockdep_task(fn, name: str = "pool-task"):
    """Wrap a thread-pool work item: entering or leaving a work item
    while holding any lockdep-tracked lock raises :class:`HeldLockError`
    (pool threads are recycled — a carried lock deadlocks a *later*,
    unrelated work item). No-op passthrough when not sanitizing."""
    if not sanitize_enabled():
        return fn

    def wrapped(*args, **kwargs):
        held = list(LOCKDEP.held())
        if held:
            raise HeldLockError(
                f"work item '{name}' entered while holding {held}: pool "
                f"work must start lock-free.\n{_stack()}")
        result = fn(*args, **kwargs)
        leaked = list(LOCKDEP.held())
        if leaked:
            raise HeldLockError(
                f"work item '{name}' returned while still holding "
                f"{leaked}: a recycled pool thread would deadlock the "
                f"next item.\n{_stack()}")
        return result

    return wrapped


class ThreadAffinity:
    """First-touch thread ownership for single-owner structures.

    ``SlotQueue`` and the chunk readers' consumer side are lock-free *by
    contract*: exactly one thread drives them. The contract is invisible
    at runtime — until a foreign thread touches the structure and a
    torn list/dict update corrupts a wave. Under ``REPRO_SANITIZE=1``
    each :meth:`check` binds the structure to the first touching thread
    and raises :class:`ThreadOwnershipError` (with the binding stack and
    the foreign stack) on any touch from another thread.
    """

    def __init__(self, label: str):
        self._label = label
        self._owner = None
        self._bind_stack = None
        self._bind_op = None

    def check(self, op: str) -> None:
        if not sanitize_enabled():
            return
        me = threading.current_thread()
        if self._owner is None:
            self._owner, self._bind_op = me, op
            self._bind_stack = _stack()
            return
        if me is not self._owner:
            raise ThreadOwnershipError(
                f"{self._label}.{op} called from thread "
                f"'{me.name}' but the structure is bound to "
                f"'{self._owner.name}' (first touch: "
                f"{self._bind_op}). It is lock-free by contract — exactly "
                "one thread may drive it; hand off through a queue "
                "instead. Binding stack:\n"
                f"{self._bind_stack}\nForeign touch stack:\n{_stack()}")

    def rebind(self) -> None:
        """Release ownership (intentional handoff between threads)."""
        self._owner = None
        self._bind_stack = None
        self._bind_op = None
