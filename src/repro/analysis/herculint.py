"""herculint — the repo-native lint engine.

Runs the rule set in :mod:`repro.analysis.rules` over Python sources,
applies inline suppressions, fingerprints findings for the ratchet
baseline, and reports.

Suppressions
------------
A finding is suppressed by a comment on its line (or the line above)::

    dev = jax.device_put(fresh)  # herculint: ok[alias-transfer] -- sync get() returns fresh buffers

The ``-- reason`` part is **mandatory**: a bare suppression is itself
reported (rule ``bare-suppression``). Suppressions are the preferred way
to record *justified* exceptions; the baseline is only for grandfathering
findings that predate a new rule.

Ratchet baseline
----------------
``baseline.json`` maps finding fingerprints to justifications. A
fingerprint hashes (rule, path, enclosing qualname, normalized source
line, occurrence index) — stable across unrelated line drift. Findings
in the baseline are reported as grandfathered and do not fail the run;
anything new does. Shrink the baseline whenever you fix a grandfathered
finding (``--write-baseline`` regenerates it; stale entries are flagged).
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

from repro.analysis import callgraph
from repro.analysis.rules import ALL_RULES

SUPPRESS_RE = re.compile(
    r"#\s*herculint:\s*ok\[(?P<rules>[\w,\- ]+)\]"
    r"(?:\s*--\s*(?P<reason>\S.*))?")

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str           # repo-relative, posix separators
    line: int
    col: int
    context: str        # dotted qualname of the enclosing scope
    snippet: str        # stripped source of the offending line
    message: str
    occurrence: int = 0  # disambiguates identical lines in one scope

    @property
    def fingerprint(self) -> str:
        payload = "|".join((self.rule, self.path, self.context,
                            self.snippet, str(self.occurrence)))
        return hashlib.sha256(payload.encode()).hexdigest()[:16]

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col + 1}: "
                f"[{self.rule}] {self.context}: {self.message}")

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "context": self.context,
                "snippet": self.snippet, "message": self.message,
                "fingerprint": self.fingerprint}


def _qualname_index(tree: ast.Module) -> Dict[Tuple[int, int], str]:
    """Maps (lineno, end_lineno) of each scope to its dotted qualname."""
    spans: Dict[Tuple[int, int], str] = {}

    def walk(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                spans[(child.lineno, child.end_lineno or child.lineno)] = qual
                walk(child, qual)
            else:
                walk(child, prefix)

    walk(tree, "")
    return spans


def _context_for(line: int, spans: Dict[Tuple[int, int], str]) -> str:
    best, best_len = "<module>", None
    for (lo, hi), qual in spans.items():
        if lo <= line <= hi and (best_len is None or hi - lo < best_len):
            best, best_len = qual, hi - lo
    return best


def lint_source(source: str, rel_path: str, rules=ALL_RULES,
                summaries=callgraph.AUTO,
                ) -> Tuple[List[Finding], List[Finding]]:
    """Lint one source string.

    Returns ``(findings, suppression_problems)`` — the latter are
    bare-suppression findings (missing ``-- reason``).

    ``summaries`` is the interprocedural :class:`~repro.analysis.callgraph
    .SummaryIndex` consulted by the v2 rules. The default
    (:data:`callgraph.AUTO`) builds a single-file index from *source*
    itself — enough for self-contained fixtures; ``run_lint`` passes the
    project-wide index instead. Pass ``SummaryIndex.empty()`` to
    reproduce v1's single-scope behaviour.
    """
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        f = Finding("parse-error", rel_path, e.lineno or 1, 0, "<module>",
                    "", f"could not parse: {e.msg}")
        return [f], []
    if summaries is callgraph.AUTO:
        summaries = callgraph.index_for_source(source, rel_path)
    lines = source.splitlines()
    spans = _qualname_index(tree)

    suppress: Dict[int, Tuple[set, Optional[str]]] = {}
    problems: List[Finding] = []
    for i, text in enumerate(lines, start=1):
        m = SUPPRESS_RE.search(text)
        if not m:
            continue
        ids = {r.strip() for r in m.group("rules").split(",") if r.strip()}
        reason = m.group("reason")
        suppress[i] = (ids, reason)
        if not reason:
            problems.append(Finding(
                "bare-suppression", rel_path, i, 0,
                _context_for(i, spans), text.strip(),
                f"suppression of {sorted(ids)} has no '-- reason': every "
                "suppression must say why the pattern is safe here."))

    seen_occurrences: Dict[Tuple[str, str, str], int] = {}
    findings: List[Finding] = []
    for rule in rules:
        for raw in rule.check(tree, rel_path, lines, summaries=summaries):
            sup = suppress.get(raw.line) or suppress.get(raw.line - 1)
            if sup and (raw.rule in sup[0] or "all" in sup[0]):
                continue
            snippet = (lines[raw.line - 1].strip()
                       if 0 < raw.line <= len(lines) else "")
            context = _context_for(raw.line, spans)
            occ_key = (raw.rule, context, snippet)
            occ = seen_occurrences.get(occ_key, 0)
            seen_occurrences[occ_key] = occ + 1
            findings.append(Finding(raw.rule, rel_path, raw.line, raw.col,
                                    context, snippet, raw.message, occ))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings, problems


def lint_file(path: Path, repo_root: Path, rules=ALL_RULES,
              summaries=callgraph.AUTO) -> Tuple[List[Finding], List[Finding]]:
    rel = path.resolve().relative_to(repo_root.resolve()).as_posix()
    return lint_source(path.read_text(), rel, rules, summaries=summaries)


def iter_python_files(roots: Iterable[Path]) -> List[Path]:
    out: List[Path] = []
    for root in roots:
        root = Path(root)
        if root.is_file() and root.suffix == ".py":
            out.append(root)
        elif root.is_dir():
            out.extend(sorted(p for p in root.rglob("*.py")
                              if "__pycache__" not in p.parts))
    return out


def run_lint(roots: Iterable[Path], repo_root: Path,
             rules=ALL_RULES) -> List[Finding]:
    """All findings (including bare-suppression problems) for *roots*.

    Builds one project-wide summary index over every file in *roots*
    first, so the rules see helper returns across file boundaries."""
    files = iter_python_files(roots)
    sources: Dict[str, str] = {}
    for path in files:
        rel = path.resolve().relative_to(repo_root.resolve()).as_posix()
        sources[rel] = path.read_text()
    index = callgraph.build_index(sources)
    findings: List[Finding] = []
    for rel, src in sources.items():
        got, problems = lint_source(src, rel, rules, summaries=index)
        findings.extend(got)
        findings.extend(problems)
    return findings


# ---------------------------------------------------------------------------
# ratchet baseline
# ---------------------------------------------------------------------------

def load_baseline(path: Path = DEFAULT_BASELINE) -> Dict[str, dict]:
    if not path.exists():
        return {}
    data = json.loads(path.read_text())
    return {e["fingerprint"]: e for e in data.get("findings", [])}


def write_baseline(findings: List[Finding], path: Path,
                   previous: Optional[Dict[str, dict]] = None) -> None:
    previous = previous or {}
    entries = []
    for f in findings:
        old = previous.get(f.fingerprint, {})
        entry = f.to_json()
        entry["justification"] = old.get(
            "justification", "TODO: justify or fix")
        entries.append(entry)
    payload = {
        "_comment": ("herculint ratchet baseline: grandfathered findings. "
                     "New findings fail CI; shrink this file whenever one "
                     "is fixed. Regenerate with "
                     "`python -m repro.analysis --write-baseline`."),
        "findings": entries,
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")


@dataclasses.dataclass
class RatchetResult:
    new: List[Finding]
    grandfathered: List[Finding]
    stale: List[str]    # fingerprints in the baseline no longer observed

    @property
    def ok(self) -> bool:
        return not self.new


def ratchet(findings: List[Finding],
            baseline: Dict[str, dict]) -> RatchetResult:
    new, grand = [], []
    observed = set()
    for f in findings:
        observed.add(f.fingerprint)
        (grand if f.fingerprint in baseline else new).append(f)
    stale = sorted(set(baseline) - observed)
    return RatchetResult(new=new, grandfathered=grand, stale=stale)
