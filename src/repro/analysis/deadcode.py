"""Dead-code report: import-graph reachability over ``src/repro``.

Walks the static import graph from four root sets — the public API
(``repro.api``), the test suite, the benchmark/example drivers, and the
``python -m repro.launch.*`` CLIs — and classifies every module under
``src/repro`` by what reaches it. Dynamic registries are handled
specially: a call like ``importlib.import_module(f"repro.configs.{...}")``
adds edges to every module under that prefix.

Since v2 the module graph itself lives in
:mod:`repro.analysis.callgraph` — one graph shared by this report, the
``--graph`` JSON emission, and the interprocedural lint rules, so the
three can never disagree about what imports what. ``build_report``
accepts a prebuilt :class:`~repro.analysis.callgraph.ProjectGraph` to
avoid re-parsing when the caller already has one.

Some modules are reachable only from tests: the ``configs/`` + ``models/``
LLM architecture exemplars predate the Hercules pivot and are kept
deliberately as dry-run/trace fixtures for the distributed tooling. They
are listed in :data:`INTENTIONAL` with a justification so the report
never shows them as ambiguous — anything *outside* that list that is
unreachable is genuinely dead and should be deleted.
"""
from __future__ import annotations

from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis import callgraph
from repro.analysis.callgraph import (  # noqa: F401  (public re-exports)
    PKG, discover_modules, module_imports,
)

#: Modules (by prefix) that are intentionally kept even when nothing on
#: the api/CLI path imports them. Keyed by dotted-prefix.
INTENTIONAL: Dict[str, str] = {
    "repro.configs": (
        "LLM architecture registry: dry-run/trace fixtures for the "
        "distributed sharding + launch tooling (tests/test_dryrun_units, "
        "launch/dryrun); exercised via the dynamic importlib registry."),
    "repro.models": (
        "Model exemplars backing the configs registry; covered by "
        "tests/test_models + tests/test_train and used by launch/dryrun "
        "shape-level traces."),
}

#: Backwards-compatible alias — the edge extractor moved to callgraph.
_imports_of = module_imports


def _closure(seeds: Iterable[str], graph: Dict[str, Set[str]]) -> Set[str]:
    seen: Set[str] = set()
    frontier = list(seeds)
    while frontier:
        name = frontier.pop()
        if name in seen:
            continue
        seen.add(name)
        frontier.extend(graph.get(name, ()))
        # importing a submodule executes its package __init__s too
        parent = name.rpartition(".")[0]
        if parent and parent not in seen:
            frontier.append(parent)
    return seen


def build_report(repo_root: Path,
                 project: Optional[callgraph.ProjectGraph] = None) -> dict:
    if project is None:
        project = callgraph.build_project_graph(repo_root)
    modules, graph = project.modules, project.imports

    def external_roots(dirname: str) -> Set[str]:
        roots: Set[str] = set()
        d = repo_root / dirname
        if not d.is_dir():
            return roots
        for py in sorted(d.rglob("*.py")):
            roots |= module_imports(py, modules, f"<{dirname}>")
        return roots

    root_sets = {
        "api": _closure({"repro.api"}, graph),
        "cli": _closure([m for m in modules
                         if m.startswith("repro.launch")
                         or m.endswith("__main__")], graph),
        "tests": _closure(external_roots("tests"), graph),
        "bench/examples": _closure(
            external_roots("benchmarks") | external_roots("examples"), graph),
    }

    classified: Dict[str, dict] = {}
    for name in sorted(modules):
        reached_by = [k for k, s in root_sets.items() if name in s]
        if name == "repro":
            reached_by = reached_by or ["api"]
        status = "reachable" if reached_by else "dead"
        note = ""
        if reached_by and "api" not in reached_by and "cli" not in reached_by:
            status = "test-only"
        # the exemplar audit is explicit whatever the reachability verdict:
        # configs/models must never show up as ambiguous
        for prefix, why in INTENTIONAL.items():
            if name == prefix or name.startswith(prefix + "."):
                note = why
                if status in ("dead", "test-only"):
                    status = "intentional"
                break
        classified[name] = {
            "path": str(modules[name].relative_to(repo_root)),
            "status": status,
            "reached_by": reached_by,
            **({"note": note} if note else {}),
        }

    dead = [n for n, c in classified.items() if c["status"] == "dead"]
    return {
        "modules": classified,
        "dead": dead,
        "counts": {
            s: sum(1 for c in classified.values() if c["status"] == s)
            for s in ("reachable", "test-only", "intentional", "dead")
        },
    }


def format_report(report: dict) -> str:
    lines = ["herculint dead-code report", "=" * 26, ""]
    counts = report["counts"]
    lines.append("  ".join(f"{k}: {v}" for k, v in counts.items()))
    lines.append("")
    by_status: Dict[str, List[str]] = {}
    for name, c in report["modules"].items():
        by_status.setdefault(c["status"], []).append(name)
    for status in ("dead", "test-only", "intentional"):
        names = by_status.get(status, [])
        if not names:
            continue
        lines.append(f"[{status}]")
        for name in names:
            entry = report["modules"][name]
            via = ",".join(entry["reached_by"]) or "-"
            lines.append(f"  {name:45s} via={via}")
            if entry.get("note"):
                lines.append(f"      kept: {entry['note']}")
        lines.append("")
    exemplars = [n for n, c in report["modules"].items()
                 if c["status"] == "reachable" and c.get("note")]
    if exemplars:
        lines.append("[exemplars (reachable, intentionally kept)]")
        seen_notes = set()
        for name in exemplars:
            lines.append(f"  {name}")
            note = report["modules"][name]["note"]
            if note not in seen_notes:
                seen_notes.add(note)
                lines.append(f"      kept: {note}")
        lines.append("")
    if report["dead"]:
        lines.append("DEAD modules above are unreachable from api/CLI/tests/"
                     "benchmarks and not marked intentional: delete them.")
    else:
        lines.append("No unexplained dead modules.")
    return "\n".join(lines)
