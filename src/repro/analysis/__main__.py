"""CLI: ``python -m repro.analysis`` — lint + ratchet check.

Exit codes: 0 clean (or all findings grandfathered/justified), 1 new
findings, 2 usage/parse trouble.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import callgraph, deadcode, herculint


def _repo_root() -> Path:
    # src/repro/analysis/__main__.py -> repo root is three parents above src/
    return Path(__file__).resolve().parents[3]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="herculint: repo-native static analysis + ratchet")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files/dirs to lint (default: src benchmarks "
                         "examples under the repo root)")
    ap.add_argument("--repo-root", type=Path, default=_repo_root())
    ap.add_argument("--baseline", type=Path,
                    default=herculint.DEFAULT_BASELINE)
    ap.add_argument("--write-baseline", action="store_true",
                    help="grandfather all current findings into the "
                         "baseline (preserves existing justifications)")
    ap.add_argument("--json", type=Path, metavar="OUT",
                    help="also dump findings (and the dead-code report "
                         "with --deadcode) as JSON")
    ap.add_argument("--deadcode", action="store_true",
                    help="print the import-graph dead-code report "
                         "(informational; never fails the run by itself)")
    ap.add_argument("--graph", type=Path, metavar="OUT",
                    help="emit the project call graph + per-function "
                         "summaries + telemetry contract as JSON (the "
                         "interprocedural state the v2 rules consume)")
    args = ap.parse_args(argv)

    root = args.repo_root.resolve()
    roots = args.paths or [root / "src", root / "benchmarks",
                           root / "examples"]
    findings = herculint.run_lint(roots, root)

    # --graph and --deadcode share one ProjectGraph — the same modules,
    # import edges, and summaries the rules just consumed.
    project = None
    if args.graph or args.deadcode:
        project = callgraph.build_project_graph(root, roots)
    if args.graph:
        args.graph.write_text(
            json.dumps(project.to_json(), indent=2) + "\n")
        n_fn = len(project.index.functions)
        print(f"call graph written: {args.graph} "
              f"({len(project.modules)} modules, {n_fn} functions)")
    if args.deadcode:
        report = deadcode.build_report(root, project=project)
        print(deadcode.format_report(report))
        print()
    else:
        report = None

    if args.write_baseline:
        herculint.write_baseline(
            findings, args.baseline,
            previous=herculint.load_baseline(args.baseline))
        print(f"baseline written: {args.baseline} "
              f"({len(findings)} grandfathered findings)")
        return 0

    baseline = herculint.load_baseline(args.baseline)
    result = herculint.ratchet(findings, baseline)

    for f in result.new:
        print(f.format())
    if result.grandfathered:
        print(f"-- {len(result.grandfathered)} grandfathered finding(s) "
              f"(see {args.baseline.name})")
    for fp in result.stale:
        entry = baseline[fp]
        print(f"-- stale baseline entry {fp} "
              f"({entry.get('rule')} @ {entry.get('path')}): the finding "
              "is gone — shrink the baseline.")

    if args.json:
        payload = {
            "new": [f.to_json() for f in result.new],
            "grandfathered": [f.to_json() for f in result.grandfathered],
            "stale": result.stale,
        }
        if report is not None:
            payload["deadcode"] = report
        args.json.write_text(json.dumps(payload, indent=2) + "\n")

    if result.new:
        print(f"herculint: {len(result.new)} new finding(s) — fix them, "
              "suppress with `# herculint: ok[rule] -- reason`, or "
              "(new-rule rollout only) --write-baseline.")
        return 1
    print(f"herculint: clean ({len(result.grandfathered)} grandfathered, "
          f"{len(result.stale)} stale baseline entries)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
