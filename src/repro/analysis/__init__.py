"""``repro.analysis`` — herculint static analysis + runtime sanitizers.

Hercules' speed rests on exactly the mechanisms that are easiest to get
silently wrong in Python/JAX: memory-mapped base files, reusable host slot
buffers refilled by a daemon reader thread, and atomic manifest commits.
PR 4 (a segfault from ``jnp`` zero-copy aliasing a closed mmap) and PR 5
(the reader refilling a slot that a bare ``device_put`` had aliased) each
found one instance of a *class* of bug by hand. This package finds the
classes mechanically:

* :mod:`repro.analysis.herculint` — an AST lint engine with repo-specific
  rules (``repro.analysis.rules``): alias-unsafe device transfers,
  mmap-lifetime escapes, atomic-commit ordering, cross-thread attribute
  discipline, and SearchConfig plumbing. Run it with
  ``python -m repro.analysis``; a ratchet baseline
  (``src/repro/analysis/baseline.json``) freezes grandfathered findings so
  any *new* violation fails CI.
* :mod:`repro.analysis.sanitize` — runtime sanitizers, enabled by
  ``REPRO_SANITIZE=1``: the async chunk reader poisons recycled slots with
  a NaN canary and re-checks staged device copies (latent aliasing becomes
  a loud :class:`~repro.analysis.sanitize.SanitizerError`), and
  ``SavedIndex`` wraps its memory maps in use-after-close guards.
* :mod:`repro.analysis.deadcode` — import-graph reachability report over
  ``src/repro`` (``python -m repro.analysis --deadcode``).

This module stays import-light (stdlib + numpy only at the sanitize leaf):
the hot paths import :mod:`repro.analysis.sanitize` at module load.
"""
from repro.analysis.sanitize import (  # noqa: F401
    SanitizerError, UseAfterCloseError, sanitize_enabled,
)
