"""Rule: config-plumbing — SearchConfig fields must be validated and keyed.

Every ``SearchConfig`` field steers a compiled plan. A field that is not
validated in ``__post_init__`` ships garbage into kernels at trace time
(where the error surfaces as an inscrutable XLA shape failure three
layers down); a field missing from the plan-cache key silently reuses a
plan compiled for different semantics — the worst kind of wrong answer.

Two checks:

* in the module defining ``class SearchConfig``: every dataclass field
  (AnnAssign, non-ClassVar) must be read as ``self.<field>`` inside
  ``__post_init__``;
* in the module defining ``class QueryEngine``: the plan-cache ``key``
  tuple built in ``knn`` must contain the whole ``cfg`` object (frozen
  dataclass equality makes every field participate automatically —
  never rebuild the key from hand-picked fields).
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.rules.common import RawFinding

RULE_ID = "config-plumbing"
DESCRIPTION = ("every SearchConfig field must be validated in __post_init__ "
               "and participate in the plan-cache key (pass cfg whole)")


def check(tree: ast.Module, rel_path: str, src_lines,
          summaries=None) -> Iterator[RawFinding]:
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        if node.name == "SearchConfig":
            yield from _check_config(node)
        elif node.name == "QueryEngine":
            yield from _check_plan_key(node)


def _check_config(cls: ast.ClassDef) -> Iterator[RawFinding]:
    fields = []
    for stmt in cls.body:
        if isinstance(stmt, ast.AnnAssign) and \
                isinstance(stmt.target, ast.Name):
            ann = ast.unparse(stmt.annotation)
            if "ClassVar" in ann:
                continue
            fields.append((stmt.target.id, stmt))
    if not fields:
        return

    post = next((s for s in cls.body
                 if isinstance(s, ast.FunctionDef)
                 and s.name == "__post_init__"), None)
    if post is None:
        yield RawFinding(
            RULE_ID, cls.lineno, cls.col_offset,
            "SearchConfig has no __post_init__: fields reach trace time "
            "unvalidated and fail as XLA shape errors instead of "
            "ValueError at construction.")
        return

    # a field counts as validated when __post_init__ reads `self.<field>`
    # directly or names it as a string constant (the getattr-over-a-
    # field-tuple loop idiom)
    validated = {
        sub.attr for sub in ast.walk(post)
        if isinstance(sub, ast.Attribute)
        and isinstance(sub.value, ast.Name) and sub.value.id == "self"
    }
    validated |= {
        sub.value for sub in ast.walk(post)
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str)
    }
    for name, stmt in fields:
        if name not in validated:
            yield RawFinding(
                RULE_ID, stmt.lineno, stmt.col_offset,
                f"SearchConfig.{name} is never touched in __post_init__: "
                "add a validity check so a bad value raises ValueError at "
                "construction, not deep inside a traced kernel.")


def _check_plan_key(cls: ast.ClassDef) -> Iterator[RawFinding]:
    knn = next((s for s in cls.body
                if isinstance(s, ast.FunctionDef) and s.name == "knn"), None)
    if knn is None:
        return
    for sub in ast.walk(knn):
        if isinstance(sub, ast.Assign) and len(sub.targets) == 1 and \
                isinstance(sub.targets[0], ast.Name) and \
                sub.targets[0].id == "key":
            if not isinstance(sub.value, ast.Tuple):
                continue
            names = {e.id for e in sub.value.elts
                     if isinstance(e, ast.Name)}
            if "cfg" not in names and "config" not in names:
                yield RawFinding(
                    RULE_ID, sub.lineno, sub.col_offset,
                    "plan-cache key does not include the resolved config "
                    "object: hand-picking fields lets a new SearchConfig "
                    "field silently alias plans compiled for different "
                    "semantics. Put `cfg` itself in the key tuple.")
