"""Shared AST helpers for herculint rules: dotted names + view-taint tracking.

The taint model is deliberately a *linter's* model, not a dataflow
engine: one pass per function body in statement order, a single set of
tainted names, no path sensitivity. That is enough to catch the bug
classes this repo has actually shipped (PR 4 / PR 5) with near-zero false
positives on the real tree; see the heuristics documented on
:class:`TaintTracker`.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterator, Optional


@dataclasses.dataclass(frozen=True)
class RawFinding:
    """A rule hit before the engine attaches file/context/fingerprint."""
    rule: str
    line: int
    col: int
    message: str


def dotted(node: ast.AST) -> Optional[str]:
    """'jax.device_put' for Attribute/Name chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    return dotted(call.func)


def last_attr(name: Optional[str]) -> Optional[str]:
    """Terminal component of a dotted name ('np.asarray' -> 'asarray')."""
    return name.rsplit(".", 1)[-1] if name else None


def name_components(name: str) -> set:
    return {c for c in name.lower().split("_") if c}


def kwarg(call: ast.Call, key: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == key:
            return kw.value
    return None


def is_true_const(node: Optional[ast.expr]) -> bool:
    return isinstance(node, ast.Constant) and node.value is True


def is_none_const(node: Optional[ast.expr]) -> bool:
    return isinstance(node, ast.Constant) and node.value is None


def iter_scopes(tree: ast.Module) -> Iterator[ast.AST]:
    """Module plus every function/method body, each scanned independently."""
    yield tree
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


#: Identifier components that mark a value as a mapped segment / reusable
#: slot buffer by naming convention (`lrd_rows`, `mmap_view`, `slot`,
#: `enc_block`, ...). ``enc`` is the format-v3 encoded sidecar — memory-
#: mapped exactly like lrd/lsd, so the same aliasing hazards apply.
VIEW_NAME_COMPONENTS = {
    "lrd", "lsd", "enc", "mmap", "memmap", "slot", "slots", "view", "views",
    # dist-ooc per-shard row-range views (repro.distributed.ooc._ShardRows):
    # a `shard_rows` / `shard_view` name is a window onto the mapped base
    # file — slicing it hands out mmap-backed memory like slicing the file
    "shard", "shards",
}

#: Attribute reads that hand out mapped segments (`saved.lrd`, `idx.lsd`,
#: `saved.enc`).
VIEW_ATTRS = {"lrd", "lsd", "enc"}

#: Method calls that hand out mapped segments or borrowed buffers.
#: ``chunk`` is here because the ChunkSource protocol documents that
#: ``source.chunk(lo, hi)`` may return a view of the underlying (possibly
#: memory-mapped) buffer; ``_journal_rows`` returns mmap-mode np.load
#: results per segment. A ``_ShardView._mapped()`` result (the dist-ooc
#: per-shard ``_ShardRows`` range view) is covered by ``_mapped``:
#: slicing it inside ``shard_map`` yields mapped memory exactly like
#: slicing the base file, so the device-transfer rules apply unchanged.
VIEW_METHODS = {"_mapped", "_lrd", "_lsd", "_enc", "chunk", "_journal_rows"}

#: Method calls whose *result* is always a fresh buffer even when the
#: receiver/arguments are mapped segments — the codec hot path's cleansers.
#: ``decode`` reconstructs float32 rows from encoded bytes (the Codec
#: protocol guarantees fresh arrays; storage/codecs.py), ``encode``
#: likewise materializes the byte rows, and ``np.take`` is the
#: copy-guaranteed gather (unlike ``x[idx]``, whose copy-vs-view outcome
#: this model has to guess from the index expression).
CLEANSING_CALLS = {"decode", "encode", "take"}

#: ndarray methods that return *views* of their receiver.
VIEW_PRESERVING_METHODS = {
    "reshape", "ravel", "view", "transpose", "squeeze", "swapaxes",
}

#: Calls that return a fresh buffer regardless of the argument.
COPYING_CALLS = {"array", "copy", "ascontiguousarray_copy", "astype", "tolist"}

#: Reader factories — names assigned from these are chunk readers whose
#: ``get()`` returns a reusable slot view.
READER_FACTORIES = {"make_chunk_reader", "AsyncChunkReader", "SyncChunkReader"}


def _names_a_view(name: str) -> bool:
    return bool(name_components(name) & VIEW_NAME_COMPONENTS)


class TaintTracker:
    """Tracks which local names may refer to an mmap segment or slot buffer.

    Heuristics (tuned against this repo, documented for rule authors):

    * **Sources** — ``np.load(..., mmap_mode=...)``, ``np.memmap`` /
      ``open_memmap``, ``._mapped()`` / ``._lrd()`` / ``._lsd()`` calls,
      ``.lrd`` / ``.lsd`` attribute reads, ``reader.get()`` on a known
      chunk reader, and any identifier whose ``_``-components include
      lrd/lsd/mmap/slot/view (parameters included).
    * **View propagation** — plain assignment, ``np.asarray`` /
      ``np.ascontiguousarray``, ndarray view methods (``reshape`` ...),
      ``.T``, and subscripts whose index is a slice or a constant
      (``x[lo:hi]``, ``x[0]`` are views).
    * **Cleansers** — ``np.array`` (copies by default), ``.copy()``,
      ``.astype()``, codec ``.decode()`` / ``.encode()`` and ``np.take``
      (:data:`CLEANSING_CALLS` — always fresh buffers), and subscripts
      whose index is a *computed expression* (``x[perm]`` is fancy
      indexing, which copies). ``x[i]`` inside a loop is mis-modelled as
      a copy; acceptable — scalar-row extraction has never been the bug.
    * **Summaries (v2)** — when a :class:`~repro.analysis.callgraph.SummaryIndex`
      is supplied, helper calls are resolved through it: a call whose every
      candidate definition is ``returns_tainted`` is a source (the
      interprocedural escape v1 missed), and one whose candidates all
      ``cleanses_return`` is a cleanser even if its name *sounds* like a
      view. Name heuristics still apply when resolution fails.
    """

    def __init__(self, scope: ast.AST, summaries=None, path=None):
        self.summaries = summaries
        self.path = path
        self.tainted: set = set()
        self.cleansed: set = set()  # view-named but explicitly copied
        self.readers: set = set()
        if isinstance(scope, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = scope.args
            for a in (*args.posonlyargs, *args.args, *args.kwonlyargs):
                comps = name_components(a.arg)
                if comps & VIEW_NAME_COMPONENTS:
                    self.tainted.add(a.arg)
                if "reader" in comps or "readers" in comps:
                    self.readers.add(a.arg)

    # ---- sources ------------------------------------------------------
    def _call_is_source(self, call: ast.Call) -> bool:
        name = call_name(call)
        tail = last_attr(name)
        if tail == "load" and not is_none_const(kwarg(call, "mmap_mode")) \
                and kwarg(call, "mmap_mode") is not None:
            return True
        if tail in ("memmap", "open_memmap"):
            return True
        if tail in VIEW_METHODS:
            return True
        if tail == "get" and isinstance(call.func, ast.Attribute):
            recv = call.func.value
            if isinstance(recv, ast.Name) and (
                    recv.id in self.readers or "reader" in name_components(recv.id)):
                return True
            recv_name = dotted(recv)
            if recv_name and "reader" in name_components(recv_name.replace(".", "_")):
                return True
        return False

    def _is_reader_factory(self, value: ast.expr) -> bool:
        if isinstance(value, ast.Call):
            tail = last_attr(call_name(value))
            return tail in READER_FACTORIES
        return False

    # ---- expression classification ------------------------------------
    def is_tainted(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Name):
            if node.id in self.cleansed:
                return False
            return node.id in self.tainted or _names_a_view(node.id)
        if isinstance(node, ast.Attribute):
            if node.attr in VIEW_ATTRS or _names_a_view(node.attr):
                return True
            if node.attr == "T":  # transpose view
                return self.is_tainted(node.value)
            return False
        if isinstance(node, ast.Subscript):
            if not self.is_tainted(node.value):
                return False
            return _subscript_is_view(node.slice)
        if isinstance(node, ast.Call):
            tail = last_attr(call_name(node))
            if tail in CLEANSING_CALLS:
                # decode/encode/take produce fresh buffers no matter how
                # tainted their inputs — checked before the sources so a
                # view-named receiver (`enc.decode(...)`) cannot re-taint
                return False
            verdict = (self.summaries.call_verdict(node, self.path)
                       if self.summaries is not None else None)
            if verdict == "cleanses":
                # every resolvable definition returns a fresh buffer —
                # overrides the name heuristics below
                return False
            if self._call_is_source(node):
                return True
            if verdict == "tainted":
                return True
            if tail in ("asarray", "ascontiguousarray") and node.args:
                # np.asarray of a view is (usually) still the same view;
                # jnp.asarray is handled as a sink by alias_transfer.
                mod = call_name(node) or ""
                if not mod.startswith(("jnp.", "jax.")):
                    return self.is_tainted(node.args[0])
                return False
            if tail in VIEW_PRESERVING_METHODS and \
                    isinstance(node.func, ast.Attribute):
                return self.is_tainted(node.func.value)
            return False
        if isinstance(node, ast.IfExp):
            return self.is_tainted(node.body) or self.is_tainted(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_tainted(e) for e in node.elts)
        return False

    # ---- statement-order updates ---------------------------------------
    def handle_assign(self, node: ast.AST) -> None:
        if isinstance(node, ast.Assign):
            value, targets = node.value, node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            value, targets = node.value, [node.target]
        elif isinstance(node, ast.AugAssign):
            return
        else:
            return
        tainted = self.is_tainted(value)
        is_reader = self._is_reader_factory(value)
        for tgt in targets:
            for name_node in _target_names(tgt):
                if tainted:
                    self.tainted.add(name_node)
                    self.cleansed.discard(name_node)
                else:
                    self.tainted.discard(name_node)
                    self.cleansed.add(name_node)
                if is_reader:
                    self.readers.add(name_node)
                else:
                    self.readers.discard(name_node)

    def handle_for(self, node) -> None:
        """``for chunk in reader`` / ``for lo, chunk in iter_host_chunks(...)``."""
        it = node.iter
        taint_targets = False
        if self.is_tainted(it):
            taint_targets = True
        elif isinstance(it, ast.Call):
            tail = last_attr(call_name(it))
            if tail in ("iter_host_chunks", "iter_chunks"):
                taint_targets = True
            elif tail == "enumerate" and it.args and self.is_tainted(it.args[0]):
                taint_targets = True
        if taint_targets:
            for name_node in _target_names(node.target):
                self.tainted.add(name_node)
                self.cleansed.discard(name_node)


def _target_names(tgt: ast.expr) -> Iterator[str]:
    if isinstance(tgt, ast.Name):
        yield tgt.id
    elif isinstance(tgt, (ast.Tuple, ast.List)):
        for e in tgt.elts:
            yield from _target_names(e)
    elif isinstance(tgt, ast.Starred):
        yield from _target_names(tgt.value)


def _subscript_is_view(idx: ast.expr) -> bool:
    """True when ``x[idx]`` is a numpy *view* of x (slice / scalar const);
    computed indices are fancy indexing, which copies."""
    if isinstance(idx, ast.Slice):
        return True
    if isinstance(idx, ast.Constant):
        return True
    if isinstance(idx, ast.UnaryOp) and isinstance(idx.operand, ast.Constant):
        return True
    if isinstance(idx, ast.Tuple):
        return all(_subscript_is_view(e) for e in idx.elts)
    if isinstance(idx, ast.Name):
        # A bare name index is almost always an integer loop variable
        # (`x[i]` — a row view) in this repo; treat as view to stay safe.
        return True
    return False


def statements_in_order(scope: ast.AST) -> Iterator[ast.stmt]:
    """All statements in a scope body in source order, recursing into
    control flow but NOT into nested function/class definitions."""
    body = scope.body if hasattr(scope, "body") else []
    yield from _walk_stmts(body)


def _walk_stmts(body) -> Iterator[ast.stmt]:
    for stmt in body:
        yield stmt
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        for field in ("body", "orelse", "finalbody"):
            inner = getattr(stmt, field, None)
            if inner:
                yield from _walk_stmts(inner)
        for handler in getattr(stmt, "handlers", []) or []:
            yield from _walk_stmts(handler.body)
