"""Rule: exactness-invariant — no raw BSF compare against decoded values.

The format-v3 exactness contract (PR 8): candidate pruning over encoded
leaves is only exact when the comparison against the best-so-far goes
through the **certified interval pattern** — per-row LB/UB carries with
encoder-embedded reconstruction bounds — or when the distance is
recomputed from decoded bytes in float32 difference form over a
copy-gathered candidate pool. A raw ``decoded_distance <= bsf`` skips
the slack accounting: bf16 round-trip error silently drops true
neighbours, and the answer is wrong without any test noticing until the
exact oracle disagrees.

Per scope, the rule taints names assigned from ``.decode(...)`` calls
(and arithmetic derived from them) and flags ``<``/``<=``/``>``/``>=``
comparisons where one side is decoded-derived and the other names a
best-so-far (``bsf`` / ``theta`` / ``best`` / ``tau``), unless the
decoded side is itself a certified bound (its identifiers mention
lb/ub/bound/slack) or was cleansed by the recompute pattern
(``np.take`` gather or an ``.astype(np.float32)`` recompute).
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from repro.analysis.rules.common import (
    RawFinding, call_name, iter_scopes, last_attr, name_components,
    statements_in_order, _target_names, _walk_stmts,
)

RULE_ID = "exactness-invariant"
DESCRIPTION = ("comparisons of decoded/codec values against the BSF must "
               "flow through certified LB/UB slack or a float32 "
               "difference-form recompute, never a raw <=")

_BSF_COMPONENTS = {"bsf", "theta", "best", "tau"}
_BOUND_COMPONENTS = {"lb", "ub", "lower", "upper", "bound", "bounds",
                     "slack", "certified"}
_COMPARE_OPS = (ast.Lt, ast.LtE, ast.Gt, ast.GtE)


def _names_in(expr: ast.expr) -> Set[str]:
    out = set()
    for node in ast.walk(expr):
        if isinstance(node, ast.Name):
            out.add(node.id)
        elif isinstance(node, ast.Attribute):
            out.add(node.attr)
    return out


def _components_in(expr: ast.expr) -> Set[str]:
    comps: Set[str] = set()
    for name in _names_in(expr):
        comps |= name_components(name)
    return comps


def _is_float32_recompute(expr: ast.expr) -> bool:
    """``x.astype(np.float32)`` / ``np.float32(...)`` / ``np.take`` —
    the sanctioned recompute/copy-gather cleansers."""
    for node in ast.walk(expr):
        if isinstance(node, ast.Call):
            tail = last_attr(call_name(node))
            if tail == "take":
                return True
            if tail == "astype":
                args = [ast.unparse(a) for a in node.args]
                if any("float32" in a or "float64" in a for a in args):
                    return True
            if tail in ("float32", "float64"):
                return True
    return False


class _DecodedTaint:
    """Names holding decoded/codec-reconstructed values in this scope."""

    def __init__(self):
        self.decoded: Set[str] = set()

    def expr_decoded(self, expr: ast.expr) -> bool:
        for node in ast.walk(expr):
            if isinstance(node, ast.Call) and \
                    last_attr(call_name(node)) == "decode":
                return True
            if isinstance(node, ast.Name) and node.id in self.decoded:
                return True
        return False

    def handle(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            value, targets = stmt.value, stmt.targets
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            value, targets = stmt.value, [stmt.target]
        elif isinstance(stmt, ast.AugAssign):
            if self.expr_decoded(stmt.value):
                for name in _target_names(stmt.target):
                    self.decoded.add(name)
            return
        else:
            return
        tainted = self.expr_decoded(value) and not _is_float32_recompute(value)
        for tgt in targets:
            for name in _target_names(tgt):
                if tainted:
                    self.decoded.add(name)
                else:
                    self.decoded.discard(name)


def check(tree: ast.Module, rel_path: str, src_lines,
          summaries=None) -> Iterator[RawFinding]:
    for scope in iter_scopes(tree):
        taint = _DecodedTaint()
        stmts = (_walk_stmts(scope.body) if isinstance(scope, ast.Module)
                 else statements_in_order(scope))
        for stmt in stmts:
            yield from _scan_compares(stmt, taint)
            taint.handle(stmt)


def _scan_compares(stmt: ast.stmt,
                   taint: _DecodedTaint) -> Iterator[RawFinding]:
    for node in ast.walk(stmt):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        if not (isinstance(node, ast.Compare) and len(node.ops) == 1 and
                isinstance(node.ops[0], _COMPARE_OPS)):
            continue
        left, right = node.left, node.comparators[0]
        for dec_side, bsf_side in ((left, right), (right, left)):
            if not taint.expr_decoded(dec_side):
                continue
            if not _components_in(bsf_side) & _BSF_COMPONENTS:
                continue
            if _components_in(dec_side) & _BOUND_COMPONENTS:
                continue    # certified LB/UB slack pattern
            if _is_float32_recompute(dec_side):
                continue    # sanctioned recompute
            yield RawFinding(
                RULE_ID, node.lineno, node.col_offset,
                f"raw BSF comparison against a decoded value "
                f"({ast.unparse(node)}): codec round-trip error is not "
                "accounted for, so true neighbours can be pruned. Compare "
                "certified LB/UB-with-slack instead, or recompute the "
                "distance in float32 difference form over a copied "
                "candidate pool (np.take + astype(np.float32)).")
            return  # one finding per statement
