"""Rule: atomic-commit ordering for on-disk index mutations.

The persistence format's crash-safety contract (PR 3/PR 4): every data
file an index references is written **and flushed** before the manifest
that names it, and the manifest lands via ``os.replace`` of a fsynced
temp file — the single atomic commit point. A crash can leave orphan
data files (the sweeper reclaims them) but never a manifest pointing at
missing or torn data.

Per function, the rule finds the commit point (a ``write_manifest(...)``
call, an ``os.replace`` whose arguments mention the manifest, or a
direct ``open(...manifest..., "w")``) and flags:

* any data mutation (``np.save`` / ``np.savez*`` / ``open_memmap`` /
  ``.flush()`` / write-mode ``open``) **after** the commit point;
* a direct write-mode ``open`` of a manifest path in a function with no
  ``os.replace`` — the non-atomic PR 4 shape.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.rules.common import (
    RawFinding, call_name, last_attr, statements_in_order, _walk_stmts,
)

RULE_ID = "atomic-commit"
DESCRIPTION = ("manifest commit (write_manifest / os.replace) must be the "
               "last mutation: no data writes after it, and manifest "
               "writes must go through an os.replace of a temp file")

_MUTATORS = {"save", "savez", "savez_compressed", "open_memmap", "flush",
             "tofile"}
_WRITE_MODES = ("w", "wb", "w+", "wb+", "a", "ab", "x", "xb")


def _mentions_manifest(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str) \
                and "manifest" in sub.value.lower():
            return True
        if isinstance(sub, ast.Name) and "manifest" in sub.id.lower():
            return True
        if isinstance(sub, ast.Attribute) and "manifest" in sub.attr.lower():
            return True
    return False


def _open_mode(call: ast.Call) -> Optional[str]:
    # only the builtin: `store.open(...)` / `Hercules.open(...)` are
    # handle constructors, not file writes
    if not (isinstance(call.func, ast.Name) and call.func.id == "open"):
        return None
    if len(call.args) >= 2 and isinstance(call.args[1], ast.Constant):
        return str(call.args[1].value)
    for kw in call.keywords:
        if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
            return str(kw.value.value)
    return "r"


def _is_commit_point(call: ast.Call) -> bool:
    name = call_name(call)
    tail = last_attr(name)
    if tail == "write_manifest":
        return True
    if name in ("os.replace", "os.rename") and _mentions_manifest(call):
        return True
    mode = _open_mode(call)
    if mode is not None and mode.startswith(_WRITE_MODES) and \
            _mentions_manifest(call):
        return True
    return False


def _is_mutation(call: ast.Call) -> bool:
    tail = last_attr(call_name(call))
    if tail in _MUTATORS:
        return True
    mode = _open_mode(call)
    if mode is not None and mode.startswith(_WRITE_MODES):
        return True
    return False


def check(tree: ast.Module, rel_path: str, src_lines,
          summaries=None) -> Iterator[RawFinding]:
    scopes = [tree]
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scopes.append(node)
    for scope in scopes:
        if isinstance(scope, ast.Module):
            stmts = list(_walk_stmts(scope.body))
        else:
            stmts = list(statements_in_order(scope))

        calls = []
        for stmt in stmts:
            from repro.analysis.rules.alias_transfer import header_exprs
            for expr in header_exprs(stmt):
                calls.extend(n for n in ast.walk(expr)
                             if isinstance(n, ast.Call))
        if not calls:
            continue

        commit: Optional[ast.Call] = None
        has_replace = any(call_name(c) in ("os.replace", "os.rename")
                          for c in calls)
        for call in calls:
            if commit is None and _is_commit_point(call):
                commit = call
                # a bare manifest open() with no replace anywhere in the
                # function is itself the non-atomic PR 4 shape
                if last_attr(call_name(call)) == "open" and not has_replace:
                    yield RawFinding(
                        RULE_ID, call.lineno, call.col_offset,
                        "manifest written in place without os.replace: a "
                        "crash mid-write leaves a torn manifest. Write to "
                        "a temp file, fsync, then os.replace it as the "
                        "single commit point (see write_manifest).")
                continue
            if commit is not None and call.lineno > commit.lineno and \
                    _is_mutation(call) and not _is_commit_point(call):
                yield RawFinding(
                    RULE_ID, call.lineno, call.col_offset,
                    f"data mutation ({ast.unparse(call.func)}) after the "
                    f"manifest commit point at line {commit.lineno}: a "
                    "crash between the two leaves a manifest referencing "
                    "unwritten data. Write+flush all data files first; "
                    "the manifest commit must be the last mutation.")
