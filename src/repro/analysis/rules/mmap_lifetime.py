"""Rule: mmap-lifetime — no view may outlive its index handle.

PR 4's segfault came from exactly this: a zero-copy ``jnp`` array over a
``SavedIndex`` memory map that had been ``close()``-d. The rule tracks
handles produced by ``open_index`` / ``open_saved`` / ``Hercules.open`` /
``Hercules.create`` (and raw ``np.load(mmap_mode=...)`` / ``np.memmap`` /
``open_memmap`` arrays), the views derived from them (``.lrd`` / ``.lsd``
/ ``._mapped()`` / slices / ``np.asarray``), and flags:

* any use of a derived view **after** ``handle.close()`` in the same
  scope (or after the handle's ``with`` block ends);
* ``return`` of a raw derived view from inside the handle's ``with``
  block (the view dies with the block — copy it first).

Copies (``np.array``, ``.copy()``, ``.astype()``, fancy indexing) break
the derivation chain, as does reassigning the handle (reopen).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.rules.common import (
    RawFinding, call_name, dotted, is_none_const, kwarg, last_attr,
    statements_in_order, _walk_stmts,
)
from repro.analysis.rules.alias_transfer import header_exprs

RULE_ID = "mmap-lifetime"
DESCRIPTION = ("a view of a memory-mapped index segment must not be used "
               "after close() or escape its with block; copy it first")

#: Calls whose result owns a memory map.
_OPEN_FUNCS = {"open_index", "open_saved", "open_memmap"}
_OPEN_DOTTED = {"Hercules.open", "Hercules.create", "np.memmap",
                "numpy.memmap"}
#: Attributes / methods on a handle that hand out mapped views.
_DERIVING_ATTRS = {"lrd", "lsd", "saved", "small"}
_DERIVING_METHODS = {"_mapped", "_lrd", "_lsd"}
#: Receiver attributes that are lifecycle management, not view reads.
_LIFECYCLE_ATTRS = {"close", "closed", "release", "flush", "path", "sync"}
_VIEW_PRESERVING = {"reshape", "ravel", "view", "transpose", "squeeze",
                    "swapaxes", "asarray", "ascontiguousarray"}


def _is_opener(call: ast.Call) -> bool:
    name = call_name(call)
    if name is None:
        return False
    if last_attr(name) in _OPEN_FUNCS or name in _OPEN_DOTTED:
        return True
    if last_attr(name) == "load":
        mm = kwarg(call, "mmap_mode")
        return mm is not None and not is_none_const(mm)
    return False


class _Derivations:
    """Maps local names to the handle they borrow their memory from.

    With a summary index (v2), calls to helpers summarised as
    ``returns_self_view`` derive from their receiver — the
    ``saved.rows()`` → private ``self._mapped()[lo:hi]`` chain that v1's
    name list could not see.
    """

    def __init__(self, summaries=None, path=None):
        self.summaries = summaries
        self.path = path
        self.handles: set = set()          # dotted handle names
        self.roots: Dict[str, str] = {}    # view name -> handle name

    def root_of(self, node: ast.expr) -> Optional[str]:
        """Handle that *node* borrows from, or None if it owns its memory."""
        if isinstance(node, ast.Name):
            if node.id in self.handles:
                return node.id
            return self.roots.get(node.id)
        if isinstance(node, ast.Attribute):
            full = dotted(node)
            if full in self.handles:
                return full
            if node.attr in _DERIVING_ATTRS or node.attr == "T":
                return self.root_of(node.value)
            return None
        if isinstance(node, ast.Subscript):
            root = self.root_of(node.value)
            if root is None:
                return None
            from repro.analysis.rules.common import _subscript_is_view
            return root if _subscript_is_view(node.slice) else None
        if isinstance(node, ast.Call):
            tail = last_attr(call_name(node))
            if tail in _DERIVING_METHODS and isinstance(node.func,
                                                        ast.Attribute):
                return self.root_of(node.func.value)
            if self.summaries is not None and \
                    isinstance(node.func, ast.Attribute) and \
                    self.summaries.returns_self_view(node, self.path):
                return self.root_of(node.func.value)
            if tail in _VIEW_PRESERVING:
                mod = call_name(node) or ""
                if mod.startswith(("jnp.", "jax.")):
                    return None
                if node.args:
                    return self.root_of(node.args[0])
                if isinstance(node.func, ast.Attribute):
                    return self.root_of(node.func.value)
            return None
        return None


def check(tree: ast.Module, rel_path: str, src_lines,
          summaries=None) -> Iterator[RawFinding]:
    scopes: List[ast.AST] = [tree]
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scopes.append(node)
    for scope in scopes:
        yield from _check_scope(scope, summaries, rel_path)


def _check_scope(scope: ast.AST, summaries=None,
                 rel_path=None) -> Iterator[RawFinding]:
    deriv = _Derivations(summaries=summaries, path=rel_path)
    closed: Dict[str, int] = {}            # handle -> close() lineno
    regions: List[Tuple[str, int]] = []    # (handle, with-block end lineno)

    if isinstance(scope, ast.Module):
        stmts = list(_walk_stmts(scope.body))
    else:
        stmts = list(statements_in_order(scope))

    for stmt in stmts:
        # handles whose `with` block ended before this statement are closed
        for handle, end in regions:
            if stmt.lineno > end and handle not in closed:
                closed[handle] = end

        # --- flag uses of views rooted at a closed handle ---------------
        for expr in header_exprs(stmt):
            for finding in _scan_uses(expr, deriv, closed, stmt):
                yield finding

        # --- flag raw-view returns inside the owning with block ---------
        if isinstance(stmt, ast.Return) and stmt.value is not None:
            root = deriv.root_of(stmt.value)
            if root is not None and any(
                    h == root and stmt.lineno <= end for h, end in regions):
                yield RawFinding(
                    RULE_ID, stmt.lineno, stmt.col_offset,
                    f"returning a raw view of '{root}' from inside its "
                    "with block: the memory map closes when the block "
                    "exits. Copy it (np.array / to_layout()) before "
                    "returning.")

        # --- track handle creation / closing / derivation ---------------
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                if isinstance(item.context_expr, ast.Call) and \
                        _is_opener(item.context_expr) and \
                        isinstance(item.optional_vars, ast.Name):
                    handle = item.optional_vars.id
                    deriv.handles.add(handle)
                    regions.append((handle, stmt.end_lineno or stmt.lineno))
        elif isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
            call = stmt.value
            if isinstance(call.func, ast.Attribute) and \
                    call.func.attr == "close":
                recv = dotted(call.func.value)
                if recv is not None and recv in deriv.handles:
                    closed[recv] = stmt.lineno
        elif isinstance(stmt, ast.Assign):
            tainted_root = deriv.root_of(stmt.value)
            opener = isinstance(stmt.value, ast.Call) and \
                _is_opener(stmt.value)
            for tgt in stmt.targets:
                name = tgt.id if isinstance(tgt, ast.Name) else dotted(tgt)
                if name is None:
                    continue
                if opener:
                    deriv.handles.add(name)
                    closed.pop(name, None)   # reopen
                    deriv.roots.pop(name, None)
                elif tainted_root is not None:
                    deriv.roots[name] = tainted_root
                else:
                    deriv.roots.pop(name, None)
                    if name in deriv.handles and not opener:
                        # handle rebound to something else
                        deriv.handles.discard(name)
                        closed.pop(name, None)


def _scan_uses(expr: ast.expr, deriv: _Derivations, closed: Dict[str, int],
               stmt: ast.stmt) -> Iterator[RawFinding]:
    if not closed:
        return
    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute) and \
                node.attr in _LIFECYCLE_ATTRS:
            continue
        if isinstance(node, ast.Name) and \
                isinstance(node.ctx, ast.Store):
            continue    # assignment target (e.g. a reopen), not a read
        if isinstance(node, (ast.Name, ast.Attribute, ast.Call,
                             ast.Subscript)):
            root = deriv.root_of(node)
            if root is not None and root in closed:
                # lifecycle calls on the closed handle are fine
                if isinstance(node, ast.Name) and _only_lifecycle_use(
                        expr, node):
                    continue
                yield RawFinding(
                    RULE_ID, node.lineno, node.col_offset,
                    f"'{ast.unparse(node)}' borrows from '{root}', which "
                    f"was closed at line {closed[root]}: a view of a "
                    "closed memory map is undefined behaviour (the PR 4 "
                    "segfault). Copy before close, or reorder.")
                return  # one finding per statement is enough


def _only_lifecycle_use(expr: ast.expr, name_node: ast.Name) -> bool:
    """True when the name only appears as `name.close()` / `name.closed`."""
    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute) and node.value is name_node:
            return node.attr in _LIFECYCLE_ATTRS
    return False
