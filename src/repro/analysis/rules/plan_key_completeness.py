"""Rule: plan-key-completeness — everything a plan reads must key it.

The plan-cache bug class this repo has shipped twice: a compiled plan is
cached under a key, the plan *producer* reads state the key does not
mention, and a later lookup reuses a plan compiled for different
semantics. PR 8 had to add ``SearchConfig.codec`` to the key by hand;
PR 9 had to add the backend mesh signature. This rule automates the
audit.

For every function containing a plan-cache store
(``self._plans[key] = make_...(...)`` / ``self._programs[cfg] = ...`` —
any container whose name mentions plan/program), the rule resolves the
key expression (a tuple assigned to the key name, or the indexing
expression itself) and flags:

* a ``cfg.<field>`` / ``config.<field>`` attribute read anywhere in the
  function whose field is not covered by the key (bare ``cfg`` in the
  key covers all fields via frozen-dataclass equality);
* backend state (``self.<attr>`` dotted reads) consumed by the producer
  call but absent from the key — unless the key carries a
  ``plan_signature`` element, the established convention for folding a
  backend's identity into the key.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Set, Tuple

from repro.analysis.rules.common import (
    RawFinding, dotted, name_components, statements_in_order,
)

RULE_ID = "plan-key-completeness"
DESCRIPTION = ("every SearchConfig field and backend attribute a cached "
               "plan's producer reads must appear in the plan-cache key "
               "or its plan_signature element")

_CONTAINER_COMPONENTS = {"plan", "plans", "program", "programs"}
_CFG_COMPONENTS = {"cfg", "config"}

#: self.<attr> reads in a producer that do not parameterise the compiled
#: plan: the cache container itself and lifecycle/telemetry plumbing.
_BENIGN_SELF_ATTRS = {"_t", "stats", "telemetry"}


def _is_plan_container(expr: ast.expr) -> bool:
    name = dotted(expr)
    if name is None:
        return False
    return bool(name_components(name.replace(".", "_"))
                & _CONTAINER_COMPONENTS)


def _functions(tree: ast.Module) -> Iterator[ast.AST]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def check(tree: ast.Module, rel_path: str, src_lines,
          summaries=None) -> Iterator[RawFinding]:
    for fn in _functions(tree):
        yield from _check_function(fn)


def _check_function(fn: ast.AST) -> Iterator[RawFinding]:
    key_tuples: Dict[str, ast.Tuple] = {}
    stores: List[Tuple[ast.expr, ast.expr, ast.stmt]] = []
    # (key expr, producer expr, store stmt) per plan-cache write

    for stmt in statements_in_order(fn):
        if isinstance(stmt, ast.Assign):
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name) and \
                        isinstance(stmt.value, ast.Tuple):
                    key_tuples[tgt.id] = stmt.value
                if isinstance(tgt, ast.Subscript) and \
                        _is_plan_container(tgt.value):
                    stores.append((tgt.slice, stmt.value, stmt))

    for key_expr, producer, stmt in stores:
        if isinstance(key_expr, ast.Name) and key_expr.id in key_tuples:
            elements = list(key_tuples[key_expr.id].elts)
        elif isinstance(key_expr, ast.Tuple):
            elements = list(key_expr.elts)
        else:
            elements = [key_expr]
        element_srcs = [ast.unparse(e) for e in elements]
        covered = " ".join(element_srcs)
        whole_names: Set[str] = {e.id for e in elements
                                 if isinstance(e, ast.Name)}
        has_signature = "plan_signature" in covered

        # --- (1) cfg fields read anywhere in the function ---------------
        flagged_fields: Set[str] = set()
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Attribute) and
                    isinstance(node.value, ast.Name)):
                continue
            recv = node.value.id
            if not name_components(recv) & _CFG_COMPONENTS:
                continue
            if recv in whole_names:
                continue        # cfg itself is in the key: all fields keyed
            ref = f"{recv}.{node.attr}"
            if any(ref in src for src in element_srcs) or \
                    node.attr in flagged_fields:
                continue
            flagged_fields.add(node.attr)
            yield RawFinding(
                RULE_ID, node.lineno, node.col_offset,
                f"'{ref}' steers the cached plan at line {stmt.lineno} but "
                f"the plan-cache key ({', '.join(element_srcs)}) does not "
                f"include it: a config differing only in '{node.attr}' "
                "would reuse a plan compiled for different semantics. Put "
                f"'{recv}' itself (or '{ref}') in the key.")

        # --- (2) backend state read by the producer ---------------------
        if has_signature:
            continue
        # the callee of `self._build(...)` is the factory, not state the
        # plan bakes in; its *receiver* (`self.backend.make_plan`) and
        # its arguments are state
        callees = {id(n.func) for n in ast.walk(producer)
                   if isinstance(n, ast.Call)}
        flagged_attrs: Set[str] = set()
        for node in ast.walk(producer):
            if not (isinstance(node, ast.Attribute) and
                    isinstance(node.value, ast.Name) and
                    node.value.id == "self"):
                continue
            if id(node) in callees:
                continue
            if node.attr in _BENIGN_SELF_ATTRS or node.attr in flagged_attrs:
                continue
            ref = f"self.{node.attr}"
            if any(ref in src for src in element_srcs):
                continue
            flagged_attrs.add(node.attr)
            yield RawFinding(
                RULE_ID, node.lineno, node.col_offset,
                f"plan producer reads '{ref}' but the plan-cache key "
                f"({', '.join(element_srcs)}) carries neither it nor a "
                "plan_signature element: if this state can differ between "
                "instances sharing the cache (or change across reopen), "
                "stale plans serve wrong answers. Fold it into a "
                "plan_signature tuple and key on that (the PR 9 mesh "
                "convention).")
