"""Rule: alias-unsafe device transfer (the PR 4 / PR 5 bug class).

On CPU jax, ``jax.device_put`` and ``jnp.asarray`` can return a
**zero-copy alias** of an aligned host buffer. Applied to a reusable
reader slot, the daemon thread refills the buffer under the "device"
array mid-computation (PR 5); applied to a memory map, closing the index
turns the array into a segfault (PR 4). The only safe transfers for such
values are ``reader.stage(view)`` or ``jnp.array(view, copy=True)``.

Flags ``jax.device_put(x)``, ``jnp.asarray(x)`` and ``jnp.array(x)``
without ``copy=True`` where *x* is taint-tracked as a mapped segment /
slot view (see :class:`repro.analysis.rules.common.TaintTracker`).
"""
from __future__ import annotations

import ast
from typing import Iterator, List

from repro.analysis.rules.common import (
    RawFinding, TaintTracker, call_name, is_true_const, iter_scopes, kwarg,
)

RULE_ID = "alias-transfer"
DESCRIPTION = ("jax.device_put / jnp.asarray / copy-less jnp.array on an "
               "mmap segment or reader-slot view can zero-copy alias it; "
               "use reader.stage(view) or jnp.array(view, copy=True)")


def _jnp_aliases(tree: ast.Module):
    """Names bound to jax.numpy and to bare device_put in this module."""
    jnp_names = {"jnp"}
    device_put_names = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "jax.numpy" and a.asname:
                    jnp_names.add(a.asname)
        elif isinstance(node, ast.ImportFrom):
            if node.module == "jax":
                for a in node.names:
                    if a.name == "numpy":
                        jnp_names.add(a.asname or "numpy")
                    elif a.name == "device_put":
                        device_put_names.add(a.asname or "device_put")
    return jnp_names, device_put_names


def _sink_kind(call: ast.Call, jnp_names, device_put_names):
    name = call_name(call)
    if name is None:
        return None
    if name == "jax.device_put" or name in device_put_names:
        return "jax.device_put"
    root, _, tail = name.rpartition(".")
    if tail == "asarray" and (root in jnp_names or root == "jax.numpy"):
        return f"{root}.asarray"
    if tail == "array" and (root in jnp_names or root == "jax.numpy"):
        if not is_true_const(kwarg(call, "copy")):
            return f"{root}.array without copy=True"
    return None


def header_exprs(stmt: ast.stmt) -> List[ast.expr]:
    """Expressions evaluated by *stmt* itself (not by nested statements)."""
    if isinstance(stmt, ast.Assign):
        return [stmt.value, *stmt.targets]
    if isinstance(stmt, ast.AugAssign):
        return [stmt.value, stmt.target]
    if isinstance(stmt, ast.AnnAssign):
        return [stmt.value] if stmt.value is not None else []
    if isinstance(stmt, (ast.Expr, ast.Return)):
        return [stmt.value] if stmt.value is not None else []
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return [stmt.iter]
    if isinstance(stmt, (ast.While, ast.If)):
        return [stmt.test]
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        return [item.context_expr for item in stmt.items]
    if isinstance(stmt, ast.Raise):
        return [e for e in (stmt.exc, stmt.cause) if e is not None]
    if isinstance(stmt, ast.Assert):
        return [e for e in (stmt.test, stmt.msg) if e is not None]
    if isinstance(stmt, ast.Delete):
        return list(stmt.targets)
    return []


def check(tree: ast.Module, rel_path: str, src_lines,
          summaries=None) -> Iterator[RawFinding]:
    jnp_names, device_put_names = _jnp_aliases(tree)
    for scope in iter_scopes(tree):
        taint = TaintTracker(scope, summaries=summaries, path=rel_path)
        for stmt in _scope_statements(scope):
            for expr in header_exprs(stmt):
                for call in (n for n in ast.walk(expr)
                             if isinstance(n, ast.Call)):
                    sink = _sink_kind(call, jnp_names, device_put_names)
                    if sink and call.args and taint.is_tainted(call.args[0]):
                        yield RawFinding(
                            RULE_ID, call.lineno, call.col_offset,
                            f"{sink} applied to a possible mmap/slot view "
                            f"({ast.unparse(call.args[0])}): zero-copy "
                            "aliasing lets the reader thread (or close()) "
                            "mutate it under the device array. Use "
                            "reader.stage(view) or "
                            "jnp.array(view, copy=True).")
            if isinstance(stmt, (ast.For, ast.AsyncFor)):
                taint.handle_for(stmt)
            else:
                taint.handle_assign(stmt)


def _scope_statements(scope):
    from repro.analysis.rules.common import statements_in_order
    if isinstance(scope, ast.Module):
        # module scope: only top-level statements outside functions
        yield from _module_stmts(scope)
    else:
        yield from statements_in_order(scope)


def _module_stmts(tree: ast.Module):
    from repro.analysis.rules.common import _walk_stmts
    yield from _walk_stmts(tree.body)
