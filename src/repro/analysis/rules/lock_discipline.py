"""Rule: lock-discipline for classes that own worker threads.

``AsyncChunkReader`` runs a daemon reader thread; the contract is that
the thread and the consumer communicate **only** through the slot
protocol (the ``_free`` / ``_ready`` queues) or under an owning lock.
Any instance attribute mutated from both the worker context and consumer
methods without a lock is a data race (dict/​counter updates are not
atomic across the interpreter's eyes-free boundaries, and torn telemetry
was an actual PR 5 review catch).

Per class, the rule finds thread entry points
(``threading.Thread(target=self.X)``), closes them over the
self-method call graph to get the worker context, and flags attributes
stored (including item-assignment like ``self.stats[k] = v``) without a
lock from **both** sides. ``__init__`` stores are construction, not
racing, and are excluded.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.rules.common import RawFinding, call_name, dotted

RULE_ID = "lock-discipline"
DESCRIPTION = ("attributes mutated from a worker-thread context must be "
               "touched only under the owning lock or via the queue/slot "
               "protocol")

_LOCK_FACTORIES = {"Lock", "RLock", "Condition", "Semaphore",
                   "BoundedSemaphore"}


def check(tree: ast.Module, rel_path: str, src_lines,
          summaries=None) -> Iterator[RawFinding]:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            yield from _check_class(node)


def _check_class(cls: ast.ClassDef) -> Iterator[RawFinding]:
    methods: Dict[str, ast.FunctionDef] = {
        n.name: n for n in cls.body
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    }
    if not methods:
        return

    entries: Set[str] = set()
    lock_attrs: Set[str] = set()
    for m in methods.values():
        for sub in ast.walk(m):
            if isinstance(sub, ast.Call):
                name = call_name(sub)
                if name == "threading.Thread" or \
                        (name and name.rsplit(".", 1)[-1] == "Thread"):
                    tgt = _thread_target(sub)
                    if tgt is not None:
                        entries.add(tgt)
            elif isinstance(sub, ast.Assign) and isinstance(
                    sub.value, ast.Call):
                vname = call_name(sub.value) or ""
                if vname.rsplit(".", 1)[-1] in _LOCK_FACTORIES:
                    for t in sub.targets:
                        attr = _self_attr(t)
                        if attr is not None:
                            lock_attrs.add(attr)
    if not entries:
        return

    # worker context = thread entries closed over the self-call graph
    calls: Dict[str, Set[str]] = {
        name: {
            sub.func.attr for sub in ast.walk(m)
            if isinstance(sub, ast.Call)
            and isinstance(sub.func, ast.Attribute)
            and isinstance(sub.func.value, ast.Name)
            and sub.func.value.id == "self"
            and sub.func.attr in methods
        }
        for name, m in methods.items()
    }
    worker: Set[str] = set()
    frontier = list(entries & set(methods))
    while frontier:
        name = frontier.pop()
        if name in worker:
            continue
        worker.add(name)
        frontier.extend(calls.get(name, ()))

    worker_stores: Dict[str, ast.stmt] = {}
    consumer_stores: Dict[str, ast.stmt] = {}
    for name, m in methods.items():
        if name in ("__init__", "__new__"):
            continue
        sink = worker_stores if name in worker else consumer_stores
        for attr, node, locked in _stores(m, lock_attrs):
            if not locked and attr not in sink:
                sink[attr] = node

    for attr in sorted(set(worker_stores) & set(consumer_stores)):
        node = worker_stores[attr]
        yield RawFinding(
            RULE_ID, node.lineno, node.col_offset,
            f"'self.{attr}' is mutated from the worker-thread context "
            f"({cls.name}) and from consumer methods without a lock: "
            "route the value through the ready/free queue protocol or "
            "guard both sides with the owning lock.")


def _thread_target(call: ast.Call) -> Optional[str]:
    for kw in call.keywords:
        if kw.arg == "target":
            attr = _self_attr(kw.value)
            if attr is not None:
                return attr
            name = dotted(kw.value)
            return name.rsplit(".", 1)[-1] if name else None
    return None


def _self_attr(node: ast.AST) -> Optional[str]:
    """'x' for `self.x` (including through subscripts: `self.x[k]`)."""
    if isinstance(node, ast.Subscript):
        return _self_attr(node.value)
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _stores(method: ast.FunctionDef, lock_attrs: Set[str]) \
        -> List[Tuple[str, ast.stmt, bool]]:
    out: List[Tuple[str, ast.stmt, bool]] = []

    def visit(stmts, locked: bool):
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    attr = _self_attr(t)
                    if attr is not None:
                        out.append((attr, stmt, locked))
            elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                attr = _self_attr(stmt.target)
                if attr is not None:
                    out.append((attr, stmt, locked))
            inner_locked = locked
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    ctx = item.context_expr
                    held = _self_attr(ctx if not isinstance(ctx, ast.Call)
                                      else ctx.func)
                    if held in lock_attrs:
                        inner_locked = True
                visit(stmt.body, inner_locked)
                continue
            for field in ("body", "orelse", "finalbody"):
                inner = getattr(stmt, field, None)
                if inner:
                    visit(inner, locked)
            for handler in getattr(stmt, "handlers", []) or []:
                visit(handler.body, locked)

    visit(method.body, False)
    return out
