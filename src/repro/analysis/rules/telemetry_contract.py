"""Rule: telemetry-contract — counters and their declarations can't drift.

The unified ``Telemetry`` report (PR 8) is assembled from raw counter
dicts (``self._t[...]`` in the backends, ``self.stats[...]`` in the
readers) into declared ``*Telemetry`` dataclass sections. Two failure
modes have nearly shipped:

* **drift** — a call site bumps a counter key that no declared section
  field and no consumer ever reads: the bump is dead weight and the
  operator dashboards silently miss the signal the author thought they
  added;
* **dead counters** — a section declares a field nothing ever feeds:
  the report shows a frozen zero, indistinguishable from "healthy".

This rule is project-wide: it checks the file at hand against the
:class:`~repro.analysis.callgraph.TelemetryIndex` built over the whole
lint run (declared fields from every ``*Telemetry`` dataclass, fed keys
from every bump/assembly site, consumed keys from every
``telemetry()``/``stats()`` reader). Without a summary index the rule is
inert — there is no file-local way to know the project contract.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.rules.common import (
    RawFinding, dotted, last_attr,
)

RULE_ID = "telemetry-contract"
DESCRIPTION = ("every counter bumped at a call site must back a declared "
               "Telemetry section field (or a consumer), and every "
               "declared field must be fed by some bump")

_COUNTER_RECEIVERS = {"_t", "stats"}


def _is_counter_receiver(expr: ast.expr) -> bool:
    return last_attr(dotted(expr)) in _COUNTER_RECEIVERS


def check(tree: ast.Module, rel_path: str, src_lines,
          summaries=None) -> Iterator[RawFinding]:
    tix = getattr(summaries, "telemetry", None)
    if tix is None or not tix.declared:
        return

    valid_bump_keys = set(tix.declared) | set(tix.aliases) | tix.consumed

    # --- drift: bumps in this file against the project contract ---------
    for node in ast.walk(tree):
        if isinstance(node, ast.Subscript) and \
                isinstance(node.ctx, ast.Store) and \
                isinstance(node.slice, ast.Constant) and \
                isinstance(node.slice.value, str) and \
                _is_counter_receiver(node.value):
            key = node.slice.value
            if key not in valid_bump_keys:
                yield RawFinding(
                    RULE_ID, node.lineno, node.col_offset,
                    f"counter '{key}' is bumped here but no declared "
                    "*Telemetry section field, alias, or telemetry()/"
                    "stats() consumer reads it: the signal never reaches "
                    "the report. Declare it as a section field (and "
                    "assemble it) or drop the bump.")

    # --- dead counters: declarations in this file never fed -------------
    alive = tix.fed | set(tix.aliases.values())
    for field, (path, line) in sorted(tix.declared.items()):
        if path != rel_path:
            continue
        if field not in alive:
            yield RawFinding(
                RULE_ID, line, 0,
                f"Telemetry section field '{field}' is declared here but "
                "nothing ever feeds it (no counter bump, dict-literal "
                "init, or assembly kwarg): the report will show a frozen "
                "default, indistinguishable from a healthy zero. Wire a "
                "bump or delete the field.")
