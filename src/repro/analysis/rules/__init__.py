"""herculint rule registry.

Each rule module exposes ``RULE_ID``, ``DESCRIPTION`` and
``check(tree, rel_path, src_lines, summaries=None) ->
Iterable[RawFinding]``. The engine (:mod:`repro.analysis.herculint`)
attaches file paths, enclosing-scope qualnames and ratchet fingerprints;
``summaries`` is the project-wide interprocedural
:class:`~repro.analysis.callgraph.SummaryIndex` (v2 — rules that don't
need it ignore it).
"""
from repro.analysis.rules import (
    alias_transfer,
    atomic_commit,
    config_plumbing,
    exactness_invariant,
    lock_discipline,
    mmap_lifetime,
    plan_key_completeness,
    telemetry_contract,
)

#: v1 rule set — single-scope heuristics only. Kept addressable so the
#: benchmarks (and the v1-vs-v2 meta-tests) can run the old engine shape.
V1_RULES = (
    alias_transfer,
    mmap_lifetime,
    atomic_commit,
    lock_discipline,
    config_plumbing,
)

ALL_RULES = V1_RULES + (
    plan_key_completeness,
    exactness_invariant,
    telemetry_contract,
)

RULE_IDS = tuple(r.RULE_ID for r in ALL_RULES)
