"""herculint rule registry.

Each rule module exposes ``RULE_ID``, ``DESCRIPTION`` and
``check(tree, rel_path, src_lines) -> Iterable[RawFinding]``. The engine
(:mod:`repro.analysis.herculint`) attaches file paths, enclosing-scope
qualnames and ratchet fingerprints.
"""
from repro.analysis.rules import (
    alias_transfer,
    atomic_commit,
    config_plumbing,
    lock_discipline,
    mmap_lifetime,
)

ALL_RULES = (
    alias_transfer,
    mmap_lifetime,
    atomic_commit,
    lock_discipline,
    config_plumbing,
)

RULE_IDS = tuple(r.RULE_ID for r in ALL_RULES)
