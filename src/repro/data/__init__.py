from repro.data.synthetic import (  # noqa: F401
    random_walks, make_query_workload, DIFFICULTY_LEVELS,
)
