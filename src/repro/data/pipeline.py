"""Double-buffered host->device data pipeline (the paper's DBuffer, §3.3).

The paper overlaps disk reads with tree inserts via a two-slot buffer and a
coordinator thread. The JAX analogue overlaps host batch generation with
device compute: while the device works on batch t, the host prepares and
transfers batch t+1 (``jax.device_put`` is async). State is (seed, step) so
a restarted worker regenerates exactly the same stream (the fault-tolerance
contract used by launch/train.py).

This module also owns the **chunk sources** feeding the out-of-core build
(``core/tree.py::build_tree_chunked`` and ``repro/storage``): a
:class:`ChunkSource` carves one series collection into fixed-size row chunks
with stable boundaries, re-iterable any number of times (the chunked build
makes two passes per round). :class:`ArrayChunkSource` wraps an in-memory
array; :class:`NpyChunkSource` memory-maps a ``.npy`` file so a chunk's rows
are only read from disk when sliced. :func:`iter_device_chunks` streams any
source through the two-slot buffer: chunk i+1's (async) host→device transfer
is issued before chunk i is handed to the consumer, so the copy overlaps the
consumer's compute.
"""
from __future__ import annotations

from typing import Callable, Iterator, Protocol, runtime_checkable

import jax
import numpy as np


class DoubleBufferedLoader:
    """Prefetching loader over a deterministic batch function.

    ``make_batch(step) -> pytree of np/jnp arrays`` must be pure in ``step``.
    """

    def __init__(self, make_batch: Callable[[int], dict], start_step: int = 0,
                 device=None):
        self._make = make_batch
        self._step = start_step
        self._device = device or jax.devices()[0]
        self._next = self._stage(self._step)

    def _stage(self, step: int):
        host = self._make(step)
        # async transfer: returns immediately, compute overlaps the copy
        return jax.tree.map(lambda x: jax.device_put(x, self._device), host)

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        batch = self._next
        self._step += 1
        self._next = self._stage(self._step)   # prefetch t+1 while t runs
        return batch

    @property
    def state(self) -> int:
        """Checkpointable pipeline state: the next step index."""
        return self._step


# ---------------------------------------------------------------------------
# Chunk sources (out-of-core ingest)
# ---------------------------------------------------------------------------

@runtime_checkable
class ChunkSource(Protocol):
    """A series collection carved into fixed-size row chunks.

    Chunk boundaries are a pure function of (num_series, chunk_size), so
    repeated iterations see identical chunks — the contract the two-pass
    chunked build rounds rely on. ``chunk(i)`` returns host rows
    ``[i * chunk_size, min((i + 1) * chunk_size, num_series))`` as float32.
    """

    num_series: int
    series_len: int
    chunk_size: int

    @property
    def num_chunks(self) -> int: ...

    def chunk(self, i: int) -> np.ndarray: ...


class _ChunkedBase:
    """Shared chunk arithmetic over a row-sliceable backing store."""

    def __init__(self, rows, chunk_size: int):
        if chunk_size <= 0:
            raise ValueError("chunk_size must be positive")
        self._rows = rows
        self.num_series = int(rows.shape[0])
        self.series_len = int(rows.shape[1])
        self.chunk_size = int(chunk_size)

    @property
    def num_chunks(self) -> int:
        return -(-self.num_series // self.chunk_size)

    def chunk(self, i: int) -> np.ndarray:
        if not 0 <= i < self.num_chunks:
            raise IndexError(f"chunk {i} out of range ({self.num_chunks})")
        lo = i * self.chunk_size
        hi = min(lo + self.chunk_size, self.num_series)
        return np.asarray(self._rows[lo:hi], dtype=np.float32)


class ArrayChunkSource(_ChunkedBase):
    """Chunk view over an in-memory (N, n) array — tests and the
    chunked-vs-one-shot equality harness."""

    def __init__(self, data, chunk_size: int):
        super().__init__(np.asarray(data), chunk_size)


class NpyChunkSource(_ChunkedBase):
    """Chunk view over an on-disk ``.npy`` file via ``np.load(mmap_mode="r")``
    — rows hit RAM only when a chunk is sliced, so the build's host
    footprint is one chunk, not the collection."""

    def __init__(self, path: str, chunk_size: int):
        mm = np.load(path, mmap_mode="r")
        if mm.ndim != 2:
            raise ValueError(f"{path}: expected a 2-D series collection, "
                             f"got shape {mm.shape}")
        super().__init__(mm, chunk_size)
        self.path = path


def iter_chunks(source: ChunkSource) -> Iterator[tuple[int, np.ndarray]]:
    """Yield (row_start, host_chunk) over the whole source."""
    for i in range(source.num_chunks):
        yield i * source.chunk_size, source.chunk(i)


def iter_device_chunks(source: ChunkSource,
                       device=None) -> Iterator[tuple[int, jax.Array]]:
    """Yield (row_start, device_chunk) with two-slot prefetch (DBuffer):
    chunk i+1's async ``device_put`` is issued before chunk i is yielded,
    overlapping its copy with the consumer's compute on chunk i."""
    device = device or jax.devices()[0]
    n = source.num_chunks
    if n == 0:
        return
    staged = jax.device_put(source.chunk(0), device)
    for i in range(n):
        cur = staged
        if i + 1 < n:
            staged = jax.device_put(source.chunk(i + 1), device)
        yield i * source.chunk_size, cur
