"""Double-buffered host->device data pipeline (the paper's DBuffer, §3.3).

The paper overlaps disk reads with tree inserts via a two-slot buffer and a
coordinator thread. The JAX analogue overlaps host batch generation with
device compute: while the device works on batch t, the host prepares and
transfers batch t+1 (``jax.device_put`` is async). State is (seed, step) so
a restarted worker regenerates exactly the same stream (the fault-tolerance
contract used by launch/train.py).
"""
from __future__ import annotations

from typing import Callable, Iterator

import jax
import numpy as np


class DoubleBufferedLoader:
    """Prefetching loader over a deterministic batch function.

    ``make_batch(step) -> pytree of np/jnp arrays`` must be pure in ``step``.
    """

    def __init__(self, make_batch: Callable[[int], dict], start_step: int = 0,
                 device=None):
        self._make = make_batch
        self._step = start_step
        self._device = device or jax.devices()[0]
        self._next = self._stage(self._step)

    def _stage(self, step: int):
        host = self._make(step)
        # async transfer: returns immediately, compute overlaps the copy
        return jax.tree.map(lambda x: jax.device_put(x, self._device), host)

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        batch = self._next
        self._step += 1
        self._next = self._stage(self._step)   # prefetch t+1 while t runs
        return batch

    @property
    def state(self) -> int:
        """Checkpointable pipeline state: the next step index."""
        return self._step
