"""Double-buffered host->device data pipeline (the paper's DBuffer, §3.3).

The paper overlaps disk reads with tree inserts via a two-slot buffer and a
coordinator thread. The JAX analogue overlaps host batch generation with
device compute: while the device works on batch t, the host prepares and
transfers batch t+1 (``jax.device_put`` is async). State is (seed, step) so
a restarted worker regenerates exactly the same stream (the fault-tolerance
contract used by launch/train.py).

This module also owns the **chunk sources** feeding the out-of-core build
(``core/tree.py::build_tree_chunked`` and ``repro/storage``): a
:class:`ChunkSource` carves one series collection into fixed-size row chunks
with stable boundaries, re-iterable any number of times (the chunked build
makes two passes per round). :class:`ArrayChunkSource` wraps an in-memory
array; :class:`NpyChunkSource` memory-maps a ``.npy`` file so a chunk's rows
are only read from disk when sliced. :func:`iter_device_chunks` streams any
source through the two-slot buffer: chunk i+1's (async) host→device transfer
is issued before chunk i is handed to the consumer, so the copy overlaps the
consumer's compute.

Disk reads themselves are scheduled by the **chunk readers**
(:func:`make_chunk_reader`). The synchronous double-buffer above only
overlaps the host→device *copy*; the memmap *read* — where an out-of-core
collection actually pays its page faults — still blocks the consumer. With
``prefetch="thread"`` an :class:`AsyncChunkReader` (the paper's DBuffer
coordinator thread; ParIS+'s read/insert overlap) fills a bounded set of
reusable host slot buffers from a daemon thread, so read, host→device copy,
and device compute all overlap. Extents are served strictly in submission
order (deterministic — answers stay bit-identical to ``prefetch="sync"``),
reader-side exceptions re-raise at the consumer's ``get()``, and ``close()``
joins the thread. ``prefetch="sync"`` (:class:`SyncChunkReader`) keeps the
legacy inline reads behind the same surface and times them, so the two
modes are directly comparable via ``read_wait_seconds``/``overlap_blocks``.
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from typing import Callable, Iterator, Protocol, runtime_checkable

import jax
import numpy as np

from repro.analysis import sanitize

PREFETCH_MODES = ("sync", "thread")


class DoubleBufferedLoader:
    """Prefetching loader over a deterministic batch function.

    ``make_batch(step) -> pytree of np/jnp arrays`` must be pure in ``step``.
    """

    def __init__(self, make_batch: Callable[[int], dict], start_step: int = 0,
                 device=None):
        self._make = make_batch
        self._step = start_step
        self._device = device or jax.devices()[0]
        self._next = self._stage(self._step)

    def _stage(self, step: int):
        host = self._make(step)
        # async transfer: returns immediately, compute overlaps the copy
        return jax.tree.map(lambda x: jax.device_put(x, self._device), host)

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        batch = self._next
        self._step += 1
        self._next = self._stage(self._step)   # prefetch t+1 while t runs
        return batch

    @property
    def state(self) -> int:
        """Checkpointable pipeline state: the next step index."""
        return self._step


# ---------------------------------------------------------------------------
# Chunk sources (out-of-core ingest)
# ---------------------------------------------------------------------------

@runtime_checkable
class ChunkSource(Protocol):
    """A series collection carved into fixed-size row chunks.

    Chunk boundaries are a pure function of (num_series, chunk_size), so
    repeated iterations see identical chunks — the contract the two-pass
    chunked build rounds rely on. ``chunk(i)`` returns host rows
    ``[i * chunk_size, min((i + 1) * chunk_size, num_series))`` as float32.
    """

    num_series: int
    series_len: int
    chunk_size: int

    @property
    def num_chunks(self) -> int: ...

    def chunk(self, i: int) -> np.ndarray: ...


class _ChunkedBase:
    """Shared chunk arithmetic over a row-sliceable backing store."""

    def __init__(self, rows, chunk_size: int, dtype=np.float32):
        if chunk_size <= 0:
            raise ValueError("chunk_size must be positive")
        self._rows = rows
        self.num_series = int(rows.shape[0])
        self.series_len = int(rows.shape[1])
        self.chunk_size = int(chunk_size)
        # row element type: float32 raw series by default; codec-encoded
        # sources (format v3 ``enc.npy``) stream uint8 rows instead
        self.dtype = np.dtype(dtype)

    @property
    def num_chunks(self) -> int:
        return -(-self.num_series // self.chunk_size)

    def chunk(self, i: int) -> np.ndarray:
        if not 0 <= i < self.num_chunks:
            raise IndexError(f"chunk {i} out of range ({self.num_chunks})")
        lo = i * self.chunk_size
        hi = min(lo + self.chunk_size, self.num_series)
        return np.asarray(self._rows[lo:hi], dtype=self.dtype)


class ArrayChunkSource(_ChunkedBase):
    """Chunk view over an in-memory (N, n) array — tests and the
    chunked-vs-one-shot equality harness."""

    def __init__(self, data, chunk_size: int, dtype=np.float32):
        super().__init__(np.asarray(data), chunk_size, dtype)


class NpyChunkSource(_ChunkedBase):
    """Chunk view over an on-disk ``.npy`` file via ``np.load(mmap_mode="r")``
    — rows hit RAM only when a chunk is sliced, so the build's host
    footprint is one chunk, not the collection."""

    def __init__(self, path: str, chunk_size: int):
        mm = np.load(path, mmap_mode="r")
        if mm.ndim != 2:
            raise ValueError(f"{path}: expected a 2-D series collection, "
                             f"got shape {mm.shape}")
        super().__init__(mm, chunk_size)
        self.path = path


def iter_chunks(source: ChunkSource) -> Iterator[tuple[int, np.ndarray]]:
    """Yield (row_start, host_chunk) over the whole source."""
    for i in range(source.num_chunks):
        yield i * source.chunk_size, source.chunk(i)


# ---------------------------------------------------------------------------
# Chunk readers (disk-aware scheduling: the paper's DBuffer coordinator)
# ---------------------------------------------------------------------------

READ_STAT_KEYS = ("read_seconds", "read_wait_seconds", "overlap_blocks")


def _tally(telemetry: dict | None, stats: dict) -> None:
    """Accumulate a reader's read-timing stats into a shared telemetry dict
    (in place; ``blocks`` is deliberately excluded — consumers count their
    own blocks and must not double-count the reader's)."""
    if telemetry is None:
        return
    for key in READ_STAT_KEYS:
        telemetry[key] = telemetry.get(key, 0) + stats[key]


class SyncChunkReader:
    """Inline reads behind the reader surface (``prefetch="sync"``).

    ``get()`` performs the read it was submitted, into a fresh array (data
    rows copied out of the store, pad rows zeroed) — byte-identical values
    to the legacy per-piece fetch, with no buffer reuse, so the returned
    array is the caller's to keep. Because the copy faults the backing
    store's pages inside the timed region, ``read_wait_seconds`` counts
    the real synchronous disk wait — exactly what the threaded mode hides;
    ``overlap_blocks`` stays 0. Submission bounds match the threaded
    reader's slot capacity, keeping the two surfaces interchangeable.
    """

    def __init__(self, rows, capacity_rows: int, width: int,
                 dtype=np.float32, *, slots: int = 2):
        self._rows = rows
        self._capacity = max(int(capacity_rows), 1)
        self._width = int(width)
        self._dtype = np.dtype(dtype)
        self._reqs: collections.deque = collections.deque()
        self.stats = {"blocks": 0, "read_seconds": 0.0,
                      "read_wait_seconds": 0.0, "overlap_blocks": 0}
        self._closed = False

    def submit(self, start: int, count: int, pad_to: int | None = None):
        if self._closed:
            raise RuntimeError("reader is closed")
        if count <= 0:
            raise ValueError(f"count must be positive, got {count}")
        pad_to = count if pad_to is None else pad_to
        # same bound the threaded reader's slots enforce, so a consumer
        # cannot work under the default sync mode yet break under "thread"
        if not count <= pad_to <= self._capacity:
            raise ValueError(f"pad_to={pad_to} outside [count={count}, "
                             f"slot capacity={self._capacity}]")
        self._reqs.append((int(start), int(count), int(pad_to)))

    def get(self) -> np.ndarray:
        if self._closed:
            raise RuntimeError("reader is closed")
        if not self._reqs:
            raise RuntimeError("get() without a pending submit()")
        start, count, pad_to = self._reqs.popleft()
        t0 = time.perf_counter()
        out = np.empty((pad_to, self._width), self._dtype)
        out[:count] = self._rows[start:start + count]
        if pad_to > count:
            out[count:] = 0
        dt = time.perf_counter() - t0
        self.stats["read_seconds"] += dt
        self.stats["read_wait_seconds"] += dt
        self.stats["blocks"] += 1
        return out

    def stage(self, view: np.ndarray, device=None, *,
              block: bool = True) -> jax.Array:
        """Host→device transfer of a fetched block. Sync blocks are fresh
        arrays the transfer machinery keeps alive, so the async
        ``device_put`` needs no completion barrier (``block`` is accepted
        for surface parity with the threaded reader and ignored).

        ``device=None`` defers to jax's current default device — NOT a
        hardcoded ``jax.devices()[0]`` — so a consumer running under a
        ``jax.default_device(...)`` context (each dist-ooc shard pins its
        stream to its own mesh device that way) gets its blocks on the
        right device, same as the threaded reader's ``_staged_copy``."""
        del block
        if device is None:
            # herculint: ok[alias-transfer] -- sync get() returns a fresh buffer per call; nothing refills it, so a zero-copy alias is harmless
            return jax.device_put(view)
        # herculint: ok[alias-transfer] -- sync get() returns a fresh buffer per call; nothing refills it, so a zero-copy alias is harmless
        return jax.device_put(view, device)

    def close(self) -> None:
        self._closed = True
        self._reqs.clear()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _staged_copy(view: np.ndarray, device=None) -> jax.Array:
    """A device array guaranteed (once ready) to own memory independent of
    ``view`` — ``jnp.array(copy=True)``, unlike ``device_put``, may never
    zero-copy alias the host buffer."""
    import jax.numpy as jnp

    if device is None:
        return jnp.array(view, copy=True)
    with jax.default_device(device):
        return jnp.array(view, copy=True)


class AsyncChunkReader:
    """Daemon reader thread + bounded reusable host slots (DBuffer, §3.3).

    ``rows`` is any row-sliceable store (an ``np.memmap``, an ndarray, the
    store's concat views). ``submit(start, count, pad_to)`` enqueues one
    extent; ``get()`` serves extents **strictly in submission order** as
    views into one of ``slots`` reusable ``(capacity_rows, width)`` arrays.
    Each view is valid only until the next ``get()`` or ``close()`` — move
    it off-slot (``stage``) before requesting the next extent. Rows beyond
    ``count`` up to ``pad_to`` are zero-filled, matching the legacy
    zero-padded fetch byte for byte. A reader-side exception re-raises at
    the ``get()`` for the failing extent and ends the stream. ``close()``
    is idempotent, unblocks the thread wherever it waits, and joins it.
    """

    THREAD_NAME = "repro-chunk-reader"

    def __init__(self, rows, capacity_rows: int, width: int,
                 dtype=np.float32, *, slots: int = 2):
        if slots < 2:
            raise ValueError("need at least two slots (one computing, one "
                             "filling)")
        self._rows = rows
        self._slots = [np.empty((max(int(capacity_rows), 1), int(width)),
                                np.dtype(dtype)) for _ in range(slots)]
        self._requests: queue.SimpleQueue = queue.SimpleQueue()
        self._free: queue.SimpleQueue = queue.SimpleQueue()
        for i in range(slots):
            self._free.put(i)
        self._ready: queue.SimpleQueue = queue.SimpleQueue()
        self._held: int | None = None
        self._pending = 0
        self._stop = threading.Event()
        self._closed = False
        self._exc: BaseException | None = None
        self.stats = {"blocks": 0, "read_seconds": 0.0,
                      "read_wait_seconds": 0.0, "overlap_blocks": 0}
        # REPRO_SANITIZE=1: (slot_id, host snapshot, device array) per
        # stage(); verified against the poisoned slot at recycle time
        self._sanitize = sanitize.sanitize_enabled()
        self._staged_tracks: list[tuple[int, np.ndarray, jax.Array]] = []
        # The consumer surface (submit/get/stage) is single-owner by
        # contract: slot views and self.stats are driven by exactly one
        # thread, with the reader thread on the other side of the queues.
        # Binds to the first consuming thread, not the constructor —
        # building on main and consuming in a pool worker is legal.
        # close() is exempt: __del__ may run it from any thread.
        self._consumer = sanitize.ThreadAffinity(type(self).__name__)
        self._thread = threading.Thread(target=self._run,
                                        name=self.THREAD_NAME, daemon=True)
        self._thread.start()

    # -- reader thread -------------------------------------------------------

    def _fill(self, buf: np.ndarray, start: int, count: int,
              pad_to: int) -> None:
        buf[:count] = self._rows[start:start + count]
        if pad_to > count:
            buf[count:pad_to] = 0

    def _run(self) -> None:
        while True:
            req = self._requests.get()
            if req is None or self._stop.is_set():
                break
            sid = self._free.get()
            if sid is None or self._stop.is_set():
                break
            start, count, pad_to = req
            t0 = time.perf_counter()
            try:
                self._fill(self._slots[sid], start, count, pad_to)
            except BaseException as e:          # propagate to the consumer
                self._ready.put((None, 0, 0.0, e))
                break
            # the read duration rides the ready tuple: the worker must not
            # touch self.stats (consumer-owned; herculint lock-discipline)
            self._ready.put((sid, pad_to, time.perf_counter() - t0, None))

    # -- consumer side -------------------------------------------------------

    def _check_alive(self) -> None:
        if self._closed:
            raise RuntimeError("reader is closed")
        if self._exc is not None:
            raise RuntimeError("reader stream already failed") from self._exc

    def submit(self, start: int, count: int, pad_to: int | None = None):
        self._consumer.check("submit")
        self._check_alive()
        if count <= 0:
            raise ValueError(f"count must be positive, got {count}")
        pad_to = count if pad_to is None else pad_to
        if not count <= pad_to <= self._slots[0].shape[0]:
            raise ValueError(f"pad_to={pad_to} outside [count={count}, "
                             f"slot capacity={self._slots[0].shape[0]}]")
        self._pending += 1
        self._requests.put((int(start), int(count), int(pad_to)))

    def get(self) -> np.ndarray:
        self._consumer.check("get")
        self._check_alive()
        if self._pending <= 0:
            raise RuntimeError("get() without a pending submit()")
        self._pending -= 1
        if self._held is not None:              # recycle the previous view
            self._recycle(self._held)
            self._held = None
        overlapped = not self._ready.empty()    # read finished before asked
        t0 = time.perf_counter()
        sid, n_rows, read_s, exc = self._ready.get()
        self.stats["read_wait_seconds"] += time.perf_counter() - t0
        if exc is not None:
            # the reader thread has exited: latch the failure so later
            # get()/submit() fail loudly instead of blocking forever
            self._exc = exc
            raise exc
        self.stats["read_seconds"] += read_s
        self.stats["overlap_blocks"] += int(overlapped)
        self.stats["blocks"] += 1
        self._held = sid
        return self._slots[sid][:n_rows]

    def _recycle(self, sid: int) -> None:
        """Hand a slot back to the reader thread. Under REPRO_SANITIZE=1
        the slot is poisoned *first*, then every staged copy taken from it
        is re-checked against its snapshot — a zero-copy alias shows the
        canary and raises before the reader can overwrite live data."""
        if self._sanitize:
            sanitize.poison(self._slots[sid])
            self._verify_staged(sid)
        self._free.put(sid)

    def _verify_staged(self, sid: int) -> None:
        keep = []
        for slot_id, snap, dev in self._staged_tracks:
            if slot_id != sid:
                keep.append((slot_id, snap, dev))
        tracked = [t for t in self._staged_tracks if t[0] == sid]
        self._staged_tracks = keep              # drop before any raise
        for slot_id, snap, dev in tracked:
            sanitize.verify_staged(dev, snap, slot_id=slot_id)

    def stage(self, view: np.ndarray, device=None, *,
              block: bool = True) -> jax.Array:
        """Host→device transfer of a slot view, blocked to completion so the
        slot can be recycled at the next ``get()`` while async device
        compute on the staged copy proceeds. ``copy=True`` is load-bearing:
        a plain ``device_put`` may zero-copy *alias* an aligned numpy
        buffer on CPU jax, and an aliased slot would be overwritten by the
        reader thread mid-computation.

        ``block=False`` defers the completion barrier to the caller, who
        **must** ``jax.block_until_ready`` the result before the next
        ``get()`` (which recycles the slot the copy reads from) — the
        double-buffer loop uses this to overlap the copy with consumer
        compute."""
        self._consumer.check("stage")
        dev = _staged_copy(view, device)
        if block:
            jax.block_until_ready(dev)
        if self._sanitize and self._held is not None:
            self._staged_tracks.append(
                (self._held, sanitize.snapshot(view), dev))
        return dev

    def close(self) -> None:
        """Idempotent: stops and joins the reader thread (sentinels unblock
        it from whichever queue it waits on), invalidating every view."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self._requests.put(None)
        self._free.put(None)
        self._thread.join(timeout=30.0)
        if self._thread.is_alive():             # pragma: no cover
            raise RuntimeError("chunk reader thread failed to join")
        self._held = None
        if self._sanitize:
            # final sweep: poison every slot (the thread is joined, nothing
            # refills them) and verify any still-tracked staged copies
            for slot in self._slots:
                sanitize.poison(slot)
            tracked, self._staged_tracks = self._staged_tracks, []
            for slot_id, snap, dev in tracked:
                sanitize.verify_staged(dev, snap, slot_id=slot_id)

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:                       # pragma: no cover
            pass


def make_chunk_reader(rows, capacity_rows: int, width: int,
                      dtype=np.float32, *, prefetch: str = "sync",
                      slots: int = 2):
    """Reader over a row-sliceable store: ``"thread"`` → daemon-thread
    :class:`AsyncChunkReader`, ``"sync"`` → inline :class:`SyncChunkReader`
    (same surface, same bytes, so consumers have one code path)."""
    if prefetch not in PREFETCH_MODES:
        raise ValueError(f"prefetch={prefetch!r}; expected one of "
                         f"{PREFETCH_MODES}")
    cls = AsyncChunkReader if prefetch == "thread" else SyncChunkReader
    return cls(rows, capacity_rows, width, dtype, slots=slots)


def iter_scheduled_chunks(reader, requests, still_needed=None,
                          lookahead: int = 2, device=None
                          ) -> Iterator[tuple[object, jax.Array]]:
    """Demand-scheduled fetches over one shared chunk reader (the wave
    path's multi-consumer submissions).

    ``requests`` is an ordered iterable of ``(tag, start, count, pad_to)``
    — typically leaf runs sorted by how many consumers still need them.
    Each surviving request is fetched **once** and yielded as
    ``(tag, staged_device_rows)``; the tag tells the caller which run (and
    therefore which consumers) the block belongs to.

    ``still_needed(tag) -> bool`` is consulted immediately before each
    ``submit()`` — as late as possible — so a run whose every interested
    consumer has since been satisfied (e.g. all wave members' best-so-far
    bounds tightened past the run's lower bound while earlier blocks
    refined) is dropped without ever touching the disk. ``lookahead``
    bounds the number of in-flight submissions: large enough that reads
    overlap the consumer's compute (the reader's slot pair), small enough
    that the drop decision still sees a recent bound.
    """
    if lookahead < 1:
        raise ValueError(f"lookahead={lookahead}; expected >= 1")
    pending: collections.deque = collections.deque()
    it = iter(requests)

    def pump() -> None:
        while len(pending) < lookahead:
            for tag, start, count, pad_to in it:
                if still_needed is None or still_needed(tag):
                    reader.submit(start, count, pad_to)
                    pending.append(tag)
                    break
            else:
                return

    pump()
    while pending:
        tag = pending.popleft()
        rows = reader.stage(reader.get(), device)
        pump()                       # refill the window before the consumer
        yield tag, rows              # computes, so the next read overlaps


class _SourceRows:
    """Row-sliceable adapter over a protocol-only :class:`ChunkSource`
    (slices must align to the source's chunk boundaries — the whole-source
    iterators request exactly its chunks)."""

    def __init__(self, source: ChunkSource):
        self._source = source

    def __getitem__(self, sl: slice) -> np.ndarray:
        i, rem = divmod(sl.start, self._source.chunk_size)
        if rem:
            raise ValueError(f"row {sl.start} is not a chunk boundary of "
                             f"chunk_size={self._source.chunk_size}")
        return self._source.chunk(i)[:sl.stop - sl.start]


def _source_rows(source: ChunkSource):
    """The cheapest row-sliceable view of a source: its backing store when
    it has one (memmap reads land straight in the slot buffer), else the
    chunk-aligned adapter."""
    rows = getattr(source, "_rows", None)
    return _SourceRows(source) if rows is None else rows


def _whole_source_reader(source: ChunkSource, prefetch: str):
    """A reader with every chunk of ``source`` submitted, in order."""
    reader = make_chunk_reader(_source_rows(source), source.chunk_size,
                               source.series_len,
                               getattr(source, "dtype", np.float32),
                               prefetch=prefetch)
    num = source.num_series
    for i in range(source.num_chunks):
        lo = i * source.chunk_size
        reader.submit(lo, min(source.chunk_size, num - lo))
    return reader


def iter_host_chunks(source: ChunkSource, prefetch: str = "sync",
                     telemetry: dict | None = None
                     ) -> Iterator[tuple[int, np.ndarray]]:
    """Yield (row_start, host_chunk) over the whole source through a chunk
    reader. With ``prefetch="thread"`` the yielded chunk is a reusable slot
    view, valid only until the next iteration — consume (copy/scatter) it
    before advancing. Reader stats accumulate into ``telemetry``."""
    if prefetch == "sync" and telemetry is None:
        yield from iter_chunks(source)
        return
    reader = _whole_source_reader(source, prefetch)
    try:
        for i in range(source.num_chunks):
            yield i * source.chunk_size, reader.get()
    finally:
        reader.close()
        _tally(telemetry, reader.stats)


def iter_device_chunks(source: ChunkSource, device=None,
                       prefetch: str = "sync",
                       telemetry: dict | None = None
                       ) -> Iterator[tuple[int, jax.Array]]:
    """Yield (row_start, device_chunk) with two-slot prefetch (DBuffer).

    ``prefetch="sync"``: chunk i+1's async ``device_put`` is issued before
    chunk i is yielded, overlapping its copy with the consumer's compute on
    chunk i — but the memmap *read* of chunk i+1 still blocks here.
    ``prefetch="thread"``: an :class:`AsyncChunkReader` reads ahead into
    reusable host slots, so read, copy, and compute all overlap; each
    staged transfer is blocked to completion before its slot is recycled,
    which is what keeps the yielded device chunks immutable (and answers
    bit-identical to the sync path). Reader/read stats accumulate into
    ``telemetry`` (``read_wait_seconds``, ``overlap_blocks``, ...).

    Codec note: sources whose ``dtype`` is uint8 (format v3 encoded rows)
    stream encoded bytes through the very same machinery; the consumer
    decodes *after* the yield, i.e. after the disk wait — so the reader's
    prefetch of block i+1 overlaps block i's decode+refine compute.
    """
    device = device or jax.devices()[0]
    n = source.num_chunks
    if n == 0:
        return
    if prefetch not in PREFETCH_MODES:
        raise ValueError(f"prefetch={prefetch!r}; expected one of "
                         f"{PREFETCH_MODES}")
    reader = _whole_source_reader(source, prefetch)
    # both modes read through the reader: a sync get() copies the extent out
    # of the backing store (faulting its pages) inside the timed read, so
    # read_wait_seconds measures real disk wait — a raw memmap slice would
    # defer the page faults into device_put and under-report it as ~0
    try:
        if prefetch == "sync":
            # fresh per-chunk buffers: SyncChunkReader.stage is an async
            # device_put, so the transfer for chunk i+1 stays in flight
            # while the consumer computes on chunk i (the legacy
            # copy/compute overlap; nothing mutates the buffer)
            staged = reader.stage(reader.get(), device)
            for i in range(n):
                cur = staged
                if i + 1 < n:
                    staged = reader.stage(reader.get(), device)
                yield i * source.chunk_size, cur
        else:
            # block=False: the barrier is the block_until_ready(cur) below,
            # which always runs before the get() that recycles cur's slot
            staged = reader.stage(reader.get(), device, block=False)
            for i in range(n):
                cur = staged
                # copy committed -> the slot backing `cur` may be recycled
                # by the get() below while async compute on `cur` proceeds
                jax.block_until_ready(cur)
                if i + 1 < n:
                    staged = reader.stage(reader.get(), device, block=False)
                yield i * source.chunk_size, cur
    finally:
        reader.close()
        _tally(telemetry, reader.stats)
