"""Synthetic data-series generation (paper §4.1 Datasets/Queries).

* ``random_walks`` — the paper's *Synth* generator: cumulative sum of i.i.d.
  Gaussian(0, 1) steps, modelling financial series [23]; widely used in the
  data-series indexing literature [10, 23, 70].
* ``make_query_workload`` — the paper's query hardness protocol [69]: pick
  dataset series and perturb with Gaussian noise of variance sigma^2 in
  {0.01 .. 0.10} ("1%".."10%"), or draw fresh walks for *ood* queries.

All generators are pure functions of a PRNG key (restart-exact for the fault
tolerance story: pipeline state = (step, key)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

DIFFICULTY_LEVELS = ("1%", "2%", "5%", "10%", "ood")


def random_walks(key: jax.Array, num: int, length: int,
                 znorm: bool = True) -> jax.Array:
    """(num, length) float32 random-walk series (paper's Synth)."""
    steps = jax.random.normal(key, (num, length), dtype=jnp.float32)
    walks = jnp.cumsum(steps, axis=-1)
    if znorm:
        mu = jnp.mean(walks, axis=-1, keepdims=True)
        sd = jnp.maximum(jnp.std(walks, axis=-1, keepdims=True), 1e-8)
        walks = (walks - mu) / sd
    return walks


def make_query_workload(key: jax.Array, dataset: jax.Array, num_queries: int,
                        difficulty: str = "5%") -> jax.Array:
    """Queries of a given hardness from/against ``dataset`` (N, n).

    ``difficulty``: one of DIFFICULTY_LEVELS. Noise workloads select dataset
    series at random and add N(0, sigma^2) noise; *ood* draws independent
    random walks (the paper excludes ood queries from indexing — for synthetic
    data a fresh seed is the same thing).
    """
    if difficulty not in DIFFICULTY_LEVELS:
        raise ValueError(f"difficulty {difficulty!r} not in {DIFFICULTY_LEVELS}")
    n = dataset.shape[-1]
    if difficulty == "ood":
        return random_walks(key, num_queries, n)
    sigma2 = float(difficulty.rstrip("%")) / 100.0
    k_sel, k_noise = jax.random.split(key)
    idx = jax.random.randint(k_sel, (num_queries,), 0, dataset.shape[0])
    noise = jax.random.normal(k_noise, (num_queries, n)) * jnp.sqrt(sigma2)
    return dataset[idx] + noise.astype(jnp.float32)
