# The paper's primary contribution: the Hercules index — dual-summarization
# (EAPCA + iSAX) exact similarity search with adaptive access-path selection.
from repro.core.index import HerculesIndex, IndexConfig  # noqa: F401
from repro.core.layout import HerculesLayout, build_layout  # noqa: F401
from repro.core.search import (  # noqa: F401
    KnnResult, SearchConfig, approx_knn, brute_force_knn, exact_knn,
    pscan_knn,
)
from repro.core.tree import (  # noqa: F401
    BuildConfig, HerculesTree, build_tree, route_to_leaf, tree_stats,
)
