# The paper's primary contribution: the Hercules index — dual-summarization
# (EAPCA + iSAX) exact similarity search with adaptive access-path selection.
from repro.core.index import HerculesIndex, IndexConfig  # noqa: F401
from repro.core.layout import (  # noqa: F401
    HerculesLayout, LayoutGeometry, assemble_layout, build_layout,
    compute_layout_geometry,
)
from repro.core.search import (  # noqa: F401
    KnnResult, SearchConfig, approx_knn, brute_force_knn, exact_knn,
    pscan_knn, validate_runtime_config, wave_knn,
)
from repro.core.tree import (  # noqa: F401
    BuildConfig, HerculesTree, build_tree, build_tree_chunked, route_to_leaf,
    tree_stats,
)
# The unified serving surface: every caller above the core answers queries
# through a backend-agnostic QueryEngine (compiled-plan cache + telemetry).
from repro.core.engine import (  # noqa: F401
    BACKEND_NAMES, BACKENDS, DISK_BACKEND_NAMES, BackendSpec, EngineConfig,
    LocalBackend, OutOfCoreLocalBackend, OutOfCoreScanBackend, QueryEngine,
    ScanBackend, SearchBackend, ShardedBackend, Telemetry, backend_names,
    dense_scan_knn, kernel_scan_knn, make_backend, make_disk_backend,
    resolve_backend_name,
)
# Kernel execution-mode policy (SearchConfig.kernel_mode values).
from repro.kernels.compat import KERNEL_MODES, resolve_kernel_mode  # noqa: F401
