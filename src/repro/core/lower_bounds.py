"""Lower-bounding distances LB_EAPCA and LB_SAX (paper §2, §3.4).

Both bounds are *guaranteed* lower bounds on the squared Euclidean distance —
the no-false-dismissal property the paper's exactness rests on. The tests
(tests/test_lower_bounds.py) check this as a hypothesis property.

Math (LB_EAPCA, per DSTree [64]): for a segment of length l with candidate
mean/std (mu_s, sd_s) and query mean/std (mu_q, sd_q),

    sum_j (x_j - q_j)^2  =  l (mu_s - mu_q)^2 + ||x~ - q~||^2
                         >= l (mu_s - mu_q)^2 + (||x~|| - ||q~||)^2
                         =  l [ (mu_s - mu_q)^2 + (sd_s - sd_q)^2 ]

(the cross term vanishes because centered segments sum to zero; Cauchy-Schwarz
bounds the centered part; sd is the population std so ||x~|| = sqrt(l) sd).
At node granularity, (mu_s, sd_s) are relaxed to the node-synopsis intervals.

Math (LB_SAX / MINDIST [37]): per PAA segment of length l, the candidate's PAA
value lies in its iSAX cell [lo, hi]; the distance from the query's PAA value
p to the cell, d = max(lo - p, p - hi, 0), gives   LB^2 = l * sum_i d_i^2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import summaries as S


# ---------------------------------------------------------------------------
# LB_EAPCA
# ---------------------------------------------------------------------------

def lb_eapca_node(q_means: jax.Array, q_stds: jax.Array,
                  synopsis: jax.Array, seg_lens: jax.Array) -> jax.Array:
    """Squared LB_EAPCA between query segment stats and a node synopsis.

    ``q_means``/``q_stds``: (..., M) query stats under the *node's* segmentation.
    ``synopsis``: (..., M, 4) [mu_min, mu_max, sd_min, sd_max].
    ``seg_lens``: (..., M) float segment lengths (0 for padding).
    Returns (...,) squared lower bound. Broadcasts over leading dims.
    """
    mu_lo, mu_hi = synopsis[..., 0], synopsis[..., 1]
    sd_lo, sd_hi = synopsis[..., 2], synopsis[..., 3]
    dmu = jnp.maximum(jnp.maximum(mu_lo - q_means, q_means - mu_hi), 0.0)
    dsd = jnp.maximum(jnp.maximum(sd_lo - q_stds, q_stds - sd_hi), 0.0)
    per_seg = seg_lens * (jnp.square(dmu) + jnp.square(dsd))
    return jnp.sum(per_seg, axis=-1)


def lb_eapca_series(q_means: jax.Array, q_stds: jax.Array,
                    s_means: jax.Array, s_stds: jax.Array,
                    seg_lens: jax.Array) -> jax.Array:
    """Squared LB_EAPCA between query and an individual series' EAPCA stats.

    All stats (..., M) under a shared segmentation; returns (...,).
    """
    per_seg = seg_lens * (jnp.square(s_means - q_means) + jnp.square(s_stds - q_stds))
    return jnp.sum(per_seg, axis=-1)


# ---------------------------------------------------------------------------
# LB_SAX (MINDIST)
# ---------------------------------------------------------------------------

def lb_sax(q_paa: jax.Array, codes: jax.Array, series_len: int,
           alphabet: int = S.SAX_ALPHABET) -> jax.Array:
    """Squared LB_SAX between query PAA and candidate iSAX codes.

    ``q_paa``: (..., m) query PAA values.
    ``codes``: (..., m) uint8 iSAX codes (broadcast-compatible with q_paa).
    Returns broadcast shape minus the last axis, squared lower bound.

    This is the XLA reference form; the engine's phase-3 pruning dispatches
    to the Pallas kernel via ``repro.kernels.ops.lb_sax`` when
    ``SearchConfig.kernel_mode`` resolves to a Pallas mode (see
    ``core/search.py``).
    """
    m = q_paa.shape[-1]
    lo, hi = S.isax_cell_bounds(codes, alphabet)
    d = jnp.maximum(jnp.maximum(lo - q_paa, q_paa - hi), 0.0)
    seg_len = series_len / m
    return seg_len * jnp.sum(jnp.square(d), axis=-1)


# ---------------------------------------------------------------------------
# True distances (reference path; the Pallas kernel in kernels/ed.py is the
# production scan)
# ---------------------------------------------------------------------------

def squared_ed(a: jax.Array, b: jax.Array) -> jax.Array:
    """Exact squared Euclidean distance along the last axis (broadcasting)."""
    return jnp.sum(jnp.square(a - b), axis=-1)


def squared_ed_matrix(queries: jax.Array, series: jax.Array) -> jax.Array:
    """(Q, n) x (N, n) -> (Q, N) squared ED via the matmul identity.

    ||q - s||^2 = ||q||^2 + ||s||^2 - 2 q.s  — the MXU-friendly form used by
    the dense-scan access path (the PSCAN analogue). fp32 accumulation.
    """
    qn = jnp.sum(jnp.square(queries), axis=-1, dtype=jnp.float32)
    sn = jnp.sum(jnp.square(series), axis=-1, dtype=jnp.float32)
    dot = jnp.dot(queries, series.T, preferred_element_type=jnp.float32)
    d = qn[:, None] + sn[None, :] - 2.0 * dot
    return jnp.maximum(d, 0.0)
