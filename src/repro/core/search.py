"""Exact kNN query answering (paper §3.4, Algorithms 10–14), TPU-native.

Phase map (DESIGN.md §2):

  1. *Approximate search* (Alg. 11): route the query to its home leaf, rank
     all leaves by LB_EAPCA (the vectorized fixpoint of the paper's priority
     queue) and visit the best ``l_max``; exact distances over those leaf
     extents seed the best-so-far BSF_k.
  2. *Candidate leaves* (Alg. 12): vectorized LB_EAPCA test over every leaf;
     pruning ratio ``eapca_pr``.
  3. *Candidate series* (Alg. 13): LB_SAX over the LSD sidecar, masked to
     candidate leaves; pruning ratio ``sax_pr``.
  4. *Exact refinement* (Alg. 14): candidates sorted by LB ascending are
     processed in fixed-size chunks inside ``lax.while_loop``; the loop exits
     when the chunk's smallest LB exceeds BSF_k — the same no-false-dismissal
     argument as the paper, with a static shape budget.

Adaptive access-path selection (Alg. 10 lines 10/15): when ``eapca_pr`` <
EAPCA_TH or ``sax_pr`` < SAX_TH, fall back to the *dense scan* — a blocked
streaming pass over the leaf-ordered LRD array (the skip-sequential-scan
analogue; on the MXU this is the high-arithmetic-intensity path). Queries run
through ``lax.map`` so the ``lax.cond`` branches stay real branches (the
paper's "queries run asynchronously"; parallelism lives *inside* a query).

Everything here is exact: all paths return the true k nearest neighbors.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import lower_bounds as LB
from repro.core import summaries as S
from repro.core.layout import HerculesLayout
from repro.core.tree import HerculesTree, route_to_leaf
from repro.kernels import ops as kops
from repro.kernels.compat import KERNEL_MODES, resolve_kernel_mode


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """Static query-answering settings (paper §4.2 Parameterization)."""
    k: int = 1
    l_max: int = 80              # approximate-phase leaf visits (paper: 80)
    eapca_th: float = 0.25       # paper: 0.25
    sax_th: float = 0.50         # paper: 0.50
    chunk: int = 1024            # phase-4 refinement chunk (static budget)
    scan_block: int = 4096       # dense-scan block
    use_sax: bool = True         # False -> NoSAX ablation (EAPCA-only LBs)
    adaptive: bool = True        # False -> NoThresh ablation (always prune path)
    force_scan: bool = False     # True -> PSCAN baseline behaviour
    lb_slack: float = 1e-5       # fp32 guard: treat lb*(1-slack) as the bound
    unroll_visits: bool = False  # unroll the phase-1 leaf-visit loop (dry-run
                                 # probes: XLA counts scan bodies once)
    refine_select: str = "argsort"   # 'argsort' (full sort) | 'topk'
    topk_budget_chunks: int = 32     # candidate budget C = chunks * chunk
    kernel_mode: str = "auto"    # Pallas dispatch: auto | pallas | interpret
                                 # | ref (kernels/compat.py owns the policy)
    prefetch: str = "sync"       # out-of-core disk reads: sync | thread
                                 # (reader thread + two-slot host buffer;
                                 # data/pipeline.py owns the readers).
                                 # Answers are bit-identical across modes.
    codec: str = "auto"          # out-of-core leaf codec: auto | raw | bf16
                                 # | sax-residual (storage/codecs.py owns
                                 # the registry; "auto" follows the opened
                                 # index). Answers are bit-identical under
                                 # every codec — lossy codecs only shrink
                                 # the streamed bytes.

    def __post_init__(self):
        # every field is validated here (herculint config-plumbing): a bad
        # value must raise at construction, not as an XLA shape error three
        # layers into a traced kernel
        for field, lo in (("k", 1), ("l_max", 1), ("chunk", 1),
                          ("scan_block", 1), ("topk_budget_chunks", 1)):
            val = getattr(self, field)
            if not isinstance(val, int) or isinstance(val, bool) or val < lo:
                raise ValueError(f"{field}={val!r}; expected an int >= {lo}")
        import math
        for field in ("eapca_th", "sax_th"):
            # pruning ratios live in [0, 1], but >1 is a legitimate knob
            # (always below threshold -> always scan, the PSCAN-ish probe)
            val = getattr(self, field)
            if not (math.isfinite(float(val)) and float(val) >= 0.0):
                raise ValueError(f"{field}={val!r}; expected a finite "
                                 "pruning threshold >= 0")
        if not 0.0 <= float(self.lb_slack) < 1.0:
            raise ValueError(f"lb_slack={self.lb_slack!r}; expected a "
                             "relative guard in [0, 1)")
        for field in ("use_sax", "adaptive", "force_scan", "unroll_visits"):
            if not isinstance(getattr(self, field), bool):
                raise ValueError(f"{field}={getattr(self, field)!r}; "
                                 "expected a bool")
        if self.refine_select not in ("argsort", "topk"):
            raise ValueError(f"refine_select={self.refine_select!r}; "
                             "expected 'argsort' or 'topk'")
        if self.kernel_mode not in KERNEL_MODES:
            raise ValueError(f"kernel_mode={self.kernel_mode!r}; expected "
                             f"one of {KERNEL_MODES}")
        from repro.data.pipeline import PREFETCH_MODES
        if self.prefetch not in PREFETCH_MODES:
            raise ValueError(f"prefetch={self.prefetch!r}; expected one of "
                             f"{PREFETCH_MODES}")
        from repro.storage.codecs import CODEC_CHOICES
        if self.codec not in CODEC_CHOICES:
            raise ValueError(f"codec={self.codec!r}; expected one of "
                             f"{CODEC_CHOICES}")

    def pad_multiple(self) -> int:
        import math
        return math.lcm(self.chunk, self.scan_block)


def validate_runtime_config(cfg: SearchConfig, n_pad: int) -> None:
    """Check per-call settings against a layout padded to ``n_pad`` rows.

    The only thing a built layout bakes in is its padded row count; any
    ``chunk``/``scan_block`` that *divides* ``n_pad`` is servable without a
    rebuild (blocked reshapes and chunked slices stay exact — no ragged
    tail). Every other SearchConfig field is a free per-call knob. This
    replaces the older, stricter pad-multiple equality test, which rejected
    valid combinations like halving ``chunk`` on an already-padded layout.
    """
    for field in ("chunk", "scan_block"):
        val = getattr(cfg, field)
        if val <= 0 or n_pad % val:
            raise ValueError(
                f"{field}={val} does not divide the padded collection size "
                f"{n_pad}; pick a divisor of {n_pad} or rebuild the index "
                f"with the target SearchConfig")


class KnnResult(NamedTuple):
    dists: jax.Array       # (Q, k) squared ED, ascending
    positions: jax.Array   # (Q, k) layout (LRD) positions
    ids: jax.Array         # (Q, k) original series ids
    path: jax.Array        # (Q,) 0=scan(eapca) 1=scan(sax) 2=pruned 3=forced
    eapca_pr: jax.Array    # (Q,) leaf-level pruning ratio
    sax_pr: jax.Array      # (Q,) series-level pruning ratio
    accessed: jax.Array    # (Q,) exact-distance computations performed
    visited_leaves: jax.Array  # (Q,)


INF = jnp.float32(jnp.inf)


def _merge_topk(d0, p0, d1, p1, k: int):
    """Merge (d1, p1) candidates into the running top-k (d0, p0).

    The paper's Results array is a *set* of series; a position already present
    in the running top-k must not enter twice (phase 1 may visit a leaf that
    refinement later re-reads). New candidates are distinct among themselves
    by construction (leaf extents / argsort chunks / scan blocks), so checking
    against the carry is sufficient.
    """
    dup = jnp.any(p1[None, :] == p0[:, None], axis=0)
    d1 = jnp.where(dup, INF, d1)
    d = jnp.concatenate([d0, d1])
    p = jnp.concatenate([p0, p1])
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, p[idx]


def _query_seg_stats(qp, qp2, endpoints):
    """Query stats under many segmentations. qp/qp2 (n+1,), endpoints (L, M)."""
    starts = jnp.concatenate(
        [jnp.zeros((endpoints.shape[0], 1), endpoints.dtype), endpoints[:, :-1]],
        axis=1)
    lens = jnp.maximum((endpoints - starts).astype(jnp.float32), 1.0)
    s1 = qp[endpoints] - qp[starts]
    s2 = qp2[endpoints] - qp2[starts]
    mean = s1 / lens
    var = jnp.maximum(s2 / lens - jnp.square(mean), 0.0)
    empty = (endpoints - starts) <= 0
    return (jnp.where(empty, 0.0, mean), jnp.where(empty, 0.0, jnp.sqrt(var)))


def _leaf_lbs(q, layout: HerculesLayout):
    """(L,) squared LB_EAPCA of the query to every leaf (+inf for empty/pad)."""
    qp, qp2 = S.prefix_sums(q[None])
    qp, qp2 = qp[0], qp2[0]
    qm, qs = _query_seg_stats(qp, qp2, layout.leaf_endpoints)
    lb = LB.lb_eapca_node(qm, qs, layout.leaf_synopsis, layout.leaf_seg_lens)
    # empty/padded leaf slots carry count 0 (works under distributed stacking
    # where the padded leaf count varies per shard)
    dead = layout.leaf_count <= 0
    return jnp.where(dead, INF, lb)


def _leaf_block_ed(q, layout: HerculesLayout, rank, *, max_leaf: int):
    """Exact squared ED of q to every series of leaf ``rank`` (masked block)."""
    start = layout.leaf_start[rank]
    cnt = layout.leaf_count[rank]
    block = jax.lax.dynamic_slice(
        layout.lrd, (start, 0), (max_leaf, layout.lrd.shape[1]))
    d = jnp.sum(jnp.square(block - q[None, :]), axis=1)
    pos = start + jnp.arange(max_leaf, dtype=jnp.int32)
    d = jnp.where(jnp.arange(max_leaf) < cnt, d, INF)
    return d, pos


# ---------------------------------------------------------------------------
# Dense scan path (the PSCAN / skip-sequential analogue)
# ---------------------------------------------------------------------------

def _scan_path(q, layout: HerculesLayout, d0, p0, cfg: SearchConfig):
    """Blocked streaming exact scan over the leaf-ordered LRD array."""
    n_pad = layout.lrd.shape[0]
    blocks = n_pad // cfg.scan_block
    lrd3 = layout.lrd.reshape(blocks, cfg.scan_block, layout.lrd.shape[1])

    def body(carry, blk):
        d_top, p_top, base = carry
        d = jnp.sum(jnp.square(blk - q[None, :]), axis=1)
        pos = base + jnp.arange(cfg.scan_block, dtype=jnp.int32)
        d = jnp.where(pos < layout.num_series, d, INF)
        d_top, p_top = _merge_topk(d_top, p_top, d, pos, cfg.k)
        return (d_top, p_top, base + cfg.scan_block), None

    (d_top, p_top, _), _ = jax.lax.scan(body, (d0, p0, jnp.int32(0)), lrd3)
    return d_top, p_top, jnp.int32(layout.num_series)


# ---------------------------------------------------------------------------
# Pruned refinement path (phases 3-4)
# ---------------------------------------------------------------------------

def _refine_path(q, layout: HerculesLayout, cand_lb, d0, p0, cfg: SearchConfig):
    """Chunked exact refinement of candidates ordered by lower bound.

    ``cand_lb``: (N_pad,) lower bound per layout position, +inf for pruned.
    Exits when the next chunk's best LB can no longer improve BSF_k.

    Candidate ordering (EXPERIMENTS.md §Perf iteration 5): ``argsort`` fully
    sorts all N_pad bounds; ``topk`` selects only the C = budget smallest
    (lax.top_k returns them sorted) — cheaper when C << N. Exactness under
    ``topk``: the caller falls back to the dense scan if the budget is
    exhausted while the BSF could still improve (returned ``exhausted``).
    """
    n_pad = cand_lb.shape[0]
    if cfg.refine_select == "topk":
        c_budget = min(n_pad, cfg.topk_budget_chunks * cfg.chunk)
        neg_lb, order = jax.lax.top_k(-cand_lb, c_budget)
        sorted_lb = -neg_lb
        order = order.astype(jnp.int32)
        n_chunks = c_budget // cfg.chunk
    else:
        order = jnp.argsort(cand_lb).astype(jnp.int32)
        sorted_lb = cand_lb[order]
        n_chunks = n_pad // cfg.chunk
    slack = jnp.float32(1.0 - cfg.lb_slack)

    def cond(state):
        c, d_top, p_top, acc = state
        bsf = d_top[cfg.k - 1]
        head = sorted_lb[c * cfg.chunk]
        return (c < n_chunks) & (head * slack < bsf)

    def body(state):
        c, d_top, p_top, acc = state
        bsf = d_top[cfg.k - 1]
        idx = jax.lax.dynamic_slice(order, (c * cfg.chunk,), (cfg.chunk,))
        lbs = jax.lax.dynamic_slice(sorted_lb, (c * cfg.chunk,), (cfg.chunk,))
        rows = layout.lrd[idx]                       # (chunk, n) gather
        d = jnp.sum(jnp.square(rows - q[None, :]), axis=1)
        live = lbs * slack < bsf                     # Alg. 14 line 4 re-check
        d = jnp.where(live, d, INF)
        d_top, p_top = _merge_topk(d_top, p_top, d, idx, cfg.k)
        return (c + 1, d_top, p_top, acc + jnp.sum(live.astype(jnp.int32)))

    c, d_top, p_top, acc = jax.lax.while_loop(
        cond, body, (jnp.int32(0), d0, p0, jnp.int32(0)))
    # budget exhausted while the tail could still improve? (topk mode only)
    exhausted = (c >= n_chunks) & (sorted_lb[-1] * slack < d_top[cfg.k - 1])
    return d_top, p_top, acc, exhausted


# ---------------------------------------------------------------------------
# Full per-query pipeline
# ---------------------------------------------------------------------------

def _query_one(q, tree: HerculesTree, layout: HerculesLayout,
               cfg: SearchConfig, max_depth: int):
    n = layout.series_len
    L = layout.leaf_start.shape[0]
    l_max = min(cfg.l_max, layout.num_leaves)
    slack = jnp.float32(1.0 - cfg.lb_slack)

    # ---- Phase 1: approximate search (Alg. 11) ----------------------------
    leaf_lb = _leaf_lbs(q, layout)                   # (L,)
    home = layout.leaf_rank[route_to_leaf(tree, q[None], max_depth)[0]]
    _, best_ranks = jax.lax.top_k(-leaf_lb, l_max)
    visit = jnp.concatenate([home[None].astype(jnp.int32),
                             best_ranks.astype(jnp.int32)])

    d_top = jnp.full((cfg.k,), INF)
    p_top = jnp.full((cfg.k,), -1, jnp.int32)

    def visit_body(carry, rank):
        d_top, p_top, acc = carry
        d, pos = _leaf_block_ed(q, layout, rank, max_leaf=layout.max_leaf)
        d_top, p_top = _merge_topk(d_top, p_top, d, pos, cfg.k)
        return (d_top, p_top, acc + layout.leaf_count[rank]), None

    if cfg.unroll_visits:
        carry = (d_top, p_top, jnp.int32(0))
        for i in range(l_max + 1):
            carry, _ = visit_body(carry, visit[i])
        d_top, p_top, accessed = carry
    else:
        (d_top, p_top, accessed), _ = jax.lax.scan(
            visit_body, (d_top, p_top, jnp.int32(0)), visit)
    bsf = d_top[cfg.k - 1]

    # ---- Phase 2: candidate leaves (Alg. 12) -------------------------------
    cand_leaf = leaf_lb * slack < bsf                # (L,)
    n_cand_leaves = jnp.sum(cand_leaf.astype(jnp.int32))
    n_alive = jnp.maximum(jnp.sum((layout.leaf_count > 0).astype(jnp.int32)), 1)
    eapca_pr = 1.0 - n_cand_leaves.astype(jnp.float32) / n_alive.astype(jnp.float32)

    # ---- Phase 3: candidate series (Alg. 13) -------------------------------
    leaf_mask_pad = jnp.concatenate([cand_leaf, jnp.zeros((1,), bool)])
    series_in_cand = leaf_mask_pad[layout.series_leaf_rank]  # (N_pad,)

    q_paa = S.paa(q[None], layout.lsd.shape[1])[0]
    kmode = resolve_kernel_mode(cfg.kernel_mode)
    if kmode == "ref":
        lb_s = LB.lb_sax(q_paa, layout.lsd, n)       # (N_pad,)
    else:
        # the paper's phase-3 LSDFile stream: the Pallas LB_SAX (MINDIST)
        # kernel over the whole uint8 sidecar. LB values gate pruning only
        # (with lb_slack guarding fp32 rounding), so exact answers are
        # preserved for any kernel arithmetic. The single query row is
        # padded to the kernel's 8-row minimum tile — on TPU that is free
        # (the VPU/MXU processes >= 8 sublanes per op regardless), and it
        # keeps LB memory at (N_pad,) per in-flight query instead of
        # materializing a (Q, N_pad) matrix outside the lax.map.
        lb_s = kops.lb_sax(q_paa[None, :], layout.lsd, n, mode=kmode)[0]
    leaf_lb_pad = jnp.concatenate([leaf_lb, jnp.full((1,), INF)])
    lb_leaf_series = leaf_lb_pad[layout.series_leaf_rank]

    if cfg.use_sax:
        cand_lb = jnp.where(series_in_cand,
                            jnp.maximum(lb_s, lb_leaf_series), INF)
    else:
        cand_lb = jnp.where(series_in_cand, lb_leaf_series, INF)
    n_cand = jnp.sum((cand_lb * slack < bsf).astype(jnp.int32))
    sax_pr = 1.0 - n_cand.astype(jnp.float32) / layout.num_series

    # ---- Adaptive access-path selection (Alg. 10) ---------------------------
    d_f, p_f, path, acc_f = _finish_one(
        q, layout, cfg, d_top, p_top, accessed, cand_lb, eapca_pr, sax_pr)

    return (d_f, p_f, path, eapca_pr, sax_pr, acc_f,
            jnp.int32(l_max + 1))


def _finish_one(q, layout: HerculesLayout, cfg: SearchConfig,
                d_top, p_top, accessed, cand_lb, eapca_pr, sax_pr):
    """Adaptive access-path selection (Alg. 10) + exact refinement for ONE
    query — the shared tail of the per-query (`_query_one`) and wave-fused
    (`wave_knn`) pipelines. Returns (dists, positions, path, accessed)."""

    def do_scan(_):
        d, p, acc = _scan_path(q, layout, d_top, p_top, cfg)
        return d, p, accessed + acc

    def do_refine(_):
        d, p, acc, exhausted = _refine_path(q, layout, cand_lb, d_top, p_top, cfg)
        if cfg.refine_select == "topk":
            # exactness fallback: finish with a dense scan when the candidate
            # budget ran out before the bound crossed BSF_k
            return jax.lax.cond(
                exhausted,
                lambda _: (lambda r: (r[0], r[1], acc + accessed + r[2]))(
                    _scan_path(q, layout, d, p, cfg)),
                lambda _: (d, p, accessed + acc), None)
        return d, p, accessed + acc

    if cfg.force_scan:
        d_f, p_f, acc_f = do_scan(None)
        path = jnp.int32(3)
    elif not cfg.adaptive:
        d_f, p_f, acc_f = do_refine(None)
        path = jnp.int32(2)
    else:
        use_scan = (eapca_pr < cfg.eapca_th) | (
            jnp.asarray(cfg.use_sax) & (sax_pr < cfg.sax_th))
        d_f, p_f, acc_f = jax.lax.cond(use_scan, do_scan, do_refine, None)
        path = jnp.where(eapca_pr < cfg.eapca_th, 0,
                         jnp.where(sax_pr < cfg.sax_th, 1, 2)).astype(jnp.int32)
    return d_f, p_f, path, acc_f


@functools.partial(jax.jit, static_argnames=("cfg", "max_depth"))
def exact_knn(tree: HerculesTree, layout: HerculesLayout, queries: jax.Array,
              cfg: SearchConfig, max_depth: int) -> KnnResult:
    """Exact kNN for a workload of queries (Q, n). See module docstring."""

    def one(q):
        return _query_one(q, tree, layout, cfg, max_depth)

    d, p, path, e_pr, s_pr, acc, vis = jax.lax.map(one, queries)
    safe_p = jnp.clip(p, 0, layout.perm.shape[0] - 1)
    ids = jnp.where(p >= 0, layout.perm[safe_p], -1)
    return KnnResult(dists=d, positions=p, ids=ids, path=path,
                     eapca_pr=e_pr, sax_pr=s_pr, accessed=acc,
                     visited_leaves=vis)


# ---------------------------------------------------------------------------
# Wave-fused multi-query search (ROADMAP "Multi-query wave search")
# ---------------------------------------------------------------------------

def _wave_leaf_lbs(queries, layout: HerculesLayout):
    """(W, L) squared LB_EAPCA of every wave member to every leaf.

    The batched form of `_leaf_lbs`: per-row prefix sums and segment stats
    are arithmetic-identical to the single-query path, so the bounds (and
    hence every pruning decision derived from them) match bit for bit.
    """
    qp, qp2 = S.prefix_sums(queries)

    def one(args):
        qp_r, qp2_r = args
        qm, qs = _query_seg_stats(qp_r, qp2_r, layout.leaf_endpoints)
        return LB.lb_eapca_node(qm, qs, layout.leaf_synopsis,
                                layout.leaf_seg_lens)

    lb = jax.lax.map(one, (qp, qp2))
    dead = layout.leaf_count <= 0
    return jnp.where(dead[None, :], INF, lb)


@functools.partial(jax.jit, static_argnames=("cfg", "max_depth"))
def wave_knn(tree: HerculesTree, layout: HerculesLayout, queries: jax.Array,
             cfg: SearchConfig, max_depth: int) -> KnnResult:
    """Exact kNN for a *wave* of queries with fused scheduling.

    Where `exact_knn` maps `_query_one` over the workload (each query runs
    its own leaf-visit scan and its own LB_SAX kernel call), this fuses the
    per-query work that is identical in structure across the wave:

      * ONE tree descent for all members (`route_to_leaf` is batched);
      * the phase-1 visit loop runs level by level over the whole wave —
        one (W, max_leaf) gather of LRD rows per level instead of W
        per-leaf dynamic slices (layout geometry guarantees every leaf
        extent [start, start + max_leaf) stays inside the padded array, so
        the gather reads exactly the rows the per-query slice reads);
      * a shared per-wave BSF matrix (W, k) carried through the visit scan;
      * ONE LB_SAX kernel launch over the (W, m) PAA matrix for phase 3,
        instead of W single-row launches padded to the kernel's 8-row tile.

    Per member the merge sequence (home leaf, then the l_max best leaves in
    rank order) and all distance arithmetic are the same as `_query_one`,
    so answers are bit-identical to the per-query path. Phase 4 stays a
    per-member `lax.map` over the shared `_finish_one` tail — access-path
    selection is a real branch per member, exactly as in `exact_knn`.

    Memory note: phase 3 materializes the (W, N_pad) LB matrix (the
    per-query path keeps it at (N_pad,)); that is the wave's footprint cost
    and why serving waves are bounded by `batch_slots`. `unroll_visits` is
    a dry-run probe knob and is ignored here (the wave path always scans).
    """
    W = queries.shape[0]
    n = layout.series_len
    l_max = min(cfg.l_max, layout.num_leaves)
    slack = jnp.float32(1.0 - cfg.lb_slack)
    n_pad_rows = layout.lrd.shape[0]

    # ---- Phase 1: approximate search, wave-fused (Alg. 11) ----------------
    leaf_lb = _wave_leaf_lbs(queries, layout)            # (W, L)
    home = layout.leaf_rank[route_to_leaf(tree, queries, max_depth)]
    _, best = jax.lax.top_k(-leaf_lb, l_max)             # (W, l_max)
    visit = jnp.concatenate([home[:, None].astype(jnp.int32),
                             best.astype(jnp.int32)], axis=1)

    d_top = jnp.full((W, cfg.k), INF)        # the shared per-wave BSF matrix
    p_top = jnp.full((W, cfg.k), -1, jnp.int32)
    offs = jnp.arange(layout.max_leaf, dtype=jnp.int32)
    merge = jax.vmap(functools.partial(_merge_topk, k=cfg.k))

    def level_body(carry, ranks):            # ranks: (W,) — one visit level
        d_top, p_top, acc = carry
        starts = layout.leaf_start[ranks]
        cnts = layout.leaf_count[ranks]
        pos = starts[:, None] + offs[None, :]            # (W, max_leaf)
        rows = layout.lrd[jnp.clip(pos, 0, n_pad_rows - 1)]  # one gather
        d = jnp.sum(jnp.square(rows - queries[:, None, :]), axis=2)
        d = jnp.where(offs[None, :] < cnts[:, None], d, INF)
        d_top, p_top = merge(d_top, p_top, d, pos)
        return (d_top, p_top, acc + cnts), None

    (d_top, p_top, accessed), _ = jax.lax.scan(
        level_body, (d_top, p_top, jnp.zeros((W,), jnp.int32)), visit.T)
    bsf = d_top[:, cfg.k - 1]

    # ---- Phase 2: candidate leaves (Alg. 12), whole wave at once ----------
    cand_leaf = leaf_lb * slack < bsf[:, None]           # (W, L)
    n_cand_leaves = jnp.sum(cand_leaf.astype(jnp.int32), axis=1)
    n_alive = jnp.maximum(jnp.sum((layout.leaf_count > 0).astype(jnp.int32)), 1)
    eapca_pr = (1.0 - n_cand_leaves.astype(jnp.float32)
                / n_alive.astype(jnp.float32))

    # ---- Phase 3: candidate series (Alg. 13), one kernel launch -----------
    leaf_mask_pad = jnp.concatenate(
        [cand_leaf, jnp.zeros((W, 1), bool)], axis=1)
    series_in_cand = leaf_mask_pad[:, layout.series_leaf_rank]   # (W, N_pad)

    q_paa = S.paa(queries, layout.lsd.shape[1])          # (W, m)
    kmode = resolve_kernel_mode(cfg.kernel_mode)
    if kmode == "ref":
        lb_s = jax.lax.map(lambda qp: LB.lb_sax(qp, layout.lsd, n), q_paa)
    else:
        lb_s = kops.lb_sax(q_paa, layout.lsd, n, mode=kmode)     # (W, N_pad)
    leaf_lb_pad = jnp.concatenate([leaf_lb, jnp.full((W, 1), INF)], axis=1)
    lb_leaf_series = leaf_lb_pad[:, layout.series_leaf_rank]

    if cfg.use_sax:
        cand_lb = jnp.where(series_in_cand,
                            jnp.maximum(lb_s, lb_leaf_series), INF)
    else:
        cand_lb = jnp.where(series_in_cand, lb_leaf_series, INF)
    n_cand = jnp.sum((cand_lb * slack < bsf[:, None]).astype(jnp.int32),
                     axis=1)
    sax_pr = 1.0 - n_cand.astype(jnp.float32) / layout.num_series

    # ---- Phase 4: per-member adaptive refinement (Alg. 10/14) -------------
    def one(args):
        q, d0, p0, acc, clb, e_pr, s_pr = args
        return _finish_one(q, layout, cfg, d0, p0, acc, clb, e_pr, s_pr)

    d_f, p_f, path, acc_f = jax.lax.map(
        one, (queries, d_top, p_top, accessed, cand_lb, eapca_pr, sax_pr))
    safe_p = jnp.clip(p_f, 0, layout.perm.shape[0] - 1)
    ids = jnp.where(p_f >= 0, layout.perm[safe_p], -1)
    return KnnResult(dists=d_f, positions=p_f, ids=ids, path=path,
                     eapca_pr=eapca_pr, sax_pr=sax_pr, accessed=acc_f,
                     visited_leaves=jnp.full((W,), l_max + 1, jnp.int32))


# ---------------------------------------------------------------------------
# Approximate search (paper §5 future work: approximate answering — here the
# phase-1 prefix of the exact pipeline, with recall measured in benchmarks)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "max_depth"))
def approx_knn(tree: HerculesTree, layout: HerculesLayout, queries: jax.Array,
               cfg: SearchConfig, max_depth: int):
    """Phase-1-only kNN: visit the home leaf + the l_max best leaves by
    LB_EAPCA and return the best-so-far — the paper's Approx-kNN (Alg. 11)
    as a standalone answering mode. Returns (dists, ids)."""

    def one(q):
        leaf_lb = _leaf_lbs(q, layout)
        home = layout.leaf_rank[route_to_leaf(tree, q[None], max_depth)[0]]
        l_max = min(cfg.l_max, layout.num_leaves)
        _, best = jax.lax.top_k(-leaf_lb, l_max)
        visit = jnp.concatenate([home[None].astype(jnp.int32),
                                 best.astype(jnp.int32)])
        d_top = jnp.full((cfg.k,), INF)
        p_top = jnp.full((cfg.k,), -1, jnp.int32)

        def body(carry, rank):
            d_top, p_top = carry
            d, pos = _leaf_block_ed(q, layout, rank, max_leaf=layout.max_leaf)
            return _merge_topk(d_top, p_top, d, pos, cfg.k), None

        (d_top, p_top), _ = jax.lax.scan(body, (d_top, p_top), visit)
        return d_top, p_top

    d, p = jax.lax.map(one, queries)
    safe = jnp.clip(p, 0, layout.perm.shape[0] - 1)
    ids = jnp.where(p >= 0, layout.perm[safe], -1)
    return d, ids


# ---------------------------------------------------------------------------
# Standalone baselines
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "block"))
def pscan_knn(data: jax.Array, queries: jax.Array, k: int = 1,
              block: int = 4096) -> tuple[jax.Array, jax.Array]:
    """PSCAN baseline (paper §4.1): optimized parallel scan.

    Batched across all queries (the double-buffer analogue is XLA streaming);
    blocked matmul-identity distances on the MXU. Returns (Q,k) dists + ids.
    ``data`` may be unpadded; handles the ragged tail by masking.
    """
    qn = queries.shape[0]
    num = data.shape[0]
    n_pad = -(-num // block) * block
    if n_pad != num:
        data = jnp.concatenate(
            [data, jnp.zeros((n_pad - num, data.shape[1]), data.dtype)], axis=0)
    blocks = data.reshape(n_pad // block, block, data.shape[1])
    q_norm = jnp.sum(jnp.square(queries), axis=1)

    d0 = jnp.full((qn, k), INF)
    p0 = jnp.full((qn, k), -1, jnp.int32)

    def body(carry, xs):
        d_top, p_top, base = carry
        blk = xs
        s_norm = jnp.sum(jnp.square(blk), axis=1)
        dot = jnp.dot(queries, blk.T, preferred_element_type=jnp.float32)
        d = jnp.maximum(q_norm[:, None] + s_norm[None, :] - 2.0 * dot, 0.0)
        pos = base + jnp.arange(block, dtype=jnp.int32)
        d = jnp.where((pos < num)[None, :], d, INF)
        dd = jnp.concatenate([d_top, d], axis=1)
        pp = jnp.concatenate([p_top, jnp.broadcast_to(pos, (qn, block))], axis=1)
        neg, idx = jax.lax.top_k(-dd, k)
        return (-neg, jnp.take_along_axis(pp, idx, axis=1), base + block), None

    (d_top, p_top, _), _ = jax.lax.scan(body, (d0, p0, jnp.int32(0)), blocks)
    return d_top, p_top


def brute_force_knn(data: jax.Array, queries: jax.Array, k: int = 1):
    """Reference oracle: full ED matrix + top_k (tests only)."""
    d = LB.squared_ed_matrix(queries, data)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx
