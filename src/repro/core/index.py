"""HerculesIndex — build / persist / query facade (the paper's full pipeline).

``HerculesIndex.build`` = index construction + index writing (paper §3.3):
tree build, synopsis finalization, LRD/LSD materialization. ``save``/``load``
persist the three artifacts the paper names — HTree (tree arrays), LRDFile
(raw series, leaf in-order), LSDFile (iSAX sidecar) — as one .npz plus a JSON
settings header (Alg. 6 line 2). ``knn`` is the §3.4 query pipeline.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import summaries as S
from repro.core.layout import HerculesLayout, build_layout
from repro.core.search import (KnnResult, SearchConfig, approx_knn, exact_knn,
                               validate_runtime_config)
from repro.core.tree import BuildConfig, HerculesTree, build_tree, tree_stats


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    build: BuildConfig = dataclasses.field(default_factory=BuildConfig)
    search: SearchConfig = dataclasses.field(default_factory=SearchConfig)
    sax_segments: int = S.NUM_SAX_SEGMENTS


class HerculesIndex:
    """An in-memory (HBM-resident) Hercules index over one series collection."""

    def __init__(self, tree: HerculesTree, layout: HerculesLayout,
                 config: IndexConfig, max_depth: int):
        self.tree = tree
        self.layout = layout
        self.config = config
        self.max_depth = max_depth

    # -- construction --------------------------------------------------------

    @classmethod
    def build(cls, data: jax.Array, config: IndexConfig | None = None) -> "HerculesIndex":
        """One-shot in-memory build.

        .. deprecated:: store API
            For a persistent index with incremental ingest, prefer
            ``repro.api.Hercules.create(path, config, data=data)`` — this
            remains the in-memory builder the store compares against.
        """
        config = config or IndexConfig()
        if data.shape[1] % config.sax_segments:
            raise ValueError(
                f"series length {data.shape[1]} must be divisible by "
                f"{config.sax_segments} iSAX segments")
        tree, node_of = build_tree(data, config.build)
        layout = build_layout(
            tree, node_of, data, sax_segments=config.sax_segments,
            pad_series_to_multiple=config.search.pad_multiple())
        max_depth = tree_stats(tree)["max_depth"]
        return cls(tree, layout, config, max_depth)

    @classmethod
    def build_streaming(cls, source,
                        config: "IndexConfig | None" = None,
                        prefetch: "str | None" = None) -> "HerculesIndex":
        """Chunk-streamed build from a :class:`repro.data.pipeline.ChunkSource`
        — device residency bounded by one chunk during construction, result
        bit-identical to :meth:`build` on the concatenated data.
        ``prefetch="thread"`` overlaps chunk reads with build compute
        (default: the config's ``search.prefetch``).

        .. deprecated:: store API
            Prefer ``repro.api.Hercules.create(path, config, data=source)``
            for the on-disk lifecycle (append/compact included); this
            remains the low-level in-memory delegate.
        """
        from repro.storage.build import build_index_streaming
        return build_index_streaming(source, config, prefetch=prefetch)

    # -- query answering ------------------------------------------------------

    def knn(self, queries: jax.Array, k: int | None = None,
            **overrides: Any) -> KnnResult:
        cfg = self.config.search
        if k is not None or overrides:
            cfg = dataclasses.replace(cfg, **({"k": k} if k is not None else {}),
                                      **overrides)
        validate_runtime_config(cfg, self.layout.lrd.shape[0])
        return exact_knn(self.tree, self.layout, queries, cfg, self.max_depth)

    def knn_approx(self, queries: jax.Array, k: int | None = None,
                   l_max: int | None = None):
        """Approximate kNN (phase 1 only; paper §5 future work). Returns
        (dists, ids) — never better than exact, recall measured in
        benchmarks/bench_suite.py::bench_approx."""
        cfg = self.config.search
        upd = {}
        if k is not None:
            upd["k"] = k
        if l_max is not None:
            upd["l_max"] = l_max
        if upd:
            cfg = dataclasses.replace(cfg, **upd)
        return approx_knn(self.tree, self.layout, queries, cfg, self.max_depth)

    def stats(self) -> dict:
        return tree_stats(self.tree)

    # -- persistence (checkpoint/restart story for the index itself) ---------
    # Single-file .npz snapshot, kept for in-process checkpointing. The
    # serving persistence story — versioned directory format, checksums,
    # memory-mappable LRD/LSD for out-of-core backends — is
    # repro/storage/format.py (save_index / load_index / open_index).

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        arrays = {}
        for name, val in self.tree._asdict().items():
            arrays[f"tree.{name}"] = np.asarray(val)
        for name, val in self.layout._asdict().items():
            if isinstance(val, (int, float)):
                continue
            arrays[f"layout.{name}"] = np.asarray(val)
        meta = {
            "max_depth": self.max_depth,
            "layout_static": {
                "series_len": self.layout.series_len,
                "max_leaf": self.layout.max_leaf,
                "num_leaves": self.layout.num_leaves,
                "num_series": self.layout.num_series,
            },
            "build": dataclasses.asdict(self.config.build),
            "search": dataclasses.asdict(self.config.search),
            "sax_segments": self.config.sax_segments,
        }
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            np.savez_compressed(f, __meta__=json.dumps(meta), **arrays)
        os.replace(tmp, path)  # atomic publish (fault-tolerant checkpointing)

    @classmethod
    def load(cls, path: str) -> "HerculesIndex":
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["__meta__"]))
            tree = HerculesTree(**{
                name: jnp.asarray(z[f"tree.{name}"])
                for name in HerculesTree._fields})
            lay_kw = {}
            for field in dataclasses.fields(HerculesLayout):
                key = f"layout.{field.name}"
                if key in z:
                    lay_kw[field.name] = jnp.asarray(z[key])
            lay_kw.update(meta["layout_static"])
            layout = HerculesLayout(**lay_kw)
        config = IndexConfig(
            build=BuildConfig(**meta["build"]),
            search=SearchConfig(**meta["search"]),
            sax_segments=meta["sax_segments"])
        return cls(tree, layout, config, meta["max_depth"])
