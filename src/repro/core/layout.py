"""Materialized index layout — the LRDFile / LSDFile analogue (paper §3.3).

The paper's index-writing phase stores all raw series **contiguously in leaf
in-order** (LRDFile) so that query-time leaf reads and skip-sequential scans
are sequential I/O, with a position-aligned iSAX sidecar (LSDFile). On TPU the
same layout turns candidate-leaf reads into contiguous HBM block loads
(dynamic_slice of a leaf extent) instead of per-series gathers, and the dense
scan into a streaming matmul.

``HerculesLayout`` is a pytree of device arrays:
  * ``lrd``        (N, n)  — raw series, leaf in-order ("LRDFile")
  * ``lsd``        (N, m)  — uint8 iSAX codes, same order ("LSDFile")
  * ``perm``/``inv_perm``  — original <-> layout position maps
  * ``leaf_rank``  (max_nodes,) — in-order rank of each leaf node (-1 internal)
  * ``leaf_start``/``leaf_count`` (num_leaves_padded,) — extents in lrd
  * ``leaf_node``  (num_leaves_padded,) — tree node id per in-order rank
  * ``leaf_synopsis``/``leaf_endpoints``/``leaf_nsegs`` — per-rank leaf data,
    densely packed so phase-2 pruning is one vectorized pass over leaves
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import summaries as S
from repro.core.tree import HerculesTree, inorder_leaves

_LAYOUT_DATA = ("lrd", "lsd", "perm", "inv_perm", "leaf_rank", "leaf_node",
                "leaf_start", "leaf_count", "leaf_synopsis", "leaf_endpoints",
                "leaf_seg_lens", "series_leaf_rank")
_LAYOUT_META = ("series_len", "max_leaf", "num_leaves", "num_series")


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=list(_LAYOUT_DATA), meta_fields=list(_LAYOUT_META))
@dataclasses.dataclass(frozen=True)
class HerculesLayout:
    """Materialized index. Array fields are pytree leaves; the int fields are
    static metadata (jit recompiles if they change — they are shape-like)."""
    lrd: jax.Array            # (N_pad, n) float32 (rows >= num_series are pad)
    lsd: jax.Array            # (N_pad, m_sax) uint8
    perm: jax.Array           # (N,) layout pos -> original id
    inv_perm: jax.Array       # (N,) original id -> layout pos
    leaf_rank: jax.Array      # (max_nodes,) int32
    leaf_node: jax.Array      # (L,) int32 node id per rank
    leaf_start: jax.Array     # (L,) int32
    leaf_count: jax.Array     # (L,) int32
    leaf_synopsis: jax.Array  # (L, M, 4) float32
    leaf_endpoints: jax.Array # (L, M) int32
    leaf_seg_lens: jax.Array  # (L, M) float32
    series_leaf_rank: jax.Array  # (N_pad,) int32, L for pad rows
    series_len: int
    max_leaf: int             # static upper bound on leaf extent
    num_leaves: int           # true number of leaves (L may be padded)
    num_series: int           # real N (before padding)

    def _asdict(self):
        return {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}


def build_layout(tree: HerculesTree, node_of: jax.Array, data: jax.Array,
                 sax_segments: int = S.NUM_SAX_SEGMENTS,
                 pad_leaves_to: int | None = None,
                 pad_series_to_multiple: int = 1) -> HerculesLayout:
    """Materialize the leaf in-order layout from a built tree.

    Host-side orchestration (tree is small); the heavy reorders stay on device.
    ``pad_series_to_multiple`` rounds the series axis up (pad rows are zeros
    with sentinel leaf rank L) so blocked scans never need clamped slices.
    """
    num, n = data.shape
    order = inorder_leaves(tree)                    # (num_leaves,)
    num_leaves = len(order)
    L = pad_leaves_to or num_leaves

    leaf_rank_np = np.full((tree.max_nodes,), -1, np.int32)
    leaf_rank_np[order] = np.arange(num_leaves, dtype=np.int32)
    leaf_rank = jnp.asarray(leaf_rank_np)

    # stable sort series by (leaf rank, original id) -> layout order
    ranks = leaf_rank[node_of]
    perm = jnp.argsort(ranks, stable=True).astype(jnp.int32)
    inv_perm = jnp.argsort(perm).astype(jnp.int32)

    counts_np = np.zeros((L,), np.int32)
    cnt_by_node = np.asarray(
        jax.ops.segment_sum(jnp.ones_like(node_of), node_of,
                            num_segments=tree.max_nodes))
    counts_np[:num_leaves] = cnt_by_node[order]
    starts_np = np.zeros((L,), np.int32)
    starts_np[:num_leaves] = np.concatenate(
        [[0], np.cumsum(counts_np[:num_leaves])[:-1]])
    # padded (empty) leaf slots point at the end with count 0
    starts_np[num_leaves:] = num
    max_leaf = int(counts_np.max(initial=1))

    lrd = data[perm]
    lsd = S.isax(lrd, sax_segments)
    srank = ranks[perm]

    # pad the series axis so (a) blocked scans need no clamped slices and
    # (b) every leaf extent [start, start+max_leaf) stays in bounds
    blk = max(1, pad_series_to_multiple)
    n_pad = -(-(num + max_leaf) // blk) * blk
    if n_pad != num:
        pad = n_pad - num
        lrd = jnp.concatenate([lrd, jnp.zeros((pad, n), lrd.dtype)], axis=0)
        lsd = jnp.concatenate([lsd, jnp.zeros((pad, lsd.shape[1]), lsd.dtype)], axis=0)
        srank = jnp.concatenate([srank, jnp.full((pad,), L, srank.dtype)], axis=0)

    leaf_node_np = np.zeros((L,), np.int32)
    leaf_node_np[:num_leaves] = order

    syn = tree.synopsis[jnp.asarray(leaf_node_np)]
    ep = tree.endpoints[jnp.asarray(leaf_node_np)]
    seg_lens = S.segment_lengths(ep)
    # zero out padded slots so their LB is 0 (never pruned incorrectly; they
    # have count 0 and contribute nothing)
    pad_mask = jnp.arange(L) >= num_leaves
    syn = jnp.where(pad_mask[:, None, None], 0.0, syn)

    return HerculesLayout(
        lrd=lrd, lsd=lsd, perm=perm, inv_perm=inv_perm,
        leaf_rank=leaf_rank,
        leaf_node=jnp.asarray(leaf_node_np),
        leaf_start=jnp.asarray(starts_np),
        leaf_count=jnp.asarray(counts_np),
        leaf_synopsis=syn,
        leaf_endpoints=ep,
        leaf_seg_lens=seg_lens,
        series_leaf_rank=srank.astype(jnp.int32),
        series_len=n,
        max_leaf=max_leaf,
        num_leaves=num_leaves,
        num_series=num,
    )
