"""Materialized index layout — the LRDFile / LSDFile analogue (paper §3.3).

The paper's index-writing phase stores all raw series **contiguously in leaf
in-order** (LRDFile) so that query-time leaf reads and skip-sequential scans
are sequential I/O, with a position-aligned iSAX sidecar (LSDFile). On TPU the
same layout turns candidate-leaf reads into contiguous HBM block loads
(dynamic_slice of a leaf extent) instead of per-series gathers, and the dense
scan into a streaming matmul.

``HerculesLayout`` is a pytree of device arrays:
  * ``lrd``        (N, n)  — raw series, leaf in-order ("LRDFile")
  * ``lsd``        (N, m)  — uint8 iSAX codes, same order ("LSDFile")
  * ``perm``/``inv_perm``  — original <-> layout position maps
  * ``leaf_rank``  (max_nodes,) — in-order rank of each leaf node (-1 internal)
  * ``leaf_start``/``leaf_count`` (num_leaves_padded,) — extents in lrd
  * ``leaf_node``  (num_leaves_padded,) — tree node id per in-order rank
  * ``leaf_synopsis``/``leaf_endpoints``/``leaf_nsegs`` — per-rank leaf data,
    densely packed so phase-2 pruning is one vectorized pass over leaves
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import summaries as S
from repro.core.tree import HerculesTree, inorder_leaves

_LAYOUT_DATA = ("lrd", "lsd", "perm", "inv_perm", "leaf_rank", "leaf_node",
                "leaf_start", "leaf_count", "leaf_synopsis", "leaf_endpoints",
                "leaf_seg_lens", "series_leaf_rank")
_LAYOUT_META = ("series_len", "max_leaf", "num_leaves", "num_series")


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=list(_LAYOUT_DATA), meta_fields=list(_LAYOUT_META))
@dataclasses.dataclass(frozen=True)
class HerculesLayout:
    """Materialized index. Array fields are pytree leaves; the int fields are
    static metadata (jit recompiles if they change — they are shape-like)."""
    lrd: jax.Array            # (N_pad, n) float32 (rows >= num_series are pad)
    lsd: jax.Array            # (N_pad, m_sax) uint8
    perm: jax.Array           # (N,) layout pos -> original id
    inv_perm: jax.Array       # (N,) original id -> layout pos
    leaf_rank: jax.Array      # (max_nodes,) int32
    leaf_node: jax.Array      # (L,) int32 node id per rank
    leaf_start: jax.Array     # (L,) int32
    leaf_count: jax.Array     # (L,) int32
    leaf_synopsis: jax.Array  # (L, M, 4) float32
    leaf_endpoints: jax.Array # (L, M) int32
    leaf_seg_lens: jax.Array  # (L, M) float32
    series_leaf_rank: jax.Array  # (N_pad,) int32, L for pad rows
    series_len: int
    max_leaf: int             # static upper bound on leaf extent
    num_leaves: int           # true number of leaves (L may be padded)
    num_series: int           # real N (before padding)

    def _asdict(self):
        return {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}


@dataclasses.dataclass(frozen=True)
class LayoutGeometry:
    """Host-side placement plan for the LRD/LSD files.

    Everything :func:`build_layout` decides *about positions* — which layout
    row each series lands in, leaf extents, padding — separated from the
    data movement itself, so the streaming index writer
    (``repro/storage/build.py``) can scatter chunks straight into an on-disk
    memmap without ever materializing the collection. All arrays are host
    numpy; derived purely from (tree, node_of), so the one-shot and chunked
    builds compute identical geometry.
    """
    perm: np.ndarray              # (N,) layout pos -> original id
    inv_perm: np.ndarray          # (N,) original id -> layout pos
    leaf_rank: np.ndarray         # (max_nodes,)
    leaf_node: np.ndarray         # (L,)
    leaf_start: np.ndarray        # (L,)
    leaf_count: np.ndarray        # (L,)
    series_leaf_rank: np.ndarray  # (n_pad,)
    series_len: int
    max_leaf: int
    num_leaves: int
    num_series: int
    n_pad: int


def compute_layout_geometry(tree: HerculesTree, node_of,
                            num_series: int, series_len: int,
                            pad_leaves_to: int | None = None,
                            pad_series_to_multiple: int = 1) -> LayoutGeometry:
    """Leaf in-order placement plan from a built tree (host-side, no data).

    ``pad_series_to_multiple`` rounds the series axis up (pad rows are zeros
    with sentinel leaf rank L) so blocked scans never need clamped slices.
    """
    node_of_np = np.asarray(node_of)
    order = inorder_leaves(tree)                    # (num_leaves,)
    num_leaves = len(order)
    L = pad_leaves_to or num_leaves

    leaf_rank = np.full((tree.max_nodes,), -1, np.int32)
    leaf_rank[order] = np.arange(num_leaves, dtype=np.int32)

    # stable sort series by (leaf rank, original id) -> layout order
    ranks = leaf_rank[node_of_np]
    perm = np.argsort(ranks, kind="stable").astype(np.int32)
    inv_perm = np.argsort(perm).astype(np.int32)

    counts = np.zeros((L,), np.int32)
    cnt_by_node = np.bincount(node_of_np, minlength=tree.max_nodes)
    counts[:num_leaves] = cnt_by_node[order]
    starts = np.zeros((L,), np.int32)
    starts[:num_leaves] = np.concatenate(
        [[0], np.cumsum(counts[:num_leaves])[:-1]])
    # padded (empty) leaf slots point at the end with count 0
    starts[num_leaves:] = num_series
    max_leaf = int(counts.max(initial=1))

    # pad the series axis so (a) blocked scans need no clamped slices and
    # (b) every leaf extent [start, start+max_leaf) stays in bounds
    blk = max(1, pad_series_to_multiple)
    n_pad = -(-(num_series + max_leaf) // blk) * blk
    srank = np.concatenate(
        [ranks[perm], np.full((n_pad - num_series,), L, np.int32)])

    leaf_node = np.zeros((L,), np.int32)
    leaf_node[:num_leaves] = order

    return LayoutGeometry(
        perm=perm, inv_perm=inv_perm, leaf_rank=leaf_rank,
        leaf_node=leaf_node, leaf_start=starts, leaf_count=counts,
        series_leaf_rank=srank.astype(np.int32),
        series_len=series_len, max_leaf=max_leaf, num_leaves=num_leaves,
        num_series=num_series, n_pad=n_pad)


def leaf_tables(tree: HerculesTree, geo: LayoutGeometry):
    """(leaf_synopsis, leaf_endpoints, leaf_seg_lens) densely packed per
    in-order rank — the per-leaf pruning tables phase 2 sweeps."""
    ln = jnp.asarray(geo.leaf_node)
    syn = tree.synopsis[ln]
    ep = tree.endpoints[ln]
    seg_lens = S.segment_lengths(ep)
    # zero out padded slots so their LB is 0 (never pruned incorrectly; they
    # have count 0 and contribute nothing)
    L = geo.leaf_node.shape[0]
    pad_mask = jnp.arange(L) >= geo.num_leaves
    syn = jnp.where(pad_mask[:, None, None], 0.0, syn)
    return syn, ep, seg_lens


def _owned(arr):
    """Memmaps are copied before device promotion: ``jnp.asarray`` may
    zero-copy alias the map, and the alias dies (PR 4: segfaults) with it.
    In-memory arrays pass through so the common build stays zero-copy."""
    return np.array(arr, copy=True) if isinstance(arr, np.memmap) else arr


def assemble_layout(tree: HerculesTree, geo: LayoutGeometry,
                    lrd, lsd) -> HerculesLayout:
    """HerculesLayout from a placement plan plus already-materialized
    LRD/LSD arrays (device, host, or memmap — memmaps are copied, the
    rest promoted with jnp.asarray)."""
    syn, ep, seg_lens = leaf_tables(tree, geo)
    return HerculesLayout(
        lrd=jnp.asarray(_owned(lrd)), lsd=jnp.asarray(_owned(lsd)),
        perm=jnp.asarray(geo.perm), inv_perm=jnp.asarray(geo.inv_perm),
        leaf_rank=jnp.asarray(geo.leaf_rank),
        leaf_node=jnp.asarray(geo.leaf_node),
        leaf_start=jnp.asarray(geo.leaf_start),
        leaf_count=jnp.asarray(geo.leaf_count),
        leaf_synopsis=syn,
        leaf_endpoints=ep,
        leaf_seg_lens=seg_lens,
        series_leaf_rank=jnp.asarray(geo.series_leaf_rank),
        series_len=geo.series_len,
        max_leaf=geo.max_leaf,
        num_leaves=geo.num_leaves,
        num_series=geo.num_series,
    )


def build_layout(tree: HerculesTree, node_of: jax.Array, data: jax.Array,
                 sax_segments: int = S.NUM_SAX_SEGMENTS,
                 pad_leaves_to: int | None = None,
                 pad_series_to_multiple: int = 1) -> HerculesLayout:
    """Materialize the leaf in-order layout from a built tree.

    Host-side orchestration (tree is small); the heavy reorders stay on
    device. The streaming writer shares :func:`compute_layout_geometry` and
    scatters chunks to disk instead (storage/build.py).
    """
    num, n = data.shape
    geo = compute_layout_geometry(
        tree, node_of, num, n, pad_leaves_to=pad_leaves_to,
        pad_series_to_multiple=pad_series_to_multiple)

    lrd = jnp.asarray(data)[jnp.asarray(geo.perm)]
    lsd = S.isax(lrd, sax_segments)
    pad = geo.n_pad - num
    if pad:
        lrd = jnp.concatenate([lrd, jnp.zeros((pad, n), lrd.dtype)], axis=0)
        lsd = jnp.concatenate(
            [lsd, jnp.zeros((pad, lsd.shape[1]), lsd.dtype)], axis=0)
    return assemble_layout(tree, geo, lrd, lsd)
