"""Unified query engine — one search surface over every backend.

The paper's system answers exact kNN through one carefully scheduled
pipeline; this repo grew three incompatible entry points around it
(``HerculesIndex.knn``, the distributed ``StackedIndex``, the PSCAN
baseline). This module is the serving layer that unifies them:

* :class:`SearchBackend` — the protocol every answering path conforms to:
  ``knn(queries, k=None, **overrides) -> KnnResult`` plus ``stats()`` /
  ``describe()``. Three adapters ship here:

  - :class:`LocalBackend`   — in-process :class:`HerculesIndex` (the paper).
  - :class:`ShardedBackend` — the distributed ``StackedIndex`` under a mesh
    (per-shard exact top-k + all-gather merge).
  - :class:`ScanBackend`    — the dense blocked scan (PSCAN). Its default
    *parity* arithmetic uses the same difference-form squared-ED as the
    index's refinement/leaf paths, so answers are **bit-identical** across
    backends; ``mxu=True`` switches to the matmul-identity form (the
    high-arithmetic-intensity MXU path, equal up to fp32 rounding).

* :class:`QueryEngine` — a serving session over one backend that

  (a) buckets arbitrary query-batch shapes to a small set of padded sizes
      and keeps an LRU **compiled-plan cache** keyed by (static
      SearchConfig, bucket shape): plans are AOT-lowered and compiled
      (``jit(...).lower(...).compile()``), so a cache hit *cannot* retrace —
      the executable takes only device arrays;
  (b) separates build-time statics (the layout's padded row count) from
      per-call knobs: any ``chunk``/``scan_block`` dividing the padded size
      is a legal override (``validate_runtime_config``), and ``k``/``l_max``/
      threshold/ablation knobs are always legal;
  (c) exposes engine-level telemetry — plan-cache hits/misses/evictions,
      compile and execute latency, access-path counts and pruning ratios —
      as a plain dict (:meth:`QueryEngine.telemetry`).

Everything above this layer (serving loop, benchmarks, examples, CLIs)
talks to backends only through the engine.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import logging
import time
from typing import Any, Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import summaries as S
from repro.core.index import HerculesIndex, IndexConfig
from repro.core.search import (INF, KnnResult, SearchConfig, _merge_topk,
                               exact_knn, pscan_knn, validate_runtime_config,
                               wave_knn)
from repro.kernels import ops as kops
from repro.kernels.compat import resolve_kernel_mode

logger = logging.getLogger(__name__)


@runtime_checkable
class SearchBackend(Protocol):
    """What the engine (and anything else) may assume about an answering path."""

    name: str

    def resolve(self, k: int | None = None,
                overrides: dict[str, Any] | None = None) -> SearchConfig: ...

    def make_plan(self, cfg: SearchConfig,
                  q_struct: jax.ShapeDtypeStruct
                  ) -> Callable[[jax.Array], KnnResult]: ...

    def make_wave_plan(self, cfg: SearchConfig,
                       q_struct: jax.ShapeDtypeStruct
                       ) -> Callable[[jax.Array], KnnResult]: ...

    def knn(self, queries: jax.Array, k: int | None = None,
            **overrides: Any) -> KnnResult: ...

    def stats(self) -> dict: ...

    def describe(self) -> dict: ...


class BackendBase:
    """Shared resolve/describe plumbing; subclasses supply the compute."""

    name = "backend"

    @property
    def series_len(self) -> int | None:
        """Collection series length, when known (engine input validation)."""
        return None

    @property
    def base_config(self) -> SearchConfig:
        raise NotImplementedError

    def _validate(self, cfg: SearchConfig) -> None:
        pass

    def resolve(self, k: int | None = None,
                overrides: dict[str, Any] | None = None) -> SearchConfig:
        cfg = self.base_config
        upd = dict(overrides or {})
        if k is not None:
            upd["k"] = k
        if upd:
            cfg = dataclasses.replace(cfg, **upd)
        self._validate(cfg)
        return cfg

    def make_plan(self, cfg, q_struct):
        raise NotImplementedError

    def make_wave_plan(self, cfg, q_struct):
        """Plan for a *wave* — a batch of queries answered with fused
        scheduling (shared descent/BSF/fetches). The default falls back to
        the regular plan: dense scans and the sharded all-gather are
        already batch-fused, so for them the wave path IS the batch path.
        Backends with per-query work to share override this."""
        return self.make_plan(cfg, q_struct)

    def knn(self, queries: jax.Array, k: int | None = None,
            **overrides: Any) -> KnnResult:
        """Direct (non-engine) call; still jit-cached, but may retrace on
        new shapes. Serving code should go through :class:`QueryEngine`."""
        cfg = self.resolve(k, overrides)
        return self._bind(cfg)(jnp.asarray(queries))

    def _bind(self, cfg: SearchConfig) -> Callable[[jax.Array], KnnResult]:
        raise NotImplementedError

    @staticmethod
    def _fill_result(dists, positions, ids, *, path: int = -1,
                     accessed=None) -> KnnResult:
        """KnnResult from the (dists, positions, ids) a backend computes,
        with the per-query telemetry fields it does not track filled by one
        convention: path ``-1`` = unknown, pruning ratios 0, ``accessed``
        0 / a scalar broadcast / a per-query vector."""
        qn = dists.shape[0]
        zeros_f = jnp.zeros((qn,), jnp.float32)
        zeros_i = jnp.zeros((qn,), jnp.int32)
        if accessed is None:
            accessed = zeros_i
        elif jnp.ndim(accessed) == 0:
            accessed = jnp.full((qn,), accessed, jnp.int32)
        return KnnResult(
            dists=dists, positions=positions, ids=ids,
            path=jnp.full((qn,), path, jnp.int32),
            eapca_pr=zeros_f, sax_pr=zeros_f,
            accessed=accessed, visited_leaves=zeros_i)

    def stats(self) -> dict:
        return {}

    def describe(self) -> dict:
        return {"backend": self.name,
                "config": dataclasses.asdict(self.base_config)}


# ---------------------------------------------------------------------------
# Local backend — the paper's single-node Hercules index
# ---------------------------------------------------------------------------

class LocalBackend(BackendBase):
    """In-process :class:`HerculesIndex` (tree + LRD/LSD layout)."""

    name = "local"

    def __init__(self, index: HerculesIndex):
        self.index = index

    @property
    def series_len(self) -> int:
        return self.index.layout.series_len

    @property
    def base_config(self) -> SearchConfig:
        return self.index.config.search

    def _validate(self, cfg: SearchConfig) -> None:
        validate_runtime_config(cfg, self.index.layout.lrd.shape[0])

    def _bind(self, cfg):
        idx = self.index
        return lambda q: exact_knn(idx.tree, idx.layout, q, cfg, idx.max_depth)

    def make_plan(self, cfg, q_struct):
        idx = self.index
        compiled = exact_knn.lower(
            idx.tree, idx.layout, q_struct, cfg, idx.max_depth).compile()
        return lambda q: compiled(idx.tree, idx.layout, q)

    def make_wave_plan(self, cfg, q_struct):
        idx = self.index
        compiled = wave_knn.lower(
            idx.tree, idx.layout, q_struct, cfg, idx.max_depth).compile()
        return lambda q: compiled(idx.tree, idx.layout, q)

    def estimate_difficulty(self, queries: jax.Array) -> np.ndarray:
        from repro.core.search import _wave_leaf_lbs
        return _difficulty_from_leaf_lbs(
            _wave_leaf_lbs(jnp.asarray(queries), self.index.layout))

    def stats(self) -> dict:
        return self.index.stats()

    def describe(self) -> dict:
        d = super().describe()
        d["num_series"] = self.index.layout.num_series
        d["series_len"] = self.index.layout.series_len
        return d


# ---------------------------------------------------------------------------
# Scan backend — PSCAN as a first-class backend
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "block"))
def dense_scan_knn(data: jax.Array, queries: jax.Array, k: int = 1,
                   block: int = 4096):
    """Blocked exact scan in *difference form* (``sum((s - q)^2)`` per row —
    the same arithmetic as the index's leaf/refinement paths, hence
    bit-identical answers). ``data`` may be unpadded. Returns (Q,k) dists
    and positions."""
    num, n = data.shape
    n_pad = -(-num // block) * block
    if n_pad != num:
        data = jnp.concatenate(
            [data, jnp.zeros((n_pad - num, n), data.dtype)], axis=0)
    blocks3 = data.reshape(n_pad // block, block, n)

    def one(q):
        d0 = jnp.full((k,), INF)
        p0 = jnp.full((k,), -1, jnp.int32)

        def body(carry, blk):
            d_top, p_top, base = carry
            d = jnp.sum(jnp.square(blk - q[None, :]), axis=1)
            pos = base + jnp.arange(block, dtype=jnp.int32)
            d = jnp.where(pos < num, d, INF)
            d_top, p_top = _merge_topk(d_top, p_top, d, pos, k)
            return (d_top, p_top, base + block), None

        (d_top, p_top, _), _ = jax.lax.scan(body, (d0, p0, jnp.int32(0)), blocks3)
        return d_top, p_top

    return jax.lax.map(one, queries)


@functools.partial(jax.jit, static_argnames=("k", "block", "mode"))
def kernel_scan_knn(data: jax.Array, queries: jax.Array, k: int = 1,
                    block: int = 4096, mode: str = "pallas"):
    """Blocked exact scan through the Pallas ED kernels (``kernels/ops``).

    Candidate *selection* runs on the kernels — the fused :func:`ops.ed_min`
    1-NN scan for ``k == 1`` (the paper's dominant query), blocked
    :func:`ops.ed_matrix` + per-block top-k otherwise. The *reported*
    distances for selected rows are always recomputed in difference form
    (``sum((s - q)^2)``) — the same arithmetic as every other backend path —
    and for ``k > 1`` the cross-block running top-k merges those exact
    values through the shared :func:`_merge_topk`, so kernel arithmetic
    influences at most the within-block candidate choice. Answers match
    :func:`dense_scan_knn` bit-for-bit unless the matmul-identity fp32
    error exceeds the distance gap at a top-k boundary (the ``scan-mxu``
    caveat; asserted exactly on the parity workloads). Returns (Q, k)
    dists and positions.
    """
    num, n = data.shape
    qn = queries.shape[0]

    def exact_d(p):
        """Difference-form distances for selected positions (-1/pad -> inf)."""
        rows = data[jnp.clip(p, 0, num - 1)]                     # (Q, k, n)
        d = jnp.sum(jnp.square(rows - queries[:, None, :]), axis=-1)
        return jnp.where((p >= 0) & (p < num), d, INF)

    if k == 1:
        # valid_n masking in the kernel guarantees a real row wins the min
        _, amin = kops.ed_min(queries, data, mode=mode)
        p_top = amin[:, None].astype(jnp.int32)                  # (Q, 1)
        return exact_d(p_top), p_top

    n_pad = -(-num // block) * block
    padded = data if n_pad == num else jnp.concatenate(
        [data, jnp.zeros((n_pad - num, n), data.dtype)], axis=0)
    blocks3 = padded.reshape(n_pad // block, block, n)
    merge = jax.vmap(functools.partial(_merge_topk, k=k))

    def body(carry, blk):
        d_top, p_top, base = carry
        d = kops.ed_matrix(queries, blk, mode=mode)              # (Q, block)
        pos = base + jnp.arange(block, dtype=jnp.int32)
        d = jnp.where((pos < num)[None, :], d, INF)
        _, idx = jax.lax.top_k(-d, k)                            # (Q, k)
        cand = jnp.where(jnp.take_along_axis(d, idx, axis=1) < INF,
                         pos[idx], -1)
        d_top, p_top = merge(d_top, p_top, exact_d(cand), cand)
        return (d_top, p_top, base + block), None

    d0 = jnp.full((qn, k), INF)
    p0 = jnp.full((qn, k), -1, jnp.int32)
    (d_top, p_top, _), _ = jax.lax.scan(body, (d0, p0, jnp.int32(0)), blocks3)
    return d_top, p_top


class ScanBackend(BackendBase):
    """Dense blocked scan over the raw collection (the PSCAN baseline).

    Arithmetic selection, in priority order:

    * ``cfg.kernel_mode`` *explicitly* ``pallas``/``interpret`` (or ``auto``
      resolving to Pallas with ``mxu=False``): the scan runs on the ED
      kernels via :func:`kernel_scan_knn` — reported distances are
      recomputed in difference form, so answers match the reference path.
    * ``mxu=True``: matmul-identity distances on the MXU via XLA
      (:func:`pscan_knn`; equal up to fp32 rounding). Wins over the
      implicit ``auto`` resolution, never over an explicit Pallas request.
    * otherwise: difference-form :func:`dense_scan_knn`, bit-identical to
      :class:`LocalBackend`.
    """

    name = "scan"

    def __init__(self, data: jax.Array, config: SearchConfig | None = None,
                 mxu: bool = False):
        self.data = jnp.asarray(data)
        self._config = dataclasses.replace(
            config or SearchConfig(), force_scan=True)
        self.mxu = mxu

    @property
    def series_len(self) -> int:
        return int(self.data.shape[1])

    @property
    def base_config(self) -> SearchConfig:
        return self._config

    def _validate(self, cfg: SearchConfig) -> None:
        if cfg.scan_block <= 0:
            raise ValueError("scan_block must be positive")

    def _result(self, d, p) -> KnnResult:
        # identity layout (pos == id); path 3 = forced scan, everything read
        return self._fill_result(d, p, p, path=3, accessed=self.data.shape[0])

    def _fn_args(self, cfg):
        """(jitted fn, static args after (data, queries)) for this config.

        ``mxu=True`` is an explicit arithmetic choice, so it wins over the
        implicit ``kernel_mode="auto"`` resolution; an *explicit* Pallas
        mode (``pallas``/``interpret``) wins over ``mxu``.
        """
        mode = resolve_kernel_mode(cfg.kernel_mode)
        if mode != "ref" and not (self.mxu and cfg.kernel_mode == "auto"):
            return kernel_scan_knn, (cfg.k, cfg.scan_block, mode)
        return (pscan_knn if self.mxu else dense_scan_knn), \
            (cfg.k, cfg.scan_block)

    def _bind(self, cfg):
        fn, args = self._fn_args(cfg)
        return lambda q: self._result(*fn(self.data, q, *args))

    def make_plan(self, cfg, q_struct):
        fn, args = self._fn_args(cfg)
        compiled = fn.lower(self.data, q_struct, *args).compile()
        return lambda q: self._result(*compiled(self.data, q))

    def stats(self) -> dict:
        return {"num_series": int(self.data.shape[0]),
                "series_len": int(self.data.shape[1])}

    def describe(self) -> dict:
        d = super().describe()
        d.update(self.stats(), mxu=self.mxu)
        return d


# ---------------------------------------------------------------------------
# Out-of-core backends — serving a memory-mapped on-disk index under a budget
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "block", "mode"))
def _ooc_scan_block(rows: jax.Array, queries: jax.Array, base: jax.Array,
                    *, k: int, block: int, mode: str):
    """Top-k of one streamed row block through the in-memory scan hot path;
    positions shifted to global layout coordinates."""
    if mode == "ref":
        d, p = dense_scan_knn(rows, queries, k=k, block=block)
    else:
        d, p = kernel_scan_knn(rows, queries, k=k, block=block, mode=mode)
    return d, jnp.where(p >= 0, p + base, -1)


@functools.partial(jax.jit, static_argnames=("k",))
def _ooc_merge(d0, p0, d1, p1, *, k: int):
    merge = jax.vmap(lambda a, b, c, e: _merge_topk(a, b, c, e, k))
    return merge(d0, p0, d1, p1)


@functools.partial(jax.jit, static_argnames=("k",))
def _ooc_refine_block(rows: jax.Array, base: jax.Array, valid: jax.Array,
                      queries: jax.Array, d0, p0, *, k: int):
    """Merge exact difference-form distances of one padded row block into
    each query's running top-k (rows beyond ``valid`` are masked)."""
    r = rows.shape[0]
    pos = base + jnp.arange(r, dtype=jnp.int32)
    live = jnp.arange(r) < valid

    def one(args):
        q, d_top, p_top = args
        d = jnp.sum(jnp.square(rows - q[None, :]), axis=1)
        d = jnp.where(live, d, INF)
        return _merge_topk(d_top, p_top, d, pos, k)

    return jax.lax.map(one, (queries, d0, p0))


def _difficulty_from_leaf_lbs(lbs) -> np.ndarray:
    """Per-query cost score in [0, 1] from the leaf-bound landscape: the
    fraction of alive leaves whose LB_EAPCA is within 2x of the query's
    best bound. A flat landscape (many near-best leaves) predicts weak
    pruning — the query will touch many leaves and serve expensive; a
    spiky one prunes well and serves cheap. This is the difficulty signal
    the serve loop's ``pack="difficulty"`` wave packing keys on."""
    lbs = np.asarray(lbs)
    finite = np.isfinite(lbs)
    n_alive = np.maximum(finite.sum(axis=1), 1)
    best = np.where(finite, lbs, np.inf).min(axis=1)
    near = finite & (lbs <= 2.0 * best[:, None] + 1e-12)
    return near.sum(axis=1).astype(np.float32) / n_alive


def _alive_runs(alive: np.ndarray, base: int) -> list[tuple[int, int]]:
    """Contiguous True runs of a row-survival mask as absolute
    (start, count) pairs — the sub-extents the SAX filter could not prune."""
    idx = np.flatnonzero(alive)
    if idx.size == 0:
        return []
    breaks = np.flatnonzero(np.diff(idx) > 1)
    starts = np.concatenate([[0], breaks + 1])
    ends = np.concatenate([breaks, [idx.size - 1]])
    return [(base + int(idx[s]), int(idx[e] - idx[s] + 1))
            for s, e in zip(starts, ends)]


class _OutOfCoreBase(BackendBase):
    """Shared plumbing for backends that stream a :class:`SavedIndex`
    (``repro.storage.open_index``): memory-mapped LRD rows move host→device
    in blocks bounded by ``memory_budget_mb``; only small state (tree, leaf
    tables, permutation) is resident."""

    def __init__(self, saved, config: SearchConfig | None = None,
                 memory_budget_mb: float = 64.0):
        if memory_budget_mb <= 0:
            raise ValueError("memory_budget_mb must be positive")
        self.saved = saved
        self.memory_budget_mb = float(memory_budget_mb)
        self._config = config or saved.config.search
        self._perm = jnp.asarray(saved.small["perm"])
        self._t = {"calls": 0, "blocks": 0, "rows_streamed": 0,
                   "bytes_streamed": 0, "sax_rows_read": 0,
                   "read_seconds": 0.0, "read_wait_seconds": 0.0,
                   "overlap_blocks": 0,
                   # wave-fused serving: fetches shared across wave members
                   "wave_calls": 0, "wave_rows_shared": 0,
                   "runs_deduped": 0, "runs_skipped_bsf": 0}

    def _lrd(self) -> np.ndarray:
        """The LRD memmap, failing loudly if the SavedIndex was closed
        (e.g. the store compacted underneath a stale backend)."""
        return self.saved._mapped("lrd")

    def _lsd(self) -> np.ndarray:
        return self.saved._mapped("lsd")

    @property
    def series_len(self) -> int:
        return self.saved.series_len

    @property
    def base_config(self) -> SearchConfig:
        return self._config

    @classmethod
    def budget_stream_rows(cls, memory_budget_mb: float,
                           series_len: int) -> int:
        """Rows per streamed block/piece under ``memory_budget_mb``: half
        the budget's rows, because the stream keeps two blocks in flight
        (one being consumed, one being read/transferred) at peak. The one
        budget→rows code path — backends, the store, and the CLI all
        derive from here, so the arithmetic cannot drift."""
        budget_rows = int(memory_budget_mb * (1 << 20)) // (4 * series_len)
        return max(budget_rows // 2, 1)

    def stream_rows(self) -> int:
        """Cap on rows per streamed block (see :meth:`budget_stream_rows`)."""
        return self.budget_stream_rows(self.memory_budget_mb,
                                       self.saved.series_len)

    def _reap_reader(self, reader) -> None:
        """Close a chunk reader and fold its stats into the backend's."""
        from repro.data.pipeline import READ_STAT_KEYS

        reader.close()
        for key in READ_STAT_KEYS:
            self._t[key] += reader.stats[key]

    def _ids_of(self, p: jax.Array) -> jax.Array:
        safe = jnp.clip(p, 0, self._perm.shape[0] - 1)
        return jnp.where(p >= 0, self._perm[safe], -1)

    def _count(self, rows: int) -> None:
        self._t["blocks"] += 1
        self._t["rows_streamed"] += rows
        self._t["bytes_streamed"] += rows * 4 * self.saved.series_len

    def make_plan(self, cfg, q_struct):
        # Streaming plans are Python loops over jitted block kernels; the
        # jit cache (keyed on block shapes, which the budget fixes) plays
        # the role of the AOT executable here.
        return self._bind(cfg)

    def stats(self) -> dict:
        return {"num_series": self.saved.num_series,
                "series_len": self.saved.series_len,
                "memory_budget_mb": self.memory_budget_mb,
                **self._t}

    def describe(self) -> dict:
        d = super().describe()
        d.update(self.stats(), path=self.saved.path)
        return d


class OutOfCoreScanBackend(_OutOfCoreBase):
    """Exact kNN over an on-disk collection via a streamed blocked scan.

    The memory-mapped LRD file is read in row blocks sized to half of
    ``memory_budget_mb`` — the stream keeps two blocks in flight (one
    computing, one being read/transferred), so the *budget* covers peak
    residency, not one block. ``cfg.prefetch`` picks the scheduler:
    ``"sync"`` double-buffers only the host→device copy (the memmap read
    blocks the consumer), ``"thread"`` adds the reader thread + two-slot
    host buffer so the disk read overlaps compute as well — answers are
    bit-identical either way, and ``stats()`` exposes
    ``read_wait_seconds``/``overlap_blocks`` to compare the two. A base
    ``scan_block`` too large for the budget's streamed blocks is
    auto-shrunk (logged) at construction, so small budgets behave the same
    from every entry point. Each block runs the *same* in-memory scan hot
    path (:func:`kernel_scan_knn` when the kernel mode resolves to Pallas,
    else the difference-form :func:`dense_scan_knn`) and running top-k
    merges through the shared :func:`_merge_topk` in file order. Distances
    are bit-identical to :class:`ScanBackend`; ``ids`` are exact original
    ids via the stored permutation and match the in-memory scan except when
    distinct rows *tie exactly* at the top-k boundary (the streamed scan
    visits rows in LRD order, the in-memory scan in original order, so ties
    break differently). ``positions`` are layout (LRD) positions.
    """

    name = "ooc-scan"

    def __init__(self, saved, config: SearchConfig | None = None,
                 memory_budget_mb: float = 64.0):
        super().__init__(saved, config, memory_budget_mb)
        self._config = dataclasses.replace(self._config, force_scan=True)
        # auto-fit: a base scan_block that cannot fit one streamed block is
        # shrunk to the budget's block size, so every entry point (store,
        # CLI, direct construction) behaves identically on small budgets.
        # Explicit per-call scan_block overrides still fail validation.
        rows = self.stream_rows()
        if rows < self._config.scan_block:
            logger.warning(
                "ooc-scan: scan_block=%d exceeds the %g MiB budget's "
                "%d-row streamed blocks; auto-shrinking scan_block to %d",
                self._config.scan_block, self.memory_budget_mb, rows, rows)
            self._config = dataclasses.replace(self._config, scan_block=rows)

    def _validate(self, cfg: SearchConfig) -> None:
        if cfg.scan_block <= 0:
            raise ValueError("scan_block must be positive")
        if self.stream_rows() < cfg.scan_block:
            raise ValueError(
                f"memory_budget_mb={self.memory_budget_mb} streams "
                f"{self.stream_rows()} rows per block (two blocks in "
                f"flight) — less than one scan_block={cfg.scan_block}; "
                f"lower scan_block or raise the budget")

    def _block_rows(self, cfg: SearchConfig) -> int:
        return (self.stream_rows() // cfg.scan_block) * cfg.scan_block

    def _bind(self, cfg):
        mode = resolve_kernel_mode(cfg.kernel_mode)
        return lambda q: self._stream_knn(jnp.asarray(q), cfg, mode)

    def _stream_knn(self, q: jax.Array, cfg: SearchConfig,
                    mode: str) -> KnnResult:
        from repro.data.pipeline import ArrayChunkSource, iter_device_chunks

        num = self.saved.num_series
        R = self._block_rows(cfg)
        qn = q.shape[0]
        d = jnp.full((qn, cfg.k), INF)
        p = jnp.full((qn, cfg.k), -1, jnp.int32)
        blocks = ArrayChunkSource(self._lrd()[:num], R)
        for start, rows in iter_device_chunks(blocks, prefetch=cfg.prefetch,
                                              telemetry=self._t):
            d_b, p_b = _ooc_scan_block(rows, q, jnp.int32(start), k=cfg.k,
                                       block=cfg.scan_block, mode=mode)
            d, p = _ooc_merge(d, p, d_b, p_b, k=cfg.k)
            self._count(rows.shape[0])
        self._t["calls"] += 1
        return self._fill_result(d, p, self._ids_of(p), path=3, accessed=num)

    def make_wave_plan(self, cfg, q_struct):
        """The streamed scan already reads each block exactly once for the
        whole batch, so the wave path is the batch path — plus telemetry
        attributing the sharing: every streamed row serves all wave
        members but is fetched once."""
        mode = resolve_kernel_mode(cfg.kernel_mode)

        def run(q):
            q = jnp.asarray(q)
            before = self._t["rows_streamed"]
            res = self._stream_knn(q, cfg, mode)
            self._t["wave_calls"] += 1
            self._t["wave_rows_shared"] += ((self._t["rows_streamed"] - before)
                                            * max(int(q.shape[0]) - 1, 0))
            return res

        return run


class OutOfCoreLocalBackend(_OutOfCoreBase):
    """Index-pruned out-of-core answering (the paper's reason to build the
    tree at all: touch only the leaves — and series — the bounds cannot
    exclude).

    Resident state is the tree plus the per-leaf pruning tables; raw series
    stay on disk. Per batch: (1) route every query to its home leaf and seed
    BSF_k from those leaf extents; (2) one vectorized LB_EAPCA pass over all
    leaf synopses; (3) for the leaves some query cannot prune, stream the
    **LSD sidecar** (m bytes/series — tiny next to the n-float rows) and
    apply the per-series LB_SAX filter, then fetch only the surviving rows
    as contiguous LRD runs (leaf in-order == file order) cut into
    budget-bounded pieces, refining with exact difference-form distances —
    the paper's phase-3 LSDFile stream, restored for the out-of-core path.
    ``use_sax=False`` falls back to leaf-granularity pruning. Exact by the
    paper's no-false-dismissal argument: a leaf (or series) is skipped only
    if ``lb * (1 - lb_slack)`` ≥ the running BSF_k, which upper-bounds the
    final kth distance.
    """

    name = "ooc-local"

    def __init__(self, saved, config: SearchConfig | None = None,
                 memory_budget_mb: float = 64.0):
        super().__init__(saved, config, memory_budget_mb)
        s = saved.small
        self._leaf_start = s["leaf_start"]
        self._leaf_count = s["leaf_count"]
        self._leaf_rank = jnp.asarray(s["leaf_rank"])
        self._leaf_endpoints = jnp.asarray(s["leaf_endpoints"])
        self._leaf_synopsis = jnp.asarray(s["leaf_synopsis"])
        self._leaf_seg_lens = jnp.asarray(s["leaf_seg_lens"])
        self._srank = np.asarray(s["series_leaf_rank"])

    def _validate(self, cfg: SearchConfig) -> None:
        if self.stream_rows() < self.saved.max_leaf:
            raise ValueError(
                f"memory_budget_mb={self.memory_budget_mb} streams "
                f"{self.stream_rows()} rows per block — less than one leaf "
                f"extent (max_leaf={self.saved.max_leaf}); raise the budget "
                f"or rebuild with a smaller leaf_capacity")

    def _bind(self, cfg):
        return lambda q: self._stream_knn(jnp.asarray(q), cfg)

    def _pad_bucket(self, count: int, cap: int) -> int:
        """Pad a piece to a small set of shapes (powers of two between
        max_leaf and the streaming cap) so refine kernels compile O(log)
        times while tiny pieces don't pay a full-budget zero-fill/copy."""
        b = max(self.saved.max_leaf, 1)
        while b < count:
            b <<= 1
        return min(max(b, 1), max(cap, count))

    def _leaf_lbs(self, q: jax.Array) -> jax.Array:
        """(Q, L) squared LB_EAPCA of every query to every leaf synopsis."""
        from repro.core.lower_bounds import lb_eapca_node
        from repro.core.search import _query_seg_stats

        qp, qp2 = S.prefix_sums(q)

        def one(args):
            p_row, p2_row = args
            qm, qs = _query_seg_stats(p_row, p2_row, self._leaf_endpoints)
            return lb_eapca_node(qm, qs, self._leaf_synopsis,
                                 self._leaf_seg_lens)

        lbs = jax.lax.map(one, (qp, qp2))
        dead = jnp.asarray(self._leaf_count) <= 0
        return jnp.where(dead[None, :], INF, lbs)

    def _stream_knn(self, q: jax.Array, cfg: SearchConfig) -> KnnResult:
        from repro.core.tree import route_to_leaf
        from repro.data.pipeline import make_chunk_reader

        k = cfg.k
        qn = q.shape[0]
        n = self.saved.series_len
        max_leaf = self.saved.max_leaf
        R = self.stream_rows()
        rows_before = self._t["rows_streamed"]
        d = jnp.full((qn, k), INF)
        p = jnp.full((qn, k), -1, jnp.int32)

        # every raw-row fetch of this call (seeded leaves, then alive runs)
        # flows through one reader: extents are submitted ahead of
        # consumption, so with prefetch="thread" the next extent's page
        # faults land in a slot buffer while the current one refines
        lrd_reader = make_chunk_reader(self._lrd(), R, n,
                                       prefetch=cfg.prefetch)
        lsd_reader = None

        def refine_all(d, p, extents):
            """Refine (start, cnt, pad_to) extents — all submitted before
            the first is consumed, the reader's lookahead window."""
            for start, cnt, pad_to in extents:
                lrd_reader.submit(start, cnt, pad_to)
            for start, cnt, _ in extents:
                rows = lrd_reader.stage(lrd_reader.get())
                d, p = _ooc_refine_block(rows, jnp.int32(start),
                                         jnp.int32(cnt), q, d, p, k=k)
                self._count(cnt)
            return d, p

        try:
            # -- phase 1 (Alg. 11): seed BSF from each query's home leaf plus
            # its l_max best leaves by LB_EAPCA — same visit set as the
            # in-memory pipeline, so the bound entering phase 2 is comparably
            # tight.
            lbs = self._leaf_lbs(q)                          # (Q, L)
            home_nodes = route_to_leaf(self.saved.tree, q,
                                       self.saved.max_depth)
            home_ranks = np.asarray(self._leaf_rank)[np.asarray(home_nodes)]
            l_max = min(cfg.l_max, self.saved.num_leaves)
            _, best = jax.lax.top_k(-lbs, l_max)             # (Q, l_max)
            seeded = sorted(set(int(r) for r in home_ranks if r >= 0)
                            | set(int(r) for r in np.asarray(best).ravel()))
            seeds = [(int(self._leaf_start[r]), int(self._leaf_count[r]),
                      max_leaf) for r in seeded
                     if int(self._leaf_count[r]) > 0]
            seed_rows = sum(cnt for _, cnt, _ in seeds)
            d, p = refine_all(d, p, seeds)

            # -- phase 2: leaf-level pruning over resident synopses ----------
            slack = jnp.float32(1.0 - cfg.lb_slack)
            bsf = d[:, k - 1]
            cand = lbs * slack < bsf[:, None]                # (Q, L)
            needed = np.array(jnp.any(cand, axis=0))
            needed[seeded] = False
            n_alive = max(int((np.asarray(self._leaf_count) > 0).sum()), 1)
            eapca_pr = 1.0 - np.asarray(
                jnp.sum(cand, axis=1), np.float32) / n_alive

            # -- phase 3: stream the LSD sidecar over non-prunable leaves,
            # keep only series the per-row LB_SAX filter cannot exclude, and
            # fetch those as contiguous LRD runs (the paper's LSDFile pass:
            # m bytes of codes buy skipping n floats of raw series) ---------
            pieces = self._runs(needed, R)
            use_sax = bool(cfg.use_sax)
            # seeded-leaf rows were read and refined for every query — they
            # count as alive, or sax_pr would overstate pruning (rows the
            # phase-3 filter never saw are not rows it pruned)
            alive_counts = jnp.full((qn,), seed_rows, jnp.int32)
            if not use_sax:
                d, p = refine_all(d, p, [(s, c, self._pad_bucket(c, R))
                                         for s, c in pieces])
            else:
                m_sax = int(self._lsd().shape[1])
                q_paa = S.paa(q, m_sax)
                kmode = resolve_kernel_mode(cfg.kernel_mode)
                lsd_reader = make_chunk_reader(self._lsd(), R, m_sax,
                                               np.uint8,
                                               prefetch=cfg.prefetch)
                # the sidecar stream is submitted up front: piece j+1's
                # codes (m bytes/series) read while piece j filters/refines
                for start, cnt in pieces:
                    lsd_reader.submit(start, cnt, self._pad_bucket(cnt, R))
                for start, cnt in pieces:
                    # codes padded to the same bucketed shapes as the row
                    # fetches, so the LB kernel compiles O(log) times, not
                    # once per piece length; pad columns are masked out of
                    # `live` below
                    pad_to = self._pad_bucket(cnt, R)
                    codes = lsd_reader.stage(lsd_reader.get())
                    ranks = np.zeros((pad_to,), np.int32)
                    ranks[:cnt] = self._srank[start:start + cnt]
                    self._t["sax_rows_read"] += cnt
                    lb_row = jnp.maximum(
                        kops.lb_sax(q_paa, codes, n, mode=kmode),
                        lbs[:, ranks])                        # (Q, pad_to)
                    bsf = d[:, k - 1]
                    live = ((lb_row * slack < bsf[:, None])
                            & (jnp.arange(pad_to) < cnt)[None, :])
                    alive_counts = alive_counts + jnp.sum(live, axis=1,
                                                          dtype=jnp.int32)
                    alive = np.asarray(jnp.any(live, axis=0))[:cnt]
                    d, p = refine_all(d, p,
                                      [(s0, c0, self._pad_bucket(c0, R))
                                       for s0, c0 in _alive_runs(alive,
                                                                 start)])
            self._t["calls"] += 1
        finally:
            self._reap_reader(lrd_reader)
            if lsd_reader is not None:
                self._reap_reader(lsd_reader)

        res = self._fill_result(
            d, p, self._ids_of(p), path=2,
            accessed=self._t["rows_streamed"] - rows_before)
        sax_pr = (1.0 - alive_counts.astype(jnp.float32)
                  / max(self.saved.num_series, 1)
                  if use_sax else jnp.zeros((qn,), jnp.float32))
        return res._replace(
            eapca_pr=jnp.asarray(eapca_pr, jnp.float32),
            sax_pr=sax_pr,
            visited_leaves=jnp.full((qn,), len(seeded) + int(needed.sum()),
                                    jnp.int32))

    def make_wave_plan(self, cfg, q_struct):
        return lambda q: self._stream_wave_knn(jnp.asarray(q), cfg)

    def estimate_difficulty(self, queries: jax.Array) -> np.ndarray:
        return _difficulty_from_leaf_lbs(
            self._leaf_lbs(jnp.asarray(queries)))

    def _stream_wave_knn(self, q: jax.Array, cfg: SearchConfig) -> KnnResult:
        """Wave-fused out-of-core answering: the `_stream_knn` pipeline with
        the wave's disk schedule made explicit (the ROADMAP's "carefully
        schedule costly operations" applied *across* queries).

        Where `_stream_knn` walks leaf runs in file order, this merges every
        member's alive-run list, counts each run's **demand** (how many
        members still need it), fetches each run exactly once in descending
        demand order, and refines all members per fetched block through the
        shared BSF matrix — so a popular leaf is read once for the whole
        wave and its rows tighten every member's bound before the less
        popular runs are even submitted. Submissions flow through
        :func:`repro.data.pipeline.iter_scheduled_chunks`, whose
        ``still_needed`` re-check runs against the *current* BSF matrix
        right before each submit: a run whose last interested member was
        satisfied by an earlier block is dropped without touching the disk
        (``runs_skipped_bsf``). Exactness: a member is counted out of a
        run's demand only when the run's per-member lower bound (min over
        its rows) cannot beat that member's BSF_k — the same
        no-false-dismissal test as the per-query path — so answers stay
        bit-identical to per-query serving. Telemetry: ``runs_deduped``
        (fetches avoided vs independent queries) and ``wave_rows_shared``
        (rows that served >1 member per single fetch).
        """
        from repro.core.tree import route_to_leaf
        from repro.data.pipeline import (iter_scheduled_chunks,
                                         make_chunk_reader)

        k = cfg.k
        qn = q.shape[0]
        n = self.saved.series_len
        max_leaf = self.saved.max_leaf
        R = self.stream_rows()
        rows_before = self._t["rows_streamed"]
        slack_f = 1.0 - cfg.lb_slack
        d = jnp.full((qn, k), INF)
        p = jnp.full((qn, k), -1, jnp.int32)

        lrd_reader = make_chunk_reader(self._lrd(), R, n,
                                       prefetch=cfg.prefetch)
        lsd_reader = None
        counts = np.asarray(self._leaf_count)
        starts_np = np.asarray(self._leaf_start)
        try:
            # -- phase 1: per-member seed sets, fetched once for the union.
            # Demand = how many members asked for the leaf; popular leaves
            # go first so the shared BSF matrix tightens fastest.
            lbs = self._leaf_lbs(q)                          # (W, L)
            home_nodes = route_to_leaf(self.saved.tree, q,
                                       self.saved.max_depth)
            home_ranks = np.asarray(self._leaf_rank)[np.asarray(home_nodes)]
            l_max = min(cfg.l_max, self.saved.num_leaves)
            _, best = jax.lax.top_k(-lbs, l_max)             # (W, l_max)
            best_np = np.asarray(best)
            demand: collections.Counter = collections.Counter()
            for w in range(qn):
                member = {int(home_ranks[w])} | {int(r) for r in best_np[w]}
                for r in member:
                    if r >= 0 and counts[r] > 0:
                        demand[r] += 1
            seeded = sorted(demand)
            self._t["runs_deduped"] += sum(demand[r] - 1 for r in seeded)
            self._t["wave_rows_shared"] += sum(
                int(counts[r]) * (demand[r] - 1) for r in seeded)
            seed_rows = sum(int(counts[r]) for r in seeded)
            order = sorted(seeded, key=lambda r: (-demand[r], r))
            extents = [(int(starts_np[r]), int(counts[r]), max_leaf)
                       for r in order]
            for start, cnt, pad_to in extents:
                lrd_reader.submit(start, cnt, pad_to)
            for start, cnt, _ in extents:
                rows = lrd_reader.stage(lrd_reader.get())
                d, p = _ooc_refine_block(rows, jnp.int32(start),
                                         jnp.int32(cnt), q, d, p, k=k)
                self._count(cnt)

            # -- phase 2: leaf-level pruning, per member -----------------
            slack = jnp.float32(slack_f)
            bsf = d[:, k - 1]
            cand = lbs * slack < bsf[:, None]                # (W, L)
            needed = np.array(jnp.any(cand, axis=0))
            needed[seeded] = False
            n_alive = max(int((counts > 0).sum()), 1)
            eapca_pr = 1.0 - np.asarray(
                jnp.sum(cand, axis=1), np.float32) / n_alive

            # -- phase 3: build the merged alive-run list with a per-member
            # lower bound per run (min over the run's rows/leaves), instead
            # of refining file-order as the per-query path does -----------
            pieces = self._runs(needed, R)
            use_sax = bool(cfg.use_sax)
            alive_counts = jnp.full((qn,), seed_rows, jnp.int32)
            runs: list[tuple[int, int, np.ndarray]] = []
            if not use_sax:
                lbs_np = np.asarray(lbs)
                for start, cnt in pieces:
                    ranks = np.unique(self._srank[start:start + cnt])
                    runs.append((start, cnt, lbs_np[:, ranks].min(axis=1)))
            elif pieces:
                m_sax = int(self._lsd().shape[1])
                q_paa = S.paa(q, m_sax)
                kmode = resolve_kernel_mode(cfg.kernel_mode)
                lsd_reader = make_chunk_reader(self._lsd(), R, m_sax,
                                               np.uint8,
                                               prefetch=cfg.prefetch)
                for start, cnt in pieces:
                    lsd_reader.submit(start, cnt, self._pad_bucket(cnt, R))
                for start, cnt in pieces:
                    pad_to = self._pad_bucket(cnt, R)
                    codes = lsd_reader.stage(lsd_reader.get())
                    ranks = np.zeros((pad_to,), np.int32)
                    ranks[:cnt] = self._srank[start:start + cnt]
                    self._t["sax_rows_read"] += cnt
                    lb_row = jnp.maximum(
                        kops.lb_sax(q_paa, codes, n, mode=kmode),
                        lbs[:, ranks])                       # (W, pad_to)
                    live = ((lb_row * slack < bsf[:, None])
                            & (jnp.arange(pad_to) < cnt)[None, :])
                    alive_counts = alive_counts + jnp.sum(live, axis=1,
                                                          dtype=jnp.int32)
                    alive = np.asarray(jnp.any(live, axis=0))[:cnt]
                    lb_np = np.asarray(lb_row)
                    for s0, c0 in _alive_runs(alive, start):
                        lo = s0 - start
                        runs.append((s0, c0,
                                     lb_np[:, lo:lo + c0].min(axis=1)))

            # -- phase 4: fetch each run once, most-demanded first, with a
            # late BSF re-check per submit ---------------------------------
            bsf_host = {"kth": np.asarray(d[:, k - 1])}

            def run_demand(run_lb: np.ndarray) -> int:
                return int((run_lb * slack_f < bsf_host["kth"]).sum())

            runs.sort(key=lambda r: (-run_demand(r[2]), r[0]))

            def still_needed(tag) -> bool:
                _, c0, run_lb = tag
                dm = run_demand(run_lb)
                if dm == 0:
                    self._t["runs_skipped_bsf"] += 1
                    return False
                self._t["runs_deduped"] += dm - 1
                self._t["wave_rows_shared"] += c0 * (dm - 1)
                return True

            reqs = [((s0, c0, run_lb), s0, c0, self._pad_bucket(c0, R))
                    for s0, c0, run_lb in runs]
            for (s0, c0, _), rows in iter_scheduled_chunks(
                    lrd_reader, reqs, still_needed=still_needed):
                d, p = _ooc_refine_block(rows, jnp.int32(s0), jnp.int32(c0),
                                         q, d, p, k=k)
                self._count(c0)
                bsf_host["kth"] = np.asarray(d[:, k - 1])
            self._t["calls"] += 1
            self._t["wave_calls"] += 1
        finally:
            self._reap_reader(lrd_reader)
            if lsd_reader is not None:
                self._reap_reader(lsd_reader)

        res = self._fill_result(
            d, p, self._ids_of(p), path=2,
            accessed=self._t["rows_streamed"] - rows_before)
        sax_pr = (1.0 - alive_counts.astype(jnp.float32)
                  / max(self.saved.num_series, 1)
                  if use_sax else jnp.zeros((qn,), jnp.float32))
        return res._replace(
            eapca_pr=jnp.asarray(eapca_pr, jnp.float32),
            sax_pr=sax_pr,
            visited_leaves=jnp.full((qn,), len(seeded) + int(needed.sum()),
                                    jnp.int32))

    def _runs(self, needed: np.ndarray, max_rows: int):
        """Merge needed leaves' extents into contiguous row intervals (leaf
        in-order == file order), then cut into ≤ max_rows pieces."""
        starts = np.asarray(self._leaf_start)
        counts = np.asarray(self._leaf_count)
        intervals: list[list[int]] = []
        for r in np.flatnonzero(needed):
            lo, hi = int(starts[r]), int(starts[r] + counts[r])
            if hi <= lo:
                continue
            if intervals and intervals[-1][1] == lo:
                intervals[-1][1] = hi
            else:
                intervals.append([lo, hi])
        pieces = []
        for lo, hi in intervals:
            for s in range(lo, hi, max_rows):
                pieces.append((s, min(max_rows, hi - s)))
        return pieces


# ---------------------------------------------------------------------------
# Sharded backend — the distributed StackedIndex under a mesh
# ---------------------------------------------------------------------------

class ShardedBackend(BackendBase):
    """Series-sharded Hercules (``StackedIndex``): per-shard exact top-k,
    all-gather, global merge. With one shard on one device this degenerates
    to the local pipeline (same arithmetic, same answers).

    ``positions`` in results are -1 (layout positions are per-shard; global
    ``ids`` are exact) and the per-query pruning telemetry is zeroed —
    cross-shard aggregation of those counters is future work.
    """

    name = "sharded"

    def __init__(self, stacked, mesh=None):
        from jax.sharding import Mesh  # noqa: F401  (type only)

        self.stacked = stacked
        if mesh is None:
            from repro.distributed.compat import make_mesh
            mesh = make_mesh((len(jax.devices()),), ("data",))
        ndev = int(np.prod(list(mesh.shape.values())))
        if stacked.num_shards != ndev:
            raise ValueError(f"index has {stacked.num_shards} shards but the "
                             f"mesh has {ndev} devices")
        self.mesh = mesh
        self._programs: dict[SearchConfig, Callable] = {}

    @property
    def series_len(self) -> int:
        return self.stacked.layout.series_len

    @property
    def base_config(self) -> SearchConfig:
        return self.stacked.config.search

    def _validate(self, cfg: SearchConfig) -> None:
        validate_runtime_config(cfg, self.stacked.layout.lrd.shape[-2])

    def _run_for(self, cfg: SearchConfig):
        if cfg not in self._programs:
            from repro.distributed.search import make_distributed_search
            self._programs[cfg] = make_distributed_search(
                self.mesh, cfg, self.stacked.max_depth,
                self.stacked.tree, self.stacked.layout)
        return self._programs[cfg]

    def _offsets(self):
        return self.stacked.shard_offsets.reshape(self.stacked.num_shards, 1)

    def _result(self, d, gid) -> KnnResult:
        return self._fill_result(d, jnp.full_like(gid, -1), gid)

    def _bind(self, cfg):
        run = self._run_for(cfg)
        st = self.stacked
        return lambda q: self._result(
            *run(st.tree, st.layout, self._offsets(), q))

    def make_plan(self, cfg, q_struct):
        run = self._run_for(cfg)
        st = self.stacked
        offsets = self._offsets()
        compiled = run.lower(st.tree, st.layout, offsets, q_struct).compile()
        return lambda q: self._result(
            *compiled(st.tree, st.layout, offsets, q))

    def stats(self) -> dict:
        st = self.stacked
        return {"num_shards": st.num_shards,
                "num_series": st.num_shards * st.layout.num_series,
                "series_len": st.layout.series_len}

    def describe(self) -> dict:
        d = super().describe()
        d.update(self.stats(), mesh={a: int(s) for a, s in self.mesh.shape.items()})
        return d


# ---------------------------------------------------------------------------
# The engine: bucketed batching + compiled-plan LRU + telemetry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EngineConfig:
    plan_cache_size: int = 32
    # explicit batch buckets (ascending); empty -> next power of two
    bucket_sizes: tuple[int, ...] = ()
    # pull per-query path/pruning stats to host after each call
    collect_result_stats: bool = True


class QueryEngine:
    """A serving session over one :class:`SearchBackend`.

    Every call pads the query batch up to a bucket size and dispatches a
    cached AOT-compiled plan for (SearchConfig, bucket). Repeated serving
    calls with the same statics therefore never retrace or recompile —
    ``telemetry()["plan_cache"]`` proves it.
    """

    def __init__(self, backend: SearchBackend,
                 config: EngineConfig | None = None):
        self.backend = backend
        self.config = config or EngineConfig()
        self._plans: collections.OrderedDict = collections.OrderedDict()
        self._t = {
            "calls": 0, "queries": 0, "wave_calls": 0,
            "hits": 0, "misses": 0, "evictions": 0,
            "invalidations": 0,
            "compile_s": 0.0, "exec_s": 0.0, "last_exec_s": 0.0,
            "paths": np.zeros(4, np.int64), "path_unknown": 0,
            "eapca_pr_sum": 0.0, "sax_pr_sum": 0.0, "stat_queries": 0,
        }

    def invalidate(self) -> None:
        """Drop every cached compiled plan. Called when the data a plan was
        compiled against changes underneath the backend — e.g. the store
        handle (``repro.storage.store.Hercules``) appended or compacted —
        so a stale executable can never serve the mutated collection."""
        self._plans.clear()
        self._t["invalidations"] += 1

    # -- batching -----------------------------------------------------------

    def _bucket(self, qn: int) -> int:
        for b in sorted(self.config.bucket_sizes):
            if qn <= b:
                return b
        # larger than every configured bucket (or none configured):
        # next power of two keeps the distinct-shape count logarithmic
        return max(1, 1 << (qn - 1).bit_length())

    # -- the one call that matters ------------------------------------------

    def knn(self, queries: jax.Array, k: int | None = None,
            valid_rows: int | None = None, wave: bool = False,
            **overrides: Any) -> KnnResult:
        """``valid_rows``: when the caller already padded the batch (e.g. a
        slot-based server filling its wave), the number of leading real
        queries — results are sliced and telemetry counted on those only.

        ``wave=True`` answers the batch through the backend's wave-fused
        plan (shared descent / BSF matrix / once-per-wave disk fetches);
        answers are bit-identical to ``wave=False``, which maps the
        per-query pipeline over the batch. Backends without per-query work
        to share (dense scans, sharded) fall back to the regular plan."""
        q = jnp.asarray(queries)
        if q.ndim == 1:
            q = q[None, :]
        n = getattr(self.backend, "series_len", None)
        if n and q.shape[1] != n:
            raise ValueError(f"query length {q.shape[1]} != collection "
                             f"series length {n}")
        cfg = self.backend.resolve(k, overrides)
        qn = q.shape[0] if valid_rows is None else valid_rows
        if not 0 < qn <= q.shape[0]:
            raise ValueError(f"valid_rows={valid_rows} out of range for "
                             f"batch of {q.shape[0]}")
        bucket = self._bucket(q.shape[0])
        if bucket != q.shape[0]:
            q = jnp.concatenate(
                [q, jnp.zeros((bucket - q.shape[0], q.shape[1]), q.dtype)],
                axis=0)

        key = (cfg, bucket, q.shape[1], q.dtype.name, wave)
        plan = self._plans.get(key)
        if plan is None:
            t0 = time.perf_counter()
            maker = (self.backend.make_wave_plan if wave
                     else self.backend.make_plan)
            plan = maker(cfg, jax.ShapeDtypeStruct(q.shape, q.dtype))
            self._t["compile_s"] += time.perf_counter() - t0
            self._t["misses"] += 1
            self._plans[key] = plan
            while len(self._plans) > self.config.plan_cache_size:
                self._plans.popitem(last=False)
                self._t["evictions"] += 1
        else:
            self._t["hits"] += 1
            self._plans.move_to_end(key)

        t0 = time.perf_counter()
        res = plan(q)
        jax.block_until_ready(res.dists)
        dt = time.perf_counter() - t0
        self._t["exec_s"] += dt
        self._t["last_exec_s"] = dt
        self._t["calls"] += 1
        self._t["queries"] += qn
        if wave:
            self._t["wave_calls"] += 1

        if bucket != qn:
            res = KnnResult(*[a[:qn] for a in res])
        if self.config.collect_result_stats:
            self._record(res)
        return res

    def estimate_difficulty(self, queries) -> np.ndarray | None:
        """Cheap per-query cost scores in [0, 1] (higher = likely slower),
        from the backend's resident pruning tables — the signal behind
        difficulty-aware wave packing. ``None`` when the backend has no
        leaf-bound landscape to score against (dense scans cost the same
        for every query)."""
        fn = getattr(self.backend, "estimate_difficulty", None)
        if fn is None:
            return None
        return fn(jnp.asarray(queries))

    def _record(self, res: KnnResult) -> None:
        path = np.asarray(res.path)
        known = path >= 0
        self._t["paths"] += np.bincount(path[known], minlength=4)[:4]
        self._t["path_unknown"] += int((~known).sum())
        if known.any():
            self._t["eapca_pr_sum"] += float(np.asarray(res.eapca_pr)[known].sum())
            self._t["sax_pr_sum"] += float(np.asarray(res.sax_pr)[known].sum())
            self._t["stat_queries"] += int(known.sum())

    # -- introspection ------------------------------------------------------

    def telemetry(self) -> dict:
        t = self._t
        n_stat = max(t["stat_queries"], 1)
        bstats = self.backend.stats()
        ooc = ({k: bstats[k] for k in
                ("calls", "blocks", "rows_streamed", "wave_calls",
                 "wave_rows_shared", "runs_deduped", "runs_skipped_bsf")
                if k in bstats}
               if "rows_streamed" in bstats else None)
        out = {
            "backend": self.backend.name,
            "calls": t["calls"],
            "queries": t["queries"],
            "wave_calls": t["wave_calls"],
            "plan_cache": {
                "hits": t["hits"], "misses": t["misses"],
                "evictions": t["evictions"], "size": len(self._plans),
                "capacity": self.config.plan_cache_size,
                "compiles": t["misses"], "compile_s": t["compile_s"],
                "invalidations": t["invalidations"],
            },
            "latency_s": {
                "total": t["exec_s"], "last": t["last_exec_s"],
                "mean_per_call": t["exec_s"] / max(t["calls"], 1),
                "mean_per_query": t["exec_s"] / max(t["queries"], 1),
            },
            "paths": {
                "scan_eapca": int(t["paths"][0]),
                "scan_sax": int(t["paths"][1]),
                "pruned": int(t["paths"][2]),
                "forced_scan": int(t["paths"][3]),
                "unknown": t["path_unknown"],
            },
            "pruning": {
                "eapca_mean": t["eapca_pr_sum"] / n_stat,
                "sax_mean": t["sax_pr_sum"] / n_stat,
            },
        }
        if ooc is not None:
            out["ooc"] = ooc
        return out

    def stats(self) -> dict:
        return self.backend.stats()

    def describe(self) -> dict:
        return {
            "engine": {
                "plan_cache_size": self.config.plan_cache_size,
                "bucket_sizes": list(self.config.bucket_sizes) or "pow2",
                "cached_plans": [
                    {"k": key[0].k, "bucket": key[1], "series_len": key[2]}
                    for key in self._plans],
            },
            "backend": self.backend.describe(),
        }


# ---------------------------------------------------------------------------
# Name-based construction (benchmarks/run.py --backend, serve_knn CLI)
# ---------------------------------------------------------------------------

BACKEND_NAMES = ("local", "scan", "scan-mxu", "sharded")


def make_backend(name: str, data: jax.Array, *,
                 index_config: IndexConfig | None = None,
                 search: SearchConfig | None = None,
                 num_shards: int | None = None,
                 mesh=None) -> SearchBackend:
    """Build a backend over ``data`` by name.

    ``local``/``sharded`` construct the Hercules index (or stacked indexes);
    ``scan``/``scan-mxu`` serve the raw collection directly.
    """
    if name == "local":
        cfg = index_config or IndexConfig(search=search or SearchConfig())
        return LocalBackend(HerculesIndex.build(data, cfg))
    if name in ("scan", "scan-mxu"):
        scfg = search or (index_config.search if index_config else SearchConfig())
        return ScanBackend(data, scfg, mxu=name == "scan-mxu")
    if name == "sharded":
        from repro.distributed.search import build_distributed_index
        cfg = index_config or IndexConfig(search=search or SearchConfig())
        shards = num_shards or len(jax.devices())
        stacked = build_distributed_index(data, shards, cfg)
        return ShardedBackend(stacked, mesh)
    raise ValueError(f"unknown backend {name!r}; expected one of {BACKEND_NAMES}")


DISK_BACKEND_NAMES = ("local", "scan", "ooc-scan", "ooc-local")


def make_disk_backend(name: str, store, *,
                      search: SearchConfig | None = None,
                      memory_budget_mb: float = 64.0,
                      verify: bool = True,
                      prefetch: str | None = None) -> SearchBackend:
    """Serve a saved index by backend name.

    ``store`` is an index-directory path, an already-open ``SavedIndex``,
    or a ``Hercules`` store handle (backends then resolve their data
    through the handle's current base index). ``local``/``scan``
    materialize the saved arrays into the ordinary in-memory backends
    (bit-identical to the ones built from the original data);
    ``ooc-scan``/``ooc-local`` keep the raw series memory-mapped and
    stream them under ``memory_budget_mb``. ``prefetch`` overrides
    ``SearchConfig.prefetch`` for the streamed backends (``"thread"`` =
    async reader thread + two-slot host buffer; answers bit-identical to
    ``"sync"``).

    .. deprecated:: store API
        For directory paths prefer ``repro.api.Hercules.open(path)
        .engine(name)``, which additionally caches engines and invalidates
        compiled plans across ``append``/``compact``; this remains the
        low-level constructor the store delegates to.
    """
    from repro.storage import open_index

    if isinstance(store, str):
        saved = open_index(store, verify=verify)
    else:
        # a Hercules handle exposes .saved; a SavedIndex is used directly
        saved = getattr(store, "saved", store)
        if saved is None:
            raise ValueError(
                f"{store!r} has no base index to serve — append rows and "
                f"compact() first")
    if prefetch is not None:
        search = dataclasses.replace(search or saved.config.search,
                                     prefetch=prefetch)
    if name == "local":
        idx = saved.to_index()
        if search is not None:
            idx.config = dataclasses.replace(idx.config, search=search)
        return LocalBackend(idx)
    if name == "scan":
        return ScanBackend(jnp.asarray(saved.original_data()),
                           search or saved.config.search)
    if name == "ooc-scan":
        return OutOfCoreScanBackend(saved, search,
                                    memory_budget_mb=memory_budget_mb)
    if name == "ooc-local":
        return OutOfCoreLocalBackend(saved, search,
                                     memory_budget_mb=memory_budget_mb)
    raise ValueError(f"unknown disk backend {name!r}; expected one of "
                     f"{DISK_BACKEND_NAMES}")
