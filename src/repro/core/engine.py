"""Unified query engine — one search surface over every backend.

The paper's system answers exact kNN through one carefully scheduled
pipeline; this repo grew three incompatible entry points around it
(``HerculesIndex.knn``, the distributed ``StackedIndex``, the PSCAN
baseline). This module is the serving layer that unifies them:

* :class:`SearchBackend` — the protocol every answering path conforms to:
  ``knn(queries, k=None, **overrides) -> KnnResult`` plus ``stats()`` /
  ``describe()``. Three adapters ship here:

  - :class:`LocalBackend`   — in-process :class:`HerculesIndex` (the paper).
  - :class:`ShardedBackend` — the distributed ``StackedIndex`` under a mesh
    (per-shard exact top-k + all-gather merge).
  - :class:`ScanBackend`    — the dense blocked scan (PSCAN). Its default
    *parity* arithmetic uses the same difference-form squared-ED as the
    index's refinement/leaf paths, so answers are **bit-identical** across
    backends; ``mxu=True`` switches to the matmul-identity form (the
    high-arithmetic-intensity MXU path, equal up to fp32 rounding).

* :class:`QueryEngine` — a serving session over one backend that

  (a) buckets arbitrary query-batch shapes to a small set of padded sizes
      and keeps an LRU **compiled-plan cache** keyed by (static
      SearchConfig, bucket shape): plans are AOT-lowered and compiled
      (``jit(...).lower(...).compile()``), so a cache hit *cannot* retrace —
      the executable takes only device arrays;
  (b) separates build-time statics (the layout's padded row count) from
      per-call knobs: any ``chunk``/``scan_block`` dividing the padded size
      is a legal override (``validate_runtime_config``), and ``k``/``l_max``/
      threshold/ablation knobs are always legal;
  (c) exposes engine-level telemetry — plan-cache hits/misses/evictions,
      compile and execute latency, access-path counts and pruning ratios —
      as a plain dict (:meth:`QueryEngine.telemetry`).

Everything above this layer (serving loop, benchmarks, examples, CLIs)
talks to backends only through the engine.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import logging
import time
from typing import Any, Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import summaries as S
from repro.core.index import HerculesIndex, IndexConfig
from repro.core.search import (INF, KnnResult, SearchConfig, _merge_topk,
                               exact_knn, pscan_knn, validate_runtime_config,
                               wave_knn)
from repro.kernels import ops as kops
from repro.kernels.compat import resolve_kernel_mode

logger = logging.getLogger(__name__)


@runtime_checkable
class SearchBackend(Protocol):
    """What the engine (and anything else) may assume about an answering path."""

    name: str

    def resolve(self, k: int | None = None,
                overrides: dict[str, Any] | None = None) -> SearchConfig: ...

    def make_plan(self, cfg: SearchConfig,
                  q_struct: jax.ShapeDtypeStruct
                  ) -> Callable[[jax.Array], KnnResult]: ...

    def make_wave_plan(self, cfg: SearchConfig,
                       q_struct: jax.ShapeDtypeStruct
                       ) -> Callable[[jax.Array], KnnResult]: ...

    def knn(self, queries: jax.Array, k: int | None = None,
            **overrides: Any) -> KnnResult: ...

    def stats(self) -> dict: ...

    def describe(self) -> dict: ...


class BackendBase:
    """Shared resolve/describe plumbing; subclasses supply the compute."""

    name = "backend"

    @property
    def series_len(self) -> int | None:
        """Collection series length, when known (engine input validation)."""
        return None

    @property
    def base_config(self) -> SearchConfig:
        raise NotImplementedError

    def _validate(self, cfg: SearchConfig) -> None:
        pass

    def resolve(self, k: int | None = None,
                overrides: dict[str, Any] | None = None) -> SearchConfig:
        cfg = self.base_config
        upd = dict(overrides or {})
        if k is not None:
            upd["k"] = k
        if upd:
            cfg = dataclasses.replace(cfg, **upd)
        self._validate(cfg)
        return cfg

    def make_plan(self, cfg, q_struct):
        raise NotImplementedError

    def make_wave_plan(self, cfg, q_struct):
        """Plan for a *wave* — a batch of queries answered with fused
        scheduling (shared descent/BSF/fetches). The default falls back to
        the regular plan: dense scans and the sharded all-gather are
        already batch-fused, so for them the wave path IS the batch path.
        Backends with per-query work to share override this."""
        return self.make_plan(cfg, q_struct)

    def knn(self, queries: jax.Array, k: int | None = None,
            **overrides: Any) -> KnnResult:
        """Direct (non-engine) call; still jit-cached, but may retrace on
        new shapes. Serving code should go through :class:`QueryEngine`."""
        cfg = self.resolve(k, overrides)
        return self._bind(cfg)(jnp.asarray(queries))

    def _bind(self, cfg: SearchConfig) -> Callable[[jax.Array], KnnResult]:
        raise NotImplementedError

    @staticmethod
    def _fill_result(dists, positions, ids, *, path: int = -1,
                     accessed=None) -> KnnResult:
        """KnnResult from the (dists, positions, ids) a backend computes,
        with the per-query telemetry fields it does not track filled by one
        convention: path ``-1`` = unknown, pruning ratios 0, ``accessed``
        0 / a scalar broadcast / a per-query vector."""
        qn = dists.shape[0]
        zeros_f = jnp.zeros((qn,), jnp.float32)
        zeros_i = jnp.zeros((qn,), jnp.int32)
        if accessed is None:
            accessed = zeros_i
        elif jnp.ndim(accessed) == 0:
            accessed = jnp.full((qn,), accessed, jnp.int32)
        return KnnResult(
            dists=dists, positions=positions, ids=ids,
            path=jnp.full((qn,), path, jnp.int32),
            eapca_pr=zeros_f, sax_pr=zeros_f,
            accessed=accessed, visited_leaves=zeros_i)

    def stats(self) -> dict:
        return {}

    def describe(self) -> dict:
        return {"backend": self.name,
                "config": dataclasses.asdict(self.base_config)}


# ---------------------------------------------------------------------------
# Local backend — the paper's single-node Hercules index
# ---------------------------------------------------------------------------

class LocalBackend(BackendBase):
    """In-process :class:`HerculesIndex` (tree + LRD/LSD layout)."""

    name = "local"

    def __init__(self, index: HerculesIndex):
        self.index = index

    @property
    def series_len(self) -> int:
        return self.index.layout.series_len

    @property
    def base_config(self) -> SearchConfig:
        return self.index.config.search

    def _validate(self, cfg: SearchConfig) -> None:
        validate_runtime_config(cfg, self.index.layout.lrd.shape[0])

    def _bind(self, cfg):
        idx = self.index
        return lambda q: exact_knn(idx.tree, idx.layout, q, cfg, idx.max_depth)

    def make_plan(self, cfg, q_struct):
        idx = self.index
        compiled = exact_knn.lower(
            idx.tree, idx.layout, q_struct, cfg, idx.max_depth).compile()
        return lambda q: compiled(idx.tree, idx.layout, q)

    def make_wave_plan(self, cfg, q_struct):
        idx = self.index
        compiled = wave_knn.lower(
            idx.tree, idx.layout, q_struct, cfg, idx.max_depth).compile()
        return lambda q: compiled(idx.tree, idx.layout, q)

    def estimate_difficulty(self, queries: jax.Array) -> np.ndarray:
        from repro.core.search import _wave_leaf_lbs
        return _difficulty_from_leaf_lbs(
            _wave_leaf_lbs(jnp.asarray(queries), self.index.layout))

    def stats(self) -> dict:
        return self.index.stats()

    def describe(self) -> dict:
        d = super().describe()
        d["num_series"] = self.index.layout.num_series
        d["series_len"] = self.index.layout.series_len
        return d


# ---------------------------------------------------------------------------
# Scan backend — PSCAN as a first-class backend
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "block"))
def dense_scan_knn(data: jax.Array, queries: jax.Array, k: int = 1,
                   block: int = 4096):
    """Blocked exact scan in *difference form* (``sum((s - q)^2)`` per row —
    the same arithmetic as the index's leaf/refinement paths, hence
    bit-identical answers). ``data`` may be unpadded. Returns (Q,k) dists
    and positions."""
    num, n = data.shape
    n_pad = -(-num // block) * block
    if n_pad != num:
        data = jnp.concatenate(
            [data, jnp.zeros((n_pad - num, n), data.dtype)], axis=0)
    blocks3 = data.reshape(n_pad // block, block, n)

    def one(q):
        d0 = jnp.full((k,), INF)
        p0 = jnp.full((k,), -1, jnp.int32)

        def body(carry, blk):
            d_top, p_top, base = carry
            d = jnp.sum(jnp.square(blk - q[None, :]), axis=1)
            pos = base + jnp.arange(block, dtype=jnp.int32)
            d = jnp.where(pos < num, d, INF)
            d_top, p_top = _merge_topk(d_top, p_top, d, pos, k)
            return (d_top, p_top, base + block), None

        (d_top, p_top, _), _ = jax.lax.scan(body, (d0, p0, jnp.int32(0)), blocks3)
        return d_top, p_top

    return jax.lax.map(one, queries)


@functools.partial(jax.jit, static_argnames=("k", "block", "mode"))
def kernel_scan_knn(data: jax.Array, queries: jax.Array, k: int = 1,
                    block: int = 4096, mode: str = "pallas"):
    """Blocked exact scan through the Pallas ED kernels (``kernels/ops``).

    Candidate *selection* runs on the kernels — the fused :func:`ops.ed_min`
    1-NN scan for ``k == 1`` (the paper's dominant query), blocked
    :func:`ops.ed_matrix` + per-block top-k otherwise. The *reported*
    distances for selected rows are always recomputed in difference form
    (``sum((s - q)^2)``) — the same arithmetic as every other backend path —
    and for ``k > 1`` the cross-block running top-k merges those exact
    values through the shared :func:`_merge_topk`, so kernel arithmetic
    influences at most the within-block candidate choice. Answers match
    :func:`dense_scan_knn` bit-for-bit unless the matmul-identity fp32
    error exceeds the distance gap at a top-k boundary (the ``scan-mxu``
    caveat; asserted exactly on the parity workloads). Returns (Q, k)
    dists and positions.
    """
    num, n = data.shape
    qn = queries.shape[0]

    def exact_d(p):
        """Difference-form distances for selected positions (-1/pad -> inf)."""
        rows = data[jnp.clip(p, 0, num - 1)]                     # (Q, k, n)
        d = jnp.sum(jnp.square(rows - queries[:, None, :]), axis=-1)
        return jnp.where((p >= 0) & (p < num), d, INF)

    if k == 1:
        # valid_n masking in the kernel guarantees a real row wins the min
        _, amin = kops.ed_min(queries, data, mode=mode)
        p_top = amin[:, None].astype(jnp.int32)                  # (Q, 1)
        return exact_d(p_top), p_top

    n_pad = -(-num // block) * block
    padded = data if n_pad == num else jnp.concatenate(
        [data, jnp.zeros((n_pad - num, n), data.dtype)], axis=0)
    blocks3 = padded.reshape(n_pad // block, block, n)
    merge = jax.vmap(functools.partial(_merge_topk, k=k))

    def body(carry, blk):
        d_top, p_top, base = carry
        d = kops.ed_matrix(queries, blk, mode=mode)              # (Q, block)
        pos = base + jnp.arange(block, dtype=jnp.int32)
        d = jnp.where((pos < num)[None, :], d, INF)
        _, idx = jax.lax.top_k(-d, k)                            # (Q, k)
        cand = jnp.where(jnp.take_along_axis(d, idx, axis=1) < INF,
                         pos[idx], -1)
        d_top, p_top = merge(d_top, p_top, exact_d(cand), cand)
        return (d_top, p_top, base + block), None

    d0 = jnp.full((qn, k), INF)
    p0 = jnp.full((qn, k), -1, jnp.int32)
    (d_top, p_top, _), _ = jax.lax.scan(body, (d0, p0, jnp.int32(0)), blocks3)
    return d_top, p_top


class ScanBackend(BackendBase):
    """Dense blocked scan over the raw collection (the PSCAN baseline).

    Arithmetic selection, in priority order:

    * ``cfg.kernel_mode`` *explicitly* ``pallas``/``interpret`` (or ``auto``
      resolving to Pallas with ``mxu=False``): the scan runs on the ED
      kernels via :func:`kernel_scan_knn` — reported distances are
      recomputed in difference form, so answers match the reference path.
    * ``mxu=True``: matmul-identity distances on the MXU via XLA
      (:func:`pscan_knn`; equal up to fp32 rounding). Wins over the
      implicit ``auto`` resolution, never over an explicit Pallas request.
    * otherwise: difference-form :func:`dense_scan_knn`, bit-identical to
      :class:`LocalBackend`.
    """

    name = "scan"

    def __init__(self, data: jax.Array, config: SearchConfig | None = None,
                 mxu: bool = False):
        self.data = jnp.asarray(data)
        self._config = dataclasses.replace(
            config or SearchConfig(), force_scan=True)
        self.mxu = mxu

    @property
    def series_len(self) -> int:
        return int(self.data.shape[1])

    @property
    def base_config(self) -> SearchConfig:
        return self._config

    def _validate(self, cfg: SearchConfig) -> None:
        if cfg.scan_block <= 0:
            raise ValueError("scan_block must be positive")

    def _result(self, d, p) -> KnnResult:
        # identity layout (pos == id); path 3 = forced scan, everything read
        return self._fill_result(d, p, p, path=3, accessed=self.data.shape[0])

    def _fn_args(self, cfg):
        """(jitted fn, static args after (data, queries)) for this config.

        ``mxu=True`` is an explicit arithmetic choice, so it wins over the
        implicit ``kernel_mode="auto"`` resolution; an *explicit* Pallas
        mode (``pallas``/``interpret``) wins over ``mxu``.
        """
        mode = resolve_kernel_mode(cfg.kernel_mode)
        if mode != "ref" and not (self.mxu and cfg.kernel_mode == "auto"):
            return kernel_scan_knn, (cfg.k, cfg.scan_block, mode)
        return (pscan_knn if self.mxu else dense_scan_knn), \
            (cfg.k, cfg.scan_block)

    def _bind(self, cfg):
        fn, args = self._fn_args(cfg)
        return lambda q: self._result(*fn(self.data, q, *args))

    def make_plan(self, cfg, q_struct):
        fn, args = self._fn_args(cfg)
        compiled = fn.lower(self.data, q_struct, *args).compile()
        return lambda q: self._result(*compiled(self.data, q))

    def stats(self) -> dict:
        return {"num_series": int(self.data.shape[0]),
                "series_len": int(self.data.shape[1])}

    def describe(self) -> dict:
        d = super().describe()
        d.update(self.stats(), mxu=self.mxu)
        return d


# ---------------------------------------------------------------------------
# Out-of-core backends — serving a memory-mapped on-disk index under a budget
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "block", "mode"))
def _ooc_scan_block(rows: jax.Array, queries: jax.Array, base: jax.Array,
                    *, k: int, block: int, mode: str):
    """Top-k of one streamed row block through the in-memory scan hot path;
    positions shifted to global layout coordinates."""
    if mode == "ref":
        d, p = dense_scan_knn(rows, queries, k=k, block=block)
    else:
        d, p = kernel_scan_knn(rows, queries, k=k, block=block, mode=mode)
    return d, jnp.where(p >= 0, p + base, -1)


@functools.partial(jax.jit, static_argnames=("k",))
def _ooc_merge(d0, p0, d1, p1, *, k: int):
    merge = jax.vmap(lambda a, b, c, e: _merge_topk(a, b, c, e, k))
    return merge(d0, p0, d1, p1)


@functools.partial(jax.jit, static_argnames=("k",))
def _ooc_refine_block(rows: jax.Array, base: jax.Array, valid: jax.Array,
                      queries: jax.Array, d0, p0, *, k: int):
    """Merge exact difference-form distances of one padded row block into
    each query's running top-k (rows beyond ``valid`` are masked)."""
    r = rows.shape[0]
    pos = base + jnp.arange(r, dtype=jnp.int32)
    live = jnp.arange(r) < valid

    def one(args):
        q, d_top, p_top = args
        d = jnp.sum(jnp.square(rows - q[None, :]), axis=1)
        d = jnp.where(live, d, INF)
        return _merge_topk(d_top, p_top, d, pos, k)

    return jax.lax.map(one, (queries, d0, p0))


# -- codec-aware streaming (format v3 encoded leaves) -----------------------
#
# With a lossy codec the streamed bytes are approximations, so decoded
# distances can only *select* candidates, never answer. Per block we turn
# each decoded distance d̂ into a sound interval around the true distance
# using the per-row reconstruction bound e embedded at encode time
# (||s - ŝ|| <= e, storage/codecs.py):
#
#     sqrt(d_true) ∈ [sqrt(d̂) - e, sqrt(d̂) + e]
#
# and carry two running sets per query: the k smallest *upper* bounds
# (a conservative BSF — the kth UB provably upper-bounds the true kth
# distance) and the _CAND smallest *lower* bounds (the candidate pool).
# After the stream, candidates are re-checked against the full-precision
# float32 rows with the exact difference-form arithmetic — bit-identical
# distances to LocalBackend — and a guard certifies completeness: every
# dropped/pruned row had LB >= the kth UB, so it cannot beat the top-k.
# Guard failure (bounds too loose for this batch) falls back to the raw
# float32 stream — counted in ``codec_fallbacks``, never wrong.

_CAND_MARGIN = 32   # candidate pool size = k + margin (see _codec_cand)

# slack absorbing the float32 evaluation error of the decoded distances
# themselves (identity-form matmul): additive in the *squared* domain,
# scaled by the norms entering the dot product. The stored per-row ``e``
# only covers reconstruction error, not arithmetic.
_BOUND_REL = 1e-5
_BOUND_ABS = 1e-6


def _codec_cand(k: int, num: int) -> int:
    return min(num, k + _CAND_MARGIN)


def _merge_topc(d0, p0, d1, p1, c: int):
    """Per-query: merge (value, position) pairs, keep the ``c`` smallest.
    Unlike ``_merge_topk`` there is no duplicate suppression — codec
    streams visit each position exactly once."""
    d = jnp.concatenate([d0, d1])
    pos = jnp.concatenate([p0, p1])
    neg, idx = jax.lax.top_k(-d, c)
    return -neg, pos[idx]


@functools.partial(jax.jit, static_argnames=("codec", "series_len", "k",
                                             "cand", "mode"))
def _codec_bounds_block(enc, queries, base, valid, ub_d, ub_p, lb_d, lb_p, *,
                        codec, series_len: int, k: int, cand: int, mode: str):
    """Fold one encoded row block into the UB/LB carries (see above).

    ``enc`` is (B, W) uint8; rows at or past ``valid`` are padding. For the
    bf16 codec on a kernel mode the decode is fused into the ED kernel
    (``kops.decode_bf16_ed_matrix``): the payload is bitcast to bfloat16 and
    upcast per tile in VMEM, so decoded float32 rows never touch HBM.
    """
    num = enc.shape[0]
    qn2 = jnp.sum(queries * queries, axis=1)
    if getattr(codec, "name", None) == "bf16" and mode != "ref":
        payload, err = codec.split(enc)
        d_dec = kops.decode_bf16_ed_matrix(queries, payload, mode=mode)
        half = jax.lax.bitcast_convert_type(
            jnp.reshape(payload, (num, series_len, 2)), jnp.bfloat16)
        sn2 = jnp.sum(jnp.square(half.astype(jnp.float32)), axis=1)
    else:
        rows, err = codec.decode(enc, series_len)
        sn2 = jnp.sum(rows * rows, axis=1)
        d_dec = (qn2[:, None] + sn2[None, :]
                 - 2.0 * (queries @ rows.T))
    # additive slack in the squared domain, then sound sqrt-scale interval
    delta = _BOUND_REL * (qn2[:, None] + sn2[None, :]) + _BOUND_ABS
    r_lo = jnp.sqrt(jnp.maximum(d_dec - delta, 0.0))
    r_hi = jnp.sqrt(jnp.maximum(d_dec, 0.0) + delta)
    lb = jnp.square(jnp.maximum(r_lo - err[None, :], 0.0))
    ub = jnp.square(r_hi + err[None, :])
    live = jnp.arange(num) < valid
    pos = jnp.where(live, base + jnp.arange(num, dtype=jnp.int32), -1)
    lb = jnp.where(live[None, :], lb, INF)
    ub = jnp.where(live[None, :], ub, INF)
    pos_b = jnp.broadcast_to(pos, lb.shape)
    ub_d, ub_p = jax.vmap(
        lambda a, b, c, e: _merge_topc(a, b, c, e, k))(ub_d, ub_p, ub, pos_b)
    lb_d, lb_p = jax.vmap(
        lambda a, b, c, e: _merge_topc(a, b, c, e, cand))(lb_d, lb_p, lb,
                                                          pos_b)
    return ub_d, ub_p, lb_d, lb_p


@functools.partial(jax.jit, static_argnames=("k",))
def _codec_exact_topk(rows, p, queries, *, k: int):
    """Exact top-k over the gathered candidate rows: (Q, C, n) float32 rows
    at positions ``p`` (−1 = padding), same difference-form arithmetic as
    ``_ooc_refine_block`` — distances bit-identical to LocalBackend's."""
    d = jnp.sum(jnp.square(rows - queries[:, None, :]), axis=-1)
    d = jnp.where(p >= 0, d, INF)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, jnp.take_along_axis(p, idx, axis=1)


def _difficulty_from_leaf_lbs(lbs) -> np.ndarray:
    """Per-query cost score in [0, 1] from the leaf-bound landscape: the
    fraction of alive leaves whose LB_EAPCA is within 2x of the query's
    best bound. A flat landscape (many near-best leaves) predicts weak
    pruning — the query will touch many leaves and serve expensive; a
    spiky one prunes well and serves cheap. This is the difficulty signal
    the serve loop's ``pack="difficulty"`` wave packing keys on."""
    lbs = np.asarray(lbs)
    finite = np.isfinite(lbs)
    n_alive = np.maximum(finite.sum(axis=1), 1)
    best = np.where(finite, lbs, np.inf).min(axis=1)
    near = finite & (lbs <= 2.0 * best[:, None] + 1e-12)
    return near.sum(axis=1).astype(np.float32) / n_alive


def _alive_runs(alive: np.ndarray, base: int) -> list[tuple[int, int]]:
    """Contiguous True runs of a row-survival mask as absolute
    (start, count) pairs — the sub-extents the SAX filter could not prune."""
    idx = np.flatnonzero(alive)
    if idx.size == 0:
        return []
    breaks = np.flatnonzero(np.diff(idx) > 1)
    starts = np.concatenate([[0], breaks + 1])
    ends = np.concatenate([breaks, [idx.size - 1]])
    return [(base + int(idx[s]), int(idx[e] - idx[s] + 1))
            for s, e in zip(starts, ends)]


class _OutOfCoreBase(BackendBase):
    """Shared plumbing for backends that stream a :class:`SavedIndex`
    (``repro.storage.open_index``): memory-mapped LRD rows move host→device
    in blocks bounded by ``memory_budget_mb``; only small state (tree, leaf
    tables, permutation) is resident."""

    def __init__(self, saved, config: SearchConfig | None = None,
                 memory_budget_mb: float = 64.0):
        if memory_budget_mb <= 0:
            raise ValueError("memory_budget_mb must be positive")
        self.saved = saved
        self.memory_budget_mb = float(memory_budget_mb)
        self._config = config or saved.config.search
        self._perm = jnp.asarray(saved.small["perm"])
        self._t = {"calls": 0, "blocks": 0, "rows_streamed": 0,
                   "bytes_streamed": 0, "sax_rows_read": 0,
                   "read_seconds": 0.0, "read_wait_seconds": 0.0,
                   "overlap_blocks": 0,
                   # wave-fused serving: fetches shared across wave members
                   "wave_calls": 0, "wave_rows_shared": 0,
                   "runs_deduped": 0, "runs_skipped_bsf": 0,
                   # codec streaming (format v3): candidate rows re-checked
                   # against float32 truth, and whole-batch fallbacks when
                   # the bounds guard could not certify completeness
                   "codec_refine_rows": 0, "codec_fallbacks": 0}

    def _lrd(self) -> np.ndarray:
        """The LRD memmap, failing loudly if the SavedIndex was closed
        (e.g. the store compacted underneath a stale backend)."""
        return self.saved._mapped("lrd")

    def _lsd(self) -> np.ndarray:
        return self.saved._mapped("lsd")

    def _enc(self) -> np.ndarray:
        return self.saved._mapped("enc")

    def _active_codec(self, cfg: SearchConfig):
        """The codec instance this call streams under, or ``None`` for the
        raw float32 path. ``cfg.codec="auto"`` follows the opened index;
        ``"raw"`` forces the float32 stream (always available); any other
        name must match what the index was encoded with."""
        from repro.storage.codecs import get_codec

        name = getattr(cfg, "codec", "auto")
        saved_codec = getattr(self.saved, "codec", "raw")
        if name == "auto":
            name = saved_codec
        if name == "raw":
            return None
        if name != saved_codec:
            raise ValueError(
                f"codec={name!r} but the index at {self.saved.path!r} was "
                f"encoded with {saved_codec!r}; reopen after "
                f"compact(codec={name!r}) or use codec='auto'|'raw'")
        return get_codec(name)

    @property
    def series_len(self) -> int:
        return self.saved.series_len

    @property
    def base_config(self) -> SearchConfig:
        return self._config

    @classmethod
    def budget_stream_rows(cls, memory_budget_mb: float,
                           series_len: int) -> int:
        """Rows per streamed block/piece under ``memory_budget_mb``: half
        the budget's rows, because the stream keeps two blocks in flight
        (one being consumed, one being read/transferred) at peak. The one
        budget→rows code path — backends, the store, and the CLI all
        derive from here, so the arithmetic cannot drift."""
        budget_rows = int(memory_budget_mb * (1 << 20)) // (4 * series_len)
        return max(budget_rows // 2, 1)

    def stream_rows(self) -> int:
        """Cap on rows per streamed block (see :meth:`budget_stream_rows`)."""
        return self.budget_stream_rows(self.memory_budget_mb,
                                       self.saved.series_len)

    def _reap_reader(self, reader) -> None:
        """Close a chunk reader and fold its stats into the backend's."""
        from repro.data.pipeline import READ_STAT_KEYS

        reader.close()
        for key in READ_STAT_KEYS:
            self._t[key] += reader.stats[key]

    def _ids_of(self, p: jax.Array) -> jax.Array:
        safe = jnp.clip(p, 0, self._perm.shape[0] - 1)
        return jnp.where(p >= 0, self._perm[safe], -1)

    def _count(self, rows: int, row_bytes: int | None = None) -> None:
        """Account one streamed block: ``row_bytes`` defaults to the raw
        float32 width; codec streams pass their encoded width so
        ``bytes_streamed`` reflects the real disk traffic."""
        self._t["blocks"] += 1
        self._t["rows_streamed"] += rows
        self._t["bytes_streamed"] += rows * (
            4 * self.saved.series_len if row_bytes is None else row_bytes)

    def make_plan(self, cfg, q_struct):
        # Streaming plans are Python loops over jitted block kernels; the
        # jit cache (keyed on block shapes, which the budget fixes) plays
        # the role of the AOT executable here.
        return self._bind(cfg)

    def stats(self) -> dict:
        return {"num_series": self.saved.num_series,
                "series_len": self.saved.series_len,
                "memory_budget_mb": self.memory_budget_mb,
                "codec": getattr(self.saved, "codec", "raw"),
                **self._t}

    def _codec_finalize(self, q, cfg: SearchConfig, ub_d, ub_p, lb_d, lb_p,
                        valid_rows: int | None = None):
        """Certify + exact-re-check the codec carries (see the module-level
        codec notes). Returns ``(d, p, fallback_queries)``: exact top-k
        distances/positions, and how many queries the guard could NOT
        certify (0 = the returned answer is complete and exact).
        ``valid_rows`` limits the certification to the leading real queries
        of a padded batch — bucket-padding rows are sliced away by the
        caller, so their (often uncertifiable, e.g. all-zero) guard status
        must not force a fallback."""
        k = cfg.k
        theta = ub_d[:, k - 1]
        # every row not carried in the LB pool had LB >= the pool's largest
        # kept LB; if that is >= theta (>= the true kth distance), dropped
        # and pruned rows can at most tie the kth answer
        certified = np.asarray(lb_d[:, -1] >= theta)
        if valid_rows is not None:
            certified = certified[:valid_rows]
        bad = int(certified.size - int(certified.sum()))
        if bad:
            return None, None, bad
        cand_p = np.asarray(lb_p)
        safe = np.clip(cand_p, 0, max(self.saved.n_pad - 1, 0))
        # np.take = copy-guaranteed gather of the candidate rows (never a
        # view of the mapped file, so the device transfer cannot alias it)
        rows = jnp.asarray(np.take(self._lrd(), safe, axis=0))
        self._t["codec_refine_rows"] += int(cand_p.size)
        self._t["bytes_streamed"] += int(cand_p.size) * 4 * self.saved.series_len
        d, p = _codec_exact_topk(rows, jnp.asarray(cand_p), q, k=k)
        return d, p, 0

    def describe(self) -> dict:
        d = super().describe()
        d.update(self.stats(), path=self.saved.path)
        return d


class OutOfCoreScanBackend(_OutOfCoreBase):
    """Exact kNN over an on-disk collection via a streamed blocked scan.

    The memory-mapped LRD file is read in row blocks sized to half of
    ``memory_budget_mb`` — the stream keeps two blocks in flight (one
    computing, one being read/transferred), so the *budget* covers peak
    residency, not one block. ``cfg.prefetch`` picks the scheduler:
    ``"sync"`` double-buffers only the host→device copy (the memmap read
    blocks the consumer), ``"thread"`` adds the reader thread + two-slot
    host buffer so the disk read overlaps compute as well — answers are
    bit-identical either way, and ``stats()`` exposes
    ``read_wait_seconds``/``overlap_blocks`` to compare the two. A base
    ``scan_block`` too large for the budget's streamed blocks is
    auto-shrunk (logged) at construction, so small budgets behave the same
    from every entry point. Each block runs the *same* in-memory scan hot
    path (:func:`kernel_scan_knn` when the kernel mode resolves to Pallas,
    else the difference-form :func:`dense_scan_knn`) and running top-k
    merges through the shared :func:`_merge_topk` in file order. Distances
    are bit-identical to :class:`ScanBackend`; ``ids`` are exact original
    ids via the stored permutation and match the in-memory scan except when
    distinct rows *tie exactly* at the top-k boundary (the streamed scan
    visits rows in LRD order, the in-memory scan in original order, so ties
    break differently). ``positions`` are layout (LRD) positions.
    """

    name = "ooc-scan"

    def __init__(self, saved, config: SearchConfig | None = None,
                 memory_budget_mb: float = 64.0):
        super().__init__(saved, config, memory_budget_mb)
        self._config = dataclasses.replace(self._config, force_scan=True)
        # auto-fit: a base scan_block that cannot fit one streamed block is
        # shrunk to the budget's block size, so every entry point (store,
        # CLI, direct construction) behaves identically on small budgets.
        # Explicit per-call scan_block overrides still fail validation.
        rows = self.stream_rows()
        if rows < self._config.scan_block:
            logger.warning(
                "ooc-scan: scan_block=%d exceeds the %g MiB budget's "
                "%d-row streamed blocks; auto-shrinking scan_block to %d",
                self._config.scan_block, self.memory_budget_mb, rows, rows)
            self._config = dataclasses.replace(self._config, scan_block=rows)

    def _validate(self, cfg: SearchConfig) -> None:
        if cfg.scan_block <= 0:
            raise ValueError("scan_block must be positive")
        if self.stream_rows() < cfg.scan_block:
            raise ValueError(
                f"memory_budget_mb={self.memory_budget_mb} streams "
                f"{self.stream_rows()} rows per block (two blocks in "
                f"flight) — less than one scan_block={cfg.scan_block}; "
                f"lower scan_block or raise the budget")

    def _block_rows(self, cfg: SearchConfig) -> int:
        return (self.stream_rows() // cfg.scan_block) * cfg.scan_block

    def _bind(self, cfg):
        mode = resolve_kernel_mode(cfg.kernel_mode)
        codec = self._active_codec(cfg)
        if codec is not None:
            def run(q, valid_rows=None):
                return self._stream_codec_knn(jnp.asarray(q), cfg, mode,
                                              codec, valid_rows=valid_rows)
            run.valid_aware = True
            return run
        return lambda q: self._stream_knn(jnp.asarray(q), cfg, mode)

    def _stream_knn(self, q: jax.Array, cfg: SearchConfig,
                    mode: str) -> KnnResult:
        from repro.data.pipeline import ArrayChunkSource, iter_device_chunks

        num = self.saved.num_series
        R = self._block_rows(cfg)
        qn = q.shape[0]
        d = jnp.full((qn, cfg.k), INF)
        p = jnp.full((qn, cfg.k), -1, jnp.int32)
        blocks = ArrayChunkSource(self._lrd()[:num], R)
        for start, rows in iter_device_chunks(blocks, prefetch=cfg.prefetch,
                                              telemetry=self._t):
            d_b, p_b = _ooc_scan_block(rows, q, jnp.int32(start), k=cfg.k,
                                       block=cfg.scan_block, mode=mode)
            d, p = _ooc_merge(d, p, d_b, p_b, k=cfg.k)
            self._count(rows.shape[0])
        self._t["calls"] += 1
        return self._fill_result(d, p, self._ids_of(p), path=3, accessed=num)

    def _stream_codec_knn(self, q: jax.Array, cfg: SearchConfig, mode: str,
                          codec, valid_rows: int | None = None) -> KnnResult:
        """Streamed scan over the *encoded* sidecar: decoded distances feed
        the UB/LB carries, then candidates are re-checked against float32
        rows (see the module-level codec notes). Bit-identical distances to
        the raw stream; falls back to it when the guard cannot certify."""
        from repro.data.pipeline import ArrayChunkSource, iter_device_chunks

        num = self.saved.num_series
        n = self.saved.series_len
        W = codec.row_bytes(n)
        R = self.stream_rows()
        qn = q.shape[0]
        k = cfg.k
        cand = _codec_cand(k, num)
        ub_d = jnp.full((qn, k), INF)
        ub_p = jnp.full((qn, k), -1, jnp.int32)
        lb_d = jnp.full((qn, cand), INF)
        lb_p = jnp.full((qn, cand), -1, jnp.int32)
        blocks = ArrayChunkSource(self._enc()[:num], R, dtype=np.uint8)
        for start, enc in iter_device_chunks(blocks, prefetch=cfg.prefetch,
                                             telemetry=self._t):
            ub_d, ub_p, lb_d, lb_p = _codec_bounds_block(
                enc, q, jnp.int32(start), jnp.int32(enc.shape[0]),
                ub_d, ub_p, lb_d, lb_p,
                codec=codec, series_len=n, k=k, cand=cand, mode=mode)
            self._count(enc.shape[0], row_bytes=W)
        d, p, bad = self._codec_finalize(q, cfg, ub_d, ub_p, lb_d, lb_p,
                                         valid_rows=valid_rows)
        if bad:
            self._t["codec_fallbacks"] += bad
            return self._stream_knn(q, cfg, mode)
        self._t["calls"] += 1
        return self._fill_result(d, p, self._ids_of(p), path=3, accessed=num)

    def make_wave_plan(self, cfg, q_struct):
        """The streamed scan already reads each block exactly once for the
        whole batch, so the wave path is the batch path — plus telemetry
        attributing the sharing: every streamed row serves all wave
        members but is fetched once. Codec streams share identically (the
        encoded block feeds the whole wave's bound carries)."""
        mode = resolve_kernel_mode(cfg.kernel_mode)
        codec = self._active_codec(cfg)

        def run(q, valid_rows=None):
            q = jnp.asarray(q)
            before = self._t["rows_streamed"]
            if codec is not None:
                res = self._stream_codec_knn(q, cfg, mode, codec,
                                             valid_rows=valid_rows)
            else:
                res = self._stream_knn(q, cfg, mode)
            self._t["wave_calls"] += 1
            self._t["wave_rows_shared"] += ((self._t["rows_streamed"] - before)
                                            * max(int(q.shape[0]) - 1, 0))
            return res

        run.valid_aware = True
        return run


class OutOfCoreLocalBackend(_OutOfCoreBase):
    """Index-pruned out-of-core answering (the paper's reason to build the
    tree at all: touch only the leaves — and series — the bounds cannot
    exclude).

    Resident state is the tree plus the per-leaf pruning tables; raw series
    stay on disk. Per batch: (1) route every query to its home leaf and seed
    BSF_k from those leaf extents; (2) one vectorized LB_EAPCA pass over all
    leaf synopses; (3) for the leaves some query cannot prune, stream the
    **LSD sidecar** (m bytes/series — tiny next to the n-float rows) and
    apply the per-series LB_SAX filter, then fetch only the surviving rows
    as contiguous LRD runs (leaf in-order == file order) cut into
    budget-bounded pieces, refining with exact difference-form distances —
    the paper's phase-3 LSDFile stream, restored for the out-of-core path.
    ``use_sax=False`` falls back to leaf-granularity pruning. Exact by the
    paper's no-false-dismissal argument: a leaf (or series) is skipped only
    if ``lb * (1 - lb_slack)`` ≥ the running BSF_k, which upper-bounds the
    final kth distance.
    """

    name = "ooc-local"

    def __init__(self, saved, config: SearchConfig | None = None,
                 memory_budget_mb: float = 64.0):
        super().__init__(saved, config, memory_budget_mb)
        s = saved.small
        self._leaf_start = s["leaf_start"]
        self._leaf_count = s["leaf_count"]
        self._leaf_rank = jnp.asarray(s["leaf_rank"])
        self._leaf_endpoints = jnp.asarray(s["leaf_endpoints"])
        self._leaf_synopsis = jnp.asarray(s["leaf_synopsis"])
        self._leaf_seg_lens = jnp.asarray(s["leaf_seg_lens"])
        self._srank = np.asarray(s["series_leaf_rank"])

    def _validate(self, cfg: SearchConfig) -> None:
        if self.stream_rows() < self.saved.max_leaf:
            raise ValueError(
                f"memory_budget_mb={self.memory_budget_mb} streams "
                f"{self.stream_rows()} rows per block — less than one leaf "
                f"extent (max_leaf={self.saved.max_leaf}); raise the budget "
                f"or rebuild with a smaller leaf_capacity")

    def _bind(self, cfg):
        codec = self._active_codec(cfg)
        if codec is not None:
            def run(q, valid_rows=None):
                return self._stream_codec_knn(jnp.asarray(q), cfg, codec,
                                              valid_rows=valid_rows)
            run.valid_aware = True
            return run
        return lambda q: self._stream_knn(jnp.asarray(q), cfg)

    def _pad_bucket(self, count: int, cap: int) -> int:
        """Pad a piece to a small set of shapes (powers of two between
        max_leaf and the streaming cap) so refine kernels compile O(log)
        times while tiny pieces don't pay a full-budget zero-fill/copy."""
        b = max(self.saved.max_leaf, 1)
        while b < count:
            b <<= 1
        return min(max(b, 1), max(cap, count))

    def _leaf_lbs(self, q: jax.Array) -> jax.Array:
        """(Q, L) squared LB_EAPCA of every query to every leaf synopsis."""
        from repro.core.lower_bounds import lb_eapca_node
        from repro.core.search import _query_seg_stats

        qp, qp2 = S.prefix_sums(q)

        def one(args):
            p_row, p2_row = args
            qm, qs = _query_seg_stats(p_row, p2_row, self._leaf_endpoints)
            return lb_eapca_node(qm, qs, self._leaf_synopsis,
                                 self._leaf_seg_lens)

        lbs = jax.lax.map(one, (qp, qp2))
        dead = jnp.asarray(self._leaf_count) <= 0
        return jnp.where(dead[None, :], INF, lbs)

    def _stream_knn(self, q: jax.Array, cfg: SearchConfig) -> KnnResult:
        from repro.core.tree import route_to_leaf
        from repro.data.pipeline import make_chunk_reader

        k = cfg.k
        qn = q.shape[0]
        n = self.saved.series_len
        max_leaf = self.saved.max_leaf
        R = self.stream_rows()
        rows_before = self._t["rows_streamed"]
        d = jnp.full((qn, k), INF)
        p = jnp.full((qn, k), -1, jnp.int32)

        # every raw-row fetch of this call (seeded leaves, then alive runs)
        # flows through one reader: extents are submitted ahead of
        # consumption, so with prefetch="thread" the next extent's page
        # faults land in a slot buffer while the current one refines
        lrd_reader = make_chunk_reader(self._lrd(), R, n,
                                       prefetch=cfg.prefetch)
        lsd_reader = None

        def refine_all(d, p, extents):
            """Refine (start, cnt, pad_to) extents — all submitted before
            the first is consumed, the reader's lookahead window."""
            for start, cnt, pad_to in extents:
                lrd_reader.submit(start, cnt, pad_to)
            for start, cnt, _ in extents:
                rows = lrd_reader.stage(lrd_reader.get())
                d, p = _ooc_refine_block(rows, jnp.int32(start),
                                         jnp.int32(cnt), q, d, p, k=k)
                self._count(cnt)
            return d, p

        try:
            # -- phase 1 (Alg. 11): seed BSF from each query's home leaf plus
            # its l_max best leaves by LB_EAPCA — same visit set as the
            # in-memory pipeline, so the bound entering phase 2 is comparably
            # tight.
            lbs = self._leaf_lbs(q)                          # (Q, L)
            home_nodes = route_to_leaf(self.saved.tree, q,
                                       self.saved.max_depth)
            home_ranks = np.asarray(self._leaf_rank)[np.asarray(home_nodes)]
            l_max = min(cfg.l_max, self.saved.num_leaves)
            _, best = jax.lax.top_k(-lbs, l_max)             # (Q, l_max)
            seeded = sorted(set(int(r) for r in home_ranks if r >= 0)
                            | set(int(r) for r in np.asarray(best).ravel()))
            seeds = [(int(self._leaf_start[r]), int(self._leaf_count[r]),
                      max_leaf) for r in seeded
                     if int(self._leaf_count[r]) > 0]
            seed_rows = sum(cnt for _, cnt, _ in seeds)
            d, p = refine_all(d, p, seeds)

            # -- phase 2: leaf-level pruning over resident synopses ----------
            slack = jnp.float32(1.0 - cfg.lb_slack)
            bsf = d[:, k - 1]
            cand = lbs * slack < bsf[:, None]                # (Q, L)
            needed = np.array(jnp.any(cand, axis=0))
            needed[seeded] = False
            n_alive = max(int((np.asarray(self._leaf_count) > 0).sum()), 1)
            eapca_pr = 1.0 - np.asarray(
                jnp.sum(cand, axis=1), np.float32) / n_alive

            # -- phase 3: stream the LSD sidecar over non-prunable leaves,
            # keep only series the per-row LB_SAX filter cannot exclude, and
            # fetch those as contiguous LRD runs (the paper's LSDFile pass:
            # m bytes of codes buy skipping n floats of raw series) ---------
            pieces = self._runs(needed, R)
            use_sax = bool(cfg.use_sax)
            # seeded-leaf rows were read and refined for every query — they
            # count as alive, or sax_pr would overstate pruning (rows the
            # phase-3 filter never saw are not rows it pruned)
            alive_counts = jnp.full((qn,), seed_rows, jnp.int32)
            if not use_sax:
                d, p = refine_all(d, p, [(s, c, self._pad_bucket(c, R))
                                         for s, c in pieces])
            else:
                m_sax = int(self._lsd().shape[1])
                q_paa = S.paa(q, m_sax)
                kmode = resolve_kernel_mode(cfg.kernel_mode)
                lsd_reader = make_chunk_reader(self._lsd(), R, m_sax,
                                               np.uint8,
                                               prefetch=cfg.prefetch)
                # the sidecar stream is submitted up front: piece j+1's
                # codes (m bytes/series) read while piece j filters/refines
                for start, cnt in pieces:
                    lsd_reader.submit(start, cnt, self._pad_bucket(cnt, R))
                for start, cnt in pieces:
                    # codes padded to the same bucketed shapes as the row
                    # fetches, so the LB kernel compiles O(log) times, not
                    # once per piece length; pad columns are masked out of
                    # `live` below
                    pad_to = self._pad_bucket(cnt, R)
                    codes = lsd_reader.stage(lsd_reader.get())
                    ranks = np.zeros((pad_to,), np.int32)
                    ranks[:cnt] = self._srank[start:start + cnt]
                    self._t["sax_rows_read"] += cnt
                    lb_row = jnp.maximum(
                        kops.lb_sax(q_paa, codes, n, mode=kmode),
                        lbs[:, ranks])                        # (Q, pad_to)
                    bsf = d[:, k - 1]
                    live = ((lb_row * slack < bsf[:, None])
                            & (jnp.arange(pad_to) < cnt)[None, :])
                    alive_counts = alive_counts + jnp.sum(live, axis=1,
                                                          dtype=jnp.int32)
                    alive = np.asarray(jnp.any(live, axis=0))[:cnt]
                    d, p = refine_all(d, p,
                                      [(s0, c0, self._pad_bucket(c0, R))
                                       for s0, c0 in _alive_runs(alive,
                                                                 start)])
            self._t["calls"] += 1
        finally:
            self._reap_reader(lrd_reader)
            if lsd_reader is not None:
                self._reap_reader(lsd_reader)

        res = self._fill_result(
            d, p, self._ids_of(p), path=2,
            accessed=self._t["rows_streamed"] - rows_before)
        sax_pr = (1.0 - alive_counts.astype(jnp.float32)
                  / max(self.saved.num_series, 1)
                  if use_sax else jnp.zeros((qn,), jnp.float32))
        return res._replace(
            eapca_pr=jnp.asarray(eapca_pr, jnp.float32),
            sax_pr=sax_pr,
            visited_leaves=jnp.full((qn,), len(seeded) + int(needed.sum()),
                                    jnp.int32))

    def _stream_codec_knn(self, q: jax.Array, cfg: SearchConfig,
                          codec, valid_rows: int | None = None) -> KnnResult:
        """Index-pruned streaming over the *encoded* sidecar (format v3):
        the `_stream_knn` phase structure with the exact running top-k
        replaced by the sound UB/LB carries over decoded distances (see the
        module-level codec notes). The kth *upper* bound plays the BSF role
        in the leaf-level and per-series filters — it provably upper-bounds
        the true kth distance, so pruning stays no-false-dismissal — and the
        candidate pool is re-checked against full-precision float32 rows at
        the end: distances bit-identical to the raw stream, with a
        whole-batch fallback to it when the guard cannot certify."""
        from repro.core.tree import route_to_leaf
        from repro.data.pipeline import make_chunk_reader

        k = cfg.k
        qn = q.shape[0]
        n = self.saved.series_len
        num = self.saved.num_series
        max_leaf = self.saved.max_leaf
        W = codec.row_bytes(n)
        R = self.stream_rows()
        kmode = resolve_kernel_mode(cfg.kernel_mode)
        rows_before = self._t["rows_streamed"]
        cand = _codec_cand(k, num)
        ub_d = jnp.full((qn, k), INF)
        ub_p = jnp.full((qn, k), -1, jnp.int32)
        lb_d = jnp.full((qn, cand), INF)
        lb_p = jnp.full((qn, cand), -1, jnp.int32)

        # every encoded fetch flows through one reader, same submit-ahead
        # lookahead discipline as the raw path's lrd_reader
        enc_reader = make_chunk_reader(self._enc(), R, W, np.uint8,
                                       prefetch=cfg.prefetch)
        lsd_reader = None

        def bounds_all(ub_d, ub_p, lb_d, lb_p, extents):
            """Fold (start, cnt, pad_to) encoded extents into the carries —
            all submitted before the first is consumed."""
            for start, cnt, pad_to in extents:
                enc_reader.submit(start, cnt, pad_to)
            for start, cnt, _ in extents:
                enc = enc_reader.stage(enc_reader.get())
                ub_d, ub_p, lb_d, lb_p = _codec_bounds_block(
                    enc, q, jnp.int32(start), jnp.int32(cnt),
                    ub_d, ub_p, lb_d, lb_p, codec=codec, series_len=n,
                    k=k, cand=cand, mode=kmode)
                self._count(cnt, row_bytes=W)
            return ub_d, ub_p, lb_d, lb_p

        try:
            # -- phase 1: seed the conservative BSF (kth upper bound) from
            # each query's home leaf plus its l_max best leaves ------------
            lbs = self._leaf_lbs(q)                          # (Q, L)
            home_nodes = route_to_leaf(self.saved.tree, q,
                                       self.saved.max_depth)
            home_ranks = np.asarray(self._leaf_rank)[np.asarray(home_nodes)]
            l_max = min(cfg.l_max, self.saved.num_leaves)
            _, best = jax.lax.top_k(-lbs, l_max)             # (Q, l_max)
            seeded = sorted(set(int(r) for r in home_ranks if r >= 0)
                            | set(int(r) for r in np.asarray(best).ravel()))
            seeds = [(int(self._leaf_start[r]), int(self._leaf_count[r]),
                      max_leaf) for r in seeded
                     if int(self._leaf_count[r]) > 0]
            seed_rows = sum(cnt for _, cnt, _ in seeds)
            ub_d, ub_p, lb_d, lb_p = bounds_all(ub_d, ub_p, lb_d, lb_p,
                                                seeds)

            # -- phase 2: leaf-level pruning against the kth upper bound ---
            slack = jnp.float32(1.0 - cfg.lb_slack)
            bsf = ub_d[:, k - 1]
            cand_l = lbs * slack < bsf[:, None]              # (Q, L)
            needed = np.array(jnp.any(cand_l, axis=0))
            needed[seeded] = False
            n_alive = max(int((np.asarray(self._leaf_count) > 0).sum()), 1)
            eapca_pr = 1.0 - np.asarray(
                jnp.sum(cand_l, axis=1), np.float32) / n_alive

            # -- phase 3: LSD sidecar filter, then encoded alive runs ------
            pieces = self._runs(needed, R)
            use_sax = bool(cfg.use_sax)
            alive_counts = jnp.full((qn,), seed_rows, jnp.int32)
            if not use_sax:
                ub_d, ub_p, lb_d, lb_p = bounds_all(
                    ub_d, ub_p, lb_d, lb_p,
                    [(s, c, self._pad_bucket(c, R)) for s, c in pieces])
            else:
                m_sax = int(self._lsd().shape[1])
                q_paa = S.paa(q, m_sax)
                lsd_reader = make_chunk_reader(self._lsd(), R, m_sax,
                                               np.uint8,
                                               prefetch=cfg.prefetch)
                for start, cnt in pieces:
                    lsd_reader.submit(start, cnt, self._pad_bucket(cnt, R))
                for start, cnt in pieces:
                    pad_to = self._pad_bucket(cnt, R)
                    codes = lsd_reader.stage(lsd_reader.get())
                    ranks = np.zeros((pad_to,), np.int32)
                    ranks[:cnt] = self._srank[start:start + cnt]
                    self._t["sax_rows_read"] += cnt
                    lb_row = jnp.maximum(
                        kops.lb_sax(q_paa, codes, n, mode=kmode),
                        lbs[:, ranks])                        # (Q, pad_to)
                    bsf = ub_d[:, k - 1]
                    live = ((lb_row * slack < bsf[:, None])
                            & (jnp.arange(pad_to) < cnt)[None, :])
                    alive_counts = alive_counts + jnp.sum(live, axis=1,
                                                          dtype=jnp.int32)
                    alive = np.asarray(jnp.any(live, axis=0))[:cnt]
                    ub_d, ub_p, lb_d, lb_p = bounds_all(
                        ub_d, ub_p, lb_d, lb_p,
                        [(s0, c0, self._pad_bucket(c0, R))
                         for s0, c0 in _alive_runs(alive, start)])
        finally:
            self._reap_reader(enc_reader)
            if lsd_reader is not None:
                self._reap_reader(lsd_reader)

        d, p, bad = self._codec_finalize(q, cfg, ub_d, ub_p, lb_d, lb_p,
                                         valid_rows=valid_rows)
        if bad:
            self._t["codec_fallbacks"] += bad
            return self._stream_knn(q, cfg)
        self._t["calls"] += 1
        res = self._fill_result(
            d, p, self._ids_of(p), path=2,
            accessed=self._t["rows_streamed"] - rows_before)
        sax_pr = (1.0 - alive_counts.astype(jnp.float32)
                  / max(self.saved.num_series, 1)
                  if use_sax else jnp.zeros((qn,), jnp.float32))
        return res._replace(
            eapca_pr=jnp.asarray(eapca_pr, jnp.float32),
            sax_pr=sax_pr,
            visited_leaves=jnp.full((qn,), len(seeded) + int(needed.sum()),
                                    jnp.int32))

    def make_wave_plan(self, cfg, q_struct):
        codec = self._active_codec(cfg)
        if codec is not None:
            # Codec streams fold whole blocks into batched bound carries, so
            # the wave already shares every encoded fetch across members;
            # the raw path's per-run demand scheduling (and its BSF-based
            # run skipping) doesn't apply to the carry formulation.
            def run(q, valid_rows=None):
                res = self._stream_codec_knn(jnp.asarray(q), cfg, codec,
                                             valid_rows=valid_rows)
                self._t["wave_calls"] += 1
                return res

            run.valid_aware = True
            return run
        return lambda q: self._stream_wave_knn(jnp.asarray(q), cfg)

    def estimate_difficulty(self, queries: jax.Array) -> np.ndarray:
        return _difficulty_from_leaf_lbs(
            self._leaf_lbs(jnp.asarray(queries)))

    def _stream_wave_knn(self, q: jax.Array, cfg: SearchConfig) -> KnnResult:
        """Wave-fused out-of-core answering: the `_stream_knn` pipeline with
        the wave's disk schedule made explicit (the ROADMAP's "carefully
        schedule costly operations" applied *across* queries).

        Where `_stream_knn` walks leaf runs in file order, this merges every
        member's alive-run list, counts each run's **demand** (how many
        members still need it), fetches each run exactly once in descending
        demand order, and refines all members per fetched block through the
        shared BSF matrix — so a popular leaf is read once for the whole
        wave and its rows tighten every member's bound before the less
        popular runs are even submitted. Submissions flow through
        :func:`repro.data.pipeline.iter_scheduled_chunks`, whose
        ``still_needed`` re-check runs against the *current* BSF matrix
        right before each submit: a run whose last interested member was
        satisfied by an earlier block is dropped without touching the disk
        (``runs_skipped_bsf``). Exactness: a member is counted out of a
        run's demand only when the run's per-member lower bound (min over
        its rows) cannot beat that member's BSF_k — the same
        no-false-dismissal test as the per-query path — so answers stay
        bit-identical to per-query serving. Telemetry: ``runs_deduped``
        (fetches avoided vs independent queries) and ``wave_rows_shared``
        (rows that served >1 member per single fetch).
        """
        from repro.core.tree import route_to_leaf
        from repro.data.pipeline import (iter_scheduled_chunks,
                                         make_chunk_reader)

        k = cfg.k
        qn = q.shape[0]
        n = self.saved.series_len
        max_leaf = self.saved.max_leaf
        R = self.stream_rows()
        rows_before = self._t["rows_streamed"]
        slack_f = 1.0 - cfg.lb_slack
        d = jnp.full((qn, k), INF)
        p = jnp.full((qn, k), -1, jnp.int32)

        lrd_reader = make_chunk_reader(self._lrd(), R, n,
                                       prefetch=cfg.prefetch)
        lsd_reader = None
        counts = np.asarray(self._leaf_count)
        starts_np = np.asarray(self._leaf_start)
        try:
            # -- phase 1: per-member seed sets, fetched once for the union.
            # Demand = how many members asked for the leaf; popular leaves
            # go first so the shared BSF matrix tightens fastest.
            lbs = self._leaf_lbs(q)                          # (W, L)
            home_nodes = route_to_leaf(self.saved.tree, q,
                                       self.saved.max_depth)
            home_ranks = np.asarray(self._leaf_rank)[np.asarray(home_nodes)]
            l_max = min(cfg.l_max, self.saved.num_leaves)
            _, best = jax.lax.top_k(-lbs, l_max)             # (W, l_max)
            best_np = np.asarray(best)
            demand: collections.Counter = collections.Counter()
            for w in range(qn):
                member = {int(home_ranks[w])} | {int(r) for r in best_np[w]}
                for r in member:
                    if r >= 0 and counts[r] > 0:
                        demand[r] += 1
            seeded = sorted(demand)
            self._t["runs_deduped"] += sum(demand[r] - 1 for r in seeded)
            self._t["wave_rows_shared"] += sum(
                int(counts[r]) * (demand[r] - 1) for r in seeded)
            seed_rows = sum(int(counts[r]) for r in seeded)
            order = sorted(seeded, key=lambda r: (-demand[r], r))
            extents = [(int(starts_np[r]), int(counts[r]), max_leaf)
                       for r in order]
            for start, cnt, pad_to in extents:
                lrd_reader.submit(start, cnt, pad_to)
            for start, cnt, _ in extents:
                rows = lrd_reader.stage(lrd_reader.get())
                d, p = _ooc_refine_block(rows, jnp.int32(start),
                                         jnp.int32(cnt), q, d, p, k=k)
                self._count(cnt)

            # -- phase 2: leaf-level pruning, per member -----------------
            slack = jnp.float32(slack_f)
            bsf = d[:, k - 1]
            cand = lbs * slack < bsf[:, None]                # (W, L)
            needed = np.array(jnp.any(cand, axis=0))
            needed[seeded] = False
            n_alive = max(int((counts > 0).sum()), 1)
            eapca_pr = 1.0 - np.asarray(
                jnp.sum(cand, axis=1), np.float32) / n_alive

            # -- phase 3: build the merged alive-run list with a per-member
            # lower bound per run (min over the run's rows/leaves), instead
            # of refining file-order as the per-query path does -----------
            pieces = self._runs(needed, R)
            use_sax = bool(cfg.use_sax)
            alive_counts = jnp.full((qn,), seed_rows, jnp.int32)
            runs: list[tuple[int, int, np.ndarray]] = []
            if not use_sax:
                lbs_np = np.asarray(lbs)
                for start, cnt in pieces:
                    ranks = np.unique(self._srank[start:start + cnt])
                    runs.append((start, cnt, lbs_np[:, ranks].min(axis=1)))
            elif pieces:
                m_sax = int(self._lsd().shape[1])
                q_paa = S.paa(q, m_sax)
                kmode = resolve_kernel_mode(cfg.kernel_mode)
                lsd_reader = make_chunk_reader(self._lsd(), R, m_sax,
                                               np.uint8,
                                               prefetch=cfg.prefetch)
                for start, cnt in pieces:
                    lsd_reader.submit(start, cnt, self._pad_bucket(cnt, R))
                for start, cnt in pieces:
                    pad_to = self._pad_bucket(cnt, R)
                    codes = lsd_reader.stage(lsd_reader.get())
                    ranks = np.zeros((pad_to,), np.int32)
                    ranks[:cnt] = self._srank[start:start + cnt]
                    self._t["sax_rows_read"] += cnt
                    lb_row = jnp.maximum(
                        kops.lb_sax(q_paa, codes, n, mode=kmode),
                        lbs[:, ranks])                       # (W, pad_to)
                    live = ((lb_row * slack < bsf[:, None])
                            & (jnp.arange(pad_to) < cnt)[None, :])
                    alive_counts = alive_counts + jnp.sum(live, axis=1,
                                                          dtype=jnp.int32)
                    alive = np.asarray(jnp.any(live, axis=0))[:cnt]
                    lb_np = np.asarray(lb_row)
                    for s0, c0 in _alive_runs(alive, start):
                        lo = s0 - start
                        runs.append((s0, c0,
                                     lb_np[:, lo:lo + c0].min(axis=1)))

            # -- phase 4: fetch each run once, most-demanded first, with a
            # late BSF re-check per submit ---------------------------------
            bsf_host = {"kth": np.asarray(d[:, k - 1])}

            def run_demand(run_lb: np.ndarray) -> int:
                return int((run_lb * slack_f < bsf_host["kth"]).sum())

            runs.sort(key=lambda r: (-run_demand(r[2]), r[0]))

            def still_needed(tag) -> bool:
                _, c0, run_lb = tag
                dm = run_demand(run_lb)
                if dm == 0:
                    self._t["runs_skipped_bsf"] += 1
                    return False
                self._t["runs_deduped"] += dm - 1
                self._t["wave_rows_shared"] += c0 * (dm - 1)
                return True

            reqs = [((s0, c0, run_lb), s0, c0, self._pad_bucket(c0, R))
                    for s0, c0, run_lb in runs]
            for (s0, c0, _), rows in iter_scheduled_chunks(
                    lrd_reader, reqs, still_needed=still_needed):
                d, p = _ooc_refine_block(rows, jnp.int32(s0), jnp.int32(c0),
                                         q, d, p, k=k)
                self._count(c0)
                bsf_host["kth"] = np.asarray(d[:, k - 1])
            self._t["calls"] += 1
            self._t["wave_calls"] += 1
        finally:
            self._reap_reader(lrd_reader)
            if lsd_reader is not None:
                self._reap_reader(lsd_reader)

        res = self._fill_result(
            d, p, self._ids_of(p), path=2,
            accessed=self._t["rows_streamed"] - rows_before)
        sax_pr = (1.0 - alive_counts.astype(jnp.float32)
                  / max(self.saved.num_series, 1)
                  if use_sax else jnp.zeros((qn,), jnp.float32))
        return res._replace(
            eapca_pr=jnp.asarray(eapca_pr, jnp.float32),
            sax_pr=sax_pr,
            visited_leaves=jnp.full((qn,), len(seeded) + int(needed.sum()),
                                    jnp.int32))

    def _runs(self, needed: np.ndarray, max_rows: int):
        """Merge needed leaves' extents into contiguous row intervals (leaf
        in-order == file order), then cut into ≤ max_rows pieces."""
        starts = np.asarray(self._leaf_start)
        counts = np.asarray(self._leaf_count)
        intervals: list[list[int]] = []
        for r in np.flatnonzero(needed):
            lo, hi = int(starts[r]), int(starts[r] + counts[r])
            if hi <= lo:
                continue
            if intervals and intervals[-1][1] == lo:
                intervals[-1][1] = hi
            else:
                intervals.append([lo, hi])
        pieces = []
        for lo, hi in intervals:
            for s in range(lo, hi, max_rows):
                pieces.append((s, min(max_rows, hi - s)))
        return pieces


# ---------------------------------------------------------------------------
# Sharded backend — the distributed StackedIndex under a mesh
# ---------------------------------------------------------------------------

class ShardedBackend(BackendBase):
    """Series-sharded Hercules (``StackedIndex``): per-shard exact top-k,
    all-gather, global merge. With one shard on one device this degenerates
    to the local pipeline (same arithmetic, same answers).

    ``positions`` in results are -1 (layout positions are per-shard; global
    ``ids`` are exact) and the per-query pruning telemetry is zeroed —
    cross-shard aggregation of those counters is future work.
    """

    name = "sharded"

    def __init__(self, stacked, mesh=None):
        from jax.sharding import Mesh  # noqa: F401  (type only)

        self.stacked = stacked
        if mesh is None:
            from repro.distributed.compat import make_mesh
            mesh = make_mesh((len(jax.devices()),), ("data",))
        ndev = int(np.prod(list(mesh.shape.values())))
        if stacked.num_shards != ndev:
            raise ValueError(f"index has {stacked.num_shards} shards but the "
                             f"mesh has {ndev} devices")
        self.mesh = mesh
        self._programs: dict[tuple, Callable] = {}

    @property
    def plan_signature(self) -> tuple:
        """Identity of everything the compiled program bakes in besides
        ``cfg``: the mesh topology and the sharded index's shape. Part of
        every plan-cache key (here and in ``QueryEngine``) so plans can
        never be reused across a different mesh or a reopened index —
        the PR 9 dist-ooc convention, now enforced by the
        plan-key-completeness lint."""
        st = self.stacked
        return (self.name, st.num_shards,
                tuple((a, int(s)) for a, s in self.mesh.shape.items()),
                st.max_depth, st.layout.num_series, st.layout.series_len)

    @property
    def series_len(self) -> int:
        return self.stacked.layout.series_len

    @property
    def base_config(self) -> SearchConfig:
        return self.stacked.config.search

    def _validate(self, cfg: SearchConfig) -> None:
        validate_runtime_config(cfg, self.stacked.layout.lrd.shape[-2])

    def _run_for(self, cfg: SearchConfig):
        key = (cfg, self.plan_signature)
        if key not in self._programs:
            from repro.distributed.search import make_distributed_search
            self._programs[key] = make_distributed_search(
                self.mesh, cfg, self.stacked.max_depth,
                self.stacked.tree, self.stacked.layout)
        return self._programs[key]

    def _offsets(self):
        return self.stacked.shard_offsets.reshape(self.stacked.num_shards, 1)

    def _result(self, d, gid) -> KnnResult:
        return self._fill_result(d, jnp.full_like(gid, -1), gid)

    def _bind(self, cfg):
        run = self._run_for(cfg)
        st = self.stacked
        return lambda q: self._result(
            *run(st.tree, st.layout, self._offsets(), q))

    def make_plan(self, cfg, q_struct):
        run = self._run_for(cfg)
        st = self.stacked
        offsets = self._offsets()
        compiled = run.lower(st.tree, st.layout, offsets, q_struct).compile()
        return lambda q: self._result(
            *compiled(st.tree, st.layout, offsets, q))

    def stats(self) -> dict:
        st = self.stacked
        return {"num_shards": st.num_shards,
                "num_series": st.num_shards * st.layout.num_series,
                "series_len": st.layout.series_len}

    def describe(self) -> dict:
        d = super().describe()
        d.update(self.stats(), mesh={a: int(s) for a, s in self.mesh.shape.items()})
        return d


# ---------------------------------------------------------------------------
# The engine: bucketed batching + compiled-plan LRU + telemetry
# ---------------------------------------------------------------------------

class _TelemetrySection:
    """Dict-compatibility shim for the telemetry dataclasses: the historical
    ``telemetry()["plan_cache"]["hits"]`` access style keeps working (keys
    are deprecated aliases of the fields), while attribute access —
    ``telemetry().plan_cache.hits`` — is the API. ``None``-valued optional
    sections behave like absent dict keys (``"ooc" not in telemetry()``)."""

    _ALIASES: dict = {}

    def keys(self):
        return tuple(f.name for f in dataclasses.fields(self)
                     if getattr(self, f.name) is not None)

    def values(self):
        return tuple(getattr(self, k) for k in self.keys())

    def items(self):
        return tuple((k, getattr(self, k)) for k in self.keys())

    def _resolve(self, key):
        key = self._ALIASES.get(key, key)
        if key not in (f.name for f in dataclasses.fields(self)):
            raise KeyError(key)
        return key

    def __getitem__(self, key):
        key = self._resolve(key)
        value = getattr(self, key)
        if value is None:
            raise KeyError(key)
        return value

    def __setitem__(self, key, value):
        object.__setattr__(self, self._resolve(key), value)

    def get(self, key, default=None):
        try:
            return self[key]
        except KeyError:
            return default

    def __contains__(self, key):
        return self.get(key) is not None

    def __iter__(self):
        return iter(self.keys())


@dataclasses.dataclass
class PlanCacheTelemetry(_TelemetrySection):
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    size: int = 0
    capacity: int = 0
    compiles: int = 0
    compile_s: float = 0.0
    invalidations: int = 0


@dataclasses.dataclass
class LatencyTelemetry(_TelemetrySection):
    total: float = 0.0
    last: float = 0.0
    mean_per_call: float = 0.0
    mean_per_query: float = 0.0


@dataclasses.dataclass
class PathsTelemetry(_TelemetrySection):
    scan_eapca: int = 0
    scan_sax: int = 0
    pruned: int = 0
    forced_scan: int = 0
    unknown: int = 0


@dataclasses.dataclass
class PruningTelemetry(_TelemetrySection):
    eapca_mean: float = 0.0
    sax_mean: float = 0.0


@dataclasses.dataclass
class OocTelemetry(_TelemetrySection):
    """Streaming counters of the out-of-core backends (absent — ``None``
    section — for fully-resident backends). ``bytes_streamed`` counts the
    bytes actually fetched (encoded width under a codec, plus the float32
    re-check rows), the honest bandwidth number the codec benchmarks key
    on; ``codec_refine_rows``/``codec_fallbacks`` account the exactness
    machinery of format-v3 encoded streams."""
    calls: int = 0
    blocks: int = 0
    rows_streamed: int = 0
    bytes_streamed: int = 0
    sax_rows_read: int = 0
    read_seconds: float = 0.0
    read_wait_seconds: float = 0.0
    overlap_blocks: int = 0
    wave_calls: int = 0
    wave_rows_shared: int = 0
    runs_deduped: int = 0
    runs_skipped_bsf: int = 0
    codec_refine_rows: int = 0
    codec_fallbacks: int = 0


@dataclasses.dataclass
class DistTelemetry(_TelemetrySection):
    """Per-shard accounting of the distributed out-of-core backend
    (``dist-ooc``; absent for single-host backends). List fields are
    indexed by shard. ``imbalance`` is the max/min per-shard
    ``rows_streamed`` ratio of the traffic actually served;
    ``plan_imbalance`` is the same ratio over the shard *plan*'s row
    counts, and ``balance_warning`` mirrors the
    ``repro.storage.partition`` guardrail (plan ratio above
    ``BALANCE_WARN_RATIO``). ``row_range`` is each shard's assigned
    ``[lo, hi)`` file-row range and ``rows_touched`` the absolute extremes
    its readers actually touched (``None`` until the first read) — the
    residency-confinement proof: touched ⊆ assigned, always."""
    shards: int = 0
    rows_streamed: list = dataclasses.field(default_factory=list)
    read_wait_seconds: list = dataclasses.field(default_factory=list)
    bytes_streamed: list = dataclasses.field(default_factory=list)
    imbalance: float = 1.0
    plan_rows: list = dataclasses.field(default_factory=list)
    plan_imbalance: float = 1.0
    balance_warning: bool = False
    row_range: list = dataclasses.field(default_factory=list)
    rows_touched: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class Telemetry(_TelemetrySection):
    """The one serving-telemetry shape (see ``repro.api`` for the key →
    field mapping table). Sections are dataclasses; ``ooc`` is ``None``
    unless the backend streams from disk, ``serving`` is filled by
    :class:`repro.serve.engine.KnnServeEngine`."""
    backend: str = ""
    calls: int = 0
    queries: int = 0
    wave_calls: int = 0
    plan_cache: PlanCacheTelemetry = dataclasses.field(
        default_factory=PlanCacheTelemetry)
    latency: LatencyTelemetry = dataclasses.field(
        default_factory=LatencyTelemetry)
    paths: PathsTelemetry = dataclasses.field(default_factory=PathsTelemetry)
    pruning: PruningTelemetry = dataclasses.field(
        default_factory=PruningTelemetry)
    ooc: OocTelemetry | None = None
    dist: DistTelemetry | None = None
    serving: dict | None = None

    _ALIASES = {"latency_s": "latency"}


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    plan_cache_size: int = 32
    # explicit batch buckets (ascending); empty -> next power of two
    bucket_sizes: tuple[int, ...] = ()
    # pull per-query path/pruning stats to host after each call
    collect_result_stats: bool = True


class QueryEngine:
    """A serving session over one :class:`SearchBackend`.

    Every call pads the query batch up to a bucket size and dispatches a
    cached AOT-compiled plan for (SearchConfig, bucket). Repeated serving
    calls with the same statics therefore never retrace or recompile —
    ``telemetry()["plan_cache"]`` proves it.
    """

    def __init__(self, backend: SearchBackend,
                 config: EngineConfig | None = None):
        self.backend = backend
        self.config = config or EngineConfig()
        self._plans: collections.OrderedDict = collections.OrderedDict()
        self._t = {
            "calls": 0, "queries": 0, "wave_calls": 0,
            "hits": 0, "misses": 0, "evictions": 0,
            "invalidations": 0,
            "compile_s": 0.0, "exec_s": 0.0, "last_exec_s": 0.0,
            "paths": np.zeros(4, np.int64), "path_unknown": 0,
            "eapca_pr_sum": 0.0, "sax_pr_sum": 0.0, "stat_queries": 0,
        }

    def invalidate(self) -> None:
        """Drop every cached compiled plan. Called when the data a plan was
        compiled against changes underneath the backend — e.g. the store
        handle (``repro.storage.store.Hercules``) appended or compacted —
        so a stale executable can never serve the mutated collection."""
        self._plans.clear()
        self._t["invalidations"] += 1

    # -- batching -----------------------------------------------------------

    def _bucket(self, qn: int) -> int:
        for b in sorted(self.config.bucket_sizes):
            if qn <= b:
                return b
        # larger than every configured bucket (or none configured):
        # next power of two keeps the distinct-shape count logarithmic
        return max(1, 1 << (qn - 1).bit_length())

    # -- the one call that matters ------------------------------------------

    def knn(self, queries: jax.Array, k: int | None = None,
            valid_rows: int | None = None, wave: bool = False,
            **overrides: Any) -> KnnResult:
        """``valid_rows``: when the caller already padded the batch (e.g. a
        slot-based server filling its wave), the number of leading real
        queries — results are sliced and telemetry counted on those only.

        ``wave=True`` answers the batch through the backend's wave-fused
        plan (shared descent / BSF matrix / once-per-wave disk fetches);
        answers are bit-identical to ``wave=False``, which maps the
        per-query pipeline over the batch. Backends without per-query work
        to share (dense scans, sharded) fall back to the regular plan."""
        q = jnp.asarray(queries)
        if q.ndim == 1:
            q = q[None, :]
        n = getattr(self.backend, "series_len", None)
        if n and q.shape[1] != n:
            raise ValueError(f"query length {q.shape[1]} != collection "
                             f"series length {n}")
        cfg = self.backend.resolve(k, overrides)
        qn = q.shape[0] if valid_rows is None else valid_rows
        if not 0 < qn <= q.shape[0]:
            raise ValueError(f"valid_rows={valid_rows} out of range for "
                             f"batch of {q.shape[0]}")
        bucket = self._bucket(q.shape[0])
        if bucket != q.shape[0]:
            q = jnp.concatenate(
                [q, jnp.zeros((bucket - q.shape[0], q.shape[1]), q.dtype)],
                axis=0)

        # plan_signature folds backend identity the SearchConfig cannot see
        # into the key — e.g. dist-ooc's mesh shape: a plan compiled for one
        # mesh must never serve another
        key = (cfg, bucket, q.shape[1], q.dtype.name, wave,
               getattr(self.backend, "plan_signature", None))
        plan = self._plans.get(key)
        if plan is None:
            t0 = time.perf_counter()
            maker = (self.backend.make_wave_plan if wave
                     else self.backend.make_plan)
            plan = maker(cfg, jax.ShapeDtypeStruct(q.shape, q.dtype))
            self._t["compile_s"] += time.perf_counter() - t0
            self._t["misses"] += 1
            self._plans[key] = plan
            while len(self._plans) > self.config.plan_cache_size:
                self._plans.popitem(last=False)
                self._t["evictions"] += 1
        else:
            self._t["hits"] += 1
            self._plans.move_to_end(key)

        t0 = time.perf_counter()
        if getattr(plan, "valid_aware", False):
            # codec plans certify per-query completeness; bucket-padding
            # rows (sliced away below) must not trip the certify guard
            res = plan(q, valid_rows=qn)
        else:
            res = plan(q)
        jax.block_until_ready(res.dists)
        dt = time.perf_counter() - t0
        self._t["exec_s"] += dt
        self._t["last_exec_s"] = dt
        self._t["calls"] += 1
        self._t["queries"] += qn
        if wave:
            self._t["wave_calls"] += 1

        if bucket != qn:
            res = KnnResult(*[a[:qn] for a in res])
        if self.config.collect_result_stats:
            self._record(res)
        return res

    def estimate_difficulty(self, queries) -> np.ndarray | None:
        """Cheap per-query cost scores in [0, 1] (higher = likely slower),
        from the backend's resident pruning tables — the signal behind
        difficulty-aware wave packing. ``None`` when the backend has no
        leaf-bound landscape to score against (dense scans cost the same
        for every query)."""
        fn = getattr(self.backend, "estimate_difficulty", None)
        if fn is None:
            return None
        return fn(jnp.asarray(queries))

    def _record(self, res: KnnResult) -> None:
        path = np.asarray(res.path)
        known = path >= 0
        self._t["paths"] += np.bincount(path[known], minlength=4)[:4]
        self._t["path_unknown"] += int((~known).sum())
        if known.any():
            self._t["eapca_pr_sum"] += float(np.asarray(res.eapca_pr)[known].sum())
            self._t["sax_pr_sum"] += float(np.asarray(res.sax_pr)[known].sum())
            self._t["stat_queries"] += int(known.sum())

    # -- introspection ------------------------------------------------------

    def telemetry(self) -> Telemetry:
        t = self._t
        n_stat = max(t["stat_queries"], 1)
        bstats = self.backend.stats()
        ooc = None
        if "rows_streamed" in bstats:
            ooc = OocTelemetry(**{f.name: bstats[f.name]
                                  for f in dataclasses.fields(OocTelemetry)
                                  if f.name in bstats})
        dist = None
        if "dist" in bstats:
            dsec = bstats["dist"]
            dist = DistTelemetry(**{f.name: dsec[f.name]
                                    for f in dataclasses.fields(DistTelemetry)
                                    if f.name in dsec})
        return Telemetry(
            backend=self.backend.name,
            calls=t["calls"],
            queries=t["queries"],
            wave_calls=t["wave_calls"],
            plan_cache=PlanCacheTelemetry(
                hits=t["hits"], misses=t["misses"],
                evictions=t["evictions"], size=len(self._plans),
                capacity=self.config.plan_cache_size,
                compiles=t["misses"], compile_s=t["compile_s"],
                invalidations=t["invalidations"]),
            latency=LatencyTelemetry(
                total=t["exec_s"], last=t["last_exec_s"],
                mean_per_call=t["exec_s"] / max(t["calls"], 1),
                mean_per_query=t["exec_s"] / max(t["queries"], 1)),
            paths=PathsTelemetry(
                scan_eapca=int(t["paths"][0]),
                scan_sax=int(t["paths"][1]),
                pruned=int(t["paths"][2]),
                forced_scan=int(t["paths"][3]),
                unknown=t["path_unknown"]),
            pruning=PruningTelemetry(
                eapca_mean=t["eapca_pr_sum"] / n_stat,
                sax_mean=t["sax_pr_sum"] / n_stat),
            ooc=ooc, dist=dist)

    def stats(self) -> dict:
        return self.backend.stats()

    def describe(self) -> dict:
        return {
            "engine": {
                "plan_cache_size": self.config.plan_cache_size,
                "bucket_sizes": list(self.config.bucket_sizes) or "pow2",
                "cached_plans": [
                    {"k": key[0].k, "bucket": key[1], "series_len": key[2]}
                    for key in self._plans],
            },
            "backend": self.backend.describe(),
        }


# ---------------------------------------------------------------------------
# Name-based construction (benchmarks/run.py --backend, serve_knn CLI)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """One registered backend name: which construction paths serve it
    (``"memory"`` = :func:`make_backend` over an in-RAM collection,
    ``"disk"`` = :func:`make_disk_backend` over a saved index) and a
    one-line description for CLIs/docs."""
    name: str
    kinds: tuple[str, ...]
    description: str


#: The one registry of servable backend names. Every name-based entry point
#: (``make_backend``, ``make_disk_backend``, ``Hercules.engine``, the serve
#: CLI, benchmarks) resolves through here via :func:`resolve_backend_name`,
#: so the valid-name set and the error message cannot drift between them.
BACKENDS: dict[str, BackendSpec] = {s.name: s for s in (
    BackendSpec("local", ("memory", "disk"),
                "Hercules index in RAM: tree routing + EAPCA/SAX pruning "
                "+ exact refine"),
    BackendSpec("scan", ("memory", "disk"),
                "exact dense scan of the full collection"),
    BackendSpec("scan-mxu", ("memory",),
                "dense scan through the Pallas ED kernel (MXU matmul form)"),
    BackendSpec("sharded", ("memory",),
                "series-sharded index under a device mesh"),
    BackendSpec("ooc-scan", ("disk",),
                "streamed blocked scan of the on-disk collection under a "
                "memory budget"),
    BackendSpec("ooc-local", ("disk",),
                "index-pruned out-of-core answering (stream only "
                "unprunable leaves/series)"),
    BackendSpec("dist-ooc", ("disk",),
                "sharded out-of-core serving: each mesh device streams its "
                "own leaf-run row range, top-k merged collectively"),
)}


def backend_names(kind: str | None = None) -> tuple[str, ...]:
    """Registered backend names, registration order; ``kind`` filters to
    one construction path (``"memory"`` or ``"disk"``)."""
    return tuple(n for n, s in BACKENDS.items()
                 if kind is None or kind in s.kinds)


def resolve_backend_name(name: str, *, kind: str) -> BackendSpec:
    """The single place backend-name strings are validated. Returns the
    :class:`BackendSpec` or raises the one canonical error message."""
    spec = BACKENDS.get(name)
    if spec is not None and kind in spec.kinds:
        return spec
    raise ValueError(f"unknown {kind} backend {name!r}; expected one of "
                     f"{backend_names(kind)}")


# deprecated aliases of the registry's two views — prefer
# ``backend_names("memory")`` / ``backend_names("disk")``
BACKEND_NAMES = backend_names("memory")


def make_backend(name: str, data: jax.Array, *,
                 index_config: IndexConfig | None = None,
                 search: SearchConfig | None = None,
                 num_shards: int | None = None,
                 mesh=None) -> SearchBackend:
    """Build a backend over ``data`` by name (see :data:`BACKENDS`).

    ``local``/``sharded`` construct the Hercules index (or stacked indexes);
    ``scan``/``scan-mxu`` serve the raw collection directly.
    """
    resolve_backend_name(name, kind="memory")
    if name == "local":
        cfg = index_config or IndexConfig(search=search or SearchConfig())
        return LocalBackend(HerculesIndex.build(data, cfg))
    if name in ("scan", "scan-mxu"):
        scfg = search or (index_config.search if index_config else SearchConfig())
        return ScanBackend(data, scfg, mxu=name == "scan-mxu")
    if name == "sharded":
        from repro.distributed.search import build_distributed_index
        cfg = index_config or IndexConfig(search=search or SearchConfig())
        shards = num_shards or len(jax.devices())
        stacked = build_distributed_index(data, shards, cfg)
        return ShardedBackend(stacked, mesh)
    raise AssertionError(f"registered backend {name!r} not constructed")


DISK_BACKEND_NAMES = backend_names("disk")   # deprecated alias


def make_disk_backend(name: str, store, *,
                      search: SearchConfig | None = None,
                      memory_budget_mb: float = 64.0,
                      verify: bool = True,
                      prefetch: str | None = None,
                      shards: int | None = None,
                      mesh=None) -> SearchBackend:
    """Serve a saved index by backend name.

    ``store`` is an index-directory path, an already-open ``SavedIndex``,
    or a ``Hercules`` store handle (backends then resolve their data
    through the handle's current base index). ``local``/``scan``
    materialize the saved arrays into the ordinary in-memory backends
    (bit-identical to the ones built from the original data);
    ``ooc-scan``/``ooc-local`` keep the raw series memory-mapped and
    stream them under ``memory_budget_mb``. ``prefetch`` overrides
    ``SearchConfig.prefetch`` for the streamed backends (``"thread"`` =
    async reader thread + two-slot host buffer; answers bit-identical to
    ``"sync"``). ``dist-ooc`` serves the index from every device of a
    mesh at once — ``shards`` (default: device count) or an explicit
    ``mesh`` picks the layout; each shard streams only its own leaf-run
    row range and ``memory_budget_mb`` applies per shard.

    .. deprecated:: store API
        For directory paths prefer ``repro.api.Hercules.open(path)
        .engine(name)``, which additionally caches engines and invalidates
        compiled plans across ``append``/``compact``; this remains the
        low-level constructor the store delegates to.
    """
    from repro.storage import open_index

    resolve_backend_name(name, kind="disk")
    if isinstance(store, str):
        saved = open_index(store, verify=verify)
    else:
        # a Hercules handle exposes .saved; a SavedIndex is used directly
        saved = getattr(store, "saved", store)
        if saved is None:
            raise ValueError(
                f"{store!r} has no base index to serve — append rows and "
                f"compact() first")
    if prefetch is not None:
        search = dataclasses.replace(search or saved.config.search,
                                     prefetch=prefetch)
    if name == "local":
        idx = saved.to_index()
        if search is not None:
            idx.config = dataclasses.replace(idx.config, search=search)
        return LocalBackend(idx)
    if name == "scan":
        return ScanBackend(jnp.asarray(saved.original_data()),
                           search or saved.config.search)
    if name == "ooc-scan":
        return OutOfCoreScanBackend(saved, search,
                                    memory_budget_mb=memory_budget_mb)
    if name == "ooc-local":
        return OutOfCoreLocalBackend(saved, search,
                                     memory_budget_mb=memory_budget_mb)
    if name == "dist-ooc":
        # lazy import: core must not depend on repro.distributed at import
        from repro.distributed.ooc import DistOutOfCoreBackend

        return DistOutOfCoreBackend(saved, search,
                                    memory_budget_mb=memory_budget_mb,
                                    shards=shards, mesh=mesh)
    raise AssertionError(f"registered backend {name!r} not constructed")
