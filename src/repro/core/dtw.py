"""DTW support (paper §2: "Hercules can support any distance measure equipped
with a lower-bounding distance, e.g. DTW [31], similarly to [51]").

Pieces:
  * ``dtw_distance`` — Sakoe-Chiba-banded DTW (squared local costs), computed
    by anti-diagonal wavefront so it vectorizes on the VPU (the classic
    O(n*w) dynamic program re-expressed as jnp ops over diagonals).
  * ``keogh_envelope`` / ``lb_keogh`` — the standard lower bound: the
    candidate's distance to the query's upper/lower envelope under the band.
    LB_Keogh(q, s) <= DTW(q, s) (no false dismissals).
  * ``dtw_knn`` — exact banded-DTW kNN via the Hercules skeleton: LB_Keogh
    filter over the leaf-ordered LRD array, then chunked exact refinement in
    ascending-LB order with BSF pruning (the same exactness argument as the
    ED pipeline).

Note the paper's framing holds: the *index tree* clusters by ED-space EAPCA;
LB_Keogh replaces LB_SAX as the series-level filter for DTW queries (as in
UCR-Suite [54] / the iSAX DTW adaptation [31]).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.layout import HerculesLayout
from repro.core.search import INF, SearchConfig, _merge_topk


def keogh_envelope(q: jax.Array, band: int) -> tuple[jax.Array, jax.Array]:
    """(lower, upper) running min/max of q within +-band. q: (..., n)."""
    n = q.shape[-1]
    lo, hi = q, q
    for _ in range(band):
        lo = jnp.minimum(lo, jnp.minimum(
            jnp.roll(lo, 1, -1).at[..., 0].set(jnp.inf),
            jnp.roll(lo, -1, -1).at[..., -1].set(jnp.inf)))
        hi = jnp.maximum(hi, jnp.maximum(
            jnp.roll(hi, 1, -1).at[..., 0].set(-jnp.inf),
            jnp.roll(hi, -1, -1).at[..., -1].set(-jnp.inf)))
    return lo, hi


def lb_keogh(q: jax.Array, series: jax.Array, band: int) -> jax.Array:
    """Squared LB_Keogh of query q (n,) against series (..., n)."""
    lo, hi = keogh_envelope(q, band)
    d = jnp.maximum(jnp.maximum(series - hi, lo - series), 0.0)
    return jnp.sum(jnp.square(d), axis=-1)


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_distance(a: jax.Array, b: jax.Array, band: int) -> jax.Array:
    """Squared-cost DTW with Sakoe-Chiba band. a (n,), b (..., n) -> (...).

    Wavefront form: row i holds D[i, j] for |i-j| <= band, updated from rows
    i-1/i (vectorized over the band and over b's batch dims).
    """
    n = a.shape[-1]
    batch = b.shape[:-1]
    big = jnp.float32(3.0e38)

    # D_prev[j] = best cost ending at (i-1, j); full-width rows, masked band
    def row(i, d_prev):
        cost = jnp.square(b[..., :] - a[i])                      # (..., n)
        j = jnp.arange(n)
        in_band = jnp.abs(j - i) <= band
        d_diag = jnp.roll(d_prev, 1, -1).at[..., 0].set(
            jnp.where(i == 0, 0.0, big))
        d_up = d_prev
        best_prev = jnp.minimum(d_diag, d_up)
        # d_left is sequential within the row: use associative scan over min-plus
        # simplification: evaluate left-to-right with lax.scan over j
        def left_scan(carry, xs):
            c_j, bp_j, ib_j = xs
            val = c_j + jnp.minimum(bp_j, carry)
            val = jnp.where(ib_j, val, big)
            return val, val

        init = jnp.full(batch, big)
        _, d_row = jax.lax.scan(
            left_scan, init,
            (jnp.moveaxis(cost, -1, 0), jnp.moveaxis(best_prev, -1, 0),
             in_band))
        return jnp.moveaxis(d_row, 0, -1)

    d0_cost = jnp.square(b - a[0])
    j = jnp.arange(n)
    d0 = jnp.where(j <= band, jnp.cumsum(d0_cost, -1), big)
    d = jax.lax.fori_loop(1, n, row, d0)
    return d[..., -1]


def dtw_knn(layout: HerculesLayout, queries: jax.Array, k: int, band: int,
            cfg: SearchConfig | None = None):
    """Exact banded-DTW kNN over the index's LRD array.

    LB_Keogh-ordered chunked refinement with BSF pruning (the Hercules
    phase-3/4 skeleton with DTW's lower bound). Returns (dists, layout
    positions). Exact for the banded DTW.
    """
    cfg = cfg or SearchConfig(k=k, chunk=256)
    chunk = cfg.chunk
    n_pad = layout.lrd.shape[0]
    if n_pad % chunk:
        raise ValueError("layout padding must divide refinement chunk")

    @functools.partial(jax.jit, static_argnames=())
    def run(queries):
        def one(q):
            lbs = lb_keogh(q, layout.lrd, band)
            lbs = jnp.where(jnp.arange(n_pad) < layout.num_series, lbs, INF)
            order = jnp.argsort(lbs).astype(jnp.int32)
            sorted_lb = lbs[order]
            n_chunks = n_pad // chunk

            def cond(st):
                c, d_top, p_top = st
                return (c < n_chunks) & (sorted_lb[c * chunk] < d_top[k - 1])

            def body(st):
                c, d_top, p_top = st
                idx = jax.lax.dynamic_slice(order, (c * chunk,), (chunk,))
                rows = layout.lrd[idx]
                d = dtw_distance(q, rows, band)
                live = jax.lax.dynamic_slice(
                    sorted_lb, (c * chunk,), (chunk,)) < d_top[k - 1]
                d = jnp.where(live, d, INF)
                d_top, p_top = _merge_topk(d_top, p_top, d, idx, k)
                return c + 1, d_top, p_top

            d0 = jnp.full((k,), INF)
            p0 = jnp.full((k,), -1, jnp.int32)
            _, d_top, p_top = jax.lax.while_loop(
                cond, body, (jnp.int32(0), d0, p0))
            return d_top, p_top

        return jax.lax.map(one, queries)

    return run(queries)
