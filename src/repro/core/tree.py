"""The Hercules index tree (paper §3.2–3.3), built level-synchronously in JAX.

The paper builds an unbalanced binary EAPCA tree with many threads inserting
concurrently under per-leaf locks (Algorithms 1–5). Pointer-chasing insertions
with locks have no XLA analogue; the TPU-native equivalent (DESIGN.md §2) is a
**level-synchronous batched build**: each round, *every* over-capacity leaf
picks its best split policy (the DSTree-style QoS heuristic, Alg. 5 line 10)
and all member series are re-partitioned in one data-parallel step. The
resulting tree is identical in kind — same node synopses, same H/V split
semantics, same routing — and the build is deterministic.

Tree encoding: structure-of-arrays with static capacity ``max_nodes``.
Segmentations are fixed-width right-endpoint arrays padded by repeating ``n``
(see summaries.py). A node's split is encoded *positionally* as a point range
``[split_lo, split_hi)`` plus a mean/std selector and a threshold — this makes
routing segmentation-index-free (V-splits shift indices, not point ranges).

Round structure (one jit'd ``_build_round`` per round, Python-driven loop —
the idiomatic JAX pattern for data-dependent iteration counts; every round
reuses the same compiled step):

  1. per-series segment stats under the *current leaf's* segmentation
     (via the (N, n+1) prefix sums computed once),
  2. per-leaf synopsis ranges via ``segment_min/max``,
  3. QoS scores for every candidate policy (H-split x {mean, std} per segment;
     V-split per splittable segment with best half x stat),
  4. children allocation + scatter of node metadata,
  5. series re-partition by the chosen policy.

Split policy scoring (documented reconstruction of DSTree's QoS heuristic):
``QoS(segment) = len * (range_mu^2 + range_sd^2)`` is an upper-bound proxy for
the intra-node squared diameter contributed by that segment. An H-split at the
range midpoint halves the chosen range, so its benefit is
``len * range^2 / 2``. A V-split's benefit is the segmentation-refinement gain
``QoS(segment) - sum_h QoS(half_h)`` plus the best H-benefit among halves.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import summaries as S


@dataclasses.dataclass(frozen=True)
class BuildConfig:
    """Static build-time settings (the paper's Idx.Settings, Alg. 6 line 2)."""
    leaf_capacity: int = 256          # tau: paper uses 100K on disk; scale down on CPU
    max_segments: int = 16            # M: V-splits may refine up to this many
    init_segments: int = 4            # root segmentation (equal-length)
    max_nodes: int = 0                # 0 -> auto: 8 * ceil(N / tau) + 64
    max_rounds: int = 64              # safety bound on build rounds

    def resolve_max_nodes(self, num_series: int) -> int:
        if self.max_nodes:
            return self.max_nodes
        return 8 * max(1, -(-num_series // self.leaf_capacity)) + 64


class HerculesTree(NamedTuple):
    """Structure-of-arrays binary tree. All arrays have leading dim max_nodes
    (+1 drop slot where noted). Valid node ids are [0, num_nodes)."""
    parent: jax.Array        # (max_nodes,) int32, -1 for root
    left: jax.Array          # (max_nodes,) int32, -1 if leaf
    right: jax.Array         # (max_nodes,) int32, -1 if leaf
    is_leaf: jax.Array       # (max_nodes,) bool
    no_split: jax.Array      # (max_nodes,) bool: leaf proven unsplittable
    depth: jax.Array         # (max_nodes,) int32
    endpoints: jax.Array     # (max_nodes, M) int32 right endpoints (pad = n)
    num_segs: jax.Array      # (max_nodes,) int32
    split_lo: jax.Array      # (max_nodes,) int32 routing range start
    split_hi: jax.Array      # (max_nodes,) int32 routing range end (excl)
    split_use_std: jax.Array # (max_nodes,) bool: route on sd instead of mean
    split_value: jax.Array   # (max_nodes,) float32 threshold (range midpoint)
    synopsis: jax.Array      # (max_nodes, M, 4) [mu_min, mu_max, sd_min, sd_max]
    count: jax.Array         # (max_nodes,) int32 series at/below node
    num_nodes: jax.Array     # () int32

    @property
    def max_nodes(self) -> int:
        return self.parent.shape[0]

    @property
    def max_segments(self) -> int:
        return self.endpoints.shape[1]


def _empty_tree(max_nodes: int, m: int, n: int, init_segments: int) -> HerculesTree:
    ep0 = np.full((m,), n, dtype=np.int32)
    for j in range(init_segments):
        ep0[j] = round(n * (j + 1) / init_segments)
    endpoints = jnp.zeros((max_nodes, m), jnp.int32).at[0].set(jnp.asarray(ep0))
    return HerculesTree(
        parent=jnp.full((max_nodes,), -1, jnp.int32),
        left=jnp.full((max_nodes,), -1, jnp.int32),
        right=jnp.full((max_nodes,), -1, jnp.int32),
        is_leaf=jnp.zeros((max_nodes,), bool).at[0].set(True),
        no_split=jnp.zeros((max_nodes,), bool),
        depth=jnp.zeros((max_nodes,), jnp.int32),
        endpoints=endpoints,
        num_segs=jnp.zeros((max_nodes,), jnp.int32).at[0].set(init_segments),
        split_lo=jnp.zeros((max_nodes,), jnp.int32),
        split_hi=jnp.zeros((max_nodes,), jnp.int32),
        split_use_std=jnp.zeros((max_nodes,), bool),
        split_value=jnp.zeros((max_nodes,), jnp.float32),
        synopsis=jnp.zeros((max_nodes, m, 4), jnp.float32),
        count=jnp.zeros((max_nodes,), jnp.int32),
        num_nodes=jnp.asarray(1, jnp.int32),
    )


# ---------------------------------------------------------------------------
# Per-round primitives
# ---------------------------------------------------------------------------

def _range_stat(p: jax.Array, p2: jax.Array, lo: jax.Array, hi: jax.Array,
                use_std: jax.Array) -> jax.Array:
    """Mean or population-std of each series over its own [lo, hi) range.

    ``p``/``p2``: (N, n+1); ``lo``/``hi``/``use_std``: (N,). Returns (N,).
    """
    lo = lo[:, None]
    hi = hi[:, None]
    ln = jnp.maximum((hi - lo).astype(jnp.float32), 1.0)
    s1 = jnp.take_along_axis(p, hi, axis=1) - jnp.take_along_axis(p, lo, axis=1)
    s2 = jnp.take_along_axis(p2, hi, axis=1) - jnp.take_along_axis(p2, lo, axis=1)
    mean = (s1 / ln)[:, 0]
    var = jnp.maximum((s2 / ln)[:, 0] - jnp.square(mean), 0.0)
    return jnp.where(use_std, jnp.sqrt(var), mean)


def _seg_minmax(vals: jax.Array, seg_ids: jax.Array, num_segments: int):
    """segment_min/max with a drop slot; vals (N, ...), seg_ids (N,)."""
    mn = jax.ops.segment_min(vals, seg_ids, num_segments=num_segments)
    mx = jax.ops.segment_max(vals, seg_ids, num_segments=num_segments)
    return mn, mx


class RoundStats(NamedTuple):
    """Per-node associative reductions feeding one split round's decision.

    Everything :func:`_round_decide` consumes is either a per-node member
    count (a sum) or a per-node/per-segment min/max of per-series statistics
    — all associative, order-independent reductions. A round's statistics
    can therefore be computed over any partition of the collection into
    chunks and merged exactly (:func:`_merge_round_stats`), which is what
    the out-of-core chunked build does; the one-shot build is the
    single-chunk special case, so both produce bit-identical trees.

    ``counts`` is (max_nodes,) int32; every other field is (max_nodes, M)
    float32 with min-identity +inf / max-identity -inf for nodes that saw
    no members (never read: only over-capacity leaves are consulted).
    """
    counts: jax.Array
    mu_mn: jax.Array
    mu_mx: jax.Array
    sd_mn: jax.Array
    sd_mx: jax.Array
    h1m_mn: jax.Array
    h1m_mx: jax.Array
    h1s_mn: jax.Array
    h1s_mx: jax.Array
    h2m_mn: jax.Array
    h2m_mx: jax.Array
    h2s_mn: jax.Array
    h2s_mx: jax.Array


def _round_stats(tree: HerculesTree, node_of: jax.Array,
                 p: jax.Array, p2: jax.Array) -> RoundStats:
    """Per-leaf reductions over one chunk of members (round phase 1+3 stats)."""
    max_nodes = tree.max_nodes
    num = p.shape[0]

    # per-series segment geometry under the current leaf
    ep = tree.endpoints[node_of]                       # (N, M)
    starts = jnp.concatenate([jnp.zeros((num, 1), jnp.int32), ep[:, :-1]], axis=1)
    lens = ep - starts                                  # (N, M) int32
    mids = starts + lens // 2                           # V-split half boundary

    means, stds = S.segment_stats_from_prefix(p, p2, ep)          # (N, M)
    h1m, h1s = S.segment_stats_from_prefix(p, p2, mids)           # halves [s,mid)
    # halves [mid, e): stats via difference of sums
    ln2 = jnp.maximum((ep - mids).astype(jnp.float32), 1.0)
    s1b = jnp.take_along_axis(p, ep, 1) - jnp.take_along_axis(p, mids, 1)
    s2b = jnp.take_along_axis(p2, ep, 1) - jnp.take_along_axis(p2, mids, 1)
    h2m = s1b / ln2
    h2s = jnp.sqrt(jnp.maximum(s2b / ln2 - jnp.square(h2m), 0.0))

    counts = jax.ops.segment_sum(jnp.ones((num,), jnp.int32), node_of,
                                 num_segments=max_nodes)
    parts = [counts]
    for vals in (means, stds, h1m, h1s, h2m, h2s):
        mn, mx = _seg_minmax(vals, node_of, max_nodes + 1)
        parts += [mn[:max_nodes], mx[:max_nodes]]
    return RoundStats(*parts)


def _merge_round_stats(a: RoundStats, b: RoundStats) -> RoundStats:
    """Exact merge of two chunks' reductions (sum / min / max per field)."""
    merged = [a.counts + b.counts]
    for name in RoundStats._fields[1:]:
        va, vb = getattr(a, name), getattr(b, name)
        merged.append(jnp.minimum(va, vb) if name.endswith("_mn")
                      else jnp.maximum(va, vb))
    return RoundStats(*merged)


def _round_decide(tree: HerculesTree, stats: RoundStats, *, tau: int):
    """Pick split policies and scatter children from merged round stats.

    Returns (tree, num_split). Pure function of (tree, stats): identical
    inputs give identical trees whether the stats came from one chunk or
    many.
    """
    max_nodes = tree.max_nodes
    m = tree.max_segments

    # ---- 2. which leaves split this round ---------------------------------
    counts = stats.counts
    want = tree.is_leaf & ~tree.no_split & (counts > tau)
    budget = (max_nodes - tree.num_nodes) // 2
    rank = jnp.cumsum(want.astype(jnp.int32)) - 1      # (max_nodes,)
    splitting = want & (rank < budget)

    # ---- 3. per-leaf synopsis ranges + QoS policy scores -------------------
    node_ep = tree.endpoints                            # (max_nodes, M)
    node_st = jnp.concatenate(
        [jnp.zeros((max_nodes, 1), jnp.int32), node_ep[:, :-1]], axis=1)
    node_len = (node_ep - node_st).astype(jnp.float32)  # (max_nodes, M)

    def rng(mx, mn):
        return jnp.maximum(mx - mn, 0.0)

    r_mu, r_sd = rng(stats.mu_mx, stats.mu_mn), rng(stats.sd_mx, stats.sd_mn)
    r1_mu, r1_sd = rng(stats.h1m_mx, stats.h1m_mn), rng(stats.h1s_mx, stats.h1s_mn)
    r2_mu, r2_sd = rng(stats.h2m_mx, stats.h2m_mn), rng(stats.h2s_mx, stats.h2s_mn)

    valid_seg = node_len >= 1.0
    l1 = jnp.floor(node_len / 2.0)
    l2 = node_len - l1

    score_h_mu = jnp.where(valid_seg, node_len * jnp.square(r_mu) / 2.0, -1.0)
    score_h_sd = jnp.where(valid_seg, node_len * jnp.square(r_sd) / 2.0, -1.0)

    qos_full = node_len * (jnp.square(r_mu) + jnp.square(r_sd))
    qos_halves = (l1 * (jnp.square(r1_mu) + jnp.square(r1_sd))
                  + l2 * (jnp.square(r2_mu) + jnp.square(r2_sd)))
    h_gain = jnp.stack([l1 * jnp.square(r1_mu) / 2.0,   # (max_nodes, M, 4)
                        l1 * jnp.square(r1_sd) / 2.0,
                        l2 * jnp.square(r2_mu) / 2.0,
                        l2 * jnp.square(r2_sd) / 2.0], axis=-1)
    best_half = jnp.argmax(h_gain, axis=-1)             # (max_nodes, M)
    best_half_gain = jnp.max(h_gain, axis=-1)
    can_v = (node_len >= 2.0) & (tree.num_segs < m)[:, None]
    score_v = jnp.where(can_v, qos_full - qos_halves + best_half_gain, -1.0)

    # candidate tensor: (max_nodes, M, 3) -> [h_mu, h_sd, v]
    cand = jnp.stack([score_h_mu, score_h_sd, score_v], axis=-1)
    flat = cand.reshape(max_nodes, m * 3)
    best_idx = jnp.argmax(flat, axis=1)
    best_score = jnp.take_along_axis(flat, best_idx[:, None], axis=1)[:, 0]
    seg_idx = best_idx // 3                             # (max_nodes,)
    kind = best_idx % 3                                 # 0 h_mu, 1 h_sd, 2 v

    degenerate = splitting & (best_score <= 0.0)
    splitting = splitting & (best_score > 0.0)
    # re-rank after dropping degenerates so child ids stay dense
    rank = jnp.cumsum(splitting.astype(jnp.int32)) - 1
    splitting = splitting & (rank < budget)

    # ---- 4. resolve the chosen policy per splitting node -------------------
    ar = jnp.arange(max_nodes)
    sel = lambda a: a[ar, seg_idx]                      # (max_nodes,)
    g_st, g_ep = sel(node_st), sel(node_ep)
    g_mid = g_st + (g_ep - g_st) // 2
    g_half = sel(best_half)                             # 0..3 for V splits
    v_use_h2 = g_half >= 2
    v_use_std = (g_half % 2) == 1

    lo_h, hi_h = g_st, g_ep
    lo_v = jnp.where(v_use_h2, g_mid, g_st)
    hi_v = jnp.where(v_use_h2, g_ep, g_mid)
    is_v = kind == 2
    new_lo = jnp.where(is_v, lo_v, lo_h)
    new_hi = jnp.where(is_v, hi_v, hi_h)
    new_std = jnp.where(is_v, v_use_std, kind == 1)

    def mid_of(mn, mx):
        return (sel(mn) + sel(mx)) / 2.0

    thr_h = jnp.where(kind == 1, mid_of(stats.sd_mn, stats.sd_mx),
                      mid_of(stats.mu_mn, stats.mu_mx))
    thr_v = jnp.where(
        v_use_h2,
        jnp.where(v_use_std, mid_of(stats.h2s_mn, stats.h2s_mx),
                  mid_of(stats.h2m_mn, stats.h2m_mx)),
        jnp.where(v_use_std, mid_of(stats.h1s_mn, stats.h1s_mx),
                  mid_of(stats.h1m_mn, stats.h1m_mx)))
    new_value = jnp.where(is_v, thr_v, thr_h)

    # child segmentation: V-split inserts g_mid (pad slot M-1 is always n)
    child_ep = jnp.where(is_v[:, None],
                         jnp.sort(node_ep.at[:, m - 1].set(
                             jnp.where(is_v, g_mid, node_ep[:, m - 1])), axis=1),
                         node_ep)
    child_nsegs = tree.num_segs + is_v.astype(jnp.int32)

    # ---- 5. allocate children + scatter metadata ---------------------------
    left_id = jnp.where(splitting, tree.num_nodes + 2 * rank, max_nodes)
    right_id = jnp.where(splitting, left_id + 1, max_nodes)

    def sc(arr, idx, val):
        return arr.at[idx].set(val, mode="drop")

    self_idx = jnp.where(splitting, ar, max_nodes)
    tree = tree._replace(
        left=sc(tree.left, self_idx, left_id.astype(jnp.int32)),
        right=sc(tree.right, self_idx, right_id.astype(jnp.int32)),
        is_leaf=sc(sc(sc(tree.is_leaf, self_idx, False), left_id, True), right_id, True),
        no_split=sc(tree.no_split, jnp.where(degenerate, ar, max_nodes), True),
        split_lo=sc(tree.split_lo, self_idx, new_lo),
        split_hi=sc(tree.split_hi, self_idx, new_hi),
        split_use_std=sc(tree.split_use_std, self_idx, new_std),
        split_value=sc(tree.split_value, self_idx, new_value),
        parent=sc(sc(tree.parent, left_id, ar.astype(jnp.int32)),
                  right_id, ar.astype(jnp.int32)),
        depth=sc(sc(tree.depth, left_id, tree.depth + 1), right_id, tree.depth + 1),
        endpoints=sc(sc(tree.endpoints, left_id, child_ep), right_id, child_ep),
        num_segs=sc(sc(tree.num_segs, left_id, child_nsegs), right_id, child_nsegs),
        num_nodes=tree.num_nodes + 2 * jnp.sum(splitting.astype(jnp.int32)),
    )
    return tree, jnp.sum(splitting.astype(jnp.int32))


def _route_members(tree: HerculesTree, node_of: jax.Array,
                   p: jax.Array, p2: jax.Array) -> jax.Array:
    """Round phase 6: move members of just-split leaves to the winning child.

    A member moves iff its node stopped being a leaf this round (earlier
    splits already re-homed their members), so this needs only the
    post-decide tree — it runs independently per chunk.
    """
    moved = ~tree.is_leaf[node_of]
    stat = _range_stat(p, p2, tree.split_lo[node_of], tree.split_hi[node_of],
                       tree.split_use_std[node_of])
    go_right = stat >= tree.split_value[node_of]
    new_node = jnp.where(go_right, tree.right[node_of], tree.left[node_of])
    return jnp.where(moved, new_node, node_of).astype(jnp.int32)


def _leaf_member_counts(node_of: jax.Array, max_nodes: int) -> jax.Array:
    return jax.ops.segment_sum(jnp.ones(node_of.shape, jnp.int32), node_of,
                               num_segments=max_nodes)


@functools.partial(jax.jit, static_argnames=("tau",), donate_argnums=(0, 1))
def _build_round(tree: HerculesTree, node_of: jax.Array,
                 p: jax.Array, p2: jax.Array, *, tau: int):
    """One level-synchronous split round. Returns (tree, node_of, num_split).

    Composition of the chunkable primitives with a single chunk — the
    chunked driver (:func:`build_tree_chunked`) runs the same stats /
    decide / route functions over many chunks and merges, so both paths
    build bit-identical trees.
    """
    stats = _round_stats(tree, node_of, p, p2)
    tree, num_split = _round_decide(tree, stats, tau=tau)
    node_of = _route_members(tree, node_of, p, p2)
    counts = _leaf_member_counts(node_of, tree.max_nodes)
    tree = tree._replace(count=jnp.where(tree.is_leaf, counts, tree.count))
    return tree, node_of, num_split


def _synopsis_chunk_minmax(tree: HerculesTree, anc: jax.Array,
                           p: jax.Array, p2: jax.Array):
    """One chunk's contribution to the current-level synopsis fold:
    (mu_mn, mu_mx, sd_mn, sd_mx), each (max_nodes, M). Associative —
    chunks merge exactly via :func:`_merge_synopsis_minmax`."""
    max_nodes = tree.max_nodes
    ep = tree.endpoints[jnp.maximum(anc, 0)]
    means, stds = S.segment_stats_from_prefix(p, p2, ep)
    ids = jnp.where(anc >= 0, anc, max_nodes)
    mu_mn, mu_mx = _seg_minmax(means, ids, max_nodes + 1)
    sd_mn, sd_mx = _seg_minmax(stds, ids, max_nodes + 1)
    return (mu_mn[:max_nodes], mu_mx[:max_nodes],
            sd_mn[:max_nodes], sd_mx[:max_nodes])


def _merge_synopsis_minmax(a, b):
    return (jnp.minimum(a[0], b[0]), jnp.maximum(a[1], b[1]),
            jnp.minimum(a[2], b[2]), jnp.maximum(a[3], b[3]))


def _synopsis_fold(tree: HerculesTree, mm) -> HerculesTree:
    """Fold merged (mu_mn, mu_mx, sd_mn, sd_mx) into the running synopsis.
    Min/max identities mean untouched slots keep their +-big init."""
    old = tree.synopsis
    syn = jnp.stack([jnp.minimum(old[..., 0], mm[0]),
                     jnp.maximum(old[..., 1], mm[1]),
                     jnp.minimum(old[..., 2], mm[2]),
                     jnp.maximum(old[..., 3], mm[3])], axis=-1)
    return tree._replace(synopsis=syn)


@functools.partial(jax.jit, donate_argnums=(0,))
def _synopsis_level(tree: HerculesTree, anc: jax.Array,
                    p: jax.Array, p2: jax.Array):
    """Fold every series' stats (under ancestor ``anc``'s segmentation) into
    that ancestor's synopsis, then step ancestors one level up.

    This is the batched analogue of the paper's index-writing synopsis pass
    (Algorithms 7–9): instead of per-leaf worker threads walking up with
    locks, one vectorized reduction per tree level.
    """
    mm = _synopsis_chunk_minmax(tree, anc, p, p2)
    tree = _synopsis_fold(tree, mm)
    anc = jnp.where(anc >= 0, tree.parent[jnp.maximum(anc, 0)], -1)
    return tree, anc


_SYN_BIG = 3.0e38


def compute_synopses(tree: HerculesTree, node_of: jax.Array,
                     p: jax.Array, p2: jax.Array, max_depth: int) -> HerculesTree:
    """Exact synopses for every node (leaf + internal), level-vectorized.

    Every series folds its per-segment stats into each of its ancestors
    (including its leaf), one tree level per step — the index-writing phase
    of the paper without locks.
    """
    init = jnp.stack([jnp.full(tree.synopsis.shape[:-1], _SYN_BIG, jnp.float32),
                      jnp.full(tree.synopsis.shape[:-1], -_SYN_BIG, jnp.float32),
                      jnp.full(tree.synopsis.shape[:-1], _SYN_BIG, jnp.float32),
                      jnp.full(tree.synopsis.shape[:-1], -_SYN_BIG, jnp.float32)],
                     axis=-1)
    tree = tree._replace(synopsis=init)
    anc = node_of
    for _ in range(max_depth + 1):
        tree, anc = _synopsis_level(tree, anc, p, p2)
    # zero out untouched (empty) nodes so downstream arithmetic stays finite
    untouched = tree.synopsis[..., 0] >= _SYN_BIG
    syn = jnp.where(untouched[..., None], 0.0, tree.synopsis)
    return tree._replace(synopsis=syn)


# ---------------------------------------------------------------------------
# Build driver
# ---------------------------------------------------------------------------

def build_tree(data: jax.Array, config: BuildConfig) -> tuple[HerculesTree, jax.Array]:
    """Build the Hercules tree over ``data`` (N, n).

    Returns (tree, node_of) where node_of maps each series to its leaf.
    Python-driven round loop over a single compiled round step; the number of
    rounds equals the final tree depth (level-synchronous).
    """
    num, n = data.shape
    max_nodes = config.resolve_max_nodes(num)
    if config.init_segments > config.max_segments:
        raise ValueError("init_segments > max_segments")
    tree = _empty_tree(max_nodes, config.max_segments, n, config.init_segments)
    node_of = jnp.zeros((num,), jnp.int32)
    p, p2 = S.prefix_sums(data)
    tree = tree._replace(count=tree.count.at[0].set(num))

    for _ in range(config.max_rounds):
        tree, node_of, n_split = _build_round(tree, node_of, p, p2,
                                              tau=config.leaf_capacity)
        if int(n_split) == 0:
            break

    max_depth = int(jnp.max(jnp.where(jnp.arange(max_nodes) < tree.num_nodes,
                                      tree.depth, 0)))
    tree = compute_synopses(tree, node_of, p, p2, max_depth)
    return tree, node_of


# ---------------------------------------------------------------------------
# Chunked (out-of-core) build driver
# ---------------------------------------------------------------------------

_round_stats_jit = jax.jit(_round_stats)
_merge_round_stats_jit = jax.jit(_merge_round_stats, donate_argnums=(0,))
_round_decide_jit = functools.partial(jax.jit, static_argnames=("tau",),
                                      donate_argnums=(0,))(_round_decide)
_route_members_jit = jax.jit(_route_members)
_synopsis_chunk_minmax_jit = jax.jit(_synopsis_chunk_minmax)
_merge_synopsis_minmax_jit = jax.jit(_merge_synopsis_minmax, donate_argnums=(0,))
_synopsis_fold_jit = jax.jit(_synopsis_fold, donate_argnums=(0,))


def compute_synopses_chunked(tree: HerculesTree, node_of: jax.Array,
                             source, max_depth: int,
                             prefetch: str = "sync") -> HerculesTree:
    """Chunk-streamed :func:`compute_synopses` — bit-identical synopses
    without ever holding the collection (or its prefix sums) on device.
    ``prefetch="thread"`` overlaps the chunk reads with the fold compute
    (same bits: the stream order is deterministic either way)."""
    from repro.data.pipeline import iter_device_chunks

    init = jnp.stack([jnp.full(tree.synopsis.shape[:-1], _SYN_BIG, jnp.float32),
                      jnp.full(tree.synopsis.shape[:-1], -_SYN_BIG, jnp.float32),
                      jnp.full(tree.synopsis.shape[:-1], _SYN_BIG, jnp.float32),
                      jnp.full(tree.synopsis.shape[:-1], -_SYN_BIG, jnp.float32)],
                     axis=-1)
    tree = tree._replace(synopsis=init)
    anc = node_of
    for _ in range(max_depth + 1):
        mm = None
        for start, chunk in iter_device_chunks(source, prefetch=prefetch):
            p, p2 = S.prefix_sums(chunk)
            cm = _synopsis_chunk_minmax_jit(
                tree, anc[start:start + chunk.shape[0]], p, p2)
            mm = cm if mm is None else _merge_synopsis_minmax_jit(mm, cm)
        tree = _synopsis_fold_jit(tree, mm)
        anc = jnp.where(anc >= 0, tree.parent[jnp.maximum(anc, 0)], -1)
    untouched = tree.synopsis[..., 0] >= _SYN_BIG
    syn = jnp.where(untouched[..., None], 0.0, tree.synopsis)
    return tree._replace(synopsis=syn)


def build_tree_chunked(source, config: BuildConfig,
                       prefetch: str = "sync") -> tuple[HerculesTree, jax.Array]:
    """Out-of-core :func:`build_tree`: stream the collection in chunks.

    ``source`` is a :class:`repro.data.pipeline.ChunkSource` (re-iterable,
    fixed chunk boundaries). Each round makes two streamed passes — one to
    accumulate :class:`RoundStats` (merged with exact min/max/sum), one to
    re-partition members — so device residency is bounded by the two
    in-flight chunks of the double-buffered stream plus O(max_nodes * M)
    tree state plus the (N,) node assignment, never the (N, n) collection. Because every cross-series reduction is
    associative and per-series statistics depend only on that series' own
    prefix sums, the resulting tree is **bit-identical** to the one-shot
    build on the concatenated data (asserted in tests/test_storage.py).

    Cost: prefix sums are recomputed per chunk per pass instead of being
    materialized once — the classic out-of-core trade of FLOPs for memory
    (the paper's disk-based build makes the same trade with its two-pass
    leaf writes).
    """
    from repro.data.pipeline import iter_device_chunks

    num, n = source.num_series, source.series_len
    max_nodes = config.resolve_max_nodes(num)
    if config.init_segments > config.max_segments:
        raise ValueError("init_segments > max_segments")
    tree = _empty_tree(max_nodes, config.max_segments, n, config.init_segments)
    node_of = jnp.zeros((num,), jnp.int32)
    tree = tree._replace(count=tree.count.at[0].set(num))

    for _ in range(config.max_rounds):
        stats = None
        for start, chunk in iter_device_chunks(source, prefetch=prefetch):
            p, p2 = S.prefix_sums(chunk)
            cs = _round_stats_jit(tree, node_of[start:start + chunk.shape[0]],
                                  p, p2)
            stats = cs if stats is None else _merge_round_stats_jit(stats, cs)
        tree, num_split = _round_decide_jit(tree, stats,
                                            tau=config.leaf_capacity)
        if int(num_split) == 0:
            break
        parts = []
        for start, chunk in iter_device_chunks(source, prefetch=prefetch):
            p, p2 = S.prefix_sums(chunk)
            parts.append(_route_members_jit(
                tree, node_of[start:start + chunk.shape[0]], p, p2))
        node_of = jnp.concatenate(parts)
        counts = _leaf_member_counts(node_of, max_nodes)
        tree = tree._replace(count=jnp.where(tree.is_leaf, counts, tree.count))

    max_depth = int(jnp.max(jnp.where(jnp.arange(max_nodes) < tree.num_nodes,
                                      tree.depth, 0)))
    tree = compute_synopses_chunked(tree, node_of, source, max_depth,
                                    prefetch=prefetch)
    return tree, node_of


# ---------------------------------------------------------------------------
# Routing (query-time descent, paper Alg. 5 line 1 / RouteToLeaf)
# ---------------------------------------------------------------------------

def route_to_leaf(tree: HerculesTree, series: jax.Array, max_depth: int) -> jax.Array:
    """Route each series (Q, n) to its home leaf id. Returns (Q,) int32."""
    p, p2 = S.prefix_sums(series)
    node = jnp.zeros((series.shape[0],), jnp.int32)

    def step(_, node):
        leaf = tree.is_leaf[node]
        stat = _range_stat(p, p2, tree.split_lo[node], tree.split_hi[node],
                           tree.split_use_std[node])
        go_right = stat >= tree.split_value[node]
        nxt = jnp.where(go_right, tree.right[node], tree.left[node])
        return jnp.where(leaf, node, nxt).astype(jnp.int32)

    return jax.lax.fori_loop(0, max_depth + 1, step, node)


# ---------------------------------------------------------------------------
# Host-side inspection helpers (small-tree operations; numpy)
# ---------------------------------------------------------------------------

def inorder_leaves(tree: HerculesTree) -> np.ndarray:
    """Leaf ids in in-order traversal — the LRDFile layout order (§3.3.1).

    For leaves, in-order == left-to-right DFS order (internal nodes interleave
    but are not materialized in LRDFile).
    """
    left = np.asarray(tree.left)
    right = np.asarray(tree.right)
    is_leaf = np.asarray(tree.is_leaf)
    order: list[int] = []
    stack: list[int] = [0]
    while stack:
        node = stack.pop()
        if node < 0:
            continue
        if is_leaf[node]:
            order.append(node)
        else:
            stack.append(right[node])
            stack.append(left[node])
    return np.asarray(order, dtype=np.int32)


def tree_stats(tree: HerculesTree) -> dict:
    nn = int(tree.num_nodes)
    leaf = np.asarray(tree.is_leaf[:nn])
    cnt = np.asarray(tree.count[:nn])
    return {
        "num_nodes": nn,
        "num_leaves": int(leaf.sum()),
        "max_depth": int(np.asarray(tree.depth[:nn]).max(initial=0)),
        "max_leaf": int(cnt[leaf].max(initial=0)),
        "min_leaf": int(cnt[leaf].min(initial=0)),
        "total_in_leaves": int(cnt[leaf].sum()),
    }
