"""Data-series summarizations: PAA, iSAX, EAPCA (paper §2, Fig. 1).

All functions are pure jnp, jit-safe, and operate on batches of series with
static shapes. Throughout the framework a *series collection* is an array of
shape ``(N, n)`` float32 — N series of length n (the paper's dimensionality).

Conventions
-----------
* Distances are **squared** Euclidean everywhere (the paper's own optimization,
  §4.1 "squared distances"); square roots are taken only for display.
* iSAX uses ``NUM_SAX_SEGMENTS = 16`` segments and ``SAX_ALPHABET = 256``
  symbols (8 bits), the paper's settings (§2, following [21] and [58]).
* Standard deviations are population (ddof=0) — required for the EAPCA lower
  bound to be a true lower bound (see lower_bounds.py).
* Variable-length segmentations (EAPCA) are encoded as a fixed-width array of
  *right endpoints* padded by repeating ``n``; a repeated endpoint denotes an
  empty segment contributing nothing. This keeps every node's segmentation a
  static ``(max_segments,)`` int32 array, the TPU-friendly equivalent of the
  paper's per-node variable segmentation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.scipy.special import ndtri

NUM_SAX_SEGMENTS = 16
SAX_ALPHABET = 256
SAX_CARD_BITS = 8  # log2(SAX_ALPHABET)


# ---------------------------------------------------------------------------
# z-normalization
# ---------------------------------------------------------------------------

def znormalize(series: jax.Array, eps: float = 1e-8) -> jax.Array:
    """Z-normalize each series (zero mean, unit variance). Shape-preserving."""
    mu = jnp.mean(series, axis=-1, keepdims=True)
    sd = jnp.std(series, axis=-1, keepdims=True)
    return (series - mu) / jnp.maximum(sd, eps)


# ---------------------------------------------------------------------------
# PAA + iSAX
# ---------------------------------------------------------------------------

def paa(series: jax.Array, num_segments: int = NUM_SAX_SEGMENTS) -> jax.Array:
    """Piecewise Aggregate Approximation.

    ``series``: (..., n) with n divisible by num_segments.
    Returns (..., num_segments) segment means.
    """
    n = series.shape[-1]
    if n % num_segments:
        raise ValueError(f"series length {n} not divisible by {num_segments}")
    seg = n // num_segments
    return jnp.mean(series.reshape(*series.shape[:-1], num_segments, seg), axis=-1)


def sax_breakpoints(alphabet: int = SAX_ALPHABET) -> jax.Array:
    """(alphabet-1,) ascending breakpoints: standard-normal quantiles.

    Cell ``c`` covers [bp[c-1], bp[c]) with bp[-1] = -inf, bp[a-1] = +inf.
    NOTE: deliberately NOT cached — a cached traced/committed array leaks
    across mesh contexts (shard_map under different meshes rejects it).
    """
    qs = jnp.arange(1, alphabet, dtype=jnp.float32) / alphabet
    return ndtri(qs).astype(jnp.float32)


def isax_from_paa(paa_vals: jax.Array, alphabet: int = SAX_ALPHABET) -> jax.Array:
    """Discretize PAA values to iSAX symbols. Returns uint8 codes (alphabet<=256)."""
    bps = sax_breakpoints(alphabet)
    codes = jnp.searchsorted(bps, paa_vals, side="right")
    return codes.astype(jnp.uint8)


def isax(series: jax.Array,
         num_segments: int = NUM_SAX_SEGMENTS,
         alphabet: int = SAX_ALPHABET) -> jax.Array:
    """iSAX summary of each series: (..., num_segments) uint8 symbol codes."""
    return isax_from_paa(paa(series, num_segments), alphabet)


def isax_cell_bounds(codes: jax.Array,
                     alphabet: int = SAX_ALPHABET) -> tuple[jax.Array, jax.Array]:
    """Per-symbol cell [lo, hi] bounds for iSAX codes.

    Returns (lo, hi) arrays, same shape as ``codes``, float32. Open ends use
    +-LARGE (not inf, so arithmetic stays finite under masking).
    """
    big = jnp.float32(3.0e38)
    bps = sax_breakpoints(alphabet)
    c = codes.astype(jnp.int32)
    lo = jnp.where(c == 0, -big, bps[jnp.maximum(c - 1, 0)])
    hi = jnp.where(c == alphabet - 1, big, bps[jnp.minimum(c, alphabet - 2)])
    return lo, hi


# ---------------------------------------------------------------------------
# Prefix sums + variable-segment (EAPCA) statistics
# ---------------------------------------------------------------------------

def prefix_sums(series: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Inclusive-zero prefix sums of values and squares.

    ``series``: (N, n). Returns (P, P2) each (N, n+1) float32 with P[:,0]=0 so
    sum over [a,b) = P[:,b]-P[:,a]. This is the batched analogue of the
    paper's per-series incremental statistics, computed once per build.
    """
    z = jnp.zeros((*series.shape[:-1], 1), dtype=jnp.float32)
    p = jnp.concatenate([z, jnp.cumsum(series.astype(jnp.float32), axis=-1)], axis=-1)
    p2 = jnp.concatenate([z, jnp.cumsum(jnp.square(series.astype(jnp.float32)), axis=-1)], axis=-1)
    return p, p2


def segment_stats_from_prefix(p: jax.Array, p2: jax.Array,
                              endpoints: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-segment (mean, std) from prefix sums under a *per-row* segmentation.

    ``p``, ``p2``: (N, n+1) prefix sums.  ``endpoints``: (N, M) int32 right
    endpoints (padded by repeating n → empty segments).
    Returns (means, stds), each (N, M); empty segments yield 0.
    """
    starts = jnp.concatenate(
        [jnp.zeros((*endpoints.shape[:-1], 1), endpoints.dtype), endpoints[..., :-1]],
        axis=-1)
    lens = (endpoints - starts).astype(jnp.float32)
    safe = jnp.maximum(lens, 1.0)
    s1 = jnp.take_along_axis(p, endpoints, axis=-1) - jnp.take_along_axis(p, starts, axis=-1)
    s2 = jnp.take_along_axis(p2, endpoints, axis=-1) - jnp.take_along_axis(p2, starts, axis=-1)
    mean = s1 / safe
    var = jnp.maximum(s2 / safe - jnp.square(mean), 0.0)
    std = jnp.sqrt(var)
    empty = lens <= 0
    return jnp.where(empty, 0.0, mean), jnp.where(empty, 0.0, std)


def eapca(series: jax.Array, endpoints: jax.Array) -> tuple[jax.Array, jax.Array]:
    """EAPCA summary (per-segment mean and std) of each series.

    ``series``: (N, n); ``endpoints``: (M,) or (N, M) right endpoints.
    Returns (means, stds) each (N, M).
    """
    p, p2 = prefix_sums(series)
    if endpoints.ndim == 1:
        endpoints = jnp.broadcast_to(endpoints, (series.shape[0], endpoints.shape[0]))
    return segment_stats_from_prefix(p, p2, endpoints)


def segment_lengths(endpoints: jax.Array) -> jax.Array:
    """Segment lengths from right endpoints (same padding convention)."""
    starts = jnp.concatenate(
        [jnp.zeros((*endpoints.shape[:-1], 1), endpoints.dtype), endpoints[..., :-1]],
        axis=-1)
    return (endpoints - starts).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Node synopsis (paper §3.2): per-segment [mu_min, mu_max, sd_min, sd_max]
# ---------------------------------------------------------------------------

def synopsis_from_stats(means: jax.Array, stds: jax.Array) -> jax.Array:
    """Synopsis of a *set* of series sharing one segmentation.

    ``means``/``stds``: (N, M). Returns (M, 4) = [mu_min, mu_max, sd_min, sd_max].
    """
    return jnp.stack([
        jnp.min(means, axis=0), jnp.max(means, axis=0),
        jnp.min(stds, axis=0), jnp.max(stds, axis=0),
    ], axis=-1)


def merge_synopses(a: jax.Array, b: jax.Array) -> jax.Array:
    """Merge two (M,4) synopses over the same segmentation (H-split parent rule,
    Algorithm 9: parent synopsis derivable entirely from its children)."""
    return jnp.stack([
        jnp.minimum(a[..., 0], b[..., 0]), jnp.maximum(a[..., 1], b[..., 1]),
        jnp.minimum(a[..., 2], b[..., 2]), jnp.maximum(a[..., 3], b[..., 3]),
    ], axis=-1)
