from repro.serve.engine import (  # noqa: F401
    KnnAnswer, KnnServeConfig, KnnServeEngine, ServeConfig, ServeEngine,
    SlotQueue, greedy_sample,
)
