from repro.serve.engine import ServeConfig, ServeEngine, greedy_sample  # noqa: F401
