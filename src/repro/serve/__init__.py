from repro.serve.engine import (  # noqa: F401
    KnnAnswer, KnnFailure, KnnServeConfig, KnnServeEngine, QueueFull,
    ServeConfig, ServeEngine, SlotQueue, greedy_sample,
)
