"""Slot-based continuous-batching serving loops (single host).

Two workload-specific engines share one execution model — a fixed pool of B
slots served by one compiled program per wave, with finished requests freeing
their slot for the next queued request:

* :class:`ServeEngine` — batched LM decode (prefill + per-token decode steps
  over any ModelDef), the production context the dry-run's ``prefill_32k`` /
  ``decode_32k`` cells lower.
* :class:`KnnServeEngine` — batched exact kNN over a
  :class:`repro.core.engine.QueryEngine`: queued queries are drained in
  waves of ``batch_slots``, each wave padded to the slot count so every wave
  hits the engine's compiled-plan cache (one plan for the whole serving
  session).

Both inherit the submit/poll bookkeeping from :class:`SlotQueue`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.sanitize import ThreadAffinity
from repro.models import ModelDef
from repro.models.arch import ArchConfig


class SlotQueue:
    """Request bookkeeping shared by the slot-based engines: monotonically
    increasing request ids, a FIFO of pending payloads, a result map.

    Results are *claimed*: ``poll``/``drain``/``run`` hand each answer out
    exactly once and drop it from the engine, so a long-running serving
    session does not accumulate its whole answer history in memory.

    The queue is lock-free **by contract**: exactly one thread drives
    submit/step/drain/poll. Under ``REPRO_SANITIZE=1`` the contract is
    enforced — the queue binds to the first touching thread and a foreign
    touch raises ``ThreadOwnershipError`` with both stacks (lockdep's
    ownership half). Use :meth:`rebind_owner` for an intentional handoff.
    """

    def __init__(self):
        self._queue: list[dict] = []
        self._results: dict[int, Any] = {}
        self._next_id = 0
        self._served = 0
        self._affinity = ThreadAffinity(type(self).__name__)

    def rebind_owner(self) -> None:
        """Hand the queue to another thread (releases the sanitizer's
        thread binding; the next touch binds the new owner)."""
        self._affinity.rebind()

    def _enqueue(self, payload: dict) -> int:
        self._affinity.check("_enqueue")
        rid = self._next_id
        self._next_id += 1
        payload["id"] = rid
        self._queue.append(payload)
        return rid

    def _take_wave(self, slots: int) -> list[dict]:
        self._affinity.check("_take_wave")
        wave, self._queue = self._queue[:slots], self._queue[slots:]
        return wave

    def _requeue(self, wave: list[dict]) -> None:
        self._affinity.check("_requeue")
        self._queue[:0] = wave

    def _complete(self, rid: int, result) -> None:
        self._affinity.check("_complete")
        self._results[rid] = result
        self._served += 1

    def _collect(self) -> dict[int, Any]:
        self._affinity.check("_collect")
        out, self._results = self._results, {}
        return out

    def pending(self) -> int:
        """Requests submitted but not yet answered."""
        return len(self._queue)

    def poll(self, rid: int):
        """Claim the result for ``rid``: returns it once, then None (also
        None while the request is still queued)."""
        self._affinity.check("poll")
        return self._results.pop(rid, None)


# ---------------------------------------------------------------------------
# LM decode serving
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 4096
    batch_slots: int = 8
    max_new_tokens: int = 64
    eos_token: int = -1            # -1: disabled
    temperature: float = 0.0       # 0 => greedy


def greedy_sample(logits: jax.Array, key=None, temperature: float = 0.0):
    if temperature and temperature > 0.0:
        return jax.random.categorical(key, logits / temperature, axis=-1)
    return jnp.argmax(logits, axis=-1)


class ServeEngine(SlotQueue):
    """Slot-based batch server over any ModelDef."""

    def __init__(self, model: ModelDef, cfg: ArchConfig, params: dict,
                 scfg: ServeConfig):
        super().__init__()
        self.model = model
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, t, cfg, c))

    def submit(self, prompt: np.ndarray, extras: dict | None = None) -> int:
        return self._enqueue({"prompt": np.asarray(prompt),
                              "extras": extras or {}})

    def _prefill_batch(self, requests: list[dict]):
        """Batched prefill over ragged prompts: shorter prompts are
        right-padded with token 0 to the batch max. ``batch["lens"]``
        carries each request's real prompt length so the model projects
        logits at position ``lens[i]-1`` — sampling from the batch-max
        column would read a pad slot for any shorter prompt."""
        b = len(requests)
        lens = np.array([r["prompt"].shape[0] for r in requests], np.int32)
        maxlen = int(lens.max())
        toks = np.zeros((b, maxlen), np.int32)
        for i, r in enumerate(requests):
            toks[i, :r["prompt"].shape[0]] = r["prompt"]
        batch = {"tokens": jnp.asarray(toks)}
        if lens.min() != maxlen:
            batch["lens"] = jnp.asarray(lens)
        for k in requests[0]["extras"]:
            batch[k] = jnp.stack([jnp.asarray(r["extras"][k]) for r in requests])
        cache = self.model.init_cache(self.cfg, b, self.scfg.max_seq)
        logits, cache = self.model.prefill(self.params, batch, self.cfg, cache)
        return logits, cache

    def run(self) -> dict[int, list[int]]:
        """Drain the queue in waves of ``batch_slots``; returns {id: tokens}."""
        scfg = self.scfg
        while self._queue:
            wave = self._take_wave(scfg.batch_slots)
            logits, cache = self._prefill_batch(wave)
            # prefill projects each row's *last real token* (causal attention
            # keeps position lens[i]-1 independent of the pads to its right),
            # so logits[:, -1] is the correct sampling column for every row
            tok = greedy_sample(logits[:, -1], temperature=scfg.temperature)
            out = [[int(t)] for t in np.asarray(tok)]
            live = np.ones(len(wave), bool)
            for _ in range(scfg.max_new_tokens - 1):
                tok2d = tok[:, None].astype(jnp.int32)
                logits, cache = self._decode(self.params, tok2d, cache)
                tok = greedy_sample(logits[:, 0], temperature=scfg.temperature)
                t_np = np.asarray(tok)
                for i in range(len(wave)):
                    if live[i]:
                        out[i].append(int(t_np[i]))
                        if scfg.eos_token >= 0 and t_np[i] == scfg.eos_token:
                            live[i] = False
                if not live.any():
                    break
            for r, o in zip(wave, out):
                self._complete(r["id"], o)
        return self._collect()


# ---------------------------------------------------------------------------
# kNN query serving
# ---------------------------------------------------------------------------

class QueueFull(RuntimeError):
    """Admission control rejected a ``submit``: the pending queue is at
    ``KnnServeConfig.max_queue``. The backpressure signal — callers should
    serve a wave (``step``) or drain before resubmitting."""


@dataclasses.dataclass(frozen=True)
class KnnServeConfig:
    batch_slots: int = 32          # queries per wave (the slot pool)
    k: int | None = None           # None -> the backend's configured k
    wave: bool = False             # serve waves through the fused wave path
    max_queue: int | None = None   # admission bound; None = unbounded
    pack: str = "fifo"             # wave packing: "fifo" | "difficulty"

    def __post_init__(self):
        if not isinstance(self.batch_slots, int) or self.batch_slots < 1:
            raise ValueError(f"batch_slots={self.batch_slots!r}; "
                             "expected an int >= 1")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"max_queue={self.max_queue!r}; expected None "
                             "or an int >= 1")
        if self.pack not in ("fifo", "difficulty"):
            raise ValueError(f"pack={self.pack!r}; expected 'fifo' or "
                             "'difficulty'")


class KnnAnswer(NamedTuple):
    dists: np.ndarray              # (k,) squared ED, ascending
    ids: np.ndarray                # (k,) series ids
    path: int                      # access path taken (-1 when unknown)


class KnnFailure(NamedTuple):
    """Claimable per-request failure (``poll``/``drain`` hand it out like
    an answer): the request was invalid or the engine rejected it, and the
    rest of its wave was served normally."""
    error: str                     # "ExceptionType: message"


class KnnServeEngine(SlotQueue):
    """Continuous-batching front end for a :class:`QueryEngine`.

    ``submit`` enqueues one query series and returns a request id; ``step``
    serves one wave of up to ``batch_slots`` *compatible* queued queries
    through the engine (the wave is padded to the slot count, so a
    long-running session compiles exactly one plan per (k, slot-count));
    ``drain`` steps until the queue is empty and returns every completed
    answer.

    Mixed traffic: requests are grouped into compatible sub-waves by their
    ``(k, overrides)`` signature — the head request's signature selects each
    wave, so interleaved k=1/k=10 submits serve in submission order, one
    signature per step, instead of erroring. A request that still fails solo
    (wrong series length, bad override) completes as a claimable
    :class:`KnnFailure` and never blocks the traffic behind it.

    QoS knobs (:class:`KnnServeConfig`): ``wave=True`` routes each wave
    through the engine's fused wave plan (shared descent/BSF/disk fetches);
    ``max_queue`` bounds the pending queue, rejecting further submits with
    :class:`QueueFull` (the backpressure signal); ``pack="difficulty"``
    packs each wave with the compatible peers closest in predicted cost to
    the oldest request (``QueryEngine.estimate_difficulty``), so cheap
    queries are not latency-coupled to expensive wave-mates — while the
    oldest request always ships first, which is the anti-starvation
    guarantee.
    """

    def __init__(self, engine, cfg: KnnServeConfig | None = None):
        super().__init__()
        self.engine = engine
        self.cfg = cfg or KnnServeConfig()
        self._rejected = 0
        self._failed = 0
        self._waves = 0
        self._scored = 0
        self._score_sum = 0.0

    def submit(self, query: np.ndarray, k: int | None = None,
               **overrides: Any) -> int:
        q = np.asarray(query)
        if q.ndim != 1:
            raise ValueError(f"submit() takes one query series, got {q.shape}")
        if (self.cfg.max_queue is not None
                and len(self._queue) >= self.cfg.max_queue):
            self._rejected += 1
            raise QueueFull(f"pending queue at max_queue="
                            f"{self.cfg.max_queue}; step() or drain() first")
        return self._enqueue({"q": q, "k": k, "ov": overrides, "score": None})

    @staticmethod
    def _sig(r: dict) -> tuple:
        """Compatibility signature: requests sharing it can ride one wave
        (one compiled plan, one SearchConfig)."""
        return (r["k"], tuple(sorted(r["ov"].items())))

    def _score(self, reqs: list[dict]) -> None:
        """Attach a predicted-cost score to each unscored request (cached on
        the payload — a request is scored at most once per lifetime)."""
        todo = [r for r in reqs if r["score"] is None]
        if not todo:
            return
        try:
            scores = self.engine.estimate_difficulty(
                np.stack([r["q"] for r in todo]))
        except Exception:   # ragged/invalid queries surface at serve time
            scores = None
        if scores is None:
            for r in todo:
                r["score"] = 0.0
            return
        for r, s in zip(todo, np.asarray(scores)):
            r["score"] = float(s)
            self._score_sum += float(s)
            self._scored += 1

    def _next_wave(self) -> list[dict]:
        """Up to ``batch_slots`` compatible requests. The head (oldest)
        request's signature selects the sub-wave; with ``pack="difficulty"``
        it is joined by the compatible peers closest to its predicted cost
        instead of strict FIFO order."""
        if not self._queue:
            return []
        head = self._queue[0]
        sig = self._sig(head)
        compat = [r for r in self._queue if self._sig(r) == sig]
        if self.cfg.pack == "difficulty" and len(compat) > self.cfg.batch_slots:
            self._score(compat)
            peers = sorted(compat[1:],
                           key=lambda r: abs(r["score"] - head["score"]))
            wave = [head] + peers[:self.cfg.batch_slots - 1]
        else:
            wave = compat[:self.cfg.batch_slots]
        taken = {id(r) for r in wave}
        self._queue = [r for r in self._queue if id(r) not in taken]
        return wave

    def step(self) -> int:
        """Serve one compatible sub-wave; returns the number of requests
        answered (failures included — each completes as a claimable
        :class:`KnnFailure`). Never livelocks: every selected request
        leaves the queue with a result, success or not."""
        wave = self._next_wave()
        if not wave:
            return 0
        try:
            self._serve(wave)
        except Exception:
            # head-of-line isolation: one bad request (wrong length, bad
            # override) must not poison its wave-mates — serve each member
            # solo, completing the ones that still fail as failures
            for r in wave:
                try:
                    self._serve([r])
                except Exception as e:
                    self._failed += 1
                    self._complete(r["id"],
                                   KnnFailure(f"{type(e).__name__}: {e}"))
        self._waves += 1
        return len(wave)

    def _serve(self, wave: list[dict]) -> None:
        slots = self.cfg.batch_slots
        k = wave[0]["k"] if wave[0]["k"] is not None else self.cfg.k
        ov = wave[0]["ov"]
        q = np.stack([r["q"] for r in wave])
        if len(wave) < slots:  # pad the partial wave to the slot pool
            q = np.concatenate(
                [q, np.zeros((slots - len(wave), q.shape[1]), q.dtype)])
        res = self.engine.knn(jnp.asarray(q), k=k, valid_rows=len(wave),
                              wave=self.cfg.wave, **ov)
        dists = np.asarray(res.dists)
        ids = np.asarray(res.ids)
        paths = np.asarray(res.path)
        for i, r in enumerate(wave):
            self._complete(r["id"], KnnAnswer(
                dists=dists[i], ids=ids[i], path=int(paths[i])))

    def drain(self) -> dict[int, KnnAnswer | KnnFailure]:
        """Serve until the queue is empty; returns (and claims) every
        unclaimed completed answer (failed requests as KnnFailure)."""
        while self.step():
            pass
        return self._collect()

    def telemetry(self):
        """The engine's :class:`repro.core.engine.Telemetry` with the
        ``serving`` section filled in."""
        t = self.engine.telemetry()
        t["serving"] = {"pending": self.pending(),
                        "served": self._served,
                        "unclaimed": len(self._results),
                        "batch_slots": self.cfg.batch_slots,
                        "waves": self._waves,
                        "wave_mode": self.cfg.wave,
                        "pack": self.cfg.pack,
                        "max_queue": self.cfg.max_queue,
                        "rejected": self._rejected,
                        "failed": self._failed,
                        "difficulty_scored": self._scored,
                        "difficulty_mean": (self._score_sum
                                            / max(self._scored, 1))}
        return t
