"""Slot-based continuous-batching serving loops (single host).

Two workload-specific engines share one execution model — a fixed pool of B
slots served by one compiled program per wave, with finished requests freeing
their slot for the next queued request:

* :class:`ServeEngine` — batched LM decode (prefill + per-token decode steps
  over any ModelDef), the production context the dry-run's ``prefill_32k`` /
  ``decode_32k`` cells lower.
* :class:`KnnServeEngine` — batched exact kNN over a
  :class:`repro.core.engine.QueryEngine`: queued queries are drained in
  waves of ``batch_slots``, each wave padded to the slot count so every wave
  hits the engine's compiled-plan cache (one plan for the whole serving
  session).

Both inherit the submit/poll bookkeeping from :class:`SlotQueue`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelDef
from repro.models.arch import ArchConfig


class SlotQueue:
    """Request bookkeeping shared by the slot-based engines: monotonically
    increasing request ids, a FIFO of pending payloads, a result map.

    Results are *claimed*: ``poll``/``drain``/``run`` hand each answer out
    exactly once and drop it from the engine, so a long-running serving
    session does not accumulate its whole answer history in memory."""

    def __init__(self):
        self._queue: list[dict] = []
        self._results: dict[int, Any] = {}
        self._next_id = 0
        self._served = 0

    def _enqueue(self, payload: dict) -> int:
        rid = self._next_id
        self._next_id += 1
        payload["id"] = rid
        self._queue.append(payload)
        return rid

    def _take_wave(self, slots: int) -> list[dict]:
        wave, self._queue = self._queue[:slots], self._queue[slots:]
        return wave

    def _requeue(self, wave: list[dict]) -> None:
        self._queue[:0] = wave

    def _complete(self, rid: int, result) -> None:
        self._results[rid] = result
        self._served += 1

    def _collect(self) -> dict[int, Any]:
        out, self._results = self._results, {}
        return out

    def pending(self) -> int:
        """Requests submitted but not yet answered."""
        return len(self._queue)

    def poll(self, rid: int):
        """Claim the result for ``rid``: returns it once, then None (also
        None while the request is still queued)."""
        return self._results.pop(rid, None)


# ---------------------------------------------------------------------------
# LM decode serving
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 4096
    batch_slots: int = 8
    max_new_tokens: int = 64
    eos_token: int = -1            # -1: disabled
    temperature: float = 0.0       # 0 => greedy


def greedy_sample(logits: jax.Array, key=None, temperature: float = 0.0):
    if temperature and temperature > 0.0:
        return jax.random.categorical(key, logits / temperature, axis=-1)
    return jnp.argmax(logits, axis=-1)


class ServeEngine(SlotQueue):
    """Slot-based batch server over any ModelDef."""

    def __init__(self, model: ModelDef, cfg: ArchConfig, params: dict,
                 scfg: ServeConfig):
        super().__init__()
        self.model = model
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, t, cfg, c))

    def submit(self, prompt: np.ndarray, extras: dict | None = None) -> int:
        return self._enqueue({"prompt": np.asarray(prompt),
                              "extras": extras or {}})

    def _prefill_batch(self, requests: list[dict]):
        """Left-pad-free batched prefill: all prompts padded to max length
        with per-request loss of left context avoided by right-aligning is
        unnecessary for greedy decoding benchmarks — prompts here are
        equal-length by construction of the drivers; ragged support pads with
        token 0 and masks in sampling (position bookkeeping via cache.pos)."""
        b = len(requests)
        maxlen = max(r["prompt"].shape[0] for r in requests)
        toks = np.zeros((b, maxlen), np.int32)
        for i, r in enumerate(requests):
            toks[i, :r["prompt"].shape[0]] = r["prompt"]
        batch = {"tokens": jnp.asarray(toks)}
        for k in requests[0]["extras"]:
            batch[k] = jnp.stack([jnp.asarray(r["extras"][k]) for r in requests])
        cache = self.model.init_cache(self.cfg, b, self.scfg.max_seq)
        logits, cache = self.model.prefill(self.params, batch, self.cfg, cache)
        return logits, cache

    def run(self) -> dict[int, list[int]]:
        """Drain the queue in waves of ``batch_slots``; returns {id: tokens}."""
        scfg = self.scfg
        while self._queue:
            wave = self._take_wave(scfg.batch_slots)
            logits, cache = self._prefill_batch(wave)
            tok = greedy_sample(logits[:, -1], temperature=scfg.temperature)
            out = [[int(t)] for t in np.asarray(tok)]
            live = np.ones(len(wave), bool)
            for _ in range(scfg.max_new_tokens - 1):
                tok2d = tok[:, None].astype(jnp.int32)
                logits, cache = self._decode(self.params, tok2d, cache)
                tok = greedy_sample(logits[:, 0], temperature=scfg.temperature)
                t_np = np.asarray(tok)
                for i in range(len(wave)):
                    if live[i]:
                        out[i].append(int(t_np[i]))
                        if scfg.eos_token >= 0 and t_np[i] == scfg.eos_token:
                            live[i] = False
                if not live.any():
                    break
            for r, o in zip(wave, out):
                self._complete(r["id"], o)
        return self._collect()


# ---------------------------------------------------------------------------
# kNN query serving
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KnnServeConfig:
    batch_slots: int = 32          # queries per wave (the slot pool)
    k: int | None = None           # None -> the backend's configured k


class KnnAnswer(NamedTuple):
    dists: np.ndarray              # (k,) squared ED, ascending
    ids: np.ndarray                # (k,) series ids
    path: int                      # access path taken (-1 when unknown)


class KnnServeEngine(SlotQueue):
    """Continuous-batching front end for a :class:`QueryEngine`.

    ``submit`` enqueues one query series and returns a request id; ``step``
    serves one wave of up to ``batch_slots`` queued queries through the
    engine (the wave is padded to the slot count, so a long-running session
    compiles exactly one plan per (k, slot-count)); ``drain`` steps until
    the queue is empty and returns every completed answer.
    """

    def __init__(self, engine, cfg: KnnServeConfig | None = None):
        super().__init__()
        self.engine = engine
        self.cfg = cfg or KnnServeConfig()

    def submit(self, query: np.ndarray, k: int | None = None,
               **overrides: Any) -> int:
        q = np.asarray(query)
        if q.ndim != 1:
            raise ValueError(f"submit() takes one query series, got {q.shape}")
        return self._enqueue({"q": q, "k": k, "ov": overrides})

    def step(self) -> int:
        """Serve one wave; returns the number of requests answered. A wave
        that fails (mixed configs, bad override, wrong query length) is put
        back on the queue before the error propagates — no request is lost."""
        slots = self.cfg.batch_slots
        wave = self._take_wave(slots)
        if not wave:
            return 0
        try:
            # per-request k/overrides are grouped per wave: requests in one
            # wave must agree (the common case is a uniform serving config)
            k = wave[0]["k"] if wave[0]["k"] is not None else self.cfg.k
            ov = wave[0]["ov"]
            if any(r["k"] != wave[0]["k"] or r["ov"] != ov for r in wave[1:]):
                raise ValueError("mixed k/overrides within one wave; "
                                 "submit uniform waves or use separate engines")
            q = np.stack([r["q"] for r in wave])
            if len(wave) < slots:  # pad the partial tail wave to the slot pool
                q = np.concatenate(
                    [q, np.zeros((slots - len(wave), q.shape[1]), q.dtype)])
            res = self.engine.knn(jnp.asarray(q), k=k,
                                  valid_rows=len(wave), **ov)
        except Exception:
            self._requeue(wave)
            raise
        dists = np.asarray(res.dists)
        ids = np.asarray(res.ids)
        paths = np.asarray(res.path)
        for i, r in enumerate(wave):
            self._complete(r["id"], KnnAnswer(
                dists=dists[i], ids=ids[i], path=int(paths[i])))
        return len(wave)

    def drain(self) -> dict[int, KnnAnswer]:
        """Serve until the queue is empty; returns (and claims) every
        unclaimed completed answer."""
        while self.step():
            pass
        return self._collect()

    def telemetry(self) -> dict:
        t = self.engine.telemetry()
        t["serving"] = {"pending": self.pending(),
                        "served": self._served,
                        "unclaimed": len(self._results),
                        "batch_slots": self.cfg.batch_slots}
        return t
