"""Batched decode serving loop (slot-based continuous batching, single host).

The production context the dry-run's ``prefill_32k``/``decode_32k`` cells
lower: a fixed pool of B slots, each holding one request's cache region;
finished requests free their slot for the next queued request. All slots
share one jitted decode step (the cache is batched), so throughput is one
model step per token across the whole batch — the standard continuous-
batching execution model reduced to its JAX-native core.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelDef
from repro.models.arch import ArchConfig


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 4096
    batch_slots: int = 8
    max_new_tokens: int = 64
    eos_token: int = -1            # -1: disabled
    temperature: float = 0.0       # 0 => greedy


def greedy_sample(logits: jax.Array, key=None, temperature: float = 0.0):
    if temperature and temperature > 0.0:
        return jax.random.categorical(key, logits / temperature, axis=-1)
    return jnp.argmax(logits, axis=-1)


class ServeEngine:
    """Slot-based batch server over any ModelDef."""

    def __init__(self, model: ModelDef, cfg: ArchConfig, params: dict,
                 scfg: ServeConfig):
        self.model = model
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, t, cfg, c))
        self._queue: list[dict] = []
        self._results: dict[int, list[int]] = {}
        self._next_id = 0

    def submit(self, prompt: np.ndarray, extras: dict | None = None) -> int:
        rid = self._next_id
        self._next_id += 1
        self._queue.append({"id": rid, "prompt": np.asarray(prompt),
                            "extras": extras or {}})
        return rid

    def _prefill_batch(self, requests: list[dict]):
        """Left-pad-free batched prefill: all prompts padded to max length
        with per-request loss of left context avoided by right-aligning is
        unnecessary for greedy decoding benchmarks — prompts here are
        equal-length by construction of the drivers; ragged support pads with
        token 0 and masks in sampling (position bookkeeping via cache.pos)."""
        b = len(requests)
        maxlen = max(r["prompt"].shape[0] for r in requests)
        toks = np.zeros((b, maxlen), np.int32)
        for i, r in enumerate(requests):
            toks[i, :r["prompt"].shape[0]] = r["prompt"]
        batch = {"tokens": jnp.asarray(toks)}
        for k in requests[0]["extras"]:
            batch[k] = jnp.stack([jnp.asarray(r["extras"][k]) for r in requests])
        cache = self.model.init_cache(self.cfg, b, self.scfg.max_seq)
        logits, cache = self.model.prefill(self.params, batch, self.cfg, cache)
        return logits, cache

    def run(self) -> dict[int, list[int]]:
        """Drain the queue in waves of ``batch_slots``; returns {id: tokens}."""
        scfg = self.scfg
        while self._queue:
            wave = self._queue[: scfg.batch_slots]
            self._queue = self._queue[scfg.batch_slots:]
            logits, cache = self._prefill_batch(wave)
            tok = greedy_sample(logits[:, -1], temperature=scfg.temperature)
            out = [[int(t)] for t in np.asarray(tok)]
            live = np.ones(len(wave), bool)
            for _ in range(scfg.max_new_tokens - 1):
                tok2d = tok[:, None].astype(jnp.int32)
                logits, cache = self._decode(self.params, tok2d, cache)
                tok = greedy_sample(logits[:, 0], temperature=scfg.temperature)
                t_np = np.asarray(tok)
                for i in range(len(wave)):
                    if live[i]:
                        out[i].append(int(t_np[i]))
                        if scfg.eos_token >= 0 and t_np[i] == scfg.eos_token:
                            live[i] = False
                if not live.any():
                    break
            for r, o in zip(wave, out):
                self._results[r["id"]] = o
        return dict(self._results)
