"""granite-34b: dense 88L code model, MQA (kv=1).

Source: arXiv:2405.04324 [hf]
"""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense",
    num_layers=88, d_model=6144, d_ff=24576, vocab_size=49152,
    num_heads=48, num_kv_heads=1, mlp_type="gelu",   # GPTBigCode 2-mat MLP
    source="arXiv:2405.04324",
)

SMOKE = ArchConfig(
    name="granite-34b-smoke", family="dense",
    num_layers=3, d_model=64, d_ff=128, vocab_size=256,
    num_heads=4, num_kv_heads=1, mlp_type="gelu",
    dtype="float32", remat=False,
)
