"""whisper-large-v3: enc-dec, conv frontend STUB (frame embeddings supplied).

Source: arXiv:2212.04356 [unverified]
32 encoder + 32 decoder layers, d=1280, 20 heads, MHA.
"""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    num_layers=32, d_model=1280, d_ff=5120, vocab_size=51866,
    num_heads=20, num_kv_heads=20,
    encoder_layers=32, num_frames=1500,
    source="arXiv:2212.04356",
)

SMOKE = ArchConfig(
    name="whisper-large-v3-smoke", family="audio",
    num_layers=2, d_model=64, d_ff=128, vocab_size=256,
    num_heads=4, num_kv_heads=4,
    encoder_layers=2, num_frames=16,
    dtype="float32", remat=False,
)
