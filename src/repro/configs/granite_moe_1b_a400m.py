"""granite-moe-1b-a400m: 24L MoE, 32 experts top-8, GQA kv=8.

Source: hf:ibm-granite/granite-3.0-1b-a400m-base [hf]
"""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, d_ff=512, vocab_size=49155,
    num_heads=16, num_kv_heads=8,
    num_experts=32, experts_per_token=8,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

SMOKE = ArchConfig(
    name="granite-moe-1b-a400m-smoke", family="moe",
    num_layers=2, d_model=64, d_ff=32, vocab_size=256,
    num_heads=4, num_kv_heads=2,
    num_experts=4, experts_per_token=2, capacity_factor=8.0,
    dtype="float32", remat=False,
)
