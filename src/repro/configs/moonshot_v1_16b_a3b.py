"""moonshot-v1-16b-a3b (Moonlight): 48L MoE, 64 experts top-6, MHA kv=16.

Source: hf:moonshotai/Moonlight-16B-A3B [hf]
(Deviation noted in DESIGN.md: Moonlight's single dense first layer is
modeled as MoE like the rest so layers stay scan-homogeneous.)
"""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, d_ff=1408, vocab_size=163840,
    num_heads=16, num_kv_heads=16,
    num_experts=64, experts_per_token=6,
    source="hf:moonshotai/Moonlight-16B-A3B",
)

SMOKE = ArchConfig(
    name="moonshot-v1-16b-a3b-smoke", family="moe",
    num_layers=2, d_model=64, d_ff=48, vocab_size=256,
    num_heads=4, num_kv_heads=4,
    num_experts=8, experts_per_token=2, capacity_factor=8.0,
    dtype="float32", remat=False,
)
