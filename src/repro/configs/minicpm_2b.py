"""minicpm-2b: dense 40L, MHA (kv=36), WSD schedule (arch llama-like).

Source: arXiv:2404.06395 [hf]
"""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, d_ff=5760, vocab_size=122753,
    num_heads=36, num_kv_heads=36,
    source="arXiv:2404.06395",
)

SMOKE = ArchConfig(
    name="minicpm-2b-smoke", family="dense",
    num_layers=2, d_model=72, d_ff=144, vocab_size=256,
    num_heads=4, num_kv_heads=4,
    dtype="float32", remat=False,
)
