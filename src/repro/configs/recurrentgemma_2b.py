"""recurrentgemma-2b (Griffin): RG-LRU + local attention 1:2.

Source: arXiv:2402.19427 [hf]
26L, pattern (rec, rec, attn), window 2048, MQA kv=1; runs long_500k.
"""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, d_ff=7680, vocab_size=256000,
    num_heads=10, num_kv_heads=1, head_dim=256,
    window=2048, block_pattern=("rec", "rec", "attn"),
    d_rnn=2560, conv_width=4,
    scan_layers=False,
    source="arXiv:2402.19427",
)

SMOKE = ArchConfig(
    name="recurrentgemma-2b-smoke", family="hybrid",
    num_layers=3, d_model=64, d_ff=128, vocab_size=256,
    num_heads=4, num_kv_heads=1, head_dim=16,
    window=16, block_pattern=("rec", "rec", "attn"),
    d_rnn=64, conv_width=4,
    scan_layers=False, dtype="float32", remat=False,
)
