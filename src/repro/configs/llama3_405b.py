"""llama3-405b: dense 126L, GQA kv=8, 128k vocab.

Source: arXiv:2407.21783 [unverified]
"""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b", family="dense",
    num_layers=126, d_model=16384, d_ff=53248, vocab_size=128256,
    num_heads=128, num_kv_heads=8, rope_theta=500000.0,
    param_dtype="bfloat16",   # §Perf iter 3: halves FSDP gather + grad bytes
    source="arXiv:2407.21783",
)

SMOKE = ArchConfig(
    name="llama3-405b-smoke", family="dense",
    num_layers=2, d_model=64, d_ff=192, vocab_size=256,
    num_heads=8, num_kv_heads=2, rope_theta=500000.0,
    dtype="float32", remat=False,
)
