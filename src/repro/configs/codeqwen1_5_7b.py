"""codeqwen1.5-7b: dense 32L, MHA (kv=32), qwen1.5 arch.

Source: hf:Qwen/CodeQwen1.5-7B [hf]
"""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b", family="dense",
    num_layers=32, d_model=4096, d_ff=13440, vocab_size=92416,
    num_heads=32, num_kv_heads=32,
    source="hf:Qwen/CodeQwen1.5-7B",
)

SMOKE = ArchConfig(
    name="codeqwen1.5-7b-smoke", family="dense",
    num_layers=2, d_model=64, d_ff=128, vocab_size=256,
    num_heads=4, num_kv_heads=4,
    dtype="float32", remat=False,
)
