"""phi-3-vision-4.2b: phi3-mini backbone + CLIP stub frontend.

Source: hf:microsoft/Phi-3-vision-128k-instruct [hf]
The vision tower is a STUB per assignment: input_specs() provides
precomputed patch embeddings (B, 576, 1024); only the projector and the
language backbone are real compute.
"""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    num_layers=32, d_model=3072, d_ff=8192, vocab_size=32064,
    num_heads=32, num_kv_heads=32,
    num_patches=576, d_patch=1024,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)

SMOKE = ArchConfig(
    name="phi-3-vision-4.2b-smoke", family="vlm",
    num_layers=2, d_model=64, d_ff=128, vocab_size=256,
    num_heads=4, num_kv_heads=4,
    num_patches=8, d_patch=32,
    dtype="float32", remat=False,
)
