"""Architecture config registry: ``--arch <id>`` resolution.

Each module defines CONFIG (the exact assigned architecture) and SMOKE (a
reduced same-family config for CPU tests). The dry-run exercises CONFIG via
ShapeDtypeStructs only; SMOKE actually runs.
"""
from __future__ import annotations

import importlib

from repro.models.arch import ArchConfig

_MODULES = {
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "granite-34b": "granite_34b",
    "llama3-405b": "llama3_405b",
    "minicpm-2b": "minicpm_2b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "whisper-large-v3": "whisper_large_v3",
    "rwkv6-7b": "rwkv6_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
}

ARCH_NAMES = tuple(_MODULES)


def _load(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ArchConfig:
    return _load(name).CONFIG


def get_smoke(name: str) -> ArchConfig:
    return _load(name).SMOKE


def all_configs() -> dict[str, ArchConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}
