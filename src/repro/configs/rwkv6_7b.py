"""rwkv6-7b (Finch): attention-free, data-dependent decay.

Source: arXiv:2404.05892 [hf]
d=4096, head size 64 -> 64 wkv heads; O(1) decode state (runs long_500k).
"""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, d_ff=14336, vocab_size=65536,
    rwkv_head_size=64,
    source="arXiv:2404.05892",
)

SMOKE = ArchConfig(
    name="rwkv6-7b-smoke", family="ssm",
    num_layers=2, d_model=64, d_ff=128, vocab_size=256,
    rwkv_head_size=16,
    dtype="float32", remat=False,
)
