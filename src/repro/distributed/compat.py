"""Version-compat shims for jax APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace, and its replication-check kwarg was renamed
``check_rep`` -> ``check_vma`` along the way. Callers in this repo use the
modern spelling (``from repro.distributed.compat import shard_map`` with
``check_vma=``); the shim translates for whichever jax is installed.
"""
from __future__ import annotations

try:  # jax >= 0.6: top-level export, kwarg is check_vma
    from jax import shard_map as _shard_map
    _CHECK_KW = "check_vma"
except ImportError:  # older jax: experimental module, kwarg is check_rep
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
    if check_vma is not None:
        kwargs[_CHECK_KW] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def make_mesh(axis_shapes, axis_names, **kwargs):
    """``jax.make_mesh`` with ``axis_types`` dropped on jax builds that
    predate explicit axis types (everything is Auto there anyway)."""
    import inspect

    import jax

    if "axis_types" in kwargs and \
            "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        kwargs.pop("axis_types")
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def auto_axis_types(n: int):
    """``(AxisType.Auto,) * n`` when the installed jax has axis types, else
    ``None`` (to be passed through :func:`make_mesh`, which drops it)."""
    import jax

    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n
