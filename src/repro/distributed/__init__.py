from repro.distributed.pipeline import pipeline_forward, split_stages  # noqa: F401
from repro.distributed.sharding import (  # noqa: F401
    batch_sharding, cache_sharding, install_activation_hook, param_sharding,
    shard_params_tree,
)
from repro.distributed.ooc import DistOutOfCoreBackend  # noqa: F401
from repro.distributed.search import (  # noqa: F401
    StackedIndex, build_distributed_index, distributed_knn,
)
