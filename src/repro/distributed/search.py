"""Distributed Hercules search: series-sharded local indexes + top-k merge.

The paper is single-node (§2 excludes TARDIS/DPiSAX); this layer is the
beyond-paper scaling story (DESIGN.md §2): the collection is split into one
contiguous range per device, each device builds its own Hercules index over
its shard (embarrassingly parallel — the paper's InsertWorkers become
devices), and a query answers as:

    local exact top-k on every shard  ->  all_gather((k,) per shard)
    ->  merge to global exact top-k        [O(devices * k) floats on ICI]

Exactness: the global kNN set is the k smallest of the union of per-shard
exact kNN sets (each shard returns its k best, and any global top-k member is
within the top-k of its own shard). The collective term is tiny by
construction — this search is compute/memory bound at any scale, which is
what EXPERIMENTS.md §Roofline shows for the hercules rows.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.compat import shard_map

from repro.core.index import HerculesIndex, IndexConfig
from repro.core.layout import HerculesLayout
from repro.core.search import SearchConfig, _query_one
from repro.core.tree import HerculesTree


@dataclasses.dataclass
class StackedIndex:
    """D per-shard indexes stacked leaf-wise (leading shard dim on arrays)."""
    tree: HerculesTree              # arrays (D, ...)
    layout: HerculesLayout          # arrays (D, ...); static fields unified
    shard_offsets: jax.Array        # (D,) global id offset per shard
    max_depth: int
    config: IndexConfig
    num_shards: int


def build_distributed_index(data: jax.Array, num_shards: int,
                            config: IndexConfig | None = None) -> StackedIndex:
    """Split ``data`` into contiguous shards and build one index per shard.

    Host-driven (builds are independent); static metadata (padded leaf count,
    max leaf extent, padded series count) is unified across shards so one
    compiled search program serves every shard under shard_map.
    """
    config = config or IndexConfig()
    n = data.shape[0]
    if n % num_shards:
        raise ValueError(f"{n} series not divisible into {num_shards} shards")
    per = n // num_shards
    sub = [HerculesIndex.build(data[i * per:(i + 1) * per], config)
           for i in range(num_shards)]

    # unify static shapes
    max_nodes = max(s.tree.max_nodes for s in sub)
    L = max(s.layout.leaf_start.shape[0] for s in sub)
    n_pad = max(s.layout.lrd.shape[0] for s in sub)
    max_leaf = max(s.layout.max_leaf for s in sub)
    max_depth = max(s.max_depth for s in sub)

    def pad_to(arr, target_rows, fill=0):
        pad = target_rows - arr.shape[0]
        if pad <= 0:
            return arr
        padding = jnp.full((pad, *arr.shape[1:]), fill, arr.dtype)
        return jnp.concatenate([arr, padding], axis=0)

    trees = []
    layouts = []
    for s in sub:
        t = s.tree
        trees.append(HerculesTree(*[
            pad_to(getattr(t, f), max_nodes) if getattr(t, f).ndim else getattr(t, f)
            for f in HerculesTree._fields]))
        l = s.layout
        layouts.append(HerculesLayout(
            lrd=pad_to(l.lrd, n_pad), lsd=pad_to(l.lsd, n_pad),
            perm=pad_to(l.perm, n_pad, fill=-1),
            inv_perm=pad_to(l.inv_perm, n_pad, fill=-1),
            leaf_rank=pad_to(l.leaf_rank, max_nodes, fill=-1),
            leaf_node=pad_to(l.leaf_node, L),
            leaf_start=pad_to(l.leaf_start, L, fill=l.num_series),
            leaf_count=pad_to(l.leaf_count, L, fill=0),
            leaf_synopsis=pad_to(l.leaf_synopsis, L),
            leaf_endpoints=pad_to(l.leaf_endpoints, L),
            leaf_seg_lens=pad_to(l.leaf_seg_lens, L),
            series_leaf_rank=pad_to(l.series_leaf_rank, n_pad, fill=L),
            series_len=l.series_len, max_leaf=max_leaf,
            num_leaves=l.num_leaves, num_series=per))

    tree = HerculesTree(*[jnp.stack([getattr(t, f) for t in trees])
                          for f in HerculesTree._fields])
    lay0 = layouts[0]
    layout = HerculesLayout(
        **{f: jnp.stack([getattr(l, f) for l in layouts])
           for f in ("lrd", "lsd", "perm", "inv_perm", "leaf_rank", "leaf_node",
                     "leaf_start", "leaf_count", "leaf_synopsis",
                     "leaf_endpoints", "leaf_seg_lens", "series_leaf_rank")},
        series_len=lay0.series_len, max_leaf=max_leaf,
        num_leaves=L, num_series=per)
    offsets = jnp.arange(num_shards, dtype=jnp.int32) * per
    return StackedIndex(tree=tree, layout=layout, shard_offsets=offsets,
                        max_depth=max_depth, config=config,
                        num_shards=num_shards)


def _unstack(tree_or_layout, cls):
    """Strip the leading shard dim (size 1 inside each shard_map program)."""
    if cls is HerculesTree:
        return HerculesTree(*[getattr(tree_or_layout, f)[0]
                              for f in HerculesTree._fields])
    kw = {f: getattr(tree_or_layout, f)[0]
          for f in ("lrd", "lsd", "perm", "inv_perm", "leaf_rank", "leaf_node",
                    "leaf_start", "leaf_count", "leaf_synopsis",
                    "leaf_endpoints", "leaf_seg_lens", "series_leaf_rank")}
    for f in ("series_len", "max_leaf", "num_leaves", "num_series"):
        kw[f] = getattr(tree_or_layout, f)
    return HerculesLayout(**kw)


def make_distributed_search(mesh: Mesh, cfg: SearchConfig, max_depth: int,
                            tree_template, layout_template):
    """Build the jitted shard_map search program (also lowered by the
    dry-run with ShapeDtypeStruct templates)."""
    axes = tuple(mesh.axis_names)
    shard_spec = P(axes)
    repl = P()
    tree_specs = jax.tree.map(lambda _: shard_spec, tree_template)
    lay_specs = jax.tree.map(lambda _: shard_spec, layout_template)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(tree_specs, lay_specs, shard_spec, repl),
        out_specs=(repl, repl),
        check_vma=False)
    def run(tree_s, lay_s, offset, q):
        tree = _unstack(tree_s, HerculesTree)
        layout = _unstack(lay_s, HerculesLayout)

        def one(qi):
            d, p, *_ = _query_one(qi, tree, layout, cfg, max_depth)
            safe = jnp.clip(p, 0, layout.perm.shape[0] - 1)
            gid = jnp.where(p >= 0, layout.perm[safe] + offset[0], -1)
            return d, gid

        d, gid = jax.lax.map(one, q)                   # (Q, k) local
        all_d = jax.lax.all_gather(d, axes, axis=0, tiled=False)
        all_g = jax.lax.all_gather(gid, axes, axis=0, tiled=False)
        # all_gather over multiple axes stacks per axis: flatten to (D, Q, k)
        all_d = all_d.reshape(-1, *d.shape)
        all_g = all_g.reshape(-1, *gid.shape)
        dd = jnp.moveaxis(all_d, 0, 1).reshape(q.shape[0], -1)
        gg = jnp.moveaxis(all_g, 0, 1).reshape(q.shape[0], -1)
        neg, idx = jax.lax.top_k(-dd, cfg.k)
        return -neg, jnp.take_along_axis(gg, idx, axis=1)

    return jax.jit(run)


def distributed_knn(index: StackedIndex, queries: jax.Array, mesh: Mesh,
                    cfg: SearchConfig | None = None):
    """Exact global kNN under ``mesh``. All mesh axes shard the series dim.

    Returns (dists (Q, k), global ids (Q, k)).
    """
    cfg = cfg or index.config.search
    axes = tuple(mesh.axis_names)
    ndev = int(np.prod([mesh.shape[a] for a in axes]))
    if index.num_shards != ndev:
        raise ValueError(f"index has {index.num_shards} shards, mesh {ndev} devices")
    run = make_distributed_search(mesh, cfg, index.max_depth,
                                  index.tree, index.layout)
    return run(index.tree, index.layout,
               index.shard_offsets.reshape(ndev, 1), queries)
