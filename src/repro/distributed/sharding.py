"""Sharding rules: DP / FSDP / TP / EP / SP over the production mesh.

Parameters get 2-D shardings (Megatron-style TP on the contraction-adjacent
dim + ZeRO-3/FSDP on the other), experts shard on the model axis (EP), decode
KV caches shard sequence on the model axis (SP) so 32k-context caches fit.
Dims that do not divide evenly by the mesh axis are left unsharded (the
production fallback; noted per-arch in EXPERIMENTS.md).

The rules are *path-pattern based* over the flattened param tree, covering
every arch in the zoo. Activation shardings are installed as the
``maybe_shard`` hook (logical names -> PartitionSpec).
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes
from repro.models import common as C

# (path regex, spec per trailing dims) — first match wins. "fsdp" resolves to
# the mesh's data axes, "model" to the TP axis. Specs are for the LOGICAL
# (unstacked) rank; stacked layer params (leading L dim from scan) get None
# prepended automatically.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"(^|/)embed$",                ("model", "fsdp")),     # (V, d)
    (r"(^|/)lm_head$",              ("fsdp", "model")),     # (d, V)
    (r"(^|/)patch_proj$",           (None, "fsdp")),
    (r"(^|/)pos_(enc|dec)$",        (None, None)),
    (r"/attn/w[qkv]$",              ("fsdp", "model")),
    (r"/attn/wo$",                  ("model", "fsdp")),
    (r"/(self|cross)_attn/w[qkv]$", ("fsdp", "model")),
    (r"/(self|cross)_attn/wo$",     ("model", "fsdp")),
    (r"/moe/router$",               ("fsdp", None)),
    (r"/moe/w_(gate|up)$",          ("model", "fsdp", None)),   # (E, d, ff)
    (r"/moe/w_down$",               ("model", None, "fsdp")),   # (E, ff, d)
    (r"/mlp/w_(gate|up)$",          ("fsdp", "model")),
    (r"/mlp/w_down$",               ("model", "fsdp")),
    (r"/mlp/b_up$",                 ("model",)),
    (r"/mlp/b_down$",               (None,)),
    # rwkv6 time-mix (d,d) and output
    (r"/tm/w_[rkvg]$",              ("fsdp", "model")),
    (r"/tm/w_o$",                   ("model", "fsdp")),
    (r"/tm/w_lora_[ab]$",           (None, None)),
    # rwkv6 channel-mix
    (r"/cm/w_k$",                   ("fsdp", "model")),
    (r"/cm/w_v$",                   ("model", "fsdp")),
    (r"/cm/w_r$",                   ("fsdp", "model")),
    # recurrentgemma RG-LRU block
    (r"/rec/w_(x|gate)$",           ("fsdp", "model")),
    (r"/rec/w_out$",                ("model", "fsdp")),
    (r"/rec/w_(input|rec)_gate$",   (None, "model")),
    (r"/rec/b_(input|rec)_gate$",   ("model",)),
    (r"/rec/conv_w$",               (None, "model")),
    (r"/rec/conv_b$",               ("model",)),
    (r"/rec/lambda$",               ("model",)),
]


def _resolve(axis, mesh: Mesh):
    if axis == "fsdp":
        ax = data_axes(mesh)
        return ax if len(ax) > 1 else (ax[0] if ax else None)
    return axis


def _fits(dim: int, axis, mesh: Mesh) -> bool:
    if axis is None:
        return True
    names = axis if isinstance(axis, tuple) else (axis,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return dim % size == 0 and dim >= size


def _spec_for_shape(shape, spec, mesh: Mesh):
    """Adapt a rule spec to an actual shape: prepend None for stacked dims,
    drop axes that don't divide."""
    spec = tuple(spec)
    if len(shape) == len(spec) + 1:          # stacked layers (scan)
        spec = (None, *spec)
    elif len(shape) != len(spec):
        return P()                           # rank mismatch: replicate
    out = []
    for dim, axis in zip(shape, spec):
        axis = _resolve(axis, mesh)
        out.append(axis if _fits(dim, axis, mesh) else None)
    return P(*out)


def param_spec(path: str, shape, mesh: Mesh) -> P:
    """PartitionSpec for one param (mesh only consulted for axis sizes)."""
    for pattern, spec in _PARAM_RULES:
        if re.search(pattern, path):
            return _spec_for_shape(shape, spec, mesh)
    return P()                               # norms, scalars, mus: replicate


def param_sharding(path: str, arr, mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, param_spec(path, arr.shape, mesh))


def _flatten_paths(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten_paths(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten_paths(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def shard_params_tree(params, mesh: Mesh):
    """NamedSharding pytree matching ``params`` (for in_shardings / device_put)."""
    flat = _flatten_paths(params)
    shardings = {p: param_sharding(p, a, mesh) for p, a in flat.items()}

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(rebuild(v, f"{prefix}{i}/")
                              for i, v in enumerate(tree))
        return shardings[prefix.rstrip("/")]

    return rebuild(params)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------

def batch_sharding(batch_specs: dict, mesh: Mesh) -> dict:
    """tokens/labels (B, S) -> batch on data axes; frontend embeds likewise."""
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    def one(spec):
        b = spec.shape[0]
        axes = [dp if _fits(b, dp, mesh) else None]
        axes += [None] * (len(spec.shape) - 1)
        return NamedSharding(mesh, P(*axes))

    return jax.tree.map(one, batch_specs,
                        is_leaf=lambda x: hasattr(x, "shape"))


def cache_sharding(cache_specs, mesh: Mesh):
    """KV caches: batch on data axes, sequence on model (SP) so 32k-context
    caches fit HBM; recurrent states: width on model."""
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    def one(spec):
        shape = spec.shape
        if len(shape) == 5:      # (L, B, S, G, hd) stacked KV
            axes = [None,
                    dp if _fits(shape[1], dp, mesh) else None,
                    "model" if _fits(shape[2], "model", mesh) else None,
                    None, None]
        elif len(shape) == 4:    # (B, S, G, hd) per-layer KV
            axes = [dp if _fits(shape[0], dp, mesh) else None,
                    "model" if _fits(shape[1], "model", mesh) else None,
                    None, None]
        elif len(shape) == 3:    # (L, B, d) token-shift / (B, W, rnn) conv
            axes = [None,
                    dp if _fits(shape[1], dp, mesh) else None,
                    "model" if _fits(shape[2], "model", mesh) else None]
            if shape[0] <= 256:  # heuristic: leading dim is L for (L,B,d)
                pass
        elif len(shape) == 2:    # (B, rnn) state / (B,) pos is 1D
            axes = [dp if _fits(shape[0], dp, mesh) else None,
                    "model" if _fits(shape[1], "model", mesh) else None]
        elif len(shape) == 1:
            axes = [None]
        else:                    # (L, B, H, K, V) wkv state — shard H
            axes = [None] * len(shape)
            if len(shape) >= 3:
                axes[1] = dp if _fits(shape[1], dp, mesh) else None
                axes[2] = "model" if _fits(shape[2], "model", mesh) else None
        return NamedSharding(mesh, P(*axes))

    return jax.tree.map(one, cache_specs,
                        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, (list, dict)))


# ---------------------------------------------------------------------------
# activation annotations (the maybe_shard hook)
# ---------------------------------------------------------------------------

def install_activation_hook(mesh: Mesh) -> None:
    dp = data_axes(mesh)
    dp_ax = dp if len(dp) > 1 else (dp[0] if dp else None)
    table = {
        "act_btd": P(dp_ax, None, None),
        "act_ff": P(dp_ax, None, "model"),
        "act_heads": P(dp_ax, None, "model", None),
        "moe_dispatch": P(dp_ax, "model", None, None),   # (B, E, C, d)
        "moe_hidden": P(dp_ax, "model", None, None),     # (B, E, C, ff)
        "kv_seq": P(dp_ax, "model", None, None),         # (B, S, H, hd)
        "decode_scores": P(dp_ax, None, None, "model"),  # (B, H, 1, S)
    }

    def hook(x, logical):
        spec = table.get(logical)
        if spec is None:
            return x
        # drop axes that don't divide the actual dims
        axes = []
        for dim, ax in zip(x.shape, tuple(spec) + (None,) * len(x.shape)):
            axes.append(ax if _fits(dim, ax, mesh) else None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*axes)))

    C.set_shard_hook(hook)


def clear_activation_hook() -> None:
    C.set_shard_hook(None)
