"""Distributed out-of-core serving: one on-disk index, a mesh of readers.

``DistOutOfCoreBackend`` (registry name ``dist-ooc``) serves a single
committed base generation from every device of a mesh at once. The shard
plan (``repro.storage.partition``) cuts the file into contiguous leaf-run
row ranges balanced by row count; each shard then

* memory-maps **only its own** LRD/LSD/enc row range — the per-shard
  :class:`_ShardRows` views translate shard-local row slices to absolute
  file rows, *refuse* anything outside the shard's range, and record the
  absolute rows actually touched (``stats()["dist"]["rows_touched"]``), so
  tests can assert residency confinement instead of trusting it;
* descends the shared resident tree (routing tables are small and
  replicated; only raw rows are sharded) and streams its local leaf runs
  through its own :class:`repro.data.pipeline.AsyncChunkReader` — the
  codec-certified encoded stream and the wave-fused dedup'd run schedule
  both come along for free, because each shard is a full
  :class:`~repro.core.engine.OutOfCoreLocalBackend` over its range view;
* merges per-shard top-k triplets **in difference form** through the same
  ``shard_map`` + ``all_gather`` collective idiom as
  ``repro.distributed.search``.

Exactness / bit-identity argument: each shard's answer is the exact top-k
of its row range with the same difference-form squared-ED arithmetic as
every other backend, and shards partition the file into *ascending
contiguous* ranges. ``jax.lax.top_k`` breaks ties toward the lower index,
so the shard-major concatenation the collective merge sorts resolves equal
distances toward the lower file position — exactly the tie-break the
single-host fold (:func:`repro.core.search._merge_topk` in file order)
produces. Hence distances, positions, and ids match ``LocalBackend`` /
``ooc-local`` bit for bit for every shard count, codec, and
``kernel_mode``; only the telemetry differs.

Placement: each shard's stream is staged and refined under
``jax.default_device(shard_device)``, so on a real (or
``--xla_force_host_platform_device_count``-forced) mesh the blocks land on
the device that owns the shard before the collective merge runs.
"""
from __future__ import annotations

import dataclasses
import functools
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.analysis.sanitize import lockdep_task
from repro.core.engine import OutOfCoreLocalBackend, _OutOfCoreBase
from repro.core.search import SearchConfig
from repro.distributed.compat import make_mesh, shard_map
from repro.storage.partition import ShardPlan, shard_plan

MESH_AXIS = "shards"


class _ShardRows:
    """Row-range view of one mapped base file, in shard-local coordinates.

    The chunk readers only ever take contiguous row slices
    (``rows[start:start+count]``); this proxy translates them to absolute
    file rows, raises on anything outside ``[row_lo, row_hi)``, and records
    the absolute extremes touched into ``audit`` (a shared two-element
    ``[lo, hi)`` list) — the residency-confinement proof the telemetry
    exposes. ``take`` provides the copy-guaranteed gather
    ``_codec_finalize`` needs (advanced indexing on a memmap always
    copies).
    """

    def __init__(self, base, row_lo: int, row_hi: int, audit: list):
        self._base = base
        self._lo = int(row_lo)
        self._hi = int(row_hi)
        self._audit = audit

    @property
    def shape(self) -> tuple:
        return (self._hi - self._lo,) + tuple(self._base.shape[1:])

    @property
    def dtype(self):
        return self._base.dtype

    def __len__(self) -> int:
        return self._hi - self._lo

    def _record(self, a: int, b: int) -> None:
        if b > a:
            self._audit[0] = min(self._audit[0], a)
            self._audit[1] = max(self._audit[1], b)

    def _absolute(self, start: int, stop: int) -> tuple[int, int]:
        rows = self._hi - self._lo
        if not 0 <= start <= stop <= rows:
            raise IndexError(
                f"rows [{start}, {stop}) escape the shard's range view "
                f"(local rows [0, {rows}) = file rows "
                f"[{self._lo}, {self._hi}))")
        a, b = self._lo + start, self._lo + stop
        self._record(a, b)
        return a, b

    def __getitem__(self, idx):
        if not isinstance(idx, slice):
            raise TypeError(
                f"_ShardRows supports contiguous row slices, got {idx!r}")
        start, stop, step = idx.indices(self._hi - self._lo)
        if step != 1:
            raise IndexError(f"_ShardRows slices must be contiguous "
                             f"(step={step})")
        a, b = self._absolute(start, stop)
        return self._base[a:b]

    def take(self, indices, axis: int = 0, out=None, mode: str = "raise"):
        """Copy-guaranteed gather of shard-local rows (np.take dispatches
        here) — advanced indexing on the underlying map always copies, so
        the result can cross to device without aliasing the file."""
        if axis != 0 or out is not None or mode != "raise":
            raise ValueError(
                f"_ShardRows.take supports axis=0/out=None/mode='raise'; "
                f"got axis={axis}, out={out!r}, mode={mode!r}")
        idx = np.asarray(indices, np.int64)
        rows = self._hi - self._lo
        if idx.size:
            lo, hi = int(idx.min()), int(idx.max())
            if lo < 0 or hi >= rows:
                raise IndexError(
                    f"take indices [{lo}, {hi}] escape the shard's "
                    f"{rows}-row range view")
            self._record(self._lo + lo, self._lo + hi + 1)
        return self._base[idx + self._lo]


@dataclasses.dataclass
class _ShardView:
    """A ``SavedIndex``-shaped window onto one shard of an opened index.

    Leaf tables are sliced to the shard's leaf run and re-based to
    shard-local rows/ranks; the tree stays the shared resident one (node ->
    leaf-rank lookups map out-of-shard leaves to -1, so routing a query to
    a home leaf another shard owns simply contributes no seed here). The
    big files surface as :class:`_ShardRows` range views, which is what
    makes "this reader cannot leave its shard" a structural property
    rather than a convention.
    """
    path: str
    manifest: dict
    config: object
    max_depth: int
    tree: object
    small: dict
    codec: str
    series_len: int
    max_leaf: int
    num_leaves: int
    num_series: int
    row_lo: int
    row_hi: int
    _parent: object = dataclasses.field(repr=False, default=None)
    _audit: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def of(cls, saved, plan: ShardPlan, shard: int) -> "_ShardView":
        leaf_lo, leaf_hi = plan.leaf_range(shard)
        row_lo, row_hi = plan.row_range(shard)
        s = saved.small
        lr = np.asarray(s["leaf_rank"])
        local_rank = np.where((lr >= leaf_lo) & (lr < leaf_hi),
                              lr - leaf_lo, -1).astype(lr.dtype)
        small = {
            "perm": np.asarray(s["perm"])[row_lo:row_hi],
            "leaf_rank": local_rank,
            "leaf_start": np.asarray(s["leaf_start"])[leaf_lo:leaf_hi]
            - row_lo,
            "leaf_count": np.asarray(s["leaf_count"])[leaf_lo:leaf_hi],
            "leaf_synopsis": np.asarray(s["leaf_synopsis"])[leaf_lo:leaf_hi],
            "leaf_endpoints": np.asarray(s["leaf_endpoints"])[leaf_lo:leaf_hi],
            "leaf_seg_lens": np.asarray(s["leaf_seg_lens"])[leaf_lo:leaf_hi],
            "series_leaf_rank": np.asarray(s["series_leaf_rank"])
            [row_lo:row_hi] - leaf_lo,
        }
        return cls(
            path=saved.path, manifest=saved.manifest, config=saved.config,
            max_depth=saved.max_depth, tree=saved.tree, small=small,
            codec=getattr(saved, "codec", "raw"),
            series_len=saved.series_len,
            # max_leaf stays global so every shard pads fetches to the same
            # bucket shapes (one compiled refine kernel set for the mesh)
            max_leaf=saved.max_leaf,
            num_leaves=leaf_hi - leaf_lo, num_series=row_hi - row_lo,
            row_lo=row_lo, row_hi=row_hi, _parent=saved)

    @property
    def n_pad(self) -> int:
        return self.row_hi - self.row_lo

    def _mapped(self, name: str) -> _ShardRows:
        audit = self._audit.setdefault(name, [self.row_hi, self.row_lo])
        return _ShardRows(self._parent._mapped(name), self.row_lo,
                          self.row_hi, audit)

    def rows_touched(self) -> tuple[int, int] | None:
        """Absolute ``[lo, hi)`` file rows this shard's readers touched so
        far, across lrd/lsd/enc; ``None`` before the first read."""
        lo = min((a[0] for a in self._audit.values()), default=self.row_hi)
        hi = max((a[1] for a in self._audit.values()), default=self.row_lo)
        if hi <= lo:
            return None
        return lo, hi


def _make_collective_merge(mesh):
    """The jitted shard_map program that merges stacked per-shard top-k
    triplets ``(D, Q, k)`` into the global ``(Q, k)`` answer — the same
    all_gather + stable top_k idiom as ``make_distributed_search``, so
    equal distances resolve toward the lower shard (= lower file
    position)."""
    axes = tuple(mesh.axis_names)
    spec = P(axes)
    repl = P()

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec, spec, spec), out_specs=(repl, repl, repl),
        check_vma=False)
    def merge(d_s, p_s, i_s):
        # local block (1, Q, k): drop the shard dim, gather the mesh's
        qn, k = d_s.shape[1], d_s.shape[2]
        all_d = jax.lax.all_gather(d_s[0], axes, axis=0, tiled=False)
        all_p = jax.lax.all_gather(p_s[0], axes, axis=0, tiled=False)
        all_i = jax.lax.all_gather(i_s[0], axes, axis=0, tiled=False)
        # all_gather over multiple axes stacks per axis: flatten to (D, Q, k)
        dd = jnp.moveaxis(all_d.reshape(-1, qn, k), 0, 1).reshape(qn, -1)
        pp = jnp.moveaxis(all_p.reshape(-1, qn, k), 0, 1).reshape(qn, -1)
        ii = jnp.moveaxis(all_i.reshape(-1, qn, k), 0, 1).reshape(qn, -1)
        neg, idx = jax.lax.top_k(-dd, k)
        return (-neg, jnp.take_along_axis(pp, idx, axis=1),
                jnp.take_along_axis(ii, idx, axis=1))

    return jax.jit(merge)


class DistOutOfCoreBackend(_OutOfCoreBase):
    """Sharded out-of-core serving over one saved index (see module docs).

    ``shards`` defaults to the device count; the mesh must have exactly one
    device per shard (force host devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to test meshes
    on one machine). ``memory_budget_mb`` is **per shard** — each reader
    keeps its own two blocks in flight.
    """

    name = "dist-ooc"

    def __init__(self, saved, config: SearchConfig | None = None,
                 memory_budget_mb: float = 64.0, *,
                 shards: int | None = None, mesh=None):
        super().__init__(saved, config, memory_budget_mb)
        if mesh is None:
            n = int(shards) if shards else len(jax.devices())
            if n < 1:
                raise ValueError(f"shards={shards}; expected >= 1")
            if n > len(jax.devices()):
                raise ValueError(
                    f"dist-ooc needs one device per shard: {n} shards > "
                    f"{len(jax.devices())} devices. Force host devices with "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
                    f"(before jax import) or lower --shards")
            mesh = make_mesh((n,), (MESH_AXIS,))
        self.mesh = mesh
        devices = np.asarray(mesh.devices).reshape(-1)
        self.num_shards = int(devices.size)
        if shards is not None and int(shards) != self.num_shards:
            raise ValueError(f"shards={shards} but the mesh has "
                             f"{self.num_shards} devices")
        self._devices = list(devices)
        self.plan = shard_plan(saved, self.num_shards)
        self._views = [_ShardView.of(saved, self.plan, i)
                       for i in range(self.num_shards)]
        self._subs = [OutOfCoreLocalBackend(v, self._config, memory_budget_mb)
                      for v in self._views]
        self._merge = _make_collective_merge(mesh)
        # folded into the engine's plan-cache key: a plan compiled for one
        # mesh must not serve another (different collective program and
        # different shard placement)
        self.plan_signature = (
            self.name, self.num_shards,
            tuple((str(a), int(mesh.shape[a])) for a in mesh.axis_names))

    # -- plans ---------------------------------------------------------------

    def _validate(self, cfg: SearchConfig) -> None:
        for sub in self._subs:
            sub._validate(cfg)

    def _bind(self, cfg):
        return self._fan_plan(cfg, wave=False)

    def make_wave_plan(self, cfg, q_struct):
        return self._fan_plan(cfg, wave=True, q_struct=q_struct)

    def _fan_plan(self, cfg, wave: bool, q_struct=None):
        subs = [(i, sub) for i, sub in enumerate(self._subs)
                if self._views[i].num_series > 0]
        plans = [(i, (sub.make_wave_plan(cfg, q_struct) if wave
                      else sub._bind(cfg)))
                 for i, sub in subs]
        valid_aware = any(getattr(p, "valid_aware", False) for _, p in plans)

        def run(q, valid_rows=None):
            return self._fan_out(jnp.asarray(q), cfg, plans, valid_rows)

        run.valid_aware = valid_aware
        return run

    def estimate_difficulty(self, queries: jax.Array) -> np.ndarray | None:
        scores = [sub.estimate_difficulty(queries)
                  for i, sub in enumerate(self._subs)
                  if self._views[i].num_leaves > 0]
        if not scores:
            return None
        return np.max(np.stack([np.asarray(s) for s in scores]), axis=0)

    # -- the fan-out / collective-merge call ---------------------------------

    def _run_shard(self, shard: int, plan, q, valid_rows):
        """One shard's stream, pinned to its mesh device: blocks stage to
        (and the refine kernels run on) the device that owns the shard."""
        with jax.default_device(self._devices[shard]):
            if getattr(plan, "valid_aware", False):
                res = plan(q, valid_rows=valid_rows)
            else:
                res = plan(q)
            jax.block_until_ready(res.dists)
        return res

    def _fan_out(self, q, cfg: SearchConfig, plans, valid_rows):
        k = cfg.k
        qn = q.shape[0]
        if len(plans) > 1:
            # one worker per shard: reads and refines overlap across the
            # mesh (each shard already overlaps read with compute via its
            # own reader; this overlaps the shards with each other).
            # Under REPRO_SANITIZE=1 lockdep asserts each work item enters
            # and leaves lock-free — pool threads are recycled, so a
            # carried lock would deadlock a later, unrelated item.
            run = lockdep_task(
                lambda ip: self._run_shard(ip[0], ip[1], q, valid_rows),
                name="dist-ooc-shard")
            with ThreadPoolExecutor(max_workers=len(plans),
                                    thread_name_prefix="repro-dist-shard"
                                    ) as pool:
                results = list(pool.map(run, plans))
        else:
            results = [self._run_shard(i, p, q, valid_rows)
                       for i, p in plans]

        by_shard = dict(zip((i for i, _ in plans), results))
        empty_d = np.full((qn, k), np.float32(np.inf))
        empty_i = np.full((qn, k), -1, np.int32)
        d_parts, p_parts, i_parts = [], [], []
        for s in range(self.num_shards):
            res = by_shard.get(s)
            if res is None:
                d_parts.append(empty_d)
                p_parts.append(empty_i)
                i_parts.append(empty_i)
                continue
            row_lo = self._views[s].row_lo
            p_local = np.asarray(res.positions)
            d_parts.append(np.asarray(res.dists))
            p_parts.append(np.where(p_local >= 0, p_local + row_lo,
                                    -1).astype(p_local.dtype))
            i_parts.append(np.asarray(res.ids))

        md, mp, mi = self._merge(jnp.asarray(np.stack(d_parts)),
                                 jnp.asarray(np.stack(p_parts)),
                                 jnp.asarray(np.stack(i_parts)))
        self._t["calls"] += 1

        # per-query telemetry: exact counters sum; pruning ratios recombine
        # from per-shard fractions weighted by what each shard could prune
        accessed = jnp.zeros((qn,), jnp.int32)
        visited = jnp.zeros((qn,), jnp.int32)
        alive_rows = jnp.zeros((qn,), jnp.float32)
        alive_leaves = jnp.zeros((qn,), jnp.float32)
        tot_rows = tot_leaves = 0
        for (i, _), res in zip(plans, results):
            v = self._views[i]
            accessed = accessed + res.accessed
            visited = visited + res.visited_leaves
            alive_rows = alive_rows + (1.0 - res.sax_pr) * v.num_series
            alive_leaves = alive_leaves + (1.0 - res.eapca_pr) * v.num_leaves
            tot_rows += v.num_series
            tot_leaves += v.num_leaves
        res = self._fill_result(md, mp, mi, path=2, accessed=accessed)
        return res._replace(
            eapca_pr=1.0 - alive_leaves / max(tot_leaves, 1),
            sax_pr=1.0 - alive_rows / max(tot_rows, 1),
            visited_leaves=visited)

    # -- introspection -------------------------------------------------------

    @staticmethod
    def _ratio(values) -> float:
        """max/min over per-shard counts, JSON-safe: empty shards count as
        one row so a starved mesh reads as a huge finite ratio, not inf."""
        vals = [int(v) for v in values]
        if not vals or max(vals) == 0:
            return 1.0
        return max(vals) / max(min(vals), 1)

    def stats(self) -> dict:
        agg = dict(self._t)
        for sub in self._subs:
            for key, val in sub._t.items():
                agg[key] = agg.get(key, 0) + val
        agg["calls"] = self._t["calls"]  # one dist call, not one per shard
        per = lambda key: [sub._t[key] for sub in self._subs]  # noqa: E731
        streamed = per("rows_streamed")
        return {
            "num_series": self.saved.num_series,
            "series_len": self.saved.series_len,
            "memory_budget_mb": self.memory_budget_mb,
            "codec": getattr(self.saved, "codec", "raw"),
            **agg,
            "dist": {
                "shards": self.num_shards,
                "rows_streamed": streamed,
                "read_wait_seconds": per("read_wait_seconds"),
                "bytes_streamed": per("bytes_streamed"),
                "imbalance": self._ratio(streamed),
                "plan_rows": list(self.plan.shard_rows),
                "plan_imbalance": self._ratio(self.plan.shard_rows),
                "balance_warning": not self.plan.balanced,
                "row_range": [list(self.plan.row_range(s))
                              for s in range(self.num_shards)],
                "rows_touched": [list(t) if (t := v.rows_touched()) else None
                                 for v in self._views],
            },
        }

    def describe(self) -> dict:
        d = super().describe()
        d["mesh"] = {str(a): int(self.mesh.shape[a])
                     for a in self.mesh.axis_names}
        return d
