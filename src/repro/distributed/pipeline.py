"""Pipeline parallelism (GPipe schedule) over a mesh "stage" axis.

Completes the parallelism matrix (DP/FSDP/TP/EP/SP + **PP**): layers are
split into P stages, each stage's params live on one device row, and M
microbatches stream through with activations moving stage-to-stage via
``lax.ppermute`` (the TPU ICI-neighbor transfer). The standard GPipe bubble
(P-1 idle slots out of M+P-1 steps) applies; efficiency = M / (M + P - 1).

Differentiable end-to-end: ``ppermute``'s transpose is the reverse permute,
so ``jax.grad`` through ``pipeline_forward`` yields the 1F1B-equivalent
backward schedule automatically (activations for all microbatches are kept —
the prototype trades memory for simplicity; interleaved 1F1B with remat is
the documented next step).

Intended composition: the "stage" axis can be the `pod` axis of the
production mesh (2 stages across pods) with FSDP/TP inside each pod — the
standard 1000+-node layered parallelism.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.compat import shard_map


def pipeline_forward(stage_fn: Callable, stage_params, microbatches,
                     mesh: Mesh, axis: str = "stage"):
    """Run M microbatches through P pipeline stages.

    ``stage_fn(params_one_stage, x) -> y`` — one stage's compute (shapes of
    x and y must match across stages).
    ``stage_params`` — pytree with leading dim P (one slice per stage).
    ``microbatches`` — (M, mb, ...) inputs for stage 0.
    Returns (M, mb, ...) outputs of the last stage.
    """
    p_stages = mesh.shape[axis]
    m = microbatches.shape[0]
    steps = m + p_stages - 1

    param_specs = jax.tree.map(lambda _: P(axis), stage_params)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(param_specs, P()),
        out_specs=P(axis),
        check_vma=False)
    def run(params_s, micro):
        sidx = jax.lax.axis_index(axis)
        params_local = jax.tree.map(lambda a: a[0], params_s)
        zero = jnp.zeros_like(micro[0])
        outs0 = jnp.zeros((1, m, *micro.shape[1:]), micro.dtype)

        def body(carry, t):
            cur, outs = carry
            # stage 0 injects microbatch t (while t < M)
            x_in = jax.lax.dynamic_index_in_dim(
                micro, jnp.minimum(t, m - 1), keepdims=False)
            cur = jnp.where(sidx == 0, x_in, cur)
            y = stage_fn(params_local, cur)
            # last stage emits microbatch t-(P-1) once the pipe is full
            out_idx = jnp.clip(t - (p_stages - 1), 0, m - 1)
            emit = (sidx == p_stages - 1) & (t >= p_stages - 1)
            prev = jax.lax.dynamic_index_in_dim(outs[0], out_idx,
                                                keepdims=False)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(emit, y, prev)[None], out_idx, axis=1)
            # shift activations one stage forward (ring permute, last drops)
            nxt = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(p_stages - 1)])
            return (nxt, outs), None

        (cur, outs), _ = jax.lax.scan(
            body, (zero, outs0), jnp.arange(steps))
        return outs

    out = run(stage_params, microbatches)      # (P, M, mb, ...)
    return out[-1]


def split_stages(params_stacked, num_stages: int):
    """(L, ...)-stacked layer params -> (P, L/P, ...) per-stage groups."""
    def regroup(a):
        l = a.shape[0]
        if l % num_stages:
            raise ValueError(f"{l} layers not divisible into {num_stages} stages")
        return a.reshape(num_stages, l // num_stages, *a.shape[1:])

    return jax.tree.map(regroup, params_stacked)
