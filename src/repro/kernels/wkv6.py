"""Pallas TPU kernel for the RWKV-6 (Finch) WKV recurrence.

The rwkv6-7b arch's compute hot spot at long context is the data-dependent
decay recurrence (per head, K x V state S):

    out_t = r_t . (S + diag(u) k_t v_t^T)        (bonus u on the current token)
    S     = diag(w_t) S + k_t v_t^T              (w_t in (0,1), data-dependent)

GPU implementations (CUDA wkv kernels / flash-linear-attention) tile this over
thread blocks with shared-memory state. The TPU adaptation streams the
sequence through VMEM in chunks: grid = (batch*heads, T/chunk), the (K, V)
state lives in a VMEM scratch that persists across the sequential chunk grid
dimension, and each chunk is processed by an in-register time loop. HBM
traffic is exactly one read of r/k/v/w and one write of out — the recurrence
never re-touches HBM state.

The matrix (intra-chunk attention) form trades this loop for MXU matmuls but
requires exponent-difference stabilization of cumulative decays; it is the
documented next optimization (EXPERIMENTS.md §Perf) — the sequential-in-chunk
form is exact for all inputs, which is what the oracle tests require.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

DEFAULT_CHUNK = 64


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                 out_ref, sfin_ref, state_scr, *, chunk: int, nchunks: int):
    jt = pl.program_id(1)

    @pl.when(jt == 0)
    def _load_state():
        state_scr[...] = s0_ref[0]

    s = state_scr[...]                               # (K, V) f32
    r = r_ref[0].astype(jnp.float32)                 # (C, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)                 # (C, V)
    w = w_ref[0].astype(jnp.float32)                 # (C, K)
    u = u_ref[0].astype(jnp.float32)                 # (K,)

    def body(i, carry):
        s, out = carry
        rt, kt, vt, wt = r[i], k[i], v[i], w[i]
        kv = kt[:, None] * vt[None, :]               # (K, V)
        o = rt @ (s + u[:, None] * kv)               # (V,)
        out = out.at[i, :].set(o)
        # extreme-decay stability: w == 0 is an exact state reset (instant
        # forget). Computing 0 * s would turn an overflowed (inf) state into
        # NaN and poison every later token; select kv directly instead.
        wd = wt[:, None]
        s = jnp.where(wd == 0.0, kv, wd * s + kv)
        return s, out

    out0 = jnp.zeros(out_ref.shape[1:], jnp.float32)
    s, out = jax.lax.fori_loop(0, chunk, body, (s, out0))
    out_ref[0] = out.astype(out_ref.dtype)
    state_scr[...] = s

    @pl.when(jt == nchunks - 1)
    def _store_state():
        sfin_ref[0] = s


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, state: jax.Array, chunk: int = DEFAULT_CHUNK,
         interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """RWKV-6 recurrence.

    Shapes: r/k/w (B, T, H, K); v (B, T, H, V); u (H, K); state (B, H, K, V).
    T must be a multiple of ``chunk`` (the layer pads).
    Returns (out (B, T, H, V), final state (B, H, K, V)).
    """
    b, t, h, dk = r.shape
    dv = v.shape[-1]
    bh = b * h
    nchunks = t // chunk

    def fold(x, d):
        return jnp.moveaxis(x, 2, 1).reshape(bh, t, d)

    rf, kf, wf = fold(r, dk), fold(k, dk), fold(w, dk)
    vf = fold(v, dv)
    uf = jnp.tile(u, (b, 1))                          # (BH, K)
    sf = state.reshape(bh, dk, dv)

    kernel = functools.partial(_wkv6_kernel, chunk=chunk, nchunks=nchunks)
    out, sfin = pl.pallas_call(
        kernel,
        grid=(bh, nchunks),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dv), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, dk), lambda i, j: (i, 0)),
            pl.BlockSpec((1, dk, dv), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, dk, dv), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, dv), r.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(rf, kf, vf, wf, uf, sf)

    out = jnp.moveaxis(out.reshape(b, h, t, dv), 1, 2)
    return out, sfin.reshape(b, h, dk, dv)
