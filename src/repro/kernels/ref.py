"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors one kernel's contract exactly (shapes, dtypes, masking)
using straight-line jnp — no blocking, no scratch, no grids. Tests sweep
shapes/dtypes and assert_allclose kernel-vs-oracle (interpret mode on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import summaries as S


def ed_matrix_ref(queries: jax.Array, series: jax.Array) -> jax.Array:
    """(Q, n) x (N, n) -> (Q, N) squared ED, direct-sum formulation."""
    diff = queries[:, None, :].astype(jnp.float32) - series[None, :, :].astype(jnp.float32)
    return jnp.sum(diff * diff, axis=-1)


def ed_min_ref(queries: jax.Array, series: jax.Array):
    """Fused 1-NN oracle: ((Q,) min squared ED, (Q,) argmin)."""
    d = ed_matrix_ref(queries, series)
    return jnp.min(d, axis=1), jnp.argmin(d, axis=1).astype(jnp.int32)


def decode_bf16_ref(payload: jax.Array) -> jax.Array:
    """(B, 2n) uint8 bfloat16 payload -> (B, n) float32 rows.

    The payload is the byte image of a little-endian bfloat16 array (what
    ``storage.codecs.Bf16Codec`` writes); the upcast to float32 is exact.
    """
    num, twon = payload.shape
    raw = jnp.reshape(payload, (num, twon // 2, 2))
    return jax.lax.bitcast_convert_type(raw, jnp.bfloat16).astype(jnp.float32)


def decode_bf16_ed_matrix_ref(queries: jax.Array,
                              payload: jax.Array) -> jax.Array:
    """Fused decode+ED oracle: (Q, n) x (B, 2n) uint8 -> (Q, B) squared ED
    against the decoded rows, direct-sum formulation."""
    return ed_matrix_ref(queries, decode_bf16_ref(payload))


def lb_sax_matrix_ref(q_paa: jax.Array, codes: jax.Array, series_len: int,
                      alphabet: int = S.SAX_ALPHABET) -> jax.Array:
    """(Q, m) x (N, m) -> (Q, N) squared LB_SAX (MINDIST)."""
    lo, hi = S.isax_cell_bounds(codes, alphabet)         # (N, m)
    q = q_paa[:, None, :]
    d = jnp.maximum(jnp.maximum(lo[None] - q, q - hi[None]), 0.0)
    m = q_paa.shape[-1]
    return (series_len / m) * jnp.sum(d * d, axis=-1)


def wkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, state: jax.Array):
    """RWKV-6 recurrence oracle (B, T, H, K/V dims); see kernels/wkv6.py.

    state: (B, H, K, V). Returns (out (B,T,H,V), final state).
      out_t = r_t . (state + u * k_t v_t^T);  state = diag(w_t) state + k_t v_t^T
    """
    def step(s, xs):
        rt, kt, vt, wt = xs                              # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        kv = kt[..., :, None] * vt[..., None, :]         # (B,H,K,V)
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        # w == 0 is an exact state reset (instant forget): never compute
        # 0 * s, which NaN-poisons an overflowed state (see kernels/wkv6.py)
        wd = wt[..., :, None]
        s = jnp.where(wd == 0.0, kv, wd * s + kv)
        return s, out

    xs = (jnp.moveaxis(r, 1, 0), jnp.moveaxis(k, 1, 0),
          jnp.moveaxis(v, 1, 0), jnp.moveaxis(w, 1, 0))
    state, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1), state
