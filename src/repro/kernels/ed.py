"""Pallas TPU kernels for the exact-distance hot loop (paper's SIMD scans).

The paper's skip-sequential scan and refinement steps spend their cycles in
SIMD Euclidean-distance code (§3.4 "distance calculations in all steps are
performed using SIMD"). On TPU the same computation is a blocked matmul-
identity reduction on the MXU:

    ||q - s||^2 = ||q||^2 + ||s||^2 - 2 q.s

Two kernels:

* ``ed_matrix_kernel`` — (Q, n) x (N, n) -> (Q, N) squared distances, tiled
  (bq x bn x bk) with fp32 accumulation in the output block across the k-grid
  (the canonical Pallas matmul schedule). Norm contributions are accumulated
  per k-tile so no separate norm pass over HBM is needed.
* ``ed_min_kernel`` — fused 1-NN: per query block, a VMEM scratch accumulates
  the (bq, bn) partial distances over k-tiles, then folds a running
  (min distance, argmin) pair across series blocks. This is the paper's most
  common query (k=1) without materializing the (Q, N) matrix.

Tiling notes (VMEM/MXU): block shapes default to (128, 512, 128) — last-dim
multiples of 128 keep the MXU systolic dims aligned; f32 tiles of
128x512 + 128x128 + 512x128 ≈ 0.6 MB comfortably fit the ~16 MB VMEM
with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


DEFAULT_BQ = 128
DEFAULT_BN = 512
DEFAULT_BK = 128


def _ed_matrix_kernel(q_ref, s_ref, out_ref):
    """Grid (iq, jn, kk); accumulate ||.||^2 identity terms per k-tile."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    q = q_ref[...].astype(jnp.float32)          # (bq, bk)
    s = s_ref[...].astype(jnp.float32)          # (bn, bk)
    qn = jnp.sum(q * q, axis=1)                 # (bq,)
    sn = jnp.sum(s * s, axis=1)                 # (bn,)
    dot = jax.lax.dot_general(q, s, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    out_ref[...] += qn[:, None] + sn[None, :] - 2.0 * dot


def _ed_min_kernel(q_ref, s_ref, dmin_ref, amin_ref, acc_ref, *, bn: int,
                   nk: int, valid_n: int):
    """Grid (iq, jn, kk). acc_ref: VMEM scratch (bq, bn) partial distances.

    ``valid_n``: logical series count — columns at or past it are padding
    and are masked to ``+inf`` before the fold, so ragged collections never
    need sentinel rows (which break down for adversarial input magnitudes).
    """
    jn = pl.program_id(1)
    kk = pl.program_id(2)

    @pl.when((jn == 0) & (kk == 0))
    def _init_out():
        # +inf, not a finite sentinel: real distances can land anywhere up
        # to and including inf, and the strict-< fold must still admit them
        # (all-inf collections then match the oracle's argmin of 0)
        dmin_ref[...] = jnp.full_like(dmin_ref, jnp.inf)
        amin_ref[...] = jnp.zeros_like(amin_ref)

    @pl.when(kk == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    qn = jnp.sum(q * q, axis=1)
    sn = jnp.sum(s * s, axis=1)
    dot = jax.lax.dot_general(q, s, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    acc_ref[...] += qn[:, None] + sn[None, :] - 2.0 * dot

    @pl.when(kk == nk - 1)
    def _fold():
        d = acc_ref[...]                                       # (bq, bn)
        cols = jn * bn + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
        d = jnp.where(cols < valid_n, d, jnp.inf)
        local_min = jnp.min(d, axis=1)
        local_arg = jnp.argmin(d, axis=1).astype(jnp.int32) + jn * bn
        better = local_min < dmin_ref[...]
        dmin_ref[...] = jnp.where(better, local_min, dmin_ref[...])
        amin_ref[...] = jnp.where(better, local_arg, amin_ref[...])


@functools.partial(jax.jit, static_argnames=("bq", "bn", "bk", "interpret"))
def ed_matrix(queries: jax.Array, series: jax.Array,
              bq: int = DEFAULT_BQ, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
              interpret: bool = False) -> jax.Array:
    """Blocked squared-ED matrix. Shapes must be multiples of the blocks
    (ops.py pads); returns (Q, N) float32."""
    qn, n = queries.shape
    sn = series.shape[0]
    grid = (qn // bq, sn // bn, n // bk)
    return pl.pallas_call(
        _ed_matrix_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, sn), jnp.float32),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(queries, series)


@functools.partial(jax.jit, static_argnames=("bq", "bn", "bk", "valid_n",
                                             "interpret"))
def ed_min(queries: jax.Array, series: jax.Array,
           bq: int = DEFAULT_BQ, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
           valid_n: int | None = None,
           interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Fused 1-NN scan: returns ((Q,) min squared ED, (Q,) argmin).

    ``valid_n``: logical (unpadded) series count; rows at or past it never
    win the min. Defaults to every row being live."""
    qn, n = queries.shape
    sn = series.shape[0]
    nk = n // bk
    grid = (qn // bq, sn // bn, nk)
    kernel = functools.partial(_ed_min_kernel, bn=bn, nk=nk,
                               valid_n=sn if valid_n is None else valid_n)
    dmin, amin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=[
            pl.BlockSpec((bq,), lambda i, j, k: (i,)),
            pl.BlockSpec((bq,), lambda i, j, k: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn,), jnp.float32),
            jax.ShapeDtypeStruct((qn,), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((bq, bn), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(queries, series)
    return dmin, amin
