"""Version-compat shims for Pallas TPU APIs that moved between jax releases
(the kernels' analogue of ``distributed/compat.py``).

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` (and the
class itself moved around) across the jax 0.4.x -> 0.5+ window; kernels in
this repo construct their compiler params through :func:`compiler_params`,
which targets whichever class the installed jax exports and silently drops
kwargs that class does not know about (older jax builds predate e.g.
``serialization_format``). This is the single place new pltpu drift gets
absorbed — kernels themselves never touch ``pltpu.*CompilerParams`` directly.

Also exported here:

* :data:`KERNEL_MODES` / :func:`resolve_kernel_mode` — the engine-facing
  execution-mode policy (``auto | pallas | interpret | ref``). ``auto``
  resolves per-platform: compiled Pallas on TPU, the jnp reference path
  everywhere Pallas/Mosaic is unavailable (CPU/GPU). ``interpret`` runs the
  same kernel bodies through the Pallas interpreter (CI's differential
  conformance mode).
"""
from __future__ import annotations

import dataclasses

import jax
from jax.experimental.pallas import tpu as pltpu

try:  # jax >= 0.5: the class is pltpu.CompilerParams
    _CompilerParams = pltpu.CompilerParams
except AttributeError:  # jax 0.4.x: pltpu.TPUCompilerParams
    _CompilerParams = pltpu.TPUCompilerParams


def compiler_params(**kwargs):
    """Construct the installed jax's TPU compiler-params object, dropping any
    kwarg this jax's class does not have a field for."""
    fields = {f.name for f in dataclasses.fields(_CompilerParams)}
    return _CompilerParams(**{k: v for k, v in kwargs.items() if k in fields})


# ---------------------------------------------------------------------------
# Kernel execution-mode policy
# ---------------------------------------------------------------------------

KERNEL_MODES = ("auto", "pallas", "interpret", "ref")


def pallas_available() -> bool:
    """Whether compiled (Mosaic) Pallas kernels can run on this platform."""
    return jax.default_backend() == "tpu"


def resolve_kernel_mode(mode: str = "auto") -> str:
    """Resolve a requested kernel mode to a concrete one of
    ``pallas | interpret | ref``.

    ``auto`` picks compiled Pallas on TPU and falls back to the ``ref``
    oracles (plain XLA) where Mosaic cannot compile — the engine hot path
    stays correct on every platform without configuration. ``interpret`` is
    never auto-selected: it exists for differential testing (same kernel
    body, Pallas interpreter) and is orders of magnitude slower than ``ref``.
    """
    if mode not in KERNEL_MODES:
        raise ValueError(
            f"kernel_mode={mode!r}; expected one of {KERNEL_MODES}")
    if mode == "auto":
        return "pallas" if pallas_available() else "ref"
    return mode
