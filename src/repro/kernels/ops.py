"""jit'd public wrappers around the Pallas kernels (padding + dispatch).

These are the entry points the rest of the framework calls. Each wrapper:
  * pads inputs up to block multiples (masking semantics preserved),
  * dispatches to the Pallas kernel (``interpret=True`` on CPU — the kernels
    target TPU; interpret mode executes the same kernel body for validation),
  * slices the result back to logical shapes.

``use_pallas=False`` falls back to the ref.py oracle — that is also what the
dry-run lowers (XLA path) so CPU compilation never depends on Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ed as _ed
from repro.kernels import lb_sax as _lb
from repro.kernels import ref as _ref

_PAD_DIST = 3.0e38


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_rows(x: jax.Array, mult: int, value: float = 0.0) -> jax.Array:
    n = x.shape[0]
    tgt = -(-n // mult) * mult
    if tgt == n:
        return x
    pad = jnp.full((tgt - n, *x.shape[1:]), value, x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def ed_matrix(queries: jax.Array, series: jax.Array, *,
              bq: int | None = None, bn: int | None = None,
              bk: int | None = None, use_pallas: bool = True,
              interpret: bool | None = None) -> jax.Array:
    """(Q, n) x (N, n) -> (Q, N) squared ED. Pads freely; exact result."""
    if not use_pallas:
        return _ref.ed_matrix_ref(queries, series)
    interpret = _on_cpu() if interpret is None else interpret
    q0, s0 = queries.shape[0], series.shape[0]
    n = queries.shape[1]
    bq = bq or min(_ed.DEFAULT_BQ, max(8, q0))
    bn = bn or min(_ed.DEFAULT_BN, max(128, s0))
    bk = bk or min(_ed.DEFAULT_BK, n)
    q = _pad_rows(queries, bq)
    s = _pad_rows(series, bn)
    if n % bk:
        # pad length with zeros: contributes 0 to both norms and dot
        extra = -(-n // bk) * bk - n
        q = jnp.concatenate([q, jnp.zeros((q.shape[0], extra), q.dtype)], 1)
        s = jnp.concatenate([s, jnp.zeros((s.shape[0], extra), s.dtype)], 1)
    out = _ed.ed_matrix(q, s, bq=bq, bn=bn, bk=bk, interpret=interpret)
    return out[:q0, :s0]


def ed_min(queries: jax.Array, series: jax.Array, *,
           bq: int | None = None, bn: int | None = None,
           bk: int | None = None, use_pallas: bool = True,
           interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Fused 1-NN: ((Q,) min squared ED, (Q,) argmin over the N axis)."""
    if not use_pallas:
        return _ref.ed_min_ref(queries, series)
    interpret = _on_cpu() if interpret is None else interpret
    q0, s0 = queries.shape[0], series.shape[0]
    n = queries.shape[1]
    bq = bq or min(_ed.DEFAULT_BQ, max(8, q0))
    bn = bn or min(_ed.DEFAULT_BN, max(128, s0))
    bk = bk or min(_ed.DEFAULT_BK, n)
    q = _pad_rows(queries, bq)
    # pad series rows with +inf-distance sentinels: use a huge constant row
    # (norm dominates) so padded rows never win the min
    s = _pad_rows(series, bn, value=0.0)
    pad_rows = s.shape[0] - s0
    if pad_rows:
        sentinel = jnp.full((pad_rows, s.shape[1]), 1.0e18, s.dtype)
        s = jnp.concatenate([s[:s0], sentinel], axis=0)
    if n % bk:
        extra = -(-n // bk) * bk - n
        q = jnp.concatenate([q, jnp.zeros((q.shape[0], extra), q.dtype)], 1)
        s = jnp.concatenate([s, jnp.zeros((s.shape[0], extra), s.dtype)], 1)
    dmin, amin = _ed.ed_min(q, s, bq=bq, bn=bn, bk=bk, interpret=interpret)
    return dmin[:q0], amin[:q0]


def lb_sax_matrix(q_paa: jax.Array, codes: jax.Array, series_len: int, *,
                  bq: int | None = None, bn: int | None = None,
                  use_pallas: bool = True,
                  interpret: bool | None = None) -> jax.Array:
    """(Q, m) x (N, m) uint8 -> (Q, N) squared LB_SAX."""
    if not use_pallas:
        return _ref.lb_sax_matrix_ref(q_paa, codes, series_len)
    interpret = _on_cpu() if interpret is None else interpret
    q0, s0 = q_paa.shape[0], codes.shape[0]
    bq = bq or min(_lb.DEFAULT_BQ, max(8, q0))
    bn = bn or min(_lb.DEFAULT_BN, max(128, s0))
    q = _pad_rows(q_paa, bq)
    c = _pad_rows(codes, bn)
    out = _lb.lb_sax_matrix(q, c, series_len, bq=bq, bn=bn, interpret=interpret)
    return out[:q0, :s0]
