"""Public wrappers around the Pallas kernels (mode dispatch + ragged tiling).

These are the entry points the rest of the framework calls — the engine hot
path (``ScanBackend`` ED, ``core/search.py`` LB_SAX pruning) and the
conformance suite both go through here. Each wrapper:

  * resolves the execution **mode** (``auto | pallas | interpret | ref``,
    see :func:`repro.kernels.compat.resolve_kernel_mode`) — ``ref`` runs the
    ref.py oracle (plain XLA; what the dry-run lowers on CPU), ``pallas``
    the compiled Mosaic kernel, ``interpret`` the same kernel body on the
    Pallas interpreter (differential testing);
  * picks block shapes that fit the *engine's* layouts: row blocks prefer
    divisors of the padded row count (``validate_runtime_config`` guarantees
    chunk/scan_block divide it), so kernel tiles line up with the layout and
    no row padding is materialized on the aligned path;
  * pads any genuinely ragged remainder up to block multiples (masking
    semantics preserved — ``ed_min`` masks padded rows *inside* the kernel
    by logical count, so no sentinel values enter the arithmetic) and slices
    the result back to logical shapes.

The legacy ``use_pallas=``/``interpret=`` kwargs remain accepted (mapped
onto modes) so pre-engine callers and tests keep working unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ed as _ed
from repro.kernels import lb_sax as _lb
from repro.kernels import ref as _ref
from repro.kernels.compat import (KERNEL_MODES, pallas_available,  # noqa: F401
                                  resolve_kernel_mode)


def _resolve(mode: str | None, use_pallas: bool, interpret: bool | None) -> str:
    """Mode resolution with legacy-kwarg fallback.

    Explicit ``mode`` wins. Otherwise the historical contract applies:
    ``use_pallas=False`` -> ref; else the kernel runs, interpreted on
    non-TPU platforms (``interpret=None``) or as forced by ``interpret=``.
    """
    if mode is not None:
        return resolve_kernel_mode(mode)
    if not use_pallas:
        return "ref"
    if interpret is None:
        interpret = not pallas_available()
    return "interpret" if interpret else "pallas"


def _row_block(n_rows: int, target: int, floor: int) -> int:
    """Row-block size for an ``n_rows``-row operand: prefer a divisor of
    ``n_rows`` near ``target`` (engine layouts are padded so chunk/scan_block
    divide them — aligned tiles need no padding), else fall back to
    ``target`` and let the caller pad the remainder."""
    b = min(target, max(floor, n_rows))
    while b > floor and n_rows % b:
        b //= 2
    if n_rows % b == 0:
        return b
    return min(target, max(floor, n_rows))


def _pad_rows(x: jax.Array, mult: int, value: float = 0.0) -> jax.Array:
    n = x.shape[0]
    tgt = -(-n // mult) * mult
    if tgt == n:
        return x
    pad = jnp.full((tgt - n, *x.shape[1:]), value, x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def _pad_len(q: jax.Array, s: jax.Array, bk: int):
    """Pad the series-length axis with zeros (0 contribution to norms/dot)."""
    n = q.shape[1]
    if n % bk:
        extra = -(-n // bk) * bk - n
        q = jnp.concatenate([q, jnp.zeros((q.shape[0], extra), q.dtype)], 1)
        s = jnp.concatenate([s, jnp.zeros((s.shape[0], extra), s.dtype)], 1)
    return q, s


def ed_matrix(queries: jax.Array, series: jax.Array, *,
              bq: int | None = None, bn: int | None = None,
              bk: int | None = None, mode: str | None = None,
              use_pallas: bool = True,
              interpret: bool | None = None) -> jax.Array:
    """(Q, n) x (N, n) -> (Q, N) squared ED. Pads freely; exact result."""
    mode = _resolve(mode, use_pallas, interpret)
    if mode == "ref":
        return _ref.ed_matrix_ref(queries, series)
    q0, s0 = queries.shape[0], series.shape[0]
    n = queries.shape[1]
    bq = bq or _row_block(q0, _ed.DEFAULT_BQ, 8)
    bn = bn or _row_block(s0, _ed.DEFAULT_BN, 128)
    bk = bk or min(_ed.DEFAULT_BK, n)
    q = _pad_rows(queries, bq)
    s = _pad_rows(series, bn)
    q, s = _pad_len(q, s, bk)
    out = _ed.ed_matrix(q, s, bq=bq, bn=bn, bk=bk,
                        interpret=mode == "interpret")
    return out[:q0, :s0]


def decode_bf16_ed_matrix(queries: jax.Array, payload: jax.Array, *,
                          bq: int | None = None, bn: int | None = None,
                          bk: int | None = None, mode: str | None = None,
                          use_pallas: bool = True,
                          interpret: bool | None = None) -> jax.Array:
    """Fused bf16 decode + squared ED: (Q, n) x (B, 2n) uint8 -> (Q, B).

    ``payload`` is the byte image of bfloat16 rows (the prefix of
    ``storage.codecs.Bf16Codec`` encoded rows). On the kernel path the
    bytes are bitcast to a bfloat16 HBM array — a free reinterpret, no
    widening copy — and the ED kernel upcasts each (bn, bk) tile to
    float32 *in VMEM*, so decoded float32 rows never round-trip through
    HBM. The ref path decodes eagerly and runs the direct-sum oracle.
    """
    mode = _resolve(mode, use_pallas, interpret)
    if mode == "ref":
        return _ref.decode_bf16_ed_matrix_ref(queries, payload)
    num, twon = payload.shape
    raw = jnp.reshape(payload, (num, twon // 2, 2))
    series = jax.lax.bitcast_convert_type(raw, jnp.bfloat16)
    return ed_matrix(queries, series, bq=bq, bn=bn, bk=bk, mode=mode)


def ed_min(queries: jax.Array, series: jax.Array, *,
           bq: int | None = None, bn: int | None = None,
           bk: int | None = None, mode: str | None = None,
           use_pallas: bool = True,
           interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Fused 1-NN: ((Q,) min squared ED, (Q,) argmin over the N axis)."""
    mode = _resolve(mode, use_pallas, interpret)
    if mode == "ref":
        return _ref.ed_min_ref(queries, series)
    q0, s0 = queries.shape[0], series.shape[0]
    n = queries.shape[1]
    bq = bq or _row_block(q0, _ed.DEFAULT_BQ, 8)
    bn = bn or _row_block(s0, _ed.DEFAULT_BN, 128)
    bk = bk or min(_ed.DEFAULT_BK, n)
    q = _pad_rows(queries, bq)
    # padded series rows are zeros; the kernel masks them out by logical
    # count (valid_n), so no sentinel magnitudes enter the arithmetic
    s = _pad_rows(series, bn)
    q, s = _pad_len(q, s, bk)
    dmin, amin = _ed.ed_min(q, s, bq=bq, bn=bn, bk=bk, valid_n=s0,
                            interpret=mode == "interpret")
    return dmin[:q0], amin[:q0]


def lb_sax_matrix(q_paa: jax.Array, codes: jax.Array, series_len: int, *,
                  alphabet: int | None = None,
                  bq: int | None = None, bn: int | None = None,
                  mode: str | None = None, use_pallas: bool = True,
                  interpret: bool | None = None) -> jax.Array:
    """(Q, m) x (N, m) uint8 -> (Q, N) squared LB_SAX."""
    from repro.core import summaries as _S

    alphabet = _S.SAX_ALPHABET if alphabet is None else alphabet
    mode = _resolve(mode, use_pallas, interpret)
    if mode == "ref":
        return _ref.lb_sax_matrix_ref(q_paa, codes, series_len,
                                      alphabet=alphabet)
    q0, s0 = q_paa.shape[0], codes.shape[0]
    bq = bq or _row_block(q0, _lb.DEFAULT_BQ, 8)
    bn = bn or _row_block(s0, _lb.DEFAULT_BN, 128)
    q = _pad_rows(q_paa, bq)
    c = _pad_rows(codes, bn)
    out = _lb.lb_sax_matrix(q, c, series_len, alphabet, bq=bq, bn=bn,
                            interpret=mode == "interpret")
    return out[:q0, :s0]


# the engine-facing short name (core/search.py's pruning call site)
lb_sax = lb_sax_matrix


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, state: jax.Array, *, chunk: int | None = None,
         mode: str | None = None, use_pallas: bool = True,
         interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """RWKV-6 recurrence with mode dispatch and ragged-T chunking.

    Shapes as :func:`repro.kernels.wkv6.wkv6`; T need *not* divide the chunk
    — the tail is padded with w=1 / k=0 steps (identity recurrence) and the
    output sliced back.
    """
    from repro.kernels.wkv6 import DEFAULT_CHUNK
    from repro.kernels.wkv6 import wkv6 as _wkv6

    mode = _resolve(mode, use_pallas, interpret)
    if mode == "ref":
        return _ref.wkv6_ref(r, k, v, w, u, state)
    b, t, h, dk = r.shape
    chunk = chunk or min(DEFAULT_CHUNK, t)
    t_pad = -(-t // chunk) * chunk
    if t_pad != t:
        def pad_t(x, value):
            pad = jnp.full((b, t_pad - t, *x.shape[2:]), value, x.dtype)
            return jnp.concatenate([x, pad], axis=1)
        # identity steps: w=1 keeps the state, k=0 adds nothing, r=0 reads 0
        r, k, v = pad_t(r, 0.0), pad_t(k, 0.0), pad_t(v, 0.0)
        w = pad_t(w, 1.0)
    out, sfin = _wkv6(r, k, v, w, u, state, chunk=chunk,
                      interpret=mode == "interpret")
    return out[:, :t], sfin
