# Pallas TPU kernels for the compute hot-spots the paper optimizes with SIMD
# (exact-distance scans, LB_SAX filtering) plus the ssm-arch WKV recurrence.
# Engine code calls the ops.py wrappers, which dispatch by kernel mode
# (auto | pallas | interpret | ref; compat.py owns the policy and the
# pltpu version shims) and tile/pad for the engine's ragged layouts.
from repro.kernels import compat, ops, ref  # noqa: F401
from repro.kernels.compat import (  # noqa: F401
    KERNEL_MODES, pallas_available, resolve_kernel_mode,
)
from repro.kernels.ed import ed_matrix, ed_min  # noqa: F401
from repro.kernels.lb_sax import lb_sax_matrix  # noqa: F401
from repro.kernels.wkv6 import wkv6  # noqa: F401
