# Pallas TPU kernels for the compute hot-spots the paper optimizes with SIMD
# (exact-distance scans, LB_SAX filtering) plus the ssm-arch WKV recurrence.
# Validated in interpret mode on CPU; ops.py wrappers fall back to ref.py
# oracles for XLA-only paths (e.g. the CPU dry-run lowering).
from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.ed import ed_matrix, ed_min  # noqa: F401
from repro.kernels.lb_sax import lb_sax_matrix  # noqa: F401
from repro.kernels.wkv6 import wkv6  # noqa: F401
