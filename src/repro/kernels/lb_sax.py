"""Pallas TPU kernel for LB_SAX (MINDIST) over packed iSAX codes.

The paper's phase 3 streams the in-memory LSDFile (uint8 iSAX codes, 16 bytes
per series vs 4*n bytes of raw data) and computes LB_SAX per series. On TPU
this is a bandwidth-bound VPU job; the only awkward part is the breakpoint
table lookup (codes -> cell [lo, hi] bounds). Gathers are not VPU-friendly, so
the lookup is expressed as a **one-hot matmul against the (alphabet,) bound
tables** — the embedding-lookup-as-matmul idiom, which runs on the MXU.

    lo = onehot(code) @ lo_table        hi = onehot(code) @ hi_table
    d  = max(lo - paa, paa - hi, 0)     lb = seg_len * sum_i d_i^2

Tiling: codes block (bn, m) uint8, query PAA block (bq, m) f32, tables whole
(alphabet,). Output (bq, bn). m = 16 everywhere (paper's segment count).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import summaries as S
from repro.kernels.compat import compiler_params

DEFAULT_BQ = 8
DEFAULT_BN = 1024


def _lb_sax_kernel(qpaa_ref, codes_ref, lo_tab_ref, hi_tab_ref, out_ref,
                   *, seg_len: float, alphabet: int):
    q = qpaa_ref[...].astype(jnp.float32)            # (bq, m)
    c = codes_ref[...].astype(jnp.int32)             # (bn, m)
    bn, m = c.shape
    # one-hot lookup on the MXU: (bn*m, A) @ (A,) -> (bn*m,)
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn * m, alphabet), 1)
    onehot = (c.reshape(bn * m, 1) == iota).astype(jnp.float32)
    lo = (onehot @ lo_tab_ref[...].reshape(alphabet, 1)).reshape(bn, m)
    hi = (onehot @ hi_tab_ref[...].reshape(alphabet, 1)).reshape(bn, m)
    d = jnp.maximum(jnp.maximum(lo[None] - q[:, None], q[:, None] - hi[None]),
                    0.0)                              # (bq, bn, m)
    out_ref[...] = seg_len * jnp.sum(d * d, axis=-1)


def _bound_tables(alphabet: int) -> tuple[jax.Array, jax.Array]:
    """Per-symbol cell bound tables (lo_table, hi_table), each (alphabet,)."""
    big = 3.0e38
    bps = S.sax_breakpoints(alphabet)                # (A-1,)
    lo = jnp.concatenate([jnp.asarray([-big], jnp.float32), bps])
    hi = jnp.concatenate([bps, jnp.asarray([big], jnp.float32)])
    return lo, hi


@functools.partial(jax.jit,
                   static_argnames=("series_len", "alphabet", "bq", "bn",
                                    "interpret"))
def lb_sax_matrix(q_paa: jax.Array, codes: jax.Array, series_len: int,
                  alphabet: int = S.SAX_ALPHABET,
                  bq: int = DEFAULT_BQ, bn: int = DEFAULT_BN,
                  interpret: bool = False) -> jax.Array:
    """(Q, m) PAA x (N, m) uint8 codes -> (Q, N) squared LB_SAX."""
    qn, m = q_paa.shape
    sn = codes.shape[0]
    grid = (qn // bq, sn // bn)
    lo_tab, hi_tab = _bound_tables(alphabet)
    kernel = functools.partial(_lb_sax_kernel, seg_len=series_len / m,
                               alphabet=alphabet)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, m), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, m), lambda i, j: (j, 0)),
            pl.BlockSpec((alphabet,), lambda i, j: (0,)),
            pl.BlockSpec((alphabet,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, sn), jnp.float32),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q_paa, codes, lo_tab, hi_tab)
