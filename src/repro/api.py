"""``repro.api`` — the one import for answering and serving kNN queries.

    from repro import api

    backend = api.make_backend("local", data, search=api.SearchConfig(k=5))
    engine = api.QueryEngine(backend)
    result = engine.knn(queries)                  # KnnResult, exact
    engine.telemetry()["plan_cache"]              # hits/misses/compiles

    serve = api.KnnServeEngine(engine, api.KnnServeConfig(batch_slots=32))
    rid = serve.submit(one_query)
    serve.drain()                                 # {rid: KnnAnswer}

Backends (``local`` | ``scan`` | ``scan-mxu`` | ``sharded``) all answer
exactly and interchangeably; the engine owns batching, the compiled-plan
cache, and telemetry. See README.md for the full tour.
"""
from repro.core.engine import (  # noqa: F401
    BACKEND_NAMES, EngineConfig, LocalBackend, QueryEngine, ScanBackend,
    SearchBackend, ShardedBackend, dense_scan_knn, kernel_scan_knn,
    make_backend,
)
from repro.kernels.compat import KERNEL_MODES, resolve_kernel_mode  # noqa: F401
from repro.core.index import HerculesIndex, IndexConfig  # noqa: F401
from repro.core.search import (  # noqa: F401
    KnnResult, SearchConfig, brute_force_knn, pscan_knn,
)
from repro.core.tree import BuildConfig  # noqa: F401
from repro.serve.engine import (  # noqa: F401
    KnnAnswer, KnnServeConfig, KnnServeEngine,
)
