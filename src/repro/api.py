"""``repro.api`` — the one import for answering and serving kNN queries.

    from repro import api

    backend = api.make_backend("local", data, search=api.SearchConfig(k=5))
    engine = api.QueryEngine(backend)
    result = engine.knn(queries)                  # KnnResult, exact
    engine.telemetry()["plan_cache"]              # hits/misses/compiles

    serve = api.KnnServeEngine(engine, api.KnnServeConfig(batch_slots=32))
    rid = serve.submit(one_query)
    serve.drain()                                 # {rid: KnnAnswer}

Backends (``local`` | ``scan`` | ``scan-mxu`` | ``sharded``) all answer
exactly and interchangeably; the engine owns batching, the compiled-plan
cache, and telemetry. See README.md for the full tour.

Persistence & out-of-core (``repro.storage`` + the disk backends)::

    api.save_index(index, "idx/")                 # versioned dir + checksums
    index = api.load_index("idx/")                # bit-identical round-trip
    src = api.NpyChunkSource("data.npy", 8192)
    api.build_index_to_disk(src, "idx/")          # never materializes data
    backend = api.make_disk_backend("ooc-scan", "idx/", memory_budget_mb=64)
"""
from repro.core.engine import (  # noqa: F401
    BACKEND_NAMES, DISK_BACKEND_NAMES, EngineConfig, LocalBackend,
    OutOfCoreLocalBackend, OutOfCoreScanBackend, QueryEngine, ScanBackend,
    SearchBackend, ShardedBackend, dense_scan_knn, kernel_scan_knn,
    make_backend, make_disk_backend,
)
from repro.kernels.compat import KERNEL_MODES, resolve_kernel_mode  # noqa: F401
from repro.core.index import HerculesIndex, IndexConfig  # noqa: F401
from repro.core.search import (  # noqa: F401
    KnnResult, SearchConfig, brute_force_knn, pscan_knn,
)
from repro.core.tree import BuildConfig, build_tree_chunked  # noqa: F401
from repro.data.pipeline import (  # noqa: F401
    ArrayChunkSource, ChunkSource, NpyChunkSource, iter_device_chunks,
)
from repro.serve.engine import (  # noqa: F401
    KnnAnswer, KnnServeConfig, KnnServeEngine,
)
from repro.storage import (  # noqa: F401
    FORMAT_VERSION, IndexFormatError, SavedIndex, build_index_streaming,
    build_index_to_disk, load_index, open_index, save_index,
)
