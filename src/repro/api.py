"""``repro.api`` — the one import for the whole index lifecycle.

The central object is the :class:`Hercules` store: one handle that owns an
index directory from creation through incremental ingest, compaction, and
query serving::

    from repro import api

    # create -> append -> compact -> query, one handle, context-managed
    with api.Hercules.create("idx/", api.IndexConfig(), data=chunks_a) as hx:
        hx.append(chunks_b)            # journal segment; atomic commit
        res = hx.query(queries, k=5)   # exact: base index + journal merge
        hx.compact()                   # fold the journal into the base —
                                       # bit-identical to a from-scratch
                                       # build over A concat B
        engine = hx.engine("ooc-local", memory_budget_mb=64)
        engine.knn(queries)            # compiled-plan-cached serving
        engine.telemetry()["plan_cache"]   # hits/misses/invalidations

    hx = api.Hercules.open("idx/", mode="a")   # reopen later; "r" = serve only

Appends land in checksummed journal segments (the manifest republish is the
single atomic commit point — a crash before it leaves only orphans the next
writable open sweeps away); ``compact`` replays base + journal rows through
the chunked-build primitives into a new file generation, so append+compact
answers bit-identically to building once over the concatenated collection
on every backend (``tests/test_store.py``).

Purely in-memory serving (no directory on disk) still goes through
:func:`make_backend` + :class:`QueryEngine`; ``local`` | ``scan`` |
``scan-mxu`` | ``sharded`` all answer exactly and interchangeably, and
:class:`KnnServeEngine` adds slot-based submit/poll/drain serving. All
servable names live in the one :data:`BACKENDS` registry
(``backend_names("memory")`` / ``backend_names("disk")`` are its two
construction-path views; the ``BACKEND_NAMES`` / ``DISK_BACKEND_NAMES``
tuples remain as deprecated aliases).

**Compressed leaves (format v3).** ``Hercules.create(..., codec="bf16")``
(or ``compact(codec=...)`` to migrate) stores an encoded sidecar next to
the float32 rows; the out-of-core backends stream the encoded bytes and
re-check candidates against full precision, so answers stay bit-identical
while the stream shrinks to the codec's ratio. The :class:`Codec` protocol
plus :func:`register_codec` / :func:`list_codecs` make the codec set
pluggable; ``SearchConfig.codec`` (``"auto"`` follows the index) selects
per call and flows through plan-cache keys like every other config field.

**Distributed serving (dist-ooc).** ``hx.engine("dist-ooc", shards=8)``
serves one on-disk index from every device of a mesh at once: the manifest
records a shard *plan* (contiguous leaf-run row ranges balanced by rows —
:class:`ShardPlan` / :func:`shard_plan`, derivable on open for old
indexes), each device memory-maps and streams **only its own** row range,
and per-shard top-k merges through a ``shard_map`` collective whose stable
``top_k`` reproduces the single-host tie order — answers stay bit-identical
to ``local`` for every shard count, codec, and ``kernel_mode``. Telemetry
gains a per-shard ``dist`` section (see README "Distributed serving" for
the ``XLA_FLAGS=--xla_force_host_platform_device_count`` recipe).

**Telemetry.** ``QueryEngine.telemetry()`` returns the :class:`Telemetry`
dataclass-of-sections (one shape for serving counters, plan-cache, paths,
pruning, and — for disk backends — streaming/codec counters). The old
dict keys keep working as deprecated aliases:

======================================  ===================================
old dict access                         Telemetry field
======================================  ===================================
``t["backend"] / ["calls"] /``          same-named top-level fields
``["queries"] / ["wave_calls"]``
``t["plan_cache"]["hits" | ...]``       ``t.plan_cache.hits`` ...
``t["latency_s"]["total" | ...]``       ``t.latency.total`` ...
``t["paths"]["scan_eapca" | ...]``      ``t.paths.scan_eapca`` ...
``t["pruning"]["eapca_mean" | ...]``    ``t.pruning.eapca_mean`` ...
``t["ooc"]["rows_streamed" | ...]``     ``t.ooc.rows_streamed`` ... (the
                                        section is ``None`` — key absent —
                                        for in-memory backends; it now also
                                        carries ``bytes_streamed`` and the
                                        ``codec_refine_rows`` /
                                        ``codec_fallbacks`` counters)
``t["dist"]["rows_streamed" | ...]``    ``t.dist.rows_streamed`` ...
                                        (per-shard lists; ``None`` — key
                                        absent — except under ``dist-ooc``)
``t["serving"]`` (KnnServeEngine)       ``t.serving``
======================================  ===================================

Deprecated entry points (kept working; each docstring names its successor):

======================================  ===================================
old surface                             store-API successor
======================================  ===================================
``HerculesIndex.build(data, cfg)``      ``Hercules.create(path, cfg,
                                        data=data)`` (in-memory: unchanged)
``HerculesIndex.build_streaming(src)``  ``Hercules.create(path, cfg,
                                        data=src)``
``build_index_streaming(src, cfg)``     ``Hercules.create(...)`` +
                                        ``.index()``
``build_index_to_disk(src, path)``      ``Hercules.create(path, cfg,
                                        data=src)``
``save_index(index, path)``             ``Hercules.from_index(path, index)``
``load_index(path)``                    ``Hercules.open(path).index()``
``open_index(path)``                    ``Hercules.open(path)`` (``.saved``
                                        is the SavedIndex)
``make_disk_backend(name, path)``       ``Hercules.open(path).engine(name)``
======================================  ===================================

See README.md for the full tour.
"""
from repro.core.engine import (  # noqa: F401
    BACKEND_NAMES, BACKENDS, DISK_BACKEND_NAMES, BackendSpec, DistTelemetry,
    EngineConfig, LatencyTelemetry, LocalBackend, OocTelemetry,
    OutOfCoreLocalBackend, OutOfCoreScanBackend, PathsTelemetry,
    PlanCacheTelemetry, PruningTelemetry, QueryEngine, ScanBackend,
    SearchBackend, ShardedBackend, Telemetry, backend_names, dense_scan_knn,
    kernel_scan_knn, make_backend, make_disk_backend, resolve_backend_name,
)
from repro.kernels.compat import KERNEL_MODES, resolve_kernel_mode  # noqa: F401
from repro.core.index import HerculesIndex, IndexConfig  # noqa: F401
from repro.core.search import (  # noqa: F401
    KnnResult, SearchConfig, brute_force_knn, pscan_knn, wave_knn,
)
from repro.core.tree import BuildConfig, build_tree_chunked  # noqa: F401
from repro.data.pipeline import (  # noqa: F401
    ArrayChunkSource, AsyncChunkReader, ChunkSource, NpyChunkSource,
    PREFETCH_MODES, SyncChunkReader, iter_device_chunks, iter_host_chunks,
    iter_scheduled_chunks,
    make_chunk_reader,
)
from repro.serve.engine import (  # noqa: F401
    KnnAnswer, KnnFailure, KnnServeConfig, KnnServeEngine, QueueFull,
)
from repro.storage import (  # noqa: F401
    BALANCE_WARN_RATIO, CODEC_CHOICES, Codec, FORMAT_VERSION, Hercules,
    IndexFormatError, SavedIndex, ShardPlan, build_index_streaming,
    build_index_to_disk, get_codec, list_codecs, load_index, open_index,
    partition_plan, register_codec, save_index, shard_plan,
)
