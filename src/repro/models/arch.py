"""Architecture configuration shared by the whole model zoo.

One ``ArchConfig`` describes any of the 10 assigned architectures (dense /
GQA / MQA / MoE decoder-only transformers, the VLM and audio backbones, the
ssm and hybrid recurrent families). Family-specific fields are zero/empty
when unused. All configs live in ``repro/configs/<id>.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "vlm", "audio", "ssm", "hybrid"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    num_heads: int = 0            # 0 for attention-free (rwkv)
    num_kv_heads: int = 0
    head_dim: int = 0             # 0 -> d_model // num_heads

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # recurrent / hybrid
    rwkv_head_size: int = 64      # RWKV-6 head size
    window: int = 0               # local-attention window (recurrentgemma)
    block_pattern: tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    d_rnn: int = 0                # RG-LRU width (0 -> d_model)
    conv_width: int = 4

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    num_frames: int = 0           # encoder positions from the stub frontend

    # vlm (phi-3-vision)
    num_patches: int = 0
    d_patch: int = 0              # stub patch-embedding dim

    # numerics / runtime
    mlp_type: str = "swiglu"      # 'swiglu' (3 mats) | 'gelu' (2 mats)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"
    attention_impl: str = "auto"  # 'full' | 'chunked' | 'auto'
    attention_chunk: int = 1024   # kv-chunk for flash-style attention
    remat: bool = True            # checkpoint each layer in train_step
    scan_layers: bool = True      # lax.scan over stacked layer params

    # annotations
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def q_per_kv(self) -> int:
        return max(self.num_heads, 1) // max(self.num_kv_heads, 1)

    @property
    def mlp_mats(self) -> int:
        return 2 if self.mlp_type == "gelu" else 3

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head), used by
        config sanity tests and the 6*N*D roofline term."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        h, g = max(self.num_heads, 1), max(self.num_kv_heads, 1)
        attn = d * (h * hd) + 2 * d * (g * hd) + (h * hd) * d
        if self.family == "moe":
            mlp = self.num_experts * (self.mlp_mats * d * ff) + d * self.num_experts
        else:
            mlp = self.mlp_mats * d * ff
        if self.name.startswith("rwkv"):
            # time-mix: r,k,v,w,g,o (6 d^2-ish) + channel-mix 3*d*ff approx
            per_layer = 6 * d * d + 2 * d * ff + d * ff
        elif self.family == "hybrid":
            n_att = sum(1 for b in self._pattern() if b == "attn")
            n_rec = self.num_layers - n_att
            rnn = self.d_rnn or d
            att_l = attn + 3 * d * ff
            rec_l = 2 * d * rnn + 2 * rnn + rnn * d + 3 * d * ff
            return v * d + n_att * att_l + n_rec * rec_l + v * d
        elif self.family == "audio":
            dec_l = 2 * attn + 2 * d * ff  # self+cross attn, gelu mlp (2 mats)
            enc_l = attn + 2 * d * ff
            return (v * d + self.encoder_layers * enc_l
                    + self.num_layers * dec_l + v * d)
        else:
            per_layer = attn + mlp
            return v * d + self.num_layers * per_layer + v * d
        return v * d + self.num_layers * per_layer + v * d

    def active_param_count(self) -> int:
        """Parameters touched per token (= param_count for dense)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        hd = self.resolved_head_dim
        h, g = self.num_heads, self.num_kv_heads
        attn = d * (h * hd) + 2 * d * (g * hd) + (h * hd) * d
        mlp_active = (self.experts_per_token * (self.mlp_mats * d * ff)
                      + d * self.num_experts)
        per_layer = attn + mlp_active
        return self.vocab_size * d + self.num_layers * per_layer + self.vocab_size * d

    def _pattern(self) -> tuple[str, ...]:
        if not self.block_pattern:
            return ()
        reps = -(-self.num_layers // len(self.block_pattern))
        return (self.block_pattern * reps)[: self.num_layers]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic state): ssm + hybrid
LONG_CONTEXT_ARCHS = ("rwkv6-7b", "recurrentgemma-2b")
