"""Mixture-of-Experts FFN (GShard/Switch style, sort-based dispatch).

Used by granite-moe-1b-a400m (32e top-8) and moonshot-v1-16b-a3b (64e top-6).

Dispatch is the sort-based formulation (the one MaxText uses): flatten
(token, expert) assignments, sort by expert, capacity-truncate, run all
experts as one stacked einsum, combine with router weights. Under GSPMD with
experts sharded on the "model"/expert axis and tokens on "data", the
dispatch/combine gathers lower to all-to-all collectives — the EP pattern the
roofline tracks.

Capacity per expert is static: C = ceil(T * k / E * capacity_factor); tokens
beyond capacity are dropped (standard Switch behaviour), which keeps every
shape static for XLA.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.arch import ArchConfig
from repro.models.common import dense_init, maybe_shard


def init_moe(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 4)
    e, d, ff = cfg.num_experts, cfg.d_model, cfg.d_ff

    def stack(k, shape, scale):
        return jax.random.normal(k, (e, *shape), jnp.float32) * scale

    return {
        "router": dense_init(ks[0], d, e, scale=0.02),
        "w_gate": stack(ks[1], (d, ff), 1.0 / jnp.sqrt(d)),
        "w_up": stack(ks[2], (d, ff), 1.0 / jnp.sqrt(d)),
        "w_down": stack(ks[3], (ff, d), 1.0 / jnp.sqrt(ff)),
    }


def moe_capacity(cfg: ArchConfig, num_tokens: int) -> int:
    c = int(num_tokens * cfg.experts_per_token * cfg.capacity_factor
            / cfg.num_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane alignment


def _dispatch_one_group(xt, probs, cfg: ArchConfig, cap: int):
    """Sort-based dispatch for ONE token group (a batch row).

    xt (T, d); probs (T, E) fp32. Returns (disp (E, C, d), stok, slot, sw,
    keep) for the combine step. All indices are group-local, so under GSPMD
    the vmapped scatter/gather shards on the batch axis with NO collective —
    this is the group-local dispatch that replaced the global-sort dispatch
    (EXPERIMENTS.md §Perf iteration 1: the global scatter forced XLA to
    replicate + all-reduce the full (E*C, d) buffer).
    """
    t, d = xt.shape
    k = cfg.experts_per_token
    e = cfg.num_experts
    top_w, top_e = jax.lax.top_k(probs, k)                        # (T, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(t * k)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = top_w.reshape(t * k)
    order = jnp.argsort(flat_e, stable=True).astype(jnp.int32)
    se, stok, sw = flat_e[order], flat_t[order], flat_w[order]
    first = jnp.searchsorted(se, se, side="left")
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)          # drop slot

    disp = jnp.zeros((e * cap + 1, d), xt.dtype)
    disp = disp.at[slot].add(xt[stok] * keep[:, None].astype(xt.dtype))
    return disp[:-1].reshape(e, cap, d), stok, slot, sw, keep


def moe_forward(params: dict, x: jax.Array, cfg: ArchConfig) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d). Returns (out (B, S, d), aux load-balancing loss ()).

    Dispatch is **group-local per batch row** (sequence-level capacity):
    routing, sort and scatter are vmapped over B, so they shard cleanly on
    the data axes; only the expert einsum touches the expert(model)-sharded
    weights. Capacity: C = ceil(S * k / E * capacity_factor) per sequence.
    """
    b, s, d = x.shape
    e = cfg.num_experts
    cap = moe_capacity(cfg, s)

    # --- routing (fp32) ------------------------------------------------------
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)

    # Switch load-balancing auxiliary loss (global over the batch)
    me = jnp.mean(probs, axis=(0, 1))                             # (E,)
    top1 = jnp.argmax(probs, axis=-1)
    ce = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    disp, stok, slot, sw, keep = jax.vmap(
        lambda xt, pr: _dispatch_one_group(xt, pr, cfg, cap))(x, probs)
    disp = maybe_shard(disp, "moe_dispatch")                      # (B, E, C, d)

    # --- stacked expert FFN (SwiGLU); E sharded on model (EP) -----------------
    g = jnp.einsum("becd,edf->becf", disp, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", disp, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    h = maybe_shard(h, "moe_hidden")
    out_buf = jnp.einsum("becf,efd->becd", h, params["w_down"].astype(x.dtype))
    out_buf = out_buf.reshape(b, e * cap, d)

    # --- combine (vmapped gather/scatter, group-local) ------------------------
    def combine(buf, stok_g, slot_g, sw_g, keep_g):
        contrib = buf[jnp.minimum(slot_g, e * cap - 1)]
        contrib = contrib * (sw_g * keep_g).astype(buf.dtype)[:, None]
        return jnp.zeros((s, d), buf.dtype).at[stok_g].add(contrib)

    y = jax.vmap(combine)(out_buf, stok, slot, sw, keep)
    return y, aux
