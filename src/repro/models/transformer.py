"""Decoder-only transformer family: dense / GQA / MQA / MoE (+ VLM wrapper).

Covers: codeqwen1.5-7b, granite-34b, llama3-405b, minicpm-2b (dense),
granite-moe-1b-a400m, moonshot-v1-16b-a3b (moe), phi-3-vision backbone (vlm).

Structure per block (llama-style): RMSNorm -> attention (rotary, GQA) ->
residual; RMSNorm -> SwiGLU MLP or MoE -> residual. Layers run under
``lax.scan`` over stacked params (keeps the dry-run HLO size O(1) in depth)
with optional ``jax.checkpoint`` remat per layer.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import common as C
from repro.models.arch import ArchConfig
from repro.models.moe import init_moe, moe_forward


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _attn_spec(cfg: ArchConfig, seq_len: int, window: int = 0) -> C.AttnSpec:
    return C.AttnSpec(
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim, causal=True, window=window,
        impl=C.resolve_attn_impl(cfg, seq_len), chunk=cfg.attention_chunk)


def init_block(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 3)
    spec = _attn_spec(cfg, 1)
    p = {
        "ln_attn": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": C.init_attention(ks[0], cfg.d_model, spec),
        "ln_mlp": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if cfg.num_experts:
        p["moe"] = init_moe(ks[1], cfg)
    elif cfg.mlp_type == "gelu":
        ku, kd = jax.random.split(ks[1], 2)
        p["mlp"] = {
            "w_up": C.dense_init(ku, cfg.d_model, cfg.d_ff),
            "b_up": jnp.zeros((cfg.d_ff,), jnp.float32),
            "w_down": C.dense_init(kd, cfg.d_ff, cfg.d_model),
            "b_down": jnp.zeros((cfg.d_model,), jnp.float32),
        }
    else:
        kg, ku, kd = jax.random.split(ks[1], 3)
        p["mlp"] = {
            "w_gate": C.dense_init(kg, cfg.d_model, cfg.d_ff),
            "w_up": C.dense_init(ku, cfg.d_model, cfg.d_ff),
            "w_down": C.dense_init(kd, cfg.d_ff, cfg.d_model),
        }
    return p


def init_params(key, cfg: ArchConfig) -> dict:
    k_embed, k_blocks, k_head, k_extra = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_blocks, cfg.num_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(layer_keys)
    params = {
        "embed": C.embed_init(k_embed, cfg.vocab_size, cfg.d_model),
        "blocks": blocks,                       # stacked: leading dim L
        "ln_final": jnp.zeros((cfg.d_model,), jnp.float32),
        "lm_head": C.dense_init(k_head, cfg.d_model, cfg.vocab_size, scale=0.02),
    }
    if cfg.family == "vlm":
        params["patch_proj"] = C.dense_init(k_extra, cfg.d_patch, cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# block forward (shared by train / prefill / decode)
# ---------------------------------------------------------------------------

def _block_fwd(p: dict, x: jax.Array, positions: jax.Array, cfg: ArchConfig,
               spec: C.AttnSpec):
    h = C.rms_norm(x, p["ln_attn"], cfg.norm_eps)
    x = x + C.attention_forward(p["attn"], h, positions, spec, cfg.rope_theta)
    h = C.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    if cfg.num_experts:
        y, aux = moe_forward(p["moe"], h, cfg)
    else:
        y = _mlp(p["mlp"], h, cfg)
        aux = jnp.float32(0.0)
    return x + y, aux


def _mlp(mp: dict, h, cfg: ArchConfig):
    if cfg.mlp_type == "gelu":
        return C.gelu_mlp(h, mp["w_up"], mp["b_up"], mp["w_down"], mp["b_down"])
    return C.swiglu(h, mp["w_gate"], mp["w_up"], mp["w_down"])


def embed_inputs(params: dict, batch: dict, cfg: ArchConfig,
                 dtype) -> jax.Array:
    """Token embeddings; VLM prepends projected patch embeddings (stub
    frontend supplies ``patch_embeds`` (B, P, d_patch))."""
    x = params["embed"].astype(dtype)[batch["tokens"]]
    x = x * jnp.sqrt(cfg.d_model).astype(dtype)
    if cfg.family == "vlm":
        patches = batch["patch_embeds"].astype(dtype)
        proj = jnp.dot(patches, params["patch_proj"].astype(dtype))
        x = jnp.concatenate([proj, x], axis=1)
    return x


def forward(params: dict, batch: dict, cfg: ArchConfig) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward -> (logits (B, S_total, V), aux loss)."""
    dtype = jnp.dtype(cfg.dtype)
    x = embed_inputs(params, batch, cfg, dtype)
    b, s, _ = x.shape
    positions = jnp.arange(s)
    spec = _attn_spec(cfg, s, window=cfg.window)
    x = C.maybe_shard(x, "act_btd")

    def layer(x, p):
        x = C.grad_cast(x, dtype)           # bf16 backward residual traffic
        y, aux = _block_fwd(p, x, positions, cfg, spec)
        y = C.maybe_shard(y, "act_btd")
        return y, aux

    if cfg.remat:
        layer = jax.checkpoint(layer, prevent_cse=False)
    if cfg.scan_layers:
        x, auxs = jax.lax.scan(lambda c, p: layer(c, p), x, params["blocks"])
        aux = jnp.sum(auxs)
    else:
        aux = jnp.float32(0.0)
        for i in range(cfg.num_layers):
            p = jax.tree.map(lambda a: a[i], params["blocks"])
            x, a = layer(x, p)
            aux = aux + a

    x = C.rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = jnp.dot(x, params["lm_head"].astype(dtype),
                     preferred_element_type=jnp.float32)
    return logits, aux


# ---------------------------------------------------------------------------
# KV-cache serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch_size: int, max_seq: int,
               dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    smax = min(max_seq, cfg.window) if cfg.window else max_seq
    shape = (cfg.num_layers, batch_size, smax, cfg.num_kv_heads,
             cfg.resolved_head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((batch_size,), jnp.int32),
    }


def prefill(params: dict, batch: dict, cfg: ArchConfig, cache: dict):
    """Run the full prompt, fill the cache, return (last-position logits, cache)."""
    dtype = jnp.dtype(cfg.dtype)
    x = embed_inputs(params, batch, cfg, dtype)
    b, s, _ = x.shape
    positions = jnp.arange(s)
    spec = _attn_spec(cfg, s, window=cfg.window)
    x = C.maybe_shard(x, "act_btd")

    def layer(x, p):
        h = C.rms_norm(x, p["ln_attn"], cfg.norm_eps)
        k, v = C.project_kv(p["attn"], h, positions, spec, cfg.rope_theta)
        x, _ = _block_fwd(p, x, positions, cfg, spec)
        x = C.maybe_shard(x, "act_btd")
        return x, (k, v)

    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(layer, x, params["blocks"])
    else:
        outs = []
        for i in range(cfg.num_layers):
            p = jax.tree.map(lambda a: a[i], params["blocks"])
            x, kv = layer(x, p)
            outs.append(kv)
        ks = jnp.stack([o[0] for o in outs])
        vs = jnp.stack([o[1] for o in outs])
    x = C.rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = jnp.dot(C.last_token_slice(x, batch),
                     params["lm_head"].astype(dtype),
                     preferred_element_type=jnp.float32)
    smax = cache["k"].shape[2]
    if cfg.window and s > smax:                      # keep last window only
        ks, vs = ks[:, :, -smax:], vs[:, :, -smax:]
        write = smax
    else:
        write = min(s, smax)
    cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], ks[:, :, -write:].astype(cache["k"].dtype), (0, 0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], vs[:, :, -write:].astype(cache["v"].dtype), (0, 0, 0, 0, 0)),
        "pos": jnp.full((b,), s, jnp.int32),
    }
    return logits, cache


def decode_step(params: dict, tokens: jax.Array, cfg: ArchConfig, cache: dict):
    """One token step. tokens (B, 1). Returns (logits (B, 1, V), new cache)."""
    dtype = jnp.dtype(cfg.dtype)
    b = tokens.shape[0]
    x = params["embed"].astype(dtype)[tokens] * jnp.sqrt(cfg.d_model).astype(dtype)
    pos = cache["pos"]
    spec = _attn_spec(cfg, 1, window=cfg.window)

    def layer(x, xs):
        p, ck, cv = xs
        h = C.rms_norm(x, p["ln_attn"], cfg.norm_eps)
        att, ck, cv = C.attention_decode_step(
            p["attn"], h, ck, cv, pos, spec, cfg.rope_theta)
        x = x + att
        h = C.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
        if cfg.num_experts:
            y, _ = moe_forward(p["moe"], h, cfg)
        else:
            y = _mlp(p["mlp"], h, cfg)
        return x + y, (ck, cv)

    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(
            layer, x, (params["blocks"], cache["k"], cache["v"]))
    else:
        outs = []
        for i in range(cfg.num_layers):
            xs_i = jax.tree.map(lambda a: a[i],
                                (params["blocks"], cache["k"], cache["v"]))
            x, kv = layer(x, xs_i)
            outs.append(kv)
        ks = jnp.stack([o[0] for o in outs])
        vs = jnp.stack([o[1] for o in outs])
    x = C.rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = jnp.dot(x, params["lm_head"].astype(dtype),
                     preferred_element_type=jnp.float32)
    return logits, {"k": ks, "v": vs, "pos": pos + 1}
