"""Whisper-large-v3 backbone (arXiv:2212.04356): encoder-decoder transformer.

The conv1d audio frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, num_frames=1500, d_model) — the
output the two conv layers would produce from the mel spectrogram. Everything
after that (32 encoder layers, 32 decoder layers with cross-attention, tied
embedding head) is implemented fully.

Layers: pre-LayerNorm blocks with GELU MLPs and learned positional
embeddings, per the paper. Decode uses self-KV caches plus cross-K/V computed
once from the encoder memory at prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as C
from repro.models.arch import ArchConfig


def _sinusoid(positions: jax.Array, d: int, dtype) -> jax.Array:
    """Length-generic sinusoidal positions (Whisper's encoder embedding; used
    for the decoder too so the assignment's 32k-token decoder shapes lower —
    real Whisper caps decoder positions at 448, noted in DESIGN.md)."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _spec(cfg: ArchConfig, seq_len: int, causal: bool) -> C.AttnSpec:
    return C.AttnSpec(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                      head_dim=cfg.resolved_head_dim, causal=causal,
                      impl=C.resolve_attn_impl(cfg, seq_len),
                      chunk=cfg.attention_chunk)


def _init_mlp(key, d, ff):
    k1, k2 = jax.random.split(key)
    return {"w_up": C.dense_init(k1, d, ff), "b_up": jnp.zeros((ff,), jnp.float32),
            "w_down": C.dense_init(k2, ff, d), "b_down": jnp.zeros((d,), jnp.float32)}


def _init_enc_layer(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "ln1_w": jnp.ones((d,), jnp.float32), "ln1_b": jnp.zeros((d,), jnp.float32),
        "attn": C.init_attention(k1, d, _spec(cfg, 1, False)),
        "ln2_w": jnp.ones((d,), jnp.float32), "ln2_b": jnp.zeros((d,), jnp.float32),
        "mlp": _init_mlp(k2, d, cfg.d_ff),
    }


def _init_dec_layer(key, cfg: ArchConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "ln1_w": jnp.ones((d,), jnp.float32), "ln1_b": jnp.zeros((d,), jnp.float32),
        "self_attn": C.init_attention(k1, d, _spec(cfg, 1, True)),
        "ln2_w": jnp.ones((d,), jnp.float32), "ln2_b": jnp.zeros((d,), jnp.float32),
        "cross_attn": C.init_attention(k2, d, _spec(cfg, 1, False)),
        "ln3_w": jnp.ones((d,), jnp.float32), "ln3_b": jnp.zeros((d,), jnp.float32),
        "mlp": _init_mlp(k3, d, cfg.d_ff),
    }


def init_params(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    d = cfg.d_model
    return {
        "embed": C.embed_init(ks[2], cfg.vocab_size, d),    # tied head
        "enc": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "dec": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "ln_enc_w": jnp.ones((d,), jnp.float32), "ln_enc_b": jnp.zeros((d,), jnp.float32),
        "ln_dec_w": jnp.ones((d,), jnp.float32), "ln_dec_b": jnp.zeros((d,), jnp.float32),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(params: dict, frames: jax.Array, cfg: ArchConfig) -> jax.Array:
    """frames: (B, F, d) stub frontend output -> encoder memory (B, F, d)."""
    dtype = jnp.dtype(cfg.dtype)
    f = frames.shape[1]
    x = frames.astype(dtype) + _sinusoid(jnp.arange(f), cfg.d_model, dtype)[None]
    spec = _spec(cfg, f, causal=False)
    positions = jnp.arange(f)

    def layer(x, p):
        h = C.layer_norm(x, p["ln1_w"], p["ln1_b"], cfg.norm_eps)
        x = x + C.attention_forward(p["attn"], h, positions, spec, rope_theta=0.0)
        h = C.layer_norm(x, p["ln2_w"], p["ln2_b"], cfg.norm_eps)
        x = x + C.gelu_mlp(h, p["mlp"]["w_up"], p["mlp"]["b_up"],
                           p["mlp"]["w_down"], p["mlp"]["b_down"])
        return C.maybe_shard(x, "act_btd"), None

    if cfg.remat:
        layer = jax.checkpoint(layer, prevent_cse=False)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(layer, x, params["enc"])
    else:
        for i in range(cfg.encoder_layers):
            x, _ = layer(x, jax.tree.map(lambda a: a[i], params["enc"]))
    return C.layer_norm(x, params["ln_enc_w"], params["ln_enc_b"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------

def _dec_layer_full(p, x, memory, positions, mem_pos, cfg, spec_self, spec_cross):
    h = C.layer_norm(x, p["ln1_w"], p["ln1_b"], cfg.norm_eps)
    x = x + C.attention_forward(p["self_attn"], h, positions, spec_self,
                                rope_theta=0.0)
    h = C.layer_norm(x, p["ln2_w"], p["ln2_b"], cfg.norm_eps)
    mk, mv = C.project_kv(p["cross_attn"], memory, mem_pos, spec_cross,
                          rope_theta=0.0)
    x = x + C.attention_forward(p["cross_attn"], h, positions, spec_cross,
                                rope_theta=0.0, kv_override=(mk, mv, mem_pos))
    h = C.layer_norm(x, p["ln3_w"], p["ln3_b"], cfg.norm_eps)
    x = x + C.gelu_mlp(h, p["mlp"]["w_up"], p["mlp"]["b_up"],
                       p["mlp"]["w_down"], p["mlp"]["b_down"])
    return C.maybe_shard(x, "act_btd")


def forward(params: dict, batch: dict, cfg: ArchConfig):
    """Teacher-forced training forward.

    batch: frames (B, F, d) stub embeddings; tokens (B, S) decoder input.
    Returns (logits (B, S, V), aux).
    """
    dtype = jnp.dtype(cfg.dtype)
    memory = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"].astype(dtype)[tokens] + \
        _sinusoid(jnp.arange(s), cfg.d_model, dtype)[None]
    positions = jnp.arange(s)
    mem_pos = jnp.arange(memory.shape[1])
    spec_self = _spec(cfg, s, causal=True)
    spec_cross = _spec(cfg, memory.shape[1], causal=False)

    def layer(x, p):
        return _dec_layer_full(p, x, memory, positions, mem_pos, cfg,
                               spec_self, spec_cross), None

    if cfg.remat:
        layer = jax.checkpoint(layer, prevent_cse=False)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(layer, x, params["dec"])
    else:
        for i in range(cfg.num_layers):
            x, _ = layer(x, jax.tree.map(lambda a: a[i], params["dec"]))
    x = C.layer_norm(x, params["ln_dec_w"], params["ln_dec_b"], cfg.norm_eps)
    logits = jnp.dot(x, params["embed"].T.astype(dtype),
                     preferred_element_type=jnp.float32)
    return logits, jnp.float32(0.0)


def init_cache(cfg: ArchConfig, batch_size: int, max_seq: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    l = cfg.num_layers
    return {
        "k": jnp.zeros((l, batch_size, max_seq, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((l, batch_size, max_seq, cfg.num_kv_heads, hd), dtype),
        "cross_k": jnp.zeros((l, batch_size, cfg.num_frames, cfg.num_kv_heads, hd), dtype),
        "cross_v": jnp.zeros((l, batch_size, cfg.num_frames, cfg.num_kv_heads, hd), dtype),
        "pos": jnp.zeros((batch_size,), jnp.int32),
    }


def prefill(params: dict, batch: dict, cfg: ArchConfig, cache: dict):
    """Encode audio, precompute cross-K/V, run the decoder prompt."""
    dtype = jnp.dtype(cfg.dtype)
    memory = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"].astype(dtype)[tokens] + \
        _sinusoid(jnp.arange(s), cfg.d_model, dtype)[None]
    positions = jnp.arange(s)
    mem_pos = jnp.arange(memory.shape[1])
    spec_self = _spec(cfg, s, causal=True)
    spec_cross = _spec(cfg, memory.shape[1], causal=False)

    def layer(x, p):
        h = C.layer_norm(x, p["ln1_w"], p["ln1_b"], cfg.norm_eps)
        sk, sv = C.project_kv(p["self_attn"], h, positions, spec_self, 0.0)
        mk, mv = C.project_kv(p["cross_attn"], memory, mem_pos, spec_cross, 0.0)
        x = _dec_layer_full(p, x, memory, positions, mem_pos, cfg,
                            spec_self, spec_cross)
        return x, (sk, sv, mk, mv)

    if cfg.scan_layers:
        x, (sk, sv, mk, mv) = jax.lax.scan(layer, x, params["dec"])
    else:
        outs = []
        for i in range(cfg.num_layers):
            x, ys = layer(x, jax.tree.map(lambda a: a[i], params["dec"]))
            outs.append(ys)
        sk, sv, mk, mv = (jnp.stack([o[j] for o in outs]) for j in range(4))
    x = C.layer_norm(C.last_token_slice(x, batch),
                     params["ln_dec_w"], params["ln_dec_b"],
                     cfg.norm_eps)
    logits = jnp.dot(x, params["embed"].T.astype(dtype),
                     preferred_element_type=jnp.float32)
    smax = cache["k"].shape[2]
    write = min(s, smax)
    cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], sk[:, :, :write].astype(cache["k"].dtype), (0, 0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], sv[:, :, :write].astype(cache["v"].dtype), (0, 0, 0, 0, 0)),
        "cross_k": mk.astype(cache["cross_k"].dtype),
        "cross_v": mv.astype(cache["cross_v"].dtype),
        "pos": jnp.full((b,), s, jnp.int32),
    }
    return logits, cache


def decode_step(params: dict, tokens: jax.Array, cfg: ArchConfig, cache: dict):
    dtype = jnp.dtype(cfg.dtype)
    b = tokens.shape[0]
    pos = cache["pos"]
    x = params["embed"].astype(dtype)[tokens] + \
        _sinusoid(pos, cfg.d_model, dtype)[:, None]
    spec_self = _spec(cfg, 1, causal=True)
    spec_cross = _spec(cfg, 1, causal=False)
    mem_pos_ok = jnp.ones((b,), jnp.int32) * cfg.num_frames

    def layer(x, xs):
        p, ck, cv, mk, mv = xs
        h = C.layer_norm(x, p["ln1_w"], p["ln1_b"], cfg.norm_eps)
        att, ck, cv = C.attention_decode_step(p["self_attn"], h, ck, cv, pos,
                                              spec_self, rope_theta=0.0)
        x = x + att
        h = C.layer_norm(x, p["ln2_w"], p["ln2_b"], cfg.norm_eps)
        # cross attention: all memory positions valid
        catt, _, _ = C.attention_decode_step(
            p["cross_attn"], h, mk, mv, mem_pos_ok - 1, spec_cross,
            rope_theta=0.0, update_cache=False)
        x = x + catt
        h = C.layer_norm(x, p["ln3_w"], p["ln3_b"], cfg.norm_eps)
        x = x + C.gelu_mlp(h, p["mlp"]["w_up"], p["mlp"]["b_up"],
                           p["mlp"]["w_down"], p["mlp"]["b_down"])
        return x, (ck, cv)

    xs_all = (params["dec"], cache["k"], cache["v"],
              cache["cross_k"], cache["cross_v"])
    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(layer, x, xs_all)
    else:
        outs = []
        for i in range(cfg.num_layers):
            x, ys = layer(x, jax.tree.map(lambda a: a[i], xs_all))
            outs.append(ys)
        ks = jnp.stack([o[0] for o in outs])
        vs = jnp.stack([o[1] for o in outs])
    x = C.layer_norm(x, params["ln_dec_w"], params["ln_dec_b"], cfg.norm_eps)
    logits = jnp.dot(x, params["embed"].T.astype(dtype),
                     preferred_element_type=jnp.float32)
    return logits, {"k": ks, "v": vs, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"], "pos": pos + 1}
