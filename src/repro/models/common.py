"""Shared model building blocks (pure JAX, params = nested dicts of arrays).

Conventions:
  * ``init_*`` take a PRNG key and return a params pytree (fp32 by default —
    the train step decides the compute dtype).
  * forward functions take (params, x, cfg) and are shape-polymorphic over
    batch/sequence.
  * Attention supports GQA/MQA, rotary embeddings, three execution modes:
    full (materialized scores), chunked (flash-style streaming softmax over
    KV blocks — required for 32k+ contexts), and decode (single query
    position against a cache).
  * Sharding is NOT baked in here; the distributed layer applies
    ``with_sharding_constraint`` via logical annotations (see
    repro/distributed/sharding.py). Layers call ``maybe_shard`` hooks.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.arch import ArchConfig

Params = dict
# annotation hook installed by the distributed layer; identity by default
_SHARD_HOOK: list[Callable[[jax.Array, str], jax.Array]] = []


def maybe_shard(x: jax.Array, logical: str) -> jax.Array:
    """Apply the installed logical-sharding annotation hook (if any)."""
    for hook in _SHARD_HOOK:
        x = hook(x, logical)
    return x


def set_shard_hook(fn: Callable[[jax.Array, str], jax.Array] | None) -> None:
    _SHARD_HOOK.clear()
    if fn is not None:
        _SHARD_HOOK.append(fn)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def grad_cast(x: jax.Array, dtype) -> jax.Array:
    """Identity forward; casts the COTANGENT to ``dtype`` on the way back.

    The fp32 loss head emits fp32 cotangents that ride the residual stream
    through every layer's TP all-reduces at 2x the bytes (EXPERIMENTS.md
    §Perf iteration 3b). A barrier per layer keeps backward activation
    traffic in the compute dtype — the standard mixed-precision discipline.
    """
    return x


def _grad_cast_fwd(x, dtype):
    return x, None


def _grad_cast_bwd(dtype, _, ct):
    return (ct.astype(dtype),)


grad_cast.defvjp(_grad_cast_fwd, _grad_cast_bwd)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(in_dim))
    return jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale


def embed_init(key, vocab: int, dim: int):
    return jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(jnp.square(x32), axis=-1, keepdims=True) + eps)
    return ((x32 * scale) * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def last_token_slice(x: jax.Array, batch: dict) -> jax.Array:
    """(B, 1, D) hidden state at each sequence's last *real* token.

    Ragged serving waves right-pad ``batch["tokens"]`` and pass
    ``batch["lens"]`` (B,) with the true prompt lengths; the logits the
    sampler needs then live at column ``lens - 1`` (plus any frontend
    prefix — e.g. VLM patch embeddings — preceding the tokens), not at
    the padded final column. Without ``lens`` this is ``x[:, -1:]``.
    """
    lens = batch.get("lens")
    if lens is None:
        return x[:, -1:]
    off = x.shape[1] - batch["tokens"].shape[1]
    idx = off + jnp.asarray(lens, jnp.int32) - 1
    return jnp.take_along_axis(x, idx[:, None, None], axis=1)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.dot(x, w_gate.astype(x.dtype))
    u = jnp.dot(x, w_up.astype(x.dtype))
    h = jax.nn.silu(g) * u
    h = maybe_shard(h, "act_ff")
    return jnp.dot(h, w_down.astype(x.dtype))


def gelu_mlp(x: jax.Array, w_up: jax.Array, b_up: jax.Array,
             w_down: jax.Array, b_down: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.dot(x, w_up.astype(x.dtype)) + b_up.astype(x.dtype))
    h = maybe_shard(h, "act_ff")
    return jnp.dot(h, w_down.astype(x.dtype)) + b_down.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                     # (hd/2,)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, hd/2)
        ang = ang[None, :, None, :]
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    causal: bool = True
    window: int = 0               # 0 = global; >0 = local (sliding) window
    impl: str = "full"            # 'full' | 'chunked'
    chunk: int = 1024


def init_attention(key, d_model: int, spec: AttnSpec) -> Params:
    ks = jax.random.split(key, 4)
    hd = spec.head_dim
    return {
        "wq": dense_init(ks[0], d_model, spec.num_heads * hd),
        "wk": dense_init(ks[1], d_model, spec.num_kv_heads * hd),
        "wv": dense_init(ks[2], d_model, spec.num_kv_heads * hd),
        "wo": dense_init(ks[3], spec.num_heads * hd, d_model),
    }


def _expand_kv(k: jax.Array, q_per_kv: int) -> jax.Array:
    """(B, S, G, hd) -> (B, S, G*q_per_kv, hd) by repeat (GQA)."""
    if q_per_kv == 1:
        return k
    return jnp.repeat(k, q_per_kv, axis=2)


def _mask_bias(q_pos, k_pos, causal: bool, window: int) -> jax.Array:
    """additive bias (..., Sq, Sk) in fp32: 0 allowed / -inf masked."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def attention_full(q, k, v, q_pos, k_pos, spec: AttnSpec):
    """Materialized-scores attention. q (B,Sq,H,hd); k,v (B,Sk,G,hd)."""
    k = _expand_kv(k, spec.num_heads // spec.num_kv_heads)
    v = _expand_kv(v, spec.num_heads // spec.num_kv_heads)
    scale = 1.0 / jnp.sqrt(spec.head_dim).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits += _mask_bias(q_pos, k_pos, spec.causal, spec.window)[None, None]
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention_chunked(q, k, v, q_pos, k_pos, spec: AttnSpec):
    """Flash-style streaming softmax over KV chunks (no Sq x Sk buffer).

    Memory: O(Sq * chunk) per step instead of O(Sq * Sk). This is the XLA
    formulation of the fused-attention schedule; the Pallas version would tile
    the same loop into VMEM (DESIGN.md §6).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    c = min(spec.chunk, sk)
    if sk % c:
        raise ValueError(f"kv length {sk} not divisible by chunk {c}")
    k = _expand_kv(k, spec.num_heads // spec.num_kv_heads)
    v = _expand_kv(v, spec.num_heads // spec.num_kv_heads)
    scale = 1.0 / jnp.sqrt(spec.head_dim).astype(jnp.float32)
    kc = k.reshape(b, sk // c, c, h, hd)
    vc = v.reshape(b, sk // c, c, h, hd)
    kpc = k_pos.reshape(sk // c, c)

    def step(carry, xs):
        m, l, acc = carry                          # (B,H,Sq), (B,H,Sq), (B,H,Sq,hd)
        kb, vb, kp = xs                            # (B,c,H,hd), (B,c,H,hd), (c,)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kb,
                            preferred_element_type=jnp.float32) * scale
        logits += _mask_bias(q_pos, kp, spec.causal, spec.window)[None, None]
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        # guard fully-masked rows (all -inf): keep m finite
        m_new = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vb).astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), kpc))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)     # (B,Sq,H,hd)


def attention_decode(q, k_cache, v_cache, pos, spec: AttnSpec):
    """Single-position decode. q (B,1,H,hd); caches (B,Smax,G,hd); pos (B,).

    Masks cache slots >= pos+1 (and outside the local window when set).
    The cache stays SEQUENCE-sharded end to end (constraints below): without
    them GSPMD re-shards the expanded KV by heads, all-gathering the full
    32k cache every layer (EXPERIMENTS.md §Perf iteration 4). Softmax over
    the sharded S axis costs only O(B*H) reduction bytes.
    """
    b, _, h, hd = q.shape
    smax = k_cache.shape[1]
    k = _expand_kv(k_cache, spec.num_heads // spec.num_kv_heads)
    v = _expand_kv(v_cache, spec.num_heads // spec.num_kv_heads)
    k = maybe_shard(k, "kv_seq")
    v = maybe_shard(v, "kv_seq")
    scale = 1.0 / jnp.sqrt(spec.head_dim).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = maybe_shard(logits, "decode_scores")
    kpos = jnp.arange(smax)
    ok = kpos[None, :] <= pos[:, None]
    if spec.window > 0:
        ok &= (pos[:, None] - kpos[None, :]) < spec.window
    logits = jnp.where(ok[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    probs = maybe_shard(probs, "decode_scores")
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention_forward(params: Params, x: jax.Array, positions: jax.Array,
                      spec: AttnSpec, rope_theta: float = 10000.0,
                      kv_override: tuple | None = None) -> jax.Array:
    """Self-attention over a full sequence (train/prefill).

    ``kv_override`` supplies external (k, v, k_pos) for cross-attention.
    """
    b, s, _ = x.shape
    hd = spec.head_dim
    q = jnp.dot(x, params["wq"].astype(x.dtype)).reshape(b, s, spec.num_heads, hd)
    if kv_override is None:
        k = jnp.dot(x, params["wk"].astype(x.dtype)).reshape(b, s, spec.num_kv_heads, hd)
        v = jnp.dot(x, params["wv"].astype(x.dtype)).reshape(b, s, spec.num_kv_heads, hd)
        if rope_theta > 0:
            q = apply_rope(q, positions, rope_theta)
            k = apply_rope(k, positions, rope_theta)
        k_pos = positions
    else:
        k, v, k_pos = kv_override
    q = maybe_shard(q, "act_heads")
    impl = spec.impl
    if impl != "full" and k.shape[1] % min(spec.chunk, k.shape[1]):
        impl = "full"                 # ragged KV (e.g. 1500-frame memory)
    if impl == "full":
        out = attention_full(q, k, v, positions, k_pos, spec)
    else:
        out = attention_chunked(q, k, v, positions, k_pos, spec)
    out = out.reshape(b, s, spec.num_heads * hd)
    return jnp.dot(out, params["wo"].astype(x.dtype))


def project_kv(params: Params, x: jax.Array, positions: jax.Array,
               spec: AttnSpec, rope_theta: float) -> tuple[jax.Array, jax.Array]:
    """K/V projections only (used to fill caches / cross-attention memory)."""
    b, s, _ = x.shape
    hd = spec.head_dim
    k = jnp.dot(x, params["wk"].astype(x.dtype)).reshape(b, s, spec.num_kv_heads, hd)
    v = jnp.dot(x, params["wv"].astype(x.dtype)).reshape(b, s, spec.num_kv_heads, hd)
    if rope_theta > 0:
        k = apply_rope(k, positions, rope_theta)
    return k, v


def attention_decode_step(params: Params, x: jax.Array, cache_k, cache_v,
                          pos, spec: AttnSpec, rope_theta: float = 10000.0,
                          update_cache: bool = True):
    """One decode step. x (B,1,d); caches (B,Smax,G,hd); pos (B,) current index.

    Returns (out (B,1,d), new_k, new_v).
    """
    b = x.shape[0]
    hd = spec.head_dim
    q = jnp.dot(x, params["wq"].astype(x.dtype)).reshape(b, 1, spec.num_heads, hd)
    if rope_theta > 0:
        q = apply_rope(q, pos[:, None], rope_theta)
    if update_cache:
        k_new = jnp.dot(x, params["wk"].astype(x.dtype)).reshape(b, 1, spec.num_kv_heads, hd)
        v_new = jnp.dot(x, params["wv"].astype(x.dtype)).reshape(b, 1, spec.num_kv_heads, hd)
        if rope_theta > 0:
            k_new = apply_rope(k_new, pos[:, None], rope_theta)
        # Lockstep decode (all slots share one step counter — the serving
        # engine prefills per wave, so positions are batch-uniform): a scalar
        # dynamic_update_slice lets GSPMD mask-update the owning shard of the
        # sequence-sharded cache instead of replicating it for a batched
        # scatter (EXPERIMENTS.md §Perf iteration 4: 16x less decode
        # collective traffic on llama3-405b).
        slot = pos[0] if spec.window <= 0 else pos[0] % cache_k.shape[1]
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k_new.astype(cache_k.dtype), (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v_new.astype(cache_v.dtype), (0, slot, 0, 0))
    if spec.window > 0:
        # ring buffer: reconstruct absolute positions of slots
        smax = cache_k.shape[1]
        slots = jnp.arange(smax)
        # absolute position of slot s given current pos p (ring of size smax):
        # latest write at p%smax; slot holds p - ((p%smax - s) mod smax)
        abs_pos = pos[:, None] - ((pos[:, None] % smax - slots[None, :]) % smax)
        logits_ok = (abs_pos >= 0) & (abs_pos <= pos[:, None])
        out = _ring_decode(q, cache_k, cache_v, logits_ok, spec)
    else:
        out = attention_decode(q, cache_k, cache_v, pos, spec)
    out = out.reshape(b, 1, spec.num_heads * hd)
    return jnp.dot(out, params["wo"].astype(x.dtype)), cache_k, cache_v


def _ring_decode(q, k_cache, v_cache, ok, spec: AttnSpec):
    k = _expand_kv(k_cache, spec.num_heads // spec.num_kv_heads)
    v = _expand_kv(v_cache, spec.num_heads // spec.num_kv_heads)
    scale = 1.0 / jnp.sqrt(spec.head_dim).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(ok[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def resolve_attn_impl(cfg: ArchConfig, seq_len: int) -> str:
    if cfg.attention_impl != "auto":
        return cfg.attention_impl
    return "chunked" if seq_len > 2048 else "full"
