"""RWKV-6 "Finch" (rwkv6-7b): attention-free LM with data-dependent decay.

Per layer: a **time-mix** block (token-shift lerps, r/k/v/g projections, the
data-dependent per-channel decay ``w = exp(-exp(w0 + tanh(x A) B))``, the WKV
recurrence with bonus ``u``, grouped-head output norm, silu(g) gating) and a
**channel-mix** block (squared-relu FFN gated by sigmoid(r)). This follows
arXiv:2404.05892; the data-dependent token-shift LoRA ("ddlerp") is
simplified to static lerp coefficients (noted in DESIGN.md — it does not
change the compute/memory shape of the recurrence, which is what the
roofline sees).

The WKV recurrence runs as a jnp ``lax.scan`` (XLA path, used by dry-run) or
the Pallas chunked kernel (kernels/wkv6.py) when ``use_kernel=True``. Decode
carries O(1) state per layer — this is why rwkv6-7b runs the ``long_500k``
shape that dense-attention archs must skip.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ref import wkv6_ref
from repro.kernels.wkv6 import wkv6 as wkv6_kernel
from repro.models import common as C
from repro.models.arch import ArchConfig

_DECAY_LORA = 64


def _heads(cfg: ArchConfig) -> int:
    return cfg.d_model // cfg.rwkv_head_size


def init_layer(key, cfg: ArchConfig) -> dict:
    d, ff, hs = cfg.d_model, cfg.d_ff, cfg.rwkv_head_size
    h = _heads(cfg)
    ks = jax.random.split(key, 12)
    return {
        "ln1_w": jnp.ones((d,), jnp.float32), "ln1_b": jnp.zeros((d,), jnp.float32),
        "ln2_w": jnp.ones((d,), jnp.float32), "ln2_b": jnp.zeros((d,), jnp.float32),
        "tm": {
            "mu_r": jnp.full((d,), 0.5, jnp.float32),
            "mu_k": jnp.full((d,), 0.5, jnp.float32),
            "mu_v": jnp.full((d,), 0.5, jnp.float32),
            "mu_w": jnp.full((d,), 0.5, jnp.float32),
            "mu_g": jnp.full((d,), 0.5, jnp.float32),
            "w_r": C.dense_init(ks[0], d, d),
            "w_k": C.dense_init(ks[1], d, d),
            "w_v": C.dense_init(ks[2], d, d),
            "w_g": C.dense_init(ks[3], d, d),
            "w_o": C.dense_init(ks[4], d, d),
            "w0": jnp.zeros((d,), jnp.float32) - 0.6,   # decay bias
            "w_lora_a": C.dense_init(ks[5], d, _DECAY_LORA, scale=0.01),
            "w_lora_b": C.dense_init(ks[6], _DECAY_LORA, d, scale=0.01),
            "u": jax.random.normal(ks[7], (h, hs), jnp.float32) * 0.1,
            "gn_w": jnp.ones((d,), jnp.float32),
            "gn_b": jnp.zeros((d,), jnp.float32),
        },
        "cm": {
            "mu_k": jnp.full((d,), 0.5, jnp.float32),
            "mu_r": jnp.full((d,), 0.5, jnp.float32),
            "w_k": C.dense_init(ks[8], d, ff),
            "w_v": C.dense_init(ks[9], ff, d),
            "w_r": C.dense_init(ks[10], d, d),
        },
    }


def init_params(key, cfg: ArchConfig) -> dict:
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_blocks, cfg.num_layers)
    return {
        "embed": C.embed_init(k_embed, cfg.vocab_size, cfg.d_model),
        "ln0_w": jnp.ones((cfg.d_model,), jnp.float32),
        "ln0_b": jnp.zeros((cfg.d_model,), jnp.float32),
        "blocks": jax.vmap(lambda k: init_layer(k, cfg))(layer_keys),
        "lnf_w": jnp.ones((cfg.d_model,), jnp.float32),
        "lnf_b": jnp.zeros((cfg.d_model,), jnp.float32),
        "lm_head": C.dense_init(k_head, cfg.d_model, cfg.vocab_size, scale=0.02),
    }


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _lerp(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _decay(tm: dict, xw: jax.Array) -> jax.Array:
    """data-dependent decay in (0,1): exp(-exp(w0 + tanh(x A) B))."""
    lora = jnp.dot(jnp.tanh(jnp.dot(xw.astype(jnp.float32), tm["w_lora_a"])),
                   tm["w_lora_b"])
    return jnp.exp(-jnp.exp(tm["w0"] + lora))


def _group_norm(x: jax.Array, w, b, heads: int, eps: float = 1e-5):
    """Per-head LayerNorm over the head channel (RWKV's GroupNorm)."""
    b_, t, d = x.shape
    xh = x.reshape(b_, t, heads, d // heads).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(b_, t, d) * w + b).astype(x.dtype)


def time_mix(tm: dict, x: jax.Array, x_prev: jax.Array, state: jax.Array,
             cfg: ArchConfig, use_kernel: bool = False):
    """x (B,T,d); x_prev (B,d) token before the window; state (B,H,K,V).

    Returns (out (B,T,d), last x (B,d), new state).
    """
    bsz, t, d = x.shape
    h, hs = _heads(cfg), cfg.rwkv_head_size
    xs = jnp.concatenate([x_prev[:, None].astype(x.dtype), x[:, :-1]], axis=1)
    r = jnp.dot(_lerp(x, xs, tm["mu_r"]), tm["w_r"].astype(x.dtype))
    k = jnp.dot(_lerp(x, xs, tm["mu_k"]), tm["w_k"].astype(x.dtype))
    v = jnp.dot(_lerp(x, xs, tm["mu_v"]), tm["w_v"].astype(x.dtype))
    g = jnp.dot(_lerp(x, xs, tm["mu_g"]), tm["w_g"].astype(x.dtype))
    w = _decay(tm, _lerp(x, xs, tm["mu_w"]))

    rh = r.reshape(bsz, t, h, hs).astype(jnp.float32)
    kh = k.reshape(bsz, t, h, hs).astype(jnp.float32)
    vh = v.reshape(bsz, t, h, hs).astype(jnp.float32)
    wh = w.reshape(bsz, t, h, hs)
    fn = wkv6_kernel if use_kernel else wkv6_ref
    out, state = fn(rh, kh, vh, wh, tm["u"], state)
    out = out.reshape(bsz, t, d).astype(x.dtype)
    out = _group_norm(out, tm["gn_w"], tm["gn_b"], h)
    out = out * jax.nn.silu(g)
    return jnp.dot(out, tm["w_o"].astype(x.dtype)), x[:, -1], state


def channel_mix(cm: dict, x: jax.Array, x_prev: jax.Array):
    xs = jnp.concatenate([x_prev[:, None].astype(x.dtype), x[:, :-1]], axis=1)
    xk = _lerp(x, xs, cm["mu_k"])
    xr = _lerp(x, xs, cm["mu_r"])
    k = jnp.square(jax.nn.relu(jnp.dot(xk, cm["w_k"].astype(x.dtype))))
    k = C.maybe_shard(k, "act_ff")
    kv = jnp.dot(k, cm["w_v"].astype(x.dtype))
    return jax.nn.sigmoid(jnp.dot(xr, cm["w_r"].astype(x.dtype))) * kv, x[:, -1]


def _layer(p: dict, x, tm_x, cm_x, wkv_state, cfg: ArchConfig,
           use_kernel: bool = False):
    h = C.layer_norm(x, p["ln1_w"], p["ln1_b"], cfg.norm_eps)
    out, tm_x, wkv_state = time_mix(p["tm"], h, tm_x, wkv_state, cfg, use_kernel)
    x = x + out
    h = C.layer_norm(x, p["ln2_w"], p["ln2_b"], cfg.norm_eps)
    out, cm_x = channel_mix(p["cm"], h, cm_x)
    return x + out, tm_x, cm_x, wkv_state


# ---------------------------------------------------------------------------
# public API (mirrors transformer.py)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch_size: int, max_seq: int, dtype=None):
    h, hs = _heads(cfg), cfg.rwkv_head_size
    sh = (cfg.num_layers, batch_size)
    return {
        "tm_x": jnp.zeros((*sh, cfg.d_model), jnp.float32),
        "cm_x": jnp.zeros((*sh, cfg.d_model), jnp.float32),
        "wkv": jnp.zeros((*sh, h, hs, hs), jnp.float32),
        "pos": jnp.zeros((batch_size,), jnp.int32),
    }


def _embed(params, tokens, cfg):
    dtype = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dtype)[tokens]
    return C.layer_norm(x, params["ln0_w"], params["ln0_b"], cfg.norm_eps)


def _run(params: dict, x: jax.Array, cache: dict, cfg: ArchConfig,
         use_kernel: bool = False):
    """Shared scan over layers for train/prefill/decode."""
    def layer(x, xs):
        p, tm_x, cm_x, st = xs
        x, tm_x, cm_x, st = _layer(p, x, tm_x, cm_x, st, cfg, use_kernel)
        x = C.maybe_shard(x, "act_btd")
        return x, (tm_x, cm_x, st)

    if cfg.remat:
        layer = jax.checkpoint(layer, prevent_cse=False)
    xs_all = (params["blocks"], cache["tm_x"], cache["cm_x"], cache["wkv"])
    if cfg.scan_layers:
        x, (tm_x, cm_x, st) = jax.lax.scan(layer, x, xs_all)
    else:
        outs = []
        for i in range(cfg.num_layers):
            x, ys = layer(x, jax.tree.map(lambda a: a[i], xs_all))
            outs.append(ys)
        tm_x = jnp.stack([o[0] for o in outs])
        cm_x = jnp.stack([o[1] for o in outs])
        st = jnp.stack([o[2] for o in outs])
    return x, {"tm_x": tm_x, "cm_x": cm_x, "wkv": st, "pos": cache["pos"]}


def forward(params: dict, batch: dict, cfg: ArchConfig):
    tokens = batch["tokens"]
    x = _embed(params, tokens, cfg)
    cache = init_cache(cfg, tokens.shape[0], 0)
    x, _ = _run(params, x, cache, cfg)
    x = C.layer_norm(x, params["lnf_w"], params["lnf_b"], cfg.norm_eps)
    logits = jnp.dot(x, params["lm_head"].astype(x.dtype),
                     preferred_element_type=jnp.float32)
    return logits, jnp.float32(0.0)


def prefill(params: dict, batch: dict, cfg: ArchConfig, cache: dict):
    x = _embed(params, batch["tokens"], cfg)
    x, cache = _run(params, x, cache, cfg)
    x = C.layer_norm(C.last_token_slice(x, batch),
                     params["lnf_w"], params["lnf_b"], cfg.norm_eps)
    logits = jnp.dot(x, params["lm_head"].astype(x.dtype),
                     preferred_element_type=jnp.float32)
    cache["pos"] = jnp.full((batch["tokens"].shape[0],),
                            batch["tokens"].shape[1], jnp.int32)
    return logits, cache


def decode_step(params: dict, tokens: jax.Array, cfg: ArchConfig, cache: dict):
    x = _embed(params, tokens, cfg)
    pos = cache["pos"]
    x, cache = _run(params, x, cache, cfg)
    x = C.layer_norm(x, params["lnf_w"], params["lnf_b"], cfg.norm_eps)
    logits = jnp.dot(x, params["lm_head"].astype(x.dtype),
                     preferred_element_type=jnp.float32)
    cache["pos"] = pos + 1
    return logits, cache
