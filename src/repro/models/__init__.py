"""Model zoo registry: ArchConfig -> ModelDef dispatch."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.models.arch import ArchConfig, ShapeConfig, SHAPES, LONG_CONTEXT_ARCHS  # noqa: F401


@dataclasses.dataclass(frozen=True)
class ModelDef:
    """Uniform interface every architecture implements."""
    init: Callable[..., dict]
    forward: Callable[..., tuple]        # (params, batch, cfg) -> (logits, aux)
    init_cache: Callable[..., dict]      # (cfg, batch, max_seq) -> cache
    prefill: Callable[..., tuple]        # (params, batch, cfg, cache)
    decode_step: Callable[..., tuple]    # (params, tokens, cfg, cache)


def get_model(cfg: ArchConfig) -> ModelDef:
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models import transformer as m
    elif cfg.family == "ssm":
        from repro.models import rwkv6 as m
    elif cfg.family == "hybrid":
        from repro.models import recurrentgemma as m
    elif cfg.family == "audio":
        from repro.models import whisper as m
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return ModelDef(init=m.init_params, forward=m.forward,
                    init_cache=m.init_cache, prefill=m.prefill,
                    decode_step=m.decode_step)
