"""RecurrentGemma-2B (Griffin, arXiv:2402.19427): RG-LRU + local attention.

26 layers in a repeating (recurrent, recurrent, attention) pattern (the 1:2
attention:recurrent ratio of the assignment). Blocks:

* **recurrent**: RMSNorm -> [x-branch: linear -> causal conv1d(4) -> RG-LRU]
  gated by [gate branch: linear -> GeLU] -> output linear -> residual.
  RG-LRU: a_t = a^(c * sigmoid(r_t)) with a = sigmoid(Lambda) (per channel),
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t).
* **attention**: local sliding-window (2048) MQA (kv=1) with rope.
* every block is followed by RMSNorm -> GeGLU MLP -> residual.

Because the pattern is heterogeneous, layers are a Python loop (26 unrolled
layers keep the HLO small enough). Decode state: ring KV for attention
layers, (conv tail, h) for recurrent layers — O(window + d_rnn), which is why
this arch runs ``long_500k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as C
from repro.models.arch import ArchConfig

_LRU_C = 8.0


def _pattern(cfg: ArchConfig) -> tuple[str, ...]:
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    reps = -(-cfg.num_layers // len(pat))
    return (pat * reps)[: cfg.num_layers]


def _d_rnn(cfg: ArchConfig) -> int:
    return cfg.d_rnn or cfg.d_model


def _attn_spec(cfg: ArchConfig, seq_len: int) -> C.AttnSpec:
    return C.AttnSpec(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                      head_dim=cfg.resolved_head_dim, causal=True,
                      window=cfg.window,
                      impl=C.resolve_attn_impl(cfg, seq_len),
                      chunk=cfg.attention_chunk)


def init_layer(key, kind: str, cfg: ArchConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    rnn = _d_rnn(cfg)
    ks = jax.random.split(key, 8)
    p: dict = {
        "ln_mix": jnp.zeros((d,), jnp.float32),
        "ln_mlp": jnp.zeros((d,), jnp.float32),
        "mlp": {
            "w_gate": C.dense_init(ks[0], d, ff),
            "w_up": C.dense_init(ks[1], d, ff),
            "w_down": C.dense_init(ks[2], ff, d),
        },
    }
    if kind == "attn":
        p["attn"] = C.init_attention(ks[3], d, _attn_spec(cfg, 1))
    else:
        p["rec"] = {
            "w_x": C.dense_init(ks[3], d, rnn),
            "w_gate": C.dense_init(ks[4], d, rnn),
            "conv_w": jax.random.normal(ks[5], (cfg.conv_width, rnn),
                                        jnp.float32) * 0.1,
            "conv_b": jnp.zeros((rnn,), jnp.float32),
            "lambda": jnp.ones((rnn,), jnp.float32) * 2.0,   # sigmoid -> a ~ .88
            "w_input_gate": C.dense_init(ks[6], rnn, rnn, scale=0.01),
            "b_input_gate": jnp.zeros((rnn,), jnp.float32),
            "w_rec_gate": C.dense_init(ks[7], rnn, rnn, scale=0.01),
            "b_rec_gate": jnp.zeros((rnn,), jnp.float32),
            "w_out": C.dense_init(jax.random.fold_in(key, 99), rnn, d),
        }
    return p


def init_params(key, cfg: ArchConfig) -> dict:
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_blocks, cfg.num_layers)
    blocks = [init_layer(layer_keys[i], kind, cfg)
              for i, kind in enumerate(_pattern(cfg))]
    return {
        "embed": C.embed_init(k_embed, cfg.vocab_size, cfg.d_model),
        "blocks": blocks,
        "ln_final": jnp.zeros((cfg.d_model,), jnp.float32),
        "lm_head": C.dense_init(k_head, cfg.d_model, cfg.vocab_size, scale=0.02),
    }


# ---------------------------------------------------------------------------
# RG-LRU + conv
# ---------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: jax.Array | None = None):
    """Depthwise causal conv. x (B,T,rnn); w (W,rnn); tail (B,W-1,rnn) carry.

    Returns (y, new tail). Width is small (4): computed as shifted adds.
    """
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xfull = jnp.concatenate([tail, x], axis=1)       # (B, T+W-1, rnn)
    y = jnp.zeros_like(x)
    t = x.shape[1]
    for j in range(width):
        y = y + xfull[:, j:j + t] * w[width - 1 - j].astype(x.dtype)
    y = y + b.astype(x.dtype)
    return y, xfull[:, -(width - 1):] if width > 1 else tail


def _rg_lru(rec: dict, x: jax.Array, h0: jax.Array):
    """x (B,T,rnn) post-conv; h0 (B,rnn) carried state. Returns (y, hT)."""
    x32 = x.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ rec["w_rec_gate"] + rec["b_rec_gate"])
    i = jax.nn.sigmoid(x32 @ rec["w_input_gate"] + rec["b_input_gate"])
    log_a_base = jax.nn.log_sigmoid(rec["lambda"])          # (rnn,) < 0
    log_a = _LRU_C * r * log_a_base[None, None, :]          # (B,T,rnn)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (i * x32)

    def step(h, xs):
        a_t, g_t = xs
        h = a_t * h + g_t
        return h, h

    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32),
                          (jnp.moveaxis(a, 1, 0), jnp.moveaxis(gated, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), hT


def _rec_block(rec: dict, x: jax.Array, conv_tail, h0):
    """Full recurrent temporal-mix branch. Returns (out, new conv tail, hT)."""
    xb = jnp.dot(x, rec["w_x"].astype(x.dtype))
    gate = jax.nn.gelu(jnp.dot(x, rec["w_gate"].astype(x.dtype)))
    xb, conv_tail = _causal_conv(xb, rec["conv_w"], rec["conv_b"], conv_tail)
    y, hT = _rg_lru(rec, xb, h0)
    out = jnp.dot(y * gate, rec["w_out"].astype(x.dtype))
    return out, conv_tail, hT


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def forward(params: dict, batch: dict, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"].astype(dtype)[tokens] * jnp.sqrt(cfg.d_model).astype(dtype)
    positions = jnp.arange(s)
    spec = _attn_spec(cfg, s)
    rnn = _d_rnn(cfg)

    for p, kind in zip(params["blocks"], _pattern(cfg)):
        def blk(x, p=p, kind=kind):
            h = C.rms_norm(x, p["ln_mix"], cfg.norm_eps)
            if kind == "attn":
                mix = C.attention_forward(p["attn"], h, positions, spec,
                                          cfg.rope_theta)
            else:
                h0 = jnp.zeros((b, rnn), jnp.float32)
                mix, _, _ = _rec_block(p["rec"], h, None, h0)
            x = x + mix
            h = C.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
            return x + C.swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                                p["mlp"]["w_down"])
        x = jax.checkpoint(blk)(x) if cfg.remat else blk(x)
        x = C.maybe_shard(x, "act_btd")

    x = C.rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = jnp.dot(x, params["lm_head"].astype(dtype),
                     preferred_element_type=jnp.float32)
    return logits, jnp.float32(0.0)


def init_cache(cfg: ArchConfig, batch_size: int, max_seq: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    rnn = _d_rnn(cfg)
    window = min(cfg.window or max_seq, max_seq)
    cache: dict = {"pos": jnp.zeros((batch_size,), jnp.int32), "layers": []}
    for kind in _pattern(cfg):
        if kind == "attn":
            cache["layers"].append({
                "k": jnp.zeros((batch_size, window, cfg.num_kv_heads,
                                cfg.resolved_head_dim), dtype),
                "v": jnp.zeros((batch_size, window, cfg.num_kv_heads,
                                cfg.resolved_head_dim), dtype),
            })
        else:
            cache["layers"].append({
                "conv": jnp.zeros((batch_size, cfg.conv_width - 1, rnn), dtype),
                "h": jnp.zeros((batch_size, rnn), jnp.float32),
            })
    return cache


def prefill(params: dict, batch: dict, cfg: ArchConfig, cache: dict):
    dtype = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"].astype(dtype)[tokens] * jnp.sqrt(cfg.d_model).astype(dtype)
    positions = jnp.arange(s)
    spec = _attn_spec(cfg, s)
    rnn = _d_rnn(cfg)
    new_layers = []

    for p, kind, lc in zip(params["blocks"], _pattern(cfg), cache["layers"]):
        h = C.rms_norm(x, p["ln_mix"], cfg.norm_eps)
        if kind == "attn":
            k, v = C.project_kv(p["attn"], h, positions, spec, cfg.rope_theta)
            mix = C.attention_forward(p["attn"], h, positions, spec,
                                      cfg.rope_theta)
            win = lc["k"].shape[1]
            keep = min(win, s)
            nk = lc["k"].at[:, :keep].set(k[:, -keep:].astype(lc["k"].dtype))
            nv = lc["v"].at[:, :keep].set(v[:, -keep:].astype(lc["v"].dtype))
            new_layers.append({"k": nk, "v": nv})
        else:
            h0 = jnp.zeros((b, rnn), jnp.float32)
            mix, tail, hT = _rec_block(p["rec"], h, None, h0)
            new_layers.append({"conv": tail.astype(lc["conv"].dtype), "h": hT})
        x = x + mix
        h = C.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
        x = x + C.swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                         p["mlp"]["w_down"])

    x = C.rms_norm(C.last_token_slice(x, batch), params["ln_final"],
                   cfg.norm_eps)
    logits = jnp.dot(x, params["lm_head"].astype(dtype),
                     preferred_element_type=jnp.float32)
    # NOTE: ring-buffer decode assumes slot = pos % window; prefill wrote the
    # last `keep` positions at slots [0, keep) which matches pos % window only
    # when s % window == 0 or s <= window. serve drivers use s <= window
    # prompts or align; documented simplification.
    return logits, {"pos": jnp.full((b,), s, jnp.int32), "layers": new_layers}


def decode_step(params: dict, tokens: jax.Array, cfg: ArchConfig, cache: dict):
    dtype = jnp.dtype(cfg.dtype)
    b = tokens.shape[0]
    x = params["embed"].astype(dtype)[tokens] * jnp.sqrt(cfg.d_model).astype(dtype)
    pos = cache["pos"]
    spec = _attn_spec(cfg, 1)
    new_layers = []

    for p, kind, lc in zip(params["blocks"], _pattern(cfg), cache["layers"]):
        h = C.rms_norm(x, p["ln_mix"], cfg.norm_eps)
        if kind == "attn":
            mix, nk, nv = C.attention_decode_step(
                p["attn"], h, lc["k"], lc["v"], pos, spec, cfg.rope_theta)
            new_layers.append({"k": nk, "v": nv})
        else:
            mix, tail, hT = _rec_block(p["rec"], h, lc["conv"].astype(h.dtype),
                                       lc["h"])
            new_layers.append({"conv": tail.astype(lc["conv"].dtype), "h": hT})
        x = x + mix
        h = C.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
        x = x + C.swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                         p["mlp"]["w_down"])

    x = C.rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = jnp.dot(x, params["lm_head"].astype(dtype),
                     preferred_element_type=jnp.float32)
    return logits, {"pos": pos + 1, "layers": new_layers}
