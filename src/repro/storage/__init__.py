# Persistence + out-of-core subsystem: the versioned on-disk index format
# (manifest + checksums + append journal), the chunked streaming builders,
# and the Hercules store facade owning the whole lifecycle
# (create -> append -> compact -> query). The serving-side out-of-core
# backends live in core/engine.py and consume SavedIndex.
from repro.storage.build import (  # noqa: F401
    build_index_streaming, build_index_to_disk, stream_base_files,
)
from repro.storage.codecs import (  # noqa: F401
    CODEC_CHOICES, Codec, get_codec, list_codecs, register_codec,
)
from repro.storage.format import (  # noqa: F401
    FORMAT_NAME, FORMAT_VERSION, IndexFormatError, SavedIndex, load_index,
    open_index, read_manifest, save_index, verify_files,
)
from repro.storage.partition import (  # noqa: F401
    BALANCE_WARN_RATIO, RECORDED_SHARD_COUNTS, ShardPlan, partition_plan,
    partition_section, shard_plan,
)
from repro.storage.store import Hercules  # noqa: F401
