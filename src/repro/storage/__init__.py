# Persistence + out-of-core subsystem: the versioned on-disk index format
# (save/load/open with manifest + checksums) and the chunked streaming
# builders that never materialize the collection. The serving-side
# out-of-core backends live in core/engine.py and consume SavedIndex.
from repro.storage.build import (  # noqa: F401
    build_index_streaming, build_index_to_disk,
)
from repro.storage.format import (  # noqa: F401
    FORMAT_NAME, FORMAT_VERSION, IndexFormatError, SavedIndex, load_index,
    open_index, read_manifest, save_index, verify_files,
)
