"""Versioned on-disk Hercules index format (the paper's persisted artifacts).

An index directory holds the three files the paper names plus a sidecar of
small arrays, an **append journal**, and a manifest that commits the whole
set:

    <dir>/
      manifest.json   format name + version, build/search config, statics,
                      per-file byte sizes and CRC32 checksums, journal
                      segment list. Written last (atomically) — its presence
                      commits every other file; anything on disk the
                      manifest does not name is an uncommitted orphan.
      tree.npz        HTree: every HerculesTree array (small, compressed).
      layout.npz      small layout arrays (perm, leaf extents, pruning
                      tables) — everything but the two big files.
      lrd.npy         LRDFile: raw series, leaf in-order, (n_pad, n) float32.
                      A plain ``np.save`` array => ``np.load(mmap_mode="r")``
                      serves it without reading it into RAM.
      lsd.npy         LSDFile: position-aligned iSAX sidecar, (n_pad, m) uint8.
      journal/        append segments (``seg-00000.lrd.npy`` + matching
                      ``.lsd.npy``): rows inserted since the last compaction,
                      in original append order — the store-level insert path
                      (``repro.storage.store.Hercules``) lands new chunks
                      here so appends never rewrite the base files.

Format version 2 adds the journal section and an optional per-file ``path``
indirection: a compaction writes its new base files under
*generation-numbered* names (``lrd-00001.npy``) and republishes the manifest
atomically, so the old index stays valid until the single
``os.replace(manifest)`` commit point — the ParIS+-style "organize for
appends, never rewrite in place" discipline. Version-1 directories (no
journal, plain file names) still load unchanged.

Format version 3 (this build) adds an optional **encoded leaf sidecar**:

    enc.npy         codec-encoded rows, position-aligned with lrd.npy,
                    (n_pad, row_bytes) uint8 — present only when the index
                    was built/compacted with a lossy codec. Out-of-core
                    backends stream it instead of lrd.npy (fewer bytes off
                    disk) and fall back to lrd.npy rows to make reported
                    answers exact. See ``repro/storage/codecs.py``.

plus a manifest ``codec`` section (``{"name", "row_bytes", "exact"}``).
Version-1/2 directories still load unchanged and report codec ``raw``;
``Hercules.compact(codec=...)`` migrates an index between codecs (the
sidecar is rebuilt whenever the base generation is rewritten).

Loading offers two shapes: :func:`load_index` materializes a full in-memory
:class:`HerculesIndex` (bit-identical to the one that was saved), while
:func:`open_index` returns a :class:`SavedIndex` handle whose LRD/LSD stay
memory-mapped — the out-of-core backends (``core/engine.py``) stream leaf and
scan blocks from it under a memory budget. Both read the committed **base**
index only; journal rows are layered on top by the
:class:`~repro.storage.store.Hercules` store handle.

Every load validates the manifest (format name, version <= supported) and,
with ``verify=True`` (the default), re-checksums every file — truncation or
corruption surfaces as a clear :class:`IndexFormatError` instead of garbage
answers.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import zlib

import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitize
from repro.core.index import HerculesIndex, IndexConfig
from repro.core.layout import HerculesLayout
from repro.core.search import SearchConfig
from repro.core.tree import BuildConfig, HerculesTree

FORMAT_NAME = "hercules-index"
FORMAT_VERSION = 3

MANIFEST_FILE = "manifest.json"
TREE_FILE = "tree.npz"
LAYOUT_FILE = "layout.npz"
LRD_FILE = "lrd.npy"
LSD_FILE = "lsd.npy"
ENC_FILE = "enc.npy"
_ARRAY_FILES = (TREE_FILE, LAYOUT_FILE, LRD_FILE, LSD_FILE)

JOURNAL_DIR = "journal"

# HerculesLayout fields persisted in layout.npz (everything but lrd/lsd and
# the static ints, which live in the manifest)
SMALL_LAYOUT_FIELDS = (
    "perm", "inv_perm", "leaf_rank", "leaf_node", "leaf_start", "leaf_count",
    "leaf_synopsis", "leaf_endpoints", "leaf_seg_lens", "series_leaf_rank")
LAYOUT_STATIC_FIELDS = ("series_len", "max_leaf", "num_leaves", "num_series")


class IndexFormatError(RuntimeError):
    """A saved index is missing, truncated, corrupted, or from an
    unsupported format version."""


# ---------------------------------------------------------------------------
# checksums
# ---------------------------------------------------------------------------

def _crc32_file(path: str, blocksize: int = 1 << 20) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            block = f.read(blocksize)
            if not block:
                return crc & 0xFFFFFFFF
            crc = zlib.crc32(block, crc)


def _file_entry(path: str) -> dict:
    return {"bytes": os.path.getsize(path), "crc32": _crc32_file(path)}


# ---------------------------------------------------------------------------
# manifest helpers (base files, journal section, generation naming)
# ---------------------------------------------------------------------------

def _config_meta(config: IndexConfig) -> dict:
    return {"build": dataclasses.asdict(config.build),
            "search": dataclasses.asdict(config.search),
            "sax_segments": config.sax_segments}


def array_path(manifest: dict, name: str) -> str:
    """Directory-relative path of a logical base file (``tree.npz`` …).

    Version-1 manifests (and version-2 saves before any compaction) store
    files under their logical names; after a compaction each entry carries a
    ``path`` pointing at the current generation's file.
    """
    entry = manifest.get("files", {}).get(name, {})
    return entry.get("path", name)


def generation_of(manifest: dict) -> int:
    return int(manifest.get("generation", 0))


def generation_name(name: str, generation: int) -> str:
    """``lrd.npy`` at generation 3 -> ``lrd-00003.npy`` (generation 0 keeps
    the plain v1 name so fresh saves remain byte-compatible)."""
    if generation == 0:
        return name
    stem, ext = os.path.splitext(name)
    return f"{stem}-{generation:05d}{ext}"


def journal_of(manifest: dict) -> dict:
    """The journal section, normalized (v1 manifests have none)."""
    j = manifest.get("journal") or {}
    return {"segments": list(j.get("segments", [])),
            "rows": int(j.get("rows", 0))}


def codec_of(manifest: dict) -> str:
    """Name of the leaf codec the base files were written with. Version-1/2
    manifests have no ``codec`` section and are raw by construction."""
    return str((manifest.get("codec") or {}).get("name", "raw"))


def has_base(manifest: dict) -> bool:
    """Whether the directory holds a committed base index (an empty store
    created by ``Hercules.create`` has only a manifest + journal)."""
    return bool(manifest.get("files"))


def segment_file_names(seg_id: int) -> tuple[str, str]:
    """(lrd, lsd) file names of journal segment ``seg_id``, dir-relative."""
    return (f"{JOURNAL_DIR}/seg-{seg_id:05d}.lrd.npy",
            f"{JOURNAL_DIR}/seg-{seg_id:05d}.lsd.npy")


def partition_of(manifest: dict) -> dict:
    """The shard-plan section, normalized. Manifests written before the
    distributed-serving subsystem have none — plans then derive on open
    (``repro.storage.partition.shard_plan``)."""
    p = manifest.get("partition") or {}
    return {"version": int(p.get("version", 0)),
            "balanced_by": str(p.get("balanced_by", "rows")),
            "plans": dict(p.get("plans", {}))}


def _partition_meta(path: str, entries: dict) -> dict | None:
    """One shard plan per generation, computed from the just-committed leaf
    tables (``layout.npz``) — every base commit (save, build, compact, and
    an append's republish) records the same deterministic cut
    ``shard_plan`` would derive on open."""
    from repro.storage.partition import partition_section

    entry = entries.get(LAYOUT_FILE)
    if entry is None:
        return None
    small = _load_npz(path, entry.get("path", LAYOUT_FILE))
    return partition_section(small["leaf_start"], small["leaf_count"])


def write_manifest(path: str, config: IndexConfig, max_depth: int,
                   statics: dict, extra: dict | None = None, *,
                   files: dict[str, str] | None = None,
                   entries: dict[str, dict] | None = None,
                   journal: dict | None = None,
                   generation: int = 0,
                   base: bool = True,
                   codec: str = "raw") -> dict:
    """Checksum the base array files already present under ``path`` and
    commit them — together with the journal segment list — by atomically
    publishing the manifest. The ``os.replace`` here is the single commit
    point of every store mutation (save, append, compact).

    ``files`` maps logical names to their directory-relative actual paths
    (identity by default); ``entries`` supplies already-computed checksum
    entries verbatim (an append republishes the untouched base files
    without re-reading them); ``base=False`` commits a manifest with no
    base index at all (an empty store awaiting its first compaction).
    ``codec`` names the leaf codec the base files carry; non-``raw`` codecs
    add the ``enc.npy`` sidecar to the committed file set.
    """
    from repro.storage.codecs import get_codec

    codec_impl = get_codec(codec)  # validates the name
    if entries is None:
        entries = {}
        if base:
            names = files or {}
            required = _ARRAY_FILES if codec == "raw" \
                else _ARRAY_FILES + (ENC_FILE,)
            for name in required:
                actual = names.get(name, name)
                fp = os.path.join(path, actual)
                if not os.path.exists(fp):
                    raise IndexFormatError(
                        f"cannot commit {path}: missing {actual}")
                entry = _file_entry(fp)
                if actual != name:
                    entry["path"] = actual
                entries[name] = entry
    else:
        entries = {name: dict(entry) for name, entry in entries.items()}
    series_len = int(statics.get("series_len", 0))
    manifest = {
        "format": FORMAT_NAME,
        "version": FORMAT_VERSION,
        "created_unix": time.time(),
        "config": _config_meta(config),
        "max_depth": int(max_depth),
        "layout_static": {k: int(v) for k, v in statics.items()},
        "files": entries,
        "generation": int(generation),
        "journal": journal_of({"journal": journal} if journal else {}),
        "codec": {"name": codec,
                  "row_bytes": codec_impl.row_bytes(series_len)
                  if series_len else 0,
                  "exact": bool(codec_impl.exact)},
        "partition": _partition_meta(path, entries),
        "extra": dict(extra or {}),
    }
    tmp = os.path.join(path, MANIFEST_FILE + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(path, MANIFEST_FILE))
    return manifest


def save_index(index: HerculesIndex, path: str,
               extra_meta: dict | None = None) -> dict:
    """Persist an in-memory index as an index directory. Returns the
    manifest. Overwrites any previous index at ``path`` (the stale manifest
    is removed first, so a failed overwrite never half-validates).

    .. deprecated:: store API
        Prefer ``repro.api.Hercules.from_index(path, index)``, which returns
        a live store handle supporting ``append``/``compact``. This function
        remains as the low-level writer the store delegates to.
    """
    os.makedirs(path, exist_ok=True)
    stale = os.path.join(path, MANIFEST_FILE)
    if os.path.exists(stale):
        os.remove(stale)

    np.savez_compressed(
        os.path.join(path, TREE_FILE),
        **{name: np.asarray(val) for name, val in index.tree._asdict().items()})
    lay = index.layout
    np.savez_compressed(
        os.path.join(path, LAYOUT_FILE),
        **{name: np.asarray(getattr(lay, name)) for name in SMALL_LAYOUT_FIELDS})
    np.save(os.path.join(path, LRD_FILE), np.asarray(lay.lrd))
    np.save(os.path.join(path, LSD_FILE), np.asarray(lay.lsd))

    statics = {k: getattr(lay, k) for k in LAYOUT_STATIC_FIELDS}
    return write_manifest(path, index.config, index.max_depth, statics,
                          extra=extra_meta)


# ---------------------------------------------------------------------------
# load
# ---------------------------------------------------------------------------

def read_manifest(path: str) -> dict:
    mf = os.path.join(path, MANIFEST_FILE)
    if not os.path.isdir(path) or not os.path.exists(mf):
        raise IndexFormatError(
            f"{path!r} is not an index directory (no {MANIFEST_FILE})")
    try:
        with open(mf) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise IndexFormatError(f"unreadable manifest in {path!r}: {e}") from e
    if manifest.get("format") != FORMAT_NAME:
        raise IndexFormatError(
            f"{path!r}: format {manifest.get('format')!r} is not "
            f"{FORMAT_NAME!r}")
    version = manifest.get("version")
    if not isinstance(version, int) or version > FORMAT_VERSION or version < 1:
        raise IndexFormatError(
            f"{path!r}: format version {version!r} not supported "
            f"(this build reads versions 1..{FORMAT_VERSION})")
    return manifest


def _verify_one(path: str, rel: str, entry: dict) -> None:
    fp = os.path.join(path, rel)
    if not os.path.exists(fp):
        raise IndexFormatError(f"{path!r}: missing file {rel}")
    size = os.path.getsize(fp)
    if size != entry["bytes"]:
        raise IndexFormatError(
            f"{path!r}: {rel} is {size} bytes, manifest says "
            f"{entry['bytes']} (truncated or overwritten)")
    crc = _crc32_file(fp)
    if crc != entry["crc32"]:
        raise IndexFormatError(
            f"{path!r}: {rel} checksum mismatch "
            f"(crc32 {crc:#010x} != {entry['crc32']:#010x}; corrupted)")


def verify_files(path: str, manifest: dict) -> None:
    """Check every manifest-listed file's size and CRC32 — base array files
    *and* journal segments. Raises :class:`IndexFormatError` naming the
    first bad file."""
    for name, entry in manifest.get("files", {}).items():
        _verify_one(path, entry.get("path", name), entry)
    for seg in journal_of(manifest)["segments"]:
        for rel, entry in seg.get("files", {}).items():
            _verify_one(path, rel, entry)


def _load_npz(path: str, rel: str) -> dict[str, np.ndarray]:
    try:
        with np.load(os.path.join(path, rel), allow_pickle=False) as z:
            return {k: z[k] for k in z.files}
    except (OSError, ValueError, zlib.error) as e:
        raise IndexFormatError(f"{path!r}: cannot read {rel}: {e}") from e


def _restore_config(manifest: dict) -> IndexConfig:
    cfg = manifest["config"]
    try:
        return IndexConfig(build=BuildConfig(**cfg["build"]),
                           search=SearchConfig(**cfg["search"]),
                           sax_segments=cfg["sax_segments"])
    except (KeyError, TypeError) as e:
        raise IndexFormatError(f"manifest config does not match this build's "
                               f"schema: {e}") from e


@dataclasses.dataclass
class SavedIndex:
    """An opened on-disk index: small state resident, big files memory-mapped.

    ``tree`` and the ``small`` layout arrays (a few MB) are loaded; ``lrd``
    and ``lsd`` stay as read-only memmaps until someone slices rows out of
    them — the handle the out-of-core backends stream from.

    The handle is a context manager; :meth:`close` (or leaving the ``with``
    block) releases the LRD/LSD memory maps deterministically instead of
    waiting for garbage collection — required for prompt file-descriptor
    release and for deleting the index directory on platforms that refuse to
    unlink mapped files.
    """
    path: str
    manifest: dict
    config: IndexConfig
    max_depth: int
    tree: HerculesTree
    small: dict[str, np.ndarray]
    lrd: np.ndarray   # (n_pad, n) float32 memmap
    lsd: np.ndarray   # (n_pad, m_sax) uint8 memmap
    series_len: int
    max_leaf: int
    num_leaves: int
    num_series: int
    codec: str = "raw"
    enc: np.ndarray | None = None  # (n_pad, row_bytes) uint8 memmap (lossy)

    @property
    def n_pad(self) -> int:
        return int(self._mapped("lrd").shape[0])

    @property
    def closed(self) -> bool:
        return self.lrd is None

    def _mapped(self, name: str) -> np.ndarray:
        arr = getattr(self, name)
        if arr is None:
            if name == "enc" and self.codec == "raw" and self.lrd is not None:
                raise IndexFormatError(
                    f"{self.path!r}: index has no encoded sidecar (codec is "
                    f"'raw'); stream lrd instead")
            raise IndexFormatError(
                f"{self.path!r}: SavedIndex is closed (its memory maps were "
                f"released); reopen the index to read {name}")
        return arr

    def close(self) -> None:
        """Release the LRD/LSD (and encoded-sidecar) memory maps. Idempotent.
        Any backend still holding this handle will fail loudly instead of
        reading a dead map."""
        for name in ("lrd", "lsd", "enc"):
            arr = getattr(self, name)
            setattr(self, name, None)
            release = getattr(arr, "release", None)
            if release is not None:     # REPRO_SANITIZE=1 MmapGuard:
                release()               # trips use-after-close loudly
                continue
            mm = getattr(arr, "_mmap", None)
            if mm is not None:
                try:
                    mm.close()
                except BufferError:
                    # live views (e.g. a backend mid-stream) still export the
                    # buffer; dropping our reference lets GC finish the job
                    pass

    def __enter__(self) -> "SavedIndex":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def to_layout(self) -> HerculesLayout:
        kw = {name: jnp.asarray(arr) for name, arr in self.small.items()}
        # explicit host copies: jnp.asarray may zero-copy alias an aligned
        # memmap on CPU, and the materialized layout must survive close()
        return HerculesLayout(
            lrd=jnp.asarray(np.array(self._mapped("lrd"))),
            lsd=jnp.asarray(np.array(self._mapped("lsd"))),
            series_len=self.series_len, max_leaf=self.max_leaf,
            num_leaves=self.num_leaves, num_series=self.num_series, **kw)

    def to_index(self) -> HerculesIndex:
        """Materialize the full in-memory index (device-resident layout)."""
        return HerculesIndex(self.tree, self.to_layout(), self.config,
                             self.max_depth)

    def original_data(self) -> np.ndarray:
        """The collection in original id order, (num_series, n) host float32
        (reads the whole LRD file — for verification harnesses, not the
        out-of-core serving path)."""
        return np.asarray(self._mapped("lrd"))[self.small["inv_perm"]]


def open_saved(path: str, manifest: dict) -> SavedIndex:
    """Open the committed base index described by an already-read (and, if
    desired, already-verified) manifest."""
    if not has_base(manifest):
        raise IndexFormatError(
            f"{path!r}: store has no base index yet (journal-only; append "
            f"then compact, or open it through repro.api.Hercules)")
    config = _restore_config(manifest)
    tree_arrays = _load_npz(path, array_path(manifest, TREE_FILE))
    try:
        tree = HerculesTree(**{name: jnp.asarray(tree_arrays[name])
                               for name in HerculesTree._fields})
    except KeyError as e:
        raise IndexFormatError(f"{path!r}: {TREE_FILE} is missing tree "
                               f"array {e}") from e
    small = _load_npz(path, array_path(manifest, LAYOUT_FILE))
    missing = set(SMALL_LAYOUT_FIELDS) - set(small)
    if missing:
        raise IndexFormatError(
            f"{path!r}: {LAYOUT_FILE} is missing {sorted(missing)}")
    try:
        lrd = np.load(os.path.join(path, array_path(manifest, LRD_FILE)),
                      mmap_mode="r", allow_pickle=False)
        lsd = np.load(os.path.join(path, array_path(manifest, LSD_FILE)),
                      mmap_mode="r", allow_pickle=False)
    except (OSError, ValueError) as e:
        raise IndexFormatError(f"{path!r}: cannot map raw arrays: {e}") from e
    statics = manifest["layout_static"]
    if (lrd.ndim != 2 or lrd.shape[1] != int(statics["series_len"])
            or lrd.shape[0] < int(statics["num_series"])):
        raise IndexFormatError(
            f"{path!r}: {LRD_FILE} shape {tuple(lrd.shape)} does not match "
            f"manifest statics {statics}")
    codec = codec_of(manifest)
    enc = None
    if codec != "raw":
        try:
            enc = np.load(os.path.join(path, array_path(manifest, ENC_FILE)),
                          mmap_mode="r", allow_pickle=False)
        except (OSError, ValueError) as e:
            raise IndexFormatError(
                f"{path!r}: cannot map encoded sidecar: {e}") from e
        row_bytes = int(manifest["codec"].get("row_bytes", 0))
        if (enc.ndim != 2 or enc.dtype != np.uint8
                or enc.shape != (lrd.shape[0], row_bytes)):
            raise IndexFormatError(
                f"{path!r}: {ENC_FILE} shape {tuple(enc.shape)}/{enc.dtype} "
                f"does not match manifest codec section {manifest['codec']}")
    # REPRO_SANITIZE=1 wraps the maps in use-after-close guards (no-op
    # pass-through otherwise): an escaped view raises UseAfterCloseError
    # instead of segfaulting (PR 4)
    lrd = sanitize.guard_mmap(lrd, f"{path}:lrd")
    lsd = sanitize.guard_mmap(lsd, f"{path}:lsd")
    if enc is not None:
        enc = sanitize.guard_mmap(enc, f"{path}:enc")
    return SavedIndex(
        path=path, manifest=manifest, config=config,
        max_depth=int(manifest["max_depth"]), tree=tree, small=small,
        lrd=lrd, lsd=lsd, codec=codec, enc=enc,
        **{k: int(statics[k]) for k in LAYOUT_STATIC_FIELDS})


def open_index(path: str, verify: bool = True) -> SavedIndex:
    """Open an index directory without materializing the big files.

    Reads the committed **base** index; rows sitting in the append journal
    (``Hercules.append`` without a ``compact``) are not visible through this
    handle — open the directory through ``repro.api.Hercules`` to serve
    base + journal together.
    """
    manifest = read_manifest(path)
    if verify:
        verify_files(path, manifest)
    return open_saved(path, manifest)


def load_index(path: str, verify: bool = True) -> HerculesIndex:
    """Load a saved index fully into memory — bit-identical arrays to the
    index that was saved.

    .. deprecated:: store API
        Prefer ``repro.api.Hercules.open(path)`` (use ``.index()`` for the
        in-memory materialization); this remains the low-level reader.
    """
    return open_index(path, verify=verify).to_index()
