"""Chunked streaming index construction (build-side out-of-core).

Two entry points over the same machinery:

* :func:`build_index_streaming` — chunked build to an **in-memory** index.
  Device residency during the build is bounded by one chunk (plus tree
  state); the finished layout is then materialized normally. Bit-identical
  to ``HerculesIndex.build`` on the same data (tests/test_storage.py).

* :func:`build_index_to_disk` — chunked build straight to an **index
  directory**: the LRD/LSD files are created as on-disk memmaps and each
  ingest chunk is scattered to its layout positions, so the full collection
  is never materialized in host or device memory. The result loads
  bit-identically to a save of the in-memory build.

Both consume a :class:`repro.data.pipeline.ChunkSource` (re-iterable, fixed
chunk boundaries) and move chunks host→device through the double-buffered
:func:`iter_device_chunks` stream. Every entry point takes a
``prefetch`` knob (``None`` → ``config.search.prefetch``):
``"thread"`` routes the chunk reads through the async reader
(:class:`repro.data.pipeline.AsyncChunkReader`), overlapping the memmap
read with tree-statistics compute and the layout scatter — the built
index is bit-identical to a ``"sync"`` build (the stream order is
deterministic in both modes).

The directory-writing half is factored as :func:`stream_base_files` so the
store-level compaction (``repro.storage.store.Hercules.compact``) can replay
base + journal rows through the *same* primitives into a new file
generation — which is what makes append+compact bit-identical to a
from-scratch build.

.. deprecated:: store API
    For new code, the one handle for the whole lifecycle is
    ``repro.api.Hercules`` (``create`` → ``append`` → ``compact`` →
    ``query``); ``build_index_to_disk`` is equivalent to
    ``Hercules.create(path, config, data=source)`` and both entry points
    here remain as the low-level builders the store delegates to.
"""
from __future__ import annotations

import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core import summaries as S
from repro.core.index import HerculesIndex, IndexConfig
from repro.core.layout import (assemble_layout, compute_layout_geometry,
                               leaf_tables, LayoutGeometry)
from repro.core.tree import HerculesTree, build_tree_chunked, tree_stats
from repro.data.pipeline import (ChunkSource, iter_device_chunks,
                                 iter_host_chunks)
from repro.storage.format import (ENC_FILE, LAYOUT_FILE, LAYOUT_STATIC_FIELDS,
                                  LRD_FILE, LSD_FILE, SMALL_LAYOUT_FIELDS,
                                  TREE_FILE, generation_name, write_manifest)


def _check_series_len(source: ChunkSource, config: IndexConfig) -> None:
    if source.series_len % config.sax_segments:
        raise ValueError(
            f"series length {source.series_len} must be divisible by "
            f"{config.sax_segments} iSAX segments")


def _resolve_prefetch(config: IndexConfig, prefetch: str | None) -> str:
    return config.search.prefetch if prefetch is None else prefetch


def _chunked_tree_and_geometry(source: ChunkSource, config: IndexConfig,
                               prefetch: str = "sync"):
    tree, node_of = build_tree_chunked(source, config.build,
                                       prefetch=prefetch)
    geo = compute_layout_geometry(
        tree, node_of, source.num_series, source.series_len,
        pad_series_to_multiple=config.search.pad_multiple())
    return tree, geo


def build_index_streaming(source: ChunkSource,
                          config: IndexConfig | None = None,
                          prefetch: str | None = None) -> HerculesIndex:
    """Chunk-streamed build of an in-memory index (never more than one chunk
    of raw series on device during construction).

    .. deprecated:: store API
        Prefer ``repro.api.Hercules`` for on-disk stores; this remains the
        low-level in-memory builder.
    """
    config = config or IndexConfig()
    prefetch = _resolve_prefetch(config, prefetch)
    _check_series_len(source, config)
    tree, geo = _chunked_tree_and_geometry(source, config, prefetch)

    n = source.series_len
    lrd = np.zeros((geo.n_pad, n), np.float32)
    lsd = np.zeros((geo.n_pad, config.sax_segments), np.uint8)
    for start, chunk in iter_device_chunks(source, prefetch=prefetch):
        pos = geo.inv_perm[start:start + chunk.shape[0]]
        lrd[pos] = np.asarray(chunk)
        lsd[pos] = np.asarray(S.isax(chunk, config.sax_segments))

    layout = assemble_layout(tree, geo, lrd, lsd)
    return HerculesIndex(tree, layout, config, tree_stats(tree)["max_depth"])


def _write_small_arrays(path: str, tree: HerculesTree, geo: LayoutGeometry,
                        names: dict[str, str]):
    """tree.npz + layout.npz from a built tree and its placement plan —
    identical bytes to what save_index writes for the same index."""
    np.savez_compressed(
        os.path.join(path, names[TREE_FILE]),
        **{name: np.asarray(val) for name, val in tree._asdict().items()})
    syn, ep, seg_lens = leaf_tables(tree, geo)
    small = {
        "perm": geo.perm, "inv_perm": geo.inv_perm,
        "leaf_rank": geo.leaf_rank, "leaf_node": geo.leaf_node,
        "leaf_start": geo.leaf_start, "leaf_count": geo.leaf_count,
        "leaf_synopsis": np.asarray(syn), "leaf_endpoints": np.asarray(ep),
        "leaf_seg_lens": np.asarray(seg_lens),
        "series_leaf_rank": geo.series_leaf_rank,
    }
    assert set(small) == set(SMALL_LAYOUT_FIELDS)
    np.savez_compressed(os.path.join(path, names[LAYOUT_FILE]), **small)


def stream_base_files(source: ChunkSource, path: str, config: IndexConfig,
                      generation: int = 0, prefetch: str | None = None,
                      codec: str = "raw"):
    """Chunk-streamed build of one base-file generation under ``path``.

    Writes ``tree.npz``/``layout.npz``/``lrd.npy``/``lsd.npy`` (suffixed by
    ``generation`` when nonzero) WITHOUT committing a manifest — callers
    (:func:`build_index_to_disk`, the store's ``compact``) publish the
    manifest as their own atomic commit step. A non-``raw`` ``codec``
    additionally writes the ``enc.npy`` sidecar: every chunk is encoded as
    it streams past, so the encoded file costs one extra scatter, not a
    second pass over the collection. Returns
    ``(names, statics, max_depth, timings)`` where ``names`` maps logical
    file names to the generation's actual names.
    """
    from repro.storage.codecs import get_codec

    codec_impl = get_codec(codec)
    _check_series_len(source, config)
    prefetch = _resolve_prefetch(config, prefetch)
    read_stats: dict = {}
    t0 = time.perf_counter()
    tree, geo = _chunked_tree_and_geometry(source, config, prefetch)
    t_tree = time.perf_counter() - t0

    os.makedirs(path, exist_ok=True)
    logical = [TREE_FILE, LAYOUT_FILE, LRD_FILE, LSD_FILE]
    if codec != "raw":
        logical.append(ENC_FILE)
    names = {name: generation_name(name, generation) for name in logical}

    # LRD/LSD as on-disk memmaps, scattered chunk by chunk. Pad rows beyond
    # num_series stay zero (ftruncate zero-fill) — the same bytes the
    # in-memory layout pads with.
    t0 = time.perf_counter()
    n = source.series_len
    lrd = np.lib.format.open_memmap(
        os.path.join(path, names[LRD_FILE]), mode="w+", dtype=np.float32,
        shape=(geo.n_pad, n))
    lsd = np.lib.format.open_memmap(
        os.path.join(path, names[LSD_FILE]), mode="w+", dtype=np.uint8,
        shape=(geo.n_pad, config.sax_segments))
    enc = None
    if codec != "raw":
        enc = np.lib.format.open_memmap(
            os.path.join(path, names[ENC_FILE]), mode="w+", dtype=np.uint8,
            shape=(geo.n_pad, codec_impl.row_bytes(n)))
    for start, chunk in iter_host_chunks(source, prefetch=prefetch,
                                         telemetry=read_stats):
        # the chunk may be a reusable reader-slot view: the device copy is
        # explicit (a jnp.asarray could zero-copy alias the slot, and the
        # next iteration's get() recycles it) and the numpy scatter below
        # copies the host bytes out within this iteration
        dev = jnp.array(chunk, copy=True)
        pos = geo.inv_perm[start:start + chunk.shape[0]]
        lrd[pos] = chunk
        lsd[pos] = np.asarray(S.isax(dev, config.sax_segments))
        if enc is not None:
            enc[pos] = codec_impl.encode(np.asarray(chunk))
    lrd.flush()
    lsd.flush()
    if enc is not None:
        enc.flush()
        del enc
    del lrd, lsd
    t_write = time.perf_counter() - t0

    _write_small_arrays(path, tree, geo, names)
    statics = {k: getattr(geo, k) for k in LAYOUT_STATIC_FIELDS}
    timings = {
        "streaming": True,
        "codec": codec,
        "chunk_size": source.chunk_size,
        "num_chunks": source.num_chunks,
        "prefetch": prefetch,
        "tree_seconds": round(t_tree, 3),
        "write_seconds": round(t_write, 3),
        "write_read_wait_seconds": round(
            read_stats.get("read_wait_seconds", 0.0), 3),
        "write_overlap_blocks": int(read_stats.get("overlap_blocks", 0)),
        "series_per_second": round(source.num_series / max(t_tree + t_write,
                                                           1e-9), 1),
    }
    return names, statics, tree_stats(tree)["max_depth"], timings


def build_index_to_disk(source: ChunkSource, path: str,
                        config: IndexConfig | None = None,
                        extra_meta: dict | None = None,
                        prefetch: str | None = None,
                        codec: str = "raw") -> dict:
    """Chunk-streamed build straight to an index directory; the collection
    only ever exists as the on-disk LRD file. Returns the manifest (plus
    timing under ``extra["build"]``).

    .. deprecated:: store API
        Equivalent to ``repro.api.Hercules.create(path, config,
        data=source)``, which additionally returns a live store handle;
        this remains the low-level writer the store delegates to.
    """
    config = config or IndexConfig()
    os.makedirs(path, exist_ok=True)
    stale = os.path.join(path, "manifest.json")
    if os.path.exists(stale):
        os.remove(stale)

    names, statics, max_depth, timings = stream_base_files(
        source, path, config, generation=0, prefetch=prefetch, codec=codec)
    extra = dict(extra_meta or {})
    extra["build"] = timings
    return write_manifest(path, config, max_depth, statics, extra=extra,
                          files=names, codec=codec)
